#!/usr/bin/env python3
"""Schema + regression-gate validator for the BENCH_*.json perf trajectory.

The perf bench (``cd rust && cargo bench -- perf --json``) emits one JSON
file per PR milestone — BENCH_pr2.json (phase thread sweep), BENCH_pr3.json
(static-vs-stealing skew sweep), BENCH_pr4.json (sub-lane split sweep),
BENCH_pr5.json (edge-level split sweep), BENCH_pr6.json
(barrier-vs-pipelined round sweep), BENCH_pr7.json
(hashed-vs-flat store layout sweep), BENCH_serving.json (closed-loop
serving sweep: open-loop arrivals with a whale burst under
``Admit::Static`` vs ``Admit::Adaptive``) and BENCH_pr9.json (streaming
mutation sweep: incremental hub2 maintenance over the epoch overlay vs
folding every batch into a fresh CSR and rebuilding the whole index) and
BENCH_pr10.json (multi-process sweep: the same query batch served
in-process and across worker processes over localhost TCP, with wire
gauges proving which mode actually ran).
This script is the single
source of truth for their shape, shared by the ``bench-smoke`` CI lane
and local runs:

    python3 ci/validate_bench.py rust/BENCH_*.json          # schema checks
    python3 ci/validate_bench.py --gate rust/BENCH_*.json   # + speedup floors
    python3 ci/validate_bench.py --selftest                 # validator self-checks

``--gate`` additionally compares every headline speedup found in the files
against its floor in ``ci/bench_floors.json`` and fails if any committed
headline fell below it. Set ``QUEGEL_BENCH_NO_GATE=1`` to downgrade gate
failures to warnings — CI smoke runs are single-rep measurements on shared
runners and their absolute numbers are not trajectory-grade.

Exit status: 0 on success, 1 on any schema failure (always) or gate
failure (unless downgraded).
"""

import json
import os
import sys

FLOORS_PATH = os.path.join(os.path.dirname(os.path.abspath(__file__)), "bench_floors.json")

PHASE_ROW_KEYS = (
    "threads",
    "compute_s",
    "exchange_s",
    "barrier_s",
    "wall_s",
    "compute_speedup_vs_t1",
    "exchange_barrier_speedup_vs_t1",
)


def fail(msg):
    raise AssertionError(msg)


def require_keys(row, keys, ctx):
    for k in keys:
        if k not in row:
            fail(f"{ctx}: row missing {k!r}: {row}")


def check_pr2(doc, name):
    workloads = doc.get("workloads")
    if not isinstance(workloads, dict) or not workloads:
        fail(f"{name}: missing/empty 'workloads'")
    for wname, rows in workloads.items():
        if not rows:
            fail(f"{name}: workload {wname!r} has no rows")
        for row in rows:
            require_keys(row, PHASE_ROW_KEYS, f"{name}:{wname}")
    print(f"{name} ok: {len(workloads)} workloads")


def check_pr3(doc, name):
    rows = doc.get("rows") or fail(f"{name}: skew sweep produced no rows")
    for row in rows:
        require_keys(
            row,
            (
                "sched",
                "threads",
                "compute_s",
                "exchange_s",
                "barrier_s",
                "phase_wall_s",
                "jobs_executed",
                "steals",
                "max_lane_imbalance",
            ),
            name,
        )
    if {r["sched"] for r in rows} != {"static", "stealing"}:
        fail(f"{name}: rows must cover both schedulers")
    print(
        f"{name} ok: {len(rows)} rows; stealing vs static at 4 threads:",
        doc["stealing_vs_static_phase_speedup_t4"],
    )


def check_pr4(doc, name):
    rows = doc.get("rows") or fail(f"{name}: split sweep produced no rows")
    for row in rows:
        require_keys(
            row,
            (
                "split",
                "threads",
                "compute_s",
                "exchange_s",
                "barrier_s",
                "subjobs_executed",
                "tasks_split",
                "max_lane_imbalance",
                "max_post_split_imbalance",
            ),
            name,
        )
    if {r["split"] for r in rows} != {"off", "adaptive"}:
        fail(f"{name}: rows must cover split off and adaptive")
    if not any(r["split"] == "adaptive" and r["subjobs_executed"] > 0 for r in rows):
        fail(f"{name}: split-on rows never executed a sub-job")
    if not all(r["subjobs_executed"] == 0 for r in rows if r["split"] == "off"):
        fail(f"{name}: split-off rows must not execute sub-jobs")
    print(
        f"{name} ok: {len(rows)} rows; split vs off at 4 threads:",
        doc["split_vs_off_compute_speedup_t4"],
    )


def check_pr5(doc, name):
    rows = doc.get("rows") or fail(f"{name}: edge-split sweep produced no rows")
    for row in rows:
        require_keys(
            row,
            (
                "edge_split",
                "threads",
                "compute_s",
                "exchange_s",
                "barrier_s",
                "edge_ranges_split",
                "max_edge_task",
                "subjobs_executed",
                "max_lane_imbalance",
                "max_post_split_imbalance",
            ),
            name,
        )
    if {r["edge_split"] for r in rows} != {"off", "adaptive"}:
        fail(f"{name}: rows must cover edge split off and adaptive")
    if not any(r["edge_split"] == "adaptive" and r["edge_ranges_split"] > 0 for r in rows):
        fail(f"{name}: edge-split-on rows never executed an edge-range job")
    if not all(r["edge_ranges_split"] == 0 for r in rows if r["edge_split"] == "off"):
        fail(f"{name}: edge-split-off rows must not execute edge-range jobs")
    # The mono-hub fan is the whole graph minus one vertex; a tiny
    # max_edge_task means the bench silently stopped generating the
    # pathology it exists to measure.
    if not any(r["max_edge_task"] >= doc.get("n", 0) - 1 for r in rows):
        fail(f"{name}: no row saw the full mono-hub fanout (n={doc.get('n')})")
    print(
        f"{name} ok: {len(rows)} rows; edge split vs off at 4 threads:",
        doc["edge_split_vs_off_compute_speedup_t4"],
    )


def check_pr6(doc, name):
    rows = doc.get("rows") or fail(f"{name}: pipeline sweep produced no rows")
    for row in rows:
        require_keys(
            row,
            (
                "pipeline",
                "threads",
                "wall_s",
                "compute_busy_s",
                "exchange_busy_s",
                "fold_busy_s",
                "overlap_s",
                "pipelined_rounds",
            ),
            name,
        )
    if {r["pipeline"] for r in rows} != {"barrier", "pipelined"}:
        fail(f"{name}: rows must cover barrier and pipelined rounds")
    if not any(
        r["pipeline"] == "pipelined" and r["threads"] > 1 and r["pipelined_rounds"] > 0
        for r in rows
    ):
        fail(f"{name}: threaded pipelined rows never ran a ready-driven round")
    if not all(r["pipelined_rounds"] == 0 for r in rows if r["pipeline"] == "barrier"):
        fail(f"{name}: barrier rows must not run ready-driven rounds")
    # Busy accounting sanity: phase busy seconds can exceed the wall under
    # overlap, but never by more than the thread count; overlap is a
    # wall-time sub-interval. A generous 1.25 slack absorbs timer jitter
    # on loaded CI runners without letting double-counting bugs through.
    for r in rows:
        busy = r["compute_busy_s"] + r["exchange_busy_s"] + r["fold_busy_s"]
        if busy > r["threads"] * r["wall_s"] * 1.25 + 1e-4:
            fail(
                f"{name}: phase busy sum {busy:.6f}s exceeds threads x wall "
                f"({r['threads']} x {r['wall_s']:.6f}s): double-counted time?"
            )
        if r["overlap_s"] > r["wall_s"] * 1.25 + 1e-4:
            fail(
                f"{name}: overlap {r['overlap_s']:.6f}s exceeds wall "
                f"{r['wall_s']:.6f}s"
            )
    print(
        f"{name} ok: {len(rows)} rows; pipelined vs barrier wall at 4 threads:",
        doc["pipeline_vs_barrier_wall_speedup_t4"],
    )


def check_pr7(doc, name):
    rows = doc.get("rows") or fail(f"{name}: layout sweep produced no rows")
    for row in rows:
        require_keys(
            row,
            (
                "graph",
                "layout",
                "threads",
                "compute_s",
                "exchange_s",
                "barrier_s",
                "staging_bytes_peak",
            ),
            name,
        )
    if {r["layout"] for r in rows} != {"hashed", "flat"}:
        fail(f"{name}: rows must cover both store layouts")
    want_graphs = {"hub_concentrated", "mega_hub", "mono_hub"}
    if {r["graph"] for r in rows} != want_graphs:
        fail(f"{name}: rows must cover graphs {sorted(want_graphs)}")
    # Engagement: only the flat columnar staging path ever moves the
    # staging_bytes_peak gauge — a flat sweep that never touched it
    # silently measured the hashed path twice.
    if not any(r["layout"] == "flat" and r["staging_bytes_peak"] > 0 for r in rows):
        fail(f"{name}: flat rows never engaged the columnar staging buffers")
    if not all(r["staging_bytes_peak"] == 0 for r in rows if r["layout"] == "hashed"):
        fail(f"{name}: hashed rows must not move the flat staging gauge")
    print(
        f"{name} ok: {len(rows)} rows; flat vs hashed at 4 threads (geomean):",
        doc["flat_vs_hashed_compute_speedup_t4"],
    )


SERVING_ROW_KEYS = (
    "admit",
    "threads",
    "completed",
    "qps",
    "qps_wall",
    "p50_s",
    "p99_s",
    "p999_s",
    "queueing_p99_s",
    "admit_deferrals",
    "backpressured",
    "wall_s",
)


def check_serving(doc, name):
    rows = doc.get("rows") or fail(f"{name}: serving sweep produced no rows")
    for row in rows:
        require_keys(row, SERVING_ROW_KEYS, name)
    if {r["admit"] for r in rows} != {"static", "adaptive"}:
        fail(f"{name}: rows must cover both admission modes")
    for r in rows:
        if r["completed"] <= 0 or r["qps"] <= 0:
            fail(f"{name}: {r['admit']}@t{r['threads']} completed nothing")
        # Streaming-sketch percentiles are bucket upper edges, so exact
        # monotonicity holds; any inversion means the sketch regressed.
        if not (r["p50_s"] <= r["p99_s"] <= r["p999_s"]):
            fail(
                f"{name}: {r['admit']}@t{r['threads']} percentile inversion "
                f"p50={r['p50_s']} p99={r['p99_s']} p99.9={r['p999_s']}"
            )
    # Engagement: the whale burst must force the adaptive planner to
    # defer at least once, and the static planner must never defer — a
    # sweep where both hold 0 silently measured Static twice.
    if not any(r["admit"] == "adaptive" and r["admit_deferrals"] > 0 for r in rows):
        fail(f"{name}: adaptive rows never engaged the admission planner")
    if not all(r["admit_deferrals"] == 0 for r in rows if r["admit"] == "static"):
        fail(f"{name}: static rows must not defer admissions")
    print(
        f"{name} ok: {len(rows)} rows; adaptive vs static p99 at 4 threads:",
        doc["adaptive_vs_static_p99_improvement_t4"],
    )


MUT_ROW_KEYS = (
    "mode",
    "threads",
    "wall_s",
    "maint_s",
    "epochs_applied",
    "delta_bytes_peak",
    "completed",
)


def check_pr9(doc, name):
    rows = doc.get("rows") or fail(f"{name}: mutation sweep produced no rows")
    for row in rows:
        require_keys(row, MUT_ROW_KEYS, name)
    if {r["mode"] for r in rows} != {"incremental", "rebuild"}:
        fail(f"{name}: rows must cover both maintenance modes")
    for r in rows:
        if r["completed"] <= 0:
            fail(f"{name}: {r['mode']}@t{r['threads']} completed nothing")
        if r["wall_s"] <= 0 or r["maint_s"] < 0:
            fail(f"{name}: {r['mode']}@t{r['threads']} nonsensical timing")
    # Engagement: incremental rows must have pushed every batch through the
    # epoch overlay; rebuild rows run immutable engines only, so their
    # epoch gauge must stay at exactly 0 — a nonzero value means the sweep
    # silently measured the overlay twice.
    for r in rows:
        if r["mode"] == "incremental" and not (
            r["epochs_applied"] > 0 and r["delta_bytes_peak"] > 0
        ):
            fail(f"{name}: incremental@t{r['threads']} never engaged the overlay")
        if r["mode"] == "rebuild" and r["epochs_applied"] != 0:
            fail(f"{name}: rebuild@t{r['threads']} must not apply epochs")
    # Both strategies answer the same query stream, so completion counts
    # must agree per thread setting.
    by_threads = {}
    for r in rows:
        by_threads.setdefault(r["threads"], {})[r["mode"]] = r["completed"]
    for t, modes in sorted(by_threads.items()):
        if len(modes) == 2 and modes["incremental"] != modes["rebuild"]:
            fail(f"{name}: completed counts diverge at t{t}: {modes}")
    print(
        f"{name} ok: {len(rows)} rows; incremental vs rebuild wall at 4 threads:",
        doc["hub2_incremental_vs_rebuild_speedup_t4"],
    )


PROC_ROW_KEYS = (
    "procs",
    "wall_s",
    "bytes_on_wire",
    "rpc_round_trips",
    "completed",
)


def check_pr10(doc, name):
    rows = doc.get("rows") or fail(f"{name}: multi-process sweep produced no rows")
    for row in rows:
        require_keys(row, PROC_ROW_KEYS, name)
    procs = {r["procs"] for r in rows}
    if 1 not in procs or not any(p > 1 for p in procs):
        fail(f"{name}: rows must cover procs=1 and at least one procs>1 setting")
    for r in rows:
        if r["completed"] <= 0:
            fail(f"{name}: procs={r['procs']} completed nothing")
        if r["wall_s"] <= 0:
            fail(f"{name}: procs={r['procs']} nonsensical timing")
    # Engagement: the wire gauges are the proof of mode. A 1-process run
    # delegates fully in-process and must never touch the socket; an
    # N-process run cannot complete a single query without the exchange
    # riding the wire — zero bytes there means the sweep silently
    # measured the in-process engine twice.
    for r in rows:
        if r["procs"] == 1 and (r["bytes_on_wire"] != 0 or r["rpc_round_trips"] != 0):
            fail(f"{name}: procs=1 row moved the wire gauges: {r}")
        if r["procs"] > 1 and not (r["bytes_on_wire"] > 0 and r["rpc_round_trips"] > 0):
            fail(f"{name}: procs={r['procs']} row never engaged the wire")
    # Every row serves the identical query batch (the bench asserts the
    # outputs bit-identical), so completion counts must agree.
    if len({r["completed"] for r in rows}) != 1:
        fail(f"{name}: completed counts diverge across the process sweep")
    print(f"{name} ok: {len(rows)} rows; procs swept: {sorted(procs)}")


CHECKERS = {
    "perf_engine": check_pr2,
    "perf_skew_sched": check_pr3,
    "perf_sublane_split": check_pr4,
    "perf_edge_split": check_pr5,
    "perf_pipeline": check_pr6,
    "perf_flat_layout": check_pr7,
    "perf_serving": check_serving,
    "perf_mutation_maintenance": check_pr9,
    "perf_multiprocess": check_pr10,
}


def gate(docs):
    """Compare every headline found in `docs` against its committed floor."""
    with open(FLOORS_PATH) as f:
        floors = {k: v for k, v in json.load(f).items() if not k.startswith("_")}
    advisory = os.environ.get("QUEGEL_BENCH_NO_GATE", "") not in ("", "0")
    failures = []
    checked = 0
    for name, doc in docs:
        for key, floor in floors.items():
            if key not in doc:
                continue
            checked += 1
            value = doc[key]
            status = "ok" if value >= floor else "BELOW FLOOR"
            print(f"gate: {name}: {key} = {value:.3f} (floor {floor}) {status}")
            if value < floor:
                failures.append(f"{name}: {key} = {value:.3f} < floor {floor}")
    if checked == 0:
        failures.append("gate: no headline speedup found in any input file")
    if failures:
        for f_ in failures:
            print(f"gate failure: {f_}", file=sys.stderr)
        if advisory:
            print("QUEGEL_BENCH_NO_GATE set: gate failures are advisory (smoke noise)")
            return True
        return False
    return True


def _serving_fixture():
    """A minimal trajectory-grade BENCH_serving.json document."""

    def row(admit, threads, deferrals, p99):
        return {
            "admit": admit,
            "threads": threads,
            "completed": 330,
            "qps": 5.0,
            "qps_wall": 1200.0,
            "p50_s": 0.4,
            "p99_s": p99,
            "p999_s": p99 * 4.0,
            "queueing_p99_s": p99 * 0.5,
            "admit_deferrals": deferrals,
            "backpressured": 2,
            "wall_s": 0.25,
        }

    return {
        "pr": 8,
        "bench": "perf_serving",
        "rows": [
            row("static", 1, 0, 2.0),
            row("adaptive", 1, 9, 1.0),
            row("static", 4, 0, 2.0),
            row("adaptive", 4, 9, 1.0),
        ],
        "adaptive_vs_static_p99_improvement_t4": 2.0,
    }


def _pr9_fixture():
    """A minimal trajectory-grade BENCH_pr9.json document."""

    def row(mode, threads, wall, epochs, delta):
        return {
            "mode": mode,
            "threads": threads,
            "wall_s": wall,
            "maint_s": wall * 0.6,
            "epochs_applied": epochs,
            "delta_bytes_peak": delta,
            "completed": 96,
        }

    return {
        "pr": 9,
        "bench": "perf_mutation_maintenance",
        "rows": [
            row("incremental", 1, 0.2, 6, 4096),
            row("rebuild", 1, 0.5, 0, 0),
            row("incremental", 4, 0.1, 6, 4096),
            row("rebuild", 4, 0.3, 0, 0),
        ],
        "hub2_incremental_vs_rebuild_speedup_t4": 3.0,
    }


def _pr10_fixture():
    """A minimal trajectory-grade BENCH_pr10.json document."""

    def row(procs, wall, wire, rpcs):
        return {
            "procs": procs,
            "wall_s": wall,
            "bytes_on_wire": wire,
            "rpc_round_trips": rpcs,
            "completed": 48,
        }

    return {
        "pr": 10,
        "bench": "perf_multiprocess",
        "graph": "twitter_like",
        "n": 30000,
        "workers": 8,
        "capacity": 8,
        "queries": 48,
        "procs_swept": [1, 2],
        "reps": 1,
        "smoke": False,
        "rows": [row(1, 0.4, 0, 0), row(2, 0.9, 1_500_000, 240)],
    }


def selftest():
    """Validator self-checks on synthetic in-memory fixtures.

    Run by CI on every PR so that a regression in the validator itself
    (a checker that silently accepts malformed rows, or gate logic that
    stops comparing floors) fails the PR rather than the next nightly.
    """

    def expect_rejected(doc, label):
        try:
            CHECKERS[doc["bench"]](doc, label)
        except (AssertionError, KeyError):
            return
        fail(f"selftest: {label} should have been rejected")

    good = _serving_fixture()
    CHECKERS[good["bench"]](good, "fixture-good")

    no_rows = _serving_fixture()
    no_rows["rows"] = []
    expect_rejected(no_rows, "fixture-no-rows")

    missing_key = _serving_fixture()
    del missing_key["rows"][0]["p999_s"]
    expect_rejected(missing_key, "fixture-missing-row-key")

    one_mode = _serving_fixture()
    one_mode["rows"] = [r for r in one_mode["rows"] if r["admit"] == "static"]
    expect_rejected(one_mode, "fixture-static-only")

    never_deferred = _serving_fixture()
    for r in never_deferred["rows"]:
        r["admit_deferrals"] = 0
    expect_rejected(never_deferred, "fixture-planner-never-engaged")

    static_deferred = _serving_fixture()
    static_deferred["rows"][0]["admit_deferrals"] = 3
    expect_rejected(static_deferred, "fixture-static-deferred")

    inverted = _serving_fixture()
    inverted["rows"][1]["p50_s"] = inverted["rows"][1]["p999_s"] * 2.0
    expect_rejected(inverted, "fixture-percentile-inversion")

    no_headline = _serving_fixture()
    del no_headline["adaptive_vs_static_p99_improvement_t4"]
    expect_rejected(no_headline, "fixture-missing-headline")

    mut_good = _pr9_fixture()
    CHECKERS[mut_good["bench"]](mut_good, "fixture-pr9-good")

    mut_one_mode = _pr9_fixture()
    mut_one_mode["rows"] = [r for r in mut_one_mode["rows"] if r["mode"] == "rebuild"]
    expect_rejected(mut_one_mode, "fixture-pr9-rebuild-only")

    mut_rebuild_epochs = _pr9_fixture()
    mut_rebuild_epochs["rows"][1]["epochs_applied"] = 2
    expect_rejected(mut_rebuild_epochs, "fixture-pr9-rebuild-applied-epochs")

    mut_no_overlay = _pr9_fixture()
    for r in mut_no_overlay["rows"]:
        if r["mode"] == "incremental":
            r["delta_bytes_peak"] = 0
    expect_rejected(mut_no_overlay, "fixture-pr9-overlay-never-engaged")

    mut_diverged = _pr9_fixture()
    mut_diverged["rows"][2]["completed"] = 95
    expect_rejected(mut_diverged, "fixture-pr9-completed-diverge")

    mut_no_headline = _pr9_fixture()
    del mut_no_headline["hub2_incremental_vs_rebuild_speedup_t4"]
    expect_rejected(mut_no_headline, "fixture-pr9-missing-headline")

    mp_good = _pr10_fixture()
    CHECKERS[mp_good["bench"]](mp_good, "fixture-pr10-good")

    mp_one_proc = _pr10_fixture()
    mp_one_proc["rows"] = [r for r in mp_one_proc["rows"] if r["procs"] == 1]
    expect_rejected(mp_one_proc, "fixture-pr10-single-process-only")

    mp_local_wire = _pr10_fixture()
    mp_local_wire["rows"][0]["bytes_on_wire"] = 64
    expect_rejected(mp_local_wire, "fixture-pr10-inprocess-moved-wire-gauge")

    mp_dry_wire = _pr10_fixture()
    mp_dry_wire["rows"][1]["bytes_on_wire"] = 0
    expect_rejected(mp_dry_wire, "fixture-pr10-multiprocess-never-on-wire")

    mp_diverged = _pr10_fixture()
    mp_diverged["rows"][1]["completed"] = 47
    expect_rejected(mp_diverged, "fixture-pr10-completed-diverge")

    mp_missing_key = _pr10_fixture()
    del mp_missing_key["rows"][0]["rpc_round_trips"]
    expect_rejected(mp_missing_key, "fixture-pr10-missing-row-key")

    # Gate logic against the committed floors file: the good fixture's
    # headline (2.0) clears the serving floor; a sub-floor headline must
    # fail strictly and pass only when downgraded to advisory.
    saved = os.environ.pop("QUEGEL_BENCH_NO_GATE", None)
    try:
        if not gate([("fixture-good", good)]):
            fail("selftest: gate rejected a headline above its floor")
        low = _serving_fixture()
        low["adaptive_vs_static_p99_improvement_t4"] = 0.5
        if gate([("fixture-low", low)]):
            fail("selftest: gate accepted a headline below its floor")
        os.environ["QUEGEL_BENCH_NO_GATE"] = "1"
        if not gate([("fixture-low", low)]):
            fail("selftest: advisory mode must downgrade gate failures")
    finally:
        os.environ.pop("QUEGEL_BENCH_NO_GATE", None)
        if saved is not None:
            os.environ["QUEGEL_BENCH_NO_GATE"] = saved

    print(
        "selftest ok: serving + mutation + multi-process checkers and gate "
        "fixtures all behaved"
    )


def main(argv):
    if "--selftest" in argv:
        try:
            selftest()
        except AssertionError as e:
            print(f"selftest failure: {e}", file=sys.stderr)
            return 1
        return 0
    args = [a for a in argv if a != "--gate"]
    run_gate = "--gate" in argv
    if not args:
        print(__doc__)
        print("error: no BENCH_*.json files given", file=sys.stderr)
        return 1
    docs = []
    ok = True
    for path in args:
        name = os.path.basename(path)
        try:
            with open(path) as f:
                doc = json.load(f)
            bench = doc.get("bench")
            checker = CHECKERS.get(bench)
            if checker is None:
                fail(f"{name}: unknown bench kind {bench!r}")
            checker(doc, name)
            docs.append((name, doc))
        except (AssertionError, OSError, json.JSONDecodeError, KeyError) as e:
            print(f"schema failure: {name}: {e}", file=sys.stderr)
            ok = False
    if ok and run_gate:
        ok = gate(docs)
    return 0 if ok else 1


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
