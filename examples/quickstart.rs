//! Quickstart: generate a small social graph, run interactive PPSP queries
//! with BFS and bidirectional BFS, and print per-query stats.
//!
//!     cargo run --release --offline --example quickstart

use quegel::apps::ppsp::{Bfs, BiBfs};
use quegel::coordinator::Engine;
use quegel::graph::gen;
use quegel::metrics::{fmt_pct, fmt_secs, Table};
use quegel::network::Cluster;

fn main() {
    // A Twitter-like graph: skewed in-degrees, one weak component.
    let n = 20_000;
    let mut g = gen::twitter_like(n, 8, 1);
    g.ensure_in_edges();
    println!(
        "graph: |V| = {}, |E| = {}, max deg = {}, avg deg = {:.1}",
        g.num_vertices(),
        g.num_edges(),
        g.max_degree(),
        g.avg_degree()
    );

    let cluster = Cluster::new(8); // 8 simulated workers
    let queries = gen::random_pairs(n, 8, 2);

    // Interactive mode: one query at a time, BFS vs BiBFS.
    let mut table = Table::new(vec![
        "query", "algo", "d(s,t)", "supersteps", "access", "sim time",
    ]);
    for &(s, t) in &queries {
        let mut eng = Engine::new(Bfs::new(&g), cluster.clone(), n);
        let r = eng.run_one((s, t));
        table.row(vec![
            format!("({s},{t})"),
            "BFS".into(),
            r.out.map_or("inf".into(), |d| d.to_string()),
            r.stats.supersteps.to_string(),
            fmt_pct(r.stats.access_rate),
            fmt_secs(r.stats.processing()),
        ]);
        let mut eng = Engine::new(BiBfs::new(&g), cluster.clone(), n);
        let r = eng.run_one((s, t));
        table.row(vec![
            format!("({s},{t})"),
            "BiBFS".into(),
            r.out.map_or("inf".into(), |d| d.to_string()),
            r.stats.supersteps.to_string(),
            fmt_pct(r.stats.access_rate),
            fmt_secs(r.stats.processing()),
        ]);
    }
    println!("{}", table.render());
    println!("BiBFS touches far less of the graph — the access-rate gap is");
    println!("what Quegel's query-centric design exploits (paper §6).");
}
