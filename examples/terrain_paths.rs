//! Terrain shortest-path demo (paper §5.3): build a fractal DEM, transform
//! it into the ε-shortcut network, answer P2P queries with the distributed
//! SSSP (early termination), and compare against the Chen–Han stand-in.
//!
//!     cargo run --release --offline --example terrain_paths

use quegel::apps::terrain::baseline::{hausdorff, ChResult, ChenHanStandIn};
use quegel::apps::terrain::{Dem, TerrainNet, TerrainSssp};
use quegel::coordinator::Engine;
use quegel::metrics::{fmt_pct, fmt_secs, Table};
use quegel::network::Cluster;

fn main() {
    let dem = Dem::fractal(101, 140, 10.0, 300.0, 17);
    println!(
        "DEM: {}x{} @ {}m, TIN faces = {}",
        dem.width,
        dem.height,
        dem.spacing,
        dem.tin_faces()
    );
    let net = TerrainNet::build(&dem, 2.0);
    println!(
        "eps-network: |V| = {}, |E| = {}",
        net.graph.num_vertices(),
        net.graph.num_edges()
    );

    let ch = ChenHanStandIn::new(&dem);
    let cluster = Cluster::new(8);
    let mut table = Table::new(vec![
        "query", "cells", "quegel len", "steps", "access", "sim time", "CH len", "CH time",
        "HDist",
    ]);
    // Paper's query ladder: destinations 2^2 .. 2^6 cells along the diagonal.
    for (qi, exp) in (2..=6).enumerate() {
        let d = 1usize << exp;
        let (tx, ty) = (d.min(dem.width - 1), d.min(dem.height - 1));
        let s = net.corner(0, 0);
        let t = net.corner(tx, ty);
        let mut eng = Engine::new(TerrainSssp::new(&net), cluster.clone(), net.graph.num_vertices());
        let r = eng.run_one((s, t));
        let (ch_len, ch_time, hd) = match ch.query(0, 0, tx, ty) {
            ChResult::Ok {
                len,
                modeled_secs,
                path,
            } => (
                format!("{len:.1} m"),
                fmt_secs(modeled_secs),
                format!("{:.2} m", hausdorff(&r.out.path, &path)),
            ),
            ChResult::Oom => ("-".into(), "OOM".into(), "-".into()),
        };
        table.row(vec![
            format!("Q{}", qi + 1),
            d.to_string(),
            format!("{:.1} m", r.out.dist),
            r.stats.supersteps.to_string(),
            fmt_pct(r.stats.access_rate),
            fmt_secs(r.stats.processing()),
            ch_len,
            ch_time,
            hd,
        ]);
    }
    println!("{}", table.render());
    println!("CH blows up quadratically with distance while the Quegel");
    println!("network scales; HDist stays within a few meters (paper Tab 10).");
}
