//! P2P reachability demo (paper §5.4): condense a web-like digraph, build
//! the level/yes/no labels as Quegel jobs, then serve indexed queries.
//!
//!     cargo run --release --offline --example reachability

use quegel::apps::reach::{build_labels, condense, ReachQuery};
use quegel::coordinator::Engine;
use quegel::graph::gen;
use quegel::metrics::{fmt_pct, fmt_secs, Table};
use quegel::network::Cluster;

fn main() {
    let n = 60_000;
    let g = gen::web_cyclic(n, 120, 3, 21);
    println!("graph: |V| = {}, |E| = {}", g.num_vertices(), g.num_edges());

    let cond = condense(&g);
    let mut dag = cond.dag.clone();
    dag.ensure_in_edges();
    println!(
        "condensation: |V_DAG| = {}, |E_DAG| = {}",
        dag.num_vertices(),
        dag.num_edges()
    );

    let cluster = Cluster::new(8);
    let (labels, lstats) = build_labels(&dag, &cluster, true);
    println!(
        "labels: level {} (in {} supersteps), yes {}, no {}",
        fmt_secs(lstats.level_time),
        lstats.level_supersteps,
        fmt_secs(lstats.yes_time),
        fmt_secs(lstats.no_time)
    );

    let queries = gen::random_pairs(n, 1_000, 22);
    let app = ReachQuery::new(&dag, &labels);
    let mut eng = Engine::new(app, cluster, dag.num_vertices()).capacity(8);
    for &(s, t) in &queries {
        eng.submit((cond.scc_of[s as usize], cond.scc_of[t as usize]));
    }
    eng.run_until_idle();

    let mut reach = 0usize;
    let mut label_only = 0usize;
    let mut access = 0.0;
    for r in eng.results() {
        if r.out {
            reach += 1;
        }
        if r.stats.supersteps <= 1 {
            label_only += 1;
        }
        access += r.stats.access_rate;
    }
    let mut t = Table::new(vec!["metric", "value"]);
    t.row(vec!["queries".to_string(), queries.len().to_string()]);
    t.row(vec!["sim time".into(), fmt_secs(eng.sim_time())]);
    t.row(vec![
        "avg / query".into(),
        fmt_secs(eng.sim_time() / queries.len() as f64),
    ]);
    t.row(vec!["reachable".into(), fmt_pct(reach as f64 / queries.len() as f64)]);
    t.row(vec![
        "label-only answers".into(),
        fmt_pct(label_only as f64 / queries.len() as f64),
    ]);
    t.row(vec![
        "avg access rate".into(),
        fmt_pct(access / queries.len() as f64),
    ]);
    println!("{}", t.render());
}
