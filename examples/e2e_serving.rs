//! End-to-end serving driver (the repo's headline validation run).
//!
//! Full stack on a real small workload:
//!  1. generate a Twitter-like graph (100k vertices, ~1M arcs);
//!  2. build the Hub² index with |H| = 64 hubs — the hub BFS jobs run as
//!     superstep-shared Quegel queries, and the hub-pair closure runs
//!     through the AOT-compiled Pallas min-plus kernel via PJRT;
//!  3. serve 512 PPSP queries in batched mode: each admission batch's
//!     upper bounds d_ub are evaluated by ONE call to the compiled
//!     `dub_batch` kernel (L1 on the hot path), then the BiBFS phase runs
//!     under superstep sharing with capacity C = 8;
//!  4. report throughput, latency percentiles (exact sort here; the
//!     engine also keeps streaming p50/p99/p999 sketches in
//!     `EngineMetrics::latency` / `::queueing`), access rate, and validate
//!     a sample of answers against the serial oracle.
//!
//!     make artifacts && cargo run --release --offline --example e2e_serving
//!
//! The closed-loop serving *benchmark* this example grew into lives in
//! `rust/benches/tables/perf.rs` (the serving sweep): an open-loop arrival
//! stream with a whale burst against the bounded submission queue
//! (`Engine::try_submit`) under `Admit::Static` vs `Admit::Adaptive`,
//! emitting `BENCH_serving.json`. Regenerate with
//! `cargo bench -- perf --json` from `rust/`.

use quegel::apps::ppsp::hub2::{Hub2Indexer, Hub2Query, MinPlus, RustMinPlus};
use quegel::apps::ppsp::{oracle, UNREACHED};
use quegel::coordinator::Engine;
use quegel::graph::gen;
use quegel::metrics::{fmt_pct, fmt_secs};
use quegel::network::Cluster;
use quegel::runtime::minplus::PjrtMinPlus;
use quegel::runtime::Runtime;
use std::time::Instant;

fn main() {
    let t_total = Instant::now();
    let n = 100_000;
    let avg_deg = 10;
    let n_queries = 512;
    let capacity = 8;

    println!("== e2e_serving: Quegel + Hub2 + PJRT kernels ==");
    let t0 = Instant::now();
    let mut g = gen::twitter_like(n, avg_deg, 7);
    g.ensure_in_edges();
    println!(
        "[1] graph: |V| = {}, |E| = {}, max deg = {} ({} wall)",
        g.num_vertices(),
        g.num_edges(),
        g.max_degree(),
        fmt_secs(t0.elapsed().as_secs_f64())
    );

    // PJRT-backed kernels when artifacts are present; rust fallback else.
    let rt = Runtime::cpu().ok();
    let pjrt = rt.as_ref().and_then(|rt| {
        let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        PjrtMinPlus::load(rt, dir, 128).ok()
    });
    let mp: &dyn MinPlus = match &pjrt {
        Some(p) => {
            println!("[2] kernels: PJRT Pallas artifacts (k = {}, c = {})", p.k, p.c);
            p
        }
        None => {
            println!("[2] kernels: rust fallback (run `make artifacts` for PJRT)");
            &RustMinPlus
        }
    };

    let cluster = Cluster::new(120); // paper's 15 machines x 8 workers
    let t0 = Instant::now();
    let (idx, istats) = Hub2Indexer::new(64).capacity(capacity).build(&g, cluster.clone(), mp);
    println!(
        "[3] hub2 index: k = {}, labels = {:.1}/vertex, sim {} (wall {})",
        idx.k(),
        idx.label_in.iter().map(Vec::len).sum::<usize>() as f64 / n as f64,
        fmt_secs(istats.index_time),
        fmt_secs(t0.elapsed().as_secs_f64()),
    );

    // ---- Serving phase.
    let queries = gen::random_pairs(n, n_queries, 8);
    let t_serve = Instant::now();
    // Batched d_ub on the hot path: one kernel call per admission batch.
    let k_pad = pjrt.as_ref().map(|p| p.k).unwrap_or(idx.k());
    let dubs = idx.dub_for(&queries, mp, capacity, k_pad);
    let dub_wall = t_serve.elapsed().as_secs_f64();

    // Explicit d_ub at submission also feeds the admission planner's
    // whale flag (`Hub2Query::is_heavy`); the default `Admit::Adaptive`
    // confines flagged queries to the reserved capacity slice.
    let mut eng = Engine::new(Hub2Query::new(&g, &idx), cluster.clone(), n).capacity(capacity);
    let ids: Vec<_> = queries
        .iter()
        .zip(&dubs)
        .map(|(&(s, t), &d)| eng.submit((s, t, d)))
        .collect();
    eng.run_until_idle();
    let serve_wall = t_serve.elapsed().as_secs_f64();

    // ---- Reporting.
    let mut latencies: Vec<f64> = Vec::new();
    let mut access = 0.0;
    let mut answered = 0usize;
    for id in &ids {
        let r = eng.results().iter().find(|r| r.qid == *id).unwrap();
        latencies.push(r.stats.latency());
        access += r.stats.access_rate;
        if r.out.is_some() {
            answered += 1;
        }
    }
    latencies.sort_by(f64::total_cmp);
    let pct = |p: f64| latencies[(p * (latencies.len() - 1) as f64) as usize];
    let sim_total = eng.sim_time();
    println!("[4] served {n_queries} queries (C = {capacity}):");
    println!(
        "    simulated cluster time {} -> {:.1} queries/s (paper: ~3/s on 2B edges)",
        fmt_secs(sim_total),
        n_queries as f64 / sim_total
    );
    println!(
        "    wall time {} ({} of it in the dub kernel) -> {:.0} queries/s wall",
        fmt_secs(serve_wall),
        fmt_secs(dub_wall),
        n_queries as f64 / serve_wall
    );
    println!(
        "    sim latency p50 {} / p95 {} / p99 {}",
        fmt_secs(pct(0.5)),
        fmt_secs(pct(0.95)),
        fmt_secs(pct(0.99))
    );
    println!(
        "    streaming sketch p50 {} / p99 {} / p99.9 {} ({} planner deferrals)",
        fmt_secs(eng.metrics().latency.quantile(0.5)),
        fmt_secs(eng.metrics().latency.quantile(0.99)),
        fmt_secs(eng.metrics().latency.quantile(0.999)),
        eng.metrics().admit_deferrals
    );
    println!(
        "    mean access rate {} | reach rate {}",
        fmt_pct(access / n_queries as f64),
        fmt_pct(answered as f64 / n_queries as f64)
    );

    // ---- Validation against the serial oracle (sample).
    let t0 = Instant::now();
    let mut checked = 0;
    for (i, id) in ids.iter().enumerate().step_by(16) {
        let r = eng.results().iter().find(|r| r.qid == *id).unwrap();
        let want = oracle::bfs_dist(&g, queries[i].0, queries[i].1);
        assert_eq!(
            r.out,
            (want != UNREACHED).then_some(want),
            "query {i} {:?} disagrees with oracle",
            queries[i]
        );
        checked += 1;
    }
    println!(
        "[5] validated {checked} sampled answers against the serial oracle ({})",
        fmt_secs(t0.elapsed().as_secs_f64())
    );
    println!(
        "== done in {} ==",
        fmt_secs(t_total.elapsed().as_secs_f64())
    );
}
