//! XML keyword search demo: parse an inline document (the paper's Figure 3
//! shape), then run SLCA / ELCA / MaxMatch over a generated DBLP-like
//! corpus with the inverted-index activation path.
//!
//!     cargo run --release --offline --example xml_search

use quegel::apps::xml::{self, data, parser};
use quegel::coordinator::Engine;
use quegel::metrics::{fmt_pct, fmt_secs, Table};
use quegel::network::Cluster;

const DOC: &str = r#"<lab>
  <name>Infolab</name>
  <members>
    <member><name>Tom</name><interest>Graph Database</interest></member>
    <member><name>Peter</name><interest>Data Mining</interest></member>
  </members>
  <projects>Graph Systems</projects>
</lab>"#;

fn main() {
    // ---- Part 1: semantics on a hand-written document.
    let t = parser::parse(DOC).expect("parse inline document");
    let q = t.query_ids(&["tom", "graph"]).expect("keywords exist");
    println!("document: {} vertices; query = {{tom, graph}}", t.len());
    let mut eng = Engine::new(xml::SlcaNaive::new(&t), Cluster::new(2), t.len());
    let slca = eng.run_one(q.clone()).out;
    println!("SLCA roots: {:?}", slca.iter().map(|r| r.0).collect::<Vec<_>>());
    let mut eng = Engine::new(xml::Elca::new(&t), Cluster::new(2), t.len());
    let elca = eng.run_one(q.clone()).out;
    println!("ELCA roots: {:?}", elca.iter().map(|r| r.0).collect::<Vec<_>>());
    let mut eng = Engine::new(xml::MaxMatch::new(&t), Cluster::new(2), t.len());
    let mm = eng.run_one(q).out;
    println!("MaxMatch tree vertices: {mm:?}\n");

    // ---- Part 2: throughput over a DBLP-like corpus.
    let corpus = data::generate(&data::XmlGenConfig {
        dblp_like: true,
        records: 20_000,
        vocab: 5_000,
        seed: 11,
    });
    println!(
        "corpus: {} vertices, max fan-out {} (DBLP-like)",
        corpus.len(),
        corpus.max_fanout()
    );
    let pool = data::query_pool(&corpus, 50, 2, 12);
    let cluster = Cluster::new(8);
    let mut table = Table::new(vec!["semantics", "queries", "sim total", "avg access"]);
    macro_rules! run_sem {
        ($name:expr, $app:expr) => {{
            let mut eng = Engine::new($app, cluster.clone(), corpus.len()).capacity(8);
            for q in &pool {
                eng.submit(q.clone());
            }
            eng.run_until_idle();
            let acc: f64 = eng
                .results()
                .iter()
                .map(|r| r.stats.access_rate)
                .sum::<f64>()
                / pool.len() as f64;
            table.row(vec![
                $name.to_string(),
                pool.len().to_string(),
                fmt_secs(eng.sim_time()),
                fmt_pct(acc),
            ]);
        }};
    }
    run_sem!("SLCA (naive)", xml::SlcaNaive::new(&corpus));
    run_sem!("SLCA (level-aligned)", xml::SlcaLevelAligned::new(&corpus));
    run_sem!("ELCA", xml::Elca::new(&corpus));
    run_sem!("MaxMatch", xml::MaxMatch::new(&corpus));
    println!("{}", table.render());
}
