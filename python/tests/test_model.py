"""L2 correctness: closure + batched d_ub graphs vs dense oracles."""

import numpy as np
import pytest

import jax.numpy as jnp

from compile import model
from compile.kernels import ref

INF = float(ref.INF)


def floyd_warshall(d):
    """Dense APSP oracle over the hub subgraph (numpy, O(k^3))."""
    d = d.copy()
    k = d.shape[0]
    for mid in range(k):
        d = np.minimum(d, d[:, mid : mid + 1] + d[mid : mid + 1, :])
    return np.minimum(d, INF)


def random_hub_table(rng, k, edge_frac=0.3):
    d = np.full((k, k), INF, np.float32)
    np.fill_diagonal(d, 0.0)
    mask = rng.uniform(size=(k, k)) < edge_frac
    w = np.floor(rng.uniform(1, 20, size=(k, k))).astype(np.float32)
    d = np.where(mask, np.minimum(d, w), d)
    # symmetric (undirected hub graph, as in the paper's undirected case)
    d = np.minimum(d, d.T)
    np.fill_diagonal(d, 0.0)
    return d


@pytest.mark.parametrize("k", [8, 16, 32])
@pytest.mark.parametrize("seed", [0, 1, 2])
def test_closure_reaches_apsp(k, seed):
    rng = np.random.default_rng(seed)
    d = random_hub_table(rng, k)
    want = floyd_warshall(d)
    cur = jnp.asarray(d)
    steps = max(1, int(np.ceil(np.log2(k))))
    for _ in range(steps):
        (cur,) = model.hub_closure_step(cur)
    np.testing.assert_allclose(np.asarray(cur), want, rtol=0, atol=0)


def test_closure_idempotent_at_fixpoint():
    rng = np.random.default_rng(5)
    d = random_hub_table(rng, 16)
    fixed = jnp.asarray(floyd_warshall(d))
    (again,) = model.hub_closure_step(fixed)
    np.testing.assert_array_equal(np.asarray(again), np.asarray(fixed))


@pytest.mark.parametrize("c,k", [(1, 8), (8, 16), (4, 32)])
def test_dub_batch_matches_bruteforce(c, k):
    rng = np.random.default_rng(9)
    s = rng.uniform(0, 50, size=(c, k)).astype(np.float32)
    t = rng.uniform(0, 50, size=(c, k)).astype(np.float32)
    d = random_hub_table(rng, k)
    (got,) = model.dub_batch(jnp.asarray(s), jnp.asarray(d), jnp.asarray(t))
    want = np.array(
        [np.min(s[q][:, None] + d + t[q][None, :]) for q in range(c)],
        np.float32,
    )
    np.testing.assert_allclose(np.asarray(got), want, rtol=0, atol=0)


def test_dub_batch_inf_rows_are_inert():
    """Padding rows (all-INF s/t) must produce INF, not corrupt the batch."""
    k = 16
    rng = np.random.default_rng(2)
    d = random_hub_table(rng, k)
    s = np.full((2, k), INF, np.float32)
    t = np.full((2, k), INF, np.float32)
    s[0, 3] = 1.0
    t[0, 5] = 2.0
    (got,) = model.dub_batch(jnp.asarray(s), jnp.asarray(d), jnp.asarray(t))
    assert np.asarray(got)[1] == INF
    assert np.asarray(got)[0] == 1.0 + d[3, 5] + 2.0 or np.asarray(got)[0] <= INF
