"""L1 correctness: Pallas min-plus kernel vs the pure-jnp oracle.

This is the CORE correctness signal for the kernel layer: hypothesis sweeps
shapes, block sizes and value distributions; every case must match ref.py to
f32-exact tolerances (min-plus is exact arithmetic: adds and mins only).
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from compile.kernels import ref
from compile.kernels.minplus import minplus_matmul

INF = float(ref.INF)


def rand_dist(rng, shape, inf_frac=0.3):
    """Random distance matrix: non-negative floats with INF holes."""
    a = rng.uniform(0.0, 100.0, size=shape).astype(np.float32)
    mask = rng.uniform(size=shape) < inf_frac
    a[mask] = INF
    return a


@pytest.mark.parametrize("m,k,n", [(8, 8, 8), (16, 8, 24), (128, 128, 128)])
def test_matches_ref_basic(m, k, n):
    rng = np.random.default_rng(7)
    a, b = rand_dist(rng, (m, k)), rand_dist(rng, (k, n))
    bm, bk, bn = min(m, 8), min(k, 8), min(n, 8)
    got = minplus_matmul(jnp.asarray(a), jnp.asarray(b), bm=bm, bn=bn, bk=bk)
    want = ref.minplus_matmul(jnp.asarray(a), jnp.asarray(b))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=0, atol=0)


def test_identity():
    """I (*) A == A where tropical identity has 0 diagonal, INF elsewhere."""
    rng = np.random.default_rng(3)
    a = rand_dist(rng, (16, 16))
    eye = np.full((16, 16), INF, np.float32)
    np.fill_diagonal(eye, 0.0)
    got = minplus_matmul(jnp.asarray(eye), jnp.asarray(a), bm=8, bn=8, bk=8)
    np.testing.assert_array_equal(np.asarray(got), a)


def test_all_inf_stays_inf():
    a = np.full((8, 8), INF, np.float32)
    got = minplus_matmul(jnp.asarray(a), jnp.asarray(a), bm=8, bn=8, bk=8)
    np.testing.assert_array_equal(np.asarray(got), a)


def test_non_tiling_shapes_fall_back_to_full_dim():
    """Shapes that don't tile by the requested block still compute correctly
    (the tile auto-shrinks to the full dimension)."""
    rng = np.random.default_rng(13)
    a, b = rand_dist(rng, (9, 7)), rand_dist(rng, (7, 5))
    got = minplus_matmul(jnp.asarray(a), jnp.asarray(b), bm=8, bn=8, bk=8)
    want = ref.minplus_matmul(jnp.asarray(a), jnp.asarray(b))
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@settings(max_examples=40, deadline=None)
@given(
    mi=st.integers(1, 4),
    ki=st.integers(1, 4),
    ni=st.integers(1, 4),
    blk=st.sampled_from([8, 16]),
    inf_frac=st.floats(0.0, 1.0),
    seed=st.integers(0, 2**31 - 1),
)
def test_matches_ref_hypothesis(mi, ki, ni, blk, inf_frac, seed):
    m, k, n = mi * blk, ki * blk, ni * blk
    rng = np.random.default_rng(seed)
    a, b = rand_dist(rng, (m, k), inf_frac), rand_dist(rng, (k, n), inf_frac)
    got = minplus_matmul(jnp.asarray(a), jnp.asarray(b), bm=blk, bn=blk, bk=blk)
    want = ref.minplus_matmul(jnp.asarray(a), jnp.asarray(b))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=0, atol=0)


@settings(max_examples=20, deadline=None)
@given(blk=st.sampled_from([8, 16]), seed=st.integers(0, 2**31 - 1))
def test_block_shape_invariance(blk, seed):
    """Result must not depend on the tiling."""
    m = k = n = 32
    rng = np.random.default_rng(seed)
    a, b = rand_dist(rng, (m, k)), rand_dist(rng, (k, n))
    got = minplus_matmul(jnp.asarray(a), jnp.asarray(b), bm=blk, bn=blk, bk=blk)
    base = minplus_matmul(jnp.asarray(a), jnp.asarray(b), bm=32, bn=32, bk=32)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(base))


def test_associativity_small():
    """(A*B)*C == A*(B*C) on exact integer-valued floats."""
    rng = np.random.default_rng(11)
    mats = [
        np.floor(rand_dist(rng, (16, 16), 0.2)).astype(np.float32) for _ in range(3)
    ]
    a, b, c = (jnp.asarray(x) for x in mats)
    left = ref.minplus_matmul(ref.minplus_matmul(a, b), c)
    right = ref.minplus_matmul(a, ref.minplus_matmul(b, c))
    # Values beyond INF are clamped identically on both sides.
    np.testing.assert_array_equal(np.asarray(left), np.asarray(right))
