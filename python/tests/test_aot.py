"""AOT smoke tests: lowering produces parseable HLO text for every variant."""

import jax

from compile import aot


def test_variants_enumerate():
    names = [name for name, _, _ in aot.variants()]
    assert f"hub_closure_k{aot.HUB_DIM}" in names
    assert f"dub_batch_c{aot.BATCH}_k{aot.HUB_DIM}" in names
    assert len(names) == len(set(names)) == 4


def test_lowering_emits_hlo_text():
    for name, fn, specs in aot.variants():
        lowered = jax.jit(fn).lower(*specs)
        text = aot.to_hlo_text(lowered)
        assert text.startswith("HloModule"), name
        # return_tuple=True => root is a tuple
        assert "tuple(" in text or "(" in text.splitlines()[0], name
        assert len(text) > 200, name


def test_hlo_has_no_custom_calls():
    """interpret=True must lower pallas to plain HLO (no Mosaic custom-call),
    otherwise the rust CPU PJRT client cannot execute the artifact."""
    for name, fn, specs in aot.variants():
        lowered = jax.jit(fn).lower(*specs)
        text = aot.to_hlo_text(lowered)
        assert "custom-call" not in text, f"{name} contains a custom-call"
