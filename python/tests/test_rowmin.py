"""L1 correctness: fused tropical row-min kernel vs the jnp oracle."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from compile.kernels import ref
from compile.kernels.rowmin import tropical_rowmin

INF = float(ref.INF)


def oracle(a, b):
    return np.minimum(np.min(a + b, axis=1), INF)


def rand(rng, shape, inf_frac=0.3):
    x = rng.uniform(0, 100, size=shape).astype(np.float32)
    x[rng.uniform(size=shape) < inf_frac] = INF
    return x


@pytest.mark.parametrize("c,k", [(1, 8), (8, 128), (13, 64), (8, 2048)])
def test_matches_oracle(c, k):
    rng = np.random.default_rng(31)
    a, b = rand(rng, (c, k)), rand(rng, (c, k))
    got = tropical_rowmin(jnp.asarray(a), jnp.asarray(b))
    np.testing.assert_array_equal(np.asarray(got), oracle(a, b))


def test_all_inf_rows():
    a = np.full((4, 16), INF, np.float32)
    got = tropical_rowmin(jnp.asarray(a), jnp.asarray(a))
    np.testing.assert_array_equal(np.asarray(got), np.full(4, INF, np.float32))


@settings(max_examples=30, deadline=None)
@given(
    c=st.integers(1, 12),
    ki=st.integers(1, 6),
    bk=st.sampled_from([8, 32, 64]),
    inf_frac=st.floats(0.0, 1.0),
    seed=st.integers(0, 2**31 - 1),
)
def test_matches_oracle_hypothesis(c, ki, bk, inf_frac, seed):
    k = ki * bk
    rng = np.random.default_rng(seed)
    a, b = rand(rng, (c, k), inf_frac), rand(rng, (c, k), inf_frac)
    got = tropical_rowmin(jnp.asarray(a), jnp.asarray(b), bc=4, bk=bk)
    np.testing.assert_array_equal(np.asarray(got), oracle(a, b))


def test_block_invariance():
    rng = np.random.default_rng(33)
    a, b = rand(rng, (8, 256)), rand(rng, (8, 256))
    base = tropical_rowmin(jnp.asarray(a), jnp.asarray(b), bc=8, bk=256)
    for bk in [32, 64, 128]:
        got = tropical_rowmin(jnp.asarray(a), jnp.asarray(b), bc=4, bk=bk)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(base))
