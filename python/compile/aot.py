"""AOT-lower the L2 graphs to HLO text artifacts for the rust runtime.

HLO *text* (not serialized HloModuleProto) is the interchange format: jax
>= 0.5 emits protos with 64-bit instruction ids which the xla crate's
xla_extension 0.5.1 rejects (`proto.id() <= INT_MAX`); the text parser
reassigns ids and round-trips cleanly.

Usage:  cd python && python -m compile.aot --out-dir ../artifacts

Emits one artifact per (graph, shape) variant plus a manifest.txt that the
rust runtime reads to discover shapes.
"""

import argparse
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model

# Static artifact shapes. The hub table is padded to HUB_DIM on the rust
# side; query batches are padded to BATCH rows.
HUB_DIM = 128  # k: number of hubs after padding (1 VPU-aligned tile)
HUB_DIM_LARGE = 256  # larger variant for the top-1k-hub experiments (scaled)
BATCH = 8  # C: capacity parameter default (paper: throughput saturates ~8)


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def variants():
    f32 = jnp.float32
    for k in (HUB_DIM, HUB_DIM_LARGE):
        d = jax.ShapeDtypeStruct((k, k), f32)
        yield f"hub_closure_k{k}", model.hub_closure_step, (d,)
        s = jax.ShapeDtypeStruct((BATCH, k), f32)
        t = jax.ShapeDtypeStruct((BATCH, k), f32)
        yield f"dub_batch_c{BATCH}_k{k}", model.dub_batch, (s, d, t)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    manifest = []
    for name, fn, specs in variants():
        lowered = jax.jit(fn).lower(*specs)
        text = to_hlo_text(lowered)
        path = os.path.join(args.out_dir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        shapes = ";".join(
            "x".join(str(d) for d in spec.shape) for spec in specs
        )
        manifest.append(f"{name} {shapes}")
        print(f"wrote {path} ({len(text)} chars)")

    with open(os.path.join(args.out_dir, "manifest.txt"), "w") as f:
        f.write("\n".join(manifest) + "\n")
    print(f"wrote manifest with {len(manifest)} artifacts")


if __name__ == "__main__":
    main()
