"""L2: JAX compute graphs for the Quegel Hub^2 index, calling the L1 kernel.

Two graphs are AOT-lowered to HLO text (see aot.py) and executed from the
rust coordinator via PJRT:

  * hub_closure_step(D)      -- one min-plus squaring step of the (k, k)
                                hub-pair distance table. The rust indexer
                                iterates it ceil(log2(k)) times to reach the
                                all-pairs closure over the hub subgraph.
  * dub_batch(S, D, T)       -- batched Hub^2 query upper bound for the C
                                in-flight queries of a super-round:
                                dub[q] = min_{i,j} S[q,i] + D[i,j] + T[q,j].

Shapes are static per artifact (PJRT compiles one executable per variant);
the rust side pads batches/tables with INF rows to the artifact shape.
"""

import jax.numpy as jnp

from .kernels.minplus import minplus_matmul
from .kernels.ref import INF
from .kernels.rowmin import tropical_rowmin


def hub_closure_step(d: jnp.ndarray) -> tuple:
    """D' = min(D, D (*) D), one squaring step toward the tropical closure."""
    sq = minplus_matmul(d, d)
    return (jnp.minimum(d, sq),)


def dub_batch(s: jnp.ndarray, d: jnp.ndarray, t: jnp.ndarray) -> tuple:
    """dub[q] = min_{i,j} ( s[q,i] + d[i,j] + t[q,j] ) for each query row q.

    Computed as one tropical matmul followed by the fused tropical row-min
    (both L1 Pallas kernels); the second "matmul" collapses to a diagonal
    so we never materialize (C, C).
    """
    sd = minplus_matmul(s, d)  # (C, k)
    dub = tropical_rowmin(sd, t)  # (C,)
    return (jnp.minimum(dub, INF),)
