"""Pure-jnp oracles for the L1 Pallas kernels.

These are the correctness references: every Pallas kernel in this package is
checked against the corresponding function here by pytest (exact math on f32,
so we expect allclose with tight tolerances).

The tropical (min-plus) semiring replaces (*, +) with (+, min):

    (A (*) B)[i, j] = min_k ( A[i, k] + B[k, j] )

`INF` encodes "no path". We use a large finite sentinel rather than jnp.inf so
that additions never produce NaN (inf + -inf) and the HLO stays trivially
portable; callers clamp back to the sentinel.
"""

import jax.numpy as jnp

# Finite "infinity" for distances. Large enough that no real composed path
# reaches it (graph diameters here are << 1e9) and small enough that
# INF + INF stays exactly representable in f32 (2^31 is a power of two).
INF = jnp.float32(2.0**31)


def minplus_matmul(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """Tropical matmul: out[i,j] = min_k (a[i,k] + b[k,j]), clamped to INF."""
    # (M, K, 1) + (1, K, N) -> (M, K, N) -> min over K
    out = jnp.min(a[:, :, None] + b[None, :, :], axis=1)
    return jnp.minimum(out, INF)


def hub_closure_step(d: jnp.ndarray) -> jnp.ndarray:
    """One min-plus squaring step of the hub-pair distance table.

    D' = min(D, D (*) D). Repeated log2(k) times this yields the all-pairs
    shortest-path closure over the hub subgraph (paper §5.1.2, Hub^2).
    """
    return jnp.minimum(d, minplus_matmul(d, d))


def dub_batch(s: jnp.ndarray, dh: jnp.ndarray, t: jnp.ndarray) -> jnp.ndarray:
    """Batched Hub^2 upper bound (paper §5.1.2).

    For each query q of a batch of C queries:
        dub[q] = min_{i,j} ( s[q,i] + dh[i,j] + t[q,j] )
    where s/t are (C, k) core-hub distance rows for the query endpoints and
    dh is the (k, k) hub-pair distance table.
    """
    sd = minplus_matmul(s, dh)  # (C, k): min_i (s[q,i] + dh[i,j])
    out = jnp.min(sd + t, axis=1)  # min_j ( sd[q,j] + t[q,j] )
    return jnp.minimum(out, INF)
