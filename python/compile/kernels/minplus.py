"""L1 Pallas kernel: blocked tropical (min-plus) matrix multiply.

    out[i, j] = min_k ( a[i, k] + b[k, j] )

This is the numeric hot-spot of the Quegel Hub^2 index (hub-pair distance
closure and batched query upper-bound evaluation) promoted to a TPU-shaped
kernel.

Hardware adaptation (paper cluster -> TPU, see DESIGN.md §4):
  * Grid over (M/BM, N/BN, K/BK); the K axis is the innermost ("arbitrary")
    grid dimension so the output block is revisited across k steps and can
    act as the accumulator (revisiting semantics).
  * A-block (BM, BK) and B-block (BK, BN) stream HBM->VMEM per grid step via
    BlockSpec index maps; the accumulator block stays VMEM-resident.
  * Default tile 128x128x128: 3 x 128x128 x 4B = 192 KiB of VMEM per step,
    far under the ~16 MiB budget, leaving headroom for the pipeline
    emitter's double buffering.
  * min-plus has no MXU form (the MXU contracts with x/+), so the roofline
    is the VPU's 8x128 lanes; tiles are multiples of (8, 128) accordingly.

The kernel MUST run with interpret=True on this CPU-only image: a real TPU
lowering emits a Mosaic custom-call that the CPU PJRT plugin cannot execute.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import INF

# Plain python float for use inside the kernel body: pallas_call rejects
# kernels that close over traced jnp constants.
_INF = float(INF)


def _minplus_kernel(a_ref, b_ref, o_ref):
    """One (BM, BK) x (BK, BN) tropical tile-product, accumulated into o_ref."""
    k = pl.program_id(2)

    # First visit of this output block: initialize the accumulator to +INF
    # (the tropical zero).
    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.full(o_ref.shape, _INF, o_ref.dtype)

    a = a_ref[...]  # (BM, BK)
    b = b_ref[...]  # (BK, BN)
    # (BM, BK, 1) + (1, BK, N) -> min over BK. The broadcast-add stays in
    # registers/VMEM tile-by-tile; on TPU this vectorizes over the 8x128
    # lanes of the VPU.
    part = jnp.min(a[:, :, None] + b[None, :, :], axis=1)
    o_ref[...] = jnp.minimum(o_ref[...], part)


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk"))
def minplus_matmul(a, b, *, bm: int = 128, bn: int = 128, bk: int = 128):
    """Blocked tropical matmul via pallas_call (interpret mode on CPU).

    Shapes must tile evenly: M % bm == K % bk == N % bn == 0. The L2 model
    pads hub tables to multiples of 128 before calling.
    """
    m, k = a.shape
    k2, n = b.shape
    assert k == k2, f"contraction mismatch {k} vs {k2}"
    # Auto-shrink the requested tile to the full dimension when the dimension
    # is smaller than (or does not tile by) the default 128 tile; production
    # hub tables are padded to multiples of 128, small test shapes are not.
    if m % bm != 0:
        bm = m
    if n % bn != 0:
        bn = n
    if k % bk != 0:
        bk = k
    grid = (m // bm, n // bn, k // bk)
    out = pl.pallas_call(
        _minplus_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), a.dtype),
        interpret=True,  # CPU-only image; see module docstring
    )(a, b)
    return jnp.minimum(out, INF)
