"""L1 Pallas kernel: fused tropical row reduction.

    out[q] = min_j ( a[q, j] + b[q, j] )

The second stage of the Hub^2 batched upper bound: after `sd = S (*) D_H`
(the min-plus matmul), the per-query bound is the row-wise tropical "dot"
of `sd` with the t-side label rows. Fusing add+min in one kernel avoids
materializing `sd + t` in HBM.

BlockSpec schedule: grid over (C/BC, K/BK); each step streams (BC, BK)
tiles of both operands into VMEM, reduces the K axis locally, and folds
into the (BC,) accumulator column (revisiting semantics over the k grid
axis). VMEM per step = 2 x BC x BK x 4B + BC x 4B — 64 KiB at the default
(8, 1024) tile. Runs on the VPU (add+min, no MXU contraction).

interpret=True on this CPU-only image (see minplus.py).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import INF

_INF = float(INF)


def _rowmin_kernel(a_ref, b_ref, o_ref):
    k = pl.program_id(1)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.full(o_ref.shape, _INF, o_ref.dtype)

    part = jnp.min(a_ref[...] + b_ref[...], axis=1)
    o_ref[...] = jnp.minimum(o_ref[...], part)


@functools.partial(jax.jit, static_argnames=("bc", "bk"))
def tropical_rowmin(a, b, *, bc: int = 8, bk: int = 1024):
    """out[q] = min_j (a[q,j] + b[q,j]), blocked over the j axis."""
    c, k = a.shape
    assert a.shape == b.shape, f"shape mismatch {a.shape} vs {b.shape}"
    if c % bc != 0:
        bc = c
    if k % bk != 0:
        bk = k
    grid = (c // bc, k // bk)
    out = pl.pallas_call(
        _rowmin_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bc, bk), lambda i, kk: (i, kk)),
            pl.BlockSpec((bc, bk), lambda i, kk: (i, kk)),
        ],
        out_specs=pl.BlockSpec((bc,), lambda i, kk: (i,)),
        out_shape=jax.ShapeDtypeStruct((c,), a.dtype),
        interpret=True,  # CPU-only image
    )(a, b)
    return jnp.minimum(out, INF)
