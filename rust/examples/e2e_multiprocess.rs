//! End-to-end multi-process serving demo: one coordinator + N worker
//! processes over localhost TCP, driven by a single serializable
//! [`EngineConfig`].
//!
//! Run with `cargo run --example e2e_multiprocess` (optionally
//! `QUEGEL_TEST_PROCS=4` to change the worker-process count). The demo
//! runs the same streaming workload — PPSP queries interleaved with graph
//! mutation batches — once in-process and once across worker processes,
//! verifies the `(epoch, out)` result streams match bit for bit, and
//! prints the wire metrics that prove the multi-process run actually put
//! the exchange on the network.

use quegel::apps::ppsp::{vbfs_query, VersionedBfs};
use quegel::coordinator::remote::{maybe_serve_worker, procs_from_env, ProcEngine};
use quegel::coordinator::{Admit, EngineConfig, Pipeline};
use quegel::graph::{gen, MutationBatch};
use quegel::network::Cluster;

fn main() {
    // Worker-process entrypoint: each spawned child re-enters this same
    // main and serves the remote protocol instead of running the demo.
    if maybe_serve_worker::<VersionedBfs>() {
        return;
    }

    let n = 2_000usize;
    let workers = 8;
    let procs = procs_from_env().max(2);
    let g = gen::twitter_like(n, 6, 42);
    let mut batch = MutationBatch::new();
    batch.add_edge(17, 1_234).delete_vertex(99).add_vertex().add_edge(n as u32, 5);

    // One config object is the entire engine setup: built here, shipped
    // to every worker process in its byte codec at the handshake.
    let cfg = EngineConfig {
        capacity: 8,
        threads: 1,
        pipeline: Pipeline::Off,
        admit: Admit::Static(8),
        ..EngineConfig::default()
    };
    let queries = gen::random_pairs(n, 24, 43);

    let drive = |pe: &mut ProcEngine<VersionedBfs>| {
        let mut ids = Vec::new();
        for (i, &(s, t)) in queries.iter().enumerate() {
            // A mutation lands mid-stream: queries admitted after it pin
            // the new epoch, in-flight ones keep reading their snapshot.
            if i == queries.len() / 2 {
                pe.try_mutate(batch.clone(), pe.sim_time()).unwrap();
            }
            ids.push(pe.try_submit(vbfs_query(s, t), pe.sim_time()).unwrap());
            pe.super_round();
        }
        pe.run_until_idle();
        let results = pe.take_results();
        ids.iter()
            .map(|id| {
                let r = results.iter().find(|r| r.qid == *id).unwrap();
                (r.qid, r.stats.epoch, r.out)
            })
            .collect::<Vec<_>>()
    };

    let mut local = ProcEngine::new(
        VersionedBfs::new(g.clone()),
        Cluster::new(workers),
        n,
        cfg,
        1,
        &[],
    );
    let want = drive(&mut local);
    assert_eq!(local.metrics().bytes_on_wire, 0);

    let mut multi = ProcEngine::new(
        VersionedBfs::new(g),
        Cluster::new(workers),
        n,
        cfg,
        procs,
        &[],
    );
    let got = drive(&mut multi);

    assert_eq!(got, want, "multi-process results must match in-process");
    let m = multi.metrics();
    println!(
        "{} queries, {} epochs: identical (epoch, out) streams in-process \
         and across {} worker processes",
        want.len(),
        m.epochs_applied + 1,
        procs,
    );
    println!(
        "wire: {} bytes over {} rpc round trips ({} super-rounds)",
        m.bytes_on_wire, m.rpc_round_trips, m.super_rounds
    );
    multi.shutdown();
}
