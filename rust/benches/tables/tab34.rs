//! Tables 3 & 4: cumulative load/query/dump time for 20 PPSP queries under
//! Giraph-like / GraphLab-like / Quegel, with BFS and BiBFS, on the
//! Twitter-like (Table 3) and BTC-like (Table 4) graphs.

use quegel::apps::ppsp::{Bfs, BiBfs};
use quegel::baselines;
use quegel::coordinator::Engine;
use quegel::graph::{gen, Graph};
use quegel::metrics::{fmt_pct, fmt_secs, Table};

fn run_dataset(name: &str, mut g: Graph, seed: u64) {
    g.ensure_in_edges();
    let n = g.num_vertices();
    println!("{name}: |V| = {n}, |E| = {}", g.num_edges());
    let queries = gen::random_pairs(n, 20, seed);
    let cluster = super::paper_cluster();

    let mut t = Table::new(vec![
        "algo", "system", "Load", "Query", "Dump", "Access",
    ]);

    // ---- BFS variants.
    let gi = baselines::giraph_like::<Bfs, _>(&g, &cluster, &queries, || Bfs::new(&g));
    t.row(vec![
        "BFS".into(),
        "Giraph-like".into(),
        fmt_secs(gi.load_time),
        fmt_secs(gi.query_time),
        fmt_secs(gi.dump_time),
        fmt_pct(gi.access_rate),
    ]);
    let gl = baselines::graphlab_like::<Bfs, _>(&g, &cluster, &queries, || Bfs::new(&g));
    t.row(vec![
        "BFS".into(),
        "GraphLab-like".into(),
        fmt_secs(gl.load_time),
        fmt_secs(gl.query_time),
        fmt_secs(gl.dump_time),
        fmt_pct(gl.access_rate),
    ]);
    // Quegel: one-off load; queries share supersteps (C = 8); results to
    // console (no dump).
    let mut eng = Engine::new(Bfs::new(&g), cluster.clone(), n).capacity(8);
    eng.advance_clock(cluster.load_time(g.footprint_bytes()));
    let load = eng.sim_time();
    for &q in &queries {
        eng.submit(q);
    }
    eng.run_until_idle();
    let acc: f64 = eng.results().iter().map(|r| r.stats.access_rate).sum::<f64>() / 20.0;
    t.row(vec![
        "BFS".into(),
        "Quegel".into(),
        fmt_secs(load),
        fmt_secs(eng.sim_time() - load),
        "-".into(),
        fmt_pct(acc),
    ]);

    // ---- BiBFS variants (loading costs more: Γ_in materialization).
    let bi_bytes = g.footprint_bytes(); // includes in-edges already built
    let gi = baselines::giraph_like::<BiBfs, _>(&g, &cluster, &queries, || BiBfs::new(&g));
    t.row(vec![
        "BiBFS".into(),
        "Giraph-like".into(),
        fmt_secs(gi.load_time),
        fmt_secs(gi.query_time),
        fmt_secs(gi.dump_time),
        fmt_pct(gi.access_rate),
    ]);
    let gl = baselines::graphlab_like::<BiBfs, _>(&g, &cluster, &queries, || BiBfs::new(&g));
    t.row(vec![
        "BiBFS".into(),
        "GraphLab-like".into(),
        fmt_secs(gl.load_time),
        fmt_secs(gl.query_time),
        fmt_secs(gl.dump_time),
        fmt_pct(gl.access_rate),
    ]);
    let mut eng = Engine::new(BiBfs::new(&g), cluster.clone(), n).capacity(8);
    eng.advance_clock(cluster.load_time(bi_bytes));
    let load = eng.sim_time();
    for &q in &queries {
        eng.submit(q);
    }
    eng.run_until_idle();
    let acc: f64 = eng.results().iter().map(|r| r.stats.access_rate).sum::<f64>() / 20.0;
    t.row(vec![
        "BiBFS".into(),
        "Quegel".into(),
        fmt_secs(load),
        fmt_secs(eng.sim_time() - load),
        "-".into(),
        fmt_pct(acc),
    ]);

    println!("{}", t.render());
}

pub fn run_twitter() {
    run_dataset("Twitter-like", gen::twitter_like(100_000, 10, 405), 406);
    println!("expected shape (paper Tab 3): Giraph load >> query; Quegel");
    println!("query < GraphLab query; BiBFS access < BFS access.");
}

pub fn run_btc() {
    run_dataset("BTC-like", gen::btc_like(120_000, 8_000, 5, 407), 408);
    println!("expected shape (paper Tab 4): gap vs baselines grows (tiny");
    println!("access rate); BFS access < BiBFS access (many small CCs).");
}
