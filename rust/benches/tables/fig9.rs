//! Figure 9: path-shape similarity — dump the CH and Quegel polylines for
//! one mid-range query so they can be plotted, and report their Hausdorff
//! distance.

use quegel::apps::terrain::baseline::{hausdorff, ChResult, ChenHanStandIn};
use quegel::apps::terrain::{Dem, TerrainNet, TerrainSssp};
use quegel::coordinator::Engine;
use std::io::Write;

pub fn run() {
    let dem = Dem::fractal(101, 140, 10.0, 250.0, 421); // Eagle-like (as tab10)
    let net = TerrainNet::build(&dem, 2.0);
    let ch = ChenHanStandIn::new(&dem);
    let (tx, ty) = (16usize, 16usize); // Q3 of the ladder

    let s = net.corner(0, 0);
    let t = net.corner(tx, ty);
    let mut eng = Engine::new(
        TerrainSssp::new(&net),
        super::paper_cluster(),
        net.graph.num_vertices(),
    );
    let out = eng.run_one((s, t)).out;
    let ChResult::Ok { path: ch_path, len, .. } = ch.query(0, 0, tx, ty) else {
        panic!("Q3 must fit the CH budget");
    };

    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("bench_out");
    std::fs::create_dir_all(&dir).expect("mkdir bench_out");
    let dump = |name: &str, path: &[(f64, f64, f64)]| {
        let mut f = std::fs::File::create(dir.join(name)).expect("create polyline file");
        for (x, y, z) in path {
            writeln!(f, "{x:.2} {y:.2} {z:.2}").unwrap();
        }
    };
    dump("fig9_ch_path.txt", &ch_path);
    dump("fig9_quegel_path.txt", &out.path);
    let hd = hausdorff(&out.path, &ch_path);
    println!(
        "Q3 ({tx},{ty}): CH len {len:.1} m, Quegel len {:.1} m, HDist {hd:.2} m",
        out.dist
    );
    println!(
        "polylines written to {} (plot to reproduce Fig 9)",
        dir.display()
    );
    assert!(hd < 30.0, "paths must nearly coincide (paper Fig 9)");
}
