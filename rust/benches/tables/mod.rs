//! One module per reproduced table/figure. Shared helpers live here.

pub mod fig1;
pub mod fig9;
pub mod perf;
pub mod tab10;
pub mod tab11;
pub mod tab12;
pub mod tab2;
pub mod tab34;
pub mod tab56;
pub mod tab7;
pub mod tab8;

use quegel::network::{Cluster, CostModel};

/// The "paper cluster": 15 machines × 8 workers, Gigabit.
pub fn paper_cluster() -> Cluster {
    Cluster::new(120)
}

/// GraphX-like discipline: distributed but with Spark's per-stage
/// scheduling overhead and serialization cost (modeled; DESIGN.md §5).
pub fn graphx_cost() -> CostModel {
    CostModel {
        barrier_latency_s: 50e-3, // per-stage scheduling
        per_msg_overhead_s: 2e-6, // JVM serialization
        ..Default::default()
    }
}

/// Load the PJRT kernels if artifacts are built.
pub fn load_pjrt(k_max: usize) -> Option<quegel::runtime::minplus::PjrtMinPlus> {
    let rt = quegel::runtime::Runtime::cpu().ok()?;
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    quegel::runtime::minplus::PjrtMinPlus::load(&rt, dir, k_max).ok()
}
