//! One module per reproduced table/figure. Shared helpers live here.

pub mod fig1;
pub mod fig9;
pub mod perf;
pub mod tab10;
pub mod tab11;
pub mod tab12;
pub mod tab2;
pub mod tab34;
pub mod tab56;
pub mod tab7;
pub mod tab8;

use quegel::network::{Cluster, CostModel};

/// The "paper cluster": 15 machines × 8 workers, Gigabit.
pub fn paper_cluster() -> Cluster {
    Cluster::new(120)
}

/// GraphX-like discipline: distributed but with Spark's per-stage
/// scheduling overhead and serialization cost (modeled; DESIGN.md §5).
pub fn graphx_cost() -> CostModel {
    CostModel {
        barrier_latency_s: 50e-3, // per-stage scheduling
        per_msg_overhead_s: 2e-6, // JVM serialization
        ..Default::default()
    }
}

/// Load the PJRT kernels if artifacts are built.
#[cfg(feature = "pjrt")]
pub fn load_pjrt(k_max: usize) -> Option<quegel::runtime::minplus::PjrtMinPlus> {
    let rt = quegel::runtime::Runtime::cpu().ok()?;
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    quegel::runtime::minplus::PjrtMinPlus::load(&rt, dir, k_max).ok()
}

/// Stand-in for the PJRT evaluator when the `pjrt` feature is off: never
/// constructed (`load_pjrt` returns `None`), it only keeps the bench call
/// sites (`.map(|p| p as &dyn MinPlus)`, `.map(|p| p.k)`) compiling.
#[cfg(not(feature = "pjrt"))]
pub struct PjrtUnavailable {
    pub k: usize,
}

#[cfg(not(feature = "pjrt"))]
impl quegel::apps::ppsp::hub2::MinPlus for PjrtUnavailable {
    fn closure(&self, _d: &mut [f32], _k: usize) {
        unreachable!("PjrtUnavailable is never constructed");
    }

    fn dub_batch(
        &self,
        _s: &[f32],
        _d: &[f32],
        _t: &[f32],
        _c: usize,
        _k: usize,
    ) -> Vec<f32> {
        unreachable!("PjrtUnavailable is never constructed");
    }
}

/// No-PJRT build: the benches fall back to the pure-rust evaluator.
#[cfg(not(feature = "pjrt"))]
pub fn load_pjrt(_k_max: usize) -> Option<PjrtUnavailable> {
    None
}
