//! Table 7: (a) effect of the capacity parameter C on batch throughput;
//! (b) horizontal scalability with the number of machines.

use quegel::apps::ppsp::hub2::{Hub2Indexer, Hub2Query, MinPlus, RustMinPlus};
use quegel::coordinator::Engine;
use quegel::graph::gen;
use quegel::metrics::{fmt_secs, Table};
use quegel::network::Cluster;

pub fn run_capacity() {
    let mut g = gen::twitter_like(80_000, 10, 413);
    g.ensure_in_edges();
    let n = g.num_vertices();
    let mp_pjrt = super::load_pjrt(128);
    let mp: &dyn MinPlus = mp_pjrt
        .as_ref()
        .map(|p| p as &dyn MinPlus)
        .unwrap_or(&RustMinPlus);
    let (idx, _) = Hub2Indexer::new(128).build(&g, super::paper_cluster(), mp);
    let queries = gen::random_pairs(n, 512, 414);
    let k_pad = mp_pjrt.as_ref().map(|p| p.k).unwrap_or(idx.k());
    let dubs = idx.dub_for(&queries, mp, 8, k_pad);

    let mut t = Table::new(vec!["C", "Total_Query (sim)", "speedup vs C=1"]);
    let mut t1 = 0.0;
    for c in [1usize, 2, 4, 8, 16, 32, 64, 128] {
        let mut eng =
            Engine::new(Hub2Query::new(&g, &idx), super::paper_cluster(), n).capacity(c);
        for (&(s, tt), &dub) in queries.iter().zip(&dubs) {
            eng.submit((s, tt, dub));
        }
        eng.run_until_idle();
        let total = eng.sim_time();
        if c == 1 {
            t1 = total;
        }
        t.row(vec![
            c.to_string(),
            fmt_secs(total),
            format!("{:.2}x", t1 / total),
        ]);
    }
    println!("{}", t.render());
    println!("expected shape (paper Tab 7a): C=8 ~3x over C=1, then flat");
    println!("(bandwidth saturated).");
}

pub fn run_machines() {
    let mut g = gen::twitter_like(80_000, 10, 415);
    g.ensure_in_edges();
    let n = g.num_vertices();
    let queries = gen::random_pairs(n, 1_000, 416);
    let mp_pjrt = super::load_pjrt(128);
    let mp: &dyn MinPlus = mp_pjrt
        .as_ref()
        .map(|p| p as &dyn MinPlus)
        .unwrap_or(&RustMinPlus);

    let mut t = Table::new(vec!["# machines", "Total_Index (sim)", "Total_Query (sim)"]);
    for machines in [8usize, 10, 12, 14] {
        let cluster = Cluster::new(machines * 8);
        let (idx, istats) = Hub2Indexer::new(128).build(&g, cluster.clone(), mp);
        let k_pad = mp_pjrt.as_ref().map(|p| p.k).unwrap_or(idx.k());
        let dubs = idx.dub_for(&queries, mp, 8, k_pad);
        let mut eng = Engine::new(Hub2Query::new(&g, &idx), cluster, n).capacity(8);
        for (&(s, tt), &dub) in queries.iter().zip(&dubs) {
            eng.submit((s, tt, dub));
        }
        eng.run_until_idle();
        t.row(vec![
            machines.to_string(),
            fmt_secs(istats.index_time),
            fmt_secs(eng.sim_time()),
        ]);
    }
    println!("{}", t.render());
    println!("expected shape (paper Tab 7b): both times fall as machines grow.");
}
