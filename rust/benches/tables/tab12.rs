//! Table 12: RDF graph keyword search on Freebase-like and DBPedia-like
//! synthetic graphs — 600 two-keyword + 600 three-keyword queries.

use quegel::apps::gkws::{self, query::GkwsQuery, KeywordSearch};
use quegel::coordinator::Engine;
use quegel::metrics::{fmt_pct, fmt_secs, Table};

fn run_dataset(name: &str, cfg: gkws::RdfGenConfig) {
    let g = gkws::data::generate(&cfg);
    let edges: usize = g.out_nbrs.iter().map(Vec::len).sum();
    println!("{name}: |V| = {}, |E| = {edges}", g.len());
    let cluster = super::paper_cluster();
    let load = cluster.load_time(g.footprint_bytes());

    let mut t = Table::new(vec!["# keywords", "Load", "Query (sim)", "Access"]);
    for m in [2usize, 3] {
        let pool = gkws::data::query_pool(&g, 600, m, cfg.seed + m as u64);
        let mut eng = Engine::new(KeywordSearch::new(&g), cluster.clone(), g.len()).capacity(8);
        eng.advance_clock(load);
        for kw in pool {
            eng.submit(GkwsQuery {
                keywords: kw,
                delta_max: 3,
            });
        }
        eng.run_until_idle();
        let access: f64 =
            eng.results().iter().map(|r| r.stats.access_rate).sum::<f64>() / 600.0;
        t.row(vec![
            m.to_string(),
            fmt_secs(load),
            fmt_secs(eng.sim_time() - load),
            fmt_pct(access),
        ]);
    }
    println!("{}", t.render());
}

pub fn run() {
    run_dataset(
        "Freebase-like",
        gkws::RdfGenConfig {
            resources: 60_000,
            avg_deg: 6,
            predicates: 400,
            vocab: 6_000,
            seed: 429,
        },
    );
    run_dataset(
        "DBPedia-like",
        gkws::RdfGenConfig {
            resources: 100_000,
            avg_deg: 6,
            predicates: 600,
            vocab: 8_000,
            seed: 431,
        },
    );
    println!("expected shape (paper Tab 12): 3-keyword queries cost more time");
    println!("and access than 2-keyword; the larger graph costs more overall.");
}
