//! Table 8: XML keyword search — SLCA (naive vs level-aligned), ELCA and
//! MaxMatch over DBLP-like and XMark-like corpora, 1000 queries each.

use quegel::apps::xml::{self, data};
use quegel::coordinator::Engine;
use quegel::metrics::{fmt_pct, fmt_secs, Table};
use quegel::vertex::QueryApp;

fn bench_semantics<A: QueryApp<Query = Vec<u32>>>(
    app: A,
    n: usize,
    load_bytes: usize,
    queries: &[Vec<u32>],
) -> (f64, f64, f64, f64) {
    let cluster = super::paper_cluster();
    let mut eng = Engine::new(app, cluster.clone(), n).capacity(8);
    let load = cluster.load_time(load_bytes);
    // Index construction: one load2Idx pass over local vertices.
    let index = load + n as f64 * 20e-9;
    eng.advance_clock(index);
    for q in queries {
        eng.submit(q.clone());
    }
    eng.run_until_idle();
    let access: f64 =
        eng.results().iter().map(|r| r.stats.access_rate).sum::<f64>() / queries.len() as f64;
    (load, index, eng.sim_time() - index, access)
}

fn run_corpus(name: &str, dblp: bool, records: usize, seed: u64) {
    let corpus = data::generate(&data::XmlGenConfig {
        dblp_like: dblp,
        records,
        vocab: 4_000,
        seed,
    });
    println!(
        "{name}: {} vertices, max fan-out {}, depth {}",
        corpus.len(),
        corpus.max_fanout(),
        corpus.level.iter().max().unwrap()
    );
    // Paper methodology: a pool of tens of well-chosen queries, sampled
    // 1000 times.
    let pool = data::query_pool(&corpus, 30, 2, seed + 1);
    let queries: Vec<Vec<u32>> = (0..1_000).map(|i| pool[i % pool.len()].clone()).collect();
    let bytes = corpus.footprint_bytes();

    let mut t = Table::new(vec!["semantics", "Load", "Index", "Query", "Access"]);
    let (l, i, q, a) = bench_semantics(xml::SlcaNaive::new(&corpus), corpus.len(), bytes, &queries);
    t.row(vec![
        "SLCA naive".into(),
        fmt_secs(l),
        fmt_secs(i),
        fmt_secs(q),
        fmt_pct(a),
    ]);
    // Ablation: a combiner-less Pregel runtime (naive's repeated sends hit
    // the wire in full — the regime where level-alignment pays off).
    let (l, i, q, a) = bench_semantics(
        xml::SlcaNaive::without_combiner(&corpus),
        corpus.len(),
        bytes,
        &queries,
    );
    t.row(vec![
        "SLCA naive (no combiner)".into(),
        fmt_secs(l),
        fmt_secs(i),
        fmt_secs(q),
        fmt_pct(a),
    ]);
    let (l, i, q, a) = bench_semantics(
        xml::SlcaLevelAligned::new(&corpus),
        corpus.len(),
        bytes,
        &queries,
    );
    t.row(vec![
        "SLCA level-aligned".into(),
        fmt_secs(l),
        fmt_secs(i),
        fmt_secs(q),
        fmt_pct(a),
    ]);
    let (l, i, q, a) = bench_semantics(xml::Elca::new(&corpus), corpus.len(), bytes, &queries);
    t.row(vec![
        "ELCA".into(),
        fmt_secs(l),
        fmt_secs(i),
        fmt_secs(q),
        fmt_pct(a),
    ]);
    let (l, i, q, a) = bench_semantics(xml::MaxMatch::new(&corpus), corpus.len(), bytes, &queries);
    t.row(vec![
        "MaxMatch".into(),
        fmt_secs(l),
        fmt_secs(i),
        fmt_secs(q),
        fmt_pct(a),
    ]);
    println!("{}", t.render());
}

pub fn run() {
    run_corpus("DBLP-like", true, 60_000, 417);
    run_corpus("XMark-like", false, 40_000, 419);
    println!("expected shape (paper Tab 8): level-aligned SLCA beats naive on");
    println!("high-fanout DBLP but loses on XMark (aggregator overhead);");
    println!("MaxMatch costs the most; XMark access rates are higher.");
}
