//! Table 2: non-scalable systems on LiveJ-like — Neo4j-like / GraphChi-like
//! / GraphX-like vs Quegel-Hub², 20 serial PPSP queries.

use quegel::apps::ppsp::hub2::{Hub2Indexer, Hub2Query, MinPlus, RustMinPlus};
use quegel::baselines;
use quegel::coordinator::Engine;
use quegel::graph::gen;
use quegel::metrics::{fmt_pct, fmt_secs, Table};
use quegel::network::Cluster;

pub fn run() {
    // LiveJ-like bipartite membership graph.
    let users = 40_000;
    let groups = 8_000;
    let mut g = gen::livej_like(users, groups, 5, 403);
    g.ensure_in_edges();
    let n = g.num_vertices();
    println!("LiveJ-like: |V| = {n}, |E| = {}", g.num_edges());
    let queries = gen::random_pairs(n, 20, 404);

    // Quegel with Hub^2 (undirected).
    let mp = super::load_pjrt(128);
    let mp_ref: &dyn MinPlus = mp.as_ref().map(|p| p as &dyn MinPlus).unwrap_or(&RustMinPlus);
    let (idx, istats) = Hub2Indexer::new(64)
        .undirected(true)
        .build(&g, super::paper_cluster(), mp_ref);
    println!(
        "hub2 preprocessing: {} simulated (paper: 2912 s end-to-end)",
        fmt_secs(istats.index_time)
    );

    // Neo4j-like: serial pointer chasing, ~0.3 ms per random edge access.
    let neo = baselines::neo4j_like_ppsp(&g, &queries, 3e-4);
    // GraphChi-like: full scan per superstep (BFS algorithm).
    let chi = baselines::graphchi_like::<quegel::apps::ppsp::Bfs, _>(&g, &queries, || {
        quegel::apps::ppsp::Bfs::new(&g)
    });
    // GraphX-like: distributed but with Spark stage overheads.
    let gx_cluster = Cluster::with_cost(120, super::graphx_cost());
    let gx = baselines::graphlab_like::<quegel::apps::ppsp::Bfs, _>(&g, &gx_cluster, &queries, || {
        quegel::apps::ppsp::Bfs::new(&g)
    });

    let mut t = Table::new(vec![
        "Q", "Neo4j-like", "GraphChi-like", "GraphX-like", "Quegel", "Access", "Reach",
    ]);
    let mut quegel_total = 0.0;
    for (i, &(s, tt)) in queries.iter().enumerate() {
        let dub = idx.dub_for(&[(s, tt)], mp_ref, 1, idx.k())[0];
        let mut eng = Engine::new(Hub2Query::new(&g, &idx), super::paper_cluster(), n);
        let r = eng.run_one((s, tt, dub));
        quegel_total += r.stats.processing();
        t.row(vec![
            format!("Q{}", i + 1),
            fmt_secs(neo[i].1),
            fmt_secs(chi.results[i].stats.processing()),
            fmt_secs(gx.results[i].stats.processing()),
            fmt_secs(r.stats.processing()),
            fmt_pct(r.stats.access_rate),
            if r.out.is_some() { "y" } else { "X" }.to_string(),
        ]);
        assert_eq!(r.out.is_some(), neo[i].0.is_some(), "answers agree");
    }
    println!("{}", t.render());
    println!(
        "Quegel avg {}/query; paper: ~1 s/query on LiveJ, Neo4j minutes-hours",
        fmt_secs(quegel_total / queries.len() as f64)
    );
}
