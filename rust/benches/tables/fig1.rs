//! Figure 1: load balancing under superstep-sharing.
//!
//! The paper's exact scenario: two queries on a 2-worker cluster, the first
//! costing 2 units on worker 1 and 4 on worker 2, the second the mirror
//! image. Individually each super-round costs max = 4 (total 8 per step
//! pair); shared, the per-worker sums are 6 and 6, so one super-round costs
//! 6 — a 6/8 = 0.75 ratio.

use quegel::coordinator::Engine;
use quegel::graph::VertexId;
use quegel::metrics::{fmt_secs, Table};
use quegel::network::{Cluster, CostModel};
use quegel::vertex::{Ctx, QueryApp};

/// Micro-app: the query (w0_units, w1_units, steps) activates that many
/// vertices on each of the two workers; every vertex re-activates itself
/// for `steps` supersteps. Per-worker compute per super-round is therefore
/// exactly the requested unit count.
struct Skew;

impl QueryApp for Skew {
    /// (units on worker 0, units on worker 1, supersteps).
    type Query = (u32, u32, u32);
    /// Remaining supersteps.
    type VQ = u32;
    type Msg = ();
    type Agg = ();
    type Out = ();

    fn init_activate(&self, q: &Self::Query) -> Vec<VertexId> {
        // Even ids -> worker 0, odd ids -> worker 1 (hash partition, W=2).
        let mut v = Vec::new();
        for i in 0..q.0 {
            v.push(i * 2);
        }
        for i in 0..q.1 {
            v.push(i * 2 + 1);
        }
        v
    }

    fn init_value(&self, q: &Self::Query, _v: VertexId) -> u32 {
        q.2
    }

    fn compute(&self, ctx: &mut Ctx<'_, Self>, _v: VertexId, left: &mut u32) {
        *left -= 1;
        if *left == 0 {
            ctx.vote_halt();
        }
        // stay active otherwise: exactly one compute call per superstep
    }

    fn finish(
        &self,
        _q: &Self::Query,
        _touched: &mut dyn Iterator<Item = (VertexId, &u32)>,
        _agg: &(),
    ) {
    }
}

pub fn run() {
    let cost = CostModel {
        per_vertex_compute_s: 1.0, // 1 simulated second per work unit
        barrier_latency_s: 0.01,
        bandwidth_bytes_per_s: 1e12,
        per_msg_overhead_s: 0.0,
        ..Default::default()
    };
    let steps = 1u32;
    let queries = [(2u32, 4u32, steps), (4, 2, steps)];

    let run_with = |c: usize| -> (f64, u64) {
        let mut eng = Engine::new(Skew, Cluster::with_cost(2, cost.clone()), 16).capacity(c);
        for &q in &queries {
            eng.submit(q);
        }
        eng.run_until_idle();
        (eng.sim_time(), eng.metrics().super_rounds)
    };
    let (t_ind, r_ind) = run_with(1);
    let (t_shared, r_shared) = run_with(2);

    let mut t = Table::new(vec!["schedule", "super-rounds", "sim time (units)"]);
    t.row(vec![
        "individual (C=1)".to_string(),
        r_ind.to_string(),
        fmt_secs(t_ind),
    ]);
    t.row(vec![
        "superstep-shared (C=2)".to_string(),
        r_shared.to_string(),
        fmt_secs(t_shared),
    ]);
    println!("{}", t.render());
    println!(
        "shared/individual = {:.3} (paper's Fig 1: 6 vs 8 units = 0.750)",
        t_shared / t_ind
    );
    assert!(t_shared < t_ind, "sharing must win");
}
