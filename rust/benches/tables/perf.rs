//! §Perf micro-benchmarks: wall-clock cost of the engine hot paths, used by
//! the optimization pass (EXPERIMENTS.md §Perf). Not a paper table.
//!
//! Three phase-split sections attribute the pooled engine's wins:
//!
//! * the **thread sweep** reports compute / exchange / barrier wall time
//!   per `threads` setting and each one's speedup over the serial
//!   (`threads = 1`) run, on a combiner-heavy (BiBFS) and a combiner-less
//!   (XML SLCA) workload;
//! * the **skew sweep** runs BFS over a deliberately hub-concentrated
//!   partition (`gen::hub_concentrated`: worker 0 of 8 owns every
//!   high-degree vertex) under the static chunk scheduler vs the
//!   work-stealing scheduler, and reports per-phase wall times, steal
//!   counts, job counts and the lane-imbalance ratio — the number that
//!   shows stealing absorbing the skew static chunking serializes behind;
//! * the **split sweep** runs BFS over the single-mega-hub graph
//!   (`gen::mega_hub`: one vertex's entire blast radius lands on worker 0
//!   as ONE compute task) with sub-lane splitting off vs on, both under
//!   the stealing scheduler — isolating exactly what splitting the task's
//!   vertex range into sub-jobs buys over lane-granular stealing;
//! * the **edge-split sweep** runs BFS over the mono-hub graph
//!   (`gen::mono_hub`: ONE vertex owns an out-edge to everyone, so a
//!   single `compute()` call stages the whole fanout) with edge-level
//!   splitting off vs on — isolating what parking the fan and staging its
//!   contiguous edge ranges as pool jobs buys over every coarser
//!   granularity;
//! * the **pipeline sweep** runs a stream of point lookups alongside one
//!   deep BFS over the `gen::one_slow_query` graph (a ladder pinned to
//!   worker 0's lane that grinds for ~depth supersteps while every other
//!   query converges in two or three) under barrier rounds vs
//!   `Pipeline::On` — measuring end-to-end wall, per-phase *busy* time
//!   and the `overlap_time` gauge, i.e. what draining fast queries
//!   through exchange/fold/reporting during the slow lane's compute buys
//!   over paying three global barriers per round;
//! * the **layout sweep** re-runs BFS over the three adversarial graphs
//!   above (hub-concentrated, mega-hub, mono-hub) with the per-query
//!   stores in `Layout::Hashed` (the original hash maps) vs
//!   `Layout::Flat` (slab arenas + columnar staging), both splits and
//!   the pipeline off under the stealing scheduler — the configurations
//!   differ ONLY in where state lives, so the comparison isolates what
//!   the contiguous memory walk buys on the compute wall, with the
//!   `staging_bytes_peak` gauge as the flat-engagement signal;
//! * the **serving sweep** replays an open-loop hub2 arrival stream (a
//!   hub core of `d_ub <= 2` point lookups with a whale burst — deep
//!   ladder walks the index flags heavy — landing a quarter in) against
//!   the bounded submission queue under `Admit::Static` vs
//!   `Admit::Adaptive`, reporting throughput plus p50/p99/p99.9 latency
//!   and p99 queueing delay from the engine's streaming sketches — all
//!   on the simulated clock, so the percentiles and the
//!   adaptive-vs-static p99 headline are machine-independent.
//! * the **mutation sweep** replays a streaming schedule of small edit
//!   batches interleaved with lookup waves against the hub2 index two
//!   ways — an always-on `Hub2Serve` engine with the epoch overlay and
//!   incremental affected-hub maintenance vs folding every batch into a
//!   fresh CSR and rebuilding the whole index over the same frozen hub
//!   set — and reports end-to-end wall, the maintenance share, and the
//!   epoch gauges that prove the overlay engaged.
//!
//! With `--json`, the same numbers are written to `BENCH_pr2.json`
//! (thread sweep), `BENCH_pr3.json` (skew sweep), `BENCH_pr4.json`
//! (split sweep), `BENCH_pr5.json` (edge-split sweep), `BENCH_pr6.json`
//! (pipeline sweep), `BENCH_pr7.json` (layout sweep),
//! `BENCH_serving.json` (serving sweep), `BENCH_pr9.json` (mutation
//! sweep) and `BENCH_pr10.json` (multi-process sweep: 1-process vs
//! N-process rows with wire gauges) so the committed perf trajectory
//! is machine-readable; CI's `bench-smoke` lane validates
//! them with `ci/validate_bench.py` and archives them as workflow
//! artifacts. Setting `QUEGEL_BENCH_SMOKE=1` shrinks every input so the
//! whole module runs in CI-smoke time (the JSON shape is unchanged;
//! absolute numbers from smoke runs are not trajectory-grade).

use quegel::apps::ppsp::hub2::{
    lazy_query, Hub2Index, Hub2QueryContent, RustMinPlus, HEAVY_DUB_THRESHOLD,
};
use quegel::apps::ppsp::{
    lazy_serve_query, Bfs, BiBfs, Hub2Indexer, Hub2Maintainer, Hub2Query, Hub2Serve,
};
use quegel::apps::xml::{self, SlcaNaive, XmlGenConfig};
use quegel::coordinator::{Admit, EdgeSplit, Engine, Layout, Pipeline, Sched, Split};
use quegel::graph::{gen, Graph, GraphBuilder, MutationBatch, VersionedGraph};
use quegel::metrics::Table;
use quegel::network::Cluster;
use quegel::util::{env_flag, Rng};
use quegel::vertex::QueryApp;
use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, Ordering};
use std::time::Instant;

/// Set by `bench_main` when `--json` is passed: also emit the
/// `BENCH_*.json` trajectory files.
pub static JSON: AtomicBool = AtomicBool::new(false);

const THREAD_SWEEP: [usize; 4] = [1, 2, 4, 8];

/// Generators the layout sweep covers; its headline is the geometric
/// mean of the per-generator flat-vs-hashed compute speedups at 4
/// threads, so one graph's outlier can't carry (or sink) the gate alone.
const LAYOUT_GRAPHS: [&str; 3] = ["hub_concentrated", "mega_hub", "mono_hub"];

/// CI smoke mode: shrink inputs so the lane finishes fast while still
/// producing structurally complete JSON.
fn smoke() -> bool {
    env_flag("QUEGEL_BENCH_SMOKE")
}

fn median(mut xs: Vec<f64>) -> f64 {
    xs.sort_by(f64::total_cmp);
    xs[xs.len() / 2]
}

/// Median phase wall times of one workload at one `threads` setting.
struct PhaseRow {
    threads: usize,
    compute: f64,
    exchange: f64,
    barrier: f64,
    wall: f64,
}

/// Run `queries` as one batch (C = 8) per thread setting, `reps` reps
/// each, and report median phase times.
fn phase_rows<A, F>(
    mk: F,
    n: usize,
    workers: usize,
    queries: &[A::Query],
    reps: usize,
) -> Vec<PhaseRow>
where
    A: QueryApp,
    F: Fn() -> A,
{
    THREAD_SWEEP
        .iter()
        .map(|&threads| {
            let mut computes = Vec::new();
            let mut exchanges = Vec::new();
            let mut barriers = Vec::new();
            let mut walls = Vec::new();
            for _ in 0..reps {
                // Split::Off + EdgeSplit::Off + Pipeline::Off keep this
                // sweep measuring what it always has (thread scaling of
                // the PR 2 phase pipeline), not the PR 4/PR 5 splits or
                // the PR 6 pipelined rounds — BENCH_pr4.json,
                // BENCH_pr5.json and BENCH_pr6.json own those.
                let mut eng = Engine::new(mk(), Cluster::new(workers), n)
                    .capacity(8)
                    .threads(threads)
                    .split(Split::Off)
                    .edge_split(EdgeSplit::Off)
                    .pipeline(Pipeline::Off);
                for q in queries {
                    eng.submit(q.clone());
                }
                let t0 = Instant::now();
                eng.run_until_idle();
                walls.push(t0.elapsed().as_secs_f64());
                computes.push(eng.metrics().compute_time);
                exchanges.push(eng.metrics().exchange_time);
                barriers.push(eng.metrics().barrier_time);
            }
            PhaseRow {
                threads,
                compute: median(computes),
                exchange: median(exchanges),
                barrier: median(barriers),
                wall: median(walls),
            }
        })
        .collect()
}

fn print_phase_table(name: &str, rows: &[PhaseRow]) {
    let base_compute = rows[0].compute;
    let base_xb = rows[0].exchange + rows[0].barrier;
    let mut t = Table::new(vec![
        "threads",
        "compute",
        "exchange",
        "barrier",
        "total wall",
        "compute speedup",
        "exch+barrier speedup",
    ]);
    for r in rows {
        let xb = r.exchange + r.barrier;
        t.row(vec![
            r.threads.to_string(),
            format!("{:.1} ms", r.compute * 1e3),
            format!("{:.1} ms", r.exchange * 1e3),
            format!("{:.1} ms", r.barrier * 1e3),
            format!("{:.1} ms", r.wall * 1e3),
            format!("{:.2}x", base_compute / r.compute),
            format!("{:.2}x", base_xb / xb),
        ]);
    }
    println!("[{name}]");
    println!("{}", t.render());
}

/// Serialize one workload's sweep as a JSON array (no serde offline; the
/// format is fixed and flat, so hand-rolling is safe).
fn json_rows(rows: &[PhaseRow]) -> String {
    let base_compute = rows[0].compute;
    let base_xb = rows[0].exchange + rows[0].barrier;
    let items: Vec<String> = rows
        .iter()
        .map(|r| {
            format!(
                concat!(
                    "{{\"threads\":{},\"compute_s\":{:.6},\"exchange_s\":{:.6},",
                    "\"barrier_s\":{:.6},\"wall_s\":{:.6},",
                    "\"compute_speedup_vs_t1\":{:.3},",
                    "\"exchange_barrier_speedup_vs_t1\":{:.3}}}"
                ),
                r.threads,
                r.compute,
                r.exchange,
                r.barrier,
                r.wall,
                base_compute / r.compute,
                base_xb / (r.exchange + r.barrier),
            )
        })
        .collect();
    format!("[{}]", items.join(","))
}

/// One (scheduler, threads) configuration of the skew sweep: median phase
/// wall times plus the scheduler counters of a representative rep.
struct SkewRow {
    sched: Sched,
    threads: usize,
    compute: f64,
    exchange: f64,
    barrier: f64,
    steals: u64,
    jobs: u64,
    imbalance: f64,
}

impl SkewRow {
    /// Total phase wall time: the quantity the ≥1.2× skew target is on.
    fn phase_wall(&self) -> f64 {
        self.compute + self.exchange + self.barrier
    }
}

fn sched_name(s: Sched) -> &'static str {
    match s {
        Sched::Static => "static",
        Sched::Stealing => "stealing",
    }
}

/// BFS batch (C = 8) over the hub-concentrated graph, swept over
/// scheduler × threads.
fn skew_rows(g: &Graph, workers: usize, queries: &[(u32, u32)], reps: usize) -> Vec<SkewRow> {
    let mut rows = Vec::new();
    for sched in [Sched::Static, Sched::Stealing] {
        for &threads in &THREAD_SWEEP {
            let mut computes = Vec::new();
            let mut exchanges = Vec::new();
            let mut barriers = Vec::new();
            let mut steals = 0;
            let mut jobs = 0;
            let mut imbalance = 0.0;
            for _ in 0..reps {
                // Split::Off + EdgeSplit::Off: this sweep isolates
                // static-vs-stealing lane scheduling (the PR 3
                // trajectory); with the engine's Adaptive defaults the
                // stealing rows would silently measure stealing +
                // splitting instead — and BENCH_pr4 is premised on
                // split-off being exactly these numbers.
                let mut eng = Engine::new(Bfs::new(g), Cluster::new(workers), g.num_vertices())
                    .capacity(8)
                    .threads(threads)
                    .scheduler(sched)
                    .split(Split::Off)
                    .edge_split(EdgeSplit::Off)
                    .pipeline(Pipeline::Off);
                for &q in queries {
                    eng.submit(q);
                }
                eng.run_until_idle();
                computes.push(eng.metrics().compute_time);
                exchanges.push(eng.metrics().exchange_time);
                barriers.push(eng.metrics().barrier_time);
                steals = eng.metrics().steals();
                jobs = eng.metrics().jobs_executed();
                imbalance = eng.metrics().max_lane_imbalance;
            }
            rows.push(SkewRow {
                sched,
                threads,
                compute: median(computes),
                exchange: median(exchanges),
                barrier: median(barriers),
                steals,
                jobs,
                imbalance,
            });
        }
    }
    rows
}

/// Phase-wall speedup of stealing over static at the same thread count.
fn skew_speedup(rows: &[SkewRow], threads: usize) -> f64 {
    let wall = |sched: Sched| {
        rows.iter()
            .find(|r| r.sched == sched && r.threads == threads)
            .map(SkewRow::phase_wall)
            .unwrap_or(f64::NAN)
    };
    wall(Sched::Static) / wall(Sched::Stealing)
}

fn print_skew_table(name: &str, rows: &[SkewRow]) {
    let mut t = Table::new(vec![
        "sched",
        "threads",
        "compute",
        "exchange",
        "barrier",
        "phase wall",
        "jobs",
        "steals",
        "vs static",
    ]);
    for r in rows {
        let vs = match r.sched {
            Sched::Static => "baseline".to_string(),
            Sched::Stealing => format!("{:.2}x", skew_speedup(rows, r.threads)),
        };
        t.row(vec![
            sched_name(r.sched).to_string(),
            r.threads.to_string(),
            format!("{:.1} ms", r.compute * 1e3),
            format!("{:.1} ms", r.exchange * 1e3),
            format!("{:.1} ms", r.barrier * 1e3),
            format!("{:.1} ms", r.phase_wall() * 1e3),
            r.jobs.to_string(),
            r.steals.to_string(),
            vs,
        ]);
    }
    println!("[{name}]");
    println!("{}", t.render());
}

/// One (split, threads) configuration of the sub-lane split sweep on the
/// single-mega-hub graph.
struct SplitRow {
    split: Split,
    threads: usize,
    compute: f64,
    exchange: f64,
    barrier: f64,
    subjobs: u64,
    tasks_split: u64,
    lane_imbalance: f64,
    post_split_imbalance: f64,
}

fn split_name(s: Split) -> &'static str {
    match s {
        Split::Off => "off",
        Split::Adaptive => "adaptive",
        Split::MaxTaskVertices(_) => "fixed",
    }
}

/// BFS batch (C = 8) over the mega-hub graph, swept over split × threads,
/// always under `Sched::Stealing` — split-off IS PR 3's lane-granular
/// stealing, so the comparison isolates exactly what sub-splitting buys.
fn split_rows(
    g: &Graph,
    workers: usize,
    queries: &[(u32, u32)],
    reps: usize,
) -> Vec<SplitRow> {
    let mut rows = Vec::new();
    for split in [Split::Off, Split::Adaptive] {
        for &threads in &THREAD_SWEEP {
            let mut computes = Vec::new();
            let mut exchanges = Vec::new();
            let mut barriers = Vec::new();
            let mut subjobs = 0;
            let mut tasks_split = 0;
            let mut lane_imbalance = 0.0;
            let mut post_split_imbalance = 0.0;
            for _ in 0..reps {
                // EdgeSplit::Off: the PR 4 sweep isolates vertex-range
                // splitting of a heavy receiver batch; letting the new
                // edge split park the mega-hub's fanout would shrink the
                // very serialization this sweep's off-rows measure.
                let mut eng = Engine::new(Bfs::new(g), Cluster::new(workers), g.num_vertices())
                    .capacity(8)
                    .threads(threads)
                    .scheduler(Sched::Stealing)
                    .split(split)
                    .edge_split(EdgeSplit::Off)
                    .pipeline(Pipeline::Off);
                for &q in queries {
                    eng.submit(q);
                }
                eng.run_until_idle();
                computes.push(eng.metrics().compute_time);
                exchanges.push(eng.metrics().exchange_time);
                barriers.push(eng.metrics().barrier_time);
                subjobs = eng.metrics().subjobs_executed;
                tasks_split = eng.metrics().tasks_split;
                lane_imbalance = eng.metrics().max_lane_imbalance;
                post_split_imbalance = eng.metrics().max_post_split_imbalance;
            }
            rows.push(SplitRow {
                split,
                threads,
                compute: median(computes),
                exchange: median(exchanges),
                barrier: median(barriers),
                subjobs,
                tasks_split,
                lane_imbalance,
                post_split_imbalance,
            });
        }
    }
    rows
}

/// Compute-wall speedup of split-on over split-off at the same threads —
/// the quantity the ≥1.3× mega-hub target is on.
fn split_speedup(rows: &[SplitRow], threads: usize) -> f64 {
    let compute = |split: Split| {
        rows.iter()
            .find(|r| r.split == split && r.threads == threads)
            .map(|r| r.compute)
            .unwrap_or(f64::NAN)
    };
    compute(Split::Off) / compute(Split::Adaptive)
}

fn print_split_table(name: &str, rows: &[SplitRow]) {
    let mut t = Table::new(vec![
        "split",
        "threads",
        "compute",
        "exchange",
        "barrier",
        "subjobs",
        "tasks split",
        "post-split imbal",
        "vs off",
    ]);
    for r in rows {
        let vs = match r.split {
            Split::Off => "baseline".to_string(),
            _ => format!("{:.2}x", split_speedup(rows, r.threads)),
        };
        t.row(vec![
            split_name(r.split).to_string(),
            r.threads.to_string(),
            format!("{:.1} ms", r.compute * 1e3),
            format!("{:.1} ms", r.exchange * 1e3),
            format!("{:.1} ms", r.barrier * 1e3),
            r.subjobs.to_string(),
            r.tasks_split.to_string(),
            format!("{:.2}x", r.post_split_imbalance),
            vs,
        ]);
    }
    println!("[{name}]");
    println!("{}", t.render());
}

fn json_split_rows(rows: &[SplitRow]) -> String {
    let items: Vec<String> = rows
        .iter()
        .map(|r| {
            format!(
                concat!(
                    "{{\"split\":\"{}\",\"threads\":{},\"compute_s\":{:.6},",
                    "\"exchange_s\":{:.6},\"barrier_s\":{:.6},",
                    "\"subjobs_executed\":{},\"tasks_split\":{},",
                    "\"max_lane_imbalance\":{:.3},",
                    "\"max_post_split_imbalance\":{:.3}}}"
                ),
                split_name(r.split),
                r.threads,
                r.compute,
                r.exchange,
                r.barrier,
                r.subjobs,
                r.tasks_split,
                r.lane_imbalance,
                r.post_split_imbalance,
            )
        })
        .collect();
    format!("[{}]", items.join(","))
}

/// One (edge-split, threads) configuration of the edge-level split sweep
/// on the single-vertex-fanout graph.
struct EdgeRow {
    edge: EdgeSplit,
    threads: usize,
    compute: f64,
    exchange: f64,
    barrier: f64,
    edge_ranges: u64,
    max_edge_task: u64,
    subjobs: u64,
    lane_imbalance: f64,
    post_split_imbalance: f64,
}

fn edge_name(e: EdgeSplit) -> &'static str {
    match e {
        EdgeSplit::Off => "off",
        EdgeSplit::Adaptive => "adaptive",
        EdgeSplit::MaxFanout(_) => "fixed",
    }
}

/// BFS batch (C = 8) over the mono-hub graph, swept over edge-split ×
/// threads, always under `Sched::Stealing` + `Split::Adaptive` — with the
/// edge split off, the hub's `compute()` staging its whole fanout is ONE
/// indivisible work item no vertex-range split can cut, so the comparison
/// isolates exactly what edge-range splitting buys.
fn edge_rows(
    g: &Graph,
    workers: usize,
    queries: &[(u32, u32)],
    reps: usize,
) -> Vec<EdgeRow> {
    let mut rows = Vec::new();
    for edge in [EdgeSplit::Off, EdgeSplit::Adaptive] {
        for &threads in &THREAD_SWEEP {
            let mut computes = Vec::new();
            let mut exchanges = Vec::new();
            let mut barriers = Vec::new();
            let mut edge_ranges = 0;
            let mut max_edge_task = 0;
            let mut subjobs = 0;
            let mut lane_imbalance = 0.0;
            let mut post_split_imbalance = 0.0;
            for _ in 0..reps {
                let mut eng = Engine::new(Bfs::new(g), Cluster::new(workers), g.num_vertices())
                    .capacity(8)
                    .threads(threads)
                    .scheduler(Sched::Stealing)
                    .split(Split::Adaptive)
                    .edge_split(edge)
                    .pipeline(Pipeline::Off);
                for &q in queries {
                    eng.submit(q);
                }
                eng.run_until_idle();
                computes.push(eng.metrics().compute_time);
                exchanges.push(eng.metrics().exchange_time);
                barriers.push(eng.metrics().barrier_time);
                edge_ranges = eng.metrics().edge_ranges_split;
                max_edge_task = eng.metrics().max_edge_task;
                subjobs = eng.metrics().subjobs_executed;
                lane_imbalance = eng.metrics().max_lane_imbalance;
                post_split_imbalance = eng.metrics().max_post_split_imbalance;
            }
            rows.push(EdgeRow {
                edge,
                threads,
                compute: median(computes),
                exchange: median(exchanges),
                barrier: median(barriers),
                edge_ranges,
                max_edge_task,
                subjobs,
                lane_imbalance,
                post_split_imbalance,
            });
        }
    }
    rows
}

/// Compute-wall speedup of edge-split-on over edge-split-off at the same
/// threads — the quantity the ≥1.25× mono-hub target is on.
fn edge_speedup(rows: &[EdgeRow], threads: usize) -> f64 {
    let compute = |edge: EdgeSplit| {
        rows.iter()
            .find(|r| r.edge == edge && r.threads == threads)
            .map(|r| r.compute)
            .unwrap_or(f64::NAN)
    };
    compute(EdgeSplit::Off) / compute(EdgeSplit::Adaptive)
}

fn print_edge_table(name: &str, rows: &[EdgeRow]) {
    let mut t = Table::new(vec![
        "edge split",
        "threads",
        "compute",
        "exchange",
        "barrier",
        "edge ranges",
        "max fan",
        "post-split imbal",
        "vs off",
    ]);
    for r in rows {
        let vs = match r.edge {
            EdgeSplit::Off => "baseline".to_string(),
            _ => format!("{:.2}x", edge_speedup(rows, r.threads)),
        };
        t.row(vec![
            edge_name(r.edge).to_string(),
            r.threads.to_string(),
            format!("{:.1} ms", r.compute * 1e3),
            format!("{:.1} ms", r.exchange * 1e3),
            format!("{:.1} ms", r.barrier * 1e3),
            r.edge_ranges.to_string(),
            r.max_edge_task.to_string(),
            format!("{:.2}x", r.post_split_imbalance),
            vs,
        ]);
    }
    println!("[{name}]");
    println!("{}", t.render());
}

fn json_edge_rows(rows: &[EdgeRow]) -> String {
    let items: Vec<String> = rows
        .iter()
        .map(|r| {
            format!(
                concat!(
                    "{{\"edge_split\":\"{}\",\"threads\":{},\"compute_s\":{:.6},",
                    "\"exchange_s\":{:.6},\"barrier_s\":{:.6},",
                    "\"edge_ranges_split\":{},\"max_edge_task\":{},",
                    "\"subjobs_executed\":{},\"max_lane_imbalance\":{:.3},",
                    "\"max_post_split_imbalance\":{:.3}}}"
                ),
                edge_name(r.edge),
                r.threads,
                r.compute,
                r.exchange,
                r.barrier,
                r.edge_ranges,
                r.max_edge_task,
                r.subjobs,
                r.lane_imbalance,
                r.post_split_imbalance,
            )
        })
        .collect();
    format!("[{}]", items.join(","))
}

/// One (pipeline, threads) configuration of the pipelined-round sweep on
/// the one-slow-query graph. `compute`/`exchange`/`fold` are per-phase
/// **busy** seconds (work actually done, summed across threads), so under
/// `Pipeline::On` their sum can legitimately exceed `wall`; `overlap` is
/// the wall time with two-plus phases simultaneously live.
struct PipeRow {
    pipeline: Pipeline,
    threads: usize,
    wall: f64,
    compute: f64,
    exchange: f64,
    fold: f64,
    overlap: f64,
    pipelined_rounds: u64,
}

fn pipeline_name(p: Pipeline) -> &'static str {
    match p {
        Pipeline::Off => "barrier",
        Pipeline::On => "pipelined",
    }
}

/// One slow BFS (the lane-0 ladder) + a stream of point lookups, swept
/// over pipeline × threads under `Sched::Stealing` with both splits off
/// (pipelining's engagement precondition, and the configuration the
/// barrier baseline is PR 5's engine in). Capacity is deliberately
/// modest so the admission queue keeps feeding fresh cheap queries every
/// super-round for the ladder's whole lifetime — the workload pipelining
/// exists for.
fn pipe_rows(
    g: &Graph,
    workers: usize,
    queries: &[(u32, u32)],
    capacity: usize,
    reps: usize,
) -> Vec<PipeRow> {
    let mut rows = Vec::new();
    for pipeline in [Pipeline::Off, Pipeline::On] {
        for &threads in &THREAD_SWEEP {
            let mut walls = Vec::new();
            let mut computes = Vec::new();
            let mut exchanges = Vec::new();
            let mut folds = Vec::new();
            let mut overlaps = Vec::new();
            let mut pipelined_rounds = 0;
            for _ in 0..reps {
                let mut eng = Engine::new(Bfs::new(g), Cluster::new(workers), g.num_vertices())
                    .capacity(capacity)
                    .threads(threads)
                    .scheduler(Sched::Stealing)
                    .split(Split::Off)
                    .edge_split(EdgeSplit::Off)
                    .pipeline(pipeline);
                for &q in queries {
                    eng.submit(q);
                }
                let t0 = Instant::now();
                eng.run_until_idle();
                walls.push(t0.elapsed().as_secs_f64());
                computes.push(eng.metrics().compute_time);
                exchanges.push(eng.metrics().exchange_time);
                folds.push(eng.metrics().barrier_time);
                overlaps.push(eng.metrics().overlap_time);
                pipelined_rounds = eng.metrics().pipelined_rounds;
            }
            rows.push(PipeRow {
                pipeline,
                threads,
                wall: median(walls),
                compute: median(computes),
                exchange: median(exchanges),
                fold: median(folds),
                overlap: median(overlaps),
                pipelined_rounds,
            });
        }
    }
    rows
}

/// End-to-end wall speedup of pipelined over barrier rounds at the same
/// thread count — the quantity the ≥1.3× one-slow-query target is on.
fn pipe_speedup(rows: &[PipeRow], threads: usize) -> f64 {
    let wall = |pipeline: Pipeline| {
        rows.iter()
            .find(|r| r.pipeline == pipeline && r.threads == threads)
            .map(|r| r.wall)
            .unwrap_or(f64::NAN)
    };
    wall(Pipeline::Off) / wall(Pipeline::On)
}

fn print_pipe_table(name: &str, rows: &[PipeRow]) {
    let mut t = Table::new(vec![
        "rounds",
        "threads",
        "wall",
        "compute busy",
        "exchange busy",
        "fold busy",
        "overlap",
        "pipelined rounds",
        "vs barrier",
    ]);
    for r in rows {
        let vs = match r.pipeline {
            Pipeline::Off => "baseline".to_string(),
            Pipeline::On => format!("{:.2}x", pipe_speedup(rows, r.threads)),
        };
        t.row(vec![
            pipeline_name(r.pipeline).to_string(),
            r.threads.to_string(),
            format!("{:.1} ms", r.wall * 1e3),
            format!("{:.1} ms", r.compute * 1e3),
            format!("{:.1} ms", r.exchange * 1e3),
            format!("{:.1} ms", r.fold * 1e3),
            format!("{:.1} ms", r.overlap * 1e3),
            r.pipelined_rounds.to_string(),
            vs,
        ]);
    }
    println!("[{name}]");
    println!("{}", t.render());
}

fn json_pipe_rows(rows: &[PipeRow]) -> String {
    let items: Vec<String> = rows
        .iter()
        .map(|r| {
            format!(
                concat!(
                    "{{\"pipeline\":\"{}\",\"threads\":{},\"wall_s\":{:.6},",
                    "\"compute_busy_s\":{:.6},\"exchange_busy_s\":{:.6},",
                    "\"fold_busy_s\":{:.6},\"overlap_s\":{:.6},",
                    "\"pipelined_rounds\":{}}}"
                ),
                pipeline_name(r.pipeline),
                r.threads,
                r.wall,
                r.compute,
                r.exchange,
                r.fold,
                r.overlap,
                r.pipelined_rounds,
            )
        })
        .collect();
    format!("[{}]", items.join(","))
}

/// One (graph, layout, threads) configuration of the memory-layout sweep
/// across the three adversarial generators.
struct LayoutRow {
    graph: &'static str,
    layout: Layout,
    threads: usize,
    compute: f64,
    exchange: f64,
    barrier: f64,
    staging_peak: u64,
}

fn layout_name(l: Layout) -> &'static str {
    match l {
        Layout::Hashed => "hashed",
        Layout::Flat => "flat",
    }
}

/// BFS batch (C = 8) over one adversarial graph, swept over layout ×
/// threads, always under `Sched::Stealing` with both splits and the
/// pipeline off — the two configurations differ ONLY in where the
/// per-query stores live (hash maps vs slab arenas + columnar staging),
/// so the comparison isolates exactly what the contiguous memory walk
/// buys on the compute wall.
fn layout_rows(
    graph: &'static str,
    g: &Graph,
    workers: usize,
    queries: &[(u32, u32)],
    reps: usize,
) -> Vec<LayoutRow> {
    let mut rows = Vec::new();
    for layout in [Layout::Hashed, Layout::Flat] {
        for &threads in &THREAD_SWEEP {
            let mut computes = Vec::new();
            let mut exchanges = Vec::new();
            let mut barriers = Vec::new();
            let mut staging_peak = 0;
            for _ in 0..reps {
                let mut eng = Engine::new(Bfs::new(g), Cluster::new(workers), g.num_vertices())
                    .capacity(8)
                    .threads(threads)
                    .scheduler(Sched::Stealing)
                    .split(Split::Off)
                    .edge_split(EdgeSplit::Off)
                    .pipeline(Pipeline::Off)
                    .layout(layout);
                for &q in queries {
                    eng.submit(q);
                }
                eng.run_until_idle();
                computes.push(eng.metrics().compute_time);
                exchanges.push(eng.metrics().exchange_time);
                barriers.push(eng.metrics().barrier_time);
                staging_peak = eng.metrics().staging_bytes_peak;
            }
            rows.push(LayoutRow {
                graph,
                layout,
                threads,
                compute: median(computes),
                exchange: median(exchanges),
                barrier: median(barriers),
                staging_peak,
            });
        }
    }
    rows
}

/// Compute-wall speedup of the flat stores over the hashed baseline on
/// one graph at the same thread count — the per-generator input to the
/// geomean headline the ≥1.3× layout target is on.
fn layout_speedup(rows: &[LayoutRow], graph: &str, threads: usize) -> f64 {
    let compute = |layout: Layout| {
        rows.iter()
            .find(|r| r.graph == graph && r.layout == layout && r.threads == threads)
            .map(|r| r.compute)
            .unwrap_or(f64::NAN)
    };
    compute(Layout::Hashed) / compute(Layout::Flat)
}

fn print_layout_table(name: &str, rows: &[LayoutRow]) {
    let mut t = Table::new(vec![
        "graph",
        "layout",
        "threads",
        "compute",
        "exchange",
        "barrier",
        "staging peak",
        "vs hashed",
    ]);
    for r in rows {
        let vs = match r.layout {
            Layout::Hashed => "baseline".to_string(),
            Layout::Flat => format!("{:.2}x", layout_speedup(rows, r.graph, r.threads)),
        };
        t.row(vec![
            r.graph.to_string(),
            layout_name(r.layout).to_string(),
            r.threads.to_string(),
            format!("{:.1} ms", r.compute * 1e3),
            format!("{:.1} ms", r.exchange * 1e3),
            format!("{:.1} ms", r.barrier * 1e3),
            format!("{} B", r.staging_peak),
            vs,
        ]);
    }
    println!("[{name}]");
    println!("{}", t.render());
}

fn json_layout_rows(rows: &[LayoutRow]) -> String {
    let items: Vec<String> = rows
        .iter()
        .map(|r| {
            format!(
                concat!(
                    "{{\"graph\":\"{}\",\"layout\":\"{}\",\"threads\":{},",
                    "\"compute_s\":{:.6},\"exchange_s\":{:.6},",
                    "\"barrier_s\":{:.6},\"staging_bytes_peak\":{}}}"
                ),
                r.graph,
                layout_name(r.layout),
                r.threads,
                r.compute,
                r.exchange,
                r.barrier,
                r.staging_peak,
            )
        })
        .collect();
    format!("[{}]", items.join(","))
}

fn json_skew_rows(rows: &[SkewRow]) -> String {
    let items: Vec<String> = rows
        .iter()
        .map(|r| {
            format!(
                concat!(
                    "{{\"sched\":\"{}\",\"threads\":{},\"compute_s\":{:.6},",
                    "\"exchange_s\":{:.6},\"barrier_s\":{:.6},",
                    "\"phase_wall_s\":{:.6},\"jobs_executed\":{},",
                    "\"steals\":{},\"max_lane_imbalance\":{:.3}}}"
                ),
                sched_name(r.sched),
                r.threads,
                r.compute,
                r.exchange,
                r.barrier,
                r.phase_wall(),
                r.jobs,
                r.steals,
                r.imbalance,
            )
        })
        .collect();
    format!("[{}]", items.join(","))
}

// ---------------------------------------------------------------------------
// Serving sweep: open-loop arrivals against the admission planner.
// ---------------------------------------------------------------------------

/// Serving testbed graph: a mono-hub **core** (hub vertex 0 wired to every
/// spoke in both directions, so any core pair is within 2 hops of the hub
/// and the Hub² front end stamps `d_ub <= 2` — provably-light point
/// lookups) plus a disconnected complete-bipartite **ladder** whose
/// entry-to-end walks grind for ~`depth/2` supersteps at up to `width^2`
/// messages per band — the whale population (`d_ub = depth`, flagged heavy
/// by [`Hub2Query::is_heavy`]). Returns (graph, ladder entry, last band).
fn serving_graph(core_n: usize, width: usize, depth: usize) -> (Graph, u32, Vec<u32>) {
    let n = core_n + 1 + width * depth;
    let mut b = GraphBuilder::new(n);
    for v in 1..core_n as u32 {
        b.edge(0, v);
        b.edge(v, 0);
    }
    let entry = core_n as u32;
    let band = |i: usize, j: usize| (core_n + 1 + i * width + j) as u32;
    for j in 0..width {
        b.edge(entry, band(0, j));
    }
    for i in 0..depth - 1 {
        for j in 0..width {
            for j2 in 0..width {
                b.edge(band(i, j), band(i + 1, j2));
            }
        }
    }
    let last: Vec<u32> = (0..width).map(|j| band(depth - 1, j)).collect();
    let mut g = b.build();
    g.ensure_in_edges();
    (g, entry, last)
}

/// Fixed serving-engine shape shared by every row of the sweep.
struct ServeCfg {
    workers: usize,
    capacity: usize,
    queue_bound: usize,
}

/// Light-only service rate in queries per simulated second: a pilot batch
/// of core lookups run to idle under static admission. The open-loop
/// arrival rate is set to a fixed utilization of this, so the sweep
/// stresses the planner rather than the absolute cost-model scale.
fn light_service_rate(g: &Graph, idx: &Hub2Index, core_n: usize, cfg: &ServeCfg) -> f64 {
    let pilot = gen::random_pairs(core_n, 64, 447);
    let dubs = idx.dub_for(&pilot, &RustMinPlus, 1, idx.k());
    let mut eng = Engine::new(Hub2Query::new(g, idx), Cluster::new(cfg.workers), g.num_vertices())
        .capacity(cfg.capacity)
        .admit(Admit::Static(cfg.capacity))
        .threads(1)
        .scheduler(Sched::Stealing)
        .pipeline(Pipeline::Off);
    for (&(s, t), &d) in pilot.iter().zip(dubs.iter()) {
        eng.submit((s, t, d));
    }
    eng.run_until_idle();
    pilot.len() as f64 / eng.sim_time().max(1e-12)
}

struct ServeRow {
    admit: &'static str,
    threads: usize,
    completed: u64,
    /// Throughput on the simulated clock (deterministic).
    qps: f64,
    /// Throughput on the host wall clock (machine-dependent, advisory).
    qps_wall: f64,
    p50: f64,
    p99: f64,
    p999: f64,
    queueing_p99: f64,
    deferrals: u64,
    backpressured: u64,
    wall: f64,
}

/// One closed-loop serving run: replay `trace` — (query, arrival
/// sim-time) pairs in nondecreasing arrival order — against the engine as
/// an open-loop source. Arrivals are delivered once the simulated clock
/// passes them, back-pressured requests are re-offered in arrival order
/// (their `arrived_at` stamp is the original arrival, so the wait shows
/// up in the latency sketches), and the clock jumps to the next arrival
/// whenever the engine goes idle. Percentiles come from the engine's
/// streaming sketches on simulated time, so every number but the wall
/// clock is bit-reproducible on any machine.
fn serve_once(
    g: &Graph,
    idx: &Hub2Index,
    trace: &[(Hub2QueryContent, f64)],
    admit: Admit,
    admit_name: &'static str,
    threads: usize,
    cfg: &ServeCfg,
) -> ServeRow {
    let mut eng = Engine::new(Hub2Query::new(g, idx), Cluster::new(cfg.workers), g.num_vertices())
        .capacity(cfg.capacity)
        .admit(admit)
        .threads(threads)
        .scheduler(Sched::Stealing)
        .pipeline(Pipeline::Off)
        .queue_bound(cfg.queue_bound);
    let mut retry: VecDeque<(Hub2QueryContent, f64)> = VecDeque::new();
    let mut backpressured = 0u64;
    let mut next = 0usize;
    let t0 = Instant::now();
    loop {
        while let Some(&(q, at)) = retry.front() {
            if eng.try_submit(q, at).is_ok() {
                retry.pop_front();
            } else {
                break;
            }
        }
        while next < trace.len() && trace[next].1 <= eng.sim_time() {
            let (q, at) = trace[next];
            next += 1;
            if retry.is_empty() {
                match eng.try_submit(q, at) {
                    Ok(_) => {}
                    Err(q) => {
                        backpressured += 1;
                        retry.push_back((q, at));
                    }
                }
            } else {
                // Keep arrival order behind earlier back-pressured requests.
                retry.push_back((q, at));
            }
        }
        if !eng.super_round() {
            if !retry.is_empty() {
                // An idle engine has queue room: re-offered next pass.
                continue;
            }
            if next < trace.len() {
                let dt = trace[next].1 - eng.sim_time();
                if dt > 0.0 {
                    eng.advance_clock(dt);
                }
                continue;
            }
            break;
        }
    }
    let wall = t0.elapsed().as_secs_f64();
    let span = eng.sim_time().max(1e-12);
    let m = eng.metrics();
    assert_eq!(m.queries_completed, trace.len() as u64);
    ServeRow {
        admit: admit_name,
        threads,
        completed: m.queries_completed,
        qps: m.queries_completed as f64 / span,
        qps_wall: m.queries_completed as f64 / wall.max(1e-12),
        p50: m.latency.quantile(0.5),
        p99: m.latency.quantile(0.99),
        p999: m.latency.quantile(0.999),
        queueing_p99: m.queueing.quantile(0.99),
        deferrals: m.admit_deferrals,
        backpressured,
        wall,
    }
}

fn serve_rows(
    g: &Graph,
    idx: &Hub2Index,
    trace: &[(Hub2QueryContent, f64)],
    cfg: &ServeCfg,
) -> Vec<ServeRow> {
    let mut rows = Vec::new();
    for &threads in &[1usize, 4] {
        for (admit, name) in [
            (Admit::Static(cfg.capacity), "static"),
            (Admit::Adaptive, "adaptive"),
        ] {
            rows.push(serve_once(g, idx, trace, admit, name, threads, cfg));
        }
    }
    rows
}

/// Headline: static p99 / adaptive p99 at the given thread count (> 1
/// means the planner improved the tail).
fn serve_speedup(rows: &[ServeRow], threads: usize) -> f64 {
    let p99 = |name: &str| {
        rows.iter()
            .find(|r| r.admit == name && r.threads == threads)
            .map(|r| r.p99)
            .unwrap_or(0.0)
    };
    let adaptive = p99("adaptive");
    if adaptive > 0.0 {
        p99("static") / adaptive
    } else {
        0.0
    }
}

fn print_serve_table(name: &str, rows: &[ServeRow]) {
    let mut t = Table::new(vec![
        "admit",
        "threads",
        "qps(sim)",
        "p50",
        "p99",
        "p99.9",
        "queue p99",
        "deferrals",
        "backpressured",
        "wall",
    ]);
    for r in rows {
        t.row(vec![
            r.admit.to_string(),
            r.threads.to_string(),
            format!("{:.1}", r.qps),
            format!("{:.2} ms", r.p50 * 1e3),
            format!("{:.2} ms", r.p99 * 1e3),
            format!("{:.2} ms", r.p999 * 1e3),
            format!("{:.2} ms", r.queueing_p99 * 1e3),
            r.deferrals.to_string(),
            r.backpressured.to_string(),
            format!("{:.0} ms", r.wall * 1e3),
        ]);
    }
    println!("\n{name}");
    println!("{}", t.render());
}

fn json_serve_rows(rows: &[ServeRow]) -> String {
    let items: Vec<String> = rows
        .iter()
        .map(|r| {
            format!(
                concat!(
                    "{{\"admit\":\"{}\",\"threads\":{},\"completed\":{},",
                    "\"qps\":{:.3},\"qps_wall\":{:.3},\"p50_s\":{:.9},",
                    "\"p99_s\":{:.9},\"p999_s\":{:.9},",
                    "\"queueing_p99_s\":{:.9},\"admit_deferrals\":{},",
                    "\"backpressured\":{},\"wall_s\":{:.6}}}"
                ),
                r.admit,
                r.threads,
                r.completed,
                r.qps,
                r.qps_wall,
                r.p50,
                r.p99,
                r.p999,
                r.queueing_p99,
                r.deferrals,
                r.backpressured,
                r.wall,
            )
        })
        .collect();
    format!("[{}]", items.join(","))
}

// ---------------------------------------------------------------------------
// Mutation sweep: incremental hub2 maintenance vs full index rebuild.
// ---------------------------------------------------------------------------

/// One (mode, threads) configuration of the streaming-mutation sweep.
struct MutRow {
    mode: &'static str,
    threads: usize,
    /// End-to-end host wall of the whole maintain-and-serve loop.
    wall: f64,
    /// The maintenance share: overlay apply + affected-hub refresh for the
    /// incremental mode, CSR fold + full `build_with_hubs` for rebuild.
    maint: f64,
    epochs_applied: u64,
    delta_bytes_peak: u64,
    completed: u64,
}

/// Deterministic streaming schedule: `rounds` small batches, each a few
/// edge deletes drawn from arcs that exist plus a few random adds — the
/// streaming regime the overlay exists for, where recomputing every hub
/// BFS per batch is almost all waste. Batches are built against the
/// serially folded chain so every delete names a live arc.
fn mutation_schedule(g0: &Graph, rounds: usize, edits: usize, seed: u64) -> Vec<MutationBatch> {
    let mut rng = Rng::new(seed);
    let mut cur = g0.clone();
    let mut batches = Vec::new();
    for _ in 0..rounds {
        let n = cur.num_vertices();
        let mut b = MutationBatch::new();
        for _ in 0..edits {
            let v = rng.below(n as u64) as u32;
            let out = cur.out(v);
            if !out.is_empty() {
                b.delete_edge(v, out[rng.below_usize(out.len())]);
            }
        }
        for _ in 0..edits {
            let u = rng.below(n as u64) as u32;
            let w = rng.below(n as u64) as u32;
            b.add_edge(u, w);
        }
        cur = cur.apply(&b);
        batches.push(b);
    }
    batches
}

/// Incremental mode: ONE always-on [`Hub2Serve`] engine; each round queues
/// a batch via `try_mutate` (applied to the epoch overlay and incrementally
/// maintained at the next round boundary) and serves a wave of lazy
/// lookups. `maint` is attributed on a standalone overlay + maintainer
/// replay of the same schedule, since the engine's own refresh runs inside
/// its round loop where it is part of `wall`.
fn mut_incremental_row(
    g: &Graph,
    indexer: &Hub2Indexer,
    batches: &[MutationBatch],
    waves: &[Vec<(u32, u32)>],
    workers: usize,
    threads: usize,
) -> MutRow {
    let app = Hub2Serve::build(g.clone(), indexer, Cluster::new(workers), &RustMinPlus);
    let mut eng = Engine::new(app, Cluster::new(workers), g.num_vertices())
        .capacity(8)
        .admit(Admit::Static(8))
        .threads(threads)
        .scheduler(Sched::Stealing)
        .pipeline(Pipeline::Off);
    let t0 = Instant::now();
    for (b, wave) in batches.iter().zip(waves) {
        eng.try_mutate(b.clone(), eng.sim_time())
            .expect("Hub2Serve supports mutations");
        for &(s, t) in wave {
            eng.try_submit(lazy_serve_query(s, t), eng.sim_time())
                .expect("queue accepts");
        }
        eng.run_until_idle();
    }
    let wall = t0.elapsed().as_secs_f64();
    let mut gin = g.clone();
    gin.ensure_in_edges();
    let (mut idx, _) = indexer.build(&gin, Cluster::new(workers), &RustMinPlus);
    let mut vg = VersionedGraph::new(gin);
    let mut maintainer = Hub2Maintainer::new(&vg, &idx, false);
    let tm = Instant::now();
    for b in batches {
        vg.apply(b);
        maintainer.refresh(&vg, &mut idx, b);
    }
    let maint = tm.elapsed().as_secs_f64();
    MutRow {
        mode: "incremental",
        threads,
        wall,
        maint,
        epochs_applied: eng.metrics().epochs_applied,
        delta_bytes_peak: eng.metrics().delta_bytes_peak,
        completed: eng.metrics().queries_completed,
    }
}

/// Rebuild mode: the correctness baseline run as a strategy — each round
/// folds the batch into a fresh CSR and rebuilds the ENTIRE index over the
/// same frozen hub set, then serves the wave with the immutable
/// [`Hub2Query`] app. `epochs_applied` stays 0: no engine in this mode
/// ever sees a mutation, which is the shape `ci/validate_bench.py` pins.
fn mut_rebuild_row(
    g: &Graph,
    indexer: &Hub2Indexer,
    batches: &[MutationBatch],
    waves: &[Vec<(u32, u32)>],
    workers: usize,
    threads: usize,
) -> MutRow {
    let mut cur = g.clone();
    cur.ensure_in_edges();
    let hubs = indexer.pick_hubs(&cur);
    let mut maint = 0.0;
    let mut completed = 0u64;
    let mut epochs = 0u64;
    let t0 = Instant::now();
    for (b, wave) in batches.iter().zip(waves) {
        let tm = Instant::now();
        cur = cur.apply(b);
        cur.ensure_in_edges();
        let (idx, _) =
            indexer.build_with_hubs(&cur, hubs.clone(), Cluster::new(workers), &RustMinPlus);
        maint += tm.elapsed().as_secs_f64();
        let mut eng = Engine::new(
            Hub2Query::new(&cur, &idx),
            Cluster::new(workers),
            cur.num_vertices(),
        )
        .capacity(8)
        .admit(Admit::Static(8))
        .threads(threads)
        .scheduler(Sched::Stealing)
        .pipeline(Pipeline::Off);
        for &(s, t) in wave {
            eng.submit(lazy_query(s, t));
        }
        eng.run_until_idle();
        completed += eng.metrics().queries_completed;
        epochs += eng.metrics().epochs_applied;
    }
    let wall = t0.elapsed().as_secs_f64();
    MutRow {
        mode: "rebuild",
        threads,
        wall,
        maint,
        epochs_applied: epochs,
        delta_bytes_peak: 0,
        completed,
    }
}

/// End-to-end wall speedup of incremental maintenance over full rebuild at
/// the same thread count — the quantity the ≥1.2× streaming target is on.
fn mut_speedup(rows: &[MutRow], threads: usize) -> f64 {
    let wall = |mode: &str| {
        rows.iter()
            .find(|r| r.mode == mode && r.threads == threads)
            .map(|r| r.wall)
            .unwrap_or(f64::NAN)
    };
    wall("rebuild") / wall("incremental")
}

fn print_mut_table(name: &str, rows: &[MutRow]) {
    let mut t = Table::new(vec![
        "mode",
        "threads",
        "wall",
        "maintenance",
        "epochs",
        "delta peak",
        "completed",
        "vs rebuild",
    ]);
    for r in rows {
        let vs = match r.mode {
            "rebuild" => "baseline".to_string(),
            _ => format!("{:.2}x", mut_speedup(rows, r.threads)),
        };
        t.row(vec![
            r.mode.to_string(),
            r.threads.to_string(),
            format!("{:.1} ms", r.wall * 1e3),
            format!("{:.1} ms", r.maint * 1e3),
            r.epochs_applied.to_string(),
            format!("{} B", r.delta_bytes_peak),
            r.completed.to_string(),
            vs,
        ]);
    }
    println!("[{name}]");
    println!("{}", t.render());
}

fn json_mut_rows(rows: &[MutRow]) -> String {
    let items: Vec<String> = rows
        .iter()
        .map(|r| {
            format!(
                concat!(
                    "{{\"mode\":\"{}\",\"threads\":{},\"wall_s\":{:.6},",
                    "\"maint_s\":{:.6},\"epochs_applied\":{},",
                    "\"delta_bytes_peak\":{},\"completed\":{}}}"
                ),
                r.mode,
                r.threads,
                r.wall,
                r.maint,
                r.epochs_applied,
                r.delta_bytes_peak,
                r.completed,
            )
        })
        .collect();
    format!("[{}]", items.join(","))
}

pub fn run() {
    let smoke = smoke();
    let reps = if smoke { 1 } else { 3 };
    let (tw_n, tw_q) = if smoke { (8_000, 16) } else { (100_000, 64) };
    let mut g = gen::twitter_like(tw_n, 10, 433);
    g.ensure_in_edges();
    let n = g.num_vertices();
    let queries = gen::random_pairs(n, tw_q, 434);
    if smoke {
        println!("QUEGEL_BENCH_SMOKE=1: shrunken inputs, 1 rep (CI lane)");
    }

    let mut t = Table::new(vec![
        "workload",
        "median wall",
        "compute calls",
        "calls/us",
    ]);

    // Engine throughput: BFS batch (dense frontier — state-table bound).
    for (name, cap) in [("bfs batch C=8", 8usize), ("bfs serial C=1", 1)] {
        let mut times = Vec::new();
        let mut calls = 0;
        for _ in 0..reps {
            let mut eng = Engine::new(Bfs::new(&g), Cluster::new(8), n)
                .capacity(cap)
                .threads(1);
            for &q in &queries {
                eng.submit(q);
            }
            let t0 = Instant::now();
            eng.run_until_idle();
            times.push(t0.elapsed().as_secs_f64());
            calls = eng.metrics().total_compute_calls;
        }
        let m = median(times);
        t.row(vec![
            name.to_string(),
            format!("{:.1} ms", m * 1e3),
            calls.to_string(),
            format!("{:.1}", calls as f64 / (m * 1e6)),
        ]);
    }
    println!("{}", t.render());
    println!("target: > 2 compute calls / us in the batch path (see");
    println!("EXPERIMENTS.md §Perf for the iteration log).");

    // --- Phase split on the pooled engine, per threads setting.
    //
    // BiBFS (combiner-heavy: most traffic combines away at the sender, so
    // compute dominates) vs naive SLCA without combiner (combiner-less:
    // every upward send reaches the staging buffers, so the exchange phase
    // carries the round).
    let bibfs_rows = phase_rows(|| BiBfs::new(&g), n, 8, &queries, reps);
    print_phase_table("bibfs batch C=8 W=8 (combiner-heavy)", &bibfs_rows);

    let tree = xml::data::generate(&XmlGenConfig {
        dblp_like: true,
        records: if smoke { 1_000 } else { 15_000 },
        vocab: 400,
        seed: 435,
    });
    let xml_queries = xml::data::query_pool(&tree, if smoke { 8 } else { 48 }, 3, 436);
    let xml_rows = phase_rows(
        || SlcaNaive::without_combiner(&tree),
        tree.len(),
        8,
        &xml_queries,
        reps,
    );
    print_phase_table("xml slca no-combiner C=8 W=8 (combiner-less)", &xml_rows);

    println!("targets: compute speedup >= 1.5x at 4 threads (BiBFS);");
    println!("exchange+barrier speedup >= 1.3x at 4 threads on the");
    println!("combiner-less XML workload. Results are bit-identical across");
    println!("the threads column by construction (tests/determinism.rs).");

    // --- Skew sweep: static chunks vs work stealing on a partition where
    // worker 0 of 8 owns every hub. Static chunking welds lane 0 to lane 1
    // in one thread's chunk at 4 threads; stealing gives the heavy lane a
    // thread of its own the moment any other thread drains its deque.
    let (sk_n, sk_q) = if smoke { (6_000, 8) } else { (60_000, 48) };
    let skew_workers = 8;
    let skew_g = gen::hub_concentrated(sk_n, skew_workers, 24, 6, 437);
    let skew_queries = gen::random_pairs(sk_n, sk_q, 438);
    let skew = skew_rows(&skew_g, skew_workers, &skew_queries, reps);
    print_skew_table("bfs hub-concentrated C=8 W=8 (skewed lane 0)", &skew);
    let headline = skew_speedup(&skew, 4);
    println!(
        "lane imbalance {:.1}x; stealing vs static phase wall at 4 threads: {:.2}x",
        skew.last().map(|r| r.imbalance).unwrap_or(0.0),
        headline
    );
    println!("target: stealing >= 1.2x over static at 4 threads on this");
    println!("partition; steals > 0 shows the deques actually engaged.");

    // --- Sub-lane split sweep: the single-mega-hub graph concentrates
    // one vertex's entire blast radius (~n/8 receivers) on worker 0, so
    // one compute task serializes the phase no matter how lanes are
    // stolen. Split-off is PR 3's lane-granular stealing; split-on cuts
    // the pathological task into sub-jobs.
    let (mh_n, mh_q) = if smoke { (8_000, 8) } else { (80_000, 48) };
    let mh_workers = 8;
    let mh_g = gen::mega_hub(mh_n, mh_workers, 8, 439);
    let mh_queries = gen::random_pairs(mh_n, mh_q, 440);
    let split = split_rows(&mh_g, mh_workers, &mh_queries, reps);
    print_split_table("bfs mega-hub C=8 W=8 (one pathological task)", &split);
    let split_headline = split_speedup(&split, 4);
    // Imbalance figures from the SAME configuration as the headline
    // speedup (adaptive, 4 threads): post-split granularity depends on
    // the thread count, so mixing rows would misattribute it.
    let headline_row = split
        .iter()
        .find(|r| r.split == Split::Adaptive && r.threads == 4);
    println!(
        "lane imbalance {:.1}x -> post-split {:.1}x; split vs off compute wall at 4 threads: {:.2}x",
        headline_row.map(|r| r.lane_imbalance).unwrap_or(0.0),
        headline_row.map(|r| r.post_split_imbalance).unwrap_or(0.0),
        split_headline
    );
    println!("target: splitting >= 1.3x over lane-granular stealing at 4");
    println!("threads on the mega-hub compute wall; subjobs > 0 shows the");
    println!("split actually engaged. Outputs are bit-identical across the");
    println!("whole table by construction (tests/fuzz_determinism.rs).");

    // --- Edge-level split sweep: the mono-hub graph gives ONE vertex an
    // out-edge to everyone, so the fan superstep stages ~n messages from
    // a single compute() call — one indivisible work item for every
    // earlier splitting granularity. Edge-split-off is PR 4's engine in
    // full; edge-split-on parks the fan, stages contiguous edge ranges as
    // pool jobs and folds them back per destination worker.
    let (eh_n, eh_q) = if smoke { (8_000, 8) } else { (80_000, 48) };
    let eh_workers = 8;
    let eh_g = gen::mono_hub(eh_n, 2, 441);
    let eh_queries = gen::random_pairs(eh_n, eh_q, 442);
    let edge = edge_rows(&eh_g, eh_workers, &eh_queries, reps);
    print_edge_table("bfs mono-hub C=8 W=8 (one pathological vertex)", &edge);
    let edge_headline = edge_speedup(&edge, 4);
    let edge_row = edge
        .iter()
        .find(|r| r.edge == EdgeSplit::Adaptive && r.threads == 4);
    println!(
        "max fan {} -> {} edge ranges; edge split vs off compute wall at 4 threads: {:.2}x",
        edge_row.map(|r| r.max_edge_task).unwrap_or(0),
        edge_row.map(|r| r.edge_ranges).unwrap_or(0),
        edge_headline
    );
    println!("target: edge splitting >= 1.25x over the unsplit engine at 4");
    println!("threads on the mono-hub compute wall; edge ranges > 0 shows");
    println!("the fan actually parked. Outputs are bit-identical across the");
    println!("whole table by construction (tests/fuzz_determinism.rs).");

    // --- Pipeline sweep: the one-slow-query graph pins a deep BFS ladder
    // to worker 0's lane; everything else is point lookups that converge
    // in two or three supersteps. Barrier rounds pay three global phase
    // dispatches per super-round and serialize the fast queries' exchange,
    // fold and reporting behind the slow lane; pipelined rounds ship each
    // fast query's cascade the moment its last lane lands, on threads the
    // slow lane isn't using.
    let (pl_n, pl_q, pl_stride, pl_width, pl_depth) = if smoke {
        (8_000, 120, 8usize, 16, 24)
    } else {
        (60_000, 600, 8usize, 48, 64)
    };
    let pl_workers = 8;
    let pl_capacity = 16;
    let pl_g = gen::one_slow_query(pl_n, pl_stride, pl_width, pl_depth, 443);
    // Query stream: the slow ladder walk first (source = hub 0, target
    // unreachable), then cheap lookups with any ladder id nudged onto the
    // star population so only query 0 is slow.
    let fix = |v: u32| {
        if v as usize % pl_stride == 0 && v as usize / pl_stride <= pl_width * pl_depth {
            v + 1
        } else {
            v
        }
    };
    let mut pl_queries: Vec<(u32, u32)> = vec![(0, (pl_n - 1) as u32)];
    for (s, t) in gen::random_pairs(pl_n, pl_q, 444) {
        pl_queries.push((fix(s), fix(t)));
    }
    let pipe = pipe_rows(&pl_g, pl_workers, &pl_queries, pl_capacity, reps);
    print_pipe_table("bfs one-slow-query C=16 W=8 (one slow lane)", &pipe);
    let pipe_headline = pipe_speedup(&pipe, 4);
    let pipe_row = pipe
        .iter()
        .find(|r| r.pipeline == Pipeline::On && r.threads == 4);
    println!(
        "pipelined rounds {}; overlap {:.1} ms; pipelined vs barrier end-to-end wall at 4 threads: {:.2}x",
        pipe_row.map(|r| r.pipelined_rounds).unwrap_or(0),
        pipe_row.map(|r| r.overlap * 1e3).unwrap_or(0.0),
        pipe_headline
    );
    println!("target: pipelining >= 1.3x over barrier rounds at 4 threads");
    println!("end-to-end on this workload; pipelined rounds > 0 shows the");
    println!("ready-driven path actually engaged. Outputs are bit-identical");
    println!("across the whole table by construction (tests/determinism.rs");
    println!("pipeline_choice_never_changes_outputs).");

    // --- Layout sweep: the same three adversarial graphs, hashed-map
    // stores vs the PR 7 flat arena/columnar stores. Both splits and the
    // pipeline stay off so the rows differ only in memory layout; the
    // skew/split/edge sweeps above already own their mechanisms' numbers.
    let mut lay = layout_rows("hub_concentrated", &skew_g, skew_workers, &skew_queries, reps);
    lay.extend(layout_rows("mega_hub", &mh_g, mh_workers, &mh_queries, reps));
    lay.extend(layout_rows("mono_hub", &eh_g, eh_workers, &eh_queries, reps));
    print_layout_table("bfs hashed vs flat stores C=8 W=8 (three graphs)", &lay);
    let layout_headline = {
        let per: Vec<f64> = LAYOUT_GRAPHS
            .iter()
            .map(|&gname| layout_speedup(&lay, gname, 4))
            .collect();
        per.iter().product::<f64>().powf(1.0 / per.len() as f64)
    };
    println!(
        "flat vs hashed compute wall at 4 threads (geomean over {} graphs): {:.2}x",
        LAYOUT_GRAPHS.len(),
        layout_headline
    );
    println!("target: flat >= 1.3x over hashed at 4 threads on the geomean");
    println!("compute wall; staging_bytes_peak > 0 on flat rows (and == 0");
    println!("on hashed rows) shows the columnar staging actually engaged.");
    println!("Outputs are bit-identical across the whole table by");
    println!("construction (tests/determinism.rs");
    println!("layout_choice_never_changes_outputs).");

    // --- Serving sweep: an open-loop arrival stream against the admission
    // planner. The graph is a hub core (every pair a d_ub<=2 point lookup)
    // plus a disconnected bipartite ladder (d_ub=depth whales); a burst of
    // whales lands a quarter into the stream. Static admission drains the
    // queue FIFO, so the burst occupies every capacity slot and the lights
    // behind it wait out the whole whale window; adaptive admission
    // confines the whales to the reserved slice and the lights keep
    // flowing. Latencies are simulated-clock, so the percentiles (and the
    // headline) are machine-independent.
    let (sv_core, sv_width, sv_depth, sv_lights, sv_whales) = if smoke {
        (512, 8, 16, 320, 3)
    } else {
        (1536, 16, 28, 1536, 10)
    };
    let sv_cfg = ServeCfg {
        workers: 8,
        capacity: 8,
        queue_bound: if smoke { 32 } else { 64 },
    };
    let sv_hubs = 8;
    let (sv_g, sv_entry, sv_last) = serving_graph(sv_core, sv_width, sv_depth);
    let (sv_idx, _) =
        Hub2Indexer::new(sv_hubs).build(&sv_g, Cluster::new(sv_cfg.workers), &RustMinPlus);
    let mu = light_service_rate(&sv_g, &sv_idx, sv_core, &sv_cfg);
    let sv_dt = 1.0 / (0.6 * mu).max(1e-12);
    // Pairs in arrival order: lights spaced 1/(0.6 mu) apart, the whale
    // burst injected at one arrival instant a quarter into the stream
    // (few enough whales that p99 stays on the lights; p99.9 is a whale).
    let light_pairs = gen::random_pairs(sv_core, sv_lights, 445);
    let burst_at = sv_lights / 4;
    let mut sv_pairs: Vec<(u32, u32)> = Vec::new();
    for (i, &(s, t)) in light_pairs.iter().enumerate() {
        if i == burst_at {
            for w in 0..sv_whales {
                sv_pairs.push((sv_entry, sv_last[w]));
            }
        }
        sv_pairs.push((s, t));
    }
    // The serving hot path: ONE batched front-end probe stamps d_ub for
    // the whole trace, so the planner sees explicit bounds at submission.
    let sv_dubs = sv_idx.dub_for(&sv_pairs, &RustMinPlus, 1, sv_idx.k());
    let mut sv_trace: Vec<(Hub2QueryContent, f64)> = Vec::new();
    let mut sv_li = 0usize;
    for (&(s, t), &d) in sv_pairs.iter().zip(sv_dubs.iter()) {
        let whale = s == sv_entry;
        assert_eq!(
            whale,
            d >= HEAVY_DUB_THRESHOLD,
            "bench premise: the whales and only the whales classify heavy"
        );
        let at = if whale {
            burst_at as f64 * sv_dt
        } else {
            let a = sv_li as f64 * sv_dt;
            sv_li += 1;
            a
        };
        sv_trace.push(((s, t, d), at));
    }
    let serve = serve_rows(&sv_g, &sv_idx, &sv_trace, &sv_cfg);
    print_serve_table("hub2 serving C=8 W=8 (whale burst at t/4)", &serve);
    let serve_headline = serve_speedup(&serve, 4);
    println!(
        "arrival rate {:.1} q/s(sim) (0.6x light service rate); static vs adaptive p99 at 4 threads: {:.2}x",
        1.0 / sv_dt,
        serve_headline
    );
    println!("target: adaptive p99 >= 1.15x better than static at 4 threads;");
    println!("admit_deferrals > 0 on adaptive rows (and == 0 on static rows)");
    println!("shows the planner actually engaged. p99.9 sits on the whales");
    println!("and may be worse under adaptive — the trade the reserved");
    println!("slice buys. Outputs are bit-identical across the admit axis");
    println!("(tests/determinism.rs admit_choice_never_changes_outputs).");

    // --- Mutation sweep: streaming graph updates against the hub2 index.
    // The incremental mode keeps ONE always-on serving engine: each batch
    // folds into the epoch overlay at a round boundary and only the
    // affected hub rows/columns are recomputed (Hub2Maintainer). The
    // rebuild mode is the correctness baseline run as a strategy: fold the
    // batch into a fresh CSR and rebuild the entire index over the same
    // frozen hub set, every time. Small batches are the streaming regime
    // the overlay exists for — recomputing all 2k hub BFS trees per
    // handful of edits is exactly the waste the incremental path avoids.
    let (mu_n, mu_deg, mu_hubs, mu_rounds, mu_edits, mu_wave) = if smoke {
        (6_000, 6, 8usize, 3usize, 3usize, 8usize)
    } else {
        (40_000, 8, 16, 6, 4, 16)
    };
    let mu_workers = 8;
    let mu_g = gen::twitter_like(mu_n, mu_deg, 448);
    let mu_indexer = Hub2Indexer::new(mu_hubs);
    let mu_batches = mutation_schedule(&mu_g, mu_rounds, mu_edits, 449);
    let mu_waves: Vec<Vec<(u32, u32)>> = (0..mu_rounds)
        .map(|i| gen::random_pairs(mu_n, mu_wave, 450 + i as u64))
        .collect();
    let mut mut_rows = Vec::new();
    for &threads in &[1usize, 4] {
        mut_rows.push(mut_incremental_row(
            &mu_g,
            &mu_indexer,
            &mu_batches,
            &mu_waves,
            mu_workers,
            threads,
        ));
        mut_rows.push(mut_rebuild_row(
            &mu_g,
            &mu_indexer,
            &mu_batches,
            &mu_waves,
            mu_workers,
            threads,
        ));
    }
    print_mut_table(
        "hub2 streaming mutations C=8 W=8 (incremental vs rebuild)",
        &mut_rows,
    );
    let mut_headline = mut_speedup(&mut_rows, 4);
    println!(
        "incremental vs full-rebuild end-to-end wall at 4 threads: {:.2}x",
        mut_headline
    );
    println!("target: incremental maintenance >= 1.2x over rebuild at 4");
    println!("threads end-to-end; epochs_applied > 0 and delta_bytes_peak > 0");
    println!("on incremental rows (and epochs_applied == 0 on rebuild rows)");
    println!("show the overlay actually engaged. Outputs are bit-identical");
    println!("across the mutation axis by construction (tests/determinism.rs");
    println!("mutating_runs_replay_against_the_serial_snapshot_oracle).");

    // --- Multi-process sweep: the same PPSP batch served in-process
    // (procs = 1) and across worker processes over localhost TCP
    // (children of this bench binary — `bench_main` serves the worker
    // protocol when the worker env knobs are set). Outputs are asserted
    // bit-identical across the sweep; the rows report end-to-end wall
    // time (spawn + handshake included — that IS the cost of the mode)
    // plus the wire gauges. `bytes_on_wire` is exactly 0 on the
    // 1-process row and necessarily positive on every N-process row, so
    // the validator can prove which mode each row actually ran.
    let (mp_n, mp_deg, mp_q) = if smoke {
        (4_000usize, 5usize, 16usize)
    } else {
        (30_000, 6, 48)
    };
    let mp_workers = 8;
    let mp_procs: [usize; 2] = [1, 2];
    let mp_g = gen::twitter_like(mp_n, mp_deg, 777);
    let mp_queries = gen::random_pairs(mp_n, mp_q, 778);
    let mp_cfg = quegel::coordinator::EngineConfig {
        capacity: 8,
        threads: 1,
        pipeline: Pipeline::Off,
        admit: Admit::Static(8),
        ..quegel::coordinator::EngineConfig::default()
    };
    struct ProcRow {
        procs: usize,
        wall: f64,
        bytes: u64,
        rpcs: u64,
        completed: u64,
    }
    let mut mp_rows: Vec<ProcRow> = Vec::new();
    let mut mp_base: Option<Vec<(u64, Option<u32>)>> = None;
    for &procs in &mp_procs {
        use quegel::apps::ppsp::{vbfs_query, VersionedBfs};
        use quegel::coordinator::remote::ProcEngine;
        let t = Instant::now();
        let mut pe = ProcEngine::new(
            VersionedBfs::new(mp_g.clone()),
            Cluster::new(mp_workers),
            mp_n,
            mp_cfg,
            procs,
            &[],
        );
        let ids: Vec<_> = mp_queries
            .iter()
            .map(|&(s, t)| pe.submit(vbfs_query(s, t)))
            .collect();
        pe.run_until_idle();
        let wall = t.elapsed().as_secs_f64();
        let results = pe.take_results();
        let outs: Vec<(u64, Option<u32>)> = ids
            .iter()
            .map(|id| {
                let r = results.iter().find(|r| r.qid == *id).unwrap();
                (r.qid, r.out)
            })
            .collect();
        match &mp_base {
            None => mp_base = Some(outs),
            Some(b) => assert_eq!(
                &outs, b,
                "{procs}-process outputs diverged from the 1-process run"
            ),
        }
        let m = pe.metrics();
        mp_rows.push(ProcRow {
            procs,
            wall,
            bytes: m.bytes_on_wire,
            rpcs: m.rpc_round_trips,
            completed: m.queries_completed,
        });
        pe.shutdown();
    }
    println!();
    println!(
        "vbfs multi-process C=8 W={mp_workers} twitter_like n={mp_n} \
         ({mp_q} queries, wall includes spawn + handshake)"
    );
    println!(
        "{:>6} {:>10} {:>14} {:>10} {:>10}",
        "procs", "wall_s", "bytes_on_wire", "rpcs", "completed"
    );
    for r in &mp_rows {
        println!(
            "{:>6} {:>10.3} {:>14} {:>10} {:>10}",
            r.procs, r.wall, r.bytes, r.rpcs, r.completed
        );
    }
    println!("outputs bit-identical across the process sweep (asserted above);");
    println!("no speedup target on this table — the sweep prices the wire, it");
    println!("does not claim localhost TCP beats shared memory.");

    if JSON.load(Ordering::Relaxed) {
        let payload = format!(
            concat!(
                "{{\"pr\":2,\"bench\":\"perf_engine\",",
                "\"threads_swept\":[1,2,4,8],\"reps\":{},\"workloads\":{{",
                "\"bibfs_batch_c8_w8\":{},",
                "\"xml_slca_nocombiner_c8_w8\":{}}}}}\n"
            ),
            reps,
            json_rows(&bibfs_rows),
            json_rows(&xml_rows),
        );
        match std::fs::write("BENCH_pr2.json", &payload) {
            Ok(()) => println!("wrote BENCH_pr2.json"),
            Err(e) => eprintln!("could not write BENCH_pr2.json: {e}"),
        }
        let payload = format!(
            concat!(
                "{{\"pr\":3,\"bench\":\"perf_skew_sched\",",
                "\"graph\":\"hub_concentrated\",\"n\":{},\"workers\":{},",
                "\"queries\":{},\"threads_swept\":[1,2,4,8],\"reps\":{},",
                "\"smoke\":{},\"rows\":{},",
                "\"stealing_vs_static_phase_speedup_t4\":{:.3}}}\n"
            ),
            sk_n,
            skew_workers,
            sk_q,
            reps,
            smoke,
            json_skew_rows(&skew),
            headline,
        );
        match std::fs::write("BENCH_pr3.json", &payload) {
            Ok(()) => println!("wrote BENCH_pr3.json"),
            Err(e) => eprintln!("could not write BENCH_pr3.json: {e}"),
        }
        let payload = format!(
            concat!(
                "{{\"pr\":4,\"bench\":\"perf_sublane_split\",",
                "\"graph\":\"mega_hub\",\"n\":{},\"workers\":{},",
                "\"queries\":{},\"threads_swept\":[1,2,4,8],\"reps\":{},",
                "\"smoke\":{},\"rows\":{},",
                "\"split_vs_off_compute_speedup_t4\":{:.3}}}\n"
            ),
            mh_n,
            mh_workers,
            mh_q,
            reps,
            smoke,
            json_split_rows(&split),
            split_headline,
        );
        match std::fs::write("BENCH_pr4.json", &payload) {
            Ok(()) => println!("wrote BENCH_pr4.json"),
            Err(e) => eprintln!("could not write BENCH_pr4.json: {e}"),
        }
        let payload = format!(
            concat!(
                "{{\"pr\":5,\"bench\":\"perf_edge_split\",",
                "\"graph\":\"mono_hub\",\"n\":{},\"workers\":{},",
                "\"queries\":{},\"threads_swept\":[1,2,4,8],\"reps\":{},",
                "\"smoke\":{},\"rows\":{},",
                "\"edge_split_vs_off_compute_speedup_t4\":{:.3}}}\n"
            ),
            eh_n,
            eh_workers,
            eh_q,
            reps,
            smoke,
            json_edge_rows(&edge),
            edge_headline,
        );
        match std::fs::write("BENCH_pr5.json", &payload) {
            Ok(()) => println!("wrote BENCH_pr5.json"),
            Err(e) => eprintln!("could not write BENCH_pr5.json: {e}"),
        }
        let payload = format!(
            concat!(
                "{{\"pr\":6,\"bench\":\"perf_pipeline\",",
                "\"graph\":\"one_slow_query\",\"n\":{},\"workers\":{},",
                "\"capacity\":{},\"queries\":{},\"ladder_width\":{},",
                "\"ladder_depth\":{},\"threads_swept\":[1,2,4,8],\"reps\":{},",
                "\"smoke\":{},\"rows\":{},",
                "\"pipeline_vs_barrier_wall_speedup_t4\":{:.3}}}\n"
            ),
            pl_n,
            pl_workers,
            pl_capacity,
            pl_queries.len(),
            pl_width,
            pl_depth,
            reps,
            smoke,
            json_pipe_rows(&pipe),
            pipe_headline,
        );
        match std::fs::write("BENCH_pr6.json", &payload) {
            Ok(()) => println!("wrote BENCH_pr6.json"),
            Err(e) => eprintln!("could not write BENCH_pr6.json: {e}"),
        }
        let payload = format!(
            concat!(
                "{{\"pr\":7,\"bench\":\"perf_flat_layout\",",
                "\"graphs\":[\"hub_concentrated\",\"mega_hub\",\"mono_hub\"],",
                "\"workers\":8,\"threads_swept\":[1,2,4,8],\"reps\":{},",
                "\"smoke\":{},\"rows\":{},",
                "\"flat_vs_hashed_compute_speedup_t4\":{:.3}}}\n"
            ),
            reps,
            smoke,
            json_layout_rows(&lay),
            layout_headline,
        );
        match std::fs::write("BENCH_pr7.json", &payload) {
            Ok(()) => println!("wrote BENCH_pr7.json"),
            Err(e) => eprintln!("could not write BENCH_pr7.json: {e}"),
        }
        let payload = format!(
            concat!(
                "{{\"pr\":8,\"bench\":\"perf_serving\",",
                "\"graph\":\"hub_core_plus_ladder\",\"n\":{},\"workers\":{},",
                "\"capacity\":{},\"queue_bound\":{},\"hubs\":{},",
                "\"lights\":{},\"whales\":{},\"ladder_width\":{},",
                "\"ladder_depth\":{},\"arrival_qps_sim\":{:.3},",
                "\"utilization\":0.6,\"threads_swept\":[1,4],\"reps\":1,",
                "\"smoke\":{},\"rows\":{},",
                "\"adaptive_vs_static_p99_improvement_t4\":{:.3}}}\n"
            ),
            sv_g.num_vertices(),
            sv_cfg.workers,
            sv_cfg.capacity,
            sv_cfg.queue_bound,
            sv_hubs,
            sv_lights,
            sv_whales,
            sv_width,
            sv_depth,
            1.0 / sv_dt,
            smoke,
            json_serve_rows(&serve),
            serve_headline,
        );
        match std::fs::write("BENCH_serving.json", &payload) {
            Ok(()) => println!("wrote BENCH_serving.json"),
            Err(e) => eprintln!("could not write BENCH_serving.json: {e}"),
        }
        let payload = format!(
            concat!(
                "{{\"pr\":9,\"bench\":\"perf_mutation_maintenance\",",
                "\"graph\":\"twitter_like\",\"n\":{},\"workers\":{},",
                "\"hubs\":{},\"rounds\":{},\"edits_per_batch\":{},",
                "\"wave_queries\":{},\"threads_swept\":[1,4],\"reps\":1,",
                "\"smoke\":{},\"rows\":{},",
                "\"hub2_incremental_vs_rebuild_speedup_t4\":{:.3}}}\n"
            ),
            mu_n,
            mu_workers,
            mu_hubs,
            mu_rounds,
            mu_edits,
            mu_wave,
            smoke,
            json_mut_rows(&mut_rows),
            mut_headline,
        );
        match std::fs::write("BENCH_pr9.json", &payload) {
            Ok(()) => println!("wrote BENCH_pr9.json"),
            Err(e) => eprintln!("could not write BENCH_pr9.json: {e}"),
        }
        let mp_json: Vec<String> = mp_rows
            .iter()
            .map(|r| {
                format!(
                    concat!(
                        "{{\"procs\":{},\"wall_s\":{:.6},\"bytes_on_wire\":{},",
                        "\"rpc_round_trips\":{},\"completed\":{}}}"
                    ),
                    r.procs, r.wall, r.bytes, r.rpcs, r.completed
                )
            })
            .collect();
        let payload = format!(
            concat!(
                "{{\"pr\":10,\"bench\":\"perf_multiprocess\",",
                "\"graph\":\"twitter_like\",\"n\":{},\"workers\":{},",
                "\"capacity\":8,\"queries\":{},\"procs_swept\":[1,2],",
                "\"reps\":1,\"smoke\":{},\"rows\":[{}]}}\n"
            ),
            mp_n,
            mp_workers,
            mp_q,
            smoke,
            mp_json.join(","),
        );
        match std::fs::write("BENCH_pr10.json", &payload) {
            Ok(()) => println!("wrote BENCH_pr10.json"),
            Err(e) => eprintln!("could not write BENCH_pr10.json: {e}"),
        }
    }
}
