//! §Perf micro-benchmarks: wall-clock cost of the engine hot paths, used by
//! the optimization pass (EXPERIMENTS.md §Perf). Not a paper table.
//!
//! The phase-split section attributes the pooled engine's win: per
//! `threads` setting it reports compute / exchange / barrier wall time and
//! the speedup of each over the serial (`threads = 1`) run. The XML
//! workload runs SLCA *without* the sender-side combiner — the
//! combiner-less regime where message routing dominated the old serial
//! barrier. With `--json`, the same numbers are written to
//! `BENCH_pr2.json` so the perf trajectory is machine-readable.

use quegel::apps::ppsp::{Bfs, BiBfs};
use quegel::apps::xml::{self, SlcaNaive, XmlGenConfig};
use quegel::coordinator::Engine;
use quegel::graph::gen;
use quegel::metrics::Table;
use quegel::network::Cluster;
use quegel::vertex::QueryApp;
use std::sync::atomic::{AtomicBool, Ordering};
use std::time::Instant;

/// Set by `bench_main` when `--json` is passed: also emit `BENCH_pr2.json`.
pub static JSON: AtomicBool = AtomicBool::new(false);

const THREAD_SWEEP: [usize; 4] = [1, 2, 4, 8];

fn median(mut xs: Vec<f64>) -> f64 {
    xs.sort_by(f64::total_cmp);
    xs[xs.len() / 2]
}

/// Median phase wall times of one workload at one `threads` setting.
struct PhaseRow {
    threads: usize,
    compute: f64,
    exchange: f64,
    barrier: f64,
    wall: f64,
}

/// Run `queries` as one batch (C = 8) per thread setting, 3 reps each,
/// and report median phase times.
fn phase_rows<A, F>(mk: F, n: usize, workers: usize, queries: &[A::Query]) -> Vec<PhaseRow>
where
    A: QueryApp,
    F: Fn() -> A,
{
    THREAD_SWEEP
        .iter()
        .map(|&threads| {
            let mut computes = Vec::new();
            let mut exchanges = Vec::new();
            let mut barriers = Vec::new();
            let mut walls = Vec::new();
            for _ in 0..3 {
                let mut eng = Engine::new(mk(), Cluster::new(workers), n)
                    .capacity(8)
                    .threads(threads);
                for q in queries {
                    eng.submit(q.clone());
                }
                let t0 = Instant::now();
                eng.run_until_idle();
                walls.push(t0.elapsed().as_secs_f64());
                computes.push(eng.metrics().compute_time);
                exchanges.push(eng.metrics().exchange_time);
                barriers.push(eng.metrics().barrier_time);
            }
            PhaseRow {
                threads,
                compute: median(computes),
                exchange: median(exchanges),
                barrier: median(barriers),
                wall: median(walls),
            }
        })
        .collect()
}

fn print_phase_table(name: &str, rows: &[PhaseRow]) {
    let base_compute = rows[0].compute;
    let base_xb = rows[0].exchange + rows[0].barrier;
    let mut t = Table::new(vec![
        "threads",
        "compute",
        "exchange",
        "barrier",
        "total wall",
        "compute speedup",
        "exch+barrier speedup",
    ]);
    for r in rows {
        let xb = r.exchange + r.barrier;
        t.row(vec![
            r.threads.to_string(),
            format!("{:.1} ms", r.compute * 1e3),
            format!("{:.1} ms", r.exchange * 1e3),
            format!("{:.1} ms", r.barrier * 1e3),
            format!("{:.1} ms", r.wall * 1e3),
            format!("{:.2}x", base_compute / r.compute),
            format!("{:.2}x", base_xb / xb),
        ]);
    }
    println!("[{name}]");
    println!("{}", t.render());
}

/// Serialize one workload's sweep as a JSON array (no serde offline; the
/// format is fixed and flat, so hand-rolling is safe).
fn json_rows(rows: &[PhaseRow]) -> String {
    let base_compute = rows[0].compute;
    let base_xb = rows[0].exchange + rows[0].barrier;
    let items: Vec<String> = rows
        .iter()
        .map(|r| {
            format!(
                concat!(
                    "{{\"threads\":{},\"compute_s\":{:.6},\"exchange_s\":{:.6},",
                    "\"barrier_s\":{:.6},\"wall_s\":{:.6},",
                    "\"compute_speedup_vs_t1\":{:.3},",
                    "\"exchange_barrier_speedup_vs_t1\":{:.3}}}"
                ),
                r.threads,
                r.compute,
                r.exchange,
                r.barrier,
                r.wall,
                base_compute / r.compute,
                base_xb / (r.exchange + r.barrier),
            )
        })
        .collect();
    format!("[{}]", items.join(","))
}

pub fn run() {
    let mut g = gen::twitter_like(100_000, 10, 433);
    g.ensure_in_edges();
    let n = g.num_vertices();
    let queries = gen::random_pairs(n, 64, 434);

    let mut t = Table::new(vec![
        "workload",
        "median wall",
        "compute calls",
        "calls/us",
    ]);

    // Engine throughput: BFS batch (dense frontier — state-table bound).
    for (name, cap) in [("bfs batch C=8", 8usize), ("bfs serial C=1", 1)] {
        let mut times = Vec::new();
        let mut calls = 0;
        for _ in 0..3 {
            let mut eng = Engine::new(Bfs::new(&g), Cluster::new(8), n)
                .capacity(cap)
                .threads(1);
            for &q in &queries {
                eng.submit(q);
            }
            let t0 = Instant::now();
            eng.run_until_idle();
            times.push(t0.elapsed().as_secs_f64());
            calls = eng.metrics().total_compute_calls;
        }
        let m = median(times);
        t.row(vec![
            name.to_string(),
            format!("{:.1} ms", m * 1e3),
            calls.to_string(),
            format!("{:.1}", calls as f64 / (m * 1e6)),
        ]);
    }
    println!("{}", t.render());
    println!("target: > 2 compute calls / us in the batch path (see");
    println!("EXPERIMENTS.md §Perf for the iteration log).");

    // --- Phase split on the pooled engine, per threads setting.
    //
    // BiBFS (combiner-heavy: most traffic combines away at the sender, so
    // compute dominates) vs naive SLCA without combiner (combiner-less:
    // every upward send reaches the staging buffers, so the exchange phase
    // carries the round).
    let bibfs_rows = phase_rows(|| BiBfs::new(&g), n, 8, &queries);
    print_phase_table("bibfs batch C=8 W=8 (combiner-heavy)", &bibfs_rows);

    let tree = xml::data::generate(&XmlGenConfig {
        dblp_like: true,
        records: 15_000,
        vocab: 400,
        seed: 435,
    });
    let xml_queries = xml::data::query_pool(&tree, 48, 3, 436);
    let xml_rows = phase_rows(
        || SlcaNaive::without_combiner(&tree),
        tree.len(),
        8,
        &xml_queries,
    );
    print_phase_table("xml slca no-combiner C=8 W=8 (combiner-less)", &xml_rows);

    println!("targets: compute speedup >= 1.5x at 4 threads (BiBFS);");
    println!("exchange+barrier speedup >= 1.3x at 4 threads on the");
    println!("combiner-less XML workload. Results are bit-identical across");
    println!("the threads column by construction (tests/determinism.rs).");

    if JSON.load(Ordering::Relaxed) {
        let payload = format!(
            concat!(
                "{{\"pr\":2,\"bench\":\"perf_engine\",",
                "\"threads_swept\":[1,2,4,8],\"reps\":3,\"workloads\":{{",
                "\"bibfs_batch_c8_w8\":{},",
                "\"xml_slca_nocombiner_c8_w8\":{}}}}}\n"
            ),
            json_rows(&bibfs_rows),
            json_rows(&xml_rows),
        );
        match std::fs::write("BENCH_pr2.json", &payload) {
            Ok(()) => println!("wrote BENCH_pr2.json"),
            Err(e) => eprintln!("could not write BENCH_pr2.json: {e}"),
        }
    }
}
