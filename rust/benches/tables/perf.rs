//! §Perf micro-benchmarks: wall-clock cost of the engine hot paths, used by
//! the optimization pass (EXPERIMENTS.md §Perf). Not a paper table.

use quegel::apps::ppsp::{Bfs, BiBfs};
use quegel::coordinator::Engine;
use quegel::graph::gen;
use quegel::metrics::Table;
use quegel::network::Cluster;
use std::time::Instant;

fn median(mut xs: Vec<f64>) -> f64 {
    xs.sort_by(f64::total_cmp);
    xs[xs.len() / 2]
}

pub fn run() {
    let mut g = gen::twitter_like(100_000, 10, 433);
    g.ensure_in_edges();
    let n = g.num_vertices();
    let queries = gen::random_pairs(n, 64, 434);

    let mut t = Table::new(vec![
        "workload",
        "median wall",
        "compute calls",
        "calls/us",
    ]);

    // Engine throughput: BFS batch (dense frontier — state-table bound).
    for (name, cap) in [("bfs batch C=8", 8usize), ("bfs serial C=1", 1)] {
        let mut times = Vec::new();
        let mut calls = 0;
        for _ in 0..3 {
            let mut eng = Engine::new(Bfs::new(&g), Cluster::new(8), n).capacity(cap);
            for &q in &queries {
                eng.submit(q);
            }
            let t0 = Instant::now();
            eng.run_until_idle();
            times.push(t0.elapsed().as_secs_f64());
            calls = eng.metrics().total_compute_calls;
        }
        let m = median(times);
        t.row(vec![
            name.to_string(),
            format!("{:.1} ms", m * 1e3),
            calls.to_string(),
            format!("{:.1}", calls as f64 / (m * 1e6)),
        ]);
    }

    // BiBFS batch (combiner-heavy).
    let mut times = Vec::new();
    let mut calls = 0;
    for _ in 0..3 {
        let mut eng = Engine::new(BiBfs::new(&g), Cluster::new(8), n).capacity(8);
        for &q in &queries {
            eng.submit(q);
        }
        let t0 = Instant::now();
        eng.run_until_idle();
        times.push(t0.elapsed().as_secs_f64());
        calls = eng.metrics().total_compute_calls;
    }
    let m = median(times);
    t.row(vec![
        "bibfs batch C=8".to_string(),
        format!("{:.1} ms", m * 1e3),
        calls.to_string(),
        format!("{:.1}", calls as f64 / (m * 1e6)),
    ]);

    println!("{}", t.render());
    println!("target: > 2 compute calls / us in the batch path (see");
    println!("EXPERIMENTS.md §Perf for the iteration log).");

    // --- Threaded worker shards: compute-phase wall time on the
    // Table-7-style batch workload (BiBFS, C = 8, W = 8) as the engine's
    // `threads` knob grows. The barrier stays single-threaded, so the
    // speedup target applies to the compute phase.
    let mut tt = Table::new(vec![
        "threads",
        "compute wall",
        "barrier wall",
        "total wall",
        "compute speedup",
    ]);
    let mut base_compute = 0.0f64;
    for threads in [1usize, 2, 4, 8] {
        let mut computes = Vec::new();
        let mut barriers = Vec::new();
        let mut walls = Vec::new();
        for _ in 0..3 {
            let mut eng = Engine::new(BiBfs::new(&g), Cluster::new(8), n)
                .capacity(8)
                .threads(threads);
            for &q in &queries {
                eng.submit(q);
            }
            let t0 = Instant::now();
            eng.run_until_idle();
            walls.push(t0.elapsed().as_secs_f64());
            computes.push(eng.metrics().compute_time);
            barriers.push(eng.metrics().barrier_time);
        }
        let mc = median(computes);
        if threads == 1 {
            base_compute = mc;
        }
        tt.row(vec![
            threads.to_string(),
            format!("{:.1} ms", mc * 1e3),
            format!("{:.1} ms", median(barriers) * 1e3),
            format!("{:.1} ms", median(walls) * 1e3),
            format!("{:.2}x", base_compute / mc),
        ]);
    }
    println!("{}", tt.render());
    println!("target: compute-phase speedup >= 1.5x at 4 threads (results");
    println!("are bit-identical across the threads column by construction).");
}
