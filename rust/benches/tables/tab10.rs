//! Tables 9 & 10: terrain shortest-path queries — Chen–Han stand-in vs the
//! Quegel ε-network SSSP on Eagle-like and Bear-like fractal DEMs; query
//! ladder Q1..Q8 at 2^2..2^9 cells along the diagonal.

use quegel::apps::terrain::baseline::{hausdorff, ChResult, ChenHanStandIn};
use quegel::apps::terrain::{Dem, TerrainNet, TerrainSssp};
use quegel::coordinator::Engine;
use quegel::metrics::{fmt_pct, fmt_secs, Table};

fn run_dataset(name: &str, width: usize, height: usize, seed: u64) {
    let dem = Dem::fractal(width, height, 10.0, 250.0, seed);
    println!(
        "{name}: mesh {}x{}, |F| = {} (paper Tab 9)",
        width,
        height,
        dem.tin_faces()
    );
    let net = TerrainNet::build(&dem, 2.0);
    println!(
        "eps-network: |V| = {}, |E| = {}",
        net.graph.num_vertices(),
        net.graph.num_edges()
    );
    let ch = ChenHanStandIn::new(&dem);
    let cluster = super::paper_cluster();

    let mut t = Table::new(vec![
        "Q", "CH time", "CH len", "Qg time", "Step", "Access", "Qg len", "HDist",
    ]);
    for (qi, exp) in (2..=9).enumerate() {
        let d = 1usize << exp;
        if d >= width.min(height) {
            // Destination beyond the mesh: clamp to the far corner once.
            if d / 2 >= width.min(height) {
                continue;
            }
        }
        let (tx, ty) = (d.min(width - 1), d.min(height - 1));
        let s = net.corner(0, 0);
        let tt = net.corner(tx, ty);
        let mut eng =
            Engine::new(TerrainSssp::new(&net), cluster.clone(), net.graph.num_vertices());
        let r = eng.run_one((s, tt));
        let (ch_time, ch_len, hd) = match ch.query(0, 0, tx, ty) {
            ChResult::Ok {
                len,
                modeled_secs,
                path,
            } => (
                fmt_secs(modeled_secs),
                format!("{len:.1} m"),
                format!("{:.2} m", hausdorff(&r.out.path, &path)),
            ),
            ChResult::Oom => ("-".into(), "-".into(), "-".into()),
        };
        t.row(vec![
            format!("Q{}", qi + 1),
            ch_time,
            ch_len,
            fmt_secs(r.stats.processing()),
            r.stats.supersteps.to_string(),
            fmt_pct(r.stats.access_rate),
            format!("{:.1} m", r.out.dist),
            hd,
        ]);
    }
    println!("{}", t.render());
}

pub fn run() {
    run_dataset("Eagle-like", 101, 140, 421);
    run_dataset("Bear-like", 97, 140, 423);
    println!("expected shape (paper Tab 10): CH time explodes then OOMs as");
    println!("distance grows; Quegel stays sub-linear with small access for");
    println!("close pairs (early termination); lengths agree within a few %");
    println!("and HDist stays at meter scale.");
}
