//! Table 11: P2P reachability — label indexing (level / yes / no, with the
//! level-aligned vs simple ablation) and 1000 queries on a Twitter-like
//! cyclic graph and a WebUK-like deep layered graph.

use quegel::apps::reach::{build_labels, condense, ReachQuery};
use quegel::coordinator::Engine;
use quegel::graph::{gen, Graph};
use quegel::metrics::{fmt_pct, fmt_secs, Table};

fn run_dataset(name: &str, g: Graph, seed: u64) {
    let n = g.num_vertices();
    let cond = condense(&g);
    let mut dag = cond.dag.clone();
    dag.ensure_in_edges();
    println!(
        "{name}: |V| = {n}, |E| = {}, |V_DAG| = {}, |E_DAG| = {}",
        g.num_edges(),
        dag.num_vertices(),
        dag.num_edges()
    );
    let cluster = super::paper_cluster();

    // ---- Indexing (level-aligned + simple ablation).
    let (labels, st_aligned) = build_labels(&dag, &cluster, true);
    let (_, st_simple) = build_labels(&dag, &cluster, false);
    let mut it = Table::new(vec!["label", "Compute (aligned)", "Compute (simple)"]);
    it.row(vec![
        format!("level ({} supersteps)", st_aligned.level_supersteps),
        fmt_secs(st_aligned.level_time),
        fmt_secs(st_simple.level_time),
    ]);
    it.row(vec![
        "yes-label".into(),
        fmt_secs(st_aligned.yes_time),
        fmt_secs(st_simple.yes_time),
    ]);
    it.row(vec![
        "no-label".into(),
        fmt_secs(st_aligned.no_time),
        fmt_secs(st_simple.no_time),
    ]);
    println!("{}", it.render());

    // ---- 1000 queries.
    let queries = gen::random_pairs(n, 1_000, seed);
    let mut eng = Engine::new(ReachQuery::new(&dag, &labels), cluster, dag.num_vertices())
        .capacity(8);
    for &(s, t) in &queries {
        eng.submit((cond.scc_of[s as usize], cond.scc_of[t as usize]));
    }
    eng.run_until_idle();
    let access: f64 =
        eng.results().iter().map(|r| r.stats.access_rate).sum::<f64>() / queries.len() as f64;
    let reach = eng.results().iter().filter(|r| r.out).count();
    let mut qt = Table::new(vec!["Query (sim)", "avg/query", "Access", "reach rate"]);
    qt.row(vec![
        fmt_secs(eng.sim_time()),
        fmt_secs(eng.sim_time() / 1_000.0),
        fmt_pct(access),
        fmt_pct(reach as f64 / 1_000.0),
    ]);
    println!("{}", qt.render());
}

pub fn run() {
    run_dataset(
        "Twitter-like (cyclic)",
        gen::web_cyclic(100_000, 40, 4, 425),
        426,
    );
    run_dataset(
        "WebUK-like (deep)",
        gen::web_cyclic(100_000, 500, 3, 427),
        428,
    );
    println!("expected shape (paper Tab 11): level computation dominates the");
    println!("indexing, with far more supersteps on the deep web graph (2793");
    println!("vs 23 in the paper); queries average well under a second with");
    println!("sub-1% access.");
}
