//! Tables 5 & 6: 1000 PPSP queries — Hub² indexing time (two hub budgets)
//! and querying time/access for BFS / BiBFS / Hub² vs the GraphLab-like
//! baseline.

use quegel::apps::ppsp::hub2::{Hub2Index, Hub2Indexer, Hub2Query, MinPlus, RustMinPlus};
use quegel::apps::ppsp::{Bfs, BiBfs};
use quegel::coordinator::Engine;
use quegel::graph::{gen, Graph};
use quegel::metrics::{fmt_pct, fmt_secs, Table};

struct QueryRow {
    name: String,
    load: f64,
    query: f64,
    access: f64,
}

fn quegel_run<A: quegel::vertex::QueryApp<Query = (u32, u32)>>(
    app: A,
    n: usize,
    load_bytes: usize,
    queries: &[(u32, u32)],
    name: &str,
) -> QueryRow {
    let cluster = super::paper_cluster();
    let mut eng = Engine::new(app, cluster.clone(), n).capacity(8);
    eng.advance_clock(cluster.load_time(load_bytes));
    let load = eng.sim_time();
    for &q in queries {
        eng.submit(q);
    }
    eng.run_until_idle();
    let access: f64 =
        eng.results().iter().map(|r| r.stats.access_rate).sum::<f64>() / queries.len() as f64;
    QueryRow {
        name: name.to_string(),
        load,
        query: eng.sim_time() - load,
        access,
    }
}

fn hub2_run(
    g: &Graph,
    idx: &Hub2Index,
    mp: &dyn MinPlus,
    queries: &[(u32, u32)],
    name: &str,
    k_pad: usize,
) -> QueryRow {
    let n = g.num_vertices();
    let cluster = super::paper_cluster();
    let load_bytes = g.footprint_bytes() + idx.footprint_bytes();
    let mut eng = Engine::new(Hub2Query::new(g, idx), cluster.clone(), n).capacity(8);
    eng.advance_clock(cluster.load_time(load_bytes));
    let load = eng.sim_time();
    let dubs = idx.dub_for(queries, mp, 8, k_pad);
    for (&(s, t), &dub) in queries.iter().zip(&dubs) {
        eng.submit((s, t, dub));
    }
    eng.run_until_idle();
    let access: f64 =
        eng.results().iter().map(|r| r.stats.access_rate).sum::<f64>() / queries.len() as f64;
    QueryRow {
        name: name.to_string(),
        load,
        query: eng.sim_time() - load,
        access,
    }
}

fn render(rows: &[QueryRow], queries: usize) {
    let mut t = Table::new(vec!["system", "Load", "Query", "Access", "q/s (sim)"]);
    for r in rows {
        t.row(vec![
            r.name.clone(),
            fmt_secs(r.load),
            fmt_secs(r.query),
            fmt_pct(r.access),
            format!("{:.1}", queries as f64 / r.query),
        ]);
    }
    println!("{}", t.render());
}

fn run_dataset(name: &str, mut g: Graph, undirected: bool, seed: u64, hub_ks: &[usize]) {
    g.ensure_in_edges();
    let n = g.num_vertices();
    println!("{name}: |V| = {n}, |E| = {}", g.num_edges());
    let queries = gen::random_pairs(n, 1_000, seed);
    let mp_pjrt = super::load_pjrt(256);
    let mp: &dyn MinPlus = mp_pjrt
        .as_ref()
        .map(|p| p as &dyn MinPlus)
        .unwrap_or(&RustMinPlus);

    // ---- Indexing table (5a / 6a).
    let mut itab = Table::new(vec!["hubs", "Index (sim)", "Closure (wall)"]);
    let mut indexes = Vec::new();
    for &k in hub_ks {
        let (idx, st) = Hub2Indexer::new(k)
            .undirected(undirected)
            .build(&g, super::paper_cluster(), mp);
        itab.row(vec![
            format!("top-{k}"),
            fmt_secs(st.index_time),
            fmt_secs(st.closure_time),
        ]);
        indexes.push(idx);
    }
    println!("{}", itab.render());

    // ---- Querying table (5b / 6b).
    let mut rows = Vec::new();
    rows.push(quegel_run(
        Bfs::new(&g),
        n,
        g.footprint_bytes(),
        &queries,
        "Quegel BFS",
    ));
    rows.push(quegel_run(
        BiBfs::new(&g),
        n,
        g.footprint_bytes(),
        &queries,
        "Quegel BiBFS",
    ));
    // GraphLab-like BiBFS baseline for the throughput ratio.
    let gl = quegel::baselines::graphlab_like::<BiBfs, _>(
        &g,
        &super::paper_cluster(),
        &queries,
        || BiBfs::new(&g),
    );
    rows.push(QueryRow {
        name: "GraphLab-like BiBFS".into(),
        load: gl.load_time,
        query: gl.query_time,
        access: gl.access_rate,
    });
    let k_pad = mp_pjrt.as_ref().map(|p| p.k).unwrap_or(0);
    for (idx, &k) in indexes.iter().zip(hub_ks) {
        rows.push(hub2_run(
            &g,
            idx,
            mp,
            &queries,
            &format!("Quegel Hub2 top-{k}"),
            k_pad.max(idx.k()),
        ));
    }
    render(&rows, queries.len());
    let hub_best = rows.last().unwrap();
    let ratio = gl.query_time / hub_best.query;
    println!(
        "Hub2 vs GraphLab-like throughput ratio: {ratio:.0}x (paper: 39x on Twitter, 68x on BTC)"
    );
}

pub fn run_twitter() {
    run_dataset(
        "Twitter-like (1k queries)",
        gen::twitter_like(100_000, 10, 409),
        false,
        410,
        &[64, 128],
    );
}

pub fn run_btc() {
    run_dataset(
        "BTC-like (1k queries)",
        gen::btc_like(120_000, 8_000, 5, 411),
        true,
        412,
        &[128],
    );
}
