//! Quegel benchmark harness: regenerates every table and figure of the
//! paper's evaluation (§6) on the scaled synthetic datasets (DESIGN.md §6).
//!
//! criterion is unavailable in this offline image, so this is a
//! `harness = false` bench binary: each module prints a paper-shaped table
//! and the main dispatches on a name filter:
//!
//!     cargo bench --offline                  # everything
//!     cargo bench --offline -- tab5          # one experiment
//!     cargo bench --offline -- perf --json   # perf + BENCH_pr{2,3,4}.json
//!
//! `QUEGEL_BENCH_SMOKE=1` shrinks the perf inputs for the CI smoke lane
//! (same tables and JSON shape, minutes → seconds).
//!
//! Absolute numbers are simulated-cluster seconds from the cost model (plus
//! wall time where meaningful); the paper-vs-measured comparison lives in
//! EXPERIMENTS.md.

mod tables;

use std::time::Instant;

fn main() {
    // Worker-process entrypoint: the perf module's multi-process sweep
    // spawns children of this bench binary; when the worker env knobs
    // are set, serve the remote protocol instead of running experiments.
    if quegel::coordinator::remote::maybe_serve_worker::<quegel::apps::ppsp::VersionedBfs>() {
        return;
    }
    let args: Vec<String> = std::env::args().skip(1).collect();
    let filter: Vec<&str> = args
        .iter()
        .filter(|a| !a.starts_with('-'))
        .map(String::as_str)
        .collect();
    let want = |name: &str| filter.is_empty() || filter.iter().any(|f| name.contains(f));
    if args.iter().any(|a| a == "--json") {
        // Machine-readable perf output (phase-split wall times + speedups).
        tables::perf::JSON.store(true, std::sync::atomic::Ordering::Relaxed);
    }

    let experiments: Vec<(&str, fn())> = vec![
        ("fig1_balance", tables::fig1::run),
        ("tab2_livej", tables::tab2::run),
        ("tab3_twitter20", tables::tab34::run_twitter),
        ("tab4_btc20", tables::tab34::run_btc),
        ("tab5_twitter1k", tables::tab56::run_twitter),
        ("tab6_btc1k", tables::tab56::run_btc),
        ("tab7a_capacity", tables::tab7::run_capacity),
        ("tab7b_machines", tables::tab7::run_machines),
        ("tab8_xml", tables::tab8::run),
        ("tab10_terrain", tables::tab10::run),
        ("fig9_paths", tables::fig9::run),
        ("tab11_reach", tables::tab11::run),
        ("tab12_gkws", tables::tab12::run),
        ("perf_engine", tables::perf::run),
    ];

    let t0 = Instant::now();
    for (name, f) in experiments {
        if !want(name) {
            continue;
        }
        println!("\n================ {name} ================");
        let t = Instant::now();
        f();
        println!("[{name}: {:.1}s wall]", t.elapsed().as_secs_f64());
    }
    println!("\ntotal bench wall time: {:.1}s", t0.elapsed().as_secs_f64());
}
