//! Simulated BSP cluster: worker topology + network/compute cost model.
//!
//! The paper ran on 15 machines × 8 workers over Gigabit Ethernet. This
//! module replaces that testbed with an analytic cost model: every
//! super-round costs
//!
//! ```text
//! max_w(compute_w) + barrier_latency + bytes_on_wire / bandwidth
//! [+ scan_bytes / disk_bw   for single-PC engines]
//! ```
//!
//! which is exactly the structure the paper's findings depend on (the
//! superstep-sharing win is "one barrier per super-round instead of C",
//! the capacity saturation is bandwidth saturation, Giraph's weakness is
//! per-query reload). See DESIGN.md §5 for the substitution argument.

pub mod wire;

use crate::graph::VertexId;
use wire::WireError;

/// Cost-model parameters (seconds / bytes). Defaults are calibrated to a
/// Gigabit-Ethernet cluster of commodity nodes, scaled so that laptop-sized
/// synthetic graphs land in the paper's regime (queries ~ a second without
/// index, tens of ms with).
#[derive(Debug, Clone)]
pub struct CostModel {
    /// One synchronization barrier (MPI allreduce-ish) per super-round.
    pub barrier_latency_s: f64,
    /// Cluster bisection bandwidth for message exchange.
    pub bandwidth_bytes_per_s: f64,
    /// CPU overhead of producing/consuming one message.
    pub per_msg_overhead_s: f64,
    /// Cost of one `compute()` call (excluding per-message work).
    pub per_vertex_compute_s: f64,
    /// Header bytes added to every message on the wire (dst + qid + len).
    pub msg_header_bytes: usize,
    /// Graph loading throughput from distributed storage ("HDFS").
    pub load_bytes_per_s: f64,
    /// Fixed job start-up cost (container scheduling etc.); dominant in the
    /// Giraph-like baseline which pays it per query.
    pub startup_s: f64,
    /// If > 0: a single-PC out-of-core engine (GraphChi-like) that must
    /// scan this many bytes from disk in EVERY super-round.
    pub scan_bytes_per_round: f64,
    /// Disk bandwidth for `scan_bytes_per_round`.
    pub disk_bytes_per_s: f64,
}

impl Default for CostModel {
    fn default() -> Self {
        Self {
            // ~1 ms barrier: MPI barrier + aggregator allreduce on 15 nodes.
            barrier_latency_s: 1e-3,
            // Gigabit Ethernet ≈ 125 MB/s payload.
            bandwidth_bytes_per_s: 125e6,
            // ~100 ns to serialize + route + deliver one small message.
            per_msg_overhead_s: 100e-9,
            // ~50 ns per compute() call (hash lookup + user logic).
            per_vertex_compute_s: 50e-9,
            msg_header_bytes: 12,
            // HDFS sequential read ≈ 200 MB/s aggregate.
            load_bytes_per_s: 200e6,
            startup_s: 0.0,
            scan_bytes_per_round: 0.0,
            disk_bytes_per_s: 100e6,
        }
    }
}

impl CostModel {
    /// Simulated time to load `bytes` of graph data (one-off, or per query
    /// for the Giraph-like baseline).
    pub fn load_time(&self, bytes: usize) -> f64 {
        self.startup_s + bytes as f64 / self.load_bytes_per_s
    }
}

/// Logical cluster: `workers` BSP workers plus the cost model.
#[derive(Debug, Clone)]
pub struct Cluster {
    pub workers: usize,
    pub cost: CostModel,
}

impl Cluster {
    /// Workers hosted per machine (the paper runs 8).
    pub const WORKERS_PER_MACHINE: usize = 8;

    /// Cluster with the default Gigabit cost model.
    pub fn new(workers: usize) -> Self {
        assert!(workers > 0);
        Self {
            workers,
            cost: CostModel::default(),
        }
    }

    /// Cluster with an explicit cost model.
    pub fn with_cost(workers: usize, cost: CostModel) -> Self {
        assert!(workers > 0);
        Self { workers, cost }
    }

    /// Number of physical machines (each contributes its own NIC, so
    /// aggregate bandwidth scales with this).
    pub fn machines(&self) -> usize {
        self.workers.div_ceil(Self::WORKERS_PER_MACHINE).max(1)
    }

    /// Paper's hash partitioning: vertex v lives on worker v mod W.
    #[inline]
    pub fn worker_of(&self, v: VertexId) -> usize {
        (v as usize) % self.workers
    }

    /// Simulated time for one super-round given per-worker compute seconds
    /// and the total bytes exchanged at the barrier. `bandwidth_bytes_per_s`
    /// is per machine; the aggregate scales with the machine count.
    pub fn super_round_time(&self, per_worker_compute: &[f64], bytes_on_wire: usize) -> f64 {
        let compute = per_worker_compute.iter().cloned().fold(0.0, f64::max);
        let agg_bw = self.cost.bandwidth_bytes_per_s * self.machines() as f64;
        let mut t = compute + self.cost.barrier_latency_s + bytes_on_wire as f64 / agg_bw;
        if self.cost.scan_bytes_per_round > 0.0 {
            t += self.cost.scan_bytes_per_round / self.cost.disk_bytes_per_s;
        }
        t
    }

    /// Simulated graph-load time (HDFS read parallelized across machines).
    pub fn load_time(&self, bytes: usize) -> f64 {
        self.cost.startup_s
            + bytes as f64 / (self.cost.load_bytes_per_s * self.machines() as f64)
    }
}

// ---------------------------------------------------------------------------
// Wire framing
// ---------------------------------------------------------------------------

/// Maximum frame payload accepted by [`FrameDecoder`]: a corrupt or
/// hostile length prefix must not make the decoder reserve gigabytes.
/// Generous enough for any message block the exchange phase emits.
pub const MAX_FRAME_BYTES: usize = 1 << 30;

/// Encode one length-prefixed frame: a `u32` little-endian payload length
/// followed by the payload itself. This is the on-wire unit a future
/// socket transport would exchange per (worker, super-round) message
/// block; the cost model above charges for it via `msg_header_bytes`.
pub fn encode_frame(payload: &[u8]) -> Vec<u8> {
    assert!(payload.len() <= MAX_FRAME_BYTES, "frame too large");
    let mut out = Vec::with_capacity(4 + payload.len());
    out.extend_from_slice(&(payload.len() as u32).to_le_bytes());
    out.extend_from_slice(payload);
    out
}

/// Try to split one complete frame off the front of `buf`. Returns the
/// payload and the total number of bytes consumed (header + payload), or
/// `None` if `buf` does not yet hold a complete frame.
pub fn decode_frame(buf: &[u8]) -> Option<(&[u8], usize)> {
    if buf.len() < 4 {
        return None;
    }
    let len = u32::from_le_bytes([buf[0], buf[1], buf[2], buf[3]]) as usize;
    assert!(len <= MAX_FRAME_BYTES, "frame length prefix out of range");
    if buf.len() < 4 + len {
        return None;
    }
    Some((&buf[4..4 + len], 4 + len))
}

/// Non-panicking variant of [`decode_frame`] for the socket transport: a
/// corrupt length prefix from a remote peer is a protocol error to surface
/// ([`WireError::Corrupt`]), not a reason to abort the process.
pub fn try_decode_frame(buf: &[u8]) -> Result<Option<(&[u8], usize)>, WireError> {
    if buf.len() < 4 {
        return Ok(None);
    }
    let len = u32::from_le_bytes([buf[0], buf[1], buf[2], buf[3]]) as usize;
    if len > MAX_FRAME_BYTES {
        return Err(WireError::Corrupt("frame length prefix out of range"));
    }
    if buf.len() < 4 + len {
        return Ok(None);
    }
    Ok(Some((&buf[4..4 + len], 4 + len)))
}

/// Once this many consumed bytes sit at the front of the reassembly
/// buffer, [`FrameDecoder`] compacts (shifts the live tail to offset 0).
/// Amortized: each byte is memmoved at most once per `COMPACT_THRESHOLD`
/// bytes streamed, instead of once per frame as the old
/// `Vec::drain(..consumed)` implementation did — and the buffer's
/// capacity stays bounded by the threshold plus the largest in-flight
/// chunk instead of growing with the total bytes ever streamed.
const COMPACT_THRESHOLD: usize = 64 * 1024;

/// Incremental frame reassembler for a stream that arrives in arbitrary
/// chunks (TCP segments, pipe reads): [`FrameDecoder::push`] bytes as they
/// arrive, then drain complete frames with [`FrameDecoder::next_frame`].
/// Partial frames are buffered until their remaining bytes show up.
///
/// Internally a cursor (`start`) tracks the consumed prefix; the buffer is
/// compacted only when that prefix crosses [`COMPACT_THRESHOLD`] (or when
/// it is fully consumed, which is free), so draining many small frames is
/// O(bytes) total, not O(frames × pending).
#[derive(Debug, Default)]
pub struct FrameDecoder {
    buf: Vec<u8>,
    /// Offset of the first unconsumed byte in `buf`.
    start: usize,
}

impl FrameDecoder {
    /// Empty decoder.
    pub fn new() -> Self {
        Self::default()
    }

    /// Shift the unconsumed tail to the front if the dead prefix is large
    /// enough to matter (or the buffer is fully consumed, which is free).
    fn maybe_compact(&mut self) {
        if self.start == 0 {
            return;
        }
        if self.start >= self.buf.len() {
            self.buf.clear();
            self.start = 0;
        } else if self.start >= COMPACT_THRESHOLD {
            self.buf.copy_within(self.start.., 0);
            self.buf.truncate(self.buf.len() - self.start);
            self.start = 0;
        }
    }

    /// Append newly received bytes to the reassembly buffer.
    pub fn push(&mut self, bytes: &[u8]) {
        self.maybe_compact();
        self.buf.extend_from_slice(bytes);
    }

    /// Pop the next complete frame's payload, or `None` if the buffer
    /// currently ends mid-frame (more bytes are needed). Panics on a
    /// corrupt length prefix (the in-process contract of
    /// [`decode_frame`]); transports reading untrusted peers should use
    /// [`FrameDecoder::try_next_frame`].
    pub fn next_frame(&mut self) -> Option<Vec<u8>> {
        let (payload, consumed) = {
            let (p, c) = decode_frame(&self.buf[self.start..])?;
            (p.to_vec(), c)
        };
        self.start += consumed;
        self.maybe_compact();
        Some(payload)
    }

    /// Like [`FrameDecoder::next_frame`], but surfaces a corrupt length
    /// prefix as `Err` instead of panicking. `Ok(None)` still means "more
    /// bytes needed".
    pub fn try_next_frame(&mut self) -> Result<Option<Vec<u8>>, WireError> {
        let decoded = try_decode_frame(&self.buf[self.start..])?;
        let Some((payload, consumed)) = decoded else {
            return Ok(None);
        };
        let payload = payload.to_vec();
        self.start += consumed;
        self.maybe_compact();
        Ok(Some(payload))
    }

    /// Bytes currently buffered without forming a complete frame.
    pub fn pending_bytes(&self) -> usize {
        self.buf.len() - self.start
    }

    /// Current capacity of the internal buffer (observability for the
    /// compaction regression test; bounded by the compaction policy).
    pub fn buffered_capacity(&self) -> usize {
        self.buf.capacity()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn worker_of_is_mod() {
        let c = Cluster::new(8);
        assert_eq!(c.worker_of(0), 0);
        assert_eq!(c.worker_of(17), 1);
    }

    #[test]
    fn super_round_time_takes_max_worker() {
        let c = Cluster::with_cost(
            2,
            CostModel {
                barrier_latency_s: 1.0,
                bandwidth_bytes_per_s: 100.0,
                ..Default::default()
            },
        );
        // workers at 2s and 4s, 200 bytes at 100 B/s = 2s, barrier 1s => 7s
        let t = c.super_round_time(&[2.0, 4.0], 200);
        assert!((t - 7.0).abs() < 1e-9, "got {t}");
    }

    #[test]
    fn scan_cost_added_when_configured() {
        let c = Cluster::with_cost(
            1,
            CostModel {
                barrier_latency_s: 0.0,
                scan_bytes_per_round: 1000.0,
                disk_bytes_per_s: 100.0,
                ..Default::default()
            },
        );
        let t = c.super_round_time(&[0.0], 0);
        assert!((t - 10.0).abs() < 1e-9);
    }

    #[test]
    fn load_time_includes_startup() {
        let cm = CostModel {
            startup_s: 5.0,
            load_bytes_per_s: 100.0,
            ..Default::default()
        };
        assert!((cm.load_time(1000) - 15.0).abs() < 1e-9);
    }

    #[test]
    fn single_worker_cluster() {
        let c = Cluster::new(1);
        assert_eq!(c.machines(), 1);
        // Every vertex lives on the only worker.
        for v in [0u32, 1, 7, u32::MAX] {
            assert_eq!(c.worker_of(v), 0);
        }
        // One worker, no traffic: the round costs compute + barrier only.
        let t = c.super_round_time(&[2.0], 0);
        assert!((t - (2.0 + c.cost.barrier_latency_s)).abs() < 1e-12, "got {t}");
    }

    #[test]
    fn zero_bytes_on_wire_costs_no_bandwidth() {
        let c = Cluster::with_cost(
            4,
            CostModel {
                barrier_latency_s: 0.5,
                bandwidth_bytes_per_s: 1.0, // absurdly slow: any byte would show
                ..Default::default()
            },
        );
        let t = c.super_round_time(&[1.0, 0.0, 0.0, 0.0], 0);
        assert!((t - 1.5).abs() < 1e-12, "got {t}");
    }

    #[test]
    fn machines_round_up_at_the_8_worker_boundary() {
        // WORKERS_PER_MACHINE = 8: 1..=8 workers fit one machine, 9 needs 2.
        assert_eq!(Cluster::new(7).machines(), 1);
        assert_eq!(Cluster::new(8).machines(), 1);
        assert_eq!(Cluster::new(9).machines(), 2);
        assert_eq!(Cluster::new(16).machines(), 2);
        assert_eq!(Cluster::new(17).machines(), 3);
        assert_eq!(Cluster::new(120).machines(), 15); // the paper cluster
    }

    #[test]
    fn frame_round_trips() {
        let payload = b"quegel message block".as_slice();
        let wire = encode_frame(payload);
        assert_eq!(wire.len(), 4 + payload.len());
        let (got, consumed) = decode_frame(&wire).expect("complete frame");
        assert_eq!(got, payload);
        assert_eq!(consumed, wire.len());
    }

    #[test]
    fn empty_payload_frames_are_legal() {
        // A worker with nothing to say still sends its barrier frame.
        let wire = encode_frame(&[]);
        assert_eq!(wire, vec![0, 0, 0, 0]);
        let (got, consumed) = decode_frame(&wire).expect("complete frame");
        assert!(got.is_empty());
        assert_eq!(consumed, 4);
        let mut dec = FrameDecoder::new();
        dec.push(&wire);
        assert_eq!(dec.next_frame(), Some(Vec::new()));
        assert_eq!(dec.next_frame(), None);
    }

    #[test]
    fn decode_frame_waits_for_complete_input() {
        let wire = encode_frame(b"0123456789");
        // No prefix, partial prefix, and partial payload are all "not yet".
        assert!(decode_frame(&[]).is_none());
        assert!(decode_frame(&wire[..3]).is_none());
        assert!(decode_frame(&wire[..wire.len() - 1]).is_none());
        assert!(decode_frame(&wire).is_some());
    }

    #[test]
    fn decoder_reassembles_byte_at_a_time_delivery() {
        // The adversarial TCP segmentation: every byte its own chunk.
        let frames: [&[u8]; 3] = [b"alpha", b"", b"gamma-delta"];
        let mut wire = Vec::new();
        for f in frames {
            wire.extend_from_slice(&encode_frame(f));
        }
        let mut dec = FrameDecoder::new();
        let mut got: Vec<Vec<u8>> = Vec::new();
        for &b in &wire {
            dec.push(&[b]);
            while let Some(f) = dec.next_frame() {
                got.push(f);
            }
        }
        assert_eq!(got.len(), 3);
        for (g, f) in got.iter().zip(frames) {
            assert_eq!(g.as_slice(), f);
        }
        assert_eq!(dec.pending_bytes(), 0);
    }

    #[test]
    fn decoder_drains_multiple_frames_from_one_push() {
        let mut wire = encode_frame(b"one");
        wire.extend_from_slice(&encode_frame(b"two"));
        // ... and carries a partial third frame across pushes.
        let third = encode_frame(b"three");
        wire.extend_from_slice(&third[..4]);
        let mut dec = FrameDecoder::new();
        dec.push(&wire);
        assert_eq!(dec.next_frame().as_deref(), Some(b"one".as_slice()));
        assert_eq!(dec.next_frame().as_deref(), Some(b"two".as_slice()));
        assert_eq!(dec.next_frame(), None, "third frame is incomplete");
        assert_eq!(dec.pending_bytes(), 4);
        dec.push(&third[4..]);
        assert_eq!(dec.next_frame().as_deref(), Some(b"three".as_slice()));
    }

    #[test]
    fn decoder_capacity_stays_bounded_over_a_long_stream() {
        // Stream ~4.6 MB of small frames in chunks chosen so frame and
        // chunk boundaries rarely align (23-byte frames, 997-byte chunks):
        // the decoder usually sits on a partial frame, so the consumed
        // prefix must be reclaimed by threshold compaction, not only by
        // the free fully-consumed reset. Neither the pending bytes nor the
        // buffer capacity may grow with the total bytes streamed.
        let frame = encode_frame(&[0xA5u8; 19]); // 23 bytes on the wire
        const FRAMES: usize = 200_000;
        let mut dec = FrameDecoder::new();
        let mut got = 0usize;
        let mut chunk: Vec<u8> = Vec::new();
        for _ in 0..FRAMES {
            chunk.extend_from_slice(&frame);
            while chunk.len() >= 997 {
                dec.push(&chunk[..997]);
                chunk.drain(..997);
                while let Some(p) = dec.next_frame() {
                    assert_eq!(p.len(), 19);
                    got += 1;
                }
                assert!(
                    dec.pending_bytes() < frame.len(),
                    "fully drained: only a partial frame may remain, got {}",
                    dec.pending_bytes()
                );
            }
        }
        dec.push(&chunk);
        while dec.next_frame().is_some() {
            got += 1;
        }
        assert_eq!(got, FRAMES);
        assert_eq!(dec.pending_bytes(), 0);
        // 64 KiB compaction threshold + one chunk of slack, with room for
        // Vec's doubling: far below the ~4.6 MB streamed.
        assert!(
            dec.buffered_capacity() <= 4 * COMPACT_THRESHOLD,
            "capacity {} must stay bounded by the compaction policy",
            dec.buffered_capacity()
        );
    }

    #[test]
    fn try_next_frame_surfaces_corrupt_length_instead_of_panicking() {
        let mut dec = FrameDecoder::new();
        // Length prefix claims 2 GiB: over MAX_FRAME_BYTES.
        dec.push(&(2u32 << 30).to_le_bytes());
        dec.push(&[1, 2, 3]);
        assert_eq!(
            dec.try_next_frame(),
            Err(WireError::Corrupt("frame length prefix out of range"))
        );
        // A fresh decoder with a legal stream works through the same API.
        let mut dec = FrameDecoder::new();
        dec.push(&encode_frame(b"ok"));
        assert_eq!(dec.try_next_frame(), Ok(Some(b"ok".to_vec())));
        assert_eq!(dec.try_next_frame(), Ok(None));
    }
}
