//! Zero-dependency wire codec for the multi-process mode.
//!
//! Everything that crosses a process boundary — engine configuration,
//! mutation batches, graph specs, staged message columns, aggregator
//! partials — is encoded with the little-endian primitives here and
//! framed by [`super::encode_frame`]. The codec is deliberately dumb:
//! fixed-width integers, `u32` length prefixes, no varints, no schema
//! negotiation. What it *is* careful about is failure: every decode path
//! returns [`WireError`] instead of panicking, and count fields are
//! validated against the bytes actually present before any allocation,
//! so a truncated or corrupted frame can never abort a worker process or
//! reserve gigabytes (see the corrupt-bytes fuzz tests below).
//!
//! Determinism note: encoding is a pure function of the value, and the
//! container orders serialized here (mutation order inside a batch,
//! per-source adjacency order inside a graph spec) are exactly the orders
//! the in-process engine replays — the wire adds no reordering anywhere.

use std::fmt;

use crate::graph::{Graph, GraphBuilder, Mutation, MutationBatch};

/// A decode failure. Never a panic: the transport surfaces these to the
/// coordinator/worker loop, which treats them as a fatal peer error.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum WireError {
    /// The buffer ended before the value did.
    Truncated,
    /// The bytes are structurally invalid (bad tag, out-of-range count,
    /// inconsistent lengths). The message names the field.
    Corrupt(&'static str),
}

impl fmt::Display for WireError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            WireError::Truncated => write!(f, "wire: truncated input"),
            WireError::Corrupt(what) => write!(f, "wire: corrupt input ({what})"),
        }
    }
}

impl std::error::Error for WireError {}

/// Codec result.
pub type WireResult<T> = Result<T, WireError>;

// ---------------------------------------------------------------------------
// Writer primitives (append-only, infallible)
// ---------------------------------------------------------------------------

#[inline]
pub fn put_u8(out: &mut Vec<u8>, v: u8) {
    out.push(v);
}

#[inline]
pub fn put_u32(out: &mut Vec<u8>, v: u32) {
    out.extend_from_slice(&v.to_le_bytes());
}

#[inline]
pub fn put_u64(out: &mut Vec<u8>, v: u64) {
    out.extend_from_slice(&v.to_le_bytes());
}

#[inline]
pub fn put_f32(out: &mut Vec<u8>, v: f32) {
    out.extend_from_slice(&v.to_le_bytes());
}

#[inline]
pub fn put_f64(out: &mut Vec<u8>, v: f64) {
    out.extend_from_slice(&v.to_le_bytes());
}

/// `u32` length prefix + raw bytes.
#[inline]
pub fn put_bytes(out: &mut Vec<u8>, bytes: &[u8]) {
    assert!(bytes.len() <= u32::MAX as usize, "byte blob too large");
    put_u32(out, bytes.len() as u32);
    out.extend_from_slice(bytes);
}

// ---------------------------------------------------------------------------
// Reader
// ---------------------------------------------------------------------------

/// Cursor over a received payload. Every accessor checks bounds and
/// returns [`WireError::Truncated`] instead of slicing out of range.
pub struct WireReader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> WireReader<'a> {
    pub fn new(buf: &'a [u8]) -> Self {
        Self { buf, pos: 0 }
    }

    /// Bytes not yet consumed.
    #[inline]
    pub fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }

    /// Take `n` raw bytes.
    #[inline]
    pub fn take(&mut self, n: usize) -> WireResult<&'a [u8]> {
        if self.remaining() < n {
            return Err(WireError::Truncated);
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    #[inline]
    pub fn u8(&mut self) -> WireResult<u8> {
        Ok(self.take(1)?[0])
    }

    #[inline]
    pub fn u32(&mut self) -> WireResult<u32> {
        let b = self.take(4)?;
        Ok(u32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }

    #[inline]
    pub fn u64(&mut self) -> WireResult<u64> {
        let b = self.take(8)?;
        Ok(u64::from_le_bytes([
            b[0], b[1], b[2], b[3], b[4], b[5], b[6], b[7],
        ]))
    }

    #[inline]
    pub fn f32(&mut self) -> WireResult<f32> {
        let b = self.take(4)?;
        Ok(f32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }

    #[inline]
    pub fn f64(&mut self) -> WireResult<f64> {
        let b = self.take(8)?;
        Ok(f64::from_le_bytes([
            b[0], b[1], b[2], b[3], b[4], b[5], b[6], b[7],
        ]))
    }

    /// A `u32`-length-prefixed byte blob (inverse of [`put_bytes`]).
    #[inline]
    pub fn bytes(&mut self) -> WireResult<&'a [u8]> {
        let n = self.u32()? as usize;
        self.take(n)
    }

    /// Read a count field and validate it against the bytes actually
    /// remaining (each counted element occupies ≥ `min_elem_bytes`), so a
    /// corrupted count can never drive an over-allocation.
    #[inline]
    pub fn count(&mut self, min_elem_bytes: usize, what: &'static str) -> WireResult<usize> {
        let n = self.u32()? as usize;
        if n.saturating_mul(min_elem_bytes.max(1)) > self.remaining() {
            return Err(WireError::Corrupt(what));
        }
        Ok(n)
    }

    /// Assert the payload was consumed exactly.
    pub fn expect_end(&self) -> WireResult<()> {
        if self.remaining() == 0 {
            Ok(())
        } else {
            Err(WireError::Corrupt("trailing bytes"))
        }
    }
}

// ---------------------------------------------------------------------------
// MutationBatch codec
// ---------------------------------------------------------------------------

const MUT_ADD_EDGE: u8 = 0;
const MUT_DELETE_EDGE: u8 = 1;
const MUT_ADD_VERTEX: u8 = 2;
const MUT_DELETE_VERTEX: u8 = 3;

/// Serialize a mutation batch in its exact application order.
pub fn encode_mutation_batch(batch: &MutationBatch, out: &mut Vec<u8>) {
    assert!(batch.muts.len() <= u32::MAX as usize, "batch too large");
    put_u32(out, batch.muts.len() as u32);
    for m in &batch.muts {
        match *m {
            Mutation::AddEdge { src, dst, w } => {
                put_u8(out, MUT_ADD_EDGE);
                put_u32(out, src);
                put_u32(out, dst);
                match w {
                    Some(w) => {
                        put_u8(out, 1);
                        put_f32(out, w);
                    }
                    None => put_u8(out, 0),
                }
            }
            Mutation::DeleteEdge { src, dst } => {
                put_u8(out, MUT_DELETE_EDGE);
                put_u32(out, src);
                put_u32(out, dst);
            }
            Mutation::AddVertex => put_u8(out, MUT_ADD_VERTEX),
            Mutation::DeleteVertex { v } => {
                put_u8(out, MUT_DELETE_VERTEX);
                put_u32(out, v);
            }
        }
    }
}

/// Inverse of [`encode_mutation_batch`]. Order-preserving by
/// construction — batches apply in mutation order, so the replica graph
/// on every worker folds the identical sequence.
pub fn decode_mutation_batch(r: &mut WireReader<'_>) -> WireResult<MutationBatch> {
    let n = r.count(1, "mutation count")?;
    let mut batch = MutationBatch::new();
    batch.muts.reserve(n);
    for _ in 0..n {
        let m = match r.u8()? {
            MUT_ADD_EDGE => {
                let src = r.u32()?;
                let dst = r.u32()?;
                let w = match r.u8()? {
                    0 => None,
                    1 => Some(r.f32()?),
                    _ => return Err(WireError::Corrupt("edge weight flag")),
                };
                Mutation::AddEdge { src, dst, w }
            }
            MUT_DELETE_EDGE => Mutation::DeleteEdge {
                src: r.u32()?,
                dst: r.u32()?,
            },
            MUT_ADD_VERTEX => Mutation::AddVertex,
            MUT_DELETE_VERTEX => Mutation::DeleteVertex { v: r.u32()? },
            _ => return Err(WireError::Corrupt("mutation tag")),
        };
        batch.muts.push(m);
    }
    Ok(batch)
}

// ---------------------------------------------------------------------------
// Graph spec codec
// ---------------------------------------------------------------------------

/// Serialize a CSR graph through its public accessors: vertex count,
/// weighted flag, then each source's out-list in per-source insertion
/// order. `GraphBuilder` preserves that order on rebuild, so
/// `decode_graph(encode_graph(g))` produces a structurally identical CSR
/// — which is what keeps replica apps' adjacency iteration (and thus
/// every `ctx.send` order) byte-identical across processes.
pub fn encode_graph(g: &Graph, out: &mut Vec<u8>) {
    let n = g.num_vertices();
    assert!(n <= u32::MAX as usize, "graph too large for the wire spec");
    put_u32(out, n as u32);
    put_u8(out, g.weighted() as u8);
    for v in 0..n as u32 {
        let outs = g.out(v);
        assert!(outs.len() <= u32::MAX as usize);
        put_u32(out, outs.len() as u32);
        if g.weighted() {
            for (i, &d) in outs.iter().enumerate() {
                put_u32(out, d);
                put_f32(out, g.out_w(v)[i]);
            }
        } else {
            for &d in outs {
                put_u32(out, d);
            }
        }
    }
}

/// Inverse of [`encode_graph`]. Validates every endpoint against the
/// declared vertex count before handing it to `GraphBuilder` (whose
/// in-range asserts would otherwise panic on corrupt input).
pub fn decode_graph(r: &mut WireReader<'_>) -> WireResult<Graph> {
    let n = r.count(1, "vertex count")? as u32;
    let weighted = match r.u8()? {
        0 => false,
        1 => true,
        _ => return Err(WireError::Corrupt("weighted flag")),
    };
    let mut b = GraphBuilder::new(n as usize);
    let elem = if weighted { 8 } else { 4 };
    for v in 0..n {
        let deg = r.count(elem, "out-degree")?;
        for _ in 0..deg {
            let d = r.u32()?;
            if d >= n {
                return Err(WireError::Corrupt("edge endpoint out of range"));
            }
            if weighted {
                let w = r.f32()?;
                b.wedge(v, d, w);
            } else {
                b.edge(v, d);
            }
        }
    }
    Ok(b.build())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::gen;
    use crate::util::Rng;

    #[test]
    fn primitives_round_trip() {
        let mut out = Vec::new();
        put_u8(&mut out, 0xAB);
        put_u32(&mut out, 0xDEAD_BEEF);
        put_u64(&mut out, u64::MAX - 7);
        put_f32(&mut out, -1.5);
        put_f64(&mut out, 2.25e-3);
        put_bytes(&mut out, b"blob");
        put_bytes(&mut out, b"");
        let mut r = WireReader::new(&out);
        assert_eq!(r.u8().unwrap(), 0xAB);
        assert_eq!(r.u32().unwrap(), 0xDEAD_BEEF);
        assert_eq!(r.u64().unwrap(), u64::MAX - 7);
        assert_eq!(r.f32().unwrap(), -1.5);
        assert_eq!(r.f64().unwrap(), 2.25e-3);
        assert_eq!(r.bytes().unwrap(), b"blob");
        assert_eq!(r.bytes().unwrap(), b"");
        r.expect_end().unwrap();
    }

    #[test]
    fn reader_reports_truncation_not_panic() {
        let mut out = Vec::new();
        put_u64(&mut out, 42);
        // Every strict prefix must decode to Truncated, never panic.
        for cut in 0..out.len() {
            let mut r = WireReader::new(&out[..cut]);
            assert_eq!(r.u64(), Err(WireError::Truncated), "cut at {cut}");
        }
        // A length prefix pointing past the end is truncation too.
        let mut out = Vec::new();
        put_u32(&mut out, 100); // claims 100 bytes follow
        out.push(1);
        let mut r = WireReader::new(&out);
        assert_eq!(r.bytes(), Err(WireError::Truncated));
    }

    #[test]
    fn count_guard_rejects_overallocation_bait() {
        // A 4-byte payload claiming four billion elements must be caught
        // before any Vec::with_capacity sees the number.
        let mut out = Vec::new();
        put_u32(&mut out, u32::MAX);
        let mut r = WireReader::new(&out);
        assert_eq!(r.count(4, "bait"), Err(WireError::Corrupt("bait")));
    }

    #[test]
    fn expect_end_flags_trailing_bytes() {
        let buf = [1u8, 2, 3];
        let mut r = WireReader::new(&buf);
        r.u8().unwrap();
        assert_eq!(r.expect_end(), Err(WireError::Corrupt("trailing bytes")));
        r.take(2).unwrap();
        r.expect_end().unwrap();
    }

    fn sample_batch() -> MutationBatch {
        let mut b = MutationBatch::new();
        b.add_edge(3, 57)
            .add_wedge(11, 503, 2.5)
            .delete_edge(120, 9)
            .add_vertex()
            .delete_vertex(77)
            .add_edge(250, 9);
        b
    }

    #[test]
    fn mutation_batch_round_trips_in_order() {
        let batch = sample_batch();
        let mut out = Vec::new();
        encode_mutation_batch(&batch, &mut out);
        let mut r = WireReader::new(&out);
        let got = decode_mutation_batch(&mut r).unwrap();
        r.expect_end().unwrap();
        assert_eq!(got, batch, "batch order and content must survive the wire");
        // Empty batch round-trips too.
        let mut out = Vec::new();
        encode_mutation_batch(&MutationBatch::new(), &mut out);
        let mut r = WireReader::new(&out);
        assert_eq!(decode_mutation_batch(&mut r).unwrap(), MutationBatch::new());
    }

    #[test]
    fn mutation_batch_truncation_and_corruption_error_cleanly() {
        let batch = sample_batch();
        let mut wire = Vec::new();
        encode_mutation_batch(&batch, &mut wire);
        // Every strict prefix: Err, never panic.
        for cut in 0..wire.len() {
            let mut r = WireReader::new(&wire[..cut]);
            assert!(
                decode_mutation_batch(&mut r).is_err(),
                "prefix of {cut} bytes must fail to decode"
            );
        }
        // Single-byte corruptions: decode must return (Ok or Err) without
        // panicking. Tag bytes and count bytes are the interesting ones,
        // but sweep everything.
        for i in 0..wire.len() {
            for flip in [0xFFu8, 0x01, 0x80] {
                let mut bad = wire.clone();
                bad[i] ^= flip;
                let mut r = WireReader::new(&bad);
                let _ = decode_mutation_batch(&mut r); // must not panic
            }
        }
        // A specifically bad tag surfaces as Corrupt.
        let mut bad = Vec::new();
        put_u32(&mut bad, 1);
        put_u8(&mut bad, 9); // no such mutation tag
        let mut r = WireReader::new(&bad);
        assert_eq!(
            decode_mutation_batch(&mut r),
            Err(WireError::Corrupt("mutation tag"))
        );
    }

    #[test]
    fn graph_spec_round_trips_adjacency_order() {
        let g = gen::twitter_like(200, 4, 991);
        let mut out = Vec::new();
        encode_graph(&g, &mut out);
        let mut r = WireReader::new(&out);
        let got = decode_graph(&mut r).unwrap();
        r.expect_end().unwrap();
        assert_eq!(got.num_vertices(), g.num_vertices());
        assert_eq!(got.num_edges(), g.num_edges());
        assert_eq!(got.weighted(), g.weighted());
        for v in 0..g.num_vertices() as u32 {
            assert_eq!(got.out(v), g.out(v), "out-list of {v} must match exactly");
        }
    }

    #[test]
    fn graph_spec_decode_never_panics_on_garbage() {
        let g = gen::twitter_like(60, 3, 992);
        let mut wire = Vec::new();
        encode_graph(&g, &mut wire);
        for cut in 0..wire.len().min(600) {
            let mut r = WireReader::new(&wire[..cut]);
            assert!(decode_graph(&mut r).is_err(), "prefix {cut} must fail");
        }
        // Randomized corruption sweep: flip bytes at seeded positions and
        // require a non-panicking verdict every time.
        let mut rng = Rng::new(0x5eed_1010);
        for _ in 0..500 {
            let mut bad = wire.clone();
            let i = rng.below_usize(bad.len());
            bad[i] ^= (rng.below(255) + 1) as u8;
            let mut r = WireReader::new(&bad);
            let _ = decode_graph(&mut r); // must not panic
        }
        // An out-of-range endpoint is caught before GraphBuilder asserts.
        let mut bad = Vec::new();
        put_u32(&mut bad, 2); // n = 2
        put_u8(&mut bad, 0);
        put_u32(&mut bad, 1); // deg(0) = 1
        put_u32(&mut bad, 7); // endpoint 7 >= n
        let mut r = WireReader::new(&bad);
        assert_eq!(
            decode_graph(&mut r),
            Err(WireError::Corrupt("edge endpoint out of range"))
        );
    }
}
