//! The `quegel` CLI: graph loading, index construction and interactive /
//! batch query serving from the console (the paper's client-console mode).
//!
//! Subcommands (hand-rolled parsing; the offline registry has no clap):
//!
//! ```text
//! quegel ppsp   [--graph FILE | --gen twitter:N:D] [--algo bfs|bibfs|hub2]
//!               [--hubs K] [--workers W] [--capacity C] [--threads T]
//!               [--queries FILE | --random N]
//! quegel xml    [--dblp N | --xmark N] [--semantics slca|slca-la|elca|maxmatch]
//!               [--random N]
//! quegel reach  [--gen web:N:L:D] [--random N]
//! quegel gkws   [--resources N] [--keywords M] [--random N]
//! quegel terrain [--mesh WxH] [--eps E] [--query X,Y]
//! ```
//!
//! Every subcommand prints per-query answers plus the engine metrics.

use quegel::apps::ppsp::hub2::{Hub2Indexer, Hub2Query, MinPlus, RustMinPlus};
use quegel::apps::ppsp::{Bfs, BiBfs};
use quegel::bail;
use quegel::coordinator::Engine;
use quegel::graph::{gen, io, Graph};
use quegel::metrics::{fmt_pct, fmt_secs};
use quegel::network::Cluster;
use quegel::util::error::{Context, Result};
use std::collections::HashMap;

fn main() {
    // Worker-process entrypoint: when a `ProcEngine` coordinator spawned
    // this process (the worker env knobs are set), serve the remote
    // protocol instead of parsing the CLI.
    if quegel::coordinator::remote::maybe_serve_worker::<quegel::apps::ppsp::VersionedBfs>() {
        return;
    }
    if let Err(e) = run() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

/// Parsed `--key value` options.
struct Opts {
    flags: HashMap<String, String>,
}

impl Opts {
    fn parse(args: &[String]) -> Result<Self> {
        let mut flags = HashMap::new();
        let mut i = 0;
        while i < args.len() {
            let a = &args[i];
            let Some(key) = a.strip_prefix("--") else {
                bail!("unexpected argument '{a}' (flags are --key value)");
            };
            let val = args
                .get(i + 1)
                .with_context(|| format!("--{key} needs a value"))?;
            flags.insert(key.to_string(), val.clone());
            i += 2;
        }
        Ok(Self { flags })
    }

    fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(String::as_str)
    }

    fn usize_or(&self, key: &str, default: usize) -> Result<usize> {
        match self.get(key) {
            Some(v) => v.parse().with_context(|| format!("bad --{key}")),
            None => Ok(default),
        }
    }
}

fn load_graph(opts: &Opts) -> Result<Graph> {
    if let Some(path) = opts.get("graph") {
        return io::load_adj(path);
    }
    let spec = opts.get("gen").unwrap_or("twitter:50000:8");
    let parts: Vec<&str> = spec.split(':').collect();
    let g = match parts.as_slice() {
        ["twitter", n, d] => gen::twitter_like(n.parse()?, d.parse()?, 1),
        ["btc", n, c, d] => gen::btc_like(n.parse()?, c.parse()?, d.parse()?, 1),
        ["livej", u, gr, m] => gen::livej_like(u.parse()?, gr.parse()?, m.parse()?, 1),
        ["web", n, l, d] => gen::web_cyclic(n.parse()?, l.parse()?, d.parse()?, 1),
        _ => bail!("unknown --gen spec '{spec}' (twitter:N:D, btc:N:C:D, livej:U:G:M, web:N:L:D)"),
    };
    Ok(g)
}

fn cmd_ppsp(opts: Opts) -> Result<()> {
    let mut g = load_graph(&opts)?;
    g.ensure_in_edges();
    let n = g.num_vertices();
    let workers = opts.usize_or("workers", 8)?;
    let capacity = opts.usize_or("capacity", 8)?;
    // Default to the machine's parallelism, like `Engine` itself.
    let threads = opts.usize_or(
        "threads",
        std::thread::available_parallelism().map(|p| p.get()).unwrap_or(1),
    )?;
    let cluster = Cluster::new(workers);
    let algo = opts.get("algo").unwrap_or("bibfs");
    let queries = match opts.get("queries") {
        Some(path) => {
            let text = std::fs::read_to_string(path)?;
            text.lines()
                .filter(|l| !l.trim().is_empty())
                .map(|l| {
                    let (s, t) = l.split_once(char::is_whitespace).context("query line")?;
                    Ok((s.trim().parse()?, t.trim().parse()?))
                })
                .collect::<Result<Vec<_>>>()?
        }
        None => gen::random_pairs(n, opts.usize_or("random", 8)?, 2),
    };
    println!("graph |V|={n} |E|={} algo={algo} W={workers} C={capacity}", g.num_edges());

    macro_rules! serve {
        ($app:expr, $mk:expr) => {{
            let mut eng = Engine::new($app, cluster.clone(), n)
                .capacity(capacity)
                .threads(threads);
            let ids: Vec<_> = queries.iter().map(|&q| eng.submit($mk(q))).collect();
            eng.run_until_idle();
            for (i, id) in ids.iter().enumerate() {
                let r = eng.results().iter().find(|r| r.qid == *id).unwrap();
                println!(
                    "({}, {}) -> {}  [steps {}, access {}, sim {}]",
                    queries[i].0,
                    queries[i].1,
                    r.out.map_or("unreachable".into(), |d| d.to_string()),
                    r.stats.supersteps,
                    fmt_pct(r.stats.access_rate),
                    fmt_secs(r.stats.processing()),
                );
            }
            println!("total sim {}", fmt_secs(eng.sim_time()));
        }};
    }
    match algo {
        "bfs" => serve!(Bfs::new(&g), |q| q),
        "bibfs" => serve!(BiBfs::new(&g), |q| q),
        "hub2" => {
            let k = opts.usize_or("hubs", 64)?;
            let mp: &dyn MinPlus = &RustMinPlus;
            let (idx, st) = Hub2Indexer::new(k).build(&g, cluster.clone(), mp);
            println!("hub2 index built: k={} sim {}", idx.k(), fmt_secs(st.index_time));
            let dubs = idx.dub_for(&queries, mp, capacity, idx.k());
            let mut eng = Engine::new(Hub2Query::new(&g, &idx), cluster.clone(), n)
                .capacity(capacity)
                .threads(threads);
            let ids: Vec<_> = queries
                .iter()
                .zip(&dubs)
                .map(|(&(s, t), &d)| eng.submit((s, t, d)))
                .collect();
            eng.run_until_idle();
            for (i, id) in ids.iter().enumerate() {
                let r = eng.results().iter().find(|r| r.qid == *id).unwrap();
                println!(
                    "({}, {}) -> {}  [steps {}, access {}]",
                    queries[i].0,
                    queries[i].1,
                    r.out.map_or("unreachable".into(), |d| d.to_string()),
                    r.stats.supersteps,
                    fmt_pct(r.stats.access_rate),
                );
            }
            println!("total sim {}", fmt_secs(eng.sim_time()));
        }
        other => bail!("unknown --algo '{other}'"),
    }
    Ok(())
}

fn cmd_xml(opts: Opts) -> Result<()> {
    use quegel::apps::xml::{self, data};
    let corpus = if let Some(n) = opts.get("xmark") {
        data::generate(&data::XmlGenConfig {
            dblp_like: false,
            records: n.parse()?,
            vocab: 4000,
            seed: 3,
        })
    } else {
        data::generate(&data::XmlGenConfig {
            dblp_like: true,
            records: opts.usize_or("dblp", 20_000)?,
            vocab: 4000,
            seed: 3,
        })
    };
    let nq = opts.usize_or("random", 10)?;
    let pool = data::query_pool(&corpus, nq, 2, 4);
    let sem = opts.get("semantics").unwrap_or("slca-la");
    let cluster = Cluster::new(opts.usize_or("workers", 8)?);
    println!("corpus {} vertices, semantics {sem}, {nq} queries", corpus.len());
    macro_rules! serve {
        ($app:expr) => {{
            let mut eng = Engine::new($app, cluster.clone(), corpus.len()).capacity(8);
            for q in &pool {
                eng.submit(q.clone());
            }
            eng.run_until_idle();
            for r in eng.results() {
                println!(
                    "q{} -> {} result vertices [access {}]",
                    r.qid,
                    r.out.len(),
                    fmt_pct(r.stats.access_rate)
                );
            }
            println!("total sim {}", fmt_secs(eng.sim_time()));
        }};
    }
    match sem {
        "slca" => serve!(xml::SlcaNaive::new(&corpus)),
        "slca-la" => serve!(xml::SlcaLevelAligned::new(&corpus)),
        "elca" => serve!(xml::Elca::new(&corpus)),
        "maxmatch" => {
            let mut eng =
                Engine::new(xml::MaxMatch::new(&corpus), cluster, corpus.len()).capacity(8);
            for q in &pool {
                eng.submit(q.clone());
            }
            eng.run_until_idle();
            for r in eng.results() {
                println!("q{} -> {} tree vertices", r.qid, r.out.len());
            }
            println!("total sim {}", fmt_secs(eng.sim_time()));
        }
        other => bail!("unknown --semantics '{other}'"),
    }
    Ok(())
}

fn cmd_reach(opts: Opts) -> Result<()> {
    use quegel::apps::reach::{build_labels, condense, ReachQuery};
    let g = load_graph(&opts)?;
    let n = g.num_vertices();
    let cond = condense(&g);
    let mut dag = cond.dag.clone();
    dag.ensure_in_edges();
    let cluster = Cluster::new(opts.usize_or("workers", 8)?);
    let (labels, st) = build_labels(&dag, &cluster, true);
    println!(
        "|V_DAG|={} labels: level {} / yes {} / no {}",
        dag.num_vertices(),
        fmt_secs(st.level_time),
        fmt_secs(st.yes_time),
        fmt_secs(st.no_time)
    );
    let queries = gen::random_pairs(n, opts.usize_or("random", 10)?, 5);
    let mut eng =
        Engine::new(ReachQuery::new(&dag, &labels), cluster, dag.num_vertices()).capacity(8);
    let ids: Vec<_> = queries
        .iter()
        .map(|&(s, t)| eng.submit((cond.scc_of[s as usize], cond.scc_of[t as usize])))
        .collect();
    eng.run_until_idle();
    for (i, id) in ids.iter().enumerate() {
        let r = eng.results().iter().find(|r| r.qid == *id).unwrap();
        println!(
            "({}, {}) -> {}  [steps {}, access {}]",
            queries[i].0,
            queries[i].1,
            if r.out { "reachable" } else { "unreachable" },
            r.stats.supersteps,
            fmt_pct(r.stats.access_rate)
        );
    }
    println!("total sim {}", fmt_secs(eng.sim_time()));
    Ok(())
}

fn cmd_gkws(opts: Opts) -> Result<()> {
    use quegel::apps::gkws::{self, query::GkwsQuery, KeywordSearch};
    let g = gkws::data::generate(&gkws::RdfGenConfig {
        resources: opts.usize_or("resources", 30_000)?,
        avg_deg: 5,
        predicates: 300,
        vocab: 4000,
        seed: 6,
    });
    let m = opts.usize_or("keywords", 2)?;
    let pool = gkws::data::query_pool(&g, opts.usize_or("random", 10)?, m, 7);
    let cluster = Cluster::new(opts.usize_or("workers", 8)?);
    let mut eng = Engine::new(KeywordSearch::new(&g), cluster, g.len()).capacity(8);
    for kw in pool {
        eng.submit(GkwsQuery {
            keywords: kw,
            delta_max: 3,
        });
    }
    eng.run_until_idle();
    for r in eng.results() {
        println!(
            "q{} -> {} roots [access {}]",
            r.qid,
            r.out.len(),
            fmt_pct(r.stats.access_rate)
        );
    }
    println!("total sim {}", fmt_secs(eng.sim_time()));
    Ok(())
}

fn cmd_terrain(opts: Opts) -> Result<()> {
    use quegel::apps::terrain::{Dem, TerrainNet, TerrainSssp};
    let mesh = opts.get("mesh").unwrap_or("60x60");
    let (w, h) = mesh
        .split_once('x')
        .context("--mesh must be WxH")
        .and_then(|(a, b)| Ok((a.parse::<usize>()?, b.parse::<usize>()?)))?;
    let eps: f64 = opts.get("eps").unwrap_or("2.0").parse()?;
    let dem = Dem::fractal(w, h, 10.0, 250.0, 9);
    let net = TerrainNet::build(&dem, eps);
    println!(
        "DEM {w}x{h}, eps {eps}: |V|={} |E|={}",
        net.graph.num_vertices(),
        net.graph.num_edges()
    );
    let q = opts.get("query").unwrap_or("10,10");
    let (qx, qy) = q
        .split_once(',')
        .context("--query must be X,Y")
        .and_then(|(a, b)| Ok((a.parse::<usize>()?, b.parse::<usize>()?)))?;
    let cluster = Cluster::new(opts.usize_or("workers", 8)?);
    let mut eng = Engine::new(TerrainSssp::new(&net), cluster, net.graph.num_vertices());
    let r = eng.run_one((net.corner(0, 0), net.corner(qx.min(w - 1), qy.min(h - 1))));
    println!(
        "(0,0) -> ({qx},{qy}): {:.1} m over {} polyline points [steps {}, access {}]",
        r.out.dist,
        r.out.path.len(),
        r.stats.supersteps,
        fmt_pct(r.stats.access_rate)
    );
    Ok(())
}

fn run() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let Some((cmd, rest)) = args.split_first() else {
        println!("usage: quegel <ppsp|xml|reach|gkws|terrain> [--flags]");
        println!("see rust/src/main.rs header for the full flag list");
        return Ok(());
    };
    let opts = Opts::parse(rest)?;
    match cmd.as_str() {
        "ppsp" => cmd_ppsp(opts),
        "xml" => cmd_xml(opts),
        "reach" => cmd_reach(opts),
        "gkws" => cmd_gkws(opts),
        "terrain" => cmd_terrain(opts),
        other => bail!("unknown subcommand '{other}'"),
    }
}
