//! Minimal in-repo property-testing harness.
//!
//! The offline registry has no `proptest`; this module provides the same
//! methodology at small scale: run a property over many seeded random
//! cases, and on failure report the seed so the case replays exactly
//! (`Rng::new(seed)` is deterministic). Used by `rust/tests/props.rs` for
//! the coordinator invariants listed in DESIGN.md §7.

use crate::util::Rng;

/// Run `prop` over `cases` deterministic random cases. Panics with the
/// failing seed (replayable) if the property returns an `Err`.
pub fn check<F>(name: &str, cases: u64, mut prop: F)
where
    F: FnMut(&mut Rng) -> Result<(), String>,
{
    for case in 0..cases {
        // Decorrelate consecutive case seeds.
        let seed = 0x9e37_79b9u64
            .wrapping_mul(case + 1)
            .wrapping_add(0x7f4a_7c15);
        let mut rng = Rng::new(seed);
        if let Err(msg) = prop(&mut rng) {
            panic!("property '{name}' failed on case {case} (seed {seed:#x}): {msg}");
        }
    }
}

/// Assert-style helper for property bodies.
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            return Err(format!($($arg)*));
        }
    };
}

/// Equality helper with value reporting.
#[macro_export]
macro_rules! prop_assert_eq {
    ($a:expr, $b:expr, $($arg:tt)*) => {{
        let (a, b) = (&$a, &$b);
        if a != b {
            return Err(format!(
                "{} (left: {:?}, right: {:?})",
                format!($($arg)*),
                a,
                b
            ));
        }
    }};
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_completes() {
        check("tautology", 50, |rng| {
            let x = rng.below(100);
            prop_assert!(x < 100, "below out of range: {x}");
            Ok(())
        });
    }

    #[test]
    #[should_panic(expected = "property 'fails'")]
    fn failing_property_reports_seed() {
        check("fails", 10, |rng| {
            let x = rng.below(10);
            prop_assert!(x > 100, "x={x} not > 100");
            Ok(())
        });
    }
}
