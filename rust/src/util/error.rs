//! Minimal error-context plumbing: the subset of the `anyhow` API this
//! crate uses (`Result`, `bail!`, `err!`, `.context()` / `.with_context()`),
//! hand-rolled because the offline registry ships no error crates. The
//! display contract matches anyhow's: `{}` prints the outermost message,
//! `{:#}` prints the whole cause chain separated by `: `.

use std::fmt;

/// A boxed error message with an optional cause chain.
pub struct Error {
    /// Outermost message first, root cause last.
    chain: Vec<String>,
}

impl Error {
    /// Build an error from a single message.
    pub fn msg<M: fmt::Display>(m: M) -> Self {
        Self {
            chain: vec![m.to_string()],
        }
    }

    /// Wrap with an outer context message.
    pub fn context<C: fmt::Display>(mut self, c: C) -> Self {
        self.chain.insert(0, c.to_string());
        self
    }

    /// The cause chain, outermost first.
    pub fn chain(&self) -> &[String] {
        &self.chain
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            write!(f, "{}", self.chain.join(": "))
        } else {
            write!(f, "{}", self.chain.first().map(String::as_str).unwrap_or(""))
        }
    }
}

/// Debug prints the full chain (what `unwrap`/`expect` show).
impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain.join(": "))
    }
}

/// Anything that is a standard error converts into [`Error`], capturing its
/// source chain. (Error itself intentionally does NOT implement
/// `std::error::Error`, so this blanket impl cannot conflict with the
/// reflexive `From<T> for T`.)
impl<E: std::error::Error> From<E> for Error {
    fn from(e: E) -> Self {
        let mut chain = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            chain.push(s.to_string());
            src = s.source();
        }
        Self { chain }
    }
}

/// Drop-in for `anyhow::Result`.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Context-attaching extension for `Result` and `Option` (the used subset
/// of `anyhow::Context`).
pub trait Context<T> {
    /// Wrap the error (or a missing `Option` value) with a message.
    fn context<C: fmt::Display>(self, c: C) -> Result<T>;

    /// Wrap with a lazily-built message.
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: std::error::Error> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T> {
        self.map_err(|e| Error::from(e).context(c))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| Error::from(e).context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(c))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// `anyhow::anyhow!` stand-in: build an [`Error`] from a format string.
#[macro_export]
macro_rules! err {
    ($($arg:tt)*) => {
        $crate::util::error::Error::msg(format!($($arg)*))
    };
}

/// `anyhow::bail!` stand-in: early-return an error from a format string.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::err!($($arg)*).into())
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse_num(s: &str) -> Result<u32> {
        let n: u32 = s.parse().context("bad number")?;
        if n > 100 {
            bail!("{n} out of range");
        }
        Ok(n)
    }

    #[test]
    fn ok_path() {
        assert_eq!(parse_num("42").unwrap(), 42);
    }

    #[test]
    fn context_wraps_std_errors() {
        let e = parse_num("nope").unwrap_err();
        assert_eq!(format!("{e}"), "bad number");
        let full = format!("{e:#}");
        assert!(full.starts_with("bad number: "), "{full}");
    }

    #[test]
    fn bail_formats() {
        let e = parse_num("500").unwrap_err();
        assert_eq!(format!("{e}"), "500 out of range");
    }

    #[test]
    fn option_context() {
        let v: Option<u32> = None;
        let e = v.context("missing value").unwrap_err();
        assert_eq!(format!("{e}"), "missing value");
        let v = Some(7u32);
        assert_eq!(v.with_context(|| "unused").unwrap(), 7);
    }

    #[test]
    fn question_mark_converts_io_errors() {
        fn open() -> Result<String> {
            let s = std::fs::read_to_string("/definitely/not/a/real/path")?;
            Ok(s)
        }
        assert!(open().is_err());
    }
}
