//! Deterministic splitmix64/xoshiro-style RNG for workload generation.

/// A small, fast, deterministic PRNG (splitmix64 core).
///
/// Not cryptographic; used only for synthetic dataset generation and
/// property-test case generation, where reproducibility is the requirement.
#[derive(Debug, Clone)]
pub struct Rng {
    state: u64,
}

impl Rng {
    /// Seeded constructor; equal seeds yield equal streams on all platforms.
    pub fn new(seed: u64) -> Self {
        // Avoid the all-zero fixpoint without changing good seeds.
        Self {
            state: seed ^ 0x9e37_79b9_7f4a_7c15,
        }
    }

    /// Next raw u64 (splitmix64).
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9e37_79b9_7f4a_7c15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
        z ^ (z >> 31)
    }

    /// Uniform in `[0, n)`. `n` must be > 0.
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        // Lemire's multiply-shift rejection-free variant is overkill here;
        // 128-bit multiply keeps the bias below 2^-64 for our n << 2^32.
        ((self.next_u64() as u128 * n as u128) >> 64) as u64
    }

    /// Uniform usize in `[0, n)`.
    #[inline]
    pub fn below_usize(&mut self, n: usize) -> usize {
        self.below(n as u64) as usize
    }

    /// Uniform f64 in `[0, 1)`.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f64 in `[lo, hi)`.
    #[inline]
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.f64() * (hi - lo)
    }

    /// Bernoulli trial with probability `p`.
    #[inline]
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Zipf-distributed rank in `[0, n)` with exponent `s` via rejection
    /// inversion (approximate, adequate for degree-skew generation).
    pub fn zipf(&mut self, n: usize, s: f64) -> usize {
        // Inverse-CDF on the continuous bounded Pareto, then clamp.
        debug_assert!(n > 0);
        let u = self.f64().max(1e-12);
        if (s - 1.0).abs() < 1e-9 {
            let x = (n as f64).powf(u) - 1.0;
            (x as usize).min(n - 1)
        } else {
            let e = 1.0 - s;
            let x = ((n as f64).powf(e) * u + (1.0 - u)).powf(1.0 / e) - 1.0;
            (x.max(0.0) as usize).min(n - 1)
        }
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below_usize(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct values from `[0, n)` (k << n expected).
    pub fn sample_distinct(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        if k * 4 >= n {
            let mut all: Vec<usize> = (0..n).collect();
            self.shuffle(&mut all);
            all.truncate(k);
            return all;
        }
        let mut seen = crate::util::FxHashSet::default();
        let mut out = Vec::with_capacity(k);
        while out.len() < k {
            let x = self.below_usize(n);
            if seen.insert(x) {
                out.push(x);
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn below_in_range() {
        let mut r = Rng::new(1);
        for _ in 0..1000 {
            assert!(r.below(17) < 17);
        }
    }

    #[test]
    fn f64_unit_interval() {
        let mut r = Rng::new(2);
        for _ in 0..1000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn zipf_skew() {
        // Rank 0 must be sampled far more often than rank n/2.
        let mut r = Rng::new(3);
        let n = 1000;
        let mut lo = 0;
        let mut hi = 0;
        for _ in 0..20_000 {
            let z = r.zipf(n, 1.2);
            assert!(z < n);
            if z == 0 {
                lo += 1;
            }
            if z >= n / 2 {
                hi += 1;
            }
        }
        assert!(lo > hi, "zipf must favor low ranks: lo={lo} hi={hi}");
    }

    #[test]
    fn sample_distinct_is_distinct() {
        let mut r = Rng::new(4);
        let s = r.sample_distinct(100, 30);
        let set: std::collections::HashSet<_> = s.iter().collect();
        assert_eq!(set.len(), 30);
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(5);
        let mut v: Vec<usize> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }
}
