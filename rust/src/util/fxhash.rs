//! FxHash (the rustc hash): a fast non-cryptographic hasher for the hot
//! per-vertex lookup tables. Hand-rolled because the offline registry has no
//! `rustc-hash` crate; algorithm is identical.

use std::collections::{HashMap, HashSet};
use std::hash::{BuildHasherDefault, Hasher};

const SEED: u64 = 0x51_7c_c1_b7_27_22_0a_95;

/// Firefox/rustc Fx hasher: multiply-rotate word mixing.
#[derive(Default)]
pub struct FxHasher {
    hash: u64,
}

impl FxHasher {
    #[inline]
    fn add_to_hash(&mut self, i: u64) {
        self.hash = (self.hash.rotate_left(5) ^ i).wrapping_mul(SEED);
    }
}

impl Hasher for FxHasher {
    #[inline]
    fn finish(&self) -> u64 {
        self.hash
    }

    #[inline]
    fn write(&mut self, bytes: &[u8]) {
        let mut chunks = bytes.chunks_exact(8);
        for c in &mut chunks {
            self.add_to_hash(u64::from_le_bytes(c.try_into().unwrap()));
        }
        let rem = chunks.remainder();
        if !rem.is_empty() {
            let mut buf = [0u8; 8];
            buf[..rem.len()].copy_from_slice(rem);
            self.add_to_hash(u64::from_le_bytes(buf));
        }
    }

    #[inline]
    fn write_u32(&mut self, i: u32) {
        self.add_to_hash(i as u64);
    }

    #[inline]
    fn write_u64(&mut self, i: u64) {
        self.add_to_hash(i);
    }

    #[inline]
    fn write_usize(&mut self, i: usize) {
        self.add_to_hash(i as u64);
    }
}

pub type FxBuildHasher = BuildHasherDefault<FxHasher>;
pub type FxHashMap<K, V> = HashMap<K, V, FxBuildHasher>;
pub type FxHashSet<T> = HashSet<T, FxBuildHasher>;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn map_roundtrip() {
        let mut m: FxHashMap<u32, u32> = FxHashMap::default();
        for i in 0..1000u32 {
            m.insert(i, i * 2);
        }
        for i in 0..1000u32 {
            assert_eq!(m[&i], i * 2);
        }
    }

    #[test]
    fn hash_differs_across_inputs() {
        let h = |x: u64| {
            let mut hasher = FxHasher::default();
            hasher.write_u64(x);
            hasher.finish()
        };
        assert_ne!(h(1), h(2));
        assert_ne!(h(0), h(u64::MAX));
    }

    #[test]
    fn write_bytes_consistent() {
        let h = |b: &[u8]| {
            let mut hasher = FxHasher::default();
            hasher.write(b);
            hasher.finish()
        };
        assert_eq!(h(b"hello"), h(b"hello"));
        assert_ne!(h(b"hello"), h(b"hellp"));
    }
}
