//! Typed environment-variable parsing with pure, unit-testable cores.
//!
//! The `QUEGEL_BENCH_SMOKE` flag predicate
//! `is_ok_and(|v| !v.is_empty() && v != "0")` used to be copy-pasted
//! between the perf bench and the determinism fuzzer — one future
//! consumer writing the "obvious" `is_ok()` instead would silently treat
//! `QUEGEL_BENCH_SMOKE=0` as ON. These helpers are the single home for
//! that semantics:
//!
//! * [`env_flag`] — set-and-nonzero boolean (`""` and `"0"` are OFF);
//! * [`env_u64`] / [`env_usize`] — typed values (`QUEGEL_FUZZ_SEED`,
//!   `QUEGEL_FUZZ_CASES`) where absent/empty/garbage fall back to the
//!   caller's default, so a typo'd variable can never panic a bench.
//!   Unlike the flag semantics, `"0"` here is a *valid parsed value*.
//!
//! Each helper is a thin `std::env::var` wrapper over a pure `*_from`
//! core, so the parsing rules are unit-tested without mutating the
//! process environment (`std::env::set_var` is racy under threaded test
//! runners and unsafe in newer editions).

/// Pure core of [`env_flag`]: `None`, `""` and `"0"` are off; any other
/// value (the flags are documented as 0/1) is on.
#[inline]
pub fn flag_from(val: Option<&str>) -> bool {
    val.is_some_and(|v| !v.is_empty() && v != "0")
}

/// Pure core of [`env_u64`]: absent, empty or unparsable values yield
/// `default`; `"0"` parses to 0.
#[inline]
pub fn u64_from(val: Option<&str>, default: u64) -> u64 {
    val.and_then(|v| v.trim().parse::<u64>().ok())
        .unwrap_or(default)
}

/// Pure core of [`env_usize`]; same fallback rules as [`u64_from`].
#[inline]
pub fn usize_from(val: Option<&str>, default: usize) -> usize {
    val.and_then(|v| v.trim().parse::<usize>().ok())
        .unwrap_or(default)
}

/// Boolean flag: set-and-nonzero (e.g. `QUEGEL_BENCH_SMOKE`).
pub fn env_flag(name: &str) -> bool {
    flag_from(std::env::var(name).ok().as_deref())
}

/// Typed `u64` variable (e.g. `QUEGEL_FUZZ_SEED`), `default` on
/// absent/empty/garbage.
pub fn env_u64(name: &str, default: u64) -> u64 {
    u64_from(std::env::var(name).ok().as_deref(), default)
}

/// Typed `usize` variable (e.g. `QUEGEL_FUZZ_CASES`), `default` on
/// absent/empty/garbage.
pub fn env_usize(name: &str, default: usize) -> usize {
    usize_from(std::env::var(name).ok().as_deref(), default)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn flag_semantics_are_set_and_nonzero() {
        assert!(!flag_from(None), "absent is off");
        assert!(!flag_from(Some("")), "empty is off");
        assert!(!flag_from(Some("0")), "explicit zero is off");
        assert!(flag_from(Some("1")));
        assert!(flag_from(Some("yes")), "any other value is on");
        assert!(
            flag_from(Some("00")),
            "only the literal \"0\" is off — the contract is 0/1"
        );
    }

    #[test]
    fn u64_falls_back_on_empty_and_garbage_but_not_zero() {
        assert_eq!(u64_from(None, 7), 7, "absent -> default");
        assert_eq!(u64_from(Some(""), 7), 7, "empty -> default");
        assert_eq!(u64_from(Some("not a number"), 7), 7, "garbage -> default");
        assert_eq!(u64_from(Some("-3"), 7), 7, "negative -> default");
        assert_eq!(u64_from(Some("0"), 7), 0, "zero is a valid value");
        assert_eq!(u64_from(Some(" 42 "), 7), 42, "whitespace is trimmed");
        assert_eq!(u64_from(Some("314159265358"), 7), 314_159_265_358);
    }

    #[test]
    fn usize_falls_back_on_empty_and_garbage_but_not_zero() {
        assert_eq!(usize_from(None, 100), 100);
        assert_eq!(usize_from(Some(""), 100), 100);
        assert_eq!(usize_from(Some("12 cases"), 100), 100);
        assert_eq!(usize_from(Some("0"), 100), 0, "zero cases is a choice");
        assert_eq!(usize_from(Some("1000"), 100), 1000);
    }
}
