//! Dense bitset over vertex ids; used for visited/activated tracking where
//! the touched set approaches the partition size.

/// Fixed-capacity dense bitset.
#[derive(Debug, Clone)]
pub struct BitSet {
    words: Vec<u64>,
    len: usize,
}

impl BitSet {
    /// All-zero bitset with capacity for `len` bits.
    pub fn new(len: usize) -> Self {
        Self {
            words: vec![0; len.div_ceil(64)],
            len,
        }
    }

    /// Bit capacity.
    pub fn len(&self) -> usize {
        self.len
    }

    /// True if no bit is set.
    pub fn is_empty(&self) -> bool {
        self.words.iter().all(|&w| w == 0)
    }

    /// Set bit `i`; returns the previous value.
    #[inline]
    pub fn set(&mut self, i: usize) -> bool {
        debug_assert!(i < self.len);
        let (w, b) = (i / 64, i % 64);
        let prev = self.words[w] & (1 << b) != 0;
        self.words[w] |= 1 << b;
        prev
    }

    /// Clear bit `i`.
    #[inline]
    pub fn clear(&mut self, i: usize) {
        let (w, b) = (i / 64, i % 64);
        self.words[w] &= !(1 << b);
    }

    /// Read bit `i`.
    #[inline]
    pub fn get(&self, i: usize) -> bool {
        debug_assert!(i < self.len);
        let (w, b) = (i / 64, i % 64);
        self.words[w] & (1 << b) != 0
    }

    /// Number of set bits.
    pub fn count(&self) -> usize {
        self.words.iter().map(|w| w.count_ones() as usize).sum()
    }

    /// Clear all bits (retains capacity).
    pub fn clear_all(&mut self) {
        self.words.fill(0);
    }

    /// Iterate indices of set bits in ascending order.
    pub fn iter(&self) -> impl Iterator<Item = usize> + '_ {
        self.words.iter().enumerate().flat_map(|(wi, &w)| {
            let mut w = w;
            std::iter::from_fn(move || {
                if w == 0 {
                    None
                } else {
                    let b = w.trailing_zeros() as usize;
                    w &= w - 1;
                    Some(wi * 64 + b)
                }
            })
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn set_get_clear() {
        let mut b = BitSet::new(130);
        assert!(!b.set(0));
        assert!(!b.set(129));
        assert!(b.set(0));
        assert!(b.get(0) && b.get(129) && !b.get(64));
        b.clear(0);
        assert!(!b.get(0));
        assert_eq!(b.count(), 1);
    }

    #[test]
    fn iter_ascending() {
        let mut b = BitSet::new(200);
        for i in [3usize, 64, 65, 199] {
            b.set(i);
        }
        let got: Vec<usize> = b.iter().collect();
        assert_eq!(got, vec![3, 64, 65, 199]);
    }

    #[test]
    fn clear_all_resets() {
        let mut b = BitSet::new(100);
        b.set(7);
        b.set(99);
        b.clear_all();
        assert!(b.is_empty());
        assert_eq!(b.count(), 0);
    }
}
