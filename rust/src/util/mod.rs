//! Small self-contained utilities: deterministic RNG, fast hashing,
//! bitsets, and error-context plumbing.
//!
//! The offline registry has no `rand`/`rustc-hash`/`fixedbitset`/`anyhow`,
//! so these are hand-rolled; all experiments require determinism anyway
//! (generators are seeded, so every bench regenerates identical workloads).

pub mod bitset;
pub mod env;
pub mod error;
pub mod fxhash;
pub mod rng;

pub use bitset::BitSet;
pub use env::{env_flag, env_u64, env_usize};
pub use error::{Context, Error, Result};
pub use fxhash::{FxHashMap, FxHashSet};
pub use rng::Rng;
