//! Small self-contained utilities: deterministic RNG, fast hashing, bitsets.
//!
//! The offline registry has no `rand`/`rustc-hash`/`fixedbitset`, so these
//! are hand-rolled; all experiments require determinism anyway (generators
//! are seeded, so every bench regenerates identical workloads).

pub mod bitset;
pub mod fxhash;
pub mod rng;

pub use bitset::BitSet;
pub use fxhash::{FxHashMap, FxHashSet};
pub use rng::Rng;
