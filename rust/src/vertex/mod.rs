//! The vertex-centric programming interface (paper §4).
//!
//! A Quegel application implements [`QueryApp`], the rust analog of the
//! paper's `Vertex<I, V^Q, V^V, M, Q>` + `Worker` subclassing:
//!
//! * `Query`  — the query content `<Q>` (e.g. `(s, t)` for PPSP);
//! * `VQ`     — the query-dependent vertex attribute `a_q(v)` (VQ-data),
//!   allocated lazily the first time `q` touches `v` via `init_value`;
//! * `Msg`    — the message type `<M>`;
//! * `Agg`    — the aggregator value;
//! * `Out`    — the per-query result assembled in the reporting superstep.
//!
//! V-data (`a^V(v)`: adjacency lists, labels, text) is owned by the app
//! struct itself — it is query-independent and shared by every in-flight
//! query, which is exactly the paper's V-data / VQ-data split.

use crate::graph::{Epoch, MutationApplied, MutationBatch, VertexId};

/// Query identifier assigned by the engine at submission.
pub type QueryId = u64;

/// Decision returned by the per-superstep master hook.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum MasterAction {
    /// Keep running.
    Continue,
    /// Terminate the query at this barrier (aggregator-driven
    /// `force_terminate`, e.g. BiBFS's zero-message-direction stop).
    Terminate,
}

/// A Quegel application: user logic for one *generic* query.
///
/// The engine executes worker shards on a persistent pool of OS threads
/// (compute, exchange and fold phases), each pool worker holding `&self`
/// plus exclusive ownership of its share of the phase state. Hence the app
/// must be `Sync` (V-data is read-shared across workers, exactly the
/// paper's immutable-V-data contract); `Query`/`Agg` are read-shared per
/// superstep (`Sync`) and travel to fold-phase workers inside per-query
/// state (`Send`); `VQ`/`Msg`/`Agg` values live inside shard state owned
/// by pool workers (`Send`).
pub trait QueryApp: Sync {
    /// Query content `<Q>`.
    type Query: Clone + Send + Sync;
    /// Query-dependent vertex attribute `a_q(v)` (VQ-data).
    type VQ: Clone + Send;
    /// Message type `<M>`.
    type Msg: Clone + Send;
    /// Aggregator value; `Default` is the identity element: `agg_merge`
    /// folding a partial into a fresh `Default` must yield that partial.
    type Agg: Clone + Default + Send + Sync;
    /// Per-query result type. `Send` because under `Pipeline::On` the
    /// reporting superstep (`finish`) runs as a pool job overlapped with
    /// the next super-round's compute, so the assembled result travels
    /// from a pool worker back to the coordinator.
    type Out: Clone + Send;

    /// Admission hook: the engine calls this once per super-round with the
    /// whole batch of queries admitted that round (in submission order),
    /// BEFORE building any per-query runtime state or calling
    /// [`QueryApp::init_activate`]. Apps that can amortize per-query
    /// preprocessing across a batch override it — e.g. the hub2 PPSP app
    /// fills lazy distance upper bounds for every admitted query in one
    /// batched min-plus kernel sweep over the padded hub table instead of
    /// one row probe per query. Mutating a query here is the ONLY
    /// sanctioned place to do so; afterwards the content is frozen for the
    /// query's lifetime. The default is a no-op.
    fn admit_batch(&self, _batch: &mut [Self::Query]) {}

    /// Serving-layer classification hook: does this query look like a
    /// **whale** — one expected to grind for many supersteps and inflate
    /// every co-resident light query's super-round count? The engine
    /// evaluates it once at submission (BEFORE [`QueryApp::admit_batch`],
    /// so content an app fills lazily per batch is not yet available —
    /// classify from what the *submitter* knew) and the `Admit::Adaptive`
    /// planner confines flagged queries to a reserved capacity slice so
    /// they can't starve point lookups. Apps with an index that prices
    /// queries up front override this — e.g. hub2 PPSP flags pairs whose
    /// index upper bound `d_ub` crosses a depth threshold. The flag only
    /// shapes *when* a query is admitted, never what it computes, so the
    /// bit-identical output contract is indifferent to it. Default:
    /// nothing is heavy (which makes `Admit::Adaptive` degenerate to
    /// `Admit::Static` — a safe default for apps without an index).
    fn is_heavy(&self, _q: &Self::Query) -> bool {
        false
    }

    /// The initial activation set `V_q^I` (paper: `init_activate()` +
    /// `get_vpos`/`activate`). Returning vertex ids (instead of per-worker
    /// positions) lets the engine filter per worker; apps with indexes
    /// (inverted lists, SCC maps) consult them here.
    fn init_activate(&self, q: &Self::Query) -> Vec<VertexId>;

    /// Initialize `a_q(v)` when `v` is first touched by `q`.
    fn init_value(&self, q: &Self::Query, v: VertexId) -> Self::VQ;

    /// The vertex UDF. Incoming messages are in `ctx.msgs`; outgoing
    /// messages, votes and aggregation go through `ctx`.
    fn compute(&self, ctx: &mut Ctx<'_, Self>, v: VertexId, vq: &mut Self::VQ)
    where
        Self: Sized;

    /// Optional message combiner: fold `from` into `into`, returning true.
    /// Return false (default) to disable combining for this app.
    fn combine(&self, _into: &mut Self::Msg, _from: &Self::Msg) -> bool {
        false
    }

    /// Merge a worker-local partial aggregate into `into`. Each worker
    /// shard accumulates its own partial during the compute phase; the
    /// fold phase folds the partials **in worker order** through this hook
    /// (deterministic regardless of thread count). Any app whose `compute`
    /// calls [`Ctx::aggregate`] must implement this; the default no-op
    /// discards every partial.
    fn agg_merge(&self, _into: &mut Self::Agg, _from: &Self::Agg) {}

    /// Master hook, run in the fold phase with the merged aggregator of the
    /// superstep that just finished (`cur`) and the previous superstep's
    /// final value (`prev`). Whatever is left in `cur` is what `compute`
    /// sees via `ctx.agg_prev()` in the next superstep — the master may
    /// fold persistent Q-data from `prev` into `cur` (e.g. the level
    /// countdown of the level-aligned XML algorithms).
    fn master_step(
        &self,
        _q: &Self::Query,
        _step: u64,
        _prev: &Self::Agg,
        _cur: &mut Self::Agg,
    ) -> MasterAction {
        MasterAction::Continue
    }

    /// Reporting superstep (super-round `n_q + 1`): assemble the result
    /// from every touched vertex state.
    fn finish(
        &self,
        q: &Self::Query,
        touched: &mut dyn Iterator<Item = (VertexId, &Self::VQ)>,
        agg: &Self::Agg,
    ) -> Self::Out;

    /// Wire size of one message, for the network cost model.
    fn msg_bytes(&self) -> usize {
        std::mem::size_of::<Self::Msg>()
    }

    // ------- streaming-mutation hooks (epoch/snapshot scheme) -------
    //
    // Apps that own a `VersionedGraph` (instead of a borrowed immutable
    // `&Graph`) opt into mutations by overriding the four hooks below.
    // The engine applies queued `MutationBatch`es only at super-round
    // boundaries, BEFORE admission, so every batch lands between
    // supersteps: an in-flight query never observes a version change.

    /// Does this app accept streaming mutations? `Engine::try_mutate`
    /// rejects batches (returning them to the caller) when this is false.
    /// Apps that override it must also override
    /// [`QueryApp::apply_mutations`] and [`QueryApp::pin_epoch`].
    fn supports_mutations(&self) -> bool {
        false
    }

    /// Apply one mutation batch, bumping the app's graph to a new epoch,
    /// and report what happened (the engine folds the receipt into its
    /// epoch gauges). Called on the coordinator between super-rounds —
    /// never concurrently with `compute`/`finish`. Apps that return true
    /// from [`QueryApp::supports_mutations`] must override this; the
    /// default is unreachable because the engine gates on that flag.
    fn apply_mutations(&mut self, _batch: &MutationBatch) -> MutationApplied {
        unreachable!("apply_mutations called on an app without mutation support")
    }

    /// Stamp the epoch current at admission into each query of the batch,
    /// so `compute`/`finish` read that pinned version for the query's
    /// whole lifetime. Called right before [`QueryApp::admit_batch`] (the
    /// epoch is part of the frozen query content). Default: no-op for
    /// immutable-graph apps.
    fn pin_epoch(&self, _batch: &mut [Self::Query], _epoch: Epoch) {}

    /// Every epoch below `oldest` is no longer pinned by any in-flight
    /// query: the app may compact its overlays (e.g.
    /// `VersionedGraph::retire`). Called after each super-round.
    fn retire_epochs(&mut self, _oldest: Epoch) {}
}

/// Per-vertex, per-query execution context (the paper's `C_vertex` +
/// `C_query` context objects: everything `compute` may touch without a
/// table lookup).
pub struct Ctx<'a, A: QueryApp> {
    pub(crate) app: &'a A,
    pub(crate) qid: QueryId,
    pub(crate) query: &'a A::Query,
    pub(crate) step: u64,
    pub(crate) msgs: &'a [A::Msg],
    pub(crate) prev_agg: &'a A::Agg,
    pub(crate) agg_partial: &'a mut A::Agg,
    /// Outgoing staged messages (dst, msg); routed at the barrier.
    pub(crate) outbox: &'a mut Vec<(VertexId, A::Msg)>,
    pub(crate) halt: bool,
    pub(crate) terminate: bool,
    pub(crate) sent: u64,
}

impl<'a, A: QueryApp> Ctx<'a, A> {
    /// Superstep number of the current query (1-based, per paper).
    #[inline]
    pub fn superstep(&self) -> u64 {
        self.step
    }

    /// Content of the current query (`get_query()`).
    #[inline]
    pub fn query(&self) -> &A::Query {
        self.query
    }

    /// Engine-assigned id of the current query.
    #[inline]
    pub fn query_id(&self) -> QueryId {
        self.qid
    }

    /// Incoming messages for this vertex.
    #[inline]
    pub fn msgs(&self) -> &[A::Msg] {
        self.msgs
    }

    /// Merged aggregator value from the previous superstep.
    #[inline]
    pub fn agg_prev(&self) -> &A::Agg {
        self.prev_agg
    }

    /// Contribute to this superstep's aggregator (worker-local partial;
    /// merged across workers at the barrier).
    #[inline]
    pub fn aggregate(&mut self, f: impl FnOnce(&A, &mut A::Agg)) {
        f(self.app, self.agg_partial);
    }

    /// Send a message to vertex `dst` (delivered next superstep).
    #[inline]
    pub fn send(&mut self, dst: VertexId, msg: A::Msg) {
        self.sent += 1;
        self.outbox.push((dst, msg));
    }

    /// Vote to halt: deactivate until re-activated by a message.
    #[inline]
    pub fn vote_halt(&mut self) {
        self.halt = true;
    }

    /// Terminate the whole query at the end of this superstep.
    #[inline]
    pub fn force_terminate(&mut self) {
        self.terminate = true;
    }
}
