//! Pregel-compatibility mode: classic whole-graph analytics expressed as
//! single-"query" Quegel jobs (the paper's second `Worker` class, used e.g.
//! for the reachability label preprocessing).
//!
//! These demonstrate that the query-centric engine subsumes the original
//! Pregel programming model: a job is just a query whose `init_activate`
//! returns every (relevant) vertex.

pub mod components;
pub mod pagerank;
pub mod sssp;

pub use components::ConnectedComponents;
pub use pagerank::PageRank;
pub use sssp::WeightedSssp;
