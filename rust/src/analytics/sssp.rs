//! Generic weighted single-source shortest paths (Pregel's SSSP example),
//! usable on any weighted graph.

use crate::graph::{Graph, VertexId};
use crate::vertex::{Ctx, QueryApp};

pub struct WeightedSssp<'g> {
    g: &'g Graph,
}

impl<'g> WeightedSssp<'g> {
    pub fn new(g: &'g Graph) -> Self {
        assert!(g.weighted(), "WeightedSssp requires edge weights");
        Self { g }
    }
}

impl<'g> QueryApp for WeightedSssp<'g> {
    /// Source vertex.
    type Query = VertexId;
    /// Tentative distance.
    type VQ = f64;
    type Msg = f64;
    type Agg = ();
    /// (vertex, distance) for every reached vertex.
    type Out = Vec<(VertexId, f64)>;

    fn init_activate(&self, s: &VertexId) -> Vec<VertexId> {
        vec![*s]
    }

    fn init_value(&self, s: &VertexId, v: VertexId) -> f64 {
        if v == *s {
            0.0
        } else {
            f64::INFINITY
        }
    }

    fn compute(&self, ctx: &mut Ctx<'_, Self>, v: VertexId, d: &mut f64) {
        let mut improved = ctx.superstep() == 1 && v == *ctx.query();
        for &m in ctx.msgs() {
            if m < *d {
                *d = m;
                improved = true;
            }
        }
        if improved {
            for (&u, &w) in self.g.out(v).iter().zip(self.g.out_w(v)) {
                ctx.send(u, *d + w as f64);
            }
        }
        ctx.vote_halt();
    }

    /// Min-combiner.
    fn combine(&self, into: &mut f64, from: &f64) -> bool {
        *into = into.min(*from);
        true
    }

    fn finish(
        &self,
        _q: &VertexId,
        touched: &mut dyn Iterator<Item = (VertexId, &f64)>,
        _agg: &(),
    ) -> Self::Out {
        let mut out: Vec<(VertexId, f64)> = touched
            .filter(|(_, d)| d.is_finite())
            .map(|(v, &d)| (v, d))
            .collect();
        out.sort_unstable_by_key(|&(v, _)| v);
        out
    }

    fn msg_bytes(&self) -> usize {
        8
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apps::terrain::baseline::dijkstra;
    use crate::coordinator::Engine;
    use crate::graph::GraphBuilder;
    use crate::network::Cluster;
    use crate::util::Rng;

    fn random_weighted(n: usize, deg: usize, seed: u64) -> Graph {
        let mut rng = Rng::new(seed);
        let mut b = GraphBuilder::new(n).undirected();
        for u in 0..n - 1 {
            b.wedge(u as u32, (u + 1) as u32, 1.0 + rng.f64() as f32 * 9.0);
        }
        for _ in 0..n * deg {
            let u = rng.below_usize(n) as u32;
            let v = rng.below_usize(n) as u32;
            if u != v {
                b.wedge(u, v, 1.0 + rng.f64() as f32 * 9.0);
            }
        }
        b.build()
    }

    #[test]
    fn matches_dijkstra() {
        let g = random_weighted(300, 3, 521);
        let (want, _) = dijkstra(&g, 7, None);
        let mut eng = Engine::new(WeightedSssp::new(&g), Cluster::new(4), 300)
            .max_supersteps(10_000);
        let got = eng.run_one(7).out;
        for (v, d) in got {
            assert!(
                (d - want[v as usize]).abs() < 1e-9,
                "v={v}: {d} vs {}",
                want[v as usize]
            );
        }
    }

    #[test]
    fn unreachable_vertices_not_reported() {
        let mut b = GraphBuilder::new(4);
        b.wedge(0, 1, 1.0);
        b.wedge(2, 3, 1.0);
        let g = b.build();
        let mut eng = Engine::new(WeightedSssp::new(&g), Cluster::new(2), 4);
        let got = eng.run_one(0).out;
        let ids: Vec<u32> = got.iter().map(|&(v, _)| v).collect();
        assert_eq!(ids, vec![0, 1]);
    }
}
