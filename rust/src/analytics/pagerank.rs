//! PageRank as a Quegel job (Pregel's canonical example, paper §1).
//!
//! Runs a fixed number of iterations; the aggregator tracks the L1 delta
//! between consecutive iterations so the master can stop early once the
//! ranks converge. Dangling-vertex mass is redistributed uniformly via the
//! aggregator (the standard correction).

use crate::graph::{Graph, VertexId};
use crate::vertex::{Ctx, MasterAction, QueryApp};

/// Aggregator: this superstep's L1 delta + dangling mass collected.
#[derive(Debug, Clone, Default)]
pub struct PrAgg {
    pub l1_delta: f64,
    pub dangling: f64,
}

/// PageRank job. The "query" is the iteration/convergence config.
#[derive(Debug, Clone, Copy)]
pub struct PrConfig {
    pub damping: f64,
    pub max_iters: u64,
    pub tol: f64,
}

impl Default for PrConfig {
    fn default() -> Self {
        Self {
            damping: 0.85,
            max_iters: 50,
            tol: 1e-7,
        }
    }
}

pub struct PageRank<'g> {
    g: &'g Graph,
}

impl<'g> PageRank<'g> {
    pub fn new(g: &'g Graph) -> Self {
        Self { g }
    }

    fn n(&self) -> f64 {
        self.g.num_vertices() as f64
    }
}

impl<'g> QueryApp for PageRank<'g> {
    type Query = PrConfig;
    /// Current rank.
    type VQ = f64;
    /// Rank contribution.
    type Msg = f64;
    type Agg = PrAgg;
    /// (vertex, rank) for every vertex.
    type Out = Vec<(VertexId, f64)>;

    fn init_activate(&self, _q: &PrConfig) -> Vec<VertexId> {
        (0..self.g.num_vertices() as VertexId).collect()
    }

    fn init_value(&self, _q: &PrConfig, _v: VertexId) -> f64 {
        1.0 / self.n()
    }

    fn compute(&self, ctx: &mut Ctx<'_, Self>, v: VertexId, rank: &mut f64) {
        let cfg = *ctx.query();
        let step = ctx.superstep();
        if step > 1 {
            // Incorporate contributions (+ dangling mass from the previous
            // superstep, uniformly redistributed).
            let incoming: f64 = ctx.msgs().iter().sum();
            let dangling = ctx.agg_prev().dangling / self.n();
            let new_rank = (1.0 - cfg.damping) / self.n() + cfg.damping * (incoming + dangling);
            let delta = (new_rank - *rank).abs();
            ctx.aggregate(|_, a| a.l1_delta += delta);
            *rank = new_rank;
        }
        let deg = self.g.out_degree(v);
        if deg > 0 {
            let share = *rank / deg as f64;
            for &u in self.g.out(v) {
                ctx.send(u, share);
            }
        } else {
            let r = *rank;
            ctx.aggregate(|_, a| a.dangling += r);
        }
        // PageRank never halts; the master stops the job.
    }

    /// Sum-combiner.
    fn combine(&self, into: &mut f64, from: &f64) -> bool {
        *into += *from;
        true
    }

    /// Both aggregator components are sums over disjoint vertex sets.
    fn agg_merge(&self, into: &mut PrAgg, from: &PrAgg) {
        into.l1_delta += from.l1_delta;
        into.dangling += from.dangling;
    }

    fn master_step(
        &self,
        q: &PrConfig,
        step: u64,
        _prev: &PrAgg,
        cur: &mut PrAgg,
    ) -> MasterAction {
        if step >= q.max_iters || (step > 2 && cur.l1_delta < q.tol) {
            return MasterAction::Terminate;
        }
        MasterAction::Continue
    }

    fn finish(
        &self,
        _q: &PrConfig,
        touched: &mut dyn Iterator<Item = (VertexId, &f64)>,
        _agg: &PrAgg,
    ) -> Self::Out {
        let mut out: Vec<(VertexId, f64)> = touched.map(|(v, &r)| (v, r)).collect();
        out.sort_unstable_by_key(|&(v, _)| v);
        out
    }

    fn msg_bytes(&self) -> usize {
        8
    }
}

/// Serial oracle: power iteration with the same dangling correction.
pub fn pagerank_oracle(g: &Graph, cfg: PrConfig) -> Vec<f64> {
    let n = g.num_vertices();
    let mut rank = vec![1.0 / n as f64; n];
    for _ in 0..cfg.max_iters {
        let mut next = vec![(1.0 - cfg.damping) / n as f64; n];
        let mut dangling = 0.0;
        for v in 0..n {
            let deg = g.out_degree(v as VertexId);
            if deg == 0 {
                dangling += rank[v];
            } else {
                let share = cfg.damping * rank[v] / deg as f64;
                for &u in g.out(v as VertexId) {
                    next[u as usize] += share;
                }
            }
        }
        let spread = cfg.damping * dangling / n as f64;
        for r in &mut next {
            *r += spread;
        }
        let delta: f64 = rank.iter().zip(&next).map(|(a, b)| (a - b).abs()).sum();
        rank = next;
        if delta < cfg.tol {
            break;
        }
    }
    rank
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::Engine;
    use crate::graph::gen;
    use crate::network::Cluster;

    #[test]
    fn matches_power_iteration() {
        let g = gen::twitter_like(500, 5, 501);
        let cfg = PrConfig {
            max_iters: 30,
            ..Default::default()
        };
        let want = pagerank_oracle(&g, cfg);
        let mut eng = Engine::new(PageRank::new(&g), Cluster::new(4), 500).max_supersteps(100);
        let got = eng.run_one(cfg).out;
        assert_eq!(got.len(), 500);
        for (v, r) in got {
            assert!(
                (r - want[v as usize]).abs() < 1e-6,
                "v={v}: {r} vs {}",
                want[v as usize]
            );
        }
    }

    #[test]
    fn ranks_sum_to_one() {
        let g = gen::btc_like(400, 40, 4, 502);
        let mut eng = Engine::new(PageRank::new(&g), Cluster::new(4), 400).max_supersteps(100);
        let got = eng.run_one(PrConfig::default()).out;
        let total: f64 = got.iter().map(|&(_, r)| r).sum();
        assert!((total - 1.0).abs() < 1e-6, "sum = {total}");
    }

    #[test]
    fn hubs_rank_higher() {
        let mut g = gen::twitter_like(2_000, 8, 503);
        g.ensure_in_edges();
        let mut eng = Engine::new(PageRank::new(&g), Cluster::new(4), 2_000).max_supersteps(100);
        let got = eng.run_one(PrConfig::default()).out;
        // The highest in-degree vertex must out-rank the median vertex.
        let hub = (0..2_000u32).max_by_key(|&v| g.in_degree(v)).unwrap();
        let hub_rank = got[hub as usize].1;
        let mut ranks: Vec<f64> = got.iter().map(|&(_, r)| r).collect();
        ranks.sort_by(f64::total_cmp);
        assert!(hub_rank > ranks[1_000] * 5.0);
    }
}
