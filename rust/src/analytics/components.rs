//! Connected components (undirected) via min-label propagation — the
//! HashMin Pregel algorithm, as a Quegel job.

use crate::graph::{Graph, VertexId};
use crate::vertex::{Ctx, QueryApp};

pub struct ConnectedComponents<'g> {
    g: &'g Graph,
}

impl<'g> ConnectedComponents<'g> {
    /// `g` must store both arcs of every undirected edge.
    pub fn new(g: &'g Graph) -> Self {
        Self { g }
    }
}

impl<'g> QueryApp for ConnectedComponents<'g> {
    type Query = ();
    /// Current component label (min vertex id seen).
    type VQ = VertexId;
    type Msg = VertexId;
    type Agg = ();
    /// (vertex, component label) for every vertex.
    type Out = Vec<(VertexId, VertexId)>;

    fn init_activate(&self, _q: &()) -> Vec<VertexId> {
        (0..self.g.num_vertices() as VertexId).collect()
    }

    fn init_value(&self, _q: &(), v: VertexId) -> VertexId {
        v
    }

    fn compute(&self, ctx: &mut Ctx<'_, Self>, v: VertexId, label: &mut VertexId) {
        let mut best = *label;
        if ctx.superstep() == 1 {
            // Adopt the smallest neighbor id immediately (saves one round).
            for &u in self.g.out(v) {
                best = best.min(u);
            }
        } else {
            for &m in ctx.msgs() {
                best = best.min(m);
            }
        }
        if best < *label || ctx.superstep() == 1 {
            *label = best;
            for &u in self.g.out(v) {
                if u != best {
                    ctx.send(u, best);
                }
            }
        }
        ctx.vote_halt();
    }

    /// Min-combiner.
    fn combine(&self, into: &mut VertexId, from: &VertexId) -> bool {
        *into = (*into).min(*from);
        true
    }

    fn finish(
        &self,
        _q: &(),
        touched: &mut dyn Iterator<Item = (VertexId, &VertexId)>,
        _agg: &(),
    ) -> Self::Out {
        let mut out: Vec<(VertexId, VertexId)> = touched.map(|(v, &l)| (v, l)).collect();
        out.sort_unstable_by_key(|&(v, _)| v);
        out
    }

    fn msg_bytes(&self) -> usize {
        4
    }
}

/// Serial union-find oracle.
pub fn components_oracle(g: &Graph) -> Vec<VertexId> {
    let n = g.num_vertices();
    let mut parent: Vec<u32> = (0..n as u32).collect();
    fn find(parent: &mut [u32], x: u32) -> u32 {
        let mut root = x;
        while parent[root as usize] != root {
            root = parent[root as usize];
        }
        let mut cur = x;
        while parent[cur as usize] != root {
            let next = parent[cur as usize];
            parent[cur as usize] = root;
            cur = next;
        }
        root
    }
    for u in 0..n as u32 {
        for &v in g.out(u) {
            let (ru, rv) = (find(&mut parent, u), find(&mut parent, v));
            if ru != rv {
                parent[ru.max(rv) as usize] = ru.min(rv);
            }
        }
    }
    // Normalize: label = min member id of the component.
    let mut label = vec![0u32; n];
    for v in 0..n as u32 {
        label[v as usize] = find(&mut parent, v);
    }
    label
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::Engine;
    use crate::graph::gen;
    use crate::network::Cluster;

    #[test]
    fn matches_union_find() {
        let g = gen::btc_like(600, 60, 4, 511);
        let want = components_oracle(&g);
        let mut eng = Engine::new(ConnectedComponents::new(&g), Cluster::new(4), 600)
            .max_supersteps(1_000);
        let got = eng.run_one(()).out;
        for (v, l) in got {
            assert_eq!(l, want[v as usize], "vertex {v}");
        }
    }

    #[test]
    fn single_component_on_connected_graph() {
        let g = gen::livej_like(300, 60, 4, 512);
        let mut eng = Engine::new(ConnectedComponents::new(&g), Cluster::new(4), 360)
            .max_supersteps(1_000);
        let got = eng.run_one(()).out;
        let want = components_oracle(&g);
        let n_components: std::collections::HashSet<u32> = want.iter().copied().collect();
        let got_components: std::collections::HashSet<u32> =
            got.iter().map(|&(_, l)| l).collect();
        assert_eq!(got_components.len(), n_components.len());
    }
}
