//! PJRT runtime: load AOT-compiled HLO artifacts and execute them from the
//! L3 hot path. Wraps the `xla` crate (PJRT C API, CPU client).
//!
//! Interchange format is HLO *text* (not serialized proto): jax >= 0.5 emits
//! protos with 64-bit instruction ids which xla_extension 0.5.1 rejects; the
//! text parser reassigns ids and round-trips cleanly.
//!
//! The PJRT pieces are gated behind the `pjrt` cargo feature: the offline
//! build image ships no `xla` crate, so the default build compiles only
//! the pure-rust kernels in [`rowmin`] (the blocked tropical min-plus /
//! row-reduction loops mirroring the Pallas tile schedules, used by the
//! batched hub2 admission path) plus the naive
//! [`crate::apps::ppsp::hub2::RustMinPlus`] oracle. Enable with
//! `--features pjrt` after adding the `xla` dependency to `Cargo.toml`.

pub mod rowmin;

#[cfg(feature = "pjrt")]
pub mod minplus;

#[cfg(feature = "pjrt")]
pub use pjrt::{HloExecutable, Runtime};

#[cfg(feature = "pjrt")]
mod pjrt {
    use crate::util::error::{Context, Result};
    use std::path::Path;

    /// A compiled HLO executable bound to a PJRT client.
    pub struct HloExecutable {
        exe: xla::PjRtLoadedExecutable,
        name: String,
    }

    /// PJRT client wrapper; owns the device and compiled executables.
    pub struct Runtime {
        client: xla::PjRtClient,
    }

    impl Runtime {
        /// Create a CPU PJRT client.
        pub fn cpu() -> Result<Self> {
            let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
            Ok(Self { client })
        }

        /// Platform name reported by PJRT (e.g. "Host").
        pub fn platform(&self) -> String {
            self.client.platform_name()
        }

        /// Load an HLO-text artifact and compile it for this client.
        pub fn load_hlo_text<P: AsRef<Path>>(&self, path: P) -> Result<HloExecutable> {
            let path = path.as_ref();
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().context("non-utf8 artifact path")?,
            )
            .with_context(|| format!("parsing HLO text {}", path.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self
                .client
                .compile(&comp)
                .with_context(|| format!("compiling {}", path.display()))?;
            Ok(HloExecutable {
                exe,
                name: path.display().to_string(),
            })
        }
    }

    impl HloExecutable {
        /// Execute with f32 input buffers of the given shapes, returning the
        /// flattened f32 elements of every tuple output.
        ///
        /// Artifacts are lowered with `return_tuple=True`, so the single
        /// output literal is a tuple; we decompose it and flatten each
        /// element.
        pub fn run_f32(&self, inputs: &[(&[f32], &[usize])]) -> Result<Vec<Vec<f32>>> {
            let mut lits = Vec::with_capacity(inputs.len());
            for (data, shape) in inputs {
                let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
                let lit = xla::Literal::vec1(data).reshape(&dims).with_context(|| {
                    format!("reshaping input to {:?} for {}", shape, self.name)
                })?;
                lits.push(lit);
            }
            let mut result = self
                .exe
                .execute::<xla::Literal>(&lits)
                .with_context(|| format!("executing {}", self.name))?[0][0]
                .to_literal_sync()?;
            let parts = result.decompose_tuple()?;
            let mut out = Vec::with_capacity(parts.len());
            for p in parts {
                out.push(p.to_vec::<f32>()?);
            }
            Ok(out)
        }

        /// Name (artifact path) this executable was loaded from.
        pub fn name(&self) -> &str {
            &self.name
        }
    }
}
