//! Pure-rust blocked tropical kernels: the CPU mirror of the L1 Pallas
//! kernels (`python/compile/kernels/minplus.py` and `rowmin.py`).
//!
//! Two primitives:
//!
//! * [`minplus_matmul`] — `out[i,j] = min_k (a[i,k] + b[k,j])`, the
//!   tropical matrix product used for the Hub² distance closure and the
//!   first stage (`sd = S ⊗ D_H`) of the batched query upper bound;
//! * [`tropical_rowmin`] — `out[q] = min_j (a[q,j] + b[q,j])`, the fused
//!   row reduction that folds `sd` against the t-side label rows without
//!   materializing `sd + t`.
//!
//! Both walk the same tile schedule as the Pallas `BlockSpec` grids
//! (accumulator revisited across the contraction-axis blocks), so a tile
//! of each operand stays cache-resident per step — this module is what
//! the default (no `pjrt` feature) build runs on the query hot path, and
//! it is the oracle the compiled artifacts are validated against. Like
//! the Pallas kernels, requested tile sizes auto-shrink to the full
//! dimension when the dimension does not tile evenly, and outputs are
//! clamped to [`INF`] (`jnp.minimum(out, INF)` in the kernels).
//!
//! All inputs are hop counts encoded as f32 (small non-negative integers,
//! exact in f32) or [`INF`]; `INF + x` rounds back to `INF` for any hop
//! count `x` (the ulp at 2^31 is 256), so tropical associativity holds
//! bit-exactly and the blocked schedules match the naive loops — the
//! tests pin that parity.

/// f32 encoding of "unreachable": 2^31, matching
/// `python/compile/kernels/ref.py` (and `apps::ppsp::hub2::F_INF`).
pub const INF: f32 = 2_147_483_648.0;

/// Default tile for [`minplus_matmul`] (the Pallas kernel's 128×128×128).
pub const MM_TILE: (usize, usize, usize) = (128, 128, 128);

/// Default tile for [`tropical_rowmin`] (the Pallas kernel's (8, 1024)).
pub const RM_TILE: (usize, usize) = (8, 1024);

/// Shrink a requested tile size to the full dimension when it does not
/// tile evenly (production hub tables are padded; test shapes are not).
#[inline]
fn fit(dim: usize, tile: usize) -> usize {
    assert!(tile > 0, "tile size must be positive");
    if dim == 0 || dim % tile != 0 {
        dim.max(1)
    } else {
        tile
    }
}

/// Blocked tropical (min-plus) matmul: `out[i,j] = min_k (a[i,k] + b[k,j])`
/// with the default tile. `a` is `m×k` row-major, `b` is `k×n` row-major.
pub fn minplus_matmul(a: &[f32], b: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
    minplus_matmul_blocked(a, b, m, k, n, MM_TILE.0, MM_TILE.1, MM_TILE.2)
}

/// [`minplus_matmul`] with explicit tile sizes `(bm, bn, bk)`. The grid
/// runs `(m/bm, n/bn, k/bk)` with the k axis innermost, so each output
/// tile is revisited across k blocks and acts as the accumulator —
/// exactly the Pallas revisiting schedule.
#[allow(clippy::too_many_arguments)]
pub fn minplus_matmul_blocked(
    a: &[f32],
    b: &[f32],
    m: usize,
    k: usize,
    n: usize,
    bm: usize,
    bn: usize,
    bk: usize,
) -> Vec<f32> {
    assert_eq!(a.len(), m * k, "a shape mismatch");
    assert_eq!(b.len(), k * n, "b shape mismatch");
    let (bm, bn, bk) = (fit(m, bm), fit(n, bn), fit(k, bk));
    let mut out = vec![INF; m * n];
    for i0 in (0..m).step_by(bm) {
        for j0 in (0..n).step_by(bn) {
            for k0 in (0..k).step_by(bk) {
                for i in i0..i0 + bm {
                    for kk in k0..k0 + bk {
                        let av = a[i * k + kk];
                        if av >= INF {
                            // INF + b[kk,j] rounds to >= INF: never lowers
                            // the accumulator (initialized to INF).
                            continue;
                        }
                        let brow = &b[kk * n + j0..kk * n + j0 + bn];
                        let orow = &mut out[i * n + j0..i * n + j0 + bn];
                        for (o, &bv) in orow.iter_mut().zip(brow) {
                            let cand = av + bv;
                            if cand < *o {
                                *o = cand;
                            }
                        }
                    }
                }
            }
        }
    }
    for o in &mut out {
        *o = o.min(INF);
    }
    out
}

/// Fused tropical row reduction: `out[q] = min_j (a[q,j] + b[q,j])` with
/// the default tile. Both operands are `c×k` row-major.
pub fn tropical_rowmin(a: &[f32], b: &[f32], c: usize, k: usize) -> Vec<f32> {
    tropical_rowmin_blocked(a, b, c, k, RM_TILE.0, RM_TILE.1)
}

/// [`tropical_rowmin`] with explicit tile sizes `(bc, bk)`: the grid runs
/// `(c/bc, k/bk)`, streaming `(bc, bk)` tiles of both operands and
/// folding each into the `(bc,)` accumulator column.
pub fn tropical_rowmin_blocked(
    a: &[f32],
    b: &[f32],
    c: usize,
    k: usize,
    bc: usize,
    bk: usize,
) -> Vec<f32> {
    assert_eq!(a.len(), c * k, "a shape mismatch");
    assert_eq!(b.len(), c * k, "b shape mismatch");
    let (bc, bk) = (fit(c, bc), fit(k, bk));
    let mut out = vec![INF; c];
    for q0 in (0..c).step_by(bc) {
        for k0 in (0..k).step_by(bk) {
            for q in q0..q0 + bc {
                let arow = &a[q * k + k0..q * k + k0 + bk];
                let brow = &b[q * k + k0..q * k + k0 + bk];
                let mut acc = out[q];
                for (&av, &bv) in arow.iter().zip(brow) {
                    let cand = av + bv;
                    if cand < acc {
                        acc = cand;
                    }
                }
                out[q] = acc;
            }
        }
    }
    for o in &mut out {
        *o = o.min(INF);
    }
    out
}

/// In-place min-plus closure of the `k×k` table `d` by repeated tropical
/// squaring (`ceil(log2 k) + 1` rounds or until fixpoint) — the CPU
/// mirror of the L2 closure built on [`minplus_matmul`].
pub fn closure_in_place(d: &mut [f32], k: usize) {
    assert_eq!(d.len(), k * k, "d shape mismatch");
    if k == 0 {
        return;
    }
    let steps = (k as f64).log2().ceil() as usize + 1;
    for _ in 0..steps.max(1) {
        let next = minplus_matmul(d, d, k, k, k);
        // Squaring a reflexive table (0 diagonal) only ever shrinks
        // entries, so fixpoint == equality.
        if next == d {
            break;
        }
        d.copy_from_slice(&next);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Deterministic xorshift32 — tests must not depend on crate rng.
    struct Rng(u32);
    impl Rng {
        fn next(&mut self) -> u32 {
            let mut x = self.0;
            x ^= x << 13;
            x ^= x >> 17;
            x ^= x << 5;
            self.0 = x;
            x
        }
        /// Hop-count-shaped value: small integer or INF (~1 in 4).
        fn hop(&mut self) -> f32 {
            let r = self.next();
            if r % 4 == 0 {
                INF
            } else {
                (r % 50) as f32
            }
        }
    }

    fn table(rng: &mut Rng, len: usize) -> Vec<f32> {
        (0..len).map(|_| rng.hop()).collect()
    }

    fn naive_matmul(a: &[f32], b: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
        let mut out = vec![INF; m * n];
        for i in 0..m {
            for j in 0..n {
                for kk in 0..k {
                    let cand = a[i * k + kk] + b[kk * n + j];
                    if cand < out[i * n + j] {
                        out[i * n + j] = cand;
                    }
                }
                out[i * n + j] = out[i * n + j].min(INF);
            }
        }
        out
    }

    fn naive_rowmin(a: &[f32], b: &[f32], c: usize, k: usize) -> Vec<f32> {
        (0..c)
            .map(|q| {
                let mut best = INF;
                for j in 0..k {
                    best = best.min(a[q * k + j] + b[q * k + j]);
                }
                best.min(INF)
            })
            .collect()
    }

    #[test]
    fn matmul_matches_naive_on_random_tables() {
        let mut rng = Rng(0xC0FFEE);
        for &(m, k, n) in &[(1, 1, 1), (3, 5, 2), (8, 8, 8), (13, 7, 17)] {
            let a = table(&mut rng, m * k);
            let b = table(&mut rng, k * n);
            assert_eq!(
                minplus_matmul(&a, &b, m, k, n),
                naive_matmul(&a, &b, m, k, n),
                "({m},{k},{n})"
            );
        }
    }

    #[test]
    fn matmul_blocking_is_invariant() {
        let mut rng = Rng(42);
        let (m, k, n) = (12, 16, 20);
        let a = table(&mut rng, m * k);
        let b = table(&mut rng, k * n);
        let want = naive_matmul(&a, &b, m, k, n);
        for &(bm, bn, bk) in &[(1, 1, 1), (4, 5, 8), (12, 20, 16), (3, 2, 4)] {
            assert_eq!(
                minplus_matmul_blocked(&a, &b, m, k, n, bm, bn, bk),
                want,
                "tile ({bm},{bn},{bk})"
            );
        }
    }

    #[test]
    fn matmul_identity_is_inert() {
        // Tropical identity: 0 diagonal, INF off-diagonal.
        let mut rng = Rng(7);
        let k = 6;
        let a = table(&mut rng, k * k);
        let mut id = vec![INF; k * k];
        for i in 0..k {
            id[i * k + i] = 0.0;
        }
        assert_eq!(minplus_matmul(&a, &id, k, k, k), a);
        assert_eq!(minplus_matmul(&id, &a, k, k, k), a);
    }

    #[test]
    fn rowmin_matches_naive_on_random_tables() {
        let mut rng = Rng(0xDEAD);
        for &(c, k) in &[(1, 1), (4, 9), (8, 1024), (5, 33)] {
            let a = table(&mut rng, c * k);
            let b = table(&mut rng, c * k);
            assert_eq!(
                tropical_rowmin(&a, &b, c, k),
                naive_rowmin(&a, &b, c, k),
                "({c},{k})"
            );
        }
    }

    #[test]
    fn rowmin_blocking_is_invariant() {
        let mut rng = Rng(99);
        let (c, k) = (10, 24);
        let a = table(&mut rng, c * k);
        let b = table(&mut rng, c * k);
        let want = naive_rowmin(&a, &b, c, k);
        for &(bc, bk) in &[(1, 1), (2, 8), (5, 24), (10, 3)] {
            assert_eq!(
                tropical_rowmin_blocked(&a, &b, c, k, bc, bk),
                want,
                "tile ({bc},{bk})"
            );
        }
    }

    #[test]
    fn empty_contraction_yields_inf() {
        assert_eq!(tropical_rowmin(&[], &[], 3, 0), vec![INF; 3]);
        assert_eq!(minplus_matmul(&[], &[], 2, 0, 2), vec![INF; 4]);
    }

    #[test]
    fn inf_plus_hop_rounds_back_to_inf() {
        // The absorption the module doc relies on: ulp(2^31) = 256, so
        // INF + any hop count rounds back to INF exactly.
        for d in [1.0f32, 50.0, 200.0] {
            assert_eq!(INF + d, INF);
        }
    }

    #[test]
    fn closure_finds_two_hop_paths_and_reaches_fixpoint() {
        // 0 ->(3) 1 ->(4) 2: closure must fill d(0,2) = 7.
        let k = 3;
        let mut d = vec![INF; k * k];
        for i in 0..k {
            d[i * k + i] = 0.0;
        }
        d[1] = 3.0;
        d[k + 2] = 4.0;
        closure_in_place(&mut d, k);
        assert_eq!(d[2], 7.0);
        let fixed = d.clone();
        closure_in_place(&mut d, k);
        assert_eq!(d, fixed, "closure must be idempotent");
    }
}
