//! PJRT-backed [`MinPlus`](crate::apps::ppsp::hub2::MinPlus) evaluator: the
//! L1 Pallas kernels (AOT-lowered to `artifacts/*.hlo.txt`) on the query
//! hot path.
//!
//! Artifact shapes are static (see python/compile/aot.py): the hub table is
//! padded to `k ∈ {128, 256}`, query batches to `c = 8` rows. The rust side
//! pads with INF rows (inert in the tropical semiring).

use super::{HloExecutable, Runtime};
use crate::apps::ppsp::hub2::{MinPlus, F_INF};
use crate::bail;
use crate::util::error::{Context, Result};
use std::path::Path;

/// Batch width the dub artifact was lowered with.
pub const ARTIFACT_BATCH: usize = 8;
/// Hub-table widths with available artifacts.
pub const ARTIFACT_KS: [usize; 2] = [128, 256];

/// PJRT-backed tropical evaluator bound to one artifact k-variant.
pub struct PjrtMinPlus {
    closure_exe: HloExecutable,
    dub_exe: HloExecutable,
    /// Kernel hub-table width (k after padding).
    pub k: usize,
    /// Kernel batch width (c after padding).
    pub c: usize,
}

impl PjrtMinPlus {
    /// Load the artifact pair for hub tables of up to `k_max` hubs from
    /// `artifacts_dir`. Picks the smallest artifact k that fits.
    pub fn load<P: AsRef<Path>>(rt: &Runtime, artifacts_dir: P, k_max: usize) -> Result<Self> {
        let dir = artifacts_dir.as_ref();
        let Some(&k) = ARTIFACT_KS.iter().find(|&&k| k >= k_max) else {
            bail!("no artifact variant for k_max={k_max} (have {ARTIFACT_KS:?})");
        };
        let closure_exe = rt
            .load_hlo_text(dir.join(format!("hub_closure_k{k}.hlo.txt")))
            .context("loading closure artifact")?;
        let dub_exe = rt
            .load_hlo_text(dir.join(format!("dub_batch_c{ARTIFACT_BATCH}_k{k}.hlo.txt")))
            .context("loading dub artifact")?;
        Ok(Self {
            closure_exe,
            dub_exe,
            k,
            c: ARTIFACT_BATCH,
        })
    }

    /// Pad a `k×k` table into the kernel's `self.k × self.k` layout.
    fn pad_table(&self, d: &[f32], k: usize) -> Vec<f32> {
        let kk = self.k;
        let mut out = vec![F_INF; kk * kk];
        for i in 0..k {
            out[i * kk..i * kk + k].copy_from_slice(&d[i * k..(i + 1) * k]);
        }
        for i in k..kk {
            out[i * kk + i] = 0.0;
        }
        out
    }
}

impl MinPlus for PjrtMinPlus {
    fn closure(&self, d: &mut [f32], k: usize) {
        assert!(k <= self.k, "table k={k} exceeds artifact k={}", self.k);
        let kk = self.k;
        let mut cur = self.pad_table(d, k);
        // ceil(log2 k) squarings reach the fixpoint for any k-vertex table.
        let steps = (k.max(2) as f64).log2().ceil() as usize;
        for _ in 0..steps {
            let out = self
                .closure_exe
                .run_f32(&[(&cur, &[kk, kk])])
                .expect("closure kernel execution");
            cur = out.into_iter().next().expect("one output");
        }
        for i in 0..k {
            d[i * k..(i + 1) * k].copy_from_slice(&cur[i * kk..i * kk + k]);
        }
    }

    fn dub_batch(&self, s: &[f32], d: &[f32], t: &[f32], c: usize, k: usize) -> Vec<f32> {
        assert!(k <= self.k, "k={k} exceeds artifact k={}", self.k);
        let (kk, cc) = (self.k, self.c);
        let dp = self.pad_table(d, k);
        let mut out = Vec::with_capacity(c);
        // Process the batch in artifact-width chunks, padding with INF rows.
        for chunk_start in (0..c).step_by(cc) {
            let rows = cc.min(c - chunk_start);
            let mut sp = vec![F_INF; cc * kk];
            let mut tp = vec![F_INF; cc * kk];
            for r in 0..rows {
                let q = chunk_start + r;
                sp[r * kk..r * kk + k].copy_from_slice(&s[q * k..(q + 1) * k]);
                tp[r * kk..r * kk + k].copy_from_slice(&t[q * k..(q + 1) * k]);
            }
            let res = self
                .dub_exe
                .run_f32(&[(&sp, &[cc, kk]), (&dp, &[kk, kk]), (&tp, &[cc, kk])])
                .expect("dub kernel execution");
            out.extend_from_slice(&res[0][..rows]);
        }
        out
    }
}
