//! Metrics: per-query statistics and engine-wide counters, plus the table
//! formatting used by the benchmark harness to print paper-style rows.

use crate::vertex::QueryId;

/// Statistics for one completed query.
#[derive(Debug, Clone, Default)]
pub struct QueryStats {
    pub qid: QueryId,
    /// Supersteps executed (n_q; excludes the reporting super-round).
    pub supersteps: u64,
    /// Messages sent (post-combiner).
    pub messages: u64,
    /// Bytes put on the wire (post-combiner, incl. headers).
    pub bytes: u64,
    /// Distinct vertices that allocated VQ-data (the paper's access count).
    pub touched: u64,
    /// Access rate = touched / |V|.
    pub access_rate: f64,
    /// Simulated cluster time at submission.
    pub submitted_at: f64,
    /// Simulated cluster time when processing started (left the queue).
    pub started_at: f64,
    /// Simulated cluster time when the result was reported.
    pub finished_at: f64,
    /// True if the query hit the engine's superstep cap.
    pub truncated: bool,
}

impl QueryStats {
    /// End-to-end simulated latency (queue wait + processing).
    pub fn latency(&self) -> f64 {
        self.finished_at - self.submitted_at
    }

    /// Processing-only simulated time.
    pub fn processing(&self) -> f64 {
        self.finished_at - self.started_at
    }
}

/// Scheduler counters for one engine phase (compute / exchange / fold),
/// accumulated across super-rounds.
#[derive(Debug, Clone, Copy, Default)]
pub struct PhaseSched {
    /// Pool jobs executed. Under `Sched::Stealing` this counts items
    /// (worker lanes / destination workers / queries); under
    /// `Sched::Static` it counts contiguous chunks (≤ threads per round),
    /// so the job-granularity difference between the schedulers is
    /// directly observable here.
    pub jobs_executed: u64,
    /// Jobs executed by a pool thread other than the one whose deque they
    /// were distributed to — each one is a load-balancing event where an
    /// idle thread absorbed a busy thread's queued work.
    pub steals: u64,
}

impl PhaseSched {
    /// Fold one phase dispatch into the counters.
    pub fn add(&mut self, jobs: u64, steals: u64) {
        self.jobs_executed += jobs;
        self.steals += steals;
    }
}

/// Engine-wide counters, accumulated across all super-rounds.
#[derive(Debug, Clone, Default)]
pub struct EngineMetrics {
    pub super_rounds: u64,
    pub total_messages: u64,
    pub total_bytes: u64,
    pub total_compute_calls: u64,
    /// Simulated cluster seconds consumed so far.
    pub sim_time: f64,
    /// Wall-clock seconds spent inside the engine (perf pass metric).
    pub wall_time: f64,
    /// **Busy** seconds of compute-phase work: time spent inside compute
    /// jobs (lane prep, sub-jobs, edge ranges, merges), summed across
    /// pool threads, plus the coordinator's serial compute segments.
    /// Under `Pipeline::Off` no two phases overlap, so the three phase
    /// fields sum to ≈ `wall_time`; under `Pipeline::On` phases run
    /// concurrently, so the busy-sum may exceed `wall_time` (bounded by
    /// `threads × wall_time`) — a wall-segment stopwatch here would
    /// double-count the overlapped spans, which is exactly the bug the
    /// busy accounting replaces.
    pub compute_time: f64,
    /// **Busy** seconds of exchange work: inbox delivery of staged
    /// columns (the pooled destination drains, the pipelined eager
    /// column applications, and the serial map handoff around them).
    pub exchange_time: f64,
    /// **Busy** seconds of the remaining barrier work: the per-query
    /// aggregator fold + lifecycle, the simulated-clock advance and the
    /// reporting round (including reporting jobs overlapped onto the
    /// next round's compute under `Pipeline::On`).
    pub barrier_time: f64,
    /// Wall seconds during which ≥2 phases were *simultaneously* active,
    /// summed over pipelined super-rounds. Always 0 under
    /// `Pipeline::Off`; under `Pipeline::On` this is the overlap the
    /// pipeline bought (and the reason the phase fields are busy time:
    /// wall-segment timers cannot attribute these spans to one phase).
    pub overlap_time: f64,
    /// Super-rounds that ran the pipelined (ready-driven) path rather
    /// than the barrier path. Zero under `Pipeline::Off`; under
    /// `Pipeline::On` rounds may still fall back to the barrier path
    /// (serial engines, split-armed rounds), so tests read this to prove
    /// the pipeline actually engaged.
    pub pipelined_rounds: u64,
    /// Queries completed (result reported). Accounted when the reporting
    /// round runs, so it never depends on the caller draining
    /// `take_results` — interactive `run_one` sessions and batch sessions
    /// count identically.
    pub queries_completed: u64,
    /// Peak number of simultaneously in-flight queries.
    pub peak_inflight: usize,
    /// Compute-phase scheduler counters. Jobs count every compute
    /// dispatch: the per-lane prep jobs, plus — in rounds where either
    /// split engaged — the vertex-range sub-jobs, the edge-range jobs of
    /// parked mega-fanouts, and the merge jobs (per-lane control folds
    /// and per-(task, destination worker) staging-column replays).
    pub compute_sched: PhaseSched,
    /// Exchange-phase scheduler counters (jobs = destination workers).
    pub exchange_sched: PhaseSched,
    /// Fold-phase scheduler counters (jobs = in-flight queries).
    pub fold_sched: PhaseSched,
    /// Compute sub-jobs executed by the sub-lane split: pool jobs that ran
    /// one contiguous sub-range of a split task against private staging.
    /// Zero means the split never engaged (balanced partitions,
    /// `Split::Off`, or the static baseline).
    pub subjobs_executed: u64,
    /// (query, worker) compute tasks the split policy cut into sub-ranges.
    pub tasks_split: u64,
    /// Edge-range jobs executed by the edge-level split: pool jobs that
    /// staged one contiguous range of a parked mega-fanout into a private
    /// insertion-ordered buffer. Zero means no compute call ever crossed
    /// the edge-split threshold (or `EdgeSplit::Off` / the static
    /// baseline / a serial engine).
    pub edge_ranges_split: u64,
    /// Largest single-vertex compute fanout seen: the `ctx.send` count of
    /// the heaviest `compute()` call across every super-round. Read next
    /// to `edge_ranges_split` to see whether a workload's mega-hubs were
    /// big enough to engage the edge split.
    pub max_edge_task: u64,
    /// Worst compute-phase lane imbalance seen: max lane cost over mean
    /// lane cost (simulated cost model, so deterministic) of the most
    /// skewed super-round. ~1.0 = balanced partition; `workers` = one lane
    /// carried the whole phase. This is the skew the stealing scheduler
    /// absorbs — read it next to `compute_sched.steals` to see whether a
    /// workload's imbalance actually engaged the steal path.
    pub max_lane_imbalance: f64,
    /// Same normalization as `max_lane_imbalance`, but over the largest
    /// *schedulable unit* after sub-lane splitting (a prep job's serial
    /// share, or one sub-job) instead of whole lanes. With splitting off
    /// the two coincide; with splitting on, the gap between them is the
    /// serialization the sub-jobs broke up — a pathological lane that
    /// reads 8× on `max_lane_imbalance` but ~1× here was fully absorbed.
    pub max_post_split_imbalance: f64,
    /// High-water mark of bytes retained by flat staging buffers across
    /// all in-flight shards, sampled at the end of each super-round (after
    /// the exchange drained them, before the capped recycler trimmed
    /// them). Always 0 under `Layout::Hashed` — tests read this to prove
    /// the flat layout actually engaged. Like the other high-water marks
    /// it is an engine-lifetime field preserved by [`EngineMetrics::reset`].
    pub staging_bytes_peak: u64,
}

impl EngineMetrics {
    /// Stolen jobs across all three phases.
    pub fn steals(&self) -> u64 {
        self.compute_sched.steals + self.exchange_sched.steals + self.fold_sched.steals
    }

    /// Pool jobs executed across all three phases.
    pub fn jobs_executed(&self) -> u64 {
        self.compute_sched.jobs_executed
            + self.exchange_sched.jobs_executed
            + self.fold_sched.jobs_executed
    }

    /// Zero the **per-session** counters, so per-session accounting is
    /// possible on a long-lived engine. Scheduler counters
    /// (`jobs_executed`, `steals`) and the split counters are per-batch
    /// values that only ever accumulate — without a reset between
    /// sessions (e.g. two `run_one` calls), the second session reads the
    /// first one's totals too.
    ///
    /// **Engine-lifetime fields are preserved**: `sim_time` mirrors the
    /// engine's monotone simulated clock (wiping it here used to leave a
    /// stale zero until the next super-round re-synced it — visible to
    /// any direct `metrics.reset()` caller bypassing
    /// `Engine::reset_metrics`), and `peak_inflight` / `max_edge_task`
    /// are high-water marks over the engine's whole life that a
    /// per-session wipe would permanently lose.
    pub fn reset(&mut self) {
        let sim_time = self.sim_time;
        let peak_inflight = self.peak_inflight;
        let max_edge_task = self.max_edge_task;
        let staging_bytes_peak = self.staging_bytes_peak;
        *self = EngineMetrics {
            sim_time,
            peak_inflight,
            max_edge_task,
            staging_bytes_peak,
            ..EngineMetrics::default()
        };
    }
}

/// Fixed-width table printer for bench output (we have no external
/// table/serde crates offline; benches print paper-shaped rows).
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new<S: Into<String>>(headers: Vec<S>) -> Self {
        Self {
            headers: headers.into_iter().map(Into::into).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row<S: Into<String>>(&mut self, cells: Vec<S>) {
        let cells: Vec<String> = cells.into_iter().map(Into::into).collect();
        assert_eq!(cells.len(), self.headers.len(), "row arity mismatch");
        self.rows.push(cells);
    }

    /// Render with padded columns.
    pub fn render(&self) -> String {
        let ncol = self.headers.len();
        let mut widths = vec![0usize; ncol];
        for (i, h) in self.headers.iter().enumerate() {
            widths[i] = widths[i].max(h.len());
        }
        for r in &self.rows {
            for (i, c) in r.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        let fmt_row = |cells: &[String], widths: &[usize]| -> String {
            let mut s = String::from("| ");
            for (c, w) in cells.iter().zip(widths) {
                s.push_str(&format!("{c:<w$} | ", w = w));
            }
            s.trim_end().to_string()
        };
        out.push_str(&fmt_row(&self.headers, &widths));
        out.push('\n');
        out.push_str(&format!(
            "|{}|",
            widths
                .iter()
                .map(|w| "-".repeat(w + 2))
                .collect::<Vec<_>>()
                .join("|")
        ));
        out.push('\n');
        for r in &self.rows {
            out.push_str(&fmt_row(r, &widths));
            out.push('\n');
        }
        out
    }
}

/// Human-readable seconds with sensible precision.
pub fn fmt_secs(s: f64) -> String {
    if s >= 100.0 {
        format!("{s:.0} s")
    } else if s >= 1.0 {
        format!("{s:.2} s")
    } else if s >= 1e-3 {
        format!("{:.2} ms", s * 1e3)
    } else {
        format!("{:.1} us", s * 1e6)
    }
}

/// Percentage with two significant digits.
pub fn fmt_pct(x: f64) -> String {
    format!("{:.2}%", x * 100.0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn latency_decomposition() {
        let s = QueryStats {
            submitted_at: 1.0,
            started_at: 2.0,
            finished_at: 5.0,
            ..Default::default()
        };
        assert!((s.latency() - 4.0).abs() < 1e-12);
        assert!((s.processing() - 3.0).abs() < 1e-12);
    }

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new(vec!["a", "bb"]);
        t.row(vec!["x", "y"]);
        t.row(vec!["long", "z"]);
        let r = t.render();
        assert!(r.contains("| a    | bb |"));
        assert!(r.lines().count() == 4);
    }

    #[test]
    #[should_panic]
    fn table_rejects_bad_arity() {
        let mut t = Table::new(vec!["a"]);
        t.row(vec!["x", "y"]);
    }

    #[test]
    fn phase_sched_counters_accumulate_and_total() {
        let mut m = EngineMetrics::default();
        m.compute_sched.add(8, 2);
        m.compute_sched.add(8, 0);
        m.exchange_sched.add(8, 1);
        m.fold_sched.add(3, 0);
        assert_eq!(m.compute_sched.jobs_executed, 16);
        assert_eq!(m.compute_sched.steals, 2);
        assert_eq!(m.jobs_executed(), 27);
        assert_eq!(m.steals(), 3);
    }

    #[test]
    fn reset_zeroes_session_counters_and_keeps_lifetime_fields() {
        let mut m = EngineMetrics::default();
        m.compute_sched.add(8, 2);
        m.subjobs_executed = 5;
        m.tasks_split = 2;
        m.edge_ranges_split = 11;
        m.max_lane_imbalance = 7.5;
        m.max_post_split_imbalance = 1.2;
        m.queries_completed = 3;
        m.super_rounds = 9;
        m.overlap_time = 0.25;
        m.pipelined_rounds = 4;
        // Engine-lifetime fields: survive a bare reset().
        m.sim_time = 12.5;
        m.peak_inflight = 6;
        m.max_edge_task = 4096;
        m.staging_bytes_peak = 1 << 20;
        m.reset();
        assert_eq!(m.steals(), 0);
        assert_eq!(m.jobs_executed(), 0);
        assert_eq!(m.subjobs_executed, 0);
        assert_eq!(m.tasks_split, 0);
        assert_eq!(m.edge_ranges_split, 0);
        assert_eq!(m.max_lane_imbalance, 0.0);
        assert_eq!(m.max_post_split_imbalance, 0.0);
        assert_eq!(m.queries_completed, 0);
        assert_eq!(m.super_rounds, 0);
        assert_eq!(m.overlap_time, 0.0);
        assert_eq!(m.pipelined_rounds, 0);
        assert!((m.sim_time - 12.5).abs() < 1e-12, "clock mirror preserved");
        assert_eq!(m.peak_inflight, 6, "high-water mark preserved");
        assert_eq!(m.max_edge_task, 4096, "high-water mark preserved");
        assert_eq!(m.staging_bytes_peak, 1 << 20, "high-water mark preserved");
    }

    #[test]
    fn fmt_helpers() {
        assert_eq!(fmt_secs(0.0005), "500.0 us");
        assert_eq!(fmt_secs(0.5), "500.00 ms");
        assert_eq!(fmt_secs(2.5), "2.50 s");
        assert_eq!(fmt_pct(0.1234), "12.34%");
    }
}
