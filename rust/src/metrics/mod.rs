//! Metrics: per-query statistics and engine-wide counters, plus the table
//! formatting used by the benchmark harness to print paper-style rows.

use crate::vertex::QueryId;

/// Statistics for one completed query.
#[derive(Debug, Clone, Default)]
pub struct QueryStats {
    pub qid: QueryId,
    /// Supersteps executed (n_q; excludes the reporting super-round).
    pub supersteps: u64,
    /// Messages sent (post-combiner).
    pub messages: u64,
    /// Bytes put on the wire (post-combiner, incl. headers).
    pub bytes: u64,
    /// Distinct vertices that allocated VQ-data (the paper's access count).
    pub touched: u64,
    /// Access rate = touched / |V|.
    pub access_rate: f64,
    /// Simulated cluster time when the request arrived at the serving
    /// front end. Equal to `submitted_at` for direct `Engine::submit`
    /// calls; earlier when a bounded submission queue back-pressured the
    /// request and `Engine::try_submit` re-delivered it later — the wait
    /// outside the queue is real latency the old single timestamp hid.
    pub arrived_at: f64,
    /// Simulated cluster time when the request entered the submission
    /// queue (historically the only pre-admission timestamp, which is why
    /// it conflated arrival with queue entry under back-pressure).
    pub submitted_at: f64,
    /// Simulated cluster time when processing started (left the queue).
    pub started_at: f64,
    /// Simulated cluster time when the result was reported.
    pub finished_at: f64,
    /// True if the query hit the engine's superstep cap.
    pub truncated: bool,
    /// Graph epoch pinned at admission: the version this query read for
    /// its whole lifetime (0 for immutable-graph apps — the loaded base).
    pub epoch: u64,
}

impl QueryStats {
    /// End-to-end simulated latency (arrival → finish: back-pressure wait
    /// + queue wait + processing). Before the serving layer this was
    /// measured from `submitted_at`, which under a bounded queue starts
    /// the clock only once a slot frees up — exactly the delay a latency
    /// metric exists to expose.
    pub fn latency(&self) -> f64 {
        self.finished_at - self.arrived_at
    }

    /// Queueing delay (arrival → admission into the in-flight set).
    pub fn queueing(&self) -> f64 {
        self.started_at - self.arrived_at
    }

    /// Processing-only simulated time.
    pub fn processing(&self) -> f64 {
        self.finished_at - self.started_at
    }
}

/// Streaming percentile sketch for latency-style values: a log₂-bucketed
/// histogram with 32 mantissa sub-buckets per octave, so any quantile is
/// reported with ≤ 1/32 ≈ 3.2% relative error while `record` stays O(1)
/// and allocation-free after the first call (one lazy ~2K-bucket table;
/// a `Default` sketch that never records owns no heap at all).
///
/// The engine feeds it simulated-clock seconds, which are deterministic,
/// so the quantiles themselves are bit-identical across thread counts —
/// that is what lets CI put a strict floor on a p99 headline without
/// runner-noise flakes.
#[derive(Debug, Clone, Default)]
pub struct LatencySketch {
    /// Lazily allocated on first record: `(EXP_MAX - EXP_MIN + 1) * SUB`
    /// counters, octave-major.
    buckets: Vec<u64>,
    count: u64,
    min: f64,
    max: f64,
}

impl LatencySketch {
    /// Mantissa sub-buckets per octave (2^5): the resolution knob.
    const SUB: usize = 32;
    /// Smallest resolved octave, 2⁻³⁰ s ≈ 1 ns — below that everything
    /// lands in bucket 0 (as do zero/negative/NaN inputs).
    const EXP_MIN: i32 = -30;
    /// Largest resolved octave, 2³⁰ s ≈ 34 years of simulated time.
    const EXP_MAX: i32 = 30;
    const NBUCKETS: usize = ((Self::EXP_MAX - Self::EXP_MIN) as usize + 1) * Self::SUB;

    fn index(v: f64) -> usize {
        if v.is_nan() || v <= 0.0 {
            return 0;
        }
        let e = (v.log2().floor() as i32).clamp(Self::EXP_MIN, Self::EXP_MAX);
        let lower = f64::exp2(e as f64);
        // Saturating float→usize casts make the sub-bucket self-clamping
        // at the bottom; the top needs the explicit min for values whose
        // exponent was clamped down.
        let sub = (((v / lower) - 1.0) * Self::SUB as f64) as usize;
        (e - Self::EXP_MIN) as usize * Self::SUB + sub.min(Self::SUB - 1)
    }

    /// Upper edge of bucket `idx` — the value `quantile` reports, so the
    /// sketch never under-states a latency.
    fn upper_edge(idx: usize) -> f64 {
        let e = (idx / Self::SUB) as i32 + Self::EXP_MIN;
        let sub = idx % Self::SUB;
        f64::exp2(e as f64) * (1.0 + (sub + 1) as f64 / Self::SUB as f64)
    }

    /// Fold one observation (seconds) into the sketch.
    pub fn record(&mut self, secs: f64) {
        // NaN pins min/max (and would make the quantile clamp panic);
        // treat it as the same degenerate observation as zero.
        let secs = if secs.is_nan() { 0.0 } else { secs };
        if self.buckets.is_empty() {
            self.buckets = vec![0; Self::NBUCKETS];
        }
        if self.count == 0 {
            self.min = secs;
            self.max = secs;
        } else {
            self.min = self.min.min(secs);
            self.max = self.max.max(secs);
        }
        self.buckets[Self::index(secs)] += 1;
        self.count += 1;
    }

    /// Observations recorded so far.
    pub fn count(&self) -> u64 {
        self.count
    }

    /// The q-quantile (q in [0, 1]): the upper edge of the bucket holding
    /// the ⌈q·count⌉-th smallest observation, clamped into the exact
    /// observed [min, max] range. Returns 0.0 on an empty sketch.
    pub fn quantile(&self, q: f64) -> f64 {
        if self.count == 0 {
            return 0.0;
        }
        if q <= 0.0 {
            return self.min;
        }
        if q >= 1.0 {
            return self.max;
        }
        let rank = ((q * self.count as f64).ceil() as u64).clamp(1, self.count);
        let mut cum = 0u64;
        for (i, &c) in self.buckets.iter().enumerate() {
            cum += c;
            if cum >= rank {
                return Self::upper_edge(i).clamp(self.min, self.max);
            }
        }
        self.max
    }
}

/// Scheduler counters for one engine phase (compute / exchange / fold),
/// accumulated across super-rounds.
#[derive(Debug, Clone, Copy, Default)]
pub struct PhaseSched {
    /// Pool jobs executed. Under `Sched::Stealing` this counts items
    /// (worker lanes / destination workers / queries); under
    /// `Sched::Static` it counts contiguous chunks (≤ threads per round),
    /// so the job-granularity difference between the schedulers is
    /// directly observable here.
    pub jobs_executed: u64,
    /// Jobs executed by a pool thread other than the one whose deque they
    /// were distributed to — each one is a load-balancing event where an
    /// idle thread absorbed a busy thread's queued work.
    pub steals: u64,
}

impl PhaseSched {
    /// Fold one phase dispatch into the counters.
    pub fn add(&mut self, jobs: u64, steals: u64) {
        self.jobs_executed += jobs;
        self.steals += steals;
    }
}

/// Engine-wide counters, accumulated across all super-rounds.
#[derive(Debug, Clone, Default)]
pub struct EngineMetrics {
    pub super_rounds: u64,
    pub total_messages: u64,
    pub total_bytes: u64,
    pub total_compute_calls: u64,
    /// Simulated cluster seconds consumed so far.
    pub sim_time: f64,
    /// Wall-clock seconds spent inside the engine (perf pass metric).
    pub wall_time: f64,
    /// **Busy** seconds of compute-phase work: time spent inside compute
    /// jobs (lane prep, sub-jobs, edge ranges, merges), summed across
    /// pool threads, plus the coordinator's serial compute segments.
    /// Under `Pipeline::Off` no two phases overlap, so the three phase
    /// fields sum to ≈ `wall_time`; under `Pipeline::On` phases run
    /// concurrently, so the busy-sum may exceed `wall_time` (bounded by
    /// `threads × wall_time`) — a wall-segment stopwatch here would
    /// double-count the overlapped spans, which is exactly the bug the
    /// busy accounting replaces.
    pub compute_time: f64,
    /// **Busy** seconds of exchange work: inbox delivery of staged
    /// columns (the pooled destination drains, the pipelined eager
    /// column applications, and the serial map handoff around them).
    pub exchange_time: f64,
    /// **Busy** seconds of the remaining barrier work: the per-query
    /// aggregator fold + lifecycle, the simulated-clock advance and the
    /// reporting round (including reporting jobs overlapped onto the
    /// next round's compute under `Pipeline::On`).
    pub barrier_time: f64,
    /// Wall seconds during which ≥2 phases were *simultaneously* active,
    /// summed over pipelined super-rounds. Always 0 under
    /// `Pipeline::Off`; under `Pipeline::On` this is the overlap the
    /// pipeline bought (and the reason the phase fields are busy time:
    /// wall-segment timers cannot attribute these spans to one phase).
    pub overlap_time: f64,
    /// Super-rounds that ran the pipelined (ready-driven) path rather
    /// than the barrier path. Zero under `Pipeline::Off`; under
    /// `Pipeline::On` rounds may still fall back to the barrier path
    /// (serial engines, split-armed rounds), so tests read this to prove
    /// the pipeline actually engaged.
    pub pipelined_rounds: u64,
    /// Queries completed (result reported). Accounted when the reporting
    /// round runs, so it never depends on the caller draining
    /// `take_results` — interactive `run_one` sessions and batch sessions
    /// count identically.
    pub queries_completed: u64,
    /// Peak number of simultaneously in-flight queries.
    pub peak_inflight: usize,
    /// Compute-phase scheduler counters. Jobs count every compute
    /// dispatch: the per-lane prep jobs, plus — in rounds where either
    /// split engaged — the vertex-range sub-jobs, the edge-range jobs of
    /// parked mega-fanouts, and the merge jobs (per-lane control folds
    /// and per-(task, destination worker) staging-column replays).
    pub compute_sched: PhaseSched,
    /// Exchange-phase scheduler counters (jobs = destination workers).
    pub exchange_sched: PhaseSched,
    /// Fold-phase scheduler counters (jobs = in-flight queries).
    pub fold_sched: PhaseSched,
    /// Compute sub-jobs executed by the sub-lane split: pool jobs that ran
    /// one contiguous sub-range of a split task against private staging.
    /// Zero means the split never engaged (balanced partitions,
    /// `Split::Off`, or the static baseline).
    pub subjobs_executed: u64,
    /// (query, worker) compute tasks the split policy cut into sub-ranges.
    pub tasks_split: u64,
    /// Edge-range jobs executed by the edge-level split: pool jobs that
    /// staged one contiguous range of a parked mega-fanout into a private
    /// insertion-ordered buffer. Zero means no compute call ever crossed
    /// the edge-split threshold (or `EdgeSplit::Off` / the static
    /// baseline / a serial engine).
    pub edge_ranges_split: u64,
    /// Largest single-vertex compute fanout seen: the `ctx.send` count of
    /// the heaviest `compute()` call across every super-round. Read next
    /// to `edge_ranges_split` to see whether a workload's mega-hubs were
    /// big enough to engage the edge split.
    pub max_edge_task: u64,
    /// Worst compute-phase lane imbalance seen: max lane cost over mean
    /// lane cost (simulated cost model, so deterministic) of the most
    /// skewed super-round. ~1.0 = balanced partition; `workers` = one lane
    /// carried the whole phase. This is the skew the stealing scheduler
    /// absorbs — read it next to `compute_sched.steals` to see whether a
    /// workload's imbalance actually engaged the steal path.
    pub max_lane_imbalance: f64,
    /// Same normalization as `max_lane_imbalance`, but over the largest
    /// *schedulable unit* after sub-lane splitting (a prep job's serial
    /// share, or one sub-job) instead of whole lanes. With splitting off
    /// the two coincide; with splitting on, the gap between them is the
    /// serialization the sub-jobs broke up — a pathological lane that
    /// reads 8× on `max_lane_imbalance` but ~1× here was fully absorbed.
    pub max_post_split_imbalance: f64,
    /// High-water mark of bytes retained by flat staging buffers across
    /// all in-flight shards, sampled at the end of each super-round (after
    /// the exchange drained them, before the capped recycler trimmed
    /// them). Always 0 under `Layout::Hashed` — tests read this to prove
    /// the flat layout actually engaged. Like the other high-water marks
    /// it is an engine-lifetime field preserved by [`EngineMetrics::reset`].
    pub staging_bytes_peak: u64,
    /// Streaming sketch of end-to-end query latency (arrival → reporting,
    /// [`QueryStats::latency`]), fed once per completed query. Simulated
    /// seconds, so p50/p99/p999 read off it are deterministic.
    pub latency: LatencySketch,
    /// Streaming sketch of queueing delay (arrival → admission,
    /// [`QueryStats::queueing`]), fed once per completed query.
    pub queueing: LatencySketch,
    /// Heavy-flagged queries the adaptive admission planner held back
    /// while light queries behind them were admitted (one count per
    /// skip event, so a whale deferred for three rounds counts three
    /// times). Zero under `Admit::Static` — tests and the serving bench
    /// read this to prove the planner actually engaged.
    pub admit_deferrals: u64,
    /// Mutation batches applied (one epoch bump each). Zero for
    /// immutable-graph apps — tests and the versioned bench read this to
    /// prove the delta-overlay path actually engaged. Engine-lifetime
    /// (epochs never rewind), preserved by [`EngineMetrics::reset`].
    pub epochs_applied: u64,
    /// Oldest epoch still pinned by an in-flight query (equals the
    /// current epoch when nothing is in flight — everything older has
    /// retired and the overlay may compact). Engine-lifetime, preserved
    /// by [`EngineMetrics::reset`].
    pub oldest_pinned_epoch: u64,
    /// High-water mark of the delta-overlay footprint in bytes, sampled
    /// right after each mutation batch applies (before any compaction).
    /// Zero when no mutation ever landed — the fuzzer's engagement
    /// signal for the overlay path. Engine-lifetime, preserved by
    /// [`EngineMetrics::reset`].
    pub delta_bytes_peak: u64,
    /// Total framed bytes the multi-process coordinator put on (and read
    /// off) its worker sockets: payload plus the 4-byte length prefix, per
    /// frame, both directions. Exactly zero when everything runs in one
    /// process — the engagement signal the bench validator gates on.
    pub bytes_on_wire: u64,
    /// Request/reply pairs the multi-process coordinator exchanged with
    /// worker processes (counted per worker: a round that asks 2 workers
    /// to compute is 2 round trips). Zero in-process.
    pub rpc_round_trips: u64,
}

impl EngineMetrics {
    /// Stolen jobs across all three phases.
    pub fn steals(&self) -> u64 {
        self.compute_sched.steals + self.exchange_sched.steals + self.fold_sched.steals
    }

    /// Pool jobs executed across all three phases.
    pub fn jobs_executed(&self) -> u64 {
        self.compute_sched.jobs_executed
            + self.exchange_sched.jobs_executed
            + self.fold_sched.jobs_executed
    }

    /// Zero the **per-session** counters, so per-session accounting is
    /// possible on a long-lived engine. Scheduler counters
    /// (`jobs_executed`, `steals`) and the split counters are per-batch
    /// values that only ever accumulate — without a reset between
    /// sessions (e.g. two `run_one` calls), the second session reads the
    /// first one's totals too.
    ///
    /// **Engine-lifetime fields are preserved**: `sim_time` mirrors the
    /// engine's monotone simulated clock (wiping it here used to leave a
    /// stale zero until the next super-round re-synced it — visible to
    /// any direct `metrics.reset()` caller bypassing
    /// `Engine::reset_metrics`), and `peak_inflight` / `max_edge_task`
    /// are high-water marks over the engine's whole life that a
    /// per-session wipe would permanently lose.
    pub fn reset(&mut self) {
        let sim_time = self.sim_time;
        let peak_inflight = self.peak_inflight;
        let max_edge_task = self.max_edge_task;
        let staging_bytes_peak = self.staging_bytes_peak;
        let epochs_applied = self.epochs_applied;
        let oldest_pinned_epoch = self.oldest_pinned_epoch;
        let delta_bytes_peak = self.delta_bytes_peak;
        *self = EngineMetrics {
            sim_time,
            peak_inflight,
            max_edge_task,
            staging_bytes_peak,
            epochs_applied,
            oldest_pinned_epoch,
            delta_bytes_peak,
            ..EngineMetrics::default()
        };
    }
}

/// Fixed-width table printer for bench output (we have no external
/// table/serde crates offline; benches print paper-shaped rows).
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new<S: Into<String>>(headers: Vec<S>) -> Self {
        Self {
            headers: headers.into_iter().map(Into::into).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row<S: Into<String>>(&mut self, cells: Vec<S>) {
        let cells: Vec<String> = cells.into_iter().map(Into::into).collect();
        assert_eq!(cells.len(), self.headers.len(), "row arity mismatch");
        self.rows.push(cells);
    }

    /// Render with padded columns.
    pub fn render(&self) -> String {
        let ncol = self.headers.len();
        let mut widths = vec![0usize; ncol];
        for (i, h) in self.headers.iter().enumerate() {
            widths[i] = widths[i].max(h.len());
        }
        for r in &self.rows {
            for (i, c) in r.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        let fmt_row = |cells: &[String], widths: &[usize]| -> String {
            let mut s = String::from("| ");
            for (c, w) in cells.iter().zip(widths) {
                s.push_str(&format!("{c:<w$} | ", w = w));
            }
            s.trim_end().to_string()
        };
        out.push_str(&fmt_row(&self.headers, &widths));
        out.push('\n');
        out.push_str(&format!(
            "|{}|",
            widths
                .iter()
                .map(|w| "-".repeat(w + 2))
                .collect::<Vec<_>>()
                .join("|")
        ));
        out.push('\n');
        for r in &self.rows {
            out.push_str(&fmt_row(r, &widths));
            out.push('\n');
        }
        out
    }
}

/// Human-readable seconds with sensible precision.
pub fn fmt_secs(s: f64) -> String {
    if s >= 100.0 {
        format!("{s:.0} s")
    } else if s >= 1.0 {
        format!("{s:.2} s")
    } else if s >= 1e-3 {
        format!("{:.2} ms", s * 1e3)
    } else {
        format!("{:.1} us", s * 1e6)
    }
}

/// Percentage with two significant digits.
pub fn fmt_pct(x: f64) -> String {
    format!("{:.2}%", x * 100.0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn latency_decomposition() {
        let s = QueryStats {
            arrived_at: 0.5,
            submitted_at: 1.0,
            started_at: 2.0,
            finished_at: 5.0,
            ..Default::default()
        };
        assert!((s.latency() - 4.5).abs() < 1e-12);
        assert!((s.queueing() - 1.5).abs() < 1e-12);
        assert!((s.processing() - 3.0).abs() < 1e-12);
    }

    /// Regression for the serving-layer bugfix: when a bounded queue
    /// back-pressures a request, `arrived_at` < `submitted_at`, and the
    /// end-to-end latency must cover the wait *outside* the queue too —
    /// the old `finished_at - submitted_at` definition hid it.
    #[test]
    fn latency_covers_backpressure_wait_before_queue_entry() {
        let s = QueryStats {
            arrived_at: 0.0,
            submitted_at: 3.0, // sat out 3 s of back-pressure first
            started_at: 4.0,
            finished_at: 6.0,
            ..Default::default()
        };
        assert!((s.latency() - 6.0).abs() < 1e-12);
        assert!((s.queueing() - 4.0).abs() < 1e-12);
        assert!(s.latency() > s.finished_at - s.submitted_at);
    }

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new(vec!["a", "bb"]);
        t.row(vec!["x", "y"]);
        t.row(vec!["long", "z"]);
        let r = t.render();
        assert!(r.contains("| a    | bb |"));
        assert!(r.lines().count() == 4);
    }

    #[test]
    #[should_panic]
    fn table_rejects_bad_arity() {
        let mut t = Table::new(vec!["a"]);
        t.row(vec!["x", "y"]);
    }

    #[test]
    fn phase_sched_counters_accumulate_and_total() {
        let mut m = EngineMetrics::default();
        m.compute_sched.add(8, 2);
        m.compute_sched.add(8, 0);
        m.exchange_sched.add(8, 1);
        m.fold_sched.add(3, 0);
        assert_eq!(m.compute_sched.jobs_executed, 16);
        assert_eq!(m.compute_sched.steals, 2);
        assert_eq!(m.jobs_executed(), 27);
        assert_eq!(m.steals(), 3);
    }

    #[test]
    fn reset_zeroes_session_counters_and_keeps_lifetime_fields() {
        let mut m = EngineMetrics::default();
        m.compute_sched.add(8, 2);
        m.subjobs_executed = 5;
        m.tasks_split = 2;
        m.edge_ranges_split = 11;
        m.max_lane_imbalance = 7.5;
        m.max_post_split_imbalance = 1.2;
        m.queries_completed = 3;
        m.super_rounds = 9;
        m.overlap_time = 0.25;
        m.pipelined_rounds = 4;
        m.latency.record(0.5);
        m.queueing.record(0.1);
        m.admit_deferrals = 7;
        // Engine-lifetime fields: survive a bare reset().
        m.sim_time = 12.5;
        m.peak_inflight = 6;
        m.max_edge_task = 4096;
        m.staging_bytes_peak = 1 << 20;
        m.epochs_applied = 3;
        m.oldest_pinned_epoch = 2;
        m.delta_bytes_peak = 512;
        m.reset();
        assert_eq!(m.steals(), 0);
        assert_eq!(m.jobs_executed(), 0);
        assert_eq!(m.subjobs_executed, 0);
        assert_eq!(m.tasks_split, 0);
        assert_eq!(m.edge_ranges_split, 0);
        assert_eq!(m.max_lane_imbalance, 0.0);
        assert_eq!(m.max_post_split_imbalance, 0.0);
        assert_eq!(m.queries_completed, 0);
        assert_eq!(m.super_rounds, 0);
        assert_eq!(m.overlap_time, 0.0);
        assert_eq!(m.pipelined_rounds, 0);
        assert_eq!(m.latency.count(), 0, "latency sketch is per-session");
        assert_eq!(m.queueing.count(), 0, "queueing sketch is per-session");
        assert_eq!(m.admit_deferrals, 0);
        assert!((m.sim_time - 12.5).abs() < 1e-12, "clock mirror preserved");
        assert_eq!(m.peak_inflight, 6, "high-water mark preserved");
        assert_eq!(m.max_edge_task, 4096, "high-water mark preserved");
        assert_eq!(m.staging_bytes_peak, 1 << 20, "high-water mark preserved");
        assert_eq!(m.epochs_applied, 3, "epoch gauge preserved");
        assert_eq!(m.oldest_pinned_epoch, 2, "epoch gauge preserved");
        assert_eq!(m.delta_bytes_peak, 512, "high-water mark preserved");
    }

    #[test]
    fn sketch_empty_single_and_extreme_inputs() {
        let mut s = LatencySketch::default();
        assert_eq!(s.count(), 0);
        assert_eq!(s.quantile(0.5), 0.0, "empty sketch reports 0");
        s.record(0.25);
        assert_eq!(s.count(), 1);
        for q in [0.0, 0.5, 0.99, 1.0] {
            assert_eq!(s.quantile(q), 0.25, "single sample: every quantile is it");
        }
        // Out-of-range inputs must not panic or poison the quantiles:
        // sub-ns and non-positive values land in bucket 0, huge ones in
        // the top octave.
        let mut s = LatencySketch::default();
        s.record(0.0);
        s.record(-1.0);
        s.record(f64::NAN);
        s.record(1e-12);
        s.record(1e12);
        assert_eq!(s.count(), 5);
        assert!(s.quantile(0.5).is_finite());
        assert!(s.quantile(1.0) >= 1e12 - 1.0);
    }

    /// The sketch against an exact sort oracle: for every rank, the
    /// reported quantile must bracket the exact order statistic from
    /// above by at most one bucket width (33/32 ≈ 3.2% relative).
    #[test]
    fn sketch_matches_exact_sort_oracle_within_bucket_error() {
        // Hand-rolled LCG (no RNG dep in this module): values spanning
        // ~7 decades with a dense mantissa, the shape of a latency
        // distribution with a long tail.
        let mut state: u64 = 0x9E37_79B9_7F4A_7C15;
        let mut next = move || {
            state = state
                .wrapping_mul(6364136223846793005)
                .wrapping_add(1442695040888963407);
            state >> 33
        };
        let mut sketch = LatencySketch::default();
        let mut exact: Vec<f64> = Vec::new();
        for _ in 0..10_000 {
            let mantissa = 1.0 + (next() % 1_000_000) as f64 / 1_000_000.0;
            let octave = (next() % 24) as i32 - 12; // 2⁻¹² .. 2¹² s
            let v = mantissa * f64::exp2(octave as f64);
            sketch.record(v);
            exact.push(v);
        }
        exact.sort_by(f64::total_cmp);
        for q in [0.01, 0.1, 0.25, 0.5, 0.9, 0.95, 0.99, 0.999, 0.9999] {
            let rank = ((q * exact.len() as f64).ceil() as usize).clamp(1, exact.len());
            let want = exact[rank - 1];
            let got = sketch.quantile(q);
            assert!(
                got >= want - 1e-12,
                "q={q}: sketch {got} under-states exact {want}"
            );
            assert!(
                got <= want * (33.0 / 32.0) + 1e-12,
                "q={q}: sketch {got} beyond one bucket above exact {want}"
            );
        }
        // Quantiles are monotone in q, and the endpoints are exact.
        assert_eq!(sketch.quantile(0.0), exact[0]);
        assert_eq!(sketch.quantile(1.0), exact[exact.len() - 1]);
        let (p50, p99, p999) = (
            sketch.quantile(0.5),
            sketch.quantile(0.99),
            sketch.quantile(0.999),
        );
        assert!(p50 <= p99 && p99 <= p999);
    }

    #[test]
    fn fmt_helpers() {
        assert_eq!(fmt_secs(0.0005), "500.0 us");
        assert_eq!(fmt_secs(0.5), "500.00 ms");
        assert_eq!(fmt_secs(2.5), "2.50 s");
        assert_eq!(fmt_pct(0.1234), "12.34%");
    }
}
