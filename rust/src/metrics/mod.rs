//! Metrics: per-query statistics and engine-wide counters, plus the table
//! formatting used by the benchmark harness to print paper-style rows.

use crate::vertex::QueryId;

/// Statistics for one completed query.
#[derive(Debug, Clone, Default)]
pub struct QueryStats {
    pub qid: QueryId,
    /// Supersteps executed (n_q; excludes the reporting super-round).
    pub supersteps: u64,
    /// Messages sent (post-combiner).
    pub messages: u64,
    /// Bytes put on the wire (post-combiner, incl. headers).
    pub bytes: u64,
    /// Distinct vertices that allocated VQ-data (the paper's access count).
    pub touched: u64,
    /// Access rate = touched / |V|.
    pub access_rate: f64,
    /// Simulated cluster time at submission.
    pub submitted_at: f64,
    /// Simulated cluster time when processing started (left the queue).
    pub started_at: f64,
    /// Simulated cluster time when the result was reported.
    pub finished_at: f64,
    /// True if the query hit the engine's superstep cap.
    pub truncated: bool,
}

impl QueryStats {
    /// End-to-end simulated latency (queue wait + processing).
    pub fn latency(&self) -> f64 {
        self.finished_at - self.submitted_at
    }

    /// Processing-only simulated time.
    pub fn processing(&self) -> f64 {
        self.finished_at - self.started_at
    }
}

/// Engine-wide counters, accumulated across all super-rounds.
#[derive(Debug, Clone, Default)]
pub struct EngineMetrics {
    pub super_rounds: u64,
    pub total_messages: u64,
    pub total_bytes: u64,
    pub total_compute_calls: u64,
    /// Simulated cluster seconds consumed so far.
    pub sim_time: f64,
    /// Wall-clock seconds spent inside the engine (perf pass metric).
    pub wall_time: f64,
    /// Wall-clock seconds spent in the (possibly pooled) compute phase.
    pub compute_time: f64,
    /// Wall-clock seconds spent in the exchange phase: destination-sharded
    /// message routing between worker shards, parallel across destination
    /// workers on the pool (includes the serial map handoff around it).
    pub exchange_time: f64,
    /// Wall-clock seconds spent in the remaining barrier work: the
    /// per-query aggregator fold + lifecycle (parallel across queries),
    /// the simulated-clock advance and the reporting round.
    pub barrier_time: f64,
    /// Queries completed (result reported). Accounted when the reporting
    /// round runs, so it never depends on the caller draining
    /// `take_results` — interactive `run_one` sessions and batch sessions
    /// count identically.
    pub queries_completed: u64,
    /// Peak number of simultaneously in-flight queries.
    pub peak_inflight: usize,
}

/// Fixed-width table printer for bench output (we have no external
/// table/serde crates offline; benches print paper-shaped rows).
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new<S: Into<String>>(headers: Vec<S>) -> Self {
        Self {
            headers: headers.into_iter().map(Into::into).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row<S: Into<String>>(&mut self, cells: Vec<S>) {
        let cells: Vec<String> = cells.into_iter().map(Into::into).collect();
        assert_eq!(cells.len(), self.headers.len(), "row arity mismatch");
        self.rows.push(cells);
    }

    /// Render with padded columns.
    pub fn render(&self) -> String {
        let ncol = self.headers.len();
        let mut widths = vec![0usize; ncol];
        for (i, h) in self.headers.iter().enumerate() {
            widths[i] = widths[i].max(h.len());
        }
        for r in &self.rows {
            for (i, c) in r.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        let fmt_row = |cells: &[String], widths: &[usize]| -> String {
            let mut s = String::from("| ");
            for (c, w) in cells.iter().zip(widths) {
                s.push_str(&format!("{c:<w$} | ", w = w));
            }
            s.trim_end().to_string()
        };
        out.push_str(&fmt_row(&self.headers, &widths));
        out.push('\n');
        out.push_str(&format!(
            "|{}|",
            widths
                .iter()
                .map(|w| "-".repeat(w + 2))
                .collect::<Vec<_>>()
                .join("|")
        ));
        out.push('\n');
        for r in &self.rows {
            out.push_str(&fmt_row(r, &widths));
            out.push('\n');
        }
        out
    }
}

/// Human-readable seconds with sensible precision.
pub fn fmt_secs(s: f64) -> String {
    if s >= 100.0 {
        format!("{s:.0} s")
    } else if s >= 1.0 {
        format!("{s:.2} s")
    } else if s >= 1e-3 {
        format!("{:.2} ms", s * 1e3)
    } else {
        format!("{:.1} us", s * 1e6)
    }
}

/// Percentage with two significant digits.
pub fn fmt_pct(x: f64) -> String {
    format!("{:.2}%", x * 100.0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn latency_decomposition() {
        let s = QueryStats {
            submitted_at: 1.0,
            started_at: 2.0,
            finished_at: 5.0,
            ..Default::default()
        };
        assert!((s.latency() - 4.0).abs() < 1e-12);
        assert!((s.processing() - 3.0).abs() < 1e-12);
    }

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new(vec!["a", "bb"]);
        t.row(vec!["x", "y"]);
        t.row(vec!["long", "z"]);
        let r = t.render();
        assert!(r.contains("| a    | bb |"));
        assert!(r.lines().count() == 4);
    }

    #[test]
    #[should_panic]
    fn table_rejects_bad_arity() {
        let mut t = Table::new(vec!["a"]);
        t.row(vec!["x", "y"]);
    }

    #[test]
    fn fmt_helpers() {
        assert_eq!(fmt_secs(0.0005), "500.0 us");
        assert_eq!(fmt_secs(0.5), "500.00 ms");
        assert_eq!(fmt_secs(2.5), "2.50 s");
        assert_eq!(fmt_pct(0.1234), "12.34%");
    }
}
