//! Graph substrate: CSR storage with optional in-edges and edge weights,
//! plus loaders, synthetic dataset generators, and epoch-versioned delta
//! overlays for streaming mutations (`versioned`).

pub mod gen;
pub mod io;
pub mod versioned;

pub use versioned::{Epoch, Mutation, MutationApplied, MutationBatch, VersionedGraph};

use crate::util::FxHashMap;

/// Vertex identifier. 32 bits covers every dataset in the evaluation.
pub type VertexId = u32;

/// Direction selector for traversals.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Dir {
    Out,
    In,
}

/// Compressed-sparse-row digraph. Undirected graphs store each edge in both
/// directions. In-adjacency is materialized lazily (`ensure_in_edges`) since
/// only bidirectional algorithms need it (mirrors the paper's observation
/// that BiBFS loading costs more because Γ_in must be built).
#[derive(Debug, Clone, Default)]
pub struct Graph {
    out_offsets: Vec<u64>,
    out_edges: Vec<VertexId>,
    /// Edge weights parallel to `out_edges`; empty means unweighted.
    out_weights: Vec<f32>,
    in_offsets: Vec<u64>,
    in_edges: Vec<VertexId>,
    in_weights: Vec<f32>,
}

impl Graph {
    /// Number of vertices.
    pub fn num_vertices(&self) -> usize {
        self.out_offsets.len().saturating_sub(1)
    }

    /// Number of directed edges (undirected graphs count both arcs).
    pub fn num_edges(&self) -> usize {
        self.out_edges.len()
    }

    /// True if edge weights are stored.
    pub fn weighted(&self) -> bool {
        !self.out_weights.is_empty()
    }

    /// Out-neighbors of `v`.
    #[inline]
    pub fn out(&self, v: VertexId) -> &[VertexId] {
        let (a, b) = (
            self.out_offsets[v as usize] as usize,
            self.out_offsets[v as usize + 1] as usize,
        );
        &self.out_edges[a..b]
    }

    /// Out-neighbor weights of `v` (parallel to `out(v)`).
    #[inline]
    pub fn out_w(&self, v: VertexId) -> &[f32] {
        let (a, b) = (
            self.out_offsets[v as usize] as usize,
            self.out_offsets[v as usize + 1] as usize,
        );
        &self.out_weights[a..b]
    }

    /// In-neighbors of `v`.
    ///
    /// **Contract**: in-adjacency is lazily materialized, so every
    /// in-direction accessor (`inn`, [`Graph::in_w`],
    /// [`Graph::in_degree`], `neighbors(_, Dir::In)`) requires
    /// [`Graph::ensure_in_edges`] to have run first — the loading-phase
    /// step the paper bills to BiBFS-style algorithms (Γ_in costs extra).
    /// Debug builds assert this and name the fix; release builds panic on
    /// the out-of-bounds offset lookup (`in_offsets` is empty), which is
    /// memory-safe but unexplained — callers should gate on
    /// [`Graph::has_in_edges`] when direction use is data-dependent.
    #[inline]
    pub fn inn(&self, v: VertexId) -> &[VertexId] {
        debug_assert!(
            !self.in_offsets.is_empty(),
            "call ensure_in_edges() before inn()"
        );
        let (a, b) = (
            self.in_offsets[v as usize] as usize,
            self.in_offsets[v as usize + 1] as usize,
        );
        &self.in_edges[a..b]
    }

    /// In-neighbor weights of `v` (parallel to `inn(v)`). Same contract
    /// as [`Graph::inn`]: requires [`Graph::ensure_in_edges`] first.
    #[inline]
    pub fn in_w(&self, v: VertexId) -> &[f32] {
        debug_assert!(
            !self.in_offsets.is_empty(),
            "call ensure_in_edges() before in_w()"
        );
        let (a, b) = (
            self.in_offsets[v as usize] as usize,
            self.in_offsets[v as usize + 1] as usize,
        );
        &self.in_weights[a..b]
    }

    /// Neighbors in the given direction.
    #[inline]
    pub fn neighbors(&self, v: VertexId, dir: Dir) -> &[VertexId] {
        match dir {
            Dir::Out => self.out(v),
            Dir::In => self.inn(v),
        }
    }

    /// Out-degree of `v`.
    #[inline]
    pub fn out_degree(&self, v: VertexId) -> usize {
        self.out(v).len()
    }

    /// In-degree of `v` (requires in-edges).
    #[inline]
    pub fn in_degree(&self, v: VertexId) -> usize {
        self.inn(v).len()
    }

    /// True if in-adjacency has been materialized.
    pub fn has_in_edges(&self) -> bool {
        !self.in_offsets.is_empty()
    }

    /// Materialize in-adjacency by transposing the out-CSR.
    pub fn ensure_in_edges(&mut self) {
        if self.has_in_edges() {
            return;
        }
        let n = self.num_vertices();
        let mut degs = vec![0u64; n + 1];
        for &d in &self.out_edges {
            degs[d as usize + 1] += 1;
        }
        for i in 0..n {
            degs[i + 1] += degs[i];
        }
        let mut edges = vec![0 as VertexId; self.out_edges.len()];
        let mut weights = if self.weighted() {
            vec![0f32; self.out_edges.len()]
        } else {
            Vec::new()
        };
        let mut cursor = degs.clone();
        for u in 0..n {
            let (a, b) = (
                self.out_offsets[u] as usize,
                self.out_offsets[u + 1] as usize,
            );
            for idx in a..b {
                let v = self.out_edges[idx] as usize;
                let at = cursor[v] as usize;
                edges[at] = u as VertexId;
                if self.weighted() {
                    weights[at] = self.out_weights[idx];
                }
                cursor[v] += 1;
            }
        }
        self.in_offsets = degs;
        self.in_edges = edges;
        self.in_weights = weights;
    }

    /// Maximum out-degree (paper Table 1 reports max degree).
    pub fn max_degree(&self) -> usize {
        (0..self.num_vertices() as VertexId)
            .map(|v| self.out_degree(v))
            .max()
            .unwrap_or(0)
    }

    /// Average out-degree.
    pub fn avg_degree(&self) -> f64 {
        if self.num_vertices() == 0 {
            return 0.0;
        }
        self.num_edges() as f64 / self.num_vertices() as f64
    }

    /// Total in-memory footprint estimate in bytes (for load-cost modeling).
    pub fn footprint_bytes(&self) -> usize {
        self.out_offsets.len() * 8
            + self.out_edges.len() * 4
            + self.out_weights.len() * 4
            + self.in_offsets.len() * 8
            + self.in_edges.len() * 4
            + self.in_weights.len() * 4
    }
}

/// Incremental builder accepting unsorted edges, with optional dedup.
#[derive(Debug, Default)]
pub struct GraphBuilder {
    n: usize,
    edges: Vec<(VertexId, VertexId)>,
    weights: Vec<f32>,
    weighted: bool,
    undirected: bool,
}

impl GraphBuilder {
    /// Builder for a graph with `n` vertices.
    pub fn new(n: usize) -> Self {
        Self {
            n,
            ..Default::default()
        }
    }

    /// Treat every added edge as undirected (stores both arcs).
    pub fn undirected(mut self) -> Self {
        self.undirected = true;
        self
    }

    /// Add an unweighted edge.
    pub fn edge(&mut self, u: VertexId, v: VertexId) {
        debug_assert!(!self.weighted);
        self.edges.push((u, v));
        if self.undirected && u != v {
            self.edges.push((v, u));
        }
    }

    /// Add a weighted edge.
    pub fn wedge(&mut self, u: VertexId, v: VertexId, w: f32) {
        self.weighted = true;
        self.edges.push((u, v));
        self.weights.push(w);
        if self.undirected && u != v {
            self.edges.push((v, u));
            self.weights.push(w);
        }
    }

    /// Number of vertices declared.
    pub fn num_vertices(&self) -> usize {
        self.n
    }

    /// Finalize into CSR form. Duplicate parallel edges are retained (they
    /// are harmless for BFS-style algorithms and the generators avoid them).
    pub fn build(self) -> Graph {
        let n = self.n;
        let mut offsets = vec![0u64; n + 1];
        for &(u, _) in &self.edges {
            offsets[u as usize + 1] += 1;
        }
        for i in 0..n {
            offsets[i + 1] += offsets[i];
        }
        let m = self.edges.len();
        let mut out_edges = vec![0 as VertexId; m];
        let mut out_weights = if self.weighted { vec![0f32; m] } else { Vec::new() };
        let mut cursor = offsets.clone();
        for (i, &(u, v)) in self.edges.iter().enumerate() {
            let at = cursor[u as usize] as usize;
            out_edges[at] = v;
            if self.weighted {
                out_weights[at] = self.weights[i];
            }
            cursor[u as usize] += 1;
        }
        Graph {
            out_offsets: offsets,
            out_edges,
            out_weights,
            in_offsets: Vec::new(),
            in_edges: Vec::new(),
            in_weights: Vec::new(),
        }
    }
}

/// Map external string ids to dense `VertexId`s (for text loaders).
#[derive(Debug, Default)]
pub struct IdMap {
    map: FxHashMap<String, VertexId>,
    names: Vec<String>,
}

impl IdMap {
    /// Intern `name`, returning its dense id.
    pub fn intern(&mut self, name: &str) -> VertexId {
        if let Some(&id) = self.map.get(name) {
            return id;
        }
        let id = self.names.len() as VertexId;
        self.map.insert(name.to_string(), id);
        self.names.push(name.to_string());
        id
    }

    /// Look up an already-interned name.
    pub fn get(&self, name: &str) -> Option<VertexId> {
        self.map.get(name).copied()
    }

    /// Reverse lookup.
    pub fn name(&self, id: VertexId) -> &str {
        &self.names[id as usize]
    }

    /// Number of interned ids.
    pub fn len(&self) -> usize {
        self.names.len()
    }

    /// True if nothing has been interned.
    pub fn is_empty(&self) -> bool {
        self.names.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn diamond() -> Graph {
        // 0 -> 1, 0 -> 2, 1 -> 3, 2 -> 3
        let mut b = GraphBuilder::new(4);
        b.edge(0, 1);
        b.edge(0, 2);
        b.edge(1, 3);
        b.edge(2, 3);
        b.build()
    }

    #[test]
    fn csr_basic() {
        let g = diamond();
        assert_eq!(g.num_vertices(), 4);
        assert_eq!(g.num_edges(), 4);
        assert_eq!(g.out(0), &[1, 2]);
        assert_eq!(g.out(3), &[] as &[VertexId]);
        assert_eq!(g.max_degree(), 2);
        assert!((g.avg_degree() - 1.0).abs() < 1e-9);
    }

    #[test]
    fn transpose() {
        let mut g = diamond();
        g.ensure_in_edges();
        assert_eq!(g.inn(3), &[1, 2]);
        assert_eq!(g.inn(0), &[] as &[VertexId]);
        assert_eq!(g.in_degree(3), 2);
    }

    #[test]
    fn undirected_doubles_arcs() {
        let mut b = GraphBuilder::new(3).undirected();
        b.edge(0, 1);
        b.edge(1, 2);
        let g = b.build();
        assert_eq!(g.num_edges(), 4);
        assert_eq!(g.out(1), &[0, 2]);
    }

    #[test]
    fn weighted_edges() {
        let mut b = GraphBuilder::new(3);
        b.wedge(0, 1, 2.5);
        b.wedge(0, 2, 1.5);
        let mut g = b.build();
        assert!(g.weighted());
        assert_eq!(g.out_w(0), &[2.5, 1.5]);
        g.ensure_in_edges();
        assert_eq!(g.in_w(1), &[2.5]);
    }

    #[test]
    #[cfg(debug_assertions)]
    #[should_panic(expected = "ensure_in_edges")]
    fn inn_asserts_in_edges_materialized() {
        let g = diamond();
        let _ = g.inn(0);
    }

    #[test]
    #[cfg(debug_assertions)]
    #[should_panic(expected = "ensure_in_edges")]
    fn in_w_asserts_in_edges_materialized() {
        let mut b = GraphBuilder::new(2);
        b.wedge(0, 1, 1.0);
        let g = b.build();
        let _ = g.in_w(1);
    }

    /// Loading-path regression for the BiBFS family: after
    /// `ensure_in_edges`, the in-CSR must be the exact transpose of the
    /// out-CSR on a scale-free generator graph (`u ∈ inn(v)` iff
    /// `v ∈ out(u)`, multiplicity included) — the invariant every
    /// backward wavefront (BiBFS, the Hub² backward indexing pass)
    /// silently depends on.
    #[test]
    fn in_csr_is_exact_transpose_on_generator_graph() {
        let mut g = gen::twitter_like(300, 5, 11);
        g.ensure_in_edges();
        let n = g.num_vertices() as VertexId;
        let mut fwd: Vec<(VertexId, VertexId)> = Vec::new();
        let mut bwd: Vec<(VertexId, VertexId)> = Vec::new();
        for u in 0..n {
            for &v in g.out(u) {
                fwd.push((u, v));
            }
            for &w in g.inn(u) {
                bwd.push((w, u));
            }
        }
        fwd.sort_unstable();
        bwd.sort_unstable();
        assert_eq!(fwd, bwd, "in-CSR is not the transpose of out-CSR");
        let in_count: usize = (0..n).map(|v| g.in_degree(v)).sum();
        assert_eq!(in_count, g.num_edges());
    }

    #[test]
    fn idmap_roundtrip() {
        let mut m = IdMap::default();
        let a = m.intern("alice");
        let b = m.intern("bob");
        assert_eq!(m.intern("alice"), a);
        assert_ne!(a, b);
        assert_eq!(m.name(b), "bob");
        assert_eq!(m.len(), 2);
    }
}
