//! Streaming graph mutations under an epoch/snapshot scheme.
//!
//! The base [`Graph`] stays an immutable CSR; a [`VersionedGraph`] layers
//! per-vertex **delta overlays** on top of it, stamped with the epoch at
//! which each change became visible. Every applied [`MutationBatch`] bumps
//! the epoch by one, so the overlay encodes *every* version between the
//! compacted base and the current epoch at once:
//!
//! - a base arc is visible at epoch `e` unless a tombstone with
//!   `deleted_at <= e` covers it;
//! - a delta arc is visible at `e` iff `added_at <= e < deleted_at`;
//! - added vertices extend the id space (`num_vertices_at` is monotone in
//!   `e`); deleted vertices **keep their id slot** (id stability for
//!   handle tables) with every incident arc tombstoned, so they simply
//!   read as isolated from their deletion epoch on.
//!
//! Readers pick an epoch once ([`VersionedGraph::out_at`],
//! [`VersionedGraph::in_at`]) and observe a consistent version for as long
//! as they keep asking for it — this is what lets the engine pin the epoch
//! current at admission for each query's whole lifetime. When the oldest
//! pinned epoch catches up with the current one,
//! [`VersionedGraph::retire`] compacts: the overlay is folded into a fresh
//! base CSR and cleared. Compaction is deliberately all-or-nothing
//! (partial compaction would re-index base-slice tombstones for no
//! observable benefit; the engine retires epochs quickly in practice).
//!
//! [`Graph::apply`] is the *serial oracle's* primitive: it folds one batch
//! into a brand-new materialized [`Graph`], so a test can replay a
//! mutation schedule snapshot-by-snapshot (`g.apply(b1).apply(b2)...`)
//! without going anywhere near the overlay machinery, then assert the
//! overlay read the same world ([`VersionedGraph::snapshot_at`] agrees
//! with the `apply` fold by construction — unit-tested below).

use std::borrow::Cow;

use crate::util::FxHashMap;

use super::{Graph, GraphBuilder, VertexId};

/// Graph version number. Epoch 0 is the loaded base; each applied
/// [`MutationBatch`] bumps it by one.
pub type Epoch = u64;

/// Sentinel for "never deleted".
const NEVER: Epoch = Epoch::MAX;

/// One edge/vertex insert or delete.
#[derive(Debug, Clone, PartialEq)]
pub enum Mutation {
    /// Insert the arc `src -> dst` (both endpoints must exist and be live).
    /// `w` is the edge weight for weighted graphs (defaults to 1.0).
    AddEdge {
        src: VertexId,
        dst: VertexId,
        w: Option<f32>,
    },
    /// Delete **one** visible occurrence of the arc `src -> dst`. A
    /// documented silent no-op when no such arc is visible (so schedules
    /// replay identically whether or not an earlier delete already won).
    DeleteEdge { src: VertexId, dst: VertexId },
    /// Append a fresh isolated vertex at the next id.
    AddVertex,
    /// Delete vertex `v`: every incident arc is tombstoned and the id slot
    /// is retained (reads as isolated from this epoch on).
    DeleteVertex { v: VertexId },
}

/// An ordered batch of mutations applied atomically as one epoch bump.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct MutationBatch {
    pub muts: Vec<Mutation>,
}

impl MutationBatch {
    /// Empty batch.
    pub fn new() -> Self {
        Self::default()
    }

    /// Queue an unweighted edge insert.
    pub fn add_edge(&mut self, src: VertexId, dst: VertexId) -> &mut Self {
        self.muts.push(Mutation::AddEdge { src, dst, w: None });
        self
    }

    /// Queue a weighted edge insert.
    pub fn add_wedge(&mut self, src: VertexId, dst: VertexId, w: f32) -> &mut Self {
        self.muts.push(Mutation::AddEdge {
            src,
            dst,
            w: Some(w),
        });
        self
    }

    /// Queue an edge delete.
    pub fn delete_edge(&mut self, src: VertexId, dst: VertexId) -> &mut Self {
        self.muts.push(Mutation::DeleteEdge { src, dst });
        self
    }

    /// Queue a vertex insert.
    pub fn add_vertex(&mut self) -> &mut Self {
        self.muts.push(Mutation::AddVertex);
        self
    }

    /// Queue a vertex delete.
    pub fn delete_vertex(&mut self, v: VertexId) -> &mut Self {
        self.muts.push(Mutation::DeleteVertex { v });
        self
    }

    /// Number of queued mutations.
    pub fn len(&self) -> usize {
        self.muts.len()
    }

    /// True when nothing is queued.
    pub fn is_empty(&self) -> bool {
        self.muts.is_empty()
    }
}

/// What one applied batch did — the receipt the engine folds into its
/// epoch gauges.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct MutationApplied {
    /// The epoch the batch created (current epoch after the apply).
    pub epoch: Epoch,
    /// Overlay footprint estimate right after the apply, **before** any
    /// compaction — the engine maxes this into `delta_bytes_peak`.
    pub delta_bytes: usize,
    /// Vertex-slot count at the new epoch.
    pub n_vertices: usize,
}

/// Per-vertex delta overlay: arcs added since the base, and tombstones
/// over the base CSR slice.
#[derive(Debug, Clone, Default)]
struct VertexDelta {
    /// `(other, weight, added_at, deleted_at)` — visible at `e` iff
    /// `added_at <= e < deleted_at`.
    adds: Vec<(VertexId, f32, Epoch, Epoch)>,
    /// `(index into the base CSR slice, deleted_at)` — the base arc at
    /// that index is hidden from `deleted_at` on. Tombstones never
    /// resurrect, so at most one per index.
    tombs: Vec<(u32, Epoch)>,
}

impl VertexDelta {
    fn bytes(&self) -> usize {
        48 + self.adds.len() * 24 + self.tombs.len() * 12
    }
}

/// A base CSR plus epoch-stamped delta overlays: every version between
/// the compacted base epoch and the current epoch is readable at once.
/// See the module docs for the visibility rules.
#[derive(Debug, Clone)]
pub struct VersionedGraph {
    base: Graph,
    /// Epoch at which `base` is exact (all older overlays compacted away).
    base_epoch: Epoch,
    /// Latest applied epoch.
    epoch: Epoch,
    /// `base.num_vertices()` — ids below this index the base CSR.
    base_n: usize,
    /// Epoch stamp per overlay-added vertex (ids `base_n..`), ascending.
    vertex_added_at: Vec<Epoch>,
    /// `deleted_at` per id slot (`NEVER` while live). Indexed by id.
    vertex_deleted_at: Vec<Epoch>,
    out_delta: FxHashMap<VertexId, VertexDelta>,
    in_delta: FxHashMap<VertexId, VertexDelta>,
}

impl VersionedGraph {
    /// Wrap `base` as epoch `0`. Materializes the base in-adjacency
    /// (vertex deletes expand through it, and [`VersionedGraph::in_at`]
    /// mirrors the overlay over it).
    pub fn new(mut base: Graph) -> Self {
        base.ensure_in_edges();
        let base_n = base.num_vertices();
        Self {
            base,
            base_epoch: 0,
            epoch: 0,
            base_n,
            vertex_added_at: Vec::new(),
            vertex_deleted_at: vec![NEVER; base_n],
            out_delta: FxHashMap::default(),
            in_delta: FxHashMap::default(),
        }
    }

    /// Latest applied epoch.
    pub fn epoch(&self) -> Epoch {
        self.epoch
    }

    /// Epoch at which the compacted base is exact.
    pub fn base_epoch(&self) -> Epoch {
        self.base_epoch
    }

    /// The compacted base CSR (exact at [`VersionedGraph::base_epoch`]).
    pub fn base(&self) -> &Graph {
        &self.base
    }

    /// Vertex-slot count at epoch `e` (deleted vertices keep their slot).
    pub fn num_vertices_at(&self, e: Epoch) -> usize {
        self.base_n + self.vertex_added_at.partition_point(|&a| a <= e)
    }

    /// Vertex-slot count at the current epoch.
    pub fn num_vertices(&self) -> usize {
        self.num_vertices_at(self.epoch)
    }

    /// True if vertex slot `v` exists and is live at epoch `e`.
    pub fn is_live_at(&self, v: VertexId, e: Epoch) -> bool {
        (v as usize) < self.num_vertices_at(e) && self.vertex_deleted_at[v as usize] > e
    }

    /// Overlay footprint estimate in bytes (zero right after compaction).
    pub fn delta_bytes(&self) -> usize {
        let mut b = self.vertex_added_at.len() * 16;
        for d in self.out_delta.values().chain(self.in_delta.values()) {
            b += d.bytes();
        }
        b
    }

    fn base_out(&self, v: VertexId) -> &[VertexId] {
        if (v as usize) < self.base_n {
            self.base.out(v)
        } else {
            &[]
        }
    }

    fn base_in(&self, v: VertexId) -> &[VertexId] {
        if (v as usize) < self.base_n {
            self.base.inn(v)
        } else {
            &[]
        }
    }

    fn neighbors_at<'a>(
        base: &'a [VertexId],
        delta: Option<&VertexDelta>,
        dead: bool,
        e: Epoch,
    ) -> Cow<'a, [VertexId]> {
        if dead {
            return Cow::Borrowed(&[]);
        }
        let Some(d) = delta else {
            // Fast path: untouched vertex borrows the base CSR slice.
            return Cow::Borrowed(base);
        };
        let mut v: Vec<VertexId> = Vec::with_capacity(base.len() + d.adds.len());
        for (idx, &u) in base.iter().enumerate() {
            let hidden = d
                .tombs
                .iter()
                .any(|&(ti, at)| ti as usize == idx && at <= e);
            if !hidden {
                v.push(u);
            }
        }
        for &(u, _, added, deleted) in &d.adds {
            if added <= e && deleted > e {
                v.push(u);
            }
        }
        Cow::Owned(v)
    }

    /// Out-neighbors of `v` at epoch `e`. Untouched vertices borrow the
    /// base CSR slice directly; touched ones assemble the visible list
    /// (base order first, then delta-insertion order — the order
    /// [`VersionedGraph::snapshot_at`] also emits, so snapshot CSRs and
    /// overlay reads iterate identically).
    pub fn out_at(&self, v: VertexId, e: Epoch) -> Cow<'_, [VertexId]> {
        debug_assert!(e >= self.base_epoch, "epoch {e} predates compacted base");
        if (v as usize) >= self.num_vertices_at(e) {
            return Cow::Borrowed(&[]);
        }
        let dead = self.vertex_deleted_at[v as usize] <= e;
        Self::neighbors_at(self.base_out(v), self.out_delta.get(&v), dead, e)
    }

    /// In-neighbors of `v` at epoch `e` (mirror of
    /// [`VersionedGraph::out_at`] over the transposed base).
    pub fn in_at(&self, v: VertexId, e: Epoch) -> Cow<'_, [VertexId]> {
        debug_assert!(e >= self.base_epoch, "epoch {e} predates compacted base");
        if (v as usize) >= self.num_vertices_at(e) {
            return Cow::Borrowed(&[]);
        }
        let dead = self.vertex_deleted_at[v as usize] <= e;
        Self::neighbors_at(self.base_in(v), self.in_delta.get(&v), dead, e)
    }

    /// Visible out-arcs of `v` at `e` with weights (for snapshot builds).
    fn out_edges_at(&self, v: VertexId, e: Epoch) -> Vec<(VertexId, f32)> {
        if (v as usize) >= self.num_vertices_at(e) || self.vertex_deleted_at[v as usize] <= e {
            return Vec::new();
        }
        let base = self.base_out(v);
        let weighted = self.base.weighted();
        let d = self.out_delta.get(&v);
        let mut out = Vec::with_capacity(base.len());
        for (idx, &u) in base.iter().enumerate() {
            let hidden = d.is_some_and(|d| {
                d.tombs
                    .iter()
                    .any(|&(ti, at)| ti as usize == idx && at <= e)
            });
            if !hidden {
                let w = if weighted { self.base.out_w(v)[idx] } else { 1.0 };
                out.push((u, w));
            }
        }
        if let Some(d) = d {
            for &(u, w, added, deleted) in &d.adds {
                if added <= e && deleted > e {
                    out.push((u, w));
                }
            }
        }
        out
    }

    /// Materialize the graph visible at epoch `e` as a plain CSR. Per-
    /// vertex arc order matches [`VersionedGraph::out_at`] exactly.
    pub fn snapshot_at(&self, e: Epoch) -> Graph {
        let n = self.num_vertices_at(e);
        let weighted = self.base.weighted();
        let mut b = GraphBuilder::new(n);
        for v in 0..n as VertexId {
            for (u, w) in self.out_edges_at(v, e) {
                if weighted {
                    b.wedge(v, u, w);
                } else {
                    b.edge(v, u);
                }
            }
        }
        b.build()
    }

    /// Tombstone one visible `src -> dst` occurrence on the `out` side and
    /// one on the `in` side (or every occurrence when `all` is set).
    /// Returns true if anything was deleted.
    fn delete_arc(&mut self, src: VertexId, dst: VertexId, e: Epoch, all: bool) -> bool {
        let mut any = false;
        // Out side: base slice first, then delta adds.
        let mut remaining = if all { usize::MAX } else { 1 };
        let base_hits: Vec<u32> = self
            .base_out(src)
            .iter()
            .enumerate()
            .filter(|&(_, &u)| u == dst)
            .map(|(i, _)| i as u32)
            .collect();
        let d = self.out_delta.entry(src).or_default();
        for idx in base_hits {
            if remaining == 0 {
                break;
            }
            if !d.tombs.iter().any(|&(ti, _)| ti == idx) {
                d.tombs.push((idx, e));
                remaining -= 1;
                any = true;
            }
        }
        for a in d.adds.iter_mut() {
            if remaining == 0 {
                break;
            }
            if a.0 == dst && a.2 <= e && a.3 == NEVER {
                a.3 = e;
                remaining -= 1;
                any = true;
            }
        }
        if d.adds.is_empty() && d.tombs.is_empty() {
            self.out_delta.remove(&src);
        }
        // In side: mirror on dst's transposed structures.
        let mut remaining = if all { usize::MAX } else { 1 };
        let base_hits: Vec<u32> = self
            .base_in(dst)
            .iter()
            .enumerate()
            .filter(|&(_, &u)| u == src)
            .map(|(i, _)| i as u32)
            .collect();
        let d = self.in_delta.entry(dst).or_default();
        for idx in base_hits {
            if remaining == 0 {
                break;
            }
            if !d.tombs.iter().any(|&(ti, _)| ti == idx) {
                d.tombs.push((idx, e));
                remaining -= 1;
            }
        }
        for a in d.adds.iter_mut() {
            if remaining == 0 {
                break;
            }
            if a.0 == src && a.2 <= e && a.3 == NEVER {
                a.3 = e;
                remaining -= 1;
            }
        }
        if d.adds.is_empty() && d.tombs.is_empty() {
            self.in_delta.remove(&dst);
        }
        any
    }

    /// Apply one batch, bumping the epoch by one. Every mutation in the
    /// batch lands at the *same* new epoch (the batch is atomic: no
    /// reader can observe a partially applied batch).
    pub fn apply(&mut self, batch: &MutationBatch) -> MutationApplied {
        let e = self.epoch + 1;
        for m in &batch.muts {
            match *m {
                Mutation::AddEdge { src, dst, w } => {
                    assert!(
                        self.is_live_at(src, e) && self.is_live_at(dst, e),
                        "AddEdge({src}, {dst}) references a missing or deleted vertex"
                    );
                    let w = w.unwrap_or(1.0);
                    self.out_delta
                        .entry(src)
                        .or_default()
                        .adds
                        .push((dst, w, e, NEVER));
                    self.in_delta
                        .entry(dst)
                        .or_default()
                        .adds
                        .push((src, w, e, NEVER));
                }
                Mutation::DeleteEdge { src, dst } => {
                    // Silent no-op when absent (documented on `Mutation`).
                    self.delete_arc(src, dst, e, false);
                }
                Mutation::AddVertex => {
                    self.vertex_added_at.push(e);
                    self.vertex_deleted_at.push(NEVER);
                }
                Mutation::DeleteVertex { v } => {
                    assert!(
                        self.is_live_at(v, e),
                        "DeleteVertex({v}) references a missing or deleted vertex"
                    );
                    let ins = self.in_at(v, e).into_owned();
                    for u in ins {
                        self.delete_arc(u, v, e, true);
                    }
                    let outs = self.out_at(v, e).into_owned();
                    for w in outs {
                        self.delete_arc(v, w, e, true);
                    }
                    self.vertex_deleted_at[v as usize] = e;
                }
            }
        }
        self.epoch = e;
        MutationApplied {
            epoch: e,
            delta_bytes: self.delta_bytes(),
            n_vertices: self.num_vertices_at(e),
        }
    }

    /// Retire every epoch below `oldest_pinned`. Compaction is
    /// all-or-nothing: only when no pinned reader is behind the current
    /// epoch (`oldest_pinned >= epoch`) is the overlay folded into a
    /// fresh base CSR and cleared — otherwise this is a no-op (partial
    /// compaction would re-index base-slice tombstones for no observable
    /// benefit).
    pub fn retire(&mut self, oldest_pinned: Epoch) {
        if oldest_pinned < self.epoch || self.base_epoch == self.epoch {
            return;
        }
        if self.out_delta.is_empty() && self.in_delta.is_empty() && self.vertex_added_at.is_empty()
        {
            self.base_epoch = self.epoch;
            return;
        }
        let mut base = self.snapshot_at(self.epoch);
        base.ensure_in_edges();
        self.base_n = base.num_vertices();
        self.base = base;
        self.base_epoch = self.epoch;
        self.vertex_added_at.clear();
        // Deleted slots stay deleted across compaction (their arcs are
        // already gone from the new base; the stamp keeps `is_live_at`
        // honest so later mutations can't target them).
        for d in self.vertex_deleted_at.iter_mut() {
            if *d != NEVER {
                *d = self.base_epoch;
            }
        }
        self.vertex_deleted_at.resize(self.base_n, NEVER);
        self.out_delta.clear();
        self.in_delta.clear();
    }
}

impl Graph {
    /// Fold one mutation batch into a brand-new materialized CSR — the
    /// serial replay oracle's primitive. `g.apply(b1).apply(b2)` walks a
    /// mutation schedule snapshot-by-snapshot without any overlay
    /// machinery; [`VersionedGraph::snapshot_at`] agrees with this fold
    /// arc-for-arc (unit-tested in `graph::versioned`).
    pub fn apply(&self, batch: &MutationBatch) -> Graph {
        let mut vg = VersionedGraph::new(self.clone());
        let applied = vg.apply(batch);
        vg.snapshot_at(applied.epoch)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn diamond() -> Graph {
        // 0 -> 1, 0 -> 2, 1 -> 3, 2 -> 3
        let mut b = GraphBuilder::new(4);
        b.edge(0, 1);
        b.edge(0, 2);
        b.edge(1, 3);
        b.edge(2, 3);
        b.build()
    }

    fn ids(c: Cow<'_, [VertexId]>) -> Vec<VertexId> {
        c.into_owned()
    }

    #[test]
    fn epoch_zero_reads_the_base() {
        let vg = VersionedGraph::new(diamond());
        assert_eq!(vg.epoch(), 0);
        assert_eq!(vg.num_vertices_at(0), 4);
        assert_eq!(ids(vg.out_at(0, 0)), vec![1, 2]);
        assert_eq!(ids(vg.in_at(3, 0)), vec![1, 2]);
        // Untouched vertices take the borrowed fast path.
        assert!(matches!(vg.out_at(0, 0), Cow::Borrowed(_)));
    }

    #[test]
    fn add_and_delete_are_visible_only_from_their_epoch() {
        let mut vg = VersionedGraph::new(diamond());
        let mut b = MutationBatch::new();
        b.add_edge(3, 0).delete_edge(0, 1);
        let applied = vg.apply(&b);
        assert_eq!(applied.epoch, 1);
        assert!(applied.delta_bytes > 0);
        // Epoch 0 still reads the original world.
        assert_eq!(ids(vg.out_at(0, 0)), vec![1, 2]);
        assert_eq!(ids(vg.out_at(3, 0)), Vec::<VertexId>::new());
        // Epoch 1 sees both changes.
        assert_eq!(ids(vg.out_at(0, 1)), vec![2]);
        assert_eq!(ids(vg.out_at(3, 1)), vec![0]);
        assert_eq!(ids(vg.in_at(1, 1)), Vec::<VertexId>::new());
        assert_eq!(ids(vg.in_at(0, 1)), vec![3]);
    }

    #[test]
    fn delete_edge_removes_one_occurrence_and_missing_is_a_noop() {
        // Parallel arcs: 0 -> 1 twice.
        let mut b = GraphBuilder::new(2);
        b.edge(0, 1);
        b.edge(0, 1);
        let mut vg = VersionedGraph::new(b.build());
        let mut del = MutationBatch::new();
        del.delete_edge(0, 1);
        vg.apply(&del);
        assert_eq!(ids(vg.out_at(0, 1)), vec![1]);
        assert_eq!(ids(vg.in_at(1, 1)), vec![0]);
        vg.apply(&del);
        assert_eq!(ids(vg.out_at(0, 2)), Vec::<VertexId>::new());
        // A third delete of the now-absent arc is a silent no-op.
        vg.apply(&del);
        assert_eq!(vg.epoch(), 3);
        assert_eq!(ids(vg.out_at(0, 3)), Vec::<VertexId>::new());
    }

    #[test]
    fn add_vertex_extends_the_id_space_monotonically() {
        let mut vg = VersionedGraph::new(diamond());
        let mut b = MutationBatch::new();
        b.add_vertex().add_edge(4, 0);
        vg.apply(&b);
        assert_eq!(vg.num_vertices_at(0), 4);
        assert_eq!(vg.num_vertices_at(1), 5);
        assert_eq!(ids(vg.out_at(4, 0)), Vec::<VertexId>::new());
        assert_eq!(ids(vg.out_at(4, 1)), vec![0]);
        assert_eq!(ids(vg.in_at(0, 1)), vec![4]);
    }

    #[test]
    fn delete_vertex_keeps_the_slot_and_drops_every_incident_arc() {
        let mut vg = VersionedGraph::new(diamond());
        let mut b = MutationBatch::new();
        b.delete_vertex(1);
        vg.apply(&b);
        // Slot count is unchanged; the old world is intact at epoch 0.
        assert_eq!(vg.num_vertices_at(1), 4);
        assert_eq!(ids(vg.out_at(0, 0)), vec![1, 2]);
        assert_eq!(ids(vg.in_at(3, 0)), vec![1, 2]);
        // At epoch 1 vertex 1 is isolated and unreferenced.
        assert_eq!(ids(vg.out_at(1, 1)), Vec::<VertexId>::new());
        assert_eq!(ids(vg.in_at(1, 1)), Vec::<VertexId>::new());
        assert_eq!(ids(vg.out_at(0, 1)), vec![2]);
        assert_eq!(ids(vg.in_at(3, 1)), vec![2]);
        assert!(!vg.is_live_at(1, 1));
        assert!(vg.is_live_at(1, 0));
    }

    #[test]
    fn snapshot_agrees_with_graph_apply_fold() {
        let g = diamond();
        let mut b1 = MutationBatch::new();
        b1.add_vertex().add_edge(4, 3).delete_edge(0, 2);
        let mut b2 = MutationBatch::new();
        b2.delete_vertex(1).add_edge(3, 4);
        // Overlay path.
        let mut vg = VersionedGraph::new(g.clone());
        vg.apply(&b1);
        vg.apply(&b2);
        // Oracle fold path.
        let folded = g.apply(&b1).apply(&b2);
        let snap = vg.snapshot_at(2);
        assert_eq!(snap.num_vertices(), folded.num_vertices());
        for v in 0..snap.num_vertices() as VertexId {
            assert_eq!(snap.out(v), folded.out(v), "vertex {v} arcs diverge");
            // And the overlay reads the same list without materializing.
            assert_eq!(ids(vg.out_at(v, 2)), snap.out(v).to_vec());
        }
    }

    #[test]
    fn retire_compacts_only_when_nothing_older_is_pinned() {
        let mut vg = VersionedGraph::new(diamond());
        let mut b = MutationBatch::new();
        b.add_edge(3, 0);
        vg.apply(&b);
        let before = vg.snapshot_at(1);
        // A reader still pins epoch 0: no compaction.
        vg.retire(0);
        assert_eq!(vg.base_epoch(), 0);
        assert!(vg.delta_bytes() > 0);
        // Oldest pin catches up: the overlay folds into the base.
        vg.retire(1);
        assert_eq!(vg.base_epoch(), 1);
        assert_eq!(vg.delta_bytes(), 0);
        let after = vg.snapshot_at(1);
        for v in 0..after.num_vertices() as VertexId {
            assert_eq!(after.out(v), before.out(v));
            // Post-compaction reads borrow the new base directly.
            assert!(matches!(vg.out_at(v, 1), Cow::Borrowed(_)));
        }
    }

    #[test]
    fn retire_preserves_deleted_slots() {
        let mut vg = VersionedGraph::new(diamond());
        let mut b = MutationBatch::new();
        b.delete_vertex(2);
        vg.apply(&b);
        vg.retire(1);
        assert_eq!(vg.num_vertices_at(1), 4);
        assert!(!vg.is_live_at(2, 1));
        assert_eq!(ids(vg.out_at(0, 1)), vec![1]);
        // Mutating through a retired-but-deleted slot still traps.
        let mut bad = MutationBatch::new();
        bad.add_edge(0, 2);
        let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| vg.apply(&bad)));
        assert!(r.is_err(), "AddEdge to a deleted slot must panic");
    }

    #[test]
    fn weighted_arcs_survive_the_overlay_and_snapshot() {
        let mut b = GraphBuilder::new(3);
        b.wedge(0, 1, 2.5);
        b.wedge(0, 2, 1.5);
        let mut vg = VersionedGraph::new(b.build());
        let mut m = MutationBatch::new();
        m.add_wedge(1, 2, 4.0).delete_edge(0, 2);
        vg.apply(&m);
        let snap = vg.snapshot_at(1);
        assert!(snap.weighted());
        assert_eq!(snap.out(0), &[1]);
        assert_eq!(snap.out_w(0), &[2.5]);
        assert_eq!(snap.out(1), &[2]);
        assert_eq!(snap.out_w(1), &[4.0]);
    }

    #[test]
    fn graph_apply_does_not_disturb_the_original() {
        let g = diamond();
        let mut b = MutationBatch::new();
        b.delete_edge(0, 1);
        let g2 = g.apply(&b);
        assert_eq!(g.out(0), &[1, 2]);
        assert_eq!(g2.out(0), &[2]);
    }
}
