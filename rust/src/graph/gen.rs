//! Deterministic synthetic dataset generators.
//!
//! The paper's datasets (Twitter, BTC, LiveJ, WebUK) are proprietary-scale
//! downloads we cannot fetch in this offline image; each generator below
//! reproduces the *structural property the experiments depend on* at a
//! laptop scale (see DESIGN.md §5):
//!
//! * `twitter_like`  — Zipf-skewed degrees, one giant SCC-ish component,
//!   high reach rate (Table 1a: max degree 0.78M vs avg 37; reach 78%).
//! * `btc_like`      — many small connected components, low average degree,
//!   low reach rate (41.8%), undirected.
//! * `livej_like`    — bipartite user/group membership graph, undirected.
//! * `webuk_like`    — layered web-graph-ish DAG with long diameter.

use super::{Graph, GraphBuilder, VertexId};
use crate::util::{FxHashSet, Rng};

/// Twitter-like: directed, power-law out-degrees via preferential-ish
/// attachment on a Zipf target distribution; a base ring guarantees one
/// giant weakly-connected component.
pub fn twitter_like(n: usize, avg_deg: usize, seed: u64) -> Graph {
    let mut rng = Rng::new(seed);
    let mut b = GraphBuilder::new(n);
    let mut seen = FxHashSet::default();
    // Base chain (not a ring!): guarantees weak connectivity and a high
    // reach rate (forward via the chain, backward via celebrity shortcuts)
    // without collapsing the graph into one giant SCC — the reachability
    // experiments need a non-trivial condensation (paper Table 11a:
    // Twitter condenses 52.6M vertices into a 12.4M-vertex DAG).
    for u in 0..n - 1 {
        b.edge(u as VertexId, (u + 1) as VertexId);
        seen.insert((u as VertexId, (u + 1) as VertexId));
    }
    let extra = n * avg_deg.saturating_sub(1);
    for _ in 0..extra {
        let u = rng.below_usize(n) as VertexId;
        // Zipf-ranked target: low ranks are "celebrities" with huge
        // in-degree, giving the hub structure Hub^2 exploits.
        let v = rng.zipf(n, 1.4) as VertexId;
        if u != v && seen.insert((u, v)) {
            b.edge(u, v);
        }
        // A fraction of follows are mutual: celebrities also follow back,
        // which makes the graph small-world (real Twitter distances are
        // ~4-5 hops) instead of chain-dominated.
        if u != v && rng.chance(0.3) && seen.insert((v, u)) {
            b.edge(v, u);
        }
    }
    b.build()
}

/// BTC-like: undirected, many islands. `n` vertices are split into
/// `components` clusters of Zipf-skewed sizes; edges stay within clusters.
pub fn btc_like(n: usize, components: usize, avg_deg: usize, seed: u64) -> Graph {
    let mut rng = Rng::new(seed);
    // Assign vertices to components with skewed sizes (one bigger island,
    // a tail of small ones — mirrors BTC's structure where most random
    // (s, t) pairs are unreachable).
    let mut comp_of = vec![0u32; n];
    for (v, c) in comp_of.iter_mut().enumerate() {
        *c = if v < n / 4 {
            0 // giant component gets a quarter of the vertices
        } else {
            1 + rng.zipf(components - 1, 1.1) as u32
        };
    }
    // Bucket members per component.
    let mut members: Vec<Vec<VertexId>> = vec![Vec::new(); components];
    for (v, &c) in comp_of.iter().enumerate() {
        members[c as usize].push(v as VertexId);
    }
    let mut b = GraphBuilder::new(n).undirected();
    let mut seen = FxHashSet::default();
    for m in &members {
        if m.len() < 2 {
            continue;
        }
        // Path backbone keeps each island connected.
        for w in m.windows(2) {
            b.edge(w[0], w[1]);
            seen.insert((w[0].min(w[1]), w[0].max(w[1])));
        }
        // Sparse random chords to reach the target degree.
        let extra = m.len() * avg_deg / 2;
        for _ in 0..extra {
            let u = m[rng.below_usize(m.len())];
            let v = m[rng.below_usize(m.len())];
            let key = (u.min(v), u.max(v));
            if u != v && seen.insert(key) {
                b.edge(u, v);
            }
        }
    }
    b.build()
}

/// LiveJ-like: undirected bipartite membership graph with `users` user
/// vertices and `groups` group vertices; group popularity is Zipf-skewed.
pub fn livej_like(users: usize, groups: usize, memberships_per_user: usize, seed: u64) -> Graph {
    let mut rng = Rng::new(seed);
    let n = users + groups;
    let mut b = GraphBuilder::new(n).undirected();
    let mut seen = FxHashSet::default();
    for u in 0..users {
        let k = 1 + rng.below_usize(memberships_per_user * 2);
        for _ in 0..k {
            let g = (users + rng.zipf(groups, 1.3)) as VertexId;
            if seen.insert((u as VertexId, g)) {
                b.edge(u as VertexId, g);
            }
        }
    }
    b.build()
}

/// WebUK-like: layered DAG with long diameter. Vertices are arranged in
/// `layers` tiers; edges point from earlier to later tiers with strong
/// locality (web graphs have high diameter — 2793 supersteps for level
/// labels in the paper vs 23 on Twitter).
pub fn webuk_like(n: usize, layers: usize, avg_deg: usize, seed: u64) -> Graph {
    let mut rng = Rng::new(seed);
    let per = n / layers;
    assert!(per >= 1, "need at least one vertex per layer");
    let mut b = GraphBuilder::new(n);
    let mut seen = FxHashSet::default();
    let layer_of = |v: usize| (v / per).min(layers - 1);
    for u in 0..n {
        let lu = layer_of(u);
        let deg = 1 + rng.below_usize(avg_deg * 2);
        for _ in 0..deg {
            // Strong locality: most edges go to the next layer; a few skip.
            let jump = if rng.chance(0.9) {
                1
            } else {
                1 + rng.below_usize(3)
            };
            let lt = lu + jump;
            if lt >= layers {
                continue;
            }
            let base = lt * per;
            let span = if lt == layers - 1 { n - base } else { per };
            let v = base + rng.below_usize(span);
            if seen.insert((u as VertexId, v as VertexId)) {
                b.edge(u as VertexId, v as VertexId);
            }
        }
    }
    b.build()
}

/// Web-like digraph with small intra-layer cycles: like [`webuk_like`] but
/// every layer carries a few 3-cycles, so the SCC condensation is
/// non-trivial (multi-vertex SCCs) without collapsing the graph — the
/// shape the reachability experiments need (paper Table 11a: Twitter's
/// 52.6M vertices condense to a 12.4M-vertex DAG).
pub fn web_cyclic(n: usize, layers: usize, avg_deg: usize, seed: u64) -> Graph {
    let mut rng = Rng::new(seed ^ 0xabcd);
    let per = n / layers;
    assert!(per >= 3, "need at least three vertices per layer");
    let mut b = GraphBuilder::new(n);
    let mut seen = FxHashSet::default();
    let layer_of = |v: usize| (v / per).min(layers - 1);
    for u in 0..n {
        let lu = layer_of(u);
        let deg = 1 + rng.below_usize(avg_deg * 2);
        for _ in 0..deg {
            let jump = if rng.chance(0.9) {
                1
            } else {
                1 + rng.below_usize(3)
            };
            let lt = lu + jump;
            if lt >= layers {
                continue;
            }
            let base = lt * per;
            let span = if lt == layers - 1 { n - base } else { per };
            let v = base + rng.below_usize(span);
            if seen.insert((u as VertexId, v as VertexId)) {
                b.edge(u as VertexId, v as VertexId);
            }
        }
    }
    // Intra-layer 3-cycles: each merges three vertices into one SCC.
    for l in 0..layers {
        let base = l * per;
        let span = if l == layers - 1 { n - base } else { per };
        for _ in 0..span / 12 {
            let x = (base + rng.below_usize(span)) as VertexId;
            let y = (base + rng.below_usize(span)) as VertexId;
            let z = (base + rng.below_usize(span)) as VertexId;
            if x != y && y != z && z != x {
                for (a, c) in [(x, y), (y, z), (z, x)] {
                    if seen.insert((a, c)) {
                        b.edge(a, c);
                    }
                }
            }
        }
    }
    b.build()
}

/// Hub-concentrated partition stressor for the scheduler benchmarks and
/// the work-stealing tests: under the engine's `v mod W` hash partitioning
/// (see `Cluster::worker_of`), every vertex id that is a multiple of
/// `stride` lands on worker 0 — and this generator makes exactly those
/// vertices the high-degree hubs. Run it on a `Cluster::new(stride)` and
/// worker 0's lane carries a multiple of every other lane's load:
///
/// * each hub fans out to `hub_deg` uniform random targets, so when a
///   traversal wave reaches the hubs, lane 0 pays the message *staging*
///   for all of them in one compute phase (the iPregel power-law case);
/// * each non-hub points at 2 random hubs, concentrating message
///   *delivery* on destination worker 0 in the exchange phase, plus
///   `base_deg` uniform random targets — the balanced background load
///   every lane sees;
/// * a chain 0→1→…→n-1 guarantees weak connectivity, and the hubs' uniform
///   fan-out carries traversals back out across all workers.
pub fn hub_concentrated(
    n: usize,
    stride: usize,
    hub_deg: usize,
    base_deg: usize,
    seed: u64,
) -> Graph {
    assert!(stride >= 2, "stride 1 would make every vertex a hub");
    assert!(n > 2 * stride, "need several hubs to concentrate on");
    let mut rng = Rng::new(seed);
    let n_hubs = n.div_ceil(stride);
    let mut b = GraphBuilder::new(n);
    let mut seen = FxHashSet::default();
    for u in 0..n - 1 {
        b.edge(u as VertexId, (u + 1) as VertexId);
        seen.insert((u as VertexId, (u + 1) as VertexId));
    }
    for u in 0..n {
        let uid = u as VertexId;
        if u % stride == 0 {
            for _ in 0..hub_deg {
                let v = rng.below_usize(n) as VertexId;
                if uid != v && seen.insert((uid, v)) {
                    b.edge(uid, v);
                }
            }
        } else {
            for _ in 0..2 {
                // Max hub index is (n_hubs - 1) * stride < n.
                let v = (rng.below_usize(n_hubs) * stride) as VertexId;
                if uid != v && seen.insert((uid, v)) {
                    b.edge(uid, v);
                }
            }
            for _ in 0..base_deg {
                let v = rng.below_usize(n) as VertexId;
                if uid != v && seen.insert((uid, v)) {
                    b.edge(uid, v);
                }
            }
        }
    }
    b.build()
}

/// Single-mega-hub stressor: the worst case the sub-lane compute split
/// exists for, strictly nastier than [`hub_concentrated`]. There, worker
/// 0 owns *many* moderately hot hubs, so lane-granular stealing still has
/// hub-free lanes to rebalance against; here **one vertex** owns the hot
/// edges and its entire blast radius lands on one worker:
///
/// * vertex 0 — the mega hub — has an out-edge to every other multiple of
///   `stride`, i.e. ~`n / stride` edges from a single vertex (plus the
///   chain edge), dwarfing every other out-degree in the graph;
/// * under the engine's `v mod W` hash partitioning on a
///   `Cluster::new(stride)`, all those targets live on worker 0 — so the
///   superstep after a traversal wave reaches the hub, ONE worker lane
///   receives the whole ~`n / stride`-vertex batch as ONE compute task.
///   Whole-lane stealing cannot absorb that (a lane is a single job);
///   only cutting the task's vertex range into sub-jobs can;
/// * each spoke (`v % stride == 0`, `v != 0`) has `spoke_deg` uniform
///   random out-edges, so the pathological round does real per-vertex
///   staging work and the wave fans back out across every worker;
/// * every vertex with `v % stride == 1` points at the hub, so traversals
///   from anywhere find it within a couple of supersteps;
/// * a chain `0 → 1 → … → n-1` guarantees weak connectivity.
pub fn mega_hub(n: usize, stride: usize, spoke_deg: usize, seed: u64) -> Graph {
    assert!(stride >= 2, "stride 1 would put every vertex on worker 0");
    assert!(n > 4 * stride, "need a real spoke population");
    let mut rng = Rng::new(seed);
    let mut b = GraphBuilder::new(n);
    let mut seen = FxHashSet::default();
    for u in 0..n - 1 {
        b.edge(u as VertexId, (u + 1) as VertexId);
        seen.insert((u as VertexId, (u + 1) as VertexId));
    }
    for v in (stride..n).step_by(stride) {
        // The mega fanout: hub 0 → every other multiple of stride.
        let v = v as VertexId;
        if seen.insert((0, v)) {
            b.edge(0, v);
        }
        // Spokes fan the wave back out to uniform random targets.
        for _ in 0..spoke_deg {
            let t = rng.below_usize(n) as VertexId;
            if t != v && seen.insert((v, t)) {
                b.edge(v, t);
            }
        }
    }
    // Fast routes into the hub from every neighborhood.
    for v in (1..n).step_by(stride) {
        let v = v as VertexId;
        if seen.insert((v, 0)) {
            b.edge(v, 0);
        }
    }
    b.build()
}

/// Single-vertex fanout stressor for the edge-level split: ONE vertex
/// owns ~all the hot edges, strictly nastier than [`mega_hub`]. There,
/// the hub's blast radius lands on one worker as one multi-vertex
/// *receiver batch*, which vertex-range splitting can cut; here the
/// pathology is the hub's own `compute()` call — a single work item no
/// vertex granularity can divide:
///
/// * vertex 0 — the mono hub — has an out-edge to EVERY other vertex
///   (`n - 1` edges from one vertex; every other out-degree is
///   `spoke_deg + 1`), so the superstep where a traversal wave reaches
///   the hub, one compute call stages an `n - 1`-message fanout. Only
///   cutting that outbox into edge ranges can parallelize it;
/// * every other vertex points back at the hub, so a BFS from ANY source
///   finds the hub at superstep 1 and the mega-fanout fires at superstep
///   2 — batched queries all detonate their fans in the SAME super-round,
///   piling every fan on the hub's worker lane;
/// * each non-hub vertex also has `spoke_deg` uniform random out-edges:
///   balanced background load, and the post-fan wave (every vertex
///   receives at superstep 3) does real work on every worker.
///
/// The graph is strongly connected through the hub (s → 0 → t), so
/// random query pairs always reach.
pub fn mono_hub(n: usize, spoke_deg: usize, seed: u64) -> Graph {
    assert!(n >= 8, "need a real spoke population");
    let mut rng = Rng::new(seed);
    let mut b = GraphBuilder::new(n);
    let mut seen = FxHashSet::default();
    for v in 1..n {
        let v = v as VertexId;
        // The mega fanout: hub 0 → everyone.
        b.edge(0, v);
        seen.insert((0, v));
        // Fast route into the hub from everywhere.
        b.edge(v, 0);
        seen.insert((v, 0));
        // Balanced background fanout.
        for _ in 0..spoke_deg {
            let t = rng.below_usize(n) as VertexId;
            if t != v && seen.insert((v, t)) {
                b.edge(v, t);
            }
        }
    }
    b.build()
}

/// Pipelining stressor: ONE deep, lane-pinned traversal next to a sea of
/// cheap point lookups. Splitting stressors ([`mega_hub`], [`mono_hub`])
/// make one *task* pathological; this one makes one *query* pathological
/// while every other query is trivial — the exact shape where barriered
/// super-rounds waste the most time, because every cheap query's exchange,
/// fold and reporting waits on the slow query's hub lane each round:
///
/// * the **slow component** is a ladder of `depth` bands of `width`
///   vertices, every id a multiple of `stride` — i.e. all on worker 0
///   under the engine's `v mod W` partitioning on a `Cluster::new(stride)`.
///   Vertex 0 (the hub) fans to band 0; each band-`i` vertex points to all
///   of band `i + 1`, so a BFS from the hub keeps a `width`-vertex
///   frontier (`width²` messages per superstep) pinned to lane 0 for
///   `depth` supersteps while every other lane is idle for that query;
/// * the **cheap components** are small bidirectional stars (4–11
///   vertices, sizes drawn from `seed`) over every id the ladder does not
///   use. A traversal from any star member converges in ≤ 3 supersteps
///   touching ≤ a dozen vertices — the "point lookup" population whose
///   results a pipelined engine can drain while the slow query grinds.
///
/// The two populations are deliberately disconnected: cheap queries must
/// never wander into the ladder and become slow themselves.
pub fn one_slow_query(n: usize, stride: usize, width: usize, depth: usize, seed: u64) -> Graph {
    assert!(stride >= 2, "stride 1 would put every vertex on worker 0");
    assert!(width >= 1 && depth >= 1);
    assert!(
        stride * width * depth < n,
        "need {} lane-0 ids for the ladder, have {}",
        width * depth,
        n / stride
    );
    let mut rng = Rng::new(seed);
    let mut b = GraphBuilder::new(n);
    // Band i, slot k lives at stride * (1 + i*width + k): a multiple of
    // stride, hence worker 0.
    let band = |i: usize, k: usize| (stride * (1 + i * width + k)) as VertexId;
    for k in 0..width {
        b.edge(0, band(0, k));
    }
    for i in 0..depth - 1 {
        for k in 0..width {
            for k2 in 0..width {
                b.edge(band(i, k), band(i + 1, k2));
            }
        }
    }
    // Cheap stars over every id the ladder does not use (including the
    // unused multiples of stride — a few stars touching lane 0 is fine,
    // their work is tiny either way).
    let free: Vec<VertexId> = (1..n)
        .filter(|&v| !(v % stride == 0 && v / stride <= width * depth))
        .map(|v| v as VertexId)
        .collect();
    let mut i = 0;
    while i < free.len() {
        let size = 4 + rng.below_usize(8);
        let end = (i + size).min(free.len());
        let center = free[i];
        for &leaf in &free[i + 1..end] {
            b.edge(center, leaf);
            b.edge(leaf, center);
        }
        i = end;
    }
    b.build()
}

/// Random (s, t) query pairs over `n` vertices.
pub fn random_pairs(n: usize, count: usize, seed: u64) -> Vec<(VertexId, VertexId)> {
    assert!(n >= 2, "need at least two vertices for distinct pairs");
    let mut rng = Rng::new(seed);
    (0..count)
        .map(|_| {
            let s = rng.below_usize(n) as VertexId;
            let mut t = rng.below_usize(n) as VertexId;
            while t == s {
                t = rng.below_usize(n) as VertexId;
            }
            (s, t)
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::BitSet;

    fn reach_fraction(g: &Graph, pairs: &[(VertexId, VertexId)]) -> f64 {
        // plain serial BFS oracle
        let n = g.num_vertices();
        let mut hit = 0;
        for &(s, t) in pairs {
            let mut vis = BitSet::new(n);
            vis.set(s as usize);
            let mut frontier = vec![s];
            'bfs: while !frontier.is_empty() {
                let mut next = Vec::new();
                for &u in &frontier {
                    for &v in g.out(u) {
                        if v == t {
                            hit += 1;
                            break 'bfs;
                        }
                        if !vis.set(v as usize) {
                            next.push(v);
                        }
                    }
                }
                frontier = next;
            }
        }
        hit as f64 / pairs.len() as f64
    }

    #[test]
    fn twitter_like_is_skewed_and_reachable() {
        let mut g = twitter_like(5_000, 10, 1);
        assert_eq!(g.num_vertices(), 5_000);
        assert!(g.avg_degree() >= 5.0);
        // skew: Zipf targets concentrate IN-degree on "celebrity" vertices
        g.ensure_in_edges();
        let max_in = (0..5_000).map(|v| g.in_degree(v as u32)).max().unwrap();
        assert!(
            max_in as f64 > 10.0 * g.avg_degree(),
            "max in-degree {max_in} vs avg {}",
            g.avg_degree()
        );
        let pairs = random_pairs(5_000, 20, 2);
        assert!(reach_fraction(&g, &pairs) > 0.6, "ring base ⇒ high reach");
    }

    #[test]
    fn btc_like_has_low_reach() {
        let g = btc_like(5_000, 400, 4, 3);
        let pairs = random_pairs(5_000, 30, 4);
        let r = reach_fraction(&g, &pairs);
        assert!(r < 0.6, "many components ⇒ low reach, got {r}");
    }

    #[test]
    fn livej_like_is_bipartite() {
        let users = 1_000;
        let groups = 200;
        let g = livej_like(users, groups, 3, 5);
        for u in 0..users {
            for &v in g.out(u as VertexId) {
                assert!(v as usize >= users, "user->user edge found");
            }
        }
        for gv in users..users + groups {
            for &v in g.out(gv as VertexId) {
                assert!((v as usize) < users, "group->group edge found");
            }
        }
    }

    #[test]
    fn webuk_like_is_dag_with_depth() {
        let g = webuk_like(4_000, 40, 4, 7);
        let per = 4_000 / 40;
        // All edges go forward in layer order => DAG.
        for u in 0..g.num_vertices() {
            for &v in g.out(u as u32) {
                assert!(v as usize / per > u / per || v as usize / per >= 39);
            }
        }
    }

    #[test]
    fn hub_concentrated_skews_one_worker_lane() {
        let stride = 8;
        let n = 4_000;
        let mut g = hub_concentrated(n, stride, 24, 6, 11);
        g.ensure_in_edges();
        // Both degree directions must concentrate on the `v mod 8 == 0`
        // lane: hubs OWN the big out-fanout (compute-phase staging skew)
        // and RECEIVE the spoke edges (exchange-phase delivery skew).
        let mut lane_out = vec![0u64; stride];
        let mut lane_in = vec![0u64; stride];
        for v in 0..n {
            lane_out[v % stride] += g.out(v as VertexId).len() as u64;
            lane_in[v % stride] += g.in_degree(v as VertexId) as u64;
        }
        let others_out = lane_out[1..].iter().sum::<u64>() as f64 / (stride - 1) as f64;
        let others_in = lane_in[1..].iter().sum::<u64>() as f64 / (stride - 1) as f64;
        assert!(
            lane_out[0] as f64 > 2.0 * others_out,
            "hub lane out {} vs avg other lane {}",
            lane_out[0],
            others_out
        );
        assert!(
            lane_in[0] as f64 > 2.0 * others_in,
            "hub lane in {} vs avg other lane {}",
            lane_in[0],
            others_in
        );
        // The chain keeps it connected: random pairs mostly reach.
        let pairs = random_pairs(n, 15, 12);
        assert!(reach_fraction(&g, &pairs) > 0.6);
    }

    #[test]
    fn mega_hub_concentrates_one_vertex_and_one_lane() {
        let stride = 8;
        let n = 4_000;
        let g = mega_hub(n, stride, 6, 21);
        // One vertex owns the big fanout: its out-degree dwarfs everyone
        // else's (chain + spoke_deg at most elsewhere).
        let hub_deg = g.out(0).len();
        let max_other = (1..n).map(|v| g.out(v as VertexId).len()).max().unwrap();
        assert!(
            hub_deg >= n / stride,
            "hub out-degree {hub_deg} < spoke count {}",
            n / stride
        );
        assert!(
            hub_deg > 10 * max_other,
            "hub {hub_deg} vs next-biggest {max_other}: one vertex must own \
             most of the hot edges"
        );
        // Every non-chain hub target is a multiple of stride, i.e. lives
        // on worker 0 of a stride-worker cluster: the hub's whole blast
        // radius is one lane's receiver batch.
        for &t in g.out(0) {
            assert!(
                t == 1 || t as usize % stride == 0,
                "hub target {t} not on worker 0"
            );
        }
        // The chain keeps it connected: random pairs mostly reach.
        let pairs = random_pairs(n, 15, 22);
        assert!(reach_fraction(&g, &pairs) > 0.6);
    }

    #[test]
    fn mono_hub_one_vertex_owns_the_edges() {
        let n = 4_000;
        let g = mono_hub(n, 2, 31);
        // ONE vertex owns ~all the hot edges: the hub fans to everyone,
        // everyone else stays at spoke_deg + 1.
        let hub_deg = g.out(0).len();
        assert_eq!(hub_deg, n - 1, "hub must fan to every other vertex");
        let max_other = (1..n).map(|v| g.out(v as VertexId).len()).max().unwrap();
        assert!(
            max_other <= 3,
            "spokes must stay tiny, got out-degree {max_other}"
        );
        // Every vertex routes back to the hub: the fan fires at superstep
        // 2 of a BFS from ANY source.
        for v in 1..n {
            assert!(
                g.out(v as VertexId).contains(&0),
                "vertex {v} must point at the hub"
            );
        }
        // Strongly connected through the hub: everything reaches.
        let pairs = random_pairs(n, 10, 32);
        assert!((reach_fraction(&g, &pairs) - 1.0).abs() < 1e-9);
    }

    #[test]
    fn one_slow_query_pins_the_ladder_to_lane_zero() {
        let stride = 4;
        let (n, width, depth) = (4_000, 16, 12);
        let g = one_slow_query(n, stride, width, depth, 41);
        assert_eq!(g.out(0).len(), width, "hub fans to band 0");
        // BFS from the hub: the frontier stays on worker 0 for the whole
        // ladder and touches exactly the ladder.
        let mut vis = BitSet::new(n);
        vis.set(0);
        let mut frontier = vec![0u32];
        let mut levels = 0usize;
        let mut touched = 1usize;
        while !frontier.is_empty() {
            let mut next = Vec::new();
            for &u in &frontier {
                for &v in g.out(u) {
                    if !vis.set(v as usize) {
                        assert_eq!(
                            v as usize % stride,
                            0,
                            "slow frontier must stay on worker 0"
                        );
                        next.push(v);
                        touched += 1;
                    }
                }
            }
            frontier = next;
            if !frontier.is_empty() {
                levels += 1;
            }
        }
        assert_eq!(levels, depth, "one superstep per band");
        assert_eq!(touched, 1 + width * depth, "hub + the full ladder");
        // Cheap components: a traversal from any non-multiple id converges
        // in a couple of hops touching at most one small star.
        for src in [1u32, 997, 2_001, 3_998] {
            assert_ne!(src as usize % stride, 0);
            let mut vis = BitSet::new(n);
            vis.set(src as usize);
            let mut frontier = vec![src];
            let mut hops = 0usize;
            let mut touched = 1usize;
            while !frontier.is_empty() {
                let mut next = Vec::new();
                for &u in &frontier {
                    for &v in g.out(u) {
                        if !vis.set(v as usize) {
                            next.push(v);
                            touched += 1;
                        }
                    }
                }
                frontier = next;
                if !frontier.is_empty() {
                    hops += 1;
                }
            }
            assert!(hops <= 2, "leaf -> center -> leaves, got {hops}");
            assert!(touched <= 11, "one star at most, got {touched}");
        }
        // Deterministic like every other generator.
        let g2 = one_slow_query(n, stride, width, depth, 41);
        assert_eq!(g.num_edges(), g2.num_edges());
        assert_eq!(g.out(0), g2.out(0));
    }

    #[test]
    fn random_pairs_no_self_loops() {
        for (s, t) in random_pairs(100, 50, 9) {
            assert_ne!(s, t);
        }
    }

    #[test]
    fn generators_are_deterministic() {
        let a = twitter_like(1_000, 5, 42);
        let b = twitter_like(1_000, 5, 42);
        assert_eq!(a.num_edges(), b.num_edges());
        assert_eq!(a.out(17), b.out(17));
    }
}
