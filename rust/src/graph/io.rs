//! Text loaders/dumpers for adjacency-list graph files.
//!
//! Format (one vertex per line, mirroring the HDFS line format the paper's
//! Worker UDF parses):
//!
//! ```text
//! <vertex-id> <tab> <neighbor> [<space> <neighbor>]*
//! ```
//!
//! Weighted variant uses `neighbor:weight` tokens.

use super::{Graph, GraphBuilder, VertexId};
use crate::bail;
use crate::util::error::{Context, Result};
use std::io::{BufRead, BufReader, BufWriter, Write};
use std::path::Path;

/// Load an adjacency-list file. `n` is inferred as max-id + 1.
pub fn load_adj<P: AsRef<Path>>(path: P) -> Result<Graph> {
    let f = std::fs::File::open(path.as_ref())
        .with_context(|| format!("opening {}", path.as_ref().display()))?;
    let mut edges: Vec<(VertexId, VertexId, Option<f32>)> = Vec::new();
    let mut max_id: VertexId = 0;
    for (lineno, line) in BufReader::new(f).lines().enumerate() {
        let line = line?;
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let mut parts = line.split_whitespace();
        let u: VertexId = parts
            .next()
            .context("missing vertex id")?
            .parse()
            .with_context(|| format!("line {}: bad vertex id", lineno + 1))?;
        max_id = max_id.max(u);
        for tok in parts {
            let (v, w) = match tok.split_once(':') {
                Some((v, w)) => (
                    v.parse::<VertexId>()
                        .with_context(|| format!("line {}: bad neighbor", lineno + 1))?,
                    Some(
                        w.parse::<f32>()
                            .with_context(|| format!("line {}: bad weight", lineno + 1))?,
                    ),
                ),
                None => (
                    tok.parse::<VertexId>()
                        .with_context(|| format!("line {}: bad neighbor", lineno + 1))?,
                    None,
                ),
            };
            max_id = max_id.max(v);
            edges.push((u, v, w));
        }
    }
    let weighted = edges.iter().any(|e| e.2.is_some());
    if weighted && edges.iter().any(|e| e.2.is_none()) {
        bail!("mixed weighted and unweighted edges");
    }
    let mut b = GraphBuilder::new(max_id as usize + 1);
    for (u, v, w) in edges {
        match w {
            Some(w) => b.wedge(u, v, w),
            None => b.edge(u, v),
        }
    }
    Ok(b.build())
}

/// Dump a graph back to the adjacency-list format (V-data dump UDF analog).
pub fn dump_adj<P: AsRef<Path>>(g: &Graph, path: P) -> Result<()> {
    let f = std::fs::File::create(path.as_ref())
        .with_context(|| format!("creating {}", path.as_ref().display()))?;
    let mut w = BufWriter::new(f);
    for v in 0..g.num_vertices() as VertexId {
        write!(w, "{v}\t")?;
        let nbrs = g.out(v);
        if g.weighted() {
            let ws = g.out_w(v);
            for (i, (&u, &wt)) in nbrs.iter().zip(ws).enumerate() {
                if i > 0 {
                    write!(w, " ")?;
                }
                write!(w, "{u}:{wt}")?;
            }
        } else {
            for (i, &u) in nbrs.iter().enumerate() {
                if i > 0 {
                    write!(w, " ")?;
                }
                write!(w, "{u}")?;
            }
        }
        writeln!(w)?;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_unweighted() {
        let mut b = GraphBuilder::new(4);
        b.edge(0, 1);
        b.edge(0, 2);
        b.edge(3, 0);
        let g = b.build();
        let dir = std::env::temp_dir().join("quegel_io_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("g.adj");
        dump_adj(&g, &p).unwrap();
        let g2 = load_adj(&p).unwrap();
        assert_eq!(g2.num_vertices(), 4);
        assert_eq!(g2.out(0), g.out(0));
        assert_eq!(g2.out(3), g.out(3));
    }

    #[test]
    fn roundtrip_weighted() {
        let mut b = GraphBuilder::new(3);
        b.wedge(0, 1, 1.5);
        b.wedge(1, 2, 2.25);
        let g = b.build();
        let dir = std::env::temp_dir().join("quegel_io_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("w.adj");
        dump_adj(&g, &p).unwrap();
        let g2 = load_adj(&p).unwrap();
        assert!(g2.weighted());
        assert_eq!(g2.out_w(0), &[1.5]);
        assert_eq!(g2.out_w(1), &[2.25]);
    }

    #[test]
    fn comments_and_blank_lines_skipped() {
        let dir = std::env::temp_dir().join("quegel_io_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("c.adj");
        std::fs::write(&p, "# comment\n\n0\t1 2\n2\t0\n").unwrap();
        let g = load_adj(&p).unwrap();
        assert_eq!(g.num_vertices(), 3);
        assert_eq!(g.out(0), &[1, 2]);
    }
}
