//! Baseline execution disciplines (paper §6 comparisons).
//!
//! Each baseline runs the *same* `QueryApp` algorithms under a different
//! system discipline; the brands differ only in how they schedule work and
//! what they pay for (see DESIGN.md §5):
//!
//! * [`giraph_like`]   — reloads the graph from "HDFS" for every query and
//!   pays one barrier per query-superstep (no sharing, high start-up).
//! * [`graphlab_like`] — keeps the graph resident but processes queries
//!   one at a time (capacity 1, barrier per query-superstep).
//! * [`graphchi_like`] — single-PC out-of-core: one worker that scans the
//!   whole edge file from disk every superstep.
//! * [`neo4j_like`]    — serial pointer-chasing graph database: BFS with a
//!   per-edge store-access latency, no parallelism, no termination bound
//!   (visits the full reachable set when s cannot reach t).

use crate::coordinator::{Engine, QueryResult};
use crate::graph::Graph;
use crate::network::{Cluster, CostModel};
use crate::vertex::QueryApp;

/// Result of running a batch under a baseline discipline.
#[derive(Debug, Clone, Default)]
pub struct BaselineRun<Out> {
    /// One-off (or cumulative, for Giraph-like) graph load seconds.
    pub load_time: f64,
    /// Cumulative query processing seconds.
    pub query_time: f64,
    /// Cumulative result dump seconds.
    pub dump_time: f64,
    /// Mean access rate across queries.
    pub access_rate: f64,
    pub results: Vec<QueryResult<Out>>,
}

/// Giraph-like: per-query job = startup + load + run (capacity 1) + dump.
pub fn giraph_like<A, F>(
    g: &Graph,
    cluster: &Cluster,
    queries: &[A::Query],
    mut mk_app: F,
) -> BaselineRun<A::Out>
where
    A: QueryApp,
    F: FnMut() -> A,
{
    // Giraph's job start-up dominates (container scheduling, JVM spin-up).
    let loader = Cluster::with_cost(
        cluster.workers,
        CostModel {
            startup_s: 12.0,
            ..cluster.cost.clone()
        },
    );
    let mut run = BaselineRun::default();
    let bytes = g.footprint_bytes();
    for q in queries {
        run.load_time += loader.load_time(bytes);
        let mut eng = Engine::new(mk_app(), cluster.clone(), g.num_vertices()).capacity(1);
        let r = eng.run_one(q.clone());
        run.query_time += r.stats.processing();
        // Dump: result write-back to HDFS, proportional to touched set.
        run.dump_time += 0.5 + r.stats.touched as f64 * 16.0 / loader.cost.load_bytes_per_s;
        run.access_rate += r.stats.access_rate;
        run.results.push(r);
    }
    run.access_rate /= queries.len().max(1) as f64;
    run
}

/// GraphLab-like: one-off load, then queries one at a time (no sharing).
pub fn graphlab_like<A, F>(
    g: &Graph,
    cluster: &Cluster,
    queries: &[A::Query],
    mut mk_app: F,
) -> BaselineRun<A::Out>
where
    A: QueryApp,
    F: FnMut() -> A,
{
    let mut run = BaselineRun {
        load_time: cluster.load_time(g.footprint_bytes()),
        ..Default::default()
    };
    for q in queries {
        let mut eng = Engine::new(mk_app(), cluster.clone(), g.num_vertices()).capacity(1);
        let r = eng.run_one(q.clone());
        run.query_time += r.stats.processing();
        run.access_rate += r.stats.access_rate;
        run.results.push(r);
    }
    run.access_rate /= queries.len().max(1) as f64;
    run
}

/// GraphChi-like: single worker, full edge scan from disk per superstep.
pub fn graphchi_like<A, F>(
    g: &Graph,
    queries: &[A::Query],
    mut mk_app: F,
) -> BaselineRun<A::Out>
where
    A: QueryApp,
    F: FnMut() -> A,
{
    let cost = CostModel {
        // Single PC: no network, but every superstep rescans the shards.
        barrier_latency_s: 0.0,
        scan_bytes_per_round: (g.num_edges() * 8) as f64,
        disk_bytes_per_s: 100e6,
        ..Default::default()
    };
    let cluster = Cluster::with_cost(1, cost);
    let mut run = BaselineRun::default();
    for q in queries {
        let mut eng = Engine::new(mk_app(), cluster.clone(), g.num_vertices()).capacity(1);
        let r = eng.run_one(q.clone());
        run.query_time += r.stats.processing();
        run.access_rate += r.stats.access_rate;
        run.results.push(r);
    }
    run.access_rate /= queries.len().max(1) as f64;
    run
}

/// Neo4j-like: serial pointer-chasing BFS for PPSP only. Every edge
/// traversal pays a store access (page cache miss mix); no early bound on
/// unreachable queries — the full reachable set is visited (this is what
/// makes the paper's Q3/Q12/Q15 take hours).
pub fn neo4j_like_ppsp(
    g: &Graph,
    queries: &[(crate::graph::VertexId, crate::graph::VertexId)],
    per_edge_s: f64,
) -> Vec<(Option<u32>, f64)> {
    use crate::apps::ppsp::oracle;
    use crate::apps::ppsp::UNREACHED;
    queries
        .iter()
        .map(|&(s, t)| {
            // Count edges actually scanned by a serial BFS.
            let mut scanned = 0u64;
            let n = g.num_vertices();
            let mut dist = vec![UNREACHED; n];
            dist[s as usize] = 0;
            let mut frontier = vec![s];
            let mut d = 0;
            let mut found = s == t;
            'bfs: while !frontier.is_empty() && !found {
                d += 1;
                let mut next = Vec::new();
                for &u in &frontier {
                    for &v in g.out(u) {
                        scanned += 1;
                        if dist[v as usize] == UNREACHED {
                            dist[v as usize] = d;
                            if v == t {
                                found = true;
                                break 'bfs;
                            }
                            next.push(v);
                        }
                    }
                }
                frontier = next;
            }
            let out = if found {
                Some(oracle::bfs_dist(g, s, t))
            } else {
                None
            };
            (out, scanned as f64 * per_edge_s)
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apps::ppsp::{oracle, Bfs, UNREACHED};
    use crate::graph::gen;

    #[test]
    fn disciplines_agree_on_answers() {
        let g = gen::twitter_like(300, 4, 51);
        let cluster = Cluster::new(4);
        let queries = gen::random_pairs(300, 5, 52);
        let gi = giraph_like::<Bfs, _>(&g, &cluster, &queries, || Bfs::new(&g));
        let gl = graphlab_like::<Bfs, _>(&g, &cluster, &queries, || Bfs::new(&g));
        let gc = graphchi_like::<Bfs, _>(&g, &queries, || Bfs::new(&g));
        for i in 0..queries.len() {
            assert_eq!(gi.results[i].out, gl.results[i].out);
            assert_eq!(gi.results[i].out, gc.results[i].out);
        }
    }

    #[test]
    fn giraph_pays_reload_per_query() {
        let g = gen::twitter_like(300, 4, 53);
        let cluster = Cluster::new(4);
        let queries = gen::random_pairs(300, 4, 54);
        let gi = giraph_like::<Bfs, _>(&g, &cluster, &queries, || Bfs::new(&g));
        let gl = graphlab_like::<Bfs, _>(&g, &cluster, &queries, || Bfs::new(&g));
        assert!(
            gi.load_time > 3.0 * gl.load_time,
            "giraph load {} should dwarf one-off load {}",
            gi.load_time,
            gl.load_time
        );
    }

    #[test]
    fn graphchi_scan_dominates() {
        let g = gen::twitter_like(2_000, 8, 55);
        let queries = gen::random_pairs(2_000, 2, 56);
        let gc = graphchi_like::<Bfs, _>(&g, &queries, || Bfs::new(&g));
        let cluster = Cluster::new(8);
        let gl = graphlab_like::<Bfs, _>(&g, &cluster, &queries, || Bfs::new(&g));
        assert!(
            gc.query_time > gl.query_time,
            "full-scan {} should exceed distributed {}",
            gc.query_time,
            gl.query_time
        );
    }

    #[test]
    fn neo4j_matches_oracle_and_costs_scale() {
        let g = gen::btc_like(500, 40, 4, 57);
        let queries = gen::random_pairs(500, 6, 58);
        let res = neo4j_like_ppsp(&g, &queries, 1e-6);
        for (i, &(s, t)) in queries.iter().enumerate() {
            let want = oracle::bfs_dist(&g, s, t);
            assert_eq!(res[i].0, (want != UNREACHED).then_some(want), "({s},{t})");
        }
    }
}
