//! Quegel: a general-purpose query-centric framework for querying big graphs.
//!
//! Reproduction of Yan et al., "Quegel: A General-Purpose Query-Centric
//! Framework for Querying Big Graphs" (2016), as a three-layer
//! Rust + JAX + Pallas stack. See DESIGN.md for the system inventory.
//!
//! Layer map:
//! * [`coordinator`] — the superstep-sharing engine (the paper's core
//!   contribution): super-rounds, capacity `C`, lazy VQ-data. Each
//!   super-round runs three phases on a persistent **work-stealing**
//!   worker pool (`Engine::threads` knob, defaulting to the machine's
//!   available parallelism; long-lived threads woken per phase, no
//!   per-round spawn/join): **compute** (shard `w` of every in-flight
//!   query forms a lane), **exchange** (destination-sharded message
//!   routing — every destination worker drains its column of the staging
//!   matrix in source-worker order, concurrently with the others), and
//!   **fold** (per-worker aggregator partials folded in worker order per
//!   query, queries folded in parallel). Under the default
//!   `Sched::Stealing` granularity every lane / destination / query is
//!   its own pool job on a per-thread deque, and idle threads steal from
//!   the back of busy threads' deques, so a hub-concentrated partition
//!   never serializes a phase behind one thread. Under the `Split` knob
//!   even one pathological *lane* is no longer atomic: a compute task
//!   whose active/receiving vertex count crosses the split threshold is
//!   cut into contiguous sub-ranges of its serial work order, each its
//!   own pool job with private staging buffers, folded back in sub-range
//!   order by a merge pass. And under the `EdgeSplit` knob not even one
//!   *vertex* is atomic: a `compute()` call staging a mega-fanout has
//!   its outbox parked and cut into contiguous **(vertex, edge-range)**
//!   tasks — each range staged by its own pool job into a private
//!   insertion-ordered buffer, folded back in range order concurrently
//!   per destination worker. Stealing only decides which thread
//!   *executes* a job, splitting (either granularity) only re-groups a
//!   fixed serial order; every order-sensitive merge (message delivery,
//!   aggregator fold, sub-buffer and edge-range absorption) replays that
//!   order inside a single job, so results are bit-identical for every
//!   thread count, scheduler, split and edge-split setting (pinned by
//!   the determinism suite and the randomized fuzzer in
//!   `rust/tests/fuzz_determinism.rs`). Under the `Pipeline` knob the
//!   three phases stop being global barriers altogether: a pipelined
//!   super-round is one pool batch of per-(query, worker) step jobs
//!   where the last lane of each query to finish ships that query's
//!   exchange and fold immediately, and deferred reporting supersteps
//!   overlap the next round's compute — same outputs, bit for bit, with
//!   the engine's phase metrics accounted as per-phase *busy* time
//!   (work actually done, summed across threads) plus an `overlap_time`
//!   gauge of wall time with two-plus phases simultaneously active.
//!   Under the `Layout` knob (flat by default) the per-query stores
//!   behind all of this are slab arenas with dense `VertexId → u32`
//!   handle tables and insertion-ordered columnar staging buffers
//!   instead of hash maps, so the compute/exchange inner loops walk
//!   contiguous memory; `Layout::Hashed` keeps the original maps as the
//!   benchmark baseline, and the bit-identical contract covers the
//!   layout axis too. And under the `Admit` knob (adaptive by default)
//!   the engine is a serving front end, not just a batch runner: a
//!   bounded submission queue with back-pressure (`try_submit`), an
//!   admission planner that confines index-flagged heavy-hub queries to
//!   a reserved capacity slice so one whale can't starve point lookups,
//!   and streaming p50/p99/p999 latency + queueing sketches in
//!   `EngineMetrics` — the planner reads deterministic inputs only, so
//!   per-query outputs stay bit-identical across the admission axis.
//!   Finally the graph itself is no longer frozen at load: `try_mutate`
//!   queues [`graph::MutationBatch`]es (edge/vertex insert/delete) that
//!   the engine applies atomically at the next super-round boundary,
//!   bumping a monotonically increasing **epoch**. Each admitted query
//!   pins the epoch current at its admission and reads that consistent
//!   snapshot for its whole lifetime through per-vertex delta overlays
//!   on the base CSR ([`graph::VersionedGraph`]); overlays compact into
//!   the base once the oldest pinned epoch retires past them. The
//!   determinism contract extends to the mutation axis: a query's output
//!   is a pure function of (pinned version, query) — bit-identical to a
//!   serial replay on the [`graph::Graph::apply`]-folded snapshot of its
//!   pinned epoch — for every thread count, scheduler, layout, pipeline
//!   and admission setting, pinned by the snapshot-replay oracle in
//!   `rust/tests/determinism.rs` and the mutation-schedule fuzzer in
//!   `rust/tests/fuzz_determinism.rs`. And since the multi-process mode
//!   (`coordinator::remote::ProcEngine`) the process boundary is real:
//!   one coordinator plus N worker processes — children of the same
//!   binary, connected over localhost TCP with the crate's
//!   length-prefixed framing — where the destination-sharded exchange,
//!   admission decisions, mutation batches and epoch pins ride the wire.
//!   The whole configuration is one serializable `EngineConfig`
//!   (`EngineConfig::from_env()` reads every `QUEGEL_TEST_*` knob once,
//!   on the coordinator; the byte codec ships it at the handshake), and
//!   the process count is one more axis of the bit-identical contract:
//!   `QueryResult::out` matches the in-process engine byte for byte at
//!   every worker-process count, with `bytes_on_wire` and
//!   `rpc_round_trips` gauges proving which mode actually ran.
//! * [`vertex`] — the `QueryApp` programming interface (paper §4); app and
//!   associated types carry the `Send`/`Sync` bounds the threaded shards
//!   require.
//! * [`network`] — simulated BSP cluster + cost model (testbed stand-in).
//! * [`graph`] — CSR substrate, loaders, synthetic dataset generators.
//! * [`apps`] — the paper's five applications (§5).
//! * [`baselines`] — Giraph/GraphLab/GraphChi/Neo4j-like execution
//!   disciplines for the comparison tables.
//! * [`runtime`] — the batched tropical kernels: pure-rust blocked
//!   min-plus / row-reduction loops (`runtime::rowmin`, always built,
//!   mirroring the Pallas tile schedules; the hub2 batched-admission hot
//!   path runs on them) plus the PJRT loader/executor for the
//!   AOT-compiled artifacts (gated behind the `pjrt` cargo feature).

pub mod analytics;
pub mod apps;
pub mod baselines;
pub mod coordinator;
pub mod graph;
pub mod metrics;
pub mod network;
pub mod prop;
pub mod runtime;
pub mod util;
pub mod vertex;
