//! Multi-process execution: one coordinator plus N worker processes over
//! localhost TCP.
//!
//! [`ProcEngine`] is the process-count axis of the determinism contract.
//! With `procs == 1` it IS the in-process [`Engine`] (zero sockets, zero
//! bytes on the wire); with `procs >= 2` it spawns N child processes of
//! the *same binary* (`std::env::current_exe()`), connects them over
//! loopback TCP with the crate's length-prefixed framing
//! ([`crate::network::encode_frame`] / [`crate::network::FrameDecoder`]),
//! and splits every super-round into three request/reply RPCs:
//!
//! 1. **StartRound → Columns.** The coordinator broadcasts the epoch
//!    retirement watermark, any queued [`MutationBatch`]es, the queries
//!    admitted this round (with their pinned epoch and `|V|`), and every
//!    running query's `(step, agg_prev)`. Each worker process applies the
//!    batches to its graph replica, seeds shards for the admitted
//!    queries, runs the compute phase serially over the BSP workers it
//!    owns (`w % procs == rank`) via the exact same
//!    [`run_task`](super::engine) body as the in-process engine, and
//!    replies with the staged columns destined for *other* processes.
//! 2. **Deliver → FoldReports.** The coordinator relays each column —
//!    body bytes verbatim, never decoded — to the process owning its
//!    destination worker. Workers replay delivery per destination shard
//!    in source-worker order, interleaving local staged buffers with
//!    decoded remote columns through the one
//!    [`deliver_into_sink`]/`merge_msg` chokepoint, so per-destination
//!    delivery order is preserved byte-for-byte. The reply carries each
//!    owned shard's fold inputs: integer phase counters, the aggregator
//!    partial, the `force_terminate` flag, and the quiescence gauges.
//! 3. **Report → Touched** (only on rounds where a query converges). The
//!    reporting worker ships its shards' touched `(v, VQ)` entries in
//!    first-touch order; the coordinator assembles them in global
//!    worker order and runs `finish` locally.
//!
//! Everything *decision-shaped* stays on the coordinator, replicating the
//! in-process engine formula for formula: admission (both `Admit`
//! planners, fed by the replicated `last_round_messages` saturation
//! signal), epoch pinning and retirement, the per-query fold
//! (worker-order `agg_merge`, `master_step`, lifecycle), the simulated
//! clock (per-lane integer counters × the cluster cost model), and
//! result assembly. That is what makes `QueryResult::out` — and the
//! whole `(epoch, out)` stream under streaming mutations — bit-identical
//! across process counts, exactly as it is across thread counts.
//!
//! The handshake ships the full [`EngineConfig`] in its zero-dependency
//! byte codec plus an app *spec* ([`WireApp::spec_bytes`]) from which the
//! worker rebuilds an identical app replica (graph included). Worker
//! shards always use [`Layout::Flat`]: its insertion-ordered staging
//! buffers give the wire encoder the explicit first-touch slot order the
//! hashed layout keeps implicit. Worker compute is the serial reference
//! path (`EdgePolicy::Never`, no pool) — the knobs in the shipped config
//! that tune intra-process parallelism are validated but not yet acted
//! on by workers; they exist so a future worker-side pool sees the same
//! configuration the coordinator does.
//!
//! Metrics: [`crate::metrics::EngineMetrics::bytes_on_wire`] counts every
//! framed byte the coordinator sends *and* receives (payload + the 4-byte
//! length prefix); `rpc_round_trips` counts request/reply pairs per
//! worker. Both are exactly 0 in `procs == 1` mode.

use std::collections::VecDeque;
use std::io::{Read as _, Write as _};
use std::net::{TcpListener, TcpStream};
use std::process::{Child, Command, Stdio};
use std::time::Instant;

use super::arena::{deliver_into_sink, Layout, StagedBuf};
use super::engine::{
    run_task, Admit, EdgePolicy, Engine, EngineConfig, Task, ADMIT_BUSY_MSGS_PER_SLOT,
};
use super::query::{MsgSlot, OrderedStaging, Phase, QueryResult, VState, WorkerShard};
use crate::graph::{Epoch, MutationBatch, VertexId};
use crate::metrics::{EngineMetrics, QueryStats};
use crate::network::wire::{
    self, put_bytes, put_f64, put_u32, put_u64, put_u8, WireError, WireReader, WireResult,
};
use crate::network::{encode_frame, Cluster, FrameDecoder};
use crate::util::FxHashMap;
use crate::vertex::{MasterAction, QueryApp, QueryId};

/// Frame tags, coordinator star topology. Worker → coordinator: `Hello`,
/// `Columns`, `FoldReports`, `Touched`. Coordinator → worker: the rest.
const TAG_HELLO: u8 = 0x01;
const TAG_INIT: u8 = 0x02;
const TAG_START_ROUND: u8 = 0x03;
const TAG_COLUMNS: u8 = 0x04;
const TAG_DELIVER: u8 = 0x05;
const TAG_FOLD: u8 = 0x06;
const TAG_REPORT_REQ: u8 = 0x07;
const TAG_TOUCHED: u8 = 0x08;
const TAG_SHUTDOWN: u8 = 0x09;

/// Upper bound on messages per wire slot. Staged slots are
/// *post-combiner*, so a slot beyond this is a corrupt count, not data —
/// the guard keeps a hostile count from spinning the decoder even for
/// zero-byte message types, where `remaining()` cannot bound it.
const MAX_WIRE_MSGS_PER_SLOT: usize = 1 << 20;

/// Env knobs a worker process is identified by. Set only by
/// [`ProcEngine`]'s spawner — never exported by anything else — so
/// [`maybe_serve_worker`] in an ordinary run is an immediate `false`.
pub const WORKER_ADDR_ENV: &str = "QUEGEL_WORKER_ADDR";
/// See [`WORKER_ADDR_ENV`].
pub const WORKER_RANK_ENV: &str = "QUEGEL_WORKER_RANK";

/// Process count requested by the `QUEGEL_TEST_PROCS` test-matrix env
/// hook (the CI process axis); 1 — in-process mode — when unset or
/// unparsable.
pub fn procs_from_env() -> usize {
    match std::env::var("QUEGEL_TEST_PROCS") {
        Ok(s) => s.trim().parse::<usize>().ok().filter(|&p| p >= 1).unwrap_or(1),
        Err(_) => 1,
    }
}

/// The child argv that makes a libtest binary run ONLY the given worker
/// entry test: pass the result as `child_args` when the calling binary is
/// a `cargo test` harness (the entry test's body is one
/// [`maybe_serve_worker`] call). Binaries with a `main` put the hook at
/// the top of `main` and pass `&[]` instead.
pub fn libtest_worker_args(entry_test: &str) -> Vec<String> {
    vec![
        entry_test.to_string(),
        "--exact".to_string(),
        "--test-threads=1".to_string(),
    ]
}

// ---------------------------------------------------------------------------
// WireApp: the per-app serialization seam
// ---------------------------------------------------------------------------

/// What an app must add to [`QueryApp`] to ride the wire: a *spec* that
/// rebuilds an identical replica in a worker process, plus byte codecs
/// for every app-typed value the protocol carries. All codecs are
/// deterministic and self-delimiting (decode consumes exactly what
/// encode wrote), so replicas stay bit-identical and frames need no
/// per-field length prefixes.
pub trait WireApp: QueryApp + Sized {
    /// Serialize the app's complete current state. Called once, at
    /// spawn time — apps with versioned state may require spawning
    /// before any mutation is applied (the engine's constructor path
    /// guarantees that) and should assert so here.
    fn spec_bytes(&self) -> Vec<u8>;
    /// Rebuild a replica from [`WireApp::spec_bytes`] output.
    fn from_spec(r: &mut WireReader<'_>) -> WireResult<Self>;
    fn enc_query(q: &Self::Query, out: &mut Vec<u8>);
    fn dec_query(r: &mut WireReader<'_>) -> WireResult<Self::Query>;
    fn enc_msg(m: &Self::Msg, out: &mut Vec<u8>);
    fn dec_msg(r: &mut WireReader<'_>) -> WireResult<Self::Msg>;
    fn enc_vq(vq: &Self::VQ, out: &mut Vec<u8>);
    fn dec_vq(r: &mut WireReader<'_>) -> WireResult<Self::VQ>;
    fn enc_agg(a: &Self::Agg, out: &mut Vec<u8>);
    fn dec_agg(r: &mut WireReader<'_>) -> WireResult<Self::Agg>;
    fn enc_out(o: &Self::Out, out: &mut Vec<u8>);
    fn dec_out(r: &mut WireReader<'_>) -> WireResult<Self::Out>;
}

// ---------------------------------------------------------------------------
// Message-column and result codecs
// ---------------------------------------------------------------------------

/// Encode one staged column (every slot bound for one destination worker)
/// in first-touch slot order — the order [`OrderedStaging`] materializes
/// and delivery replays. Slots are post-combiner, exactly what the
/// in-process exchange would hand the destination.
pub(crate) fn encode_column_body<A: WireApp>(
    slots: &[(VertexId, MsgSlot<A::Msg>)],
    out: &mut Vec<u8>,
) {
    put_u32(out, slots.len() as u32);
    for (dst, slot) in slots {
        put_u32(out, *dst);
        let msgs = slot.as_slice();
        put_u32(out, msgs.len() as u32);
        for m in msgs {
            A::enc_msg(m, out);
        }
    }
}

/// Decode a column body back into an insertion-ordered staging buffer.
/// Single-message slots decode to the inline [`MsgSlot::One`]
/// representation — unobservable either way, since delivery only reads
/// the slice view. Corrupt input is an `Err`, never a panic or an
/// unbounded allocation.
pub(crate) fn decode_column_body<A: WireApp>(body: &[u8]) -> WireResult<OrderedStaging<A>> {
    let mut r = WireReader::new(body);
    // Each slot is at least dst(4) + count(4) bytes.
    let n = r.count(8, "column slot count")?;
    let mut slots: Vec<(VertexId, MsgSlot<A::Msg>)> = Vec::with_capacity(n);
    for _ in 0..n {
        let dst = r.u32()?;
        let n_msgs = r.u32()? as usize;
        if n_msgs == 0 {
            return Err(WireError::Corrupt("empty message slot"));
        }
        if n_msgs > MAX_WIRE_MSGS_PER_SLOT {
            return Err(WireError::Corrupt("message count out of range"));
        }
        if n_msgs == 1 {
            slots.push((dst, MsgSlot::One(A::dec_msg(&mut r)?)));
        } else {
            let mut v = Vec::with_capacity(n_msgs.min(r.remaining().max(1)));
            for _ in 0..n_msgs {
                v.push(A::dec_msg(&mut r)?);
            }
            slots.push((dst, MsgSlot::Many(v)));
        }
    }
    r.expect_end()?;
    Ok(OrderedStaging::from_slots(slots))
}

/// Encode a completed [`QueryResult`] — the codec a serving process uses
/// to ship finished results (output + full per-query stats) off-box.
pub fn encode_result<A: WireApp>(res: &QueryResult<A::Out>, out: &mut Vec<u8>) {
    put_u64(out, res.qid);
    A::enc_out(&res.out, out);
    let s = &res.stats;
    put_u64(out, s.qid);
    put_u64(out, s.supersteps);
    put_u64(out, s.messages);
    put_u64(out, s.bytes);
    put_u64(out, s.touched);
    put_f64(out, s.access_rate);
    put_f64(out, s.arrived_at);
    put_f64(out, s.submitted_at);
    put_f64(out, s.started_at);
    put_f64(out, s.finished_at);
    put_u8(out, s.truncated as u8);
    put_u64(out, s.epoch);
}

/// Inverse of [`encode_result`].
pub fn decode_result<A: WireApp>(r: &mut WireReader<'_>) -> WireResult<QueryResult<A::Out>> {
    let qid = r.u64()?;
    let out = A::dec_out(r)?;
    let stats = QueryStats {
        qid: r.u64()?,
        supersteps: r.u64()?,
        messages: r.u64()?,
        bytes: r.u64()?,
        touched: r.u64()?,
        access_rate: r.f64()?,
        arrived_at: r.f64()?,
        submitted_at: r.f64()?,
        started_at: r.f64()?,
        finished_at: r.f64()?,
        truncated: match r.u8()? {
            0 => false,
            1 => true,
            _ => return Err(WireError::Corrupt("truncated flag")),
        },
        epoch: r.u64()?,
    };
    Ok(QueryResult { qid, out, stats })
}

// ---------------------------------------------------------------------------
// Framed connection
// ---------------------------------------------------------------------------

/// One framed peer: a TCP stream, the incremental frame decoder, and a
/// read scratch buffer. Both sides fully read each request before
/// replying and fully write each request before reading replies, so the
/// star never deadlocks.
struct Conn {
    stream: TcpStream,
    dec: FrameDecoder,
    scratch: Vec<u8>,
}

impl Conn {
    fn new(stream: TcpStream) -> Self {
        // Request/reply per round: latency matters, Nagle does not help.
        let _ = stream.set_nodelay(true);
        Self {
            stream,
            dec: FrameDecoder::new(),
            scratch: vec![0u8; 64 * 1024],
        }
    }

    /// Frame and send `payload`; returns framed bytes written.
    fn send(&mut self, payload: &[u8]) -> std::io::Result<u64> {
        let frame = encode_frame(payload);
        self.stream.write_all(&frame)?;
        Ok(frame.len() as u64)
    }

    /// Block until one whole frame arrives; malformed framing surfaces as
    /// `InvalidData`, a peer closing mid-frame as `UnexpectedEof`.
    fn recv(&mut self) -> std::io::Result<Vec<u8>> {
        loop {
            match self.dec.try_next_frame() {
                Ok(Some(frame)) => return Ok(frame),
                Ok(None) => {}
                Err(e) => {
                    return Err(std::io::Error::new(std::io::ErrorKind::InvalidData, e));
                }
            }
            let n = self.stream.read(&mut self.scratch)?;
            if n == 0 {
                return Err(std::io::Error::new(
                    std::io::ErrorKind::UnexpectedEof,
                    "peer closed mid-frame",
                ));
            }
            self.dec.push(&self.scratch[..n]);
        }
    }
}

fn send_counted(conn: &mut Conn, payload: &[u8], bytes_on_wire: &mut u64) {
    let n = conn
        .send(payload)
        .expect("coordinator: send to worker process");
    *bytes_on_wire += n;
}

fn recv_counted(conn: &mut Conn, bytes_on_wire: &mut u64) -> Vec<u8> {
    let frame = conn
        .recv()
        .expect("coordinator: recv from worker process");
    *bytes_on_wire += frame.len() as u64 + 4;
    frame
}

/// Coordinator-side decode helper: a malformed frame from our own worker
/// is a protocol bug, so it fails loudly with context.
fn must<T>(r: WireResult<T>, what: &str) -> T {
    match r {
        Ok(v) => v,
        Err(e) => panic!("coordinator: malformed worker frame ({what}): {e}"),
    }
}

// ---------------------------------------------------------------------------
// ProcEngine: the public face
// ---------------------------------------------------------------------------

/// Engine front end with a process-count axis. `procs == 1` delegates to
/// the in-process [`Engine`] outright; `procs >= 2` runs the
/// coordinator/worker protocol described in the module docs. The serving
/// API mirrors the engine subset the benches, tests and examples drive:
/// submission, mutation, super-rounds, results, metrics, clock.
pub struct ProcEngine<A: WireApp> {
    mode: Mode<A>,
}

enum Mode<A: WireApp> {
    Local(Engine<A>),
    Remote(Box<RemoteCoordinator<A>>),
}

impl<A: WireApp> ProcEngine<A> {
    /// Build the engine. For `procs >= 2` this spawns the worker
    /// processes (children of the current binary, `child_args` argv —
    /// see [`libtest_worker_args`]), completes the handshake (config +
    /// app spec), and leaves the fleet idle awaiting the first round.
    /// Panics on spawn/handshake failure: a half-formed fleet is not a
    /// state to limp on in.
    pub fn new(
        app: A,
        cluster: Cluster,
        n_vertices: usize,
        cfg: EngineConfig,
        procs: usize,
        child_args: &[String],
    ) -> Self {
        assert!(procs >= 1, "procs must be >= 1");
        if procs == 1 {
            return Self {
                mode: Mode::Local(Engine::with_config(app, cluster, n_vertices, cfg)),
            };
        }
        Self {
            mode: Mode::Remote(Box::new(RemoteCoordinator::new(
                app, cluster, n_vertices, cfg, procs, child_args,
            ))),
        }
    }

    /// Worker-process count (1 = in-process mode).
    pub fn procs(&self) -> usize {
        match &self.mode {
            Mode::Local(_) => 1,
            Mode::Remote(rc) => rc.procs,
        }
    }

    /// See [`Engine::submit`].
    pub fn submit(&mut self, q: A::Query) -> QueryId {
        match &mut self.mode {
            Mode::Local(eng) => eng.submit(q),
            Mode::Remote(rc) => {
                let clock = rc.clock;
                rc.try_submit(q, clock)
                    .unwrap_or_else(|_| panic!("submission queue full: use try_submit"))
            }
        }
    }

    /// See [`Engine::try_submit`].
    pub fn try_submit(&mut self, q: A::Query, arrived_at: f64) -> Result<QueryId, A::Query> {
        match &mut self.mode {
            Mode::Local(eng) => eng.try_submit(q, arrived_at),
            Mode::Remote(rc) => rc.try_submit(q, arrived_at),
        }
    }

    /// See [`Engine::try_mutate`].
    pub fn try_mutate(
        &mut self,
        batch: MutationBatch,
        arrived_at: f64,
    ) -> Result<(), MutationBatch> {
        match &mut self.mode {
            Mode::Local(eng) => eng.try_mutate(batch, arrived_at),
            Mode::Remote(rc) => rc.try_mutate(batch),
        }
    }

    /// See [`Engine::super_round`].
    pub fn super_round(&mut self) -> bool {
        match &mut self.mode {
            Mode::Local(eng) => eng.super_round(),
            Mode::Remote(rc) => rc.super_round(),
        }
    }

    /// See [`Engine::run_until_idle`].
    pub fn run_until_idle(&mut self) {
        while self.super_round() {}
    }

    /// See [`Engine::take_results`].
    pub fn take_results(&mut self) -> Vec<QueryResult<A::Out>> {
        match &mut self.mode {
            Mode::Local(eng) => eng.take_results(),
            Mode::Remote(rc) => std::mem::take(&mut rc.results),
        }
    }

    /// See [`Engine::metrics`].
    pub fn metrics(&self) -> &EngineMetrics {
        match &self.mode {
            Mode::Local(eng) => eng.metrics(),
            Mode::Remote(rc) => &rc.metrics,
        }
    }

    /// See [`Engine::sim_time`].
    pub fn sim_time(&self) -> f64 {
        match &self.mode {
            Mode::Local(eng) => eng.sim_time(),
            Mode::Remote(rc) => rc.clock,
        }
    }

    /// See [`Engine::epoch`].
    pub fn epoch(&self) -> Epoch {
        match &self.mode {
            Mode::Local(eng) => eng.epoch(),
            Mode::Remote(rc) => rc.epoch,
        }
    }

    /// See [`Engine::queue_depth`].
    pub fn queue_depth(&self) -> usize {
        match &self.mode {
            Mode::Local(eng) => eng.queue_depth(),
            Mode::Remote(rc) => rc.queue.len(),
        }
    }

    /// Stop the worker fleet (no-op in-process, idempotent). Also runs
    /// on drop; call explicitly to observe the teardown point.
    pub fn shutdown(&mut self) {
        if let Mode::Remote(rc) = &mut self.mode {
            rc.shutdown();
        }
    }
}

// ---------------------------------------------------------------------------
// Coordinator
// ---------------------------------------------------------------------------

/// Waiting submission, mirror of the in-process queue entry.
struct QueuedReq<Q> {
    id: QueryId,
    query: Q,
    arrived_at: f64,
    enqueued_at: f64,
    heavy: bool,
}

/// Coordinator-side runtime of one in-flight query: everything the
/// in-process [`super::query::QueryRt`] tracks EXCEPT the shards, which
/// live in the worker processes.
struct RemoteRt<A: WireApp> {
    id: QueryId,
    query: A::Query,
    step: u64,
    phase: Phase,
    agg_prev: A::Agg,
    terminated: bool,
    heavy: bool,
    epoch: Epoch,
    n_vertices: usize,
    stats: QueryStats,
}

/// One shard's fold inputs, decoded from a worker's `FoldReports` frame.
struct FoldRec<A: WireApp> {
    calls: u64,
    handled: u64,
    sent: u64,
    delivered: u64,
    active: u64,
    pending: u64,
    terminated: bool,
    agg: A::Agg,
}

struct RemoteCoordinator<A: WireApp> {
    app: A,
    cluster: Cluster,
    cfg: EngineConfig,
    procs: usize,
    conns: Vec<Conn>,
    children: Vec<Child>,
    shut: bool,
    queue: VecDeque<QueuedReq<A::Query>>,
    muts: Vec<MutationBatch>,
    /// Batches applied locally but not yet shipped (mutation-only rounds
    /// return before any RPC): prepended to the next `StartRound`.
    unsent_batches: Vec<Vec<u8>>,
    inflight: Vec<RemoteRt<A>>,
    results: Vec<QueryResult<A::Out>>,
    next_qid: QueryId,
    clock: f64,
    epoch: Epoch,
    n_vertices: usize,
    last_round_messages: u64,
    /// Watermark the workers retire to at their next `StartRound`: the
    /// value of the coordinator's own most recent `retire_epochs` call,
    /// so replicas retire at the same point in the round sequence.
    retire_oldest: Epoch,
    metrics: EngineMetrics,
}

impl<A: WireApp> RemoteCoordinator<A> {
    fn new(
        app: A,
        cluster: Cluster,
        n_vertices: usize,
        cfg: EngineConfig,
        procs: usize,
        child_args: &[String],
    ) -> Self {
        if let Err(what) = cfg.validate() {
            panic!("invalid EngineConfig: {what}");
        }
        let listener =
            TcpListener::bind(("127.0.0.1", 0)).expect("coordinator: bind loopback listener");
        let addr = listener
            .local_addr()
            .expect("coordinator: listener address");
        let exe = std::env::current_exe().expect("coordinator: current_exe for worker spawn");
        let mut children = Vec::with_capacity(procs);
        for rank in 0..procs {
            let child = Command::new(&exe)
                .args(child_args)
                .env(WORKER_ADDR_ENV, addr.to_string())
                .env(WORKER_RANK_ENV, rank.to_string())
                .stdin(Stdio::null())
                // libtest chatter on stdout would corrupt nothing (the
                // protocol rides the socket) but keeps logs clean;
                // panics still reach the parent's stderr.
                .stdout(Stdio::null())
                .stderr(Stdio::inherit())
                .spawn()
                .unwrap_or_else(|e| panic!("coordinator: spawn worker rank {rank}: {e}"));
            children.push(child);
        }
        let mut bytes_on_wire = 0u64;
        let mut slots: Vec<Option<Conn>> = (0..procs).map(|_| None).collect();
        for _ in 0..procs {
            let (stream, _) = listener.accept().expect("coordinator: accept worker");
            let mut conn = Conn::new(stream);
            let hello = recv_counted(&mut conn, &mut bytes_on_wire);
            let mut r = WireReader::new(&hello);
            let tag = must(r.u8(), "hello tag");
            assert_eq!(tag, TAG_HELLO, "coordinator: expected Hello frame");
            let rank = must(r.u32(), "hello rank") as usize;
            must(r.expect_end(), "hello tail");
            assert!(rank < procs, "coordinator: worker rank out of range");
            assert!(slots[rank].is_none(), "coordinator: duplicate worker rank");
            slots[rank] = Some(conn);
        }
        let mut conns: Vec<Conn> = slots.into_iter().map(|c| c.unwrap()).collect();

        let mut init = Vec::new();
        put_u8(&mut init, TAG_INIT);
        put_u32(&mut init, procs as u32);
        put_u32(&mut init, cluster.workers as u32);
        put_u64(&mut init, n_vertices as u64);
        put_bytes(&mut init, &cfg.to_bytes());
        put_bytes(&mut init, &app.spec_bytes());
        for conn in conns.iter_mut() {
            send_counted(conn, &init, &mut bytes_on_wire);
        }

        let mut metrics = EngineMetrics::default();
        metrics.bytes_on_wire = bytes_on_wire;
        // Hello/Init is the handshake round trip, one per worker.
        metrics.rpc_round_trips = procs as u64;
        Self {
            app,
            cluster,
            cfg,
            procs,
            conns,
            children,
            shut: false,
            queue: VecDeque::new(),
            muts: Vec::new(),
            unsent_batches: Vec::new(),
            inflight: Vec::new(),
            results: Vec::new(),
            next_qid: 0,
            clock: 0.0,
            epoch: 0,
            n_vertices,
            last_round_messages: 0,
            retire_oldest: 0,
            metrics,
        }
    }

    /// Mirror of [`Engine::try_submit`], including the frozen
    /// `is_heavy` evaluation the admission planner replays.
    fn try_submit(&mut self, q: A::Query, arrived_at: f64) -> Result<QueryId, A::Query> {
        if let Some(bound) = self.cfg.queue_bound {
            if self.queue.len() >= bound {
                return Err(q);
            }
        }
        let id = self.next_qid;
        self.next_qid += 1;
        let heavy = self.app.is_heavy(&q);
        self.queue.push_back(QueuedReq {
            id,
            query: q,
            arrived_at,
            enqueued_at: self.clock,
            heavy,
        });
        Ok(id)
    }

    fn try_mutate(&mut self, batch: MutationBatch) -> Result<(), MutationBatch> {
        if !self.app.supports_mutations() {
            return Err(batch);
        }
        self.muts.push(batch);
        Ok(())
    }

    /// Mirror of the in-process `refresh_epoch_pin`, additionally
    /// recording the watermark the workers will replay next round.
    fn refresh_epoch_pin(&mut self) {
        if !self.app.supports_mutations() {
            return;
        }
        let oldest = self
            .inflight
            .iter()
            .map(|rt| rt.epoch)
            .min()
            .unwrap_or(self.epoch);
        self.metrics.oldest_pinned_epoch = oldest;
        self.app.retire_epochs(oldest);
        self.retire_oldest = oldest;
    }

    /// One distributed super-round, replicating the in-process barrier
    /// path decision for decision (see the module docs). Returns false
    /// when there was nothing to do.
    fn super_round(&mut self) -> bool {
        // Mutations land at the boundary, exactly as in-process: applied
        // to the coordinator replica now (the admission hooks below need
        // the new epoch), shipped to the workers with the next
        // StartRound.
        if !self.muts.is_empty() {
            for batch in std::mem::take(&mut self.muts) {
                let mut enc = Vec::new();
                wire::encode_mutation_batch(&batch, &mut enc);
                self.unsent_batches.push(enc);
                let applied = self.app.apply_mutations(&batch);
                self.epoch = applied.epoch;
                self.n_vertices = applied.n_vertices;
                self.metrics.epochs_applied += 1;
                self.metrics.delta_bytes_peak = self
                    .metrics
                    .delta_bytes_peak
                    .max(applied.delta_bytes as u64);
            }
        }
        if self.inflight.is_empty() && self.queue.is_empty() {
            self.refresh_epoch_pin();
            return false;
        }
        let wall_start = Instant::now();
        let workers = self.cluster.workers;

        // --- Admission: the planner replica. Same inputs as in-process
        // (queue order, heavy flags, in-flight set, the previous round's
        // message counter), same outputs, same deferral accounting.
        let mut admitted: Vec<QueuedReq<A::Query>> = Vec::new();
        let capacity = self.cfg.capacity;
        match self.cfg.admit {
            Admit::Static(c) => {
                let budget = c.min(capacity);
                while self.inflight.len() + admitted.len() < budget {
                    let Some(e) = self.queue.pop_front() else {
                        break;
                    };
                    admitted.push(e);
                }
            }
            Admit::Adaptive => {
                let saturated =
                    self.last_round_messages > ADMIT_BUSY_MSGS_PER_SLOT * capacity as u64;
                let light_waiting = self.queue.iter().any(|e| !e.heavy);
                let div = if saturated && light_waiting { 8 } else { 4 };
                let slice = (capacity / div).max(1);
                let heavy_inflight = self.inflight.iter().filter(|rt| rt.heavy).count();
                let mut heavy_budget = slice.saturating_sub(heavy_inflight);
                let mut kept: VecDeque<QueuedReq<A::Query>> =
                    VecDeque::with_capacity(self.queue.len());
                while let Some(e) = self.queue.pop_front() {
                    if self.inflight.len() + admitted.len() >= capacity {
                        kept.push_back(e);
                        continue;
                    }
                    if e.heavy && heavy_budget == 0 {
                        self.metrics.admit_deferrals += 1;
                        kept.push_back(e);
                        continue;
                    }
                    if e.heavy {
                        heavy_budget -= 1;
                    }
                    admitted.push(e);
                }
                self.queue = kept;
            }
        }
        let mut metas: Vec<(QueryId, f64, f64, bool)> = Vec::with_capacity(admitted.len());
        let mut qs: Vec<A::Query> = Vec::with_capacity(admitted.len());
        for e in admitted {
            metas.push((e.id, e.arrived_at, e.enqueued_at, e.heavy));
            qs.push(e.query);
        }
        if !qs.is_empty() {
            self.app.pin_epoch(&mut qs, self.epoch);
            self.app.admit_batch(&mut qs);
        }
        let first_new = self.inflight.len();
        for ((id, arrived_at, submitted_at, heavy), q) in metas.into_iter().zip(qs) {
            let mut rt = RemoteRt {
                id,
                query: q,
                step: 0,
                phase: Phase::Running,
                agg_prev: A::Agg::default(),
                terminated: false,
                heavy,
                epoch: self.epoch,
                n_vertices: self.n_vertices,
                stats: QueryStats {
                    qid: id,
                    arrived_at,
                    submitted_at,
                    epoch: self.epoch,
                    ..Default::default()
                },
            };
            rt.stats.started_at = self.clock;
            self.inflight.push(rt);
        }
        self.metrics.peak_inflight = self.metrics.peak_inflight.max(self.inflight.len());
        if self.inflight.is_empty() {
            self.refresh_epoch_pin();
            return false;
        }

        // --- RPC 1: StartRound (identical broadcast; workers filter by
        // shard ownership) → Columns.
        let mut start = Vec::new();
        put_u8(&mut start, TAG_START_ROUND);
        // Retire first, then apply: replays the in-process temporal
        // order (retirement ran at the END of the previous round, before
        // this round's batches landed).
        put_u64(&mut start, self.retire_oldest);
        put_u32(&mut start, self.unsent_batches.len() as u32);
        for b in self.unsent_batches.drain(..) {
            put_bytes(&mut start, &b);
        }
        put_u32(&mut start, (self.inflight.len() - first_new) as u32);
        for rt in &self.inflight[first_new..] {
            put_u64(&mut start, rt.id);
            put_u64(&mut start, rt.epoch);
            put_u64(&mut start, rt.n_vertices as u64);
            A::enc_query(&rt.query, &mut start);
        }
        // Every in-flight query is Running here (reporting queries left
        // at the end of the previous round), each computing step+1.
        put_u32(&mut start, self.inflight.len() as u32);
        for rt in &self.inflight {
            debug_assert_eq!(rt.phase, Phase::Running);
            put_u64(&mut start, rt.id);
            put_u64(&mut start, rt.step + 1);
            A::enc_agg(&rt.agg_prev, &mut start);
        }
        for conn in self.conns.iter_mut() {
            send_counted(conn, &start, &mut self.metrics.bytes_on_wire);
        }

        struct ColumnRec {
            qid: QueryId,
            src_w: u32,
            dst_w: u32,
            body: Vec<u8>,
        }
        let mut outgoing: Vec<Vec<ColumnRec>> = (0..self.procs).map(|_| Vec::new()).collect();
        for conn in self.conns.iter_mut() {
            let frame = recv_counted(conn, &mut self.metrics.bytes_on_wire);
            let mut r = WireReader::new(&frame);
            let tag = must(r.u8(), "columns tag");
            assert_eq!(tag, TAG_COLUMNS, "coordinator: expected Columns frame");
            let n = must(r.count(20, "column count"), "column count");
            for _ in 0..n {
                let qid = must(r.u64(), "column qid");
                let src_w = must(r.u32(), "column src");
                let dst_w = must(r.u32(), "column dst");
                // Relay verbatim: the coordinator never decodes message
                // bodies, only reads the length prefix.
                let body = must(r.bytes(), "column body").to_vec();
                let dest = dst_w as usize % self.procs;
                outgoing[dest].push(ColumnRec { qid, src_w, dst_w, body });
            }
            must(r.expect_end(), "columns tail");
        }
        self.metrics.rpc_round_trips += self.procs as u64;

        // --- RPC 2: Deliver (relay, possibly empty — workers must still
        // deliver their local columns and fold) → FoldReports.
        for (rank, cols) in outgoing.into_iter().enumerate() {
            let mut f = Vec::new();
            put_u8(&mut f, TAG_DELIVER);
            put_u32(&mut f, cols.len() as u32);
            for c in cols {
                put_u64(&mut f, c.qid);
                put_u32(&mut f, c.src_w);
                put_u32(&mut f, c.dst_w);
                put_bytes(&mut f, &c.body);
            }
            send_counted(&mut self.conns[rank], &f, &mut self.metrics.bytes_on_wire);
        }
        let mut fold: FxHashMap<(QueryId, u32), FoldRec<A>> = FxHashMap::default();
        for conn in self.conns.iter_mut() {
            let frame = recv_counted(conn, &mut self.metrics.bytes_on_wire);
            let mut r = WireReader::new(&frame);
            let tag = must(r.u8(), "fold tag");
            assert_eq!(tag, TAG_FOLD, "coordinator: expected FoldReports frame");
            let n = must(r.count(61, "fold report count"), "fold report count");
            for _ in 0..n {
                let qid = must(r.u64(), "fold qid");
                let w = must(r.u32(), "fold worker");
                let rec = FoldRec {
                    calls: must(r.u64(), "fold calls"),
                    handled: must(r.u64(), "fold handled"),
                    sent: must(r.u64(), "fold sent"),
                    delivered: must(r.u64(), "fold delivered"),
                    active: must(r.u64(), "fold active"),
                    pending: must(r.u64(), "fold pending"),
                    terminated: must(r.u8(), "fold terminated") != 0,
                    agg: must(A::dec_agg(&mut r), "fold agg"),
                };
                let prev = fold.insert((qid, w), rec);
                assert!(prev.is_none(), "coordinator: duplicate fold report");
            }
            must(r.expect_end(), "fold tail");
        }
        self.metrics.rpc_round_trips += self.procs as u64;

        // --- Exchange accounting + per-query fold, in in-flight order
        // with worker-order aggregator merges: the in-process formulas
        // over the replicated integer counters.
        let msg_size = self.app.msg_bytes() + self.cluster.cost.msg_header_bytes;
        let c1 = self.cluster.cost.per_vertex_compute_s;
        let c2 = self.cluster.cost.per_msg_overhead_s;
        let mut worker_cost = vec![0.0f64; workers];
        let mut round_msgs: u64 = 0;
        let mut round_bytes: u64 = 0;
        let mut total_compute_calls: u64 = 0;
        let max_supersteps = self.cfg.max_supersteps;
        let app = &self.app;
        for rt in self.inflight.iter_mut() {
            rt.step += 1;
            let mut q_msgs: u64 = 0;
            let mut active_pending: u64 = 0;
            let mut merged = A::Agg::default();
            for (w, cost) in worker_cost.iter_mut().enumerate() {
                let rec = fold
                    .remove(&(rt.id, w as u32))
                    .expect("coordinator: fold report for every shard");
                q_msgs += rec.delivered;
                round_msgs += rec.sent;
                total_compute_calls += rec.calls;
                *cost += rec.calls as f64 * c1 + rec.handled as f64 * c2;
                active_pending += rec.active + rec.pending;
                app.agg_merge(&mut merged, &rec.agg);
                if rec.terminated {
                    rt.terminated = true;
                }
            }
            rt.stats.messages += q_msgs;
            let q_bytes = q_msgs * msg_size as u64;
            rt.stats.bytes += q_bytes;
            round_bytes += q_bytes;
            let action = app.master_step(&rt.query, rt.step, &rt.agg_prev, &mut merged);
            rt.agg_prev = merged;
            if action == MasterAction::Terminate {
                rt.terminated = true;
            }
            if rt.step >= max_supersteps {
                rt.terminated = true;
                rt.stats.truncated = true;
            }
            if rt.terminated || active_pending == 0 {
                rt.phase = Phase::Reporting;
            }
            rt.stats.supersteps = rt.step;
        }
        debug_assert!(fold.is_empty(), "fold reports for unknown shards");
        // Aggregator sync bytes: one Agg per worker per in-flight query.
        round_bytes += (self.inflight.len() * workers * std::mem::size_of::<A::Agg>()) as u64;

        // --- Simulated clock, from the same cost model and counters.
        let dt = self.cluster.super_round_time(&worker_cost, round_bytes as usize);
        self.clock += dt;
        self.metrics.super_rounds += 1;
        self.metrics.total_messages += round_msgs;
        self.metrics.total_bytes += round_bytes;
        self.metrics.total_compute_calls += total_compute_calls;
        self.metrics.sim_time = self.clock;
        self.last_round_messages = round_msgs;

        // --- RPC 3 (reporting rounds only): Report → Touched. Workers
        // ship (v, VQ) in first-touch order per shard; assembly is in
        // global worker order — exactly the in-process flat reporting
        // iteration — and `finish` runs on the coordinator replica.
        let reporting: Vec<QueryId> = self
            .inflight
            .iter()
            .filter(|rt| rt.phase == Phase::Reporting)
            .map(|rt| rt.id)
            .collect();
        if !reporting.is_empty() {
            let mut req = Vec::new();
            put_u8(&mut req, TAG_REPORT_REQ);
            put_u32(&mut req, reporting.len() as u32);
            for &qid in &reporting {
                put_u64(&mut req, qid);
            }
            for conn in self.conns.iter_mut() {
                send_counted(conn, &req, &mut self.metrics.bytes_on_wire);
            }
            let mut touched: FxHashMap<QueryId, Vec<Vec<(VertexId, A::VQ)>>> = reporting
                .iter()
                .map(|&qid| (qid, vec![Vec::new(); workers]))
                .collect();
            for (rank, conn) in self.conns.iter_mut().enumerate() {
                let owned = (0..workers).filter(|w| w % self.procs == rank).count();
                for _ in 0..reporting.len() * owned {
                    let frame = recv_counted(conn, &mut self.metrics.bytes_on_wire);
                    let mut r = WireReader::new(&frame);
                    let tag = must(r.u8(), "touched tag");
                    assert_eq!(tag, TAG_TOUCHED, "coordinator: expected Touched frame");
                    let qid = must(r.u64(), "touched qid");
                    let w = must(r.u32(), "touched worker") as usize;
                    let n = must(r.count(4, "touched entry count"), "touched entry count");
                    let mut entries = Vec::with_capacity(n);
                    for _ in 0..n {
                        let v = must(r.u32(), "touched vertex");
                        let vq = must(A::dec_vq(&mut r), "touched vq");
                        entries.push((v, vq));
                    }
                    must(r.expect_end(), "touched tail");
                    let groups = touched
                        .get_mut(&qid)
                        .expect("coordinator: touched for unknown query");
                    assert!(w < workers && groups[w].is_empty());
                    groups[w] = entries;
                }
            }
            self.metrics.rpc_round_trips += self.procs as u64;

            let clock = self.clock;
            let results = &mut self.results;
            let metrics = &mut self.metrics;
            let app = &self.app;
            let mut touched = touched;
            self.inflight.retain_mut(|rt| {
                if rt.phase != Phase::Reporting {
                    return true;
                }
                let groups = touched
                    .remove(&rt.id)
                    .expect("coordinator: touched groups for reporting query");
                let n_touched: u64 = groups.iter().map(|g| g.len() as u64).sum();
                rt.stats.touched = n_touched;
                rt.stats.access_rate = n_touched as f64 / rt.n_vertices.max(1) as f64;
                rt.stats.finished_at = clock;
                metrics.queries_completed += 1;
                metrics.latency.record(rt.stats.latency());
                metrics.queueing.record(rt.stats.queueing());
                let mut iter = groups.iter().flat_map(|g| g.iter().map(|(v, vq)| (*v, vq)));
                let out = app.finish(&rt.query, &mut iter, &rt.agg_prev);
                results.push(QueryResult {
                    qid: rt.id,
                    out,
                    stats: rt.stats.clone(),
                });
                false
            });
        }

        self.refresh_epoch_pin();
        self.metrics.wall_time += wall_start.elapsed().as_secs_f64();
        true
    }

    fn shutdown(&mut self) {
        if self.shut {
            return;
        }
        self.shut = true;
        let mut f = Vec::new();
        put_u8(&mut f, TAG_SHUTDOWN);
        for conn in self.conns.iter_mut() {
            if conn.send(&f).is_ok() {
                self.metrics.bytes_on_wire += f.len() as u64 + 4;
            }
        }
        for child in self.children.iter_mut() {
            let _ = child.wait();
        }
    }
}

impl<A: WireApp> Drop for RemoteCoordinator<A> {
    fn drop(&mut self) {
        self.shutdown();
    }
}

// ---------------------------------------------------------------------------
// Worker process
// ---------------------------------------------------------------------------

/// Serve as a worker process if (and only if) the worker env knobs are
/// set — i.e. this process was spawned by a [`ProcEngine`] coordinator.
/// Call at the very top of `main` (or from a dedicated libtest entry
/// test); returns `false` immediately in an ordinary run, `true` after
/// serving to shutdown. `A` must match the coordinator's app type —
/// the spec decode fails loudly otherwise.
pub fn maybe_serve_worker<A: WireApp>() -> bool {
    let Ok(addr) = std::env::var(WORKER_ADDR_ENV) else {
        return false;
    };
    let rank: usize = std::env::var(WORKER_RANK_ENV)
        .expect("worker: QUEGEL_WORKER_RANK set alongside QUEGEL_WORKER_ADDR")
        .trim()
        .parse()
        .expect("worker: QUEGEL_WORKER_RANK is an integer");
    serve_worker::<A>(&addr, rank);
    true
}

/// Per-shard integer counters for one round, reported at fold time.
#[derive(Clone, Copy, Default)]
struct LaneStats {
    calls: u64,
    handled: u64,
    sent: u64,
    delivered: u64,
}

/// Worker-side state of one in-flight query: the shards this process
/// owns (`w % procs == rank`, ascending), in forced [`Layout::Flat`].
struct WQuery<A: WireApp> {
    query: A::Query,
    shards: Vec<(usize, WorkerShard<A>, LaneStats)>,
}

struct WorkerState<A: WireApp> {
    app: A,
    cluster: Cluster,
    rank: usize,
    procs: usize,
    workers: usize,
    queries: FxHashMap<QueryId, WQuery<A>>,
    /// Cross-process columns received in the current Deliver, keyed
    /// `(qid, src_w, dst_w)`; delivery replay removes them in
    /// source-worker order.
    remote_cols: FxHashMap<(QueryId, u32, u32), Vec<u8>>,
    /// Running qids of the current round, in StartRound (= in-flight)
    /// order: fixes the fold-report iteration order.
    round_qids: Vec<QueryId>,
    outbox_scratch: Vec<(VertexId, A::Msg)>,
}

fn serve_worker<A: WireApp>(addr: &str, rank: usize) {
    let stream = TcpStream::connect(addr).expect("worker: connect to coordinator");
    let mut conn = Conn::new(stream);
    let mut hello = Vec::new();
    put_u8(&mut hello, TAG_HELLO);
    put_u32(&mut hello, rank as u32);
    conn.send(&hello).expect("worker: send hello");

    let init = conn.recv().expect("worker: recv init");
    let mut r = WireReader::new(&init);
    assert_eq!(r.u8().expect("init tag"), TAG_INIT, "worker: expected Init");
    let procs = r.u32().expect("init procs") as usize;
    let workers = r.u32().expect("init workers") as usize;
    let _n_vertices = r.u64().expect("init n_vertices");
    let cfg_bytes = r.bytes().expect("init config");
    let cfg = EngineConfig::from_bytes(cfg_bytes).expect("worker: config decode");
    cfg.validate().expect("worker: config invariants");
    let spec = r.bytes().expect("init spec");
    r.expect_end().expect("init tail");
    let mut sr = WireReader::new(spec);
    let app = A::from_spec(&mut sr).expect("worker: app spec decode");
    sr.expect_end().expect("worker: app spec tail");
    assert!(rank < procs, "worker: rank out of range");

    let mut st = WorkerState {
        app,
        cluster: Cluster::new(workers),
        rank,
        procs,
        workers,
        queries: FxHashMap::default(),
        remote_cols: FxHashMap::default(),
        round_qids: Vec::new(),
        outbox_scratch: Vec::new(),
    };
    loop {
        let frame = conn.recv().expect("worker: recv request");
        let mut r = WireReader::new(&frame);
        let tag = r.u8().expect("request tag");
        match tag {
            TAG_START_ROUND => {
                let reply = st.handle_start_round(&mut r);
                conn.send(&reply).expect("worker: send columns");
            }
            TAG_DELIVER => {
                let reply = st.handle_deliver(&mut r);
                conn.send(&reply).expect("worker: send fold reports");
            }
            TAG_REPORT_REQ => {
                for f in st.handle_report(&mut r) {
                    conn.send(&f).expect("worker: send touched");
                }
            }
            TAG_SHUTDOWN => break,
            other => panic!("worker: unexpected frame tag {other:#x}"),
        }
    }
}

impl<A: WireApp> WorkerState<A> {
    fn owns(&self, w: usize) -> bool {
        w % self.procs == self.rank
    }

    /// Retire + apply mutations, build admitted shards, run the compute
    /// phase over owned shards, reply with the cross-process columns.
    fn handle_start_round(&mut self, r: &mut WireReader<'_>) -> Vec<u8> {
        let retire = r.u64().expect("start retire");
        self.app.retire_epochs(retire);
        let n_batches = r.count(4, "batch count").expect("batch count");
        for _ in 0..n_batches {
            let b = r.bytes().expect("batch bytes");
            let mut br = WireReader::new(b);
            let batch = wire::decode_mutation_batch(&mut br).expect("worker: batch decode");
            br.expect_end().expect("worker: batch tail");
            self.app.apply_mutations(&batch);
        }

        let n_adm = r.count(24, "admitted count").expect("admitted count");
        for _ in 0..n_adm {
            let qid = r.u64().expect("admitted qid");
            let _epoch = r.u64().expect("admitted epoch");
            let n_vertices = r.u64().expect("admitted n_vertices") as usize;
            let query = A::dec_query(r).expect("worker: query decode");
            // Forced Flat: insertion-ordered staging gives the wire
            // codec the explicit first-touch slot order.
            let mut shards: Vec<(usize, WorkerShard<A>, LaneStats)> = (0..self.workers)
                .filter(|&w| self.owns(w))
                .map(|w| {
                    (
                        w,
                        WorkerShard::new(self.workers, Layout::Flat, n_vertices),
                        LaneStats::default(),
                    )
                })
                .collect();
            // Seed V_q^I, preserving init_activate order within each
            // owned shard (identical to the in-process seeding loop
            // restricted to this process's workers).
            let app = &self.app;
            for v in app.init_activate(&query) {
                let w = self.cluster.worker_of(v);
                if !self.owns(w) {
                    continue;
                }
                let (_, shard, _) = shards
                    .iter_mut()
                    .find(|(sw, _, _)| *sw == w)
                    .expect("owned shard present");
                let q = &query;
                shard.store.seed_with(v, || VState {
                    vq: app.init_value(q, v),
                    halted: false,
                    computed_step: 0,
                });
                shard.active.push(v);
            }
            self.queries.insert(qid, WQuery { query, shards });
        }

        let n_run = r.count(16, "running count").expect("running count");
        self.round_qids.clear();
        let mut cols: Vec<(QueryId, u32, u32, Vec<u8>)> = Vec::new();
        for _ in 0..n_run {
            let qid = r.u64().expect("running qid");
            let step = r.u64().expect("running step");
            let agg_prev = A::dec_agg(r).expect("worker: agg decode");
            self.round_qids.push(qid);
            let wq = self
                .queries
                .get_mut(&qid)
                .expect("worker: running query unknown");
            let WQuery { query, shards } = wq;
            for (_, shard, lane) in shards.iter_mut() {
                *lane = LaneStats::default();
                let mut task = Task {
                    qid,
                    step,
                    query,
                    agg_prev: &agg_prev,
                    shard,
                };
                // The serial reference body: no edge parking, staging
                // straight into the shard's flat buffers.
                let run = run_task(
                    &self.app,
                    &self.cluster,
                    EdgePolicy::Never,
                    &mut task,
                    &mut self.outbox_scratch,
                );
                debug_assert!(run.overflow.is_none(), "EdgePolicy::Never never parks");
                lane.calls += run.calls;
                lane.handled += run.handled;
                lane.sent += run.sent;
            }
            // Drain cross-process columns (owned destinations stay put
            // for the local leg of delivery).
            for (src_w, shard, _) in wq.shards.iter_mut() {
                for dst_w in 0..self.workers {
                    if dst_w % self.procs == self.rank {
                        continue;
                    }
                    let StagedBuf::Flat(ord) = &mut shard.staged[dst_w] else {
                        unreachable!("worker shards are Layout::Flat");
                    };
                    if ord.slots.is_empty() {
                        continue;
                    }
                    let slots: Vec<(VertexId, MsgSlot<A::Msg>)> = ord.drain_slots().collect();
                    let mut body = Vec::new();
                    encode_column_body::<A>(&slots, &mut body);
                    cols.push((qid, *src_w as u32, dst_w as u32, body));
                }
            }
        }
        r.expect_end().expect("worker: start round tail");

        let mut reply = Vec::new();
        put_u8(&mut reply, TAG_COLUMNS);
        put_u32(&mut reply, cols.len() as u32);
        for (qid, src_w, dst_w, body) in cols {
            put_u64(&mut reply, qid);
            put_u32(&mut reply, src_w);
            put_u32(&mut reply, dst_w);
            put_bytes(&mut reply, &body);
        }
        reply
    }

    /// Replay delivery for every owned destination shard — local staged
    /// buffers and remote columns interleaved in source-worker order,
    /// all through [`deliver_into_sink`] — then report fold inputs.
    fn handle_deliver(&mut self, r: &mut WireReader<'_>) -> Vec<u8> {
        let n = r.count(20, "deliver column count").expect("deliver count");
        for _ in 0..n {
            let qid = r.u64().expect("deliver qid");
            let src_w = r.u32().expect("deliver src");
            let dst_w = r.u32().expect("deliver dst");
            let body = r.bytes().expect("deliver body").to_vec();
            debug_assert!(self.owns(dst_w as usize));
            self.remote_cols.insert((qid, src_w, dst_w), body);
        }
        r.expect_end().expect("worker: deliver tail");

        let round_qids = std::mem::take(&mut self.round_qids);
        let owned: Vec<usize> = (0..self.workers).filter(|&w| self.owns(w)).collect();
        let mut reply = Vec::new();
        put_u8(&mut reply, TAG_FOLD);
        put_u32(&mut reply, (round_qids.len() * owned.len()) as u32);
        for &qid in &round_qids {
            let wq = self
                .queries
                .get_mut(&qid)
                .expect("worker: delivering unknown query");
            // Delivery per owned destination shard. The sink is moved
            // out (owned) so local source shards — including the
            // destination itself — can be borrowed for their staged
            // buffers.
            for di in 0..wq.shards.len() {
                let dst_w = wq.shards[di].0;
                let mut sink = wq.shards[di].1.store.take_exchange_sink();
                let mut delivered: u64 = 0;
                for src_w in 0..self.workers {
                    if self.owns(src_w) {
                        let si = wq
                            .shards
                            .iter()
                            .position(|(sw, _, _)| *sw == src_w)
                            .expect("owned source shard");
                        let buf = &mut wq.shards[si].1.staged[dst_w];
                        delivered += deliver_into_sink(&self.app, &mut sink, buf);
                    } else if let Some(body) =
                        self.remote_cols.remove(&(qid, src_w as u32, dst_w as u32))
                    {
                        let ord =
                            decode_column_body::<A>(&body).expect("worker: column decode");
                        let mut buf = StagedBuf::Flat(ord);
                        delivered += deliver_into_sink(&self.app, &mut sink, &mut buf);
                    }
                }
                wq.shards[di].1.store.restore_exchange_sink(sink);
                wq.shards[di].2.delivered = delivered;
            }
            // Fold inputs per owned shard, ascending worker order.
            for (w, shard, lane) in wq.shards.iter_mut() {
                put_u64(&mut reply, qid);
                put_u32(&mut reply, *w as u32);
                put_u64(&mut reply, lane.calls);
                put_u64(&mut reply, lane.handled);
                put_u64(&mut reply, lane.sent);
                put_u64(&mut reply, lane.delivered);
                put_u64(&mut reply, shard.active.len() as u64);
                put_u64(&mut reply, shard.store.pending() as u64);
                put_u8(&mut reply, shard.terminated as u8);
                shard.terminated = false;
                let agg = std::mem::take(&mut shard.agg_round);
                A::enc_agg(&agg, &mut reply);
            }
        }
        self.remote_cols.clear();
        reply
    }

    /// Ship touched `(v, VQ)` entries for every owned shard of every
    /// reporting query — first-touch order within a shard (the flat
    /// store's insertion order) — then drop the query state.
    fn handle_report(&mut self, r: &mut WireReader<'_>) -> Vec<Vec<u8>> {
        let n = r.count(8, "report qid count").expect("report count");
        let mut frames = Vec::new();
        for _ in 0..n {
            let qid = r.u64().expect("report qid");
            let wq = self
                .queries
                .remove(&qid)
                .expect("worker: reporting unknown query");
            for (w, shard, _) in wq.shards.iter() {
                let mut f = Vec::new();
                put_u8(&mut f, TAG_TOUCHED);
                put_u64(&mut f, qid);
                put_u32(&mut f, *w as u32);
                put_u32(&mut f, shard.store.touched() as u32);
                for (v, vq) in shard.store.touched_iter() {
                    put_u32(&mut f, v);
                    A::enc_vq(vq, &mut f);
                }
                frames.push(f);
            }
        }
        r.expect_end().expect("worker: report tail");
        frames
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apps::ppsp::{vbfs_query, VersionedBfs};
    use crate::coordinator::{Pipeline, Sched};
    use crate::graph::gen;
    use crate::vertex::Ctx;

    /// Codec probe: a do-nothing app with non-trivial wire types so the
    /// column/result codecs are exercised with real payload bytes.
    struct WireProbe;

    impl QueryApp for WireProbe {
        type Query = u32;
        type VQ = u32;
        type Msg = u32;
        type Agg = u64;
        type Out = Vec<u32>;

        fn init_activate(&self, _q: &u32) -> Vec<VertexId> {
            Vec::new()
        }
        fn init_value(&self, _q: &u32, _v: VertexId) -> u32 {
            0
        }
        fn compute(&self, _ctx: &mut Ctx<'_, Self>, _v: VertexId, _vq: &mut u32) {}
        fn finish(
            &self,
            _q: &u32,
            touched: &mut dyn Iterator<Item = (VertexId, &u32)>,
            _agg: &u64,
        ) -> Vec<u32> {
            touched.map(|(v, _)| v).collect()
        }
    }

    impl WireApp for WireProbe {
        fn spec_bytes(&self) -> Vec<u8> {
            Vec::new()
        }
        fn from_spec(_r: &mut WireReader<'_>) -> WireResult<Self> {
            Ok(WireProbe)
        }
        fn enc_query(q: &u32, out: &mut Vec<u8>) {
            put_u32(out, *q);
        }
        fn dec_query(r: &mut WireReader<'_>) -> WireResult<u32> {
            r.u32()
        }
        fn enc_msg(m: &u32, out: &mut Vec<u8>) {
            put_u32(out, *m);
        }
        fn dec_msg(r: &mut WireReader<'_>) -> WireResult<u32> {
            r.u32()
        }
        fn enc_vq(vq: &u32, out: &mut Vec<u8>) {
            put_u32(out, *vq);
        }
        fn dec_vq(r: &mut WireReader<'_>) -> WireResult<u32> {
            r.u32()
        }
        fn enc_agg(a: &u64, out: &mut Vec<u8>) {
            put_u64(out, *a);
        }
        fn dec_agg(r: &mut WireReader<'_>) -> WireResult<u64> {
            r.u64()
        }
        fn enc_out(o: &Vec<u32>, out: &mut Vec<u8>) {
            put_u32(out, o.len() as u32);
            for v in o {
                put_u32(out, *v);
            }
        }
        fn dec_out(r: &mut WireReader<'_>) -> WireResult<Vec<u32>> {
            let n = r.count(4, "out count")?;
            let mut v = Vec::with_capacity(n);
            for _ in 0..n {
                v.push(r.u32()?);
            }
            Ok(v)
        }
    }

    #[test]
    fn column_body_round_trips_in_slot_order() {
        let slots: Vec<(VertexId, MsgSlot<u32>)> = vec![
            (3, MsgSlot::One(7)),
            (9, MsgSlot::Many(vec![1, 2, 3])),
            (4, MsgSlot::One(5)),
        ];
        let mut body = Vec::new();
        encode_column_body::<WireProbe>(&slots, &mut body);
        let ord = decode_column_body::<WireProbe>(&body).unwrap();
        assert_eq!(ord.slots.len(), slots.len());
        for ((d1, s1), (d2, s2)) in slots.iter().zip(ord.slots.iter()) {
            assert_eq!(d1, d2);
            assert_eq!(s1.as_slice(), s2.as_slice());
        }
        // Single-message slots come back in the inline representation.
        assert!(matches!(ord.slots[0].1, MsgSlot::One(_)));
        assert!(matches!(ord.slots[1].1, MsgSlot::Many(_)));
    }

    #[test]
    fn column_body_decode_rejects_corrupt_bytes_without_panicking() {
        let slots: Vec<(VertexId, MsgSlot<u32>)> =
            vec![(1, MsgSlot::One(2)), (3, MsgSlot::Many(vec![4, 5]))];
        let mut body = Vec::new();
        encode_column_body::<WireProbe>(&slots, &mut body);
        // Every truncation errors.
        for cut in 0..body.len() {
            assert!(decode_column_body::<WireProbe>(&body[..cut]).is_err());
        }
        // Oversized slot count.
        let mut bad = Vec::new();
        put_u32(&mut bad, u32::MAX);
        assert!(decode_column_body::<WireProbe>(&bad).is_err());
        // Zero-message slot.
        let mut bad = Vec::new();
        put_u32(&mut bad, 1);
        put_u32(&mut bad, 6);
        put_u32(&mut bad, 0);
        assert!(matches!(
            decode_column_body::<WireProbe>(&bad),
            Err(WireError::Corrupt("empty message slot"))
        ));
        // Message count beyond the post-combiner bound.
        let mut bad = Vec::new();
        put_u32(&mut bad, 1);
        put_u32(&mut bad, 6);
        put_u32(&mut bad, MAX_WIRE_MSGS_PER_SLOT as u32 + 1);
        assert!(matches!(
            decode_column_body::<WireProbe>(&bad),
            Err(WireError::Corrupt("message count out of range"))
        ));
        // Trailing garbage.
        let mut padded = body.clone();
        padded.push(0);
        assert!(decode_column_body::<WireProbe>(&padded).is_err());
    }

    #[test]
    fn result_codec_round_trips() {
        let res: QueryResult<Vec<u32>> = QueryResult {
            qid: 42,
            out: vec![1, 9, 17],
            stats: QueryStats {
                qid: 42,
                supersteps: 3,
                messages: 10,
                bytes: 80,
                touched: 5,
                access_rate: 0.5,
                arrived_at: 0.25,
                submitted_at: 0.25,
                started_at: 0.5,
                finished_at: 1.5,
                truncated: true,
                epoch: 2,
            },
        };
        let mut buf = Vec::new();
        encode_result::<WireProbe>(&res, &mut buf);
        let mut r = WireReader::new(&buf);
        let back = decode_result::<WireProbe>(&mut r).unwrap();
        r.expect_end().unwrap();
        assert_eq!(back.qid, res.qid);
        assert_eq!(back.out, res.out);
        assert_eq!(back.stats.supersteps, 3);
        assert_eq!(back.stats.messages, 10);
        assert_eq!(back.stats.bytes, 80);
        assert_eq!(back.stats.touched, 5);
        assert!(back.stats.truncated);
        assert_eq!(back.stats.epoch, 2);
        assert_eq!(back.stats.finished_at, 1.5);
        // Truncation and a bad bool both error, never panic.
        for cut in 0..buf.len() {
            let mut r = WireReader::new(&buf[..cut]);
            assert!(decode_result::<WireProbe>(&mut r).is_err());
        }
        let flag_pos = buf.len() - 9; // truncated byte sits before the u64 epoch
        let mut bad = buf.clone();
        bad[flag_pos] = 7;
        let mut r = WireReader::new(&bad);
        assert!(matches!(
            decode_result::<WireProbe>(&mut r),
            Err(WireError::Corrupt("truncated flag"))
        ));
    }

    #[test]
    fn procs_one_delegates_in_process_with_zero_wire_traffic() {
        let g = gen::twitter_like(120, 4, 1201);
        let cfg = EngineConfig {
            threads: 1,
            capacity: 4,
            admit: Admit::Static(4),
            sched: Sched::Stealing,
            pipeline: Pipeline::Off,
            ..EngineConfig::default()
        };
        let mut pe = ProcEngine::new(
            VersionedBfs::new(g.clone()),
            Cluster::new(4),
            120,
            cfg,
            1,
            &[],
        );
        let mut eng = Engine::with_config(VersionedBfs::new(g), Cluster::new(4), 120, cfg);
        for (s, t) in gen::random_pairs(120, 6, 1202) {
            pe.submit(vbfs_query(s, t));
            eng.submit(vbfs_query(s, t));
        }
        pe.run_until_idle();
        eng.run_until_idle();
        let got: Vec<_> = pe.take_results().into_iter().map(|r| (r.qid, r.out)).collect();
        let want: Vec<_> = eng.take_results().into_iter().map(|r| (r.qid, r.out)).collect();
        assert_eq!(got, want);
        assert_eq!(pe.metrics().bytes_on_wire, 0);
        assert_eq!(pe.metrics().rpc_round_trips, 0);
    }

    /// Worker entrypoint for the lib test binary: the coordinator spawns
    /// `current_exe()` with `--exact` on this test's full path, so the
    /// child runs exactly this body. Without the env knobs (every
    /// ordinary `cargo test` run) it is an immediate no-op pass.
    #[test]
    fn worker_entry() {
        maybe_serve_worker::<VersionedBfs>();
    }

    #[test]
    fn two_process_outputs_match_in_process_bit_for_bit() {
        let n = 200usize;
        let g = gen::twitter_like(n, 4, 907);
        let cfg = EngineConfig {
            threads: 1,
            capacity: 4,
            admit: Admit::Static(4),
            sched: Sched::Stealing,
            pipeline: Pipeline::Off,
            ..EngineConfig::default()
        };
        let pairs = gen::random_pairs(n, 8, 908);

        let mut eng =
            Engine::with_config(VersionedBfs::new(g.clone()), Cluster::new(4), n, cfg);
        for &(s, t) in &pairs {
            eng.submit(vbfs_query(s, t));
        }
        eng.run_until_idle();
        let want: Vec<_> = eng
            .take_results()
            .into_iter()
            .map(|r| (r.qid, r.stats.epoch, r.out))
            .collect();

        let mut pe = ProcEngine::new(
            VersionedBfs::new(g),
            Cluster::new(4),
            n,
            cfg,
            2,
            &libtest_worker_args("coordinator::remote::tests::worker_entry"),
        );
        for &(s, t) in &pairs {
            pe.submit(vbfs_query(s, t));
        }
        pe.run_until_idle();
        let got: Vec<_> = pe
            .take_results()
            .into_iter()
            .map(|r| (r.qid, r.stats.epoch, r.out))
            .collect();
        assert_eq!(got, want, "2-process results must replay in-process exactly");
        assert!(pe.metrics().bytes_on_wire > 0, "exchange must ride the wire");
        assert!(pe.metrics().rpc_round_trips > 0);
        pe.shutdown();
    }
}
