//! Persistent worker pool for the engine's parallel phases.
//!
//! PR 1 ran the compute phase on `std::thread::scope`, which spawns and
//! joins fresh OS threads every super-round — a recurring cost that lands
//! exactly in Quegel's regime of short, light supersteps (a query touches
//! few vertices, so a super-round is often microseconds of real work).
//! The pool replaces that with `threads` long-lived workers created once
//! per [`Engine`](super::Engine) and woken per phase through a
//! condvar-guarded job queue: the coordinator enqueues one closure per
//! worker-lane chunk (compute), destination-worker chunk (exchange) or
//! query chunk (fold), then blocks until every job of the batch has
//! finished. Because [`WorkerPool::run`] does not return before the batch
//! drains, jobs may safely borrow engine state for the duration of the
//! call — the same guarantee `std::thread::scope` gave, without the
//! per-round spawn/join tax.

use std::any::Any;
use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;

/// One unit of phase work: a boxed closure that may borrow engine state
/// for `'scope` (erased inside [`WorkerPool::run`], which outlives no
/// borrow because it blocks until the batch completes).
pub(crate) type Job<'scope> = Box<dyn FnOnce() + Send + 'scope>;

struct PoolState {
    /// Pending jobs of the current batch. Pop order is irrelevant: every
    /// job owns disjoint state, and whatever must be deterministic is
    /// folded in a fixed order by the coordinator afterwards.
    jobs: Vec<Job<'static>>,
    /// Jobs of the current batch not yet finished (queued + running).
    in_flight: usize,
    /// First panic payload of the current batch; resumed by `run` so the
    /// coordinator observes the original panic, as `std::thread::scope`
    /// would have surfaced it.
    panic: Option<Box<dyn Any + Send>>,
    shutdown: bool,
}

struct Shared {
    state: Mutex<PoolState>,
    /// Workers wait here for jobs (or shutdown).
    work_cv: Condvar,
    /// The coordinator waits here for batch completion.
    done_cv: Condvar,
}

/// A fixed-size pool of long-lived worker threads executing batches of
/// scoped jobs. Dropping the pool (e.g. dropping the engine mid-queue)
/// shuts every worker down and joins it — no thread outlives the pool.
pub(crate) struct WorkerPool {
    shared: Arc<Shared>,
    handles: Vec<JoinHandle<()>>,
}

impl WorkerPool {
    /// Spawn `threads` long-lived workers.
    pub fn new(threads: usize) -> Self {
        assert!(threads > 0, "pool needs at least one worker");
        let shared = Arc::new(Shared {
            state: Mutex::new(PoolState {
                jobs: Vec::new(),
                in_flight: 0,
                panic: None,
                shutdown: false,
            }),
            work_cv: Condvar::new(),
            done_cv: Condvar::new(),
        });
        let handles = (0..threads)
            .map(|i| {
                let shared = Arc::clone(&shared);
                std::thread::Builder::new()
                    .name(format!("quegel-worker-{i}"))
                    .spawn(move || worker_loop(&shared))
                    .expect("spawn pool worker")
            })
            .collect();
        Self { shared, handles }
    }

    /// Number of pool workers.
    #[allow(dead_code)]
    pub fn threads(&self) -> usize {
        self.handles.len()
    }

    /// Run one batch of jobs on the pool workers, blocking the caller
    /// until the last job finishes. A panic in any job is re-raised here
    /// after the whole batch drained, mirroring `std::thread::scope`.
    pub fn run<'scope>(&self, batch: Vec<Job<'scope>>) {
        if batch.is_empty() {
            return;
        }
        // SAFETY: `run` does not return until `in_flight == 0`, i.e. until
        // every job of the batch has been executed (or unwound) and
        // dropped. The worker-side decrement happens under the state mutex
        // strictly after the job ran, and the wait below re-reads the
        // counter under the same mutex, so all job effects happen-before
        // `run` returns; no borrow captured by a job outlives the true
        // `'scope` lifetime erased here.
        let batch: Vec<Job<'static>> = batch
            .into_iter()
            .map(|job| unsafe { std::mem::transmute::<Job<'scope>, Job<'static>>(job) })
            .collect();
        let mut st = self.shared.state.lock().unwrap();
        debug_assert_eq!(st.in_flight, 0, "WorkerPool::run is not reentrant");
        st.in_flight = batch.len();
        st.jobs.extend(batch);
        self.shared.work_cv.notify_all();
        while st.in_flight > 0 {
            st = self.shared.done_cv.wait(st).unwrap();
        }
        let panic = st.panic.take();
        drop(st);
        if let Some(payload) = panic {
            resume_unwind(payload);
        }
    }
}

impl Drop for WorkerPool {
    /// Wake every worker, have it exit, and join it. Runs whenever the
    /// owning engine is dropped — even with queries still queued — so no
    /// OS thread leaks past the engine's lifetime.
    fn drop(&mut self) {
        self.shared.state.lock().unwrap().shutdown = true;
        self.shared.work_cv.notify_all();
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

fn worker_loop(shared: &Shared) {
    loop {
        let job = {
            let mut st = shared.state.lock().unwrap();
            loop {
                if let Some(job) = st.jobs.pop() {
                    break Some(job);
                }
                if st.shutdown {
                    break None;
                }
                st = shared.work_cv.wait(st).unwrap();
            }
        };
        let Some(job) = job else { return };
        // Catch panics so the worker survives a failing job: the rest of
        // the batch still drains and `run` re-raises on the coordinator.
        let result = catch_unwind(AssertUnwindSafe(job));
        let mut st = shared.state.lock().unwrap();
        if let Err(payload) = result {
            // Keep the first payload; later ones are dropped (scope, too,
            // surfaces a single panic per batch).
            if st.panic.is_none() {
                st.panic = Some(payload);
            }
        }
        st.in_flight -= 1;
        if st.in_flight == 0 {
            shared.done_cv.notify_all();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn batch_runs_every_job_and_blocks_until_done() {
        let pool = WorkerPool::new(4);
        assert_eq!(pool.threads(), 4);
        let counter = AtomicUsize::new(0);
        for round in 0..10usize {
            let jobs: Vec<Job<'_>> = (0..16)
                .map(|_| {
                    Box::new(|| {
                        counter.fetch_add(1, Ordering::Relaxed);
                    }) as Job<'_>
                })
                .collect();
            pool.run(jobs);
            // run() is a barrier: every job of the batch has finished.
            assert_eq!(counter.load(Ordering::Relaxed), (round + 1) * 16);
        }
    }

    #[test]
    fn jobs_may_borrow_caller_state_mutably() {
        let pool = WorkerPool::new(3);
        let mut data = vec![0u64; 64];
        let jobs: Vec<Job<'_>> = data
            .chunks_mut(8)
            .enumerate()
            .map(|(i, chunk)| {
                Box::new(move || {
                    for (j, x) in chunk.iter_mut().enumerate() {
                        *x = (i * 8 + j) as u64;
                    }
                }) as Job<'_>
            })
            .collect();
        pool.run(jobs);
        for (i, x) in data.iter().enumerate() {
            assert_eq!(*x, i as u64);
        }
    }

    #[test]
    fn empty_batch_is_a_no_op() {
        let pool = WorkerPool::new(2);
        pool.run(Vec::new());
    }

    #[test]
    fn drop_joins_all_workers() {
        let pool = WorkerPool::new(2);
        pool.run(vec![Box::new(|| {}) as Job<'_>]);
        drop(pool); // must return (join), not hang
    }

    #[test]
    fn job_panic_propagates_and_pool_survives() {
        let pool = WorkerPool::new(2);
        let result = catch_unwind(AssertUnwindSafe(|| {
            pool.run(vec![Box::new(|| panic!("job panic (expected in test)")) as Job<'_>]);
        }));
        let payload = result.expect_err("run must re-raise job panics");
        // The original payload is preserved (resume_unwind, not a fresh
        // panic), matching std::thread::scope semantics.
        let msg = payload.downcast_ref::<&str>().copied().unwrap_or("");
        assert!(
            msg.contains("expected in test"),
            "original panic payload must survive, got {msg:?}"
        );
        // The pool stays usable after a panicking batch.
        let ok = AtomicUsize::new(0);
        pool.run(vec![Box::new(|| {
            ok.fetch_add(1, Ordering::Relaxed);
        }) as Job<'_>]);
        assert_eq!(ok.load(Ordering::Relaxed), 1);
    }
}
