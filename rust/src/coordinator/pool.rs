//! Persistent work-stealing worker pool for the engine's parallel phases.
//!
//! PR 1 ran the compute phase on `std::thread::scope`, which spawns and
//! joins fresh OS threads every super-round. PR 2 replaced that with long-
//! lived workers draining one *shared* job queue — cheap wakeups, but the
//! coordinator still enqueued one contiguous mega-chunk per thread, so a
//! hub-heavy worker lane serialized its whole chunk behind the slowest
//! item (exactly the static-scheduling under-utilization iPregel reports
//! for power-law graphs). This revision makes the pool a **work-stealing
//! scheduler**:
//!
//! * every pool thread owns a local job **deque**; [`WorkerPool::run`]
//!   distributes the batch round-robin across the deques (job `i` starts
//!   on deque `i mod threads`), so contiguous items spread over threads;
//! * an owner pops jobs from the *front* of its deque; a thread whose
//!   deque is empty scans the other deques and **steals from the back** of
//!   the first non-empty victim, so a heavy job never queues light ones
//!   behind it — the batch finishes when the slowest single *job* does,
//!   not the slowest static chunk;
//! * a thread parks on the pool condvar only after a full scan found every
//!   deque empty; batch publication bumps an epoch under the same lock, so
//!   a job can never be published-but-unseen while a worker goes to sleep
//!   (no lost wakeups), and threads that missed the notify re-scan on the
//!   epoch change.
//!
//! **Determinism argument:** stealing changes *which OS thread executes a
//! job*, never what the job does or in what order the coordinator consumes
//! job results. Each job owns disjoint engine state (a worker lane, one
//! destination worker's exchange column, one query's fold), every ordered
//! merge (source-worker delivery order inside a destination's exchange,
//! worker-order `agg_merge` inside a query's fold) happens *inside* a
//! single job or on the coordinator after [`WorkerPool::run`] returned, so
//! results are bit-identical for every thread count and every steal
//! schedule (pinned by `rust/tests/determinism.rs`).
//!
//! A panic in any job — stolen or home-run — is caught on the executing
//! worker, its original payload parked in the pool state, and re-raised by
//! `resume_unwind` on the submitting thread once the batch drained; the
//! workers themselves survive, so the pool stays usable and joinable on
//! drop (pinned by `rust/tests/pool_drop.rs`).
//!
//! Since the pipelined super-rounds (`Pipeline::On`), a batch may be
//! **heterogeneous**: per-(query, worker) step jobs next to deferred
//! reporting jobs, with phase *sequencing* handled inside the jobs
//! themselves (the last lane of a query to finish its compute runs the
//! query's exchange and fold inline — a readiness countdown, not a pool
//! feature). The determinism argument is unchanged: the countdown orders
//! a query's cascade strictly after every sibling step job regardless of
//! which threads ran them or in what order, and the coordinator still
//! consumes everything only after the full batch barrier.

use std::any::Any;
use std::collections::VecDeque;
use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;

/// One unit of phase work: a boxed closure that may borrow engine state
/// for `'scope` (erased inside [`WorkerPool::run`], which outlives no
/// borrow because it blocks until the batch completes).
pub(crate) type Job<'scope> = Box<dyn FnOnce() + Send + 'scope>;

/// What one [`WorkerPool::run`] batch did, for the engine's per-phase
/// scheduler metrics.
#[derive(Debug, Clone, Copy, Default)]
pub(crate) struct RunStats {
    /// Jobs executed (= batch size).
    pub jobs: u64,
    /// Jobs executed by a thread other than the one whose deque they were
    /// distributed to — the scheduler's load-balancing events.
    pub steals: u64,
}

struct PoolState {
    /// Jobs of the current batch not yet finished (queued + running).
    in_flight: usize,
    /// Batch sequence number: bumped after a batch's jobs are visible in
    /// the deques. A worker that found every deque empty compares this to
    /// the epoch it last synced on — unchanged means it may park; changed
    /// means a batch was published during its scan and it must re-scan.
    epoch: u64,
    /// Steals observed in the current batch (reset by `run`).
    steals: u64,
    /// First panic payload of the current batch; resumed by `run` so the
    /// coordinator observes the original panic, as `std::thread::scope`
    /// would have surfaced it.
    panic: Option<Box<dyn Any + Send>>,
    shutdown: bool,
}

struct Shared {
    /// One job deque per pool thread. The owner pops from the front; idle
    /// threads steal from the back. Plain mutex-guarded deques (no lock-
    /// free Chase–Lev here): jobs are lane-/query-sized, so the lock is
    /// taken once per job, far off the hot path.
    deques: Vec<Mutex<VecDeque<Job<'static>>>>,
    state: Mutex<PoolState>,
    /// Workers park here when every deque is empty (or on shutdown).
    work_cv: Condvar,
    /// The coordinator waits here for batch completion.
    done_cv: Condvar,
}

/// A fixed-size pool of long-lived worker threads executing batches of
/// scoped jobs with work stealing. Dropping the pool (e.g. dropping the
/// engine mid-queue) shuts every worker down and joins it — no thread
/// outlives the pool.
pub(crate) struct WorkerPool {
    shared: Arc<Shared>,
    handles: Vec<JoinHandle<()>>,
}

impl WorkerPool {
    /// Spawn `threads` long-lived workers, each owning one steal deque.
    pub fn new(threads: usize) -> Self {
        assert!(threads > 0, "pool needs at least one worker");
        let shared = Arc::new(Shared {
            deques: (0..threads).map(|_| Mutex::new(VecDeque::new())).collect(),
            state: Mutex::new(PoolState {
                in_flight: 0,
                epoch: 0,
                steals: 0,
                panic: None,
                shutdown: false,
            }),
            work_cv: Condvar::new(),
            done_cv: Condvar::new(),
        });
        let handles = (0..threads)
            .map(|i| {
                let shared = Arc::clone(&shared);
                std::thread::Builder::new()
                    .name(format!("quegel-worker-{i}"))
                    .spawn(move || worker_loop(&shared, i))
                    .expect("spawn pool worker")
            })
            .collect();
        Self { shared, handles }
    }

    /// Number of pool workers.
    pub fn threads(&self) -> usize {
        self.handles.len()
    }

    /// Run one batch of jobs on the pool workers, blocking the caller
    /// until the last job finishes, and report how the batch was
    /// scheduled. A panic in any job is re-raised here after the whole
    /// batch drained, mirroring `std::thread::scope`.
    pub fn run<'scope>(&self, batch: Vec<Job<'scope>>) -> RunStats {
        let n = batch.len();
        if n == 0 {
            return RunStats::default();
        }
        // SAFETY: `run` does not return until `in_flight == 0`, i.e. until
        // every job of the batch has been executed (or unwound) and
        // dropped. The worker-side decrement happens under the state mutex
        // strictly after the job ran, and the wait below re-reads the
        // counter under the same mutex, so all job effects happen-before
        // `run` returns; no borrow captured by a job outlives the true
        // `'scope` lifetime erased here. Stealing moves jobs between
        // deques' consumers, never past the end of the batch.
        let batch: Vec<Job<'static>> = batch
            .into_iter()
            .map(|job| unsafe { std::mem::transmute::<Job<'scope>, Job<'static>>(job) })
            .collect();
        // Publish the batch size *before* any job becomes visible: a
        // worker may pop and finish a job while we are still distributing
        // the rest, and its decrement must never underflow.
        {
            let mut st = self.shared.state.lock().unwrap();
            debug_assert_eq!(st.in_flight, 0, "WorkerPool::run is not reentrant");
            st.in_flight = n;
            st.steals = 0;
        }
        // Round-robin distribution: job i starts on deque i mod threads,
        // so every thread has local work and contiguous items (adjacent
        // worker lanes, consecutive queries, neighboring sub-ranges of a
        // split task) spread across threads. Group each deque's strided
        // share first and take every deque lock exactly once: sub-lane
        // splitting made batches much larger than the thread count, and
        // one lock per *job* would contend with workers already draining
        // the deques mid-distribution. Placement and per-deque FIFO order
        // are identical to the per-job loop this replaces.
        let k = self.shared.deques.len();
        let mut shares: Vec<Vec<Job<'static>>> =
            (0..k).map(|_| Vec::with_capacity(n.div_ceil(k))).collect();
        for (i, job) in batch.into_iter().enumerate() {
            shares[i % k].push(job);
        }
        for (deque, share) in self.shared.deques.iter().zip(shares) {
            deque.lock().unwrap().extend(share);
        }
        // Bump the epoch only now that every job is findable by a scan,
        // then wake the workers. Parking re-checks the epoch under this
        // same lock, so no worker can sleep through the publication.
        let mut st = self.shared.state.lock().unwrap();
        st.epoch += 1;
        self.shared.work_cv.notify_all();
        while st.in_flight > 0 {
            st = self.shared.done_cv.wait(st).unwrap();
        }
        let stats = RunStats {
            jobs: n as u64,
            steals: st.steals,
        };
        let panic = st.panic.take();
        drop(st);
        if let Some(payload) = panic {
            resume_unwind(payload);
        }
        stats
    }
}

impl Drop for WorkerPool {
    /// Wake every worker, have it exit, and join it. Runs whenever the
    /// owning engine is dropped — even with queries still queued — so no
    /// OS thread leaks past the engine's lifetime.
    fn drop(&mut self) {
        self.shared.state.lock().unwrap().shutdown = true;
        self.shared.work_cv.notify_all();
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

/// One pool thread: pop the own deque's front; failing that, steal from
/// the back of the first non-empty victim (scan starting at the next
/// index, wrapping); failing that, park until a new batch is published or
/// the pool shuts down.
fn worker_loop(shared: &Shared, me: usize) {
    let k = shared.deques.len();
    let mut seen_epoch = 0u64;
    loop {
        // Each lock lives for exactly one statement: a worker never holds
        // its own deque's lock while probing a victim's (two scanning
        // workers locking each other's deques would deadlock).
        let local = shared.deques[me].lock().unwrap().pop_front();
        let mut fetched: Option<(Job<'static>, bool)> = local.map(|job| (job, false));
        if fetched.is_none() {
            for i in 1..k {
                let victim = (me + i) % k;
                let stolen = shared.deques[victim].lock().unwrap().pop_back();
                if let Some(job) = stolen {
                    fetched = Some((job, true));
                    break;
                }
            }
        }
        match fetched {
            Some((job, stolen)) => {
                // Catch panics so the worker survives a failing job: the
                // rest of the batch still drains and `run` re-raises the
                // original payload on the coordinator — also when the
                // panicking job was a stolen one.
                let result = catch_unwind(AssertUnwindSafe(job));
                let mut st = shared.state.lock().unwrap();
                if let Err(payload) = result {
                    // Keep the first payload; later ones are dropped
                    // (scope, too, surfaces a single panic per batch).
                    if st.panic.is_none() {
                        st.panic = Some(payload);
                    }
                }
                if stolen {
                    st.steals += 1;
                }
                st.in_flight -= 1;
                if st.in_flight == 0 {
                    shared.done_cv.notify_all();
                }
            }
            None => {
                let mut st = shared.state.lock().unwrap();
                if st.shutdown {
                    return;
                }
                if st.epoch == seen_epoch {
                    // Nothing was published since the scan above came up
                    // empty, so parking cannot strand a job: publication
                    // bumps the epoch under this lock and notifies.
                    st = shared.work_cv.wait(st).unwrap();
                    if st.shutdown {
                        return;
                    }
                }
                seen_epoch = st.epoch;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};

    #[test]
    fn batch_runs_every_job_and_blocks_until_done() {
        let pool = WorkerPool::new(4);
        assert_eq!(pool.threads(), 4);
        let counter = AtomicUsize::new(0);
        for round in 0..10usize {
            let jobs: Vec<Job<'_>> = (0..16)
                .map(|_| {
                    Box::new(|| {
                        counter.fetch_add(1, Ordering::Relaxed);
                    }) as Job<'_>
                })
                .collect();
            let stats = pool.run(jobs);
            assert_eq!(stats.jobs, 16);
            // run() is a barrier: every job of the batch has finished.
            assert_eq!(counter.load(Ordering::Relaxed), (round + 1) * 16);
        }
    }

    #[test]
    fn jobs_may_borrow_caller_state_mutably() {
        let pool = WorkerPool::new(3);
        let mut data = vec![0u64; 64];
        let jobs: Vec<Job<'_>> = data
            .chunks_mut(8)
            .enumerate()
            .map(|(i, chunk)| {
                Box::new(move || {
                    for (j, x) in chunk.iter_mut().enumerate() {
                        *x = (i * 8 + j) as u64;
                    }
                }) as Job<'_>
            })
            .collect();
        pool.run(jobs);
        for (i, x) in data.iter().enumerate() {
            assert_eq!(*x, i as u64);
        }
    }

    #[test]
    fn empty_batch_is_a_no_op() {
        let pool = WorkerPool::new(2);
        let stats = pool.run(Vec::new());
        assert_eq!(stats.jobs, 0);
        assert_eq!(stats.steals, 0);
    }

    #[test]
    fn drop_joins_all_workers() {
        let pool = WorkerPool::new(2);
        pool.run(vec![Box::new(|| {}) as Job<'_>]);
        drop(pool); // must return (join), not hang
    }

    /// Deterministic steal: job 0 lands on deque 0 and spins until every
    /// light job has run — the lights round-robined onto deque 0 behind it
    /// can only be executed by the *other* thread stealing them, so the
    /// batch both terminates and records steals in every interleaving.
    #[test]
    fn stealing_engages_when_one_job_blocks_its_owner() {
        const LIGHT: usize = 8;
        let pool = WorkerPool::new(2);
        let done = AtomicUsize::new(0);
        let blocker: Job<'_> = Box::new(|| {
            while done.load(Ordering::SeqCst) < LIGHT {
                std::thread::yield_now();
            }
        });
        let mut jobs: Vec<Job<'_>> = vec![blocker];
        for _ in 0..LIGHT {
            jobs.push(Box::new(|| {
                done.fetch_add(1, Ordering::SeqCst);
            }));
        }
        let stats = pool.run(jobs);
        assert_eq!(stats.jobs, (LIGHT + 1) as u64);
        assert!(
            stats.steals > 0,
            "a blocked owner with queued jobs must be stolen from"
        );
        assert_eq!(done.load(Ordering::SeqCst), LIGHT);
    }

    #[test]
    fn job_panic_propagates_and_pool_survives() {
        let pool = WorkerPool::new(2);
        let result = catch_unwind(AssertUnwindSafe(|| {
            pool.run(vec![Box::new(|| panic!("job panic (expected in test)")) as Job<'_>]);
        }));
        let payload = result.expect_err("run must re-raise job panics");
        // The original payload is preserved (resume_unwind, not a fresh
        // panic), matching std::thread::scope semantics.
        let msg = payload.downcast_ref::<&str>().copied().unwrap_or("");
        assert!(
            msg.contains("expected in test"),
            "original panic payload must survive, got {msg:?}"
        );
        // The pool stays usable after a panicking batch.
        let ok = AtomicUsize::new(0);
        pool.run(vec![Box::new(|| {
            ok.fetch_add(1, Ordering::Relaxed);
        }) as Job<'_>]);
        assert_eq!(ok.load(Ordering::Relaxed), 1);
    }

    /// The stolen-job panic path: deque 0 holds [blocker, panicker] (jobs
    /// 0 and 2 of the round-robin), and the blocker spins until the
    /// panicker has run — so the panicker is necessarily executed by the
    /// other thread, i.e. stolen. Its original payload must still surface
    /// on the submitting thread and the pool must stay usable + joinable.
    #[test]
    fn panic_in_a_stolen_job_reraises_original_payload() {
        let pool = WorkerPool::new(2);
        let panicked = AtomicBool::new(false);
        let jobs: Vec<Job<'_>> = vec![
            // Job 0 -> deque 0 front: holds its owner hostage.
            Box::new(|| {
                while !panicked.load(Ordering::SeqCst) {
                    std::thread::yield_now();
                }
            }),
            // Job 1 -> deque 1: keeps the thief's own deque non-trivial.
            Box::new(|| {}),
            // Job 2 -> deque 0 back: flags, then panics — on the thief.
            Box::new(|| {
                panicked.store(true, Ordering::SeqCst);
                panic!("stolen job panic (expected in test)");
            }),
            // Job 3 -> deque 1.
            Box::new(|| {}),
        ];
        let result = catch_unwind(AssertUnwindSafe(|| pool.run(jobs)));
        let payload = result.expect_err("run must re-raise a stolen job's panic");
        let msg = payload.downcast_ref::<&str>().copied().unwrap_or("");
        assert!(
            msg.contains("expected in test"),
            "stolen job's original panic payload must survive, got {msg:?}"
        );
        // Still usable after the panicking batch...
        let stats = pool.run(vec![Box::new(|| {}) as Job<'_>]);
        assert_eq!(stats.jobs, 1);
        // ...and joinable (drop must not hang on a wedged worker).
        drop(pool);
    }
}
