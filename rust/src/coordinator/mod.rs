//! The Quegel coordinator: superstep-sharing execution engine (paper §3).
//!
//! Queries are processed in **super-rounds**: every in-flight query advances
//! one superstep per super-round, and one message/aggregator barrier is paid
//! per super-round instead of one per query-superstep. At most `capacity`
//! (the paper's `C`) queries are in flight; new queries wait in a FIFO
//! queue. Per-query VQ-data is allocated lazily — a vertex gets state for
//! query `q` only when `q` first touches it.

mod engine;
mod query;

pub use engine::Engine;
pub use query::{QueryResult, VState};
