//! The Quegel coordinator: superstep-sharing execution engine (paper §3).
//!
//! Queries are processed in **super-rounds**: every in-flight query advances
//! one superstep per super-round, and one message/aggregator barrier is paid
//! per super-round instead of one per query-superstep. At most `capacity`
//! (the paper's `C`) queries are in flight; new queries wait in a FIFO
//! queue. Per-query VQ-data is allocated lazily — a vertex gets state for
//! query `q` only when `q` first touches it.
//!
//! Workers are real: each query's state is split into per-worker
//! `WorkerShard`s, and every super-round runs three phases on a persistent
//! work-stealing [`pool`] of up to `Engine::threads` OS threads (created
//! once per engine, woken per phase): **compute** (worker lanes, disjoint
//! state), **exchange** (destination-sharded message routing — each
//! destination worker drains its column of the staging matrix in
//! source-worker order, concurrently with every other destination), and
//! **fold** (per-query aggregator fold in worker order + lifecycle,
//! parallel across queries). Under the default [`Sched::Stealing`]
//! granularity each lane / destination / query is its own pool job, and
//! idle pool threads steal queued jobs from the back of busy threads'
//! deques, so a hub-heavy partition never pins a phase on one thread.
//!
//! Since the sub-lane split ([`Split`]), even ONE pathological lane is no
//! longer atomic: a compute task whose active/receiving vertex count
//! crosses the split threshold is transposed into its serial work-item
//! order and cut into contiguous sub-ranges, each its own pool job with
//! private staging; a merge pass folds the sub-buffers back in sub-range
//! order, replaying exactly the serial message sequences.
//!
//! Since the edge-level split ([`EdgeSplit`]), even ONE vertex is no
//! longer atomic: a `compute()` call whose fanout crosses the edge-split
//! threshold has its outbox parked and cut into contiguous
//! **(vertex, edge-range)** tasks — the second, finer compute granularity
//! below the (query, worker, vertex-range) sub-job. Each range stages its
//! slice of the fan into a private insertion-ordered buffer; everything
//! the task stages after the fan is captured in overflow segments; and
//! the merge replays ranges and segments in exact send order,
//! destination-sharded so the fold of a mega-fanout is itself parallel
//! across workers' staging maps.
//!
//! Since the pipelined super-rounds ([`Pipeline`]), the three phases are
//! no longer global barriers either: under `Pipeline::On` a super-round is
//! ONE pool batch of per-(query, worker) step jobs, and the last lane of
//! each query to finish its compute ships that query's staged columns and
//! runs its fold immediately — fast queries drain through exchange and
//! fold while a skewed query's heavy lane is still computing, and the
//! reporting supersteps of queries that converged last round run as jobs
//! of the same batch, overlapped with this round's compute.
//!
//! Since the flat memory layout ([`Layout`]), the per-query stores behind
//! all of the above are no longer hash maps by default: `Layout::Flat`
//! keeps each shard's VQ-data in a slab arena with a dense
//! `VertexId → u32` handle table (first-touch order recorded explicitly),
//! its inbox as message slots plus a delivery-order list inside the same
//! arena, and the per-destination staging as insertion-ordered columnar
//! buffers — so the compute and exchange inner loops walk contiguous
//! memory instead of hashing. `Layout::Hashed` keeps the original maps as
//! the benchmark baseline.
//!
//! Since the serving layer ([`Admit`]), the engine is no longer only a
//! batch runner: requests enter through a (optionally bounded) submission
//! queue with back-pressure (`Engine::try_submit` hands a request back
//! when the bound is hit, and `QueryStats` keeps arrival separate from
//! queue entry so the wait stays visible in the latency percentiles), and
//! each super-round's admission is planned rather than a blind FIFO drain.
//! Under `Admit::Adaptive` (the default) light queries still flow FIFO up
//! to capacity, but queries the app flagged as whales at submission
//! (`QueryApp::is_heavy`, e.g. hub2 pairs with a large index bound
//! `d_ub`) are confined to a reserved capacity slice — squeezed while the
//! previous round was message-saturated with lights waiting — so one
//! whale can't inflate every co-resident point lookup's super-round
//! count. The planner reads deterministic inputs only (queue contents,
//! prior-round integer counters); `EngineMetrics` gains streaming
//! p50/p99/p999 latency and queueing sketches plus an `admit_deferrals`
//! engagement counter.
//!
//! Since streaming mutations ([`Engine::try_mutate`]), the graph is no
//! longer frozen at load: mutation batches
//! ([`crate::graph::MutationBatch`] — edge/vertex insert/delete) queue on
//! the same simulated clock as submissions and are applied **only at
//! super-round boundaries**, each applied batch bumping a monotonically
//! increasing epoch ([`crate::graph::VersionedGraph`]). A query pins the
//! epoch current at its *admission* (stamped into `QueryStats::epoch`)
//! and reads that snapshot for its whole lifetime through per-vertex
//! delta overlays on the base CSR; once every in-flight and pending
//! report has retired past an epoch, the engine tells the app to compact
//! overlays into the base (`QueryApp::retire_epochs`, surfaced as the
//! `epochs_applied` / `oldest_pinned_epoch` / `delta_bytes_peak` gauges
//! in `EngineMetrics`). Apps opt in via `QueryApp::supports_mutations`;
//! `try_mutate` on an immutable app is an error, never a silent drop.
//!
//! The determinism argument is uniform: stealing moves jobs between
//! executors, splitting (either granularity) re-groups a fixed serial
//! order, pipelining only *re-times* each query's private
//! exchange-then-fold cascade (per-query state is disjoint; the delivery
//! replay inside the cascade is the barrier path's source-order sequence),
//! and the layout only moves where state lives (the flat stores record the
//! very first-touch/delivery orders the hashed path pinned implicitly)
//! — every order-sensitive merge (message delivery, aggregator fold,
//! sub-buffer and edge-range absorption) replays that order inside a
//! single job or on the coordinator, and the admission planner decides
//! only *when* a query runs, never what it computes — so every thread
//! count, scheduler, split, edge-split, pipeline, layout and admission
//! setting produces bit-identical per-query results (see
//! `rust/tests/determinism.rs` and the randomized matrix in
//! `rust/tests/fuzz_determinism.rs`). The mutation axis extends rather
//! than weakens this: boundary-only application plus admission-time
//! pinning make every query's output a pure function of
//! (pinned version, query), bit-identical to a serial replay on the
//! `Graph::apply`-folded snapshot of its pinned epoch. Axes that cannot
//! re-time admission (threads, scheduler, layout, splits) must also
//! agree bit-for-bit on the `(epoch, out)` record stream; axes that
//! legitimately may (pipelining, adaptive admission) are held to the
//! per-run snapshot oracle. Both gates run in the same two suites, plus
//! the mutation-schedule fuzzer's randomized interleavings.
//!
//! Since the multi-process mode ([`remote::ProcEngine`]), the engine's
//! process boundary is explicit: one coordinator process plus N worker
//! processes (children of the same binary) connected over localhost TCP
//! with the crate's length-prefixed framing. The whole configuration
//! travels as one serializable [`EngineConfig`] — built in code or from
//! the environment once, on the coordinator, via
//! [`EngineConfig::from_env`], then shipped in its byte codec at the
//! handshake so remote shards run under bit-identical knobs without
//! re-reading any environment. Admission, epoch pinning, the aggregator
//! fold and the simulated clock stay on the coordinator; compute and
//! message delivery run in the workers with the destination-sharded
//! exchange riding the wire through the same `merge_msg` chokepoints in
//! the same source order — so the process count joins threads, scheduler,
//! splits, layout and admission as one more axis the bit-identical output
//! contract quantifies over.

mod arena;
mod engine;
mod pool;
mod query;
pub mod remote;

pub use arena::Layout;
pub use engine::{Admit, EdgeSplit, Engine, EngineConfig, Pipeline, Sched, Split};
pub use query::{QueryResult, VState};
pub use remote::{
    libtest_worker_args, maybe_serve_worker, procs_from_env, ProcEngine, WireApp,
};
