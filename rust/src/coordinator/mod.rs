//! The Quegel coordinator: superstep-sharing execution engine (paper §3).
//!
//! Queries are processed in **super-rounds**: every in-flight query advances
//! one superstep per super-round, and one message/aggregator barrier is paid
//! per super-round instead of one per query-superstep. At most `capacity`
//! (the paper's `C`) queries are in flight; new queries wait in a FIFO
//! queue. Per-query VQ-data is allocated lazily — a vertex gets state for
//! query `q` only when `q` first touches it.
//!
//! Workers are real: each query's state is split into per-worker
//! `WorkerShard`s, and the compute phase runs worker lanes on up
//! to `Engine::threads` scoped OS threads. Message exchange and the
//! per-worker aggregator fold happen at the single-threaded barrier, in
//! worker order, so every thread count produces bit-identical results
//! (see `rust/tests/determinism.rs`).

mod engine;
mod query;

pub use engine::Engine;
pub use query::{QueryResult, VState};
