//! Per-query runtime state: the rust analog of the paper's Q-data entry in
//! `HT_Q` plus the per-worker shards of VQ-data and message stores — and,
//! since the sub-lane split, the primitives that let ONE shard's compute
//! work be cut into independently schedulable sub-ranges ([`WorkItem`],
//! [`SubBuf`], [`WorkerShard::split_items`], [`WorkerShard::absorb_control`])
//! without changing a single output bit.
//!
//! Since the edge-level split there is a second, finer granularity below
//! the (query, worker, vertex-range) sub-job: ONE vertex whose `compute()`
//! stages a mega-fanout is no longer an indivisible work item either. Its
//! outbox is *parked* as a [`FanTask`] inside a segmented [`StageStream`],
//! cut into contiguous **edge ranges** staged by dedicated pool jobs into
//! private insertion-ordered buffers, and folded back in fixed range order
//! through the same [`merge_msg`] combiner replay the sub-staging merge and
//! the exchange already use — so the staging map's key-insertion history,
//! and with it every downstream hash-iteration order, stays bit-identical
//! to an unsplit run.

use std::collections::hash_map::Entry;

use super::arena::{Layout, StagedBuf, VStore};
use crate::graph::VertexId;
use crate::metrics::QueryStats;
use crate::util::FxHashMap;
use crate::vertex::{QueryApp, QueryId};

/// Append `m` to `into`, first offering it to the sender-side combiner
/// against the slot head. Used both when staging (compute phase) and when
/// the exchange phase delivers cross-shard slots — the single rule that
/// makes the per-shard staging buffers reproduce, message for message, what
/// one shared staging buffer would have held. Returns the number of
/// messages added (0 when combined away).
///
/// This is the *only* way messages enter a slot: the old `MsgSlot::merge`
/// convenience silently bypassed [`QueryApp::combine`] and was removed in
/// its favor.
pub(crate) fn merge_msg<A: QueryApp>(app: &A, into: &mut MsgSlot<A::Msg>, m: A::Msg) -> u64 {
    if let Some(first) = into.first_mut() {
        if app.combine(first, &m) {
            return 0;
        }
    }
    into.push(m);
    1
}

/// Drain one source shard's staging map into a destination inbox,
/// replaying the sender-side combiner per message through [`merge_msg`] —
/// the single delivery rule shared by the barrier exchange lanes and the
/// pipelined eager column handoff, so the two paths can never diverge.
/// Returns messages delivered (post-combiner); leaves `srcmap` empty with
/// its capacity kept.
pub(crate) fn deliver_map<A: QueryApp>(
    app: &A,
    inbox: &mut FxHashMap<VertexId, MsgSlot<A::Msg>>,
    srcmap: &mut FxHashMap<VertexId, MsgSlot<A::Msg>>,
) -> u64 {
    if srcmap.is_empty() {
        return 0; // skip the W²-mostly-empty buckets cheaply
    }
    let mut delivered = 0u64;
    for (dst, slot) in srcmap.drain() {
        match inbox.entry(dst) {
            Entry::Occupied(mut e) => {
                let into = e.get_mut();
                match slot {
                    MsgSlot::One(m) => delivered += merge_msg(app, into, m),
                    MsgSlot::Many(ms) => {
                        for m in ms {
                            delivered += merge_msg(app, into, m);
                        }
                    }
                }
            }
            Entry::Vacant(e) => {
                delivered += slot.len() as u64;
                e.insert(slot); // moves, no allocation
            }
        }
    }
    delivered
}

/// Per-vertex, per-query state (one `LUT_v[q]` entry): the vertex value
/// `a_q(v)` plus the halted flag and a stamp to dedup processing within a
/// super-round.
#[derive(Debug, Clone)]
pub struct VState<VQ> {
    pub vq: VQ,
    pub halted: bool,
    pub(crate) computed_step: u64,
}

/// Message storage per destination vertex: the overwhelmingly common case
/// after sender-side combining is a single message, which this enum keeps
/// inline (no heap allocation on either side of the barrier).
#[derive(Debug, Clone)]
pub enum MsgSlot<M> {
    One(M),
    Many(Vec<M>),
}

impl<M> MsgSlot<M> {
    #[inline]
    pub fn push(&mut self, m: M) {
        match self {
            MsgSlot::One(_) => {
                let MsgSlot::One(first) = std::mem::replace(self, MsgSlot::Many(Vec::new()))
                else {
                    unreachable!()
                };
                let MsgSlot::Many(v) = self else { unreachable!() };
                v.reserve(4);
                v.push(first);
                v.push(m);
            }
            MsgSlot::Many(v) => v.push(m),
        }
    }

    #[inline]
    pub fn len(&self) -> usize {
        match self {
            MsgSlot::One(_) => 1,
            MsgSlot::Many(v) => v.len(),
        }
    }

    /// True when the slot holds no message (only possible for a drained
    /// `Many`).
    #[allow(dead_code)]
    #[inline]
    pub fn is_empty(&self) -> bool {
        matches!(self, MsgSlot::Many(v) if v.is_empty())
    }

    /// View as a slice (One is a 1-element slice via `slice::from_ref`).
    #[inline]
    pub fn as_slice(&self) -> &[M] {
        match self {
            MsgSlot::One(m) => std::slice::from_ref(m),
            MsgSlot::Many(v) => v.as_slice(),
        }
    }

    /// First message, mutable (combiner target).
    #[inline]
    pub fn first_mut(&mut self) -> Option<&mut M> {
        match self {
            MsgSlot::One(m) => Some(m),
            MsgSlot::Many(v) => v.first_mut(),
        }
    }
}

/// Completed-query record handed back to the submitter.
#[derive(Debug, Clone)]
pub struct QueryResult<Out> {
    pub qid: QueryId,
    pub out: Out,
    pub stats: QueryStats,
}

/// Lifecycle phase of an in-flight query.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub(crate) enum Phase {
    /// Executing supersteps.
    Running,
    /// Converged/terminated; the next super-round is the reporting round.
    Reporting,
}

/// One worker's slice of one in-flight query: everything the worker thread
/// mutates during the compute phase. Shards of the same query are disjoint,
/// so the engine can hand shard `w` of every query to a pool worker without
/// synchronization; cross-shard traffic flows only through `staged`, which
/// is keyed by destination worker so the exchange phase can route every
/// destination's column of the staging matrix concurrently (the maps are
/// taken from the shards for the duration of the phase and handed back).
pub(crate) struct WorkerShard<A: QueryApp> {
    /// VQ-data table + inbox of this worker, in the engine's
    /// [`Layout`]: hash maps (`Layout::Hashed`) or a slab arena with a
    /// dense handle table (`Layout::Flat`). Lazy either way: only touched
    /// vertices are present.
    pub store: VStore<A>,
    /// Active list (vertices that did not vote halt).
    pub active: Vec<VertexId>,
    /// Staged outgoing messages, keyed by destination worker then by
    /// destination vertex (reused across rounds; exchanged at the
    /// barrier). Hash maps under `Layout::Hashed`, insertion-ordered
    /// columnar buffers under `Layout::Flat` — same [`merge_msg`]
    /// combining either way.
    pub staged: Vec<StagedBuf<A>>,
    /// This worker's aggregator partial for the current superstep (folded
    /// across shards in worker order by the fold phase, then reset).
    pub agg_round: A::Agg,
    /// Set when a vertex on this shard called `force_terminate` (OR-folded
    /// into the query flag by the fold phase).
    pub terminated: bool,
}

impl<A: QueryApp> WorkerShard<A> {
    /// `n_vertices` is the vertex-slot count of the graph version this
    /// query reads (the epoch pinned at admission under streaming
    /// mutations): the flat layout pre-sizes its handle table to the
    /// worker's share of that id space, so mid-flight epoch bumps never
    /// reshape a live table.
    pub(crate) fn new(workers: usize, layout: Layout, n_vertices: usize) -> Self {
        Self {
            store: VStore::with_vertex_hint(layout, workers, n_vertices),
            active: Vec::new(),
            staged: (0..workers).map(|_| StagedBuf::new(layout)).collect(),
            agg_round: A::Agg::default(),
            terminated: false,
        }
    }

    /// Transpose this shard's superstep into an explicit work-item list so
    /// the compute can be cut into contiguous sub-ranges. The list order is
    /// EXACTLY the order the serial loop would have processed: message
    /// receivers in inbox drain order (hashed: map iteration order; flat:
    /// the `recv` delivery-order list), then still-active vertices that
    /// received nothing, in active-list order. VQ-data entries for new
    /// receivers are inserted here, in that same order, so the touched
    /// iteration order the reporting round sees is identical to an unsplit
    /// run's under either layout.
    ///
    /// Items carry raw pointers to their `VState` slots, collected in a
    /// second pass after every insertion is done (hashed: insertions may
    /// rehash the map and move values; flat: the arena's `state` vector
    /// never grows here, since every receiver's slot was allocated at
    /// delivery — either way nothing mutates the store's structure until
    /// the merge, so the pointers stay valid through the sub-jobs).
    /// Distinct vertices own distinct slots, so sub-jobs over disjoint item
    /// ranges never alias.
    /// `ptr_index` is caller-provided scratch (recycled across rounds) for
    /// the pointer-collection pass; it is cleared before use.
    pub(crate) fn split_items(
        &mut self,
        app: &A,
        query: &A::Query,
        step: u64,
        items: &mut Vec<WorkItem<A>>,
        ptr_index: &mut FxHashMap<VertexId, usize>,
    ) {
        debug_assert!(items.is_empty());
        match &mut self.store {
            VStore::Hashed { vstate, inbox } => {
                let mut inbox_now = std::mem::take(inbox);
                for (v, slot) in inbox_now.drain() {
                    let st = vstate.entry(v).or_insert_with(|| VState {
                        vq: app.init_value(query, v),
                        halted: false,
                        computed_step: 0,
                    });
                    st.halted = false;
                    st.computed_step = step;
                    items.push(WorkItem {
                        v,
                        st: SendPtr(std::ptr::null_mut()),
                        msgs: Some(slot),
                    });
                }
                // Recycle the inbox map's capacity (the exchange phase
                // refills it), exactly like the serial path does.
                *inbox = inbox_now;
                let prev_active = std::mem::take(&mut self.active);
                for v in &prev_active {
                    let st = vstate.get_mut(v).expect("active implies state");
                    if st.halted || st.computed_step == step {
                        continue;
                    }
                    st.computed_step = step;
                    items.push(WorkItem {
                        v: *v,
                        st: SendPtr(std::ptr::null_mut()),
                        msgs: None,
                    });
                }
                // Reuse the old active vec's capacity as the merge target.
                let mut prev_active = prev_active;
                prev_active.clear();
                self.active = prev_active;
                // Second pass: all insertions are done, so the slots are
                // stable. Collect every pointer in ONE mutable traversal
                // of the map: a get_mut per item would reborrow the whole
                // map each time, which under the Stacked Borrows aliasing
                // model invalidates the pointers collected before it —
                // one traversal keeps the split path Miri-clean. (The
                // traversal is O(|vstate|), i.e. every vertex the query
                // ever touched, not just the frontier — the price of the
                // aliasing-clean collection; splitting only fires on
                // heavy rounds, whose compute dwarfs a flat table scan.)
                ptr_index.clear();
                for (i, item) in items.iter().enumerate() {
                    ptr_index.insert(item.v, i);
                }
                for (v, st) in vstate.iter_mut() {
                    if let Some(&i) = ptr_index.get(v) {
                        items[i].st = SendPtr(st);
                    }
                }
            }
            VStore::Flat(fs) => {
                let recv_now = std::mem::take(&mut fs.recv);
                for &h in &recv_now {
                    let v = fs.verts[h as usize];
                    let slot = fs.msg[h as usize].take().expect("recv implies pending slot");
                    let st_slot = &mut fs.state[h as usize];
                    if st_slot.is_none() {
                        *st_slot = Some(VState {
                            vq: app.init_value(query, v),
                            halted: false,
                            computed_step: 0,
                        });
                        fs.n_state += 1;
                    }
                    let st = st_slot.as_mut().expect("just ensured");
                    st.halted = false;
                    st.computed_step = step;
                    items.push(WorkItem {
                        v,
                        st: SendPtr(std::ptr::null_mut()),
                        msgs: Some(slot),
                    });
                }
                // Recycle the delivery-order list's capacity (the
                // exchange phase refills it).
                let mut recv_now = recv_now;
                recv_now.clear();
                fs.recv = recv_now;
                let prev_active = std::mem::take(&mut self.active);
                for v in &prev_active {
                    let h = fs.handle_of(*v).expect("active implies handle");
                    let st = fs.state[h as usize].as_mut().expect("active implies state");
                    if st.halted || st.computed_step == step {
                        continue;
                    }
                    st.computed_step = step;
                    items.push(WorkItem {
                        v: *v,
                        st: SendPtr(std::ptr::null_mut()),
                        msgs: None,
                    });
                }
                let mut prev_active = prev_active;
                prev_active.clear();
                self.active = prev_active;
                // Same one-traversal pointer pass as the hashed arm (a
                // per-item index into the state vector would reborrow it
                // each time and invalidate earlier pointers under Stacked
                // Borrows). The arena's state vector cannot grow here —
                // every receiver slot was allocated at delivery — so the
                // slots are stable through the sub-jobs.
                ptr_index.clear();
                for (i, item) in items.iter().enumerate() {
                    ptr_index.insert(item.v, i);
                }
                for (st, v) in fs.state.iter_mut().zip(fs.verts.iter()) {
                    if let Some(st) = st {
                        if let Some(&i) = ptr_index.get(v) {
                            items[i].st = SendPtr(st);
                        }
                    }
                }
            }
        }
        debug_assert!(items.iter().all(|item| !item.st.0.is_null()));
    }

    /// Fold one sub-job's non-staging state back into this shard, in
    /// sub-range order: actives are appended, the aggregator partial is
    /// folded through `agg_merge`, and `force_terminate` is OR-ed. The
    /// sub-job's *staged messages* are NOT absorbed here — since the
    /// edge-level split they travel through per-destination-worker
    /// [`StagingCol`] replay jobs (independent maps, so the columns fold
    /// concurrently), which reproduce the identical serial insertion
    /// history this method used to replay inline.
    pub(crate) fn absorb_control(&mut self, app: &A, buf: &mut SubBuf<A>) {
        self.active.append(&mut buf.next_active);
        let part = std::mem::take(&mut buf.agg);
        app.agg_merge(&mut self.agg_round, &part);
        if buf.terminated {
            self.terminated = true;
            buf.terminated = false;
        }
    }
}

/// Insertion-ordered sub-staging for one destination worker: slots are
/// kept in FIRST-TOUCH order (a `Vec`) with a hash index for combining,
/// so the merge replays destinations in exactly the order a serial pass
/// would have first staged them — and the shard's staging map therefore
/// gets the same key-insertion history as an unsplit run. A plain hash
/// map here would hand the merge its internal iteration order instead;
/// since a hash map's iteration order downstream depends on insertion
/// history, that would leak a split-dependent receiver-processing order
/// into the NEXT superstep for order-sensitive apps.
pub(crate) struct OrderedStaging<A: QueryApp> {
    /// dst -> index into `slots`; cleared together with the slots when
    /// the merge drains this buffer.
    index: FxHashMap<VertexId, usize>,
    /// (dst, slot) pairs in first-touch order.
    pub slots: Vec<(VertexId, MsgSlot<A::Msg>)>,
}

impl<A: QueryApp> OrderedStaging<A> {
    pub(crate) fn empty() -> Self {
        Self {
            index: FxHashMap::default(),
            slots: Vec::new(),
        }
    }

    /// Rebuild an ordered staging buffer from explicit `(dst, slot)` pairs
    /// — the multi-process mode's decode path: a remote worker ships its
    /// staged column as exactly these pairs in first-touch order, and the
    /// receiving side reconstitutes the buffer (index included, first
    /// occurrence wins) so delivery replays the identical order the
    /// in-process exchange would have seen.
    pub(crate) fn from_slots(slots: Vec<(VertexId, MsgSlot<A::Msg>)>) -> Self {
        let mut index = FxHashMap::default();
        for (i, (dst, _)) in slots.iter().enumerate() {
            index.entry(*dst).or_insert(i);
        }
        Self { index, slots }
    }

    /// Stage one message, replaying the sender-side combiner against the
    /// destination's existing slot — the same [`merge_msg`] rule used
    /// everywhere else a message enters a slot.
    pub fn stage(&mut self, app: &A, dst: VertexId, msg: A::Msg) {
        match self.index.entry(dst) {
            Entry::Occupied(e) => {
                let _ = merge_msg(app, &mut self.slots[*e.get()].1, msg);
            }
            Entry::Vacant(e) => {
                e.insert(self.slots.len());
                self.slots.push((dst, MsgSlot::One(msg)));
            }
        }
    }

    /// Drain this buffer into a shard staging map in first-touch order,
    /// re-offering every message to the sender-side combiner through
    /// [`merge_msg`] — the single replay rule shared with the exchange.
    /// Leaves the buffer empty (capacity kept) for recycling.
    pub(crate) fn drain_into(
        &mut self,
        app: &A,
        target: &mut FxHashMap<VertexId, MsgSlot<A::Msg>>,
    ) {
        self.index.clear();
        for (dst, slot) in self.slots.drain(..) {
            match target.entry(dst) {
                Entry::Occupied(mut e) => {
                    let into = e.get_mut();
                    match slot {
                        MsgSlot::One(m) => {
                            let _ = merge_msg(app, into, m);
                        }
                        MsgSlot::Many(ms) => {
                            for m in ms {
                                let _ = merge_msg(app, into, m);
                            }
                        }
                    }
                }
                Entry::Vacant(e) => {
                    e.insert(slot); // moves, no allocation
                }
            }
        }
    }

    /// Merge one whole slot for `dst`, replaying the combiner per message
    /// against the destination's existing slot (vacant destinations take
    /// the slot wholesale, recording first-touch order) — the
    /// ordered-buffer twin of [`drain_into`](Self::drain_into)'s per-entry
    /// rule.
    pub(crate) fn merge_slot(&mut self, app: &A, dst: VertexId, slot: MsgSlot<A::Msg>) {
        match self.index.entry(dst) {
            Entry::Occupied(e) => {
                let into = &mut self.slots[*e.get()].1;
                match slot {
                    MsgSlot::One(m) => {
                        let _ = merge_msg(app, into, m);
                    }
                    MsgSlot::Many(ms) => {
                        for m in ms {
                            let _ = merge_msg(app, into, m);
                        }
                    }
                }
            }
            Entry::Vacant(e) => {
                e.insert(self.slots.len());
                self.slots.push((dst, slot)); // moves, no allocation
            }
        }
    }

    /// Drain this buffer into another ordered buffer in first-touch order
    /// — the `Layout::Flat` replay target, where a task's staged buffer
    /// is itself insertion-ordered. Leaves the buffer empty (capacity
    /// kept) for recycling.
    pub(crate) fn drain_into_ordered(&mut self, app: &A, target: &mut OrderedStaging<A>) {
        self.index.clear();
        for (dst, slot) in self.slots.drain(..) {
            target.merge_slot(app, dst, slot);
        }
    }

    /// Drain this buffer into a layout-polymorphic staged buffer — the
    /// single replay entry point the staging-column merge uses, so the
    /// split paths never care which layout the engine runs.
    pub(crate) fn drain_into_buf(&mut self, app: &A, target: &mut StagedBuf<A>) {
        match target {
            StagedBuf::Hashed(map) => self.drain_into(app, map),
            StagedBuf::Flat(ord) => self.drain_into_ordered(app, ord),
        }
    }

    /// Drain the slot list in first-touch order, clearing the combining
    /// index first (the exchange-delivery entry point for flat stores).
    pub(crate) fn drain_slots(&mut self) -> std::vec::Drain<'_, (VertexId, MsgSlot<A::Msg>)> {
        self.index.clear();
        self.slots.drain(..)
    }

    /// Bytes retained by this buffer's backing allocations (capacity, not
    /// length — the scratch a drained-but-recycled buffer still pins).
    pub(crate) fn retained_bytes(&self) -> usize {
        self.slots.capacity() * std::mem::size_of::<(VertexId, MsgSlot<A::Msg>)>()
            + self.index.capacity() * std::mem::size_of::<(VertexId, usize)>()
    }

    /// Cap the retained capacity at `cap` slots (the flat-staging twin of
    /// the per-lane ordered-staging recycling pool's cap), so a one-off
    /// mega-round cannot pin its high-water scratch forever.
    pub(crate) fn shrink_to(&mut self, cap: usize) {
        self.slots.shrink_to(cap);
        self.index.shrink_to(cap);
    }
}

/// One parked mega-fanout: the outbox of a single `compute()` call whose
/// `ctx.send` count crossed the edge-split threshold, held in exact send
/// order. The edge-range dispatch cuts `msgs` into contiguous ranges of
/// `range` and stages each into its own private per-destination-worker
/// buffer in `bufs` (one `Vec<OrderedStaging>` per range, indexed by
/// destination worker); the staging-column merge then folds `bufs[r][dw]`
/// back **in range order** — the concatenation of the ranges IS the serial
/// send order, so the replay is indistinguishable from an inline drain.
pub(crate) struct FanTask<A: QueryApp> {
    /// The heavy vertex's outbox, in `ctx.send` order.
    pub msgs: Vec<(VertexId, A::Msg)>,
    /// Contiguous edge-range size this fan is cut at (≥ 1).
    pub range: usize,
    /// Per-range private staging, `bufs[r][dw]`; allocated by the engine
    /// when the edge-range jobs are collected, filled by the jobs.
    pub bufs: Vec<Vec<OrderedStaging<A>>>,
}

impl<A: QueryApp> FanTask<A> {
    /// Number of contiguous edge ranges this fan is cut into.
    pub fn n_ranges(&self) -> usize {
        self.msgs.len().div_ceil(self.range.max(1))
    }
}

/// One unit of a [`StageStream`]: either an inline-staged segment (the
/// messages of ordinary-fanout vertices, per destination worker, in
/// first-touch order) or a parked mega-fanout awaiting the edge-range
/// dispatch. The unit sequence is the serial staging order.
pub(crate) enum StageUnit<A: QueryApp> {
    Seg(Vec<OrderedStaging<A>>),
    Fan(FanTask<A>),
}

/// Segmented private staging: the ordered sequence of everything one
/// compute unit (a sub-job, or the post-first-fan tail of a serial task)
/// staged, with mega-fanouts parked as their own units so they can be cut
/// into edge ranges without disturbing the messages around them. Replaying
/// the units in order — segments slot by slot, fans range by range —
/// reproduces the exact serial insertion sequence.
pub(crate) struct StageStream<A: QueryApp> {
    pub units: Vec<StageUnit<A>>,
    /// Destination-worker count (sizes fresh segments).
    workers: usize,
    /// Recycled drained buffers for new segments, seeded from the lane's
    /// ordered-staging pool between rounds ([`StageStream::seed`]) — this
    /// is what gives split rounds back the capacity reuse the pre-stream
    /// per-sub staging had. Private per stream, so concurrent sub-jobs
    /// never contend. Recycled buffers are empty; only their capacity
    /// differs from fresh ones, and nothing observable depends on map
    /// capacity (the index is never iterated, slot order is insertion
    /// order), so outputs are unchanged.
    pool: Vec<OrderedStaging<A>>,
}

impl<A: QueryApp> StageStream<A> {
    pub fn new(workers: usize) -> Self {
        Self {
            units: Vec::new(),
            workers,
            pool: Vec::new(),
        }
    }

    /// Stage one message into the tail segment (opening a segment — from
    /// the recycle pool where possible — if the stream is empty or ends
    /// in a parked fan).
    pub fn stage(&mut self, app: &A, dw: usize, dst: VertexId, msg: A::Msg) {
        if !matches!(self.units.last(), Some(StageUnit::Seg(_))) {
            let mut segs = Vec::with_capacity(self.workers);
            for _ in 0..self.workers {
                segs.push(self.pool.pop().unwrap_or_else(OrderedStaging::empty));
            }
            self.units.push(StageUnit::Seg(segs));
        }
        let Some(StageUnit::Seg(segs)) = self.units.last_mut() else {
            unreachable!("a Seg unit was just ensured")
        };
        segs[dw].stage(app, dst, msg);
    }

    /// Top this stream's segment pool up to `upto` buffers from `src`
    /// (drained buffers recycled by the merge). Called between rounds by
    /// the coordinator, never concurrently with staging.
    pub fn seed(&mut self, src: &mut Vec<OrderedStaging<A>>, upto: usize) {
        while self.pool.len() < upto {
            let Some(b) = src.pop() else { break };
            self.pool.push(b);
        }
    }

    /// Park one mega-fanout at the current stream position; subsequent
    /// `stage` calls open a new segment after it.
    pub fn park_fan(&mut self, msgs: Vec<(VertexId, A::Msg)>, range: usize) {
        self.units.push(StageUnit::Fan(FanTask {
            msgs,
            range: range.max(1),
            bufs: Vec::new(),
        }));
    }

    /// Move one destination worker's column out of this stream, in unit
    /// order (segments whole, fan ranges in range order) — the serial
    /// staging order the [`StagingCol`] replay must reproduce. Buffers
    /// that staged nothing for this destination are left in place (they
    /// carry no history and no capacity worth moving).
    pub fn collect_column(&mut self, dw: usize, out: &mut Vec<OrderedStaging<A>>) {
        for unit in self.units.iter_mut() {
            match unit {
                StageUnit::Seg(segs) => {
                    if !segs[dw].slots.is_empty() {
                        out.push(std::mem::replace(&mut segs[dw], OrderedStaging::empty()));
                    }
                }
                StageUnit::Fan(ft) => {
                    for rb in ft.bufs.iter_mut() {
                        if !rb[dw].slots.is_empty() {
                            out.push(std::mem::replace(&mut rb[dw], OrderedStaging::empty()));
                        }
                    }
                }
            }
        }
    }
}

/// One (split task, destination worker) staging-replay column: the task's
/// shard staging map for that destination (taken from the shard, prefix
/// inserts — if any — already inside) plus every private staging buffer
/// addressed to that destination, in exact serial-stream order (sub-ranges
/// in sub order; within each stream, segments and fan ranges in unit
/// order). Columns for distinct destination workers touch disjoint maps,
/// so they replay concurrently — that is what keeps the fold of a parked
/// mega-fanout from re-serializing the very staging the edge ranges just
/// parallelized.
pub(crate) struct StagingCol<A: QueryApp> {
    pub target: StagedBuf<A>,
    pub sources: Vec<OrderedStaging<A>>,
}

impl<A: QueryApp> StagingCol<A> {
    /// Replay every source into the target in order. After this the
    /// sources are drained (capacity kept) and the target's key-insertion
    /// (hashed) or first-touch (flat) history matches a serial pass
    /// exactly.
    pub fn replay(&mut self, app: &A) {
        for src in self.sources.iter_mut() {
            src.drain_into_buf(app, &mut self.target);
        }
    }
}

/// Raw pointer to a `VState` slot inside a shard's `vstate` map, safe to
/// hand to a pool thread: the slots of one work-item list are pairwise
/// distinct (distinct keys), the map's structure is frozen while sub-jobs
/// run, and the coordinator blocks until the batch drains.
pub(crate) struct SendPtr<T>(pub *mut T);

// SAFETY: the pointer is only ever dereferenced by the one sub-job that
// owns the item (disjoint ranges over distinct vertices), and `run` blocks
// the coordinator until every sub-job finished — the same happens-before
// edge the pool already provides for `&mut` captures.
unsafe impl<T: Send> Send for SendPtr<T> {}

/// One unit of split compute work: the vertex, a raw handle to its VQ-data
/// slot, and the messages it received this superstep (owned — taken from
/// the inbox during [`WorkerShard::split_items`]).
pub(crate) struct WorkItem<A: QueryApp> {
    pub v: VertexId,
    pub st: SendPtr<VState<A::VQ>>,
    pub msgs: Option<MsgSlot<A::Msg>>,
}

/// Private staging state of one compute sub-job: everything `compute` may
/// write, so a sub-range runs with zero synchronization against its
/// siblings. Buffers are recycled across super-rounds (the merge drains
/// them in place).
pub(crate) struct SubBuf<A: QueryApp> {
    /// Sub-staging: everything this sub-range staged, as a segmented
    /// stream — inline segments per destination worker in first-touch
    /// order, combined sender-side within this sub-range only, with
    /// mega-fanouts parked as their own [`FanTask`] units for the
    /// edge-range dispatch.
    pub stream: StageStream<A>,
    /// Vertices of this sub-range that did not vote halt, in work order.
    pub next_active: Vec<VertexId>,
    /// Per-sub outbox scratch (drained after every compute call).
    pub outbox: Vec<(VertexId, A::Msg)>,
    /// This sub-range's aggregator partial (folded in sub-range order).
    pub agg: A::Agg,
    pub terminated: bool,
    pub compute_calls: u64,
    pub msg_handled: u64,
    pub sent: u64,
    /// Messages parked into fans (⊆ `sent`); the post-split imbalance
    /// metric subtracts them, since edge-range jobs carry that staging.
    pub fanned: u64,
    /// Largest single `compute()` fanout (ctx.send count) seen here.
    pub max_fan: u64,
}

impl<A: QueryApp> SubBuf<A> {
    pub fn new(workers: usize) -> Self {
        Self {
            stream: StageStream::new(workers),
            next_active: Vec::new(),
            outbox: Vec::new(),
            agg: A::Agg::default(),
            terminated: false,
            compute_calls: 0,
            msg_handled: 0,
            sent: 0,
            fanned: 0,
            max_fan: 0,
        }
    }

    /// Zero the per-round counters (buffers are already drained by the
    /// merge; called after the lane folded the counters into its totals).
    pub fn reset_counters(&mut self) {
        self.compute_calls = 0;
        self.msg_handled = 0;
        self.sent = 0;
        self.fanned = 0;
        self.max_fan = 0;
    }
}

/// Q-data + per-worker shards for one in-flight query.
pub(crate) struct QueryRt<A: QueryApp> {
    pub id: QueryId,
    pub query: A::Query,
    /// Superstep number (1-based during compute).
    pub step: u64,
    pub phase: Phase,
    /// Worker-major state: `shards[w]` is owned by worker `w`'s thread
    /// during the compute phase.
    pub shards: Vec<WorkerShard<A>>,
    /// Merged aggregator from the previous superstep (visible to compute).
    pub agg_prev: A::Agg,
    /// Set when any vertex (or the master hook) called force_terminate.
    pub terminated: bool,
    /// Whale flag from [`QueryApp::is_heavy`], frozen at submission: the
    /// adaptive admission planner counts heavy in-flight queries against
    /// the reserved capacity slice.
    pub heavy: bool,
    /// Graph epoch pinned at admission: the version this query reads for
    /// its whole lifetime (0 for immutable-graph apps).
    pub epoch: u64,
    /// Vertex-slot count of the pinned version — the `|V|` this query's
    /// access rate normalizes against (the engine's current count may
    /// have moved on by the time the query reports).
    pub n_vertices: usize,
    pub stats: QueryStats,
}

impl<A: QueryApp> QueryRt<A> {
    #[allow(clippy::too_many_arguments)]
    pub fn new(
        id: QueryId,
        query: A::Query,
        workers: usize,
        layout: Layout,
        arrived_at: f64,
        submitted_at: f64,
        heavy: bool,
        epoch: u64,
        n_vertices: usize,
    ) -> Self {
        Self {
            id,
            query,
            step: 0,
            phase: Phase::Running,
            shards: (0..workers)
                .map(|_| WorkerShard::new(workers, layout, n_vertices))
                .collect(),
            agg_prev: A::Agg::default(),
            terminated: false,
            heavy,
            epoch,
            n_vertices,
            stats: QueryStats {
                qid: id,
                arrived_at,
                submitted_at,
                epoch,
                ..Default::default()
            },
        }
    }

    /// Total touched vertices across workers (VQ-data entries allocated).
    pub fn touched(&self) -> u64 {
        self.shards.iter().map(|s| s.store.touched() as u64).sum()
    }

    /// True when no vertex is active and no message is pending.
    pub fn quiescent(&self) -> bool {
        self.shards
            .iter()
            .all(|s| s.active.is_empty() && s.store.pending() == 0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn push_promotes_one_to_many() {
        let mut s = MsgSlot::One(1u32);
        assert_eq!(s.len(), 1);
        s.push(2);
        match &s {
            MsgSlot::Many(v) => assert_eq!(v.as_slice(), &[1, 2]),
            MsgSlot::One(_) => panic!("push must promote One to Many"),
        }
        s.push(3);
        assert_eq!(s.as_slice(), &[1, 2, 3]);
    }

    /// Minimal app whose combiner sums `u32` messages while the head stays
    /// below 100, used to pin `merge_msg`'s contract: every message is
    /// offered to `QueryApp::combine` against the slot head before being
    /// appended (the old `MsgSlot::merge` silently skipped the combiner).
    struct SumBelow100;

    impl QueryApp for SumBelow100 {
        type Query = ();
        type VQ = ();
        type Msg = u32;
        type Agg = ();
        type Out = ();

        fn init_activate(&self, _q: &()) -> Vec<VertexId> {
            Vec::new()
        }

        fn init_value(&self, _q: &(), _v: VertexId) {}

        fn compute(&self, _ctx: &mut crate::vertex::Ctx<'_, Self>, _v: VertexId, _vq: &mut ()) {}

        fn combine(&self, into: &mut u32, from: &u32) -> bool {
            if *into + *from < 100 {
                *into += *from;
                true
            } else {
                false
            }
        }

        fn finish(
            &self,
            _q: &(),
            _touched: &mut dyn Iterator<Item = (VertexId, &())>,
            _agg: &(),
        ) {
        }
    }

    #[test]
    fn merge_msg_routes_through_combiner() {
        let app = SumBelow100;
        let mut slot = MsgSlot::One(10u32);
        // Combined into the head: nothing appended, count 0.
        assert_eq!(merge_msg(&app, &mut slot, 20), 0);
        assert_eq!(slot.as_slice(), &[30]);
        // Combiner declines (sum would reach 120): appended, count 1.
        assert_eq!(merge_msg(&app, &mut slot, 90), 1);
        assert_eq!(slot.as_slice(), &[30, 90]);
        // The head stays the combiner target once the slot is Many.
        assert_eq!(merge_msg(&app, &mut slot, 5), 0);
        assert_eq!(slot.as_slice(), &[35, 90]);
    }

    #[test]
    fn merge_msg_into_drained_slot_appends() {
        // A drained Many has no head, so the combiner has nothing to fold
        // into and the message must be stored as-is.
        let app = SumBelow100;
        let mut slot: MsgSlot<u32> = MsgSlot::Many(Vec::new());
        assert_eq!(merge_msg(&app, &mut slot, 7), 1);
        assert_eq!(slot.as_slice(), &[7]);
    }

    #[test]
    fn as_slice_of_one_is_singleton() {
        let s = MsgSlot::One(7u32);
        assert_eq!(s.as_slice(), &[7]);
        assert!(!s.is_empty());
    }

    #[test]
    fn first_mut_targets_head() {
        let mut s = MsgSlot::One(1u32);
        *s.first_mut().unwrap() = 9;
        assert_eq!(s.as_slice(), &[9]);
        s.push(2);
        *s.first_mut().unwrap() = 8;
        assert_eq!(s.as_slice(), &[8, 2]);
    }

    #[test]
    fn split_items_replays_serial_order_and_dedups_actives() {
        let app = SumBelow100;
        let mut shard = WorkerShard::<SumBelow100>::new(2, Layout::Hashed, 0);
        // Receiver 2 is new to the query (no VQ-data yet — the receiver
        // pass must insert it); actives are [4, 2], and 2 also received,
        // so the active pass must dedup it exactly like the serial loop.
        let VStore::Hashed { vstate, inbox } = &mut shard.store else {
            unreachable!("Layout::Hashed was requested")
        };
        inbox.insert(2, MsgSlot::One(5));
        vstate.insert(
            4,
            VState {
                vq: (),
                halted: false,
                computed_step: 0,
            },
        );
        shard.active.extend([4u32, 2]);

        let mut items = Vec::new();
        shard.split_items(&app, &(), 1, &mut items, &mut FxHashMap::default());
        let order: Vec<u32> = items.iter().map(|i| i.v).collect();
        assert_eq!(order, vec![2, 4], "receivers first, then deduped actives");
        assert!(items[0].msgs.is_some() && items[1].msgs.is_none());
        let VStore::Hashed { vstate, inbox } = &shard.store else {
            unreachable!()
        };
        for item in &items {
            assert!(!item.st.0.is_null());
            let st = vstate.get(&item.v).unwrap();
            assert_eq!(st.computed_step, 1, "work items must be stamped");
        }
        assert!(inbox.is_empty(), "inbox must be drained for recycling");
        assert!(shard.active.is_empty(), "actives consumed; merge refills");
    }

    #[test]
    fn flat_split_items_replays_delivery_order_and_dedups_actives() {
        // The flat twin of the serial-order lock above: receivers come
        // out in `recv` delivery order, actives dedup, and the arena's
        // state slots back every work-item pointer.
        let app = SumBelow100;
        let mut shard = WorkerShard::<SumBelow100>::new(2, Layout::Flat, 0);
        let VStore::Flat(fs) = &mut shard.store else {
            unreachable!("Layout::Flat was requested")
        };
        // Deliver to 6 then 2 (delivery order ≠ numeric order) and seed
        // VQ-data for active-only vertex 4.
        fs.deliver_slot(&app, 6, MsgSlot::One(5));
        fs.deliver_slot(&app, 2, MsgSlot::One(7));
        fs.ensure_state_with(4, || VState {
            vq: (),
            halted: false,
            computed_step: 0,
        });
        shard.active.extend([4u32, 2]);

        let mut items = Vec::new();
        shard.split_items(&app, &(), 1, &mut items, &mut FxHashMap::default());
        let order: Vec<u32> = items.iter().map(|i| i.v).collect();
        assert_eq!(order, vec![6, 2, 4], "delivery order, then deduped actives");
        assert!(items[0].msgs.is_some() && items[2].msgs.is_none());
        let VStore::Flat(fs) = &shard.store else { unreachable!() };
        assert_eq!(fs.n_state, 3, "receivers allocated VQ-data lazily");
        for item in &items {
            assert!(!item.st.0.is_null());
            let h = fs.handle_of(item.v).unwrap() as usize;
            let st = fs.state[h].as_ref().unwrap();
            assert_eq!(st.computed_step, 1, "work items must be stamped");
            assert!(
                std::ptr::eq(item.st.0, st),
                "item pointer must target the arena slot"
            );
        }
        assert!(fs.recv.is_empty(), "recv list drained for recycling");
        assert!(fs.msg.iter().all(Option::is_none), "inbox slots consumed");
        assert!(shard.active.is_empty(), "actives consumed; merge refills");
    }

    /// Extract the per-destination-worker staging column of a sequence of
    /// sub-buffers with the SAME `collect_column` the engine's merge
    /// collection uses, so this test exercises the real extraction logic.
    fn column_of(bufs: &mut [SubBuf<SumBelow100>], dw: usize) -> StagingCol<SumBelow100> {
        let mut sources = Vec::new();
        for buf in bufs.iter_mut() {
            buf.stream.collect_column(dw, &mut sources);
        }
        StagingCol {
            target: StagedBuf::default(),
            sources,
        }
    }

    /// Shared-slot lookup across both staged-buffer layouts, so the replay
    /// tests can assert contents without caring which arm they drove.
    fn staged_slot<'b>(buf: &'b StagedBuf<SumBelow100>, dst: u32) -> Option<&'b [u32]> {
        match buf {
            StagedBuf::Hashed(map) => map.get(&dst).map(|s| s.as_slice()),
            StagedBuf::Flat(ord) => ord
                .slots
                .iter()
                .find(|&&(d, _)| d == dst)
                .map(|(_, s)| s.as_slice()),
        }
    }

    #[test]
    fn staging_column_replays_combiner_in_subrange_order() {
        let app = SumBelow100;
        let mut shard = WorkerShard::<SumBelow100>::new(2, Layout::Hashed, 0);
        let mut bufs = vec![SubBuf::<SumBelow100>::new(2), SubBuf::new(2)];
        bufs[0].stream.stage(&app, 0, 8, 7);
        bufs[0].stream.stage(&app, 0, 8, 3); // combines: 7 + 3 = 10 < 100
        bufs[0].next_active.push(8);
        bufs[1].stream.stage(&app, 0, 9, 1);
        bufs[1].stream.stage(&app, 0, 8, 90);
        bufs[1].next_active.push(9);
        // Sub-staging preserves FIRST-TOUCH destination order, not hash
        // order — that is what keeps the shard's staging map insertion
        // history identical to a serial pass.
        let StageUnit::Seg(segs) = &bufs[1].stream.units[0] else {
            panic!("inline staging must open a Seg unit")
        };
        let touch_order: Vec<u32> = segs[0].slots.iter().map(|&(d, _)| d).collect();
        assert_eq!(touch_order, vec![9, 8]);

        let mut col = column_of(&mut bufs, 0);
        col.replay(&app);
        // 10 then 90: the combiner declines (sum would hit 100), so the
        // slot must hold both, in sub-range order — exactly the sequence
        // one serial staging pass would have produced.
        assert_eq!(staged_slot(&col.target, 8).unwrap(), &[10, 90]);
        assert_eq!(staged_slot(&col.target, 9).unwrap(), &[1]);
        assert!(col.sources.iter().all(|s| s.slots.is_empty()));
        // The non-staging state folds separately, in the same sub order.
        let (b1, b2) = bufs.split_at_mut(1);
        shard.absorb_control(&app, &mut b1[0]);
        shard.absorb_control(&app, &mut b2[0]);
        assert_eq!(shard.active, vec![8, 9], "actives append in sub order");
    }

    #[test]
    fn stage_stream_parks_fans_between_segments() {
        let app = SumBelow100;
        let mut stream = StageStream::<SumBelow100>::new(2);
        stream.stage(&app, 0, 4, 1);
        stream.park_fan(vec![(6, 2), (8, 3), (6, 4)], 2);
        // Staging after a fan must open a NEW segment, not reuse the one
        // before it — otherwise the replay would hoist these messages
        // ahead of the fan's.
        stream.stage(&app, 0, 4, 5);
        assert_eq!(stream.units.len(), 3);
        assert!(matches!(stream.units[0], StageUnit::Seg(_)));
        let StageUnit::Fan(ft) = &stream.units[1] else {
            panic!("fan must be its own unit")
        };
        assert_eq!(ft.n_ranges(), 2, "3 msgs at range 2 -> 2 ranges");
        assert!(matches!(stream.units[2], StageUnit::Seg(_)));
    }

    #[test]
    fn fan_range_replay_matches_inline_drain() {
        // Staging a fan's ranges into private buffers and replaying them
        // in range order must produce the same map contents and insertion
        // history as draining the fan inline.
        let app = SumBelow100;
        let msgs: Vec<(u32, u32)> = vec![(2, 7), (4, 90), (2, 5), (6, 1), (4, 20), (2, 80)];
        let range = 2;

        let mut inline: FxHashMap<u32, MsgSlot<u32>> = FxHashMap::default();
        for &(dst, m) in &msgs {
            match inline.entry(dst) {
                Entry::Occupied(mut e) => {
                    let _ = merge_msg(&app, e.get_mut(), m);
                }
                Entry::Vacant(e) => {
                    e.insert(MsgSlot::One(m));
                }
            }
        }

        // Edge-range path: every destination is on worker 0 of 1.
        let mut bufs: Vec<Vec<OrderedStaging<SumBelow100>>> = (0..msgs.len().div_ceil(range))
            .map(|_| vec![OrderedStaging::empty()])
            .collect();
        for (chunk, buf) in msgs.chunks(range).zip(bufs.iter_mut()) {
            for &(dst, m) in chunk {
                buf[0].stage(&app, dst, m);
            }
        }
        let mut col = StagingCol::<SumBelow100> {
            target: StagedBuf::default(),
            sources: bufs.into_iter().map(|mut b| b.remove(0)).collect(),
        };
        col.replay(&app);

        let StagedBuf::Hashed(target) = &col.target else {
            unreachable!("default staged buffer is the hashed placeholder")
        };
        assert_eq!(target.len(), inline.len());
        for (dst, slot) in &inline {
            assert_eq!(
                target.get(dst).unwrap().as_slice(),
                slot.as_slice(),
                "destination {dst} diverged from the inline drain"
            );
        }
    }

    #[test]
    fn ordered_replay_into_flat_target_matches_hashed_target() {
        // The flat staging column replays through `drain_into_ordered` /
        // `merge_slot` instead of `drain_into`; both targets must end up
        // with identical per-destination slot contents, and the flat one
        // must additionally pin FIRST-TOUCH destination order.
        let app = SumBelow100;
        let msgs: Vec<(u32, u32)> = vec![(4, 60), (2, 5), (4, 30), (6, 1), (4, 90), (2, 7)];
        let build_sources = || {
            let mut sources: Vec<OrderedStaging<SumBelow100>> = Vec::new();
            for chunk in msgs.chunks(2) {
                let mut b = OrderedStaging::empty();
                for &(dst, m) in chunk {
                    b.stage(&app, dst, m);
                }
                sources.push(b);
            }
            sources
        };

        let mut hashed = StagingCol::<SumBelow100> {
            target: StagedBuf::new(Layout::Hashed),
            sources: build_sources(),
        };
        hashed.replay(&app);
        let mut flat = StagingCol::<SumBelow100> {
            target: StagedBuf::new(Layout::Flat),
            sources: build_sources(),
        };
        flat.replay(&app);
        assert!(flat.sources.iter().all(|s| s.slots.is_empty()));

        let StagedBuf::Flat(ord) = &flat.target else { unreachable!() };
        let touch_order: Vec<u32> = ord.slots.iter().map(|&(d, _)| d).collect();
        assert_eq!(touch_order, vec![4, 2, 6], "first-touch order preserved");
        for dst in [4u32, 2, 6] {
            assert_eq!(
                staged_slot(&flat.target, dst).unwrap(),
                staged_slot(&hashed.target, dst).unwrap(),
                "destination {dst} diverged between layouts"
            );
        }
    }

    #[test]
    fn drained_many_is_empty() {
        // A Many whose Vec was drained is the only empty form a slot can
        // take; One is always non-empty.
        let mut s: MsgSlot<u32> = MsgSlot::Many(vec![1, 2]);
        if let MsgSlot::Many(v) = &mut s {
            v.clear();
        }
        assert!(s.is_empty());
        assert_eq!(s.len(), 0);
        assert_eq!(s.as_slice(), &[] as &[u32]);
        assert!(s.first_mut().is_none());
        // Refilling via push works from the drained state.
        s.push(5);
        assert_eq!(s.as_slice(), &[5]);
    }
}
