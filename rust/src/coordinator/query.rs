//! Per-query runtime state: the rust analog of the paper's Q-data entry in
//! `HT_Q` plus the per-worker shards of VQ-data and message stores.

use crate::graph::VertexId;
use crate::metrics::QueryStats;
use crate::util::FxHashMap;
use crate::vertex::{QueryApp, QueryId};

/// Append `m` to `into`, first offering it to the sender-side combiner
/// against the slot head. Used both when staging (compute phase) and when
/// the exchange phase delivers cross-shard slots — the single rule that
/// makes the per-shard staging buffers reproduce, message for message, what
/// one shared staging buffer would have held. Returns the number of
/// messages added (0 when combined away).
///
/// This is the *only* way messages enter a slot: the old `MsgSlot::merge`
/// convenience silently bypassed [`QueryApp::combine`] and was removed in
/// its favor.
pub(crate) fn merge_msg<A: QueryApp>(app: &A, into: &mut MsgSlot<A::Msg>, m: A::Msg) -> u64 {
    if let Some(first) = into.first_mut() {
        if app.combine(first, &m) {
            return 0;
        }
    }
    into.push(m);
    1
}

/// Per-vertex, per-query state (one `LUT_v[q]` entry): the vertex value
/// `a_q(v)` plus the halted flag and a stamp to dedup processing within a
/// super-round.
#[derive(Debug, Clone)]
pub struct VState<VQ> {
    pub vq: VQ,
    pub halted: bool,
    pub(crate) computed_step: u64,
}

/// Message storage per destination vertex: the overwhelmingly common case
/// after sender-side combining is a single message, which this enum keeps
/// inline (no heap allocation on either side of the barrier).
#[derive(Debug, Clone)]
pub enum MsgSlot<M> {
    One(M),
    Many(Vec<M>),
}

impl<M> MsgSlot<M> {
    #[inline]
    pub fn push(&mut self, m: M) {
        match self {
            MsgSlot::One(_) => {
                let MsgSlot::One(first) = std::mem::replace(self, MsgSlot::Many(Vec::new()))
                else {
                    unreachable!()
                };
                let MsgSlot::Many(v) = self else { unreachable!() };
                v.reserve(4);
                v.push(first);
                v.push(m);
            }
            MsgSlot::Many(v) => v.push(m),
        }
    }

    #[inline]
    pub fn len(&self) -> usize {
        match self {
            MsgSlot::One(_) => 1,
            MsgSlot::Many(v) => v.len(),
        }
    }

    /// True when the slot holds no message (only possible for a drained
    /// `Many`).
    #[allow(dead_code)]
    #[inline]
    pub fn is_empty(&self) -> bool {
        matches!(self, MsgSlot::Many(v) if v.is_empty())
    }

    /// View as a slice (One is a 1-element slice via `slice::from_ref`).
    #[inline]
    pub fn as_slice(&self) -> &[M] {
        match self {
            MsgSlot::One(m) => std::slice::from_ref(m),
            MsgSlot::Many(v) => v.as_slice(),
        }
    }

    /// First message, mutable (combiner target).
    #[inline]
    pub fn first_mut(&mut self) -> Option<&mut M> {
        match self {
            MsgSlot::One(m) => Some(m),
            MsgSlot::Many(v) => v.first_mut(),
        }
    }
}

/// Completed-query record handed back to the submitter.
#[derive(Debug, Clone)]
pub struct QueryResult<Out> {
    pub qid: QueryId,
    pub out: Out,
    pub stats: QueryStats,
}

/// Lifecycle phase of an in-flight query.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub(crate) enum Phase {
    /// Executing supersteps.
    Running,
    /// Converged/terminated; the next super-round is the reporting round.
    Reporting,
}

/// One worker's slice of one in-flight query: everything the worker thread
/// mutates during the compute phase. Shards of the same query are disjoint,
/// so the engine can hand shard `w` of every query to a pool worker without
/// synchronization; cross-shard traffic flows only through `staged`, which
/// is keyed by destination worker so the exchange phase can route every
/// destination's column of the staging matrix concurrently (the maps are
/// taken from the shards for the duration of the phase and handed back).
pub(crate) struct WorkerShard<A: QueryApp> {
    /// VQ-data table of this worker (lazy: only touched vertices present).
    pub vstate: FxHashMap<VertexId, VState<A::VQ>>,
    /// Active list (vertices that did not vote halt).
    pub active: Vec<VertexId>,
    /// Inbox for the *current* superstep.
    pub inbox: FxHashMap<VertexId, MsgSlot<A::Msg>>,
    /// Staged outgoing messages, keyed by destination worker then by
    /// destination vertex (reused across rounds; exchanged at the barrier).
    pub staged: Vec<FxHashMap<VertexId, MsgSlot<A::Msg>>>,
    /// This worker's aggregator partial for the current superstep (folded
    /// across shards in worker order by the fold phase, then reset).
    pub agg_round: A::Agg,
    /// Set when a vertex on this shard called `force_terminate` (OR-folded
    /// into the query flag by the fold phase).
    pub terminated: bool,
}

impl<A: QueryApp> WorkerShard<A> {
    fn new(workers: usize) -> Self {
        Self {
            vstate: FxHashMap::default(),
            active: Vec::new(),
            inbox: FxHashMap::default(),
            staged: (0..workers).map(|_| FxHashMap::default()).collect(),
            agg_round: A::Agg::default(),
            terminated: false,
        }
    }
}

/// Q-data + per-worker shards for one in-flight query.
pub(crate) struct QueryRt<A: QueryApp> {
    pub id: QueryId,
    pub query: A::Query,
    /// Superstep number (1-based during compute).
    pub step: u64,
    pub phase: Phase,
    /// Worker-major state: `shards[w]` is owned by worker `w`'s thread
    /// during the compute phase.
    pub shards: Vec<WorkerShard<A>>,
    /// Merged aggregator from the previous superstep (visible to compute).
    pub agg_prev: A::Agg,
    /// Set when any vertex (or the master hook) called force_terminate.
    pub terminated: bool,
    pub stats: QueryStats,
}

impl<A: QueryApp> QueryRt<A> {
    pub fn new(id: QueryId, query: A::Query, workers: usize, submitted_at: f64) -> Self {
        Self {
            id,
            query,
            step: 0,
            phase: Phase::Running,
            shards: (0..workers).map(|_| WorkerShard::new(workers)).collect(),
            agg_prev: A::Agg::default(),
            terminated: false,
            stats: QueryStats {
                qid: id,
                submitted_at,
                ..Default::default()
            },
        }
    }

    /// Total touched vertices across workers (VQ-data entries allocated).
    pub fn touched(&self) -> u64 {
        self.shards.iter().map(|s| s.vstate.len() as u64).sum()
    }

    /// True when no vertex is active and no message is pending.
    pub fn quiescent(&self) -> bool {
        self.shards
            .iter()
            .all(|s| s.active.is_empty() && s.inbox.is_empty())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn push_promotes_one_to_many() {
        let mut s = MsgSlot::One(1u32);
        assert_eq!(s.len(), 1);
        s.push(2);
        match &s {
            MsgSlot::Many(v) => assert_eq!(v.as_slice(), &[1, 2]),
            MsgSlot::One(_) => panic!("push must promote One to Many"),
        }
        s.push(3);
        assert_eq!(s.as_slice(), &[1, 2, 3]);
    }

    /// Minimal app whose combiner sums `u32` messages while the head stays
    /// below 100, used to pin `merge_msg`'s contract: every message is
    /// offered to `QueryApp::combine` against the slot head before being
    /// appended (the old `MsgSlot::merge` silently skipped the combiner).
    struct SumBelow100;

    impl QueryApp for SumBelow100 {
        type Query = ();
        type VQ = ();
        type Msg = u32;
        type Agg = ();
        type Out = ();

        fn init_activate(&self, _q: &()) -> Vec<VertexId> {
            Vec::new()
        }

        fn init_value(&self, _q: &(), _v: VertexId) {}

        fn compute(&self, _ctx: &mut crate::vertex::Ctx<'_, Self>, _v: VertexId, _vq: &mut ()) {}

        fn combine(&self, into: &mut u32, from: &u32) -> bool {
            if *into + *from < 100 {
                *into += *from;
                true
            } else {
                false
            }
        }

        fn finish(
            &self,
            _q: &(),
            _touched: &mut dyn Iterator<Item = (VertexId, &())>,
            _agg: &(),
        ) {
        }
    }

    #[test]
    fn merge_msg_routes_through_combiner() {
        let app = SumBelow100;
        let mut slot = MsgSlot::One(10u32);
        // Combined into the head: nothing appended, count 0.
        assert_eq!(merge_msg(&app, &mut slot, 20), 0);
        assert_eq!(slot.as_slice(), &[30]);
        // Combiner declines (sum would reach 120): appended, count 1.
        assert_eq!(merge_msg(&app, &mut slot, 90), 1);
        assert_eq!(slot.as_slice(), &[30, 90]);
        // The head stays the combiner target once the slot is Many.
        assert_eq!(merge_msg(&app, &mut slot, 5), 0);
        assert_eq!(slot.as_slice(), &[35, 90]);
    }

    #[test]
    fn merge_msg_into_drained_slot_appends() {
        // A drained Many has no head, so the combiner has nothing to fold
        // into and the message must be stored as-is.
        let app = SumBelow100;
        let mut slot: MsgSlot<u32> = MsgSlot::Many(Vec::new());
        assert_eq!(merge_msg(&app, &mut slot, 7), 1);
        assert_eq!(slot.as_slice(), &[7]);
    }

    #[test]
    fn as_slice_of_one_is_singleton() {
        let s = MsgSlot::One(7u32);
        assert_eq!(s.as_slice(), &[7]);
        assert!(!s.is_empty());
    }

    #[test]
    fn first_mut_targets_head() {
        let mut s = MsgSlot::One(1u32);
        *s.first_mut().unwrap() = 9;
        assert_eq!(s.as_slice(), &[9]);
        s.push(2);
        *s.first_mut().unwrap() = 8;
        assert_eq!(s.as_slice(), &[8, 2]);
    }

    #[test]
    fn drained_many_is_empty() {
        // A Many whose Vec was drained is the only empty form a slot can
        // take; One is always non-empty.
        let mut s: MsgSlot<u32> = MsgSlot::Many(vec![1, 2]);
        if let MsgSlot::Many(v) = &mut s {
            v.clear();
        }
        assert!(s.is_empty());
        assert_eq!(s.len(), 0);
        assert_eq!(s.as_slice(), &[] as &[u32]);
        assert!(s.first_mut().is_none());
        // Refilling via push works from the drained state.
        s.push(5);
        assert_eq!(s.as_slice(), &[5]);
    }
}
