//! Per-query runtime state: the rust analog of the paper's Q-data entry in
//! `HT_Q` plus the per-worker shards of VQ-data and message stores.

use crate::graph::VertexId;
use crate::metrics::QueryStats;
use crate::util::FxHashMap;
use crate::vertex::{QueryApp, QueryId};

/// Per-vertex, per-query state (one `LUT_v[q]` entry): the vertex value
/// `a_q(v)` plus the halted flag and a stamp to dedup processing within a
/// super-round.
#[derive(Debug, Clone)]
pub struct VState<VQ> {
    pub vq: VQ,
    pub halted: bool,
    pub(crate) computed_step: u64,
}

/// Message storage per destination vertex: the overwhelmingly common case
/// after sender-side combining is a single message, which this enum keeps
/// inline (no heap allocation on either side of the barrier).
#[derive(Debug, Clone)]
pub enum MsgSlot<M> {
    One(M),
    Many(Vec<M>),
}

impl<M> MsgSlot<M> {
    #[inline]
    pub fn push(&mut self, m: M) {
        match self {
            MsgSlot::One(_) => {
                let MsgSlot::One(first) = std::mem::replace(self, MsgSlot::Many(Vec::new()))
                else {
                    unreachable!()
                };
                let MsgSlot::Many(v) = self else { unreachable!() };
                v.reserve(4);
                v.push(first);
                v.push(m);
            }
            MsgSlot::Many(v) => v.push(m),
        }
    }

    #[inline]
    pub fn len(&self) -> usize {
        match self {
            MsgSlot::One(_) => 1,
            MsgSlot::Many(v) => v.len(),
        }
    }

    /// True when the slot holds no message (only possible for a drained
    /// `Many`).
    #[allow(dead_code)]
    #[inline]
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// View as a slice (One is a 1-element slice via `slice::from_ref`).
    #[inline]
    pub fn as_slice(&self) -> &[M] {
        match self {
            MsgSlot::One(m) => std::slice::from_ref(m),
            MsgSlot::Many(v) => v.as_slice(),
        }
    }

    /// First message, mutable (combiner target).
    #[inline]
    pub fn first_mut(&mut self) -> Option<&mut M> {
        match self {
            MsgSlot::One(m) => Some(m),
            MsgSlot::Many(v) => v.first_mut(),
        }
    }

    /// Merge another slot into this one.
    #[inline]
    pub fn merge(&mut self, other: MsgSlot<M>) {
        match other {
            MsgSlot::One(m) => self.push(m),
            MsgSlot::Many(ms) => {
                for m in ms {
                    self.push(m);
                }
            }
        }
    }
}

/// Completed-query record handed back to the submitter.
#[derive(Debug, Clone)]
pub struct QueryResult<Out> {
    pub qid: QueryId,
    pub out: Out,
    pub stats: QueryStats,
}

/// Lifecycle phase of an in-flight query.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub(crate) enum Phase {
    /// Executing supersteps.
    Running,
    /// Converged/terminated; the next super-round is the reporting round.
    Reporting,
}

/// One worker's slice of one in-flight query: everything the worker thread
/// mutates during the compute phase. Shards of the same query are disjoint,
/// so the engine can hand shard `w` of every query to thread `w` without
/// synchronization; cross-shard traffic flows only through `staged`, which
/// the barrier (single-threaded) routes into the destination shards' inboxes.
pub(crate) struct WorkerShard<A: QueryApp> {
    /// VQ-data table of this worker (lazy: only touched vertices present).
    pub vstate: FxHashMap<VertexId, VState<A::VQ>>,
    /// Active list (vertices that did not vote halt).
    pub active: Vec<VertexId>,
    /// Inbox for the *current* superstep.
    pub inbox: FxHashMap<VertexId, MsgSlot<A::Msg>>,
    /// Staged outgoing messages, keyed by destination worker then by
    /// destination vertex (reused across rounds; exchanged at the barrier).
    pub staged: Vec<FxHashMap<VertexId, MsgSlot<A::Msg>>>,
    /// This worker's aggregator partial for the current superstep (folded
    /// across shards in worker order at the barrier, then reset).
    pub agg_round: A::Agg,
    /// Set when a vertex on this shard called `force_terminate` (OR-folded
    /// into the query flag at the barrier).
    pub terminated: bool,
}

impl<A: QueryApp> WorkerShard<A> {
    fn new(workers: usize) -> Self {
        Self {
            vstate: FxHashMap::default(),
            active: Vec::new(),
            inbox: FxHashMap::default(),
            staged: (0..workers).map(|_| FxHashMap::default()).collect(),
            agg_round: A::Agg::default(),
            terminated: false,
        }
    }
}

/// Q-data + per-worker shards for one in-flight query.
pub(crate) struct QueryRt<A: QueryApp> {
    pub id: QueryId,
    pub query: A::Query,
    /// Superstep number (1-based during compute).
    pub step: u64,
    pub phase: Phase,
    /// Worker-major state: `shards[w]` is owned by worker `w`'s thread
    /// during the compute phase.
    pub shards: Vec<WorkerShard<A>>,
    /// Merged aggregator from the previous superstep (visible to compute).
    pub agg_prev: A::Agg,
    /// Set when any vertex (or the master hook) called force_terminate.
    pub terminated: bool,
    pub stats: QueryStats,
}

impl<A: QueryApp> QueryRt<A> {
    pub fn new(id: QueryId, query: A::Query, workers: usize, submitted_at: f64) -> Self {
        Self {
            id,
            query,
            step: 0,
            phase: Phase::Running,
            shards: (0..workers).map(|_| WorkerShard::new(workers)).collect(),
            agg_prev: A::Agg::default(),
            terminated: false,
            stats: QueryStats {
                qid: id,
                submitted_at,
                ..Default::default()
            },
        }
    }

    /// Total touched vertices across workers (VQ-data entries allocated).
    pub fn touched(&self) -> u64 {
        self.shards.iter().map(|s| s.vstate.len() as u64).sum()
    }

    /// True when no vertex is active and no message is pending.
    pub fn quiescent(&self) -> bool {
        self.shards
            .iter()
            .all(|s| s.active.is_empty() && s.inbox.is_empty())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn push_promotes_one_to_many() {
        let mut s = MsgSlot::One(1u32);
        assert_eq!(s.len(), 1);
        s.push(2);
        match &s {
            MsgSlot::Many(v) => assert_eq!(v.as_slice(), &[1, 2]),
            MsgSlot::One(_) => panic!("push must promote One to Many"),
        }
        s.push(3);
        assert_eq!(s.as_slice(), &[1, 2, 3]);
    }

    #[test]
    fn merge_one_into_one() {
        let mut a = MsgSlot::One(10u32);
        a.merge(MsgSlot::One(20));
        assert_eq!(a.as_slice(), &[10, 20]);
        assert_eq!(a.len(), 2);
    }

    #[test]
    fn merge_many_into_one_and_one_into_many() {
        let mut a = MsgSlot::One(1u32);
        a.merge(MsgSlot::Many(vec![2, 3]));
        assert_eq!(a.as_slice(), &[1, 2, 3]);

        let mut b = MsgSlot::Many(vec![4u32, 5]);
        b.merge(MsgSlot::One(6));
        assert_eq!(b.as_slice(), &[4, 5, 6]);
    }

    #[test]
    fn merge_many_into_many_keeps_order() {
        let mut a = MsgSlot::Many(vec![1u32, 2]);
        a.merge(MsgSlot::Many(vec![3, 4]));
        assert_eq!(a.as_slice(), &[1, 2, 3, 4]);
    }

    #[test]
    fn as_slice_of_one_is_singleton() {
        let s = MsgSlot::One(7u32);
        assert_eq!(s.as_slice(), &[7]);
        assert!(!s.is_empty());
    }

    #[test]
    fn first_mut_targets_head() {
        let mut s = MsgSlot::One(1u32);
        *s.first_mut().unwrap() = 9;
        assert_eq!(s.as_slice(), &[9]);
        s.push(2);
        *s.first_mut().unwrap() = 8;
        assert_eq!(s.as_slice(), &[8, 2]);
    }

    #[test]
    fn drained_many_is_empty() {
        // A Many whose Vec was drained is the only empty form a slot can
        // take; One is always non-empty.
        let mut s: MsgSlot<u32> = MsgSlot::Many(vec![1, 2]);
        if let MsgSlot::Many(v) = &mut s {
            v.clear();
        }
        assert!(s.is_empty());
        assert_eq!(s.len(), 0);
        assert_eq!(s.as_slice(), &[] as &[u32]);
        assert!(s.first_mut().is_none());
        // Refilling via push works from the drained state.
        s.push(5);
        assert_eq!(s.as_slice(), &[5]);
    }
}
