//! Per-query runtime state: the rust analog of the paper's Q-data entry in
//! `HT_Q` plus the per-worker slices of VQ-data and message stores.

use crate::graph::VertexId;
use crate::metrics::QueryStats;
use crate::util::FxHashMap;
use crate::vertex::{QueryApp, QueryId};

/// Per-vertex, per-query state (one `LUT_v[q]` entry): the vertex value
/// `a_q(v)` plus the halted flag and a stamp to dedup processing within a
/// super-round.
#[derive(Debug, Clone)]
pub struct VState<VQ> {
    pub vq: VQ,
    pub halted: bool,
    pub(crate) computed_step: u64,
}

/// Message storage per destination vertex: the overwhelmingly common case
/// after sender-side combining is a single message, which this enum keeps
/// inline (no heap allocation on either side of the barrier).
#[derive(Debug, Clone)]
pub enum MsgSlot<M> {
    One(M),
    Many(Vec<M>),
}

impl<M> MsgSlot<M> {
    #[inline]
    pub fn push(&mut self, m: M) {
        match self {
            MsgSlot::One(_) => {
                let MsgSlot::One(first) = std::mem::replace(self, MsgSlot::Many(Vec::new()))
                else {
                    unreachable!()
                };
                let MsgSlot::Many(v) = self else { unreachable!() };
                v.reserve(4);
                v.push(first);
                v.push(m);
            }
            MsgSlot::Many(v) => v.push(m),
        }
    }

    #[inline]
    pub fn len(&self) -> usize {
        match self {
            MsgSlot::One(_) => 1,
            MsgSlot::Many(v) => v.len(),
        }
    }

    /// True when the slot holds no message (only possible for a drained
    /// `Many`).
    #[allow(dead_code)]
    #[inline]
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// View as a slice (One is a 1-element slice via `slice::from_ref`).
    #[inline]
    pub fn as_slice(&self) -> &[M] {
        match self {
            MsgSlot::One(m) => std::slice::from_ref(m),
            MsgSlot::Many(v) => v.as_slice(),
        }
    }

    /// First message, mutable (combiner target).
    #[inline]
    pub fn first_mut(&mut self) -> Option<&mut M> {
        match self {
            MsgSlot::One(m) => Some(m),
            MsgSlot::Many(v) => v.first_mut(),
        }
    }

    /// Merge another slot into this one.
    #[inline]
    pub fn merge(&mut self, other: MsgSlot<M>) {
        match other {
            MsgSlot::One(m) => self.push(m),
            MsgSlot::Many(ms) => {
                for m in ms {
                    self.push(m);
                }
            }
        }
    }
}

/// Completed-query record handed back to the submitter.
#[derive(Debug, Clone)]
pub struct QueryResult<Out> {
    pub qid: QueryId,
    pub out: Out,
    pub stats: QueryStats,
}

/// Lifecycle phase of an in-flight query.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub(crate) enum Phase {
    /// Executing supersteps.
    Running,
    /// Converged/terminated; the next super-round is the reporting round.
    Reporting,
}

/// Q-data + per-worker stores for one in-flight query.
pub(crate) struct QueryRt<A: QueryApp> {
    pub id: QueryId,
    pub query: A::Query,
    /// Superstep number (1-based during compute).
    pub step: u64,
    pub phase: Phase,
    /// Per-worker VQ-data tables (lazy: only touched vertices present).
    pub vstate: Vec<FxHashMap<VertexId, VState<A::VQ>>>,
    /// Per-worker active lists (vertices that did not vote halt).
    pub active: Vec<Vec<VertexId>>,
    /// Per-worker inbox for the *current* superstep.
    pub inbox: Vec<FxHashMap<VertexId, MsgSlot<A::Msg>>>,
    /// Per-dst-worker staged outgoing messages (reused across rounds).
    pub staged: Vec<FxHashMap<VertexId, MsgSlot<A::Msg>>>,
    /// This round's aggregator partial (reused across rounds).
    pub agg_round: A::Agg,
    /// Merged aggregator from the previous superstep (visible to compute).
    pub agg_prev: A::Agg,
    /// Set when any vertex (or the master hook) called force_terminate.
    pub terminated: bool,
    pub stats: QueryStats,
}

impl<A: QueryApp> QueryRt<A> {
    pub fn new(id: QueryId, query: A::Query, workers: usize, submitted_at: f64) -> Self {
        Self {
            id,
            query,
            step: 0,
            phase: Phase::Running,
            vstate: (0..workers).map(|_| FxHashMap::default()).collect(),
            active: vec![Vec::new(); workers],
            inbox: (0..workers).map(|_| FxHashMap::default()).collect(),
            staged: (0..workers).map(|_| FxHashMap::default()).collect(),
            agg_round: A::Agg::default(),
            agg_prev: A::Agg::default(),
            terminated: false,
            stats: QueryStats {
                qid: id,
                submitted_at,
                ..Default::default()
            },
        }
    }

    /// Total touched vertices across workers (VQ-data entries allocated).
    pub fn touched(&self) -> u64 {
        self.vstate.iter().map(|m| m.len() as u64).sum()
    }

    /// True when no vertex is active and no message is pending.
    pub fn quiescent(&self) -> bool {
        self.active.iter().all(|a| a.is_empty()) && self.inbox.iter().all(|i| i.is_empty())
    }
}
