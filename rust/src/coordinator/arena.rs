//! Flat memory layout for the query hot path (the `Layout` knob).
//!
//! The baseline engine keeps every per-query store in `FxHashMap`s: the
//! shard's VQ-data table, its inbox, and the per-destination staging maps
//! all hash, probe, and chase pointers on every touched vertex of every
//! in-flight query. Under [`Layout::Flat`] (the default) those maps become
//! arena-shaped:
//!
//! * **VQ-data + inbox** live in a [`FlatStore`]: a slab arena of
//!   `VState` slots plus a dense `VertexId → u32` handle table derived
//!   from the graph's CSR numbering (worker `w` owns exactly the vertices
//!   with `v % workers == w`, so `v / workers` is a dense per-worker
//!   index). First-touch order is recorded in a side vector, so the
//!   reporting-round iteration and the work-item order the determinism
//!   locks pin replay exactly as the serial hash-map path did. Message
//!   delivery appends the touched handle to a `recv` list in delivery
//!   order — the flat twin of the inbox map's insertion history.
//! * **Staging** becomes columnar: one insertion-ordered
//!   [`OrderedStaging`] buffer per destination worker (a
//!   `Vec<(VertexId, MsgSlot)>` in first-touch order plus a combining
//!   index), wrapped in [`StagedBuf`] so the hashed baseline and the flat
//!   path share every engine chokepoint. Sender-side combining runs the
//!   identical [`merge_msg`] rule in both layouts, so per-destination slot
//!   contents are equal by construction; only the *cross-destination*
//!   drain order differs (first-touch vs hash iteration), which no
//!   shipped app can observe — delivery per destination vertex replays
//!   the same per-slot sequences either way.
//!
//! The exchange phase moves whole stores: [`VStore::take_exchange_sink`]
//! lends the destination store (hashed: just the inbox map; flat: the
//! whole arena, since delivery assigns handles) to the exchange jobs and
//! [`VStore::restore_exchange_sink`] hands it back, mirroring the
//! map-handoff the barrier and pipelined paths already used.
//!
//! Everything here is layout *plumbing*; the single delivery/combine rule
//! stays [`merge_msg`], which is what keeps `QueryResult::out`
//! bit-identical across the `Layout` axis for every threads × workers ×
//! capacity × `Sched` × `Split` × `EdgeSplit` × `Pipeline` combination
//! (pinned by `tests/determinism.rs` and the fuzzer).

use std::collections::hash_map::Entry;

use super::query::{merge_msg, MsgSlot, OrderedStaging, VState};
use crate::graph::VertexId;
use crate::util::FxHashMap;
use crate::vertex::QueryApp;

/// Memory layout of the per-query hot-path stores (see module docs).
/// Outputs are bit-identical either way — the layout changes where state
/// lives, never what [`merge_msg`] delivers or in what per-slot order.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Layout {
    /// The pre-arena baseline: `FxHashMap` vstate/inbox/staging. Kept as
    /// the benchmark baseline and the fuzzer's serial reference.
    Hashed,
    /// Slab-arena vertex state with a dense handle table and columnar
    /// insertion-ordered staging buffers. The default.
    Flat,
}

impl Layout {
    /// The default layout for new engines: [`Layout::Flat`], unless the
    /// `QUEGEL_TEST_LAYOUT` environment variable says `hashed`. This is
    /// the CI test-matrix hook — `QUEGEL_TEST_LAYOUT=hashed cargo test`
    /// runs the whole suite on the hash-map baseline without touching any
    /// call site; explicit [`super::Engine::layout`] calls still win.
    pub fn default_from_env() -> Self {
        match std::env::var("QUEGEL_TEST_LAYOUT") {
            Ok(v) if v.eq_ignore_ascii_case("hashed") => {
                static NOTE: std::sync::Once = std::sync::Once::new();
                NOTE.call_once(|| {
                    eprintln!(
                        "quegel: QUEGEL_TEST_LAYOUT=hashed overrides the default \
                         memory layout (test-matrix hook); unset it for the flat \
                         arena path"
                    );
                });
                Layout::Hashed
            }
            _ => Layout::Flat,
        }
    }
}

/// Sentinel for "this vertex has no arena handle yet".
const NO_HANDLE: u32 = u32::MAX;

/// Slab arena holding one worker shard's per-query vertex state and inbox
/// (the flat twin of the `vstate` + `inbox` hash maps).
///
/// `handles` is indexed by the worker-local dense index `v / stride`
/// (`stride` = worker count; the cluster assigns `v % workers == w` to
/// worker `w`) and grows lazily to the highest local index touched —
/// first-touch handles are assigned in increasing order, and `verts`
/// records them so iteration replays first-touch order without scanning
/// the (mostly-empty) handle table.
pub(crate) struct FlatStore<A: QueryApp> {
    /// Worker count == modulus of the vertex→worker map; `v / stride` is
    /// this shard's dense local index for vertex `v`.
    pub stride: usize,
    /// Local index → handle (`NO_HANDLE` when untouched).
    pub handles: Vec<u32>,
    /// Handle → vertex id, in first-touch order.
    pub verts: Vec<VertexId>,
    /// Handle → VQ-data slot (`None` until the vertex allocates state —
    /// a delivered-but-never-computed message touches the handle only).
    pub state: Vec<Option<VState<A::VQ>>>,
    /// Handle → pending inbox slot for the current superstep.
    pub msg: Vec<Option<MsgSlot<A::Msg>>>,
    /// Handles with a pending inbox slot, in delivery order — the flat
    /// twin of the inbox map's key-insertion history. Drained (and its
    /// capacity recycled) by the compute phase each superstep.
    pub recv: Vec<u32>,
    /// Allocated VQ-data entries (`state[h].is_some()` count): the
    /// paper's per-query access count.
    pub n_state: usize,
}

impl<A: QueryApp> FlatStore<A> {
    pub fn new(stride: usize) -> Self {
        Self::with_vertex_hint(stride, 0)
    }

    /// Arena pre-sized for a graph of `n_vertices` id slots: the handle
    /// table is allocated up front at the worker's share of the id space
    /// instead of growing lazily. Under streaming mutations this is the
    /// epoch-aware entry point — each query's shards are sized to the
    /// vertex-slot count of the epoch **pinned at admission** (delta-added
    /// vertices included, deleted slots retained), so mid-flight epoch
    /// bumps never reshape a live handle table. A hint of 0 keeps the
    /// lazy-growth behavior; `touch` still grows past any hint, so the
    /// hint is capacity, never a bound.
    pub fn with_vertex_hint(stride: usize, n_vertices: usize) -> Self {
        let stride = stride.max(1);
        Self {
            stride,
            handles: vec![NO_HANDLE; n_vertices.div_ceil(stride)],
            verts: Vec::new(),
            state: Vec::new(),
            msg: Vec::new(),
            recv: Vec::new(),
            n_state: 0,
        }
    }

    /// Handle for `v`, assigning one (first-touch) if absent. Idempotent.
    #[inline]
    pub fn touch(&mut self, v: VertexId) -> u32 {
        let li = v as usize / self.stride;
        if li >= self.handles.len() {
            self.handles.resize(li + 1, NO_HANDLE);
        }
        let h = self.handles[li];
        if h != NO_HANDLE {
            return h;
        }
        let h = self.verts.len() as u32;
        self.handles[li] = h;
        self.verts.push(v);
        self.state.push(None);
        self.msg.push(None);
        h
    }

    /// Handle for `v` if it was ever touched.
    #[inline]
    pub fn handle_of(&self, v: VertexId) -> Option<u32> {
        let h = *self.handles.get(v as usize / self.stride)?;
        (h != NO_HANDLE).then_some(h)
    }

    /// Ensure a VQ-data slot for `v` exists, initializing via `init` on
    /// first allocation (the lazy VQ-data rule).
    #[inline]
    pub fn ensure_state_with(
        &mut self,
        v: VertexId,
        init: impl FnOnce() -> VState<A::VQ>,
    ) -> &mut VState<A::VQ> {
        let h = self.touch(v) as usize;
        let slot = &mut self.state[h];
        if slot.is_none() {
            *slot = Some(init());
            self.n_state += 1;
        }
        slot.as_mut().expect("just ensured")
    }

    /// Deliver one staged slot to `dst`, replaying the sender-side
    /// combiner per message — the flat twin of [`super::query::deliver_map`]'s
    /// per-entry rule. Returns messages delivered (post-combiner).
    pub fn deliver_slot(&mut self, app: &A, dst: VertexId, slot: MsgSlot<A::Msg>) -> u64 {
        let h = self.touch(dst);
        match &mut self.msg[h as usize] {
            Some(into) => {
                let mut delivered = 0u64;
                match slot {
                    MsgSlot::One(m) => delivered += merge_msg(app, into, m),
                    MsgSlot::Many(ms) => {
                        for m in ms {
                            delivered += merge_msg(app, into, m);
                        }
                    }
                }
                delivered
            }
            none => {
                let delivered = slot.len() as u64;
                *none = Some(slot); // moves, no allocation
                self.recv.push(h);
                delivered
            }
        }
    }

    /// Drain one source staging buffer into this store's inbox slots in
    /// the buffer's first-touch order, replaying the combiner per message.
    /// Leaves `src` empty with its capacity kept.
    pub fn deliver_from(&mut self, app: &A, src: &mut OrderedStaging<A>) -> u64 {
        let mut delivered = 0u64;
        for (dst, slot) in src.drain_slots() {
            delivered += self.deliver_slot(app, dst, slot);
        }
        delivered
    }
}

/// One worker shard's vertex-state + inbox store, in either layout. The
/// layout is fixed per engine (every shard of every query matches the
/// engine knob), so the cross-variant arms of the restore/delivery
/// helpers are unreachable by construction.
pub(crate) enum VStore<A: QueryApp> {
    Hashed {
        /// VQ-data table (lazy: only touched vertices present).
        vstate: FxHashMap<VertexId, VState<A::VQ>>,
        /// Inbox for the current superstep.
        inbox: FxHashMap<VertexId, MsgSlot<A::Msg>>,
    },
    Flat(FlatStore<A>),
}

impl<A: QueryApp> VStore<A> {
    pub fn new(layout: Layout, workers: usize) -> Self {
        Self::with_vertex_hint(layout, workers, 0)
    }

    /// Store pre-sized for `n_vertices` id slots (see
    /// [`FlatStore::with_vertex_hint`]); the hashed layout ignores the
    /// hint (its maps size to touched vertices, not the id space).
    pub fn with_vertex_hint(layout: Layout, workers: usize, n_vertices: usize) -> Self {
        match layout {
            Layout::Hashed => VStore::Hashed {
                vstate: FxHashMap::default(),
                inbox: FxHashMap::default(),
            },
            Layout::Flat => VStore::Flat(FlatStore::with_vertex_hint(workers, n_vertices)),
        }
    }

    /// Ensure VQ-data for `v` (admission seeding of `init_activate`
    /// vertices; the same lazy-allocation rule the compute phase uses).
    pub fn seed_with(&mut self, v: VertexId, init: impl FnOnce() -> VState<A::VQ>) {
        match self {
            VStore::Hashed { vstate, .. } => {
                vstate.entry(v).or_insert_with(init);
            }
            VStore::Flat(fs) => {
                fs.ensure_state_with(v, init);
            }
        }
    }

    /// Pending inbox entries (destination vertices with undelivered
    /// messages) — the receiver half of a compute task's size estimate.
    #[inline]
    pub fn pending(&self) -> usize {
        match self {
            VStore::Hashed { inbox, .. } => inbox.len(),
            VStore::Flat(fs) => fs.recv.len(),
        }
    }

    /// Allocated VQ-data entries (the paper's per-query access count).
    #[inline]
    pub fn touched(&self) -> usize {
        match self {
            VStore::Hashed { vstate, .. } => vstate.len(),
            VStore::Flat(fs) => fs.n_state,
        }
    }

    /// Iterate every touched `(v, &vq)` pair for the reporting round
    /// (hashed: map iteration order; flat: first-touch order — shipped
    /// `finish` implementations are order-insensitive, which is what the
    /// cross-layout bit-identity contract leans on).
    pub fn touched_iter(&self) -> TouchedIter<'_, A> {
        match self {
            VStore::Hashed { vstate, .. } => TouchedIter::Hashed(vstate.iter()),
            VStore::Flat(fs) => TouchedIter::Flat(fs.verts.iter().zip(fs.state.iter())),
        }
    }

    /// Lend the exchange phase this shard's delivery target: the inbox
    /// map (hashed) or the whole arena (flat — delivery assigns handles,
    /// so the store travels as one unit). The shard is left with an empty
    /// placeholder; nothing touches it until [`Self::restore_exchange_sink`].
    pub fn take_exchange_sink(&mut self) -> ExchangeSink<A> {
        match self {
            VStore::Hashed { inbox, .. } => ExchangeSink::Hashed(std::mem::take(inbox)),
            VStore::Flat(fs) => {
                let stride = fs.stride;
                ExchangeSink::Flat(std::mem::replace(fs, FlatStore::new(stride)))
            }
        }
    }

    /// Hand the exchange sink back to the shard (inverse of
    /// [`Self::take_exchange_sink`]).
    pub fn restore_exchange_sink(&mut self, sink: ExchangeSink<A>) {
        match (self, sink) {
            (VStore::Hashed { inbox, .. }, ExchangeSink::Hashed(m)) => *inbox = m,
            (VStore::Flat(fs), ExchangeSink::Flat(nfs)) => *fs = nfs,
            _ => unreachable!("layout is fixed per engine"),
        }
    }
}

/// Reporting-round iterator over touched `(v, &vq)` pairs of one shard.
pub(crate) enum TouchedIter<'s, A: QueryApp> {
    Hashed(std::collections::hash_map::Iter<'s, VertexId, VState<A::VQ>>),
    Flat(FlatTouchedIter<'s, A>),
}

/// The flat arm's zip: first-touch `verts` against the state slots.
type FlatTouchedIter<'s, A> = std::iter::Zip<
    std::slice::Iter<'s, VertexId>,
    std::slice::Iter<'s, Option<VState<<A as QueryApp>::VQ>>>,
>;

impl<'s, A: QueryApp> Iterator for TouchedIter<'s, A> {
    type Item = (VertexId, &'s A::VQ);

    fn next(&mut self) -> Option<Self::Item> {
        match self {
            TouchedIter::Hashed(it) => it.next().map(|(&v, st)| (v, &st.vq)),
            TouchedIter::Flat(it) => {
                // Skip handles that only ever received (undelivered-at-
                // termination messages): no VQ-data was allocated, so the
                // hashed path never saw them either.
                for (&v, st) in it.by_ref() {
                    if let Some(st) = st {
                        return Some((v, &st.vq));
                    }
                }
                None
            }
        }
    }
}

/// One per-destination-worker staging buffer, in either layout: the flat
/// path stages into an insertion-ordered columnar buffer (first-touch
/// `Vec` + combining index) instead of a hash map. Both arms run the same
/// [`merge_msg`] combining rule, so per-destination slot contents are
/// identical by construction. `Default` is an empty `Hashed` placeholder
/// (for `std::mem::take` handoffs); the engine replaces it before any
/// message is staged.
pub(crate) enum StagedBuf<A: QueryApp> {
    Hashed(FxHashMap<VertexId, MsgSlot<A::Msg>>),
    Flat(OrderedStaging<A>),
}

impl<A: QueryApp> Default for StagedBuf<A> {
    fn default() -> Self {
        StagedBuf::Hashed(FxHashMap::default())
    }
}

impl<A: QueryApp> StagedBuf<A> {
    pub fn new(layout: Layout) -> Self {
        match layout {
            Layout::Hashed => StagedBuf::Hashed(FxHashMap::default()),
            Layout::Flat => StagedBuf::Flat(OrderedStaging::empty()),
        }
    }

    /// Stage one message for `dst`, replaying the sender-side combiner
    /// against the destination's existing slot.
    #[inline]
    pub fn stage(&mut self, app: &A, dst: VertexId, msg: A::Msg) {
        match self {
            StagedBuf::Hashed(map) => match map.entry(dst) {
                Entry::Occupied(mut e) => {
                    let _ = merge_msg(app, e.get_mut(), msg);
                }
                Entry::Vacant(e) => {
                    e.insert(MsgSlot::One(msg));
                }
            },
            StagedBuf::Flat(ord) => ord.stage(app, dst, msg),
        }
    }

    #[inline]
    pub fn is_empty(&self) -> bool {
        match self {
            StagedBuf::Hashed(map) => map.is_empty(),
            StagedBuf::Flat(ord) => ord.slots.is_empty(),
        }
    }
}

/// The exchange phase's delivery target for one destination shard (see
/// [`VStore::take_exchange_sink`]). `Default` is an empty `Hashed`
/// placeholder for `std::mem::take` handoffs.
pub(crate) enum ExchangeSink<A: QueryApp> {
    Hashed(FxHashMap<VertexId, MsgSlot<A::Msg>>),
    Flat(FlatStore<A>),
}

impl<A: QueryApp> Default for ExchangeSink<A> {
    fn default() -> Self {
        ExchangeSink::Hashed(FxHashMap::default())
    }
}

/// Deliver one source shard's staged buffer into a destination sink,
/// replaying the sender-side combiner per message — the single delivery
/// rule shared by the barrier exchange lanes and the pipelined eager
/// column handoff, now uniform across both layouts. Returns messages
/// delivered (post-combiner); leaves `src` empty with capacity kept.
pub(crate) fn deliver_into_sink<A: QueryApp>(
    app: &A,
    sink: &mut ExchangeSink<A>,
    src: &mut StagedBuf<A>,
) -> u64 {
    match (sink, src) {
        (ExchangeSink::Hashed(inbox), StagedBuf::Hashed(map)) => {
            super::query::deliver_map(app, inbox, map)
        }
        (ExchangeSink::Flat(fs), StagedBuf::Flat(ord)) => {
            if ord.slots.is_empty() {
                return 0; // skip the W²-mostly-empty buffers cheaply
            }
            fs.deliver_from(app, ord)
        }
        _ => unreachable!("layout is fixed per engine"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::vertex::Ctx;

    /// Minimal app whose combiner sums `u32` messages while the head stays
    /// below 100 (the same contract `query.rs` pins for `merge_msg`).
    struct SumBelow100;

    impl QueryApp for SumBelow100 {
        type Query = ();
        type VQ = u32;
        type Msg = u32;
        type Agg = ();
        type Out = ();

        fn init_activate(&self, _q: &()) -> Vec<VertexId> {
            Vec::new()
        }

        fn init_value(&self, _q: &(), _v: VertexId) -> u32 {
            0
        }

        fn compute(&self, _ctx: &mut Ctx<'_, Self>, _v: VertexId, _vq: &mut u32) {}

        fn combine(&self, into: &mut u32, from: &u32) -> bool {
            if *into + *from < 100 {
                *into += *from;
                true
            } else {
                false
            }
        }

        fn finish(
            &self,
            _q: &(),
            _touched: &mut dyn Iterator<Item = (VertexId, &u32)>,
            _agg: &(),
        ) {
        }
    }

    fn vs(vq: u32) -> VState<u32> {
        VState {
            vq,
            halted: false,
            computed_step: 0,
        }
    }

    #[test]
    fn handle_table_is_dense_idempotent_and_first_touch_ordered() {
        // Worker 1 of 4 owns vertices ≡ 1 (mod 4): 9, 1, 5, 13, ...
        let mut fs = FlatStore::<SumBelow100>::new(4);
        let h9 = fs.touch(9);
        let h1 = fs.touch(1);
        let h9b = fs.touch(9);
        assert_eq!(h9, 0, "first touch gets handle 0");
        assert_eq!(h1, 1);
        assert_eq!(h9b, h9, "touch is idempotent");
        assert_eq!(fs.verts, vec![9, 1], "side vector records first-touch order");
        // Dense local indexing: vertex 9 sits at local index 9/4 = 2.
        assert_eq!(fs.handles[2], h9);
        assert_eq!(fs.handle_of(9), Some(h9));
        assert_eq!(fs.handle_of(13), None, "untouched vertex has no handle");
        assert_eq!(fs.handle_of(401), None, "beyond-table lookup is None");
        // A lazily-grown table keeps earlier handles valid.
        let h401 = fs.touch(401);
        assert_eq!(h401, 2);
        assert_eq!(fs.handle_of(9), Some(h9));
    }

    #[test]
    fn vertex_hint_presizes_the_handle_table_without_bounding_it() {
        // Worker share of a 10-slot id space across 4 workers: ceil(10/4).
        let fs = FlatStore::<SumBelow100>::with_vertex_hint(4, 10);
        assert_eq!(fs.handles.len(), 3);
        assert!(fs.handles.iter().all(|&h| h == NO_HANDLE));
        assert!(fs.verts.is_empty(), "hint allocates capacity, not handles");
        // The hint is capacity, never a bound: touching past it grows.
        let mut fs = FlatStore::<SumBelow100>::with_vertex_hint(4, 10);
        let h = fs.touch(9);
        assert_eq!(fs.handle_of(9), Some(h));
        let h2 = fs.touch(41); // local index 10, beyond the hint
        assert_eq!(fs.handle_of(41), Some(h2));
        assert_eq!(fs.verts, vec![9, 41]);
        // Hint 0 is the lazy baseline.
        let fs = FlatStore::<SumBelow100>::with_vertex_hint(4, 0);
        assert!(fs.handles.is_empty());
    }

    #[test]
    fn ensure_state_allocates_once_and_counts() {
        let mut fs = FlatStore::<SumBelow100>::new(2);
        assert_eq!(fs.n_state, 0);
        fs.ensure_state_with(4, || vs(7)).vq += 1;
        fs.ensure_state_with(4, || vs(999)); // init must NOT rerun
        assert_eq!(fs.n_state, 1);
        assert_eq!(fs.state[fs.handle_of(4).unwrap() as usize].as_ref().unwrap().vq, 8);
    }

    #[test]
    fn deliver_slot_moves_wholesale_then_merges_elementwise() {
        let app = SumBelow100;
        let mut fs = FlatStore::<SumBelow100>::new(1);
        // First delivery: wholesale move, counted at slot length, handle
        // recorded in delivery order.
        assert_eq!(fs.deliver_slot(&app, 3, MsgSlot::Many(vec![60, 50])), 2);
        assert_eq!(fs.deliver_slot(&app, 5, MsgSlot::One(1)), 1);
        assert_eq!(fs.recv, vec![0, 1], "delivery order recorded once per dst");
        // Second delivery to 3: elementwise combiner replay against the
        // head (60 + 30 < 100 combines; 90 + 90 declines and appends).
        assert_eq!(fs.deliver_slot(&app, 3, MsgSlot::Many(vec![30, 90])), 1);
        let h3 = fs.handle_of(3).unwrap() as usize;
        assert_eq!(fs.msg[h3].as_ref().unwrap().as_slice(), &[90, 50, 90]);
        assert_eq!(fs.recv, vec![0, 1], "re-delivery must not re-record");
        assert_eq!(fs.n_state, 0, "delivery alone allocates no VQ-data");
    }

    #[test]
    fn deliver_from_replays_staging_in_first_touch_order() {
        let app = SumBelow100;
        let mut fs = FlatStore::<SumBelow100>::new(1);
        let mut ord = OrderedStaging::<SumBelow100>::empty();
        ord.stage(&app, 7, 1);
        ord.stage(&app, 2, 5);
        ord.stage(&app, 7, 2); // combines into 7's slot: 1 + 2 = 3
        assert_eq!(fs.deliver_from(&app, &mut ord), 2);
        assert_eq!(fs.verts, vec![7, 2], "delivery follows first-touch order");
        assert!(ord.slots.is_empty(), "source drained for recycling");
        // The drained buffer is reusable: first-touch index was cleared.
        ord.stage(&app, 7, 9);
        assert_eq!(ord.slots.len(), 1);
    }

    #[test]
    fn exchange_sink_roundtrip_preserves_the_arena() {
        let app = SumBelow100;
        let mut store = VStore::<SumBelow100>::new(Layout::Flat, 2);
        store.seed_with(6, || vs(42));
        let mut sink = store.take_exchange_sink();
        assert_eq!(store.touched(), 0, "placeholder store is empty");
        let mut src = StagedBuf::<SumBelow100>::new(Layout::Flat);
        src.stage(&app, 8, 3);
        assert_eq!(deliver_into_sink(&app, &mut sink, &mut src), 1);
        store.restore_exchange_sink(sink);
        assert_eq!(store.touched(), 1, "seeded state survived the roundtrip");
        assert_eq!(store.pending(), 1, "delivered message is pending");
        let VStore::Flat(fs) = &store else { unreachable!() };
        assert_eq!(fs.verts, vec![6, 8]);
    }

    #[test]
    fn touched_iter_skips_stateless_handles_and_replays_first_touch() {
        let app = SumBelow100;
        let mut store = VStore::<SumBelow100>::new(Layout::Flat, 1);
        store.seed_with(5, || vs(50));
        store.seed_with(3, || vs(30));
        // Vertex 9 only ever receives (no VQ-data): invisible to reporting.
        let VStore::Flat(fs) = &mut store else { unreachable!() };
        fs.deliver_slot(&app, 9, MsgSlot::One(1));
        let got: Vec<(VertexId, u32)> = store.touched_iter().map(|(v, &vq)| (v, vq)).collect();
        assert_eq!(got, vec![(5, 50), (3, 30)]);
        assert_eq!(store.touched(), 2);
    }

    #[test]
    fn staged_buf_combines_identically_across_layouts() {
        let app = SumBelow100;
        let mut hashed = StagedBuf::<SumBelow100>::new(Layout::Hashed);
        let mut flat = StagedBuf::<SumBelow100>::new(Layout::Flat);
        for (dst, m) in [(4u32, 60u32), (2, 5), (4, 30), (4, 90)] {
            hashed.stage(&app, dst, m);
            flat.stage(&app, dst, m);
        }
        let StagedBuf::Hashed(map) = &hashed else { unreachable!() };
        let StagedBuf::Flat(ord) = &flat else { unreachable!() };
        assert_eq!(ord.slots[0].0, 4, "columnar buffer keeps first-touch order");
        for (dst, slot) in &ord.slots {
            assert_eq!(
                map.get(dst).unwrap().as_slice(),
                slot.as_slice(),
                "slot contents must match the hashed baseline for dst {dst}"
            );
        }
        assert!(!flat.is_empty() && !hashed.is_empty());
    }
}
