//! The superstep-sharing engine loop: three parallel phases per super-round
//! on a persistent worker pool.
//!
//! Execution model: every BSP worker is a [`WorkerShard`] per in-flight
//! query, and each super-round runs three phases, all executed by the same
//! long-lived [`WorkerPool`] (created once per engine and woken per phase —
//! no per-round thread spawn/join):
//!
//! 1. **Compute** — shard `w` of every running query is grouped into worker
//!    *lane* `w`; lanes run concurrently, each owning disjoint state.
//! 2. **Exchange** — the barrier's message routing, destination-sharded:
//!    staging buffers are already keyed by destination worker, so
//!    destination `dw` drains `shards[src].staged[dw]` from every `src` in
//!    source-worker order, concurrently with every other destination. The
//!    source-order replay, together with the sender-side combiner replay in
//!    `merge_msg`, reproduces message for message what one shared staging
//!    buffer would have held — delivery is bit-identical to the old serial
//!    barrier, without its O(W²) serial loop.
//! 3. **Fold** — per-query aggregator folding (worker order, unchanged),
//!    the master hook and lifecycle transitions run concurrently across
//!    queries; only the reporting round and the simulated-clock advance
//!    stay on the coordinator.
//!
//! Each phase is dispatched through [`run_phase`] at the granularity the
//! [`Sched`] knob selects. The default, [`Sched::Stealing`], hands the
//! pool one job per item — per worker lane (compute), per destination
//! worker (exchange), per query (fold) — and lets idle pool threads steal
//! queued jobs from busy ones, so a hub-heavy lane or one expensive query
//! never pins a phase on a single thread. [`Sched::Static`] keeps the old
//! one-contiguous-chunk-per-thread split as the benchmark baseline.
//!
//! All three phases are deterministic in the thread count *and* the
//! scheduler: stealing only changes which thread executes a job, never the
//! source-worker delivery order inside a destination's exchange job nor
//! the worker-order `agg_merge` fold inside a query's fold job, so
//! `threads = N` produces bit-identical `QueryResult`s to `threads = 1`
//! (pinned by `rust/tests/determinism.rs` across threads × workers ×
//! capacity × scheduler).

use std::collections::hash_map::Entry;
use std::collections::VecDeque;
use std::time::Instant;

use super::pool::{Job, RunStats, WorkerPool};
use super::query::{merge_msg, MsgSlot, Phase, QueryResult, QueryRt, VState, WorkerShard};
use crate::graph::VertexId;
use crate::metrics::EngineMetrics;
use crate::network::Cluster;
use crate::util::FxHashMap;
use crate::vertex::{Ctx, MasterAction, QueryApp, QueryId};

/// Safety cap: a query that exceeds this many supersteps is cut off and
/// flagged `truncated` in its stats (guards against non-converging UDFs).
const DEFAULT_MAX_SUPERSTEPS: u64 = 100_000;

/// The Quegel engine: owns the app (V-data lives inside it), the simulated
/// cluster, the query queue, all in-flight query state, and the persistent
/// worker pool that executes the parallel phases.
pub struct Engine<A: QueryApp> {
    app: A,
    cluster: Cluster,
    capacity: usize,
    /// OS threads for the parallel phases (1 = serial; capped at `workers`).
    threads: usize,
    /// Phase-job granularity: stealing (default) or the static baseline.
    sched: Sched,
    /// Long-lived pool, created lazily at the first super-round that needs
    /// it and joined when the engine drops (even mid-queue).
    pool: Option<WorkerPool>,
    n_vertices: usize,
    queue: VecDeque<(QueryId, A::Query, f64)>,
    inflight: Vec<QueryRt<A>>,
    results: Vec<QueryResult<A::Out>>,
    next_qid: QueryId,
    clock: f64,
    max_supersteps: u64,
    metrics: EngineMetrics,
    // Per-worker scratch buffers reused across super-rounds (perf: no
    // allocation in the hot loop; one per lane so threads never share).
    outbox_scratch: Vec<Vec<(VertexId, A::Msg)>>,
    // Exchange lanes reused across super-rounds: task structs and their
    // `inbound` vectors keep their capacity, so the steady-state exchange
    // allocates nothing (the maps themselves are loaned from the shards).
    exchange_scratch: Vec<ExchangeLane<A>>,
}

/// One worker's share of the compute phase: shard `w` of every running
/// query, plus this worker's scratch buffer and cost/traffic accumulators.
/// Lanes are handed to pool jobs whole; nothing in a lane is visible to
/// another.
struct Lane<'a, A: QueryApp> {
    tasks: Vec<Task<'a, A>>,
    scratch: &'a mut Vec<(VertexId, A::Msg)>,
    /// Simulated compute seconds accumulated by this worker.
    cost: f64,
    compute_calls: u64,
    /// `ctx.send` calls (pre-combiner), for engine-wide traffic counters.
    sent: u64,
}

/// One (query, worker) compute unit inside a lane.
struct Task<'a, A: QueryApp> {
    qid: QueryId,
    /// Superstep this compute phase executes (1-based).
    step: u64,
    query: &'a A::Query,
    agg_prev: &'a A::Agg,
    shard: &'a mut WorkerShard<A>,
}

/// One destination worker's share of the exchange phase: for every running
/// query, the staging buffers addressed to this worker plus the query's
/// destination-shard inbox. Tasks hold the maps *by value* (taken from the
/// shards for the duration of the phase and handed back afterwards), so a
/// lane is owned data — pool jobs need no shard borrows and every
/// destination drains concurrently with every other.
struct ExchangeLane<A: QueryApp> {
    /// One task per running query, in `inflight` order.
    tasks: Vec<ExchangeTask<A>>,
}

/// The exchange unit for one (destination worker, query) pair.
struct ExchangeTask<A: QueryApp> {
    /// `shards[src].staged[dw]` for each source worker, in worker order —
    /// the order the serial barrier replayed, so delivery is bit-identical.
    inbound: Vec<FxHashMap<VertexId, MsgSlot<A::Msg>>>,
    /// The destination shard's inbox for the next superstep.
    inbox: FxHashMap<VertexId, MsgSlot<A::Msg>>,
    /// Messages delivered (post-combiner); folded into stats afterwards.
    delivered: u64,
}

/// Execute every task of one lane: the per-worker serial loop over running
/// queries. Runs on a pool worker when `threads > 1`; touches only the
/// lane's own shards/scratch plus the read-shared app and cluster.
fn run_lane<A: QueryApp>(app: &A, cluster: &Cluster, lane: &mut Lane<'_, A>) {
    for task in lane.tasks.iter_mut() {
        let step = task.step;
        let qid = task.qid;
        let query = task.query;
        let agg_prev = task.agg_prev;
        // Disjoint borrows of the shard's fields so the hot loop can mutate
        // vertex state IN PLACE while staging messages and aggregating.
        let WorkerShard {
            vstate,
            active,
            inbox,
            staged,
            agg_round,
            terminated,
        } = &mut *task.shard;
        let outbox_scratch: &mut Vec<(VertexId, A::Msg)> = &mut *lane.scratch;

        let mut compute_calls: u64 = 0;
        let mut msg_handled: u64 = 0;
        let mut sent_total: u64 = 0;
        let inbox_now = std::mem::take(inbox);
        let mut next_active: Vec<VertexId> = Vec::new();

        // One closure runs a compute() call over in-place state and routes
        // the staged messages with the sender-side combiner.
        let mut run_one = |v: VertexId,
                           st: &mut VState<A::VQ>,
                           msgs: &[A::Msg],
                           next_active: &mut Vec<VertexId>|
         -> u64 {
            let mut ctx = Ctx {
                app,
                qid,
                query,
                step,
                msgs,
                prev_agg: agg_prev,
                agg_partial: &mut *agg_round,
                outbox: &mut *outbox_scratch,
                halt: false,
                terminate: false,
                sent: 0,
            };
            app.compute(&mut ctx, v, &mut st.vq);
            let (halt, terminate, sent) = (ctx.halt, ctx.terminate, ctx.sent);
            st.halted = halt;
            if !halt {
                next_active.push(v);
            }
            if terminate {
                *terminated = true;
            }
            for (dst, msg) in outbox_scratch.drain(..) {
                let dw = cluster.worker_of(dst);
                match staged[dw].entry(dst) {
                    Entry::Occupied(mut e) => {
                        let _ = merge_msg(app, e.get_mut(), msg);
                    }
                    Entry::Vacant(e) => {
                        e.insert(MsgSlot::One(msg));
                    }
                }
            }
            sent
        };

        // Process message receivers first, then still-active vertices that
        // got no messages.
        for (&v, msgs) in inbox_now.iter() {
            let st = vstate.entry(v).or_insert_with(|| VState {
                vq: app.init_value(query, v),
                halted: false,
                computed_step: 0,
            });
            st.halted = false;
            st.computed_step = step;
            msg_handled += msgs.len() as u64;
            compute_calls += 1;
            sent_total += run_one(v, st, msgs.as_slice(), &mut next_active);
        }
        // Active vertices without messages.
        let prev_active = std::mem::take(active);
        for v in prev_active {
            let st = vstate.get_mut(&v).expect("active implies state");
            if st.halted || st.computed_step == step {
                continue;
            }
            st.computed_step = step;
            compute_calls += 1;
            sent_total += run_one(v, st, &[], &mut next_active);
        }
        drop(run_one);
        // Recycle the inbox map's capacity for the next round (the exchange
        // phase refills it).
        let mut inbox_now = inbox_now;
        inbox_now.clear();
        *inbox = inbox_now;
        *active = next_active;

        lane.cost += compute_calls as f64 * cluster.cost.per_vertex_compute_s
            + msg_handled as f64 * cluster.cost.per_msg_overhead_s;
        lane.compute_calls += compute_calls;
        lane.sent += sent_total;
    }
}

/// Execute every task of one exchange lane: drain each source shard's
/// staging buffer addressed to this destination into the destination inbox,
/// in source-worker order, replaying the sender-side combiner per message.
/// Runs on a pool worker; touches only owned task data plus the read-shared
/// app.
fn run_exchange<A: QueryApp>(app: &A, lane: &mut ExchangeLane<A>) {
    for task in lane.tasks.iter_mut() {
        let ExchangeTask {
            inbound,
            inbox,
            delivered,
        } = task;
        for srcmap in inbound.iter_mut() {
            if srcmap.is_empty() {
                continue; // skip the W²-mostly-empty buckets cheaply
            }
            for (dst, slot) in srcmap.drain() {
                match inbox.entry(dst) {
                    Entry::Occupied(mut e) => {
                        let into = e.get_mut();
                        match slot {
                            MsgSlot::One(m) => *delivered += merge_msg(app, into, m),
                            MsgSlot::Many(ms) => {
                                for m in ms {
                                    *delivered += merge_msg(app, into, m);
                                }
                            }
                        }
                    }
                    Entry::Vacant(e) => {
                        *delivered += slot.len() as u64;
                        e.insert(slot); // moves, no allocation
                    }
                }
            }
        }
    }
}

/// Phase-job granularity handed to the worker pool.
///
/// Both schedulers run on the same stealing deques; they differ only in
/// how a phase's items are cut into jobs, which is exactly what decides
/// whether skew can be absorbed. Outputs are bit-identical either way —
/// the scheduler picks executors, never merge or delivery orders.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Sched {
    /// One contiguous `div_ceil(items, threads)` mega-chunk per pool
    /// thread (the pre-stealing scheduler, kept as the benchmark
    /// baseline): a skewed item serializes its whole chunk behind it.
    Static,
    /// One job per item — per worker lane (compute), per destination
    /// worker (exchange), per query (fold). Idle pool threads steal queued
    /// jobs from the back of busy threads' deques, so a heavy lane never
    /// pins the phase on one thread. The default.
    Stealing,
}

/// Dispatch one parallel phase over the pool at the `sched` granularity,
/// or inline when no pool exists (`threads = 1`). All three phases
/// (compute / exchange / fold) route through here, so job-granularity
/// policy lives in exactly one place. Returns the pool's scheduling
/// counters for the engine's per-phase metrics.
fn run_phase<T: Send>(
    pool: Option<&WorkerPool>,
    nthreads: usize,
    sched: Sched,
    items: &mut [T],
    f: impl Fn(&mut T) + Sync,
) -> RunStats {
    if items.is_empty() {
        return RunStats::default();
    }
    let Some(pool) = pool else {
        for item in items.iter_mut() {
            f(item);
        }
        return RunStats {
            jobs: items.len() as u64,
            steals: 0,
        };
    };
    let f = &f;
    let jobs: Vec<Job<'_>> = match sched {
        Sched::Static => {
            let chunk = items.len().div_ceil(nthreads);
            items
                .chunks_mut(chunk)
                .map(|chunk_items| {
                    Box::new(move || {
                        for item in chunk_items.iter_mut() {
                            f(item);
                        }
                    }) as Job<'_>
                })
                .collect()
        }
        Sched::Stealing => items
            .iter_mut()
            .map(|item| Box::new(move || f(item)) as Job<'_>)
            .collect(),
    };
    pool.run(jobs)
}

/// The fold-phase unit for one query: merge per-worker aggregator partials
/// in worker order, OR the per-shard terminate flags, run the master hook,
/// and drive the lifecycle transition. Pure per-query state, so queries
/// fold concurrently on the pool without changing any result.
fn fold_query<A: QueryApp>(app: &A, rt: &mut QueryRt<A>, max_supersteps: u64) {
    if rt.phase != Phase::Running {
        return;
    }
    let mut merged = A::Agg::default();
    for shard in rt.shards.iter_mut() {
        let part = std::mem::take(&mut shard.agg_round);
        app.agg_merge(&mut merged, &part);
        if shard.terminated {
            rt.terminated = true;
            shard.terminated = false;
        }
    }
    let action = app.master_step(&rt.query, rt.step, &rt.agg_prev, &mut merged);
    rt.agg_prev = merged;
    if action == MasterAction::Terminate {
        rt.terminated = true;
    }
    if rt.step >= max_supersteps {
        rt.terminated = true;
        rt.stats.truncated = true;
    }
    if rt.terminated || rt.quiescent() {
        rt.phase = Phase::Reporting;
    }
    rt.stats.supersteps = rt.step;
}

impl<A: QueryApp> Engine<A> {
    /// Engine over `app` (which owns the graph / V-data) on `cluster`.
    /// `n_vertices` is |V|, used for access-rate accounting.
    pub fn new(app: A, cluster: Cluster, n_vertices: usize) -> Self {
        Self {
            app,
            cluster,
            capacity: 8, // paper: throughput saturates around C = 8
            threads: std::thread::available_parallelism()
                .map(|n| n.get())
                .unwrap_or(1),
            sched: Sched::Stealing,
            pool: None,
            n_vertices,
            queue: VecDeque::new(),
            inflight: Vec::new(),
            results: Vec::new(),
            next_qid: 0,
            clock: 0.0,
            max_supersteps: DEFAULT_MAX_SUPERSTEPS,
            metrics: EngineMetrics::default(),
            outbox_scratch: Vec::new(),
            exchange_scratch: Vec::new(),
        }
    }

    /// Set the capacity parameter `C` (max queries per super-round).
    pub fn capacity(mut self, c: usize) -> Self {
        assert!(c > 0);
        self.capacity = c;
        self
    }

    /// Set the number of OS threads for the parallel phases (compute,
    /// exchange, fold). Defaults to `std::thread::available_parallelism()`;
    /// `1` forces the fully serial loop, and values above the worker count
    /// are clamped. Results are bit-identical for every setting.
    pub fn threads(mut self, t: usize) -> Self {
        assert!(t > 0);
        self.threads = t;
        // Re-created at the right size by the next super-round that needs
        // it; dropping here joins any previously spawned workers.
        self.pool = None;
        self
    }

    /// Select the phase-job scheduler. [`Sched::Stealing`] (the default)
    /// splits every phase into per-item jobs balanced by work stealing;
    /// [`Sched::Static`] keeps the contiguous one-chunk-per-thread split.
    /// Results are bit-identical for either setting.
    pub fn scheduler(mut self, s: Sched) -> Self {
        self.sched = s;
        self
    }

    /// Override the superstep safety cap.
    pub fn max_supersteps(mut self, n: u64) -> Self {
        self.max_supersteps = n;
        self
    }

    /// Borrow the app (e.g. to read indexes it built).
    pub fn app(&self) -> &A {
        &self.app
    }

    /// Mutably borrow the app (e.g. to install index data between jobs).
    pub fn app_mut(&mut self) -> &mut A {
        &mut self.app
    }

    /// Current simulated cluster time (seconds).
    pub fn sim_time(&self) -> f64 {
        self.clock
    }

    /// Advance the simulated clock (e.g. to account for graph loading).
    pub fn advance_clock(&mut self, dt: f64) {
        self.clock += dt;
    }

    /// Engine-wide counters.
    pub fn metrics(&self) -> &EngineMetrics {
        &self.metrics
    }

    /// Completed queries so far (submission order not guaranteed; sort by
    /// qid if needed).
    pub fn results(&self) -> &[QueryResult<A::Out>] {
        &self.results
    }

    /// Drain completed query results.
    pub fn take_results(&mut self) -> Vec<QueryResult<A::Out>> {
        std::mem::take(&mut self.results)
    }

    /// Submit a query; returns its id. Processing starts at the next
    /// super-round with free capacity.
    pub fn submit(&mut self, q: A::Query) -> QueryId {
        let id = self.next_qid;
        self.next_qid += 1;
        self.queue.push_back((id, q, self.clock));
        id
    }

    /// Run super-rounds until the queue and all in-flight queries drain.
    pub fn run_until_idle(&mut self) {
        while self.super_round() {}
    }

    /// Convenience: submit one query and run it to completion, returning
    /// its result (interactive-mode helper). The result is removed from the
    /// completed-result buffer, so sessions that only ever call `run_one`
    /// never accumulate results; completion is still accounted in
    /// [`EngineMetrics::queries_completed`] whether or not `take_results`
    /// is ever called, so engine-level stats stay consistent either way.
    pub fn run_one(&mut self, q: A::Query) -> QueryResult<A::Out> {
        let id = self.submit(q);
        self.run_until_idle();
        let idx = self
            .results
            .iter()
            .position(|r| r.qid == id)
            .expect("query must have completed");
        self.results.swap_remove(idx)
    }

    /// Execute one super-round. Returns false if there was nothing to do.
    pub fn super_round(&mut self) -> bool {
        if self.inflight.is_empty() && self.queue.is_empty() {
            return false;
        }
        let wall_start = Instant::now();
        let workers = self.cluster.workers;
        let nthreads = self.threads.min(workers).max(1);
        if nthreads > 1 && self.pool.is_none() {
            self.pool = Some(WorkerPool::new(nthreads));
        }

        // --- Admission: fetch queries while capacity permits (paper §3.1).
        while self.inflight.len() < self.capacity {
            let Some((id, q, submitted_at)) = self.queue.pop_front() else {
                break;
            };
            let mut rt = QueryRt::<A>::new(id, q, workers, submitted_at);
            rt.stats.started_at = self.clock;
            // init_activate: seed the initial activation set V_q^I.
            let init = self.app.init_activate(&rt.query);
            for v in init {
                let w = self.cluster.worker_of(v);
                let shard = &mut rt.shards[w];
                shard.vstate.entry(v).or_insert_with(|| VState {
                    vq: self.app.init_value(&rt.query, v),
                    halted: false,
                    computed_step: 0,
                });
                shard.active.push(v);
            }
            self.inflight.push(rt);
        }
        self.metrics.peak_inflight = self.metrics.peak_inflight.max(self.inflight.len());
        if self.inflight.is_empty() {
            return false;
        }

        let msg_size = self.app.msg_bytes() + self.cluster.cost.msg_header_bytes;
        let app = &self.app;
        let cluster = &self.cluster;
        let pool = self.pool.as_ref();
        let sched = self.sched;

        // --- Compute phase: transpose the running queries into worker
        // lanes (shard w of every query + worker w's scratch) and run the
        // lanes on the pool. Each worker still processes its share of every
        // in-flight query serially (paper model); only distinct workers run
        // concurrently.
        if self.outbox_scratch.len() < workers {
            self.outbox_scratch.resize_with(workers, Vec::new);
        }
        let mut lanes: Vec<Lane<'_, A>> = self
            .outbox_scratch
            .iter_mut()
            .take(workers)
            .map(|scratch| Lane {
                tasks: Vec::new(),
                scratch,
                cost: 0.0,
                compute_calls: 0,
                sent: 0,
            })
            .collect();
        for rt in self.inflight.iter_mut() {
            if rt.phase != Phase::Running {
                continue;
            }
            let qid = rt.id;
            let step = rt.step + 1;
            let QueryRt { query, agg_prev, shards, .. } = rt;
            // Shared refs (Copy) so every lane's task can carry them.
            let query: &A::Query = query;
            let agg_prev: &A::Agg = agg_prev;
            for (lane, shard) in lanes.iter_mut().zip(shards.iter_mut()) {
                lane.tasks.push(Task { qid, step, query, agg_prev, shard });
            }
        }

        let compute_start = Instant::now();
        let compute_stats = run_phase(pool, nthreads, sched, &mut lanes, |lane| {
            run_lane(app, cluster, lane)
        });
        self.metrics.compute_time += compute_start.elapsed().as_secs_f64();
        self.metrics.compute_sched.add(compute_stats.jobs, compute_stats.steals);

        let mut worker_cost = Vec::with_capacity(workers);
        let mut lane_load = Vec::with_capacity(workers);
        let mut round_msgs: u64 = 0;
        let mut total_compute_calls: u64 = 0;
        for lane in &lanes {
            worker_cost.push(lane.cost);
            // Imbalance basis: receive-side cost PLUS send-side staging
            // overhead. `cost` (what the simulated clock uses, unchanged)
            // counts compute calls and *handled* messages only, which for
            // combiner apps hides exactly the skew that hurts wall time —
            // a hub lane's big out-fanout is staging work on the sender.
            lane_load.push(lane.cost + lane.sent as f64 * cluster.cost.per_msg_overhead_s);
            round_msgs += lane.sent;
            total_compute_calls += lane.compute_calls;
        }
        drop(lanes);
        self.metrics.total_compute_calls += total_compute_calls;
        // Lane-imbalance ratio of this round's compute phase (max lane
        // load over mean lane load, from the deterministic cost model):
        // the skew the stealing scheduler exists to absorb. ~1.0 means a
        // balanced partition; W means one lane carried everything.
        let max_load = lane_load.iter().copied().fold(0.0_f64, f64::max);
        let total_load: f64 = lane_load.iter().sum();
        if total_load > 0.0 {
            let ratio = max_load * lane_load.len() as f64 / total_load;
            if ratio > self.metrics.max_lane_imbalance {
                self.metrics.max_lane_imbalance = ratio;
            }
        }

        // --- Exchange phase: destination-sharded message routing. The
        // staging buffers are keyed by destination worker already, so each
        // destination drains its column of the W×W staging matrix
        // independently. The maps are *taken* from the shards (cheap
        // pointer-sized moves) so exchange lanes own their data outright,
        // and are handed back below to recycle their capacity.
        let exchange_start = Instant::now();
        if self.exchange_scratch.len() < workers {
            self.exchange_scratch
                .resize_with(workers, || ExchangeLane { tasks: Vec::new() });
        }
        let ex_lanes = &mut self.exchange_scratch[..workers];
        let mut qi = 0usize;
        for rt in self.inflight.iter_mut() {
            if rt.phase != Phase::Running {
                continue;
            }
            for (dw, lane) in ex_lanes.iter_mut().enumerate() {
                // Reuse last round's task slot where possible: its inbound
                // vector was drained (capacity kept) and its inbox is an
                // unallocated leftover default.
                if lane.tasks.len() == qi {
                    lane.tasks.push(ExchangeTask {
                        inbound: Vec::with_capacity(workers),
                        inbox: FxHashMap::default(),
                        delivered: 0,
                    });
                }
                let task = &mut lane.tasks[qi];
                task.inbox = std::mem::take(&mut rt.shards[dw].inbox);
                task.delivered = 0;
            }
            // Column extraction in source-worker order, so each destination
            // replays arrivals exactly as the serial barrier did.
            for shard in rt.shards.iter_mut() {
                for (stg, lane) in shard.staged.iter_mut().zip(ex_lanes.iter_mut()) {
                    lane.tasks[qi].inbound.push(std::mem::take(stg));
                }
            }
            qi += 1;
        }
        let nq = qi;
        for lane in ex_lanes.iter_mut() {
            // Drop stale slots from rounds that ran more queries.
            lane.tasks.truncate(nq);
        }
        let exchange_stats = run_phase(pool, nthreads, sched, &mut *ex_lanes, |lane| {
            run_exchange(app, lane)
        });
        self.metrics.exchange_sched.add(exchange_stats.jobs, exchange_stats.steals);
        // Post-pass: hand filled inboxes and drained staging maps back to
        // their shards (recycling capacity) and fold delivered counts into
        // per-query stats.
        let mut round_bytes: u64 = 0;
        let mut qi = 0usize;
        for rt in self.inflight.iter_mut() {
            if rt.phase != Phase::Running {
                continue;
            }
            rt.step += 1;
            let mut q_msgs: u64 = 0;
            for (dw, lane) in ex_lanes.iter_mut().enumerate() {
                let task = &mut lane.tasks[qi];
                q_msgs += task.delivered;
                rt.shards[dw].inbox = std::mem::take(&mut task.inbox);
                for (src, map) in task.inbound.drain(..).enumerate() {
                    rt.shards[src].staged[dw] = map;
                }
            }
            qi += 1;
            rt.stats.messages += q_msgs;
            let q_bytes = q_msgs * msg_size as u64;
            rt.stats.bytes += q_bytes;
            round_bytes += q_bytes;
        }
        self.metrics.exchange_time += exchange_start.elapsed().as_secs_f64();

        // --- Fold phase: per-query aggregator fold, master hook and
        // lifecycle, parallel across queries (the fold inside each query
        // stays in worker order, so results are unchanged).
        let barrier_start = Instant::now();
        let max_supersteps = self.max_supersteps;
        let fold_stats = run_phase(pool, nthreads, sched, &mut self.inflight, |rt| {
            fold_query(app, rt, max_supersteps)
        });
        self.metrics.fold_sched.add(fold_stats.jobs, fold_stats.steals);

        // Aggregator sync bytes: one Agg per worker per running query.
        round_bytes +=
            (self.inflight.len() * workers * std::mem::size_of::<A::Agg>()) as u64;

        // --- Advance the simulated clock.
        let dt = self.cluster.super_round_time(&worker_cost, round_bytes as usize);
        self.clock += dt;
        self.metrics.super_rounds += 1;
        self.metrics.total_messages += round_msgs;
        self.metrics.total_bytes += round_bytes;
        self.metrics.sim_time = self.clock;

        // --- Reporting super-round (n_q + 1): assemble results and free
        // all VQ-data / Q-data of finished queries. Completion is counted
        // in the engine metrics here, so per-query accounting never depends
        // on the caller draining `take_results`.
        let n_vertices = self.n_vertices;
        let clock = self.clock;
        let results = &mut self.results;
        let metrics = &mut self.metrics;
        self.inflight.retain_mut(|rt| {
            if rt.phase != Phase::Reporting {
                return true;
            }
            let touched = rt.touched();
            rt.stats.touched = touched;
            rt.stats.access_rate = touched as f64 / n_vertices.max(1) as f64;
            rt.stats.finished_at = clock;
            metrics.queries_completed += 1;
            let mut iter = rt
                .shards
                .iter()
                .flat_map(|s| s.vstate.iter().map(|(&v, st)| (v, &st.vq)));
            let out = app.finish(&rt.query, &mut iter, &rt.agg_prev);
            results.push(QueryResult {
                qid: rt.id,
                out,
                stats: rt.stats.clone(),
            });
            false // drop: frees HT_Q entry + all LUT_v entries of q
        });
        self.metrics.barrier_time += barrier_start.elapsed().as_secs_f64();

        self.metrics.wall_time += wall_start.elapsed().as_secs_f64();
        true
    }
}
