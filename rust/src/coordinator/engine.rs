//! The superstep-sharing engine loop.

use std::collections::VecDeque;
use std::time::Instant;

use super::query::{MsgSlot, Phase, QueryResult, QueryRt, VState};
use crate::metrics::EngineMetrics;
use crate::network::Cluster;
use crate::vertex::{Ctx, MasterAction, QueryApp, QueryId};

/// Safety cap: a query that exceeds this many supersteps is cut off and
/// flagged `truncated` in its stats (guards against non-converging UDFs).
const DEFAULT_MAX_SUPERSTEPS: u64 = 100_000;

/// The Quegel engine: owns the app (V-data lives inside it), the simulated
/// cluster, the query queue and all in-flight query state.
pub struct Engine<A: QueryApp> {
    app: A,
    cluster: Cluster,
    capacity: usize,
    n_vertices: usize,
    queue: VecDeque<(QueryId, A::Query, f64)>,
    inflight: Vec<QueryRt<A>>,
    results: Vec<QueryResult<A::Out>>,
    next_qid: QueryId,
    clock: f64,
    max_supersteps: u64,
    metrics: EngineMetrics,
    // Scratch buffers reused across super-rounds (perf: no allocation in
    // the hot loop).
    outbox_scratch: Vec<(u32, A::Msg)>,
}

impl<A: QueryApp> Engine<A> {
    /// Engine over `app` (which owns the graph / V-data) on `cluster`.
    /// `n_vertices` is |V|, used for access-rate accounting.
    pub fn new(app: A, cluster: Cluster, n_vertices: usize) -> Self {
        Self {
            app,
            cluster,
            capacity: 8, // paper: throughput saturates around C = 8
            n_vertices,
            queue: VecDeque::new(),
            inflight: Vec::new(),
            results: Vec::new(),
            next_qid: 0,
            clock: 0.0,
            max_supersteps: DEFAULT_MAX_SUPERSTEPS,
            metrics: EngineMetrics::default(),
            outbox_scratch: Vec::new(),
        }
    }

    /// Set the capacity parameter `C` (max queries per super-round).
    pub fn capacity(mut self, c: usize) -> Self {
        assert!(c > 0);
        self.capacity = c;
        self
    }

    /// Override the superstep safety cap.
    pub fn max_supersteps(mut self, n: u64) -> Self {
        self.max_supersteps = n;
        self
    }

    /// Borrow the app (e.g. to read indexes it built).
    pub fn app(&self) -> &A {
        &self.app
    }

    /// Mutably borrow the app (e.g. to install index data between jobs).
    pub fn app_mut(&mut self) -> &mut A {
        &mut self.app
    }

    /// Current simulated cluster time (seconds).
    pub fn sim_time(&self) -> f64 {
        self.clock
    }

    /// Advance the simulated clock (e.g. to account for graph loading).
    pub fn advance_clock(&mut self, dt: f64) {
        self.clock += dt;
    }

    /// Engine-wide counters.
    pub fn metrics(&self) -> &EngineMetrics {
        &self.metrics
    }

    /// Completed queries so far (submission order not guaranteed; sort by
    /// qid if needed).
    pub fn results(&self) -> &[QueryResult<A::Out>] {
        &self.results
    }

    /// Drain completed query results.
    pub fn take_results(&mut self) -> Vec<QueryResult<A::Out>> {
        std::mem::take(&mut self.results)
    }

    /// Submit a query; returns its id. Processing starts at the next
    /// super-round with free capacity.
    pub fn submit(&mut self, q: A::Query) -> QueryId {
        let id = self.next_qid;
        self.next_qid += 1;
        self.queue.push_back((id, q, self.clock));
        id
    }

    /// Run super-rounds until the queue and all in-flight queries drain.
    pub fn run_until_idle(&mut self) {
        while self.super_round() {}
    }

    /// Convenience: submit one query and run it to completion, returning
    /// its result (interactive-mode helper).
    pub fn run_one(&mut self, q: A::Query) -> QueryResult<A::Out> {
        let id = self.submit(q);
        self.run_until_idle();
        let idx = self
            .results
            .iter()
            .position(|r| r.qid == id)
            .expect("query must have completed");
        self.results.swap_remove(idx)
    }

    /// Execute one super-round. Returns false if there was nothing to do.
    pub fn super_round(&mut self) -> bool {
        if self.inflight.is_empty() && self.queue.is_empty() {
            return false;
        }
        let wall_start = Instant::now();
        let workers = self.cluster.workers;

        // --- Admission: fetch queries while capacity permits (paper §3.1).
        while self.inflight.len() < self.capacity {
            let Some((id, q, submitted_at)) = self.queue.pop_front() else {
                break;
            };
            let mut rt = QueryRt::<A>::new(id, q, workers, submitted_at);
            rt.stats.started_at = self.clock;
            // init_activate: seed the initial activation set V_q^I.
            let init = self.app.init_activate(&rt.query);
            for v in init {
                let w = self.cluster.worker_of(v);
                rt.vstate[w].entry(v).or_insert_with(|| VState {
                    vq: self.app.init_value(&rt.query, v),
                    halted: false,
                    computed_step: 0,
                });
                rt.active[w].push(v);
            }
            self.inflight.push(rt);
        }
        self.metrics.peak_inflight = self.metrics.peak_inflight.max(self.inflight.len());
        if self.inflight.is_empty() {
            return false;
        }

        // --- Compute phase: per worker, serially over queries (paper: each
        // worker processes its share of every in-flight query serially; we
        // simulate workers and take max over per-worker costs).
        let mut worker_cost = vec![0.0f64; workers];
        let mut round_msgs: u64 = 0;
        let mut round_bytes: u64 = 0;
        let msg_size = self.app.msg_bytes() + self.cluster.cost.msg_header_bytes;

        // Split the engine into disjoint field borrows so the hot loop can
        // mutate vertex state IN PLACE (no per-call VQ clone, no second
        // hash lookup) while the context borrows the app and scratch.
        let app = &self.app;
        let cluster = &self.cluster;
        let outbox_scratch = &mut self.outbox_scratch;
        let mut total_compute_calls: u64 = 0;

        for w in 0..workers {
            for rt in self.inflight.iter_mut() {
                if rt.phase != Phase::Running {
                    continue;
                }
                let step = rt.step + 1;
                // Disjoint borrows of the query runtime's fields. Staged
                // buffers and the aggregator partial live in the QueryRt
                // and are reused across super-rounds (no allocation here).
                let QueryRt {
                    id,
                    query,
                    vstate,
                    active,
                    inbox,
                    staged,
                    agg_round,
                    agg_prev,
                    terminated,
                    ..
                } = rt;
                let mut compute_calls: u64 = 0;
                let mut msg_handled: u64 = 0;
                let inbox_w = std::mem::take(&mut inbox[w]);
                let mut next_active: Vec<u32> = Vec::new();

                // One closure runs a compute() call over in-place state and
                // routes the staged messages with the sender-side combiner.
                let mut run_one = |v: u32,
                                   st: &mut VState<A::VQ>,
                                   msgs: &[A::Msg],
                                   next_active: &mut Vec<u32>|
                 -> u64 {
                    let mut ctx = Ctx {
                        app,
                        qid: *id,
                        query,
                        step,
                        msgs,
                        prev_agg: agg_prev,
                        agg_partial: agg_round,
                        outbox: &mut *outbox_scratch,
                        halt: false,
                        terminate: false,
                        sent: 0,
                    };
                    app.compute(&mut ctx, v, &mut st.vq);
                    let (halt, terminate, sent) = (ctx.halt, ctx.terminate, ctx.sent);
                    st.halted = halt;
                    if !halt {
                        next_active.push(v);
                    }
                    if terminate {
                        *terminated = true;
                    }
                    for (dst, msg) in outbox_scratch.drain(..) {
                        let dw = cluster.worker_of(dst);
                        match staged[dw].entry(dst) {
                            std::collections::hash_map::Entry::Occupied(mut e) => {
                                let slot = e.get_mut();
                                if let Some(first) = slot.first_mut() {
                                    if app.combine(first, &msg) {
                                        continue;
                                    }
                                }
                                slot.push(msg);
                            }
                            std::collections::hash_map::Entry::Vacant(e) => {
                                e.insert(MsgSlot::One(msg));
                            }
                        }
                    }
                    sent
                };

                // Process message receivers first, then still-active
                // vertices that got no messages.
                for (&v, msgs) in inbox_w.iter() {
                    let st = vstate[w].entry(v).or_insert_with(|| VState {
                        vq: app.init_value(query, v),
                        halted: false,
                        computed_step: 0,
                    });
                    st.halted = false;
                    st.computed_step = step;
                    msg_handled += msgs.len() as u64;
                    compute_calls += 1;
                    round_msgs += run_one(v, st, msgs.as_slice(), &mut next_active);
                }
                // Active vertices without messages.
                let prev_active = std::mem::take(&mut active[w]);
                for v in prev_active {
                    let st = vstate[w].get_mut(&v).expect("active implies state");
                    if st.halted || st.computed_step == step {
                        continue;
                    }
                    st.computed_step = step;
                    compute_calls += 1;
                    round_msgs += run_one(v, st, &[], &mut next_active);
                }
                drop(run_one);
                // Recycle the inbox map's capacity for the next round (the
                // barrier below refills it).
                let mut inbox_w = inbox_w;
                inbox_w.clear();
                rt.inbox[w] = inbox_w;
                rt.active[w] = next_active;
                worker_cost[w] += compute_calls as f64 * cluster.cost.per_vertex_compute_s
                    + msg_handled as f64 * cluster.cost.per_msg_overhead_s;
                total_compute_calls += compute_calls;
            }
        }
        self.metrics.total_compute_calls += total_compute_calls;

        // --- Barrier: route staged messages, merge aggregators, lifecycle.
        for rt in self.inflight.iter_mut() {
            if rt.phase != Phase::Running {
                continue;
            }
            rt.step += 1;
            let mut q_msgs: u64 = 0;
            for (dw, buf) in rt.staged.iter_mut().enumerate() {
                for (dst, slot) in buf.drain() {
                    q_msgs += slot.len() as u64;
                    match rt.inbox[dw].entry(dst) {
                        std::collections::hash_map::Entry::Occupied(mut e) => {
                            e.get_mut().merge(slot);
                        }
                        std::collections::hash_map::Entry::Vacant(e) => {
                            e.insert(slot); // moves, no allocation
                        }
                    }
                }
            }
            rt.stats.messages += q_msgs;
            let q_bytes = q_msgs * msg_size as u64;
            rt.stats.bytes += q_bytes;
            round_bytes += q_bytes;

            // Merge aggregator and run the master hook.
            let mut merged = std::mem::take(&mut rt.agg_round);
            // (worker partials were already folded into one value because
            // Ctx::aggregate wrote into the shared per-query partial; the
            // app's agg_merge handles multi-source merging semantics.)
            let action = self
                .app
                .master_step(&rt.query, rt.step, &rt.agg_prev, &mut merged);
            rt.agg_prev = merged;
            if action == MasterAction::Terminate {
                rt.terminated = true;
            }
            if rt.step >= self.max_supersteps {
                rt.terminated = true;
                rt.stats.truncated = true;
            }
            if rt.terminated || rt.quiescent() {
                rt.phase = Phase::Reporting;
            }
            rt.stats.supersteps = rt.step;
        }

        // Aggregator sync bytes: one Agg per worker per running query.
        round_bytes +=
            (self.inflight.len() * workers * std::mem::size_of::<A::Agg>()) as u64;

        // --- Advance the simulated clock.
        let dt = self.cluster.super_round_time(&worker_cost, round_bytes as usize);
        self.clock += dt;
        self.metrics.super_rounds += 1;
        self.metrics.total_messages += round_msgs;
        self.metrics.total_bytes += round_bytes;
        self.metrics.sim_time = self.clock;

        // --- Reporting super-round (n_q + 1): assemble results and free
        // all VQ-data / Q-data of finished queries.
        let n_vertices = self.n_vertices;
        let app = &self.app;
        let clock = self.clock;
        let results = &mut self.results;
        self.inflight.retain_mut(|rt| {
            if rt.phase != Phase::Reporting {
                return true;
            }
            let touched = rt.touched();
            rt.stats.touched = touched;
            rt.stats.access_rate = touched as f64 / n_vertices.max(1) as f64;
            rt.stats.finished_at = clock;
            let mut iter = rt
                .vstate
                .iter()
                .flat_map(|m| m.iter().map(|(&v, st)| (v, &st.vq)));
            let out = app.finish(&rt.query, &mut iter, &rt.agg_prev);
            results.push(QueryResult {
                qid: rt.id,
                out,
                stats: rt.stats.clone(),
            });
            false // drop: frees HT_Q entry + all LUT_v entries of q
        });

        self.metrics.wall_time += wall_start.elapsed().as_secs_f64();
        true
    }
}
