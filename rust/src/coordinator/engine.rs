//! The superstep-sharing engine loop: three parallel phases per super-round
//! on a persistent worker pool.
//!
//! Execution model: every BSP worker is a [`WorkerShard`] per in-flight
//! query, and each super-round runs three phases, all executed by the same
//! long-lived [`WorkerPool`] (created once per engine and woken per phase —
//! no per-round thread spawn/join):
//!
//! 1. **Compute** — shard `w` of every running query is grouped into worker
//!    *lane* `w`; lanes run concurrently, each owning disjoint state.
//! 2. **Exchange** — the barrier's message routing, destination-sharded:
//!    staging buffers are already keyed by destination worker, so
//!    destination `dw` drains `shards[src].staged[dw]` from every `src` in
//!    source-worker order, concurrently with every other destination. The
//!    source-order replay, together with the sender-side combiner replay in
//!    `merge_msg`, reproduces message for message what one shared staging
//!    buffer would have held — delivery is bit-identical to the old serial
//!    barrier, without its O(W²) serial loop.
//! 3. **Fold** — per-query aggregator folding (worker order, unchanged),
//!    the master hook and lifecycle transitions run concurrently across
//!    queries; only the reporting round and the simulated-clock advance
//!    stay on the coordinator.
//!
//! Each phase is dispatched through [`run_phase`] at the granularity the
//! [`Sched`] knob selects. The default, [`Sched::Stealing`], hands the
//! pool one job per item — per worker lane (compute), per destination
//! worker (exchange), per query (fold) — and lets idle pool threads steal
//! queued jobs from busy ones, so a hub-heavy lane or one expensive query
//! never pins a phase on a single thread. [`Sched::Static`] keeps the old
//! one-contiguous-chunk-per-thread split as the benchmark baseline.
//!
//! Lanes themselves are no longer atomic: under the [`Split`] knob the
//! compute phase cuts a pathological (query, worker) task — one whose
//! active/receiving vertex count crosses the split threshold — into
//! contiguous **sub-ranges** of its serial work order, each a pool job of
//! its own with private staging buffers, actives and aggregator partial
//! ([`SubBuf`]). And under the [`EdgeSplit`] knob not even one *vertex*
//! is atomic: a `compute()` call that stages a mega-fanout has its outbox
//! parked and cut into contiguous **edge ranges**, each staged by its own
//! pool job into a private insertion-ordered buffer — the second,
//! (vertex, edge-range) task granularity below the vertex-range sub-job.
//! A merge dispatch folds everything back through the same `merge_msg`
//! rule the exchange phase uses — sub-buffers and edge ranges in fixed
//! serial-stream order, concurrently across destination workers (distinct
//! destinations own distinct staging maps) — so the per-destination
//! message sequences, the active order and the aggregator fold are
//! exactly what the unsplit serial loop produces. This parallelizes
//! *inside* the heaviest shard and *inside* its heaviest vertex — the
//! last compute-phase serialization points the lane-granular scheduler
//! could not touch.
//!
//! All three phases are deterministic in the thread count, the scheduler
//! *and* both splits: stealing only changes which thread executes a job,
//! never the source-worker delivery order inside a destination's exchange
//! job nor the worker-order `agg_merge` fold inside a query's fold job;
//! splitting (either granularity) only re-groups the serial work order
//! into ranges whose effects are replayed in that same order. So
//! `threads = N` produces bit-identical `QueryResult`s to `threads = 1`
//! (pinned by `rust/tests/determinism.rs` and the randomized fuzzer in
//! `rust/tests/fuzz_determinism.rs` across threads × workers × capacity ×
//! scheduler × split × edge-split × pipeline).
//!
//! The barrier between the phases is itself optional now: under the
//! [`Pipeline`] knob a super-round can run **ready-driven** instead of
//! barrier-to-barrier. A pipelined round is ONE pool batch holding a step
//! job per (query, worker) compute task plus the previous round's deferred
//! reporting jobs; the last lane of a query to finish its compute
//! immediately ships the query's staged columns into the destination
//! inboxes (destinations in worker order, sources in worker order within
//! each — the exact delivery sequence of the barrier exchange) and runs
//! the query's fold, while slower queries' lanes are still computing. A
//! query that converged has its reporting superstep deferred one round and
//! executed as a job of the NEXT round's batch, overlapped with that
//! round's compute. Because only *when* work runs changes — never the
//! staging insertion history, the source-order delivery, or the
//! worker-order fold — `QueryResult::out` is bit-identical across
//! `Pipeline::{Off, On}`.
//!
//! Overlap breaks wall-segment phase stopwatches (a span with compute and
//! exchange both active would be counted twice), so the phase timers in
//! [`EngineMetrics`] are **busy** counters: summed from inside pool jobs,
//! plus the coordinator's serial segments, with
//! [`EngineMetrics::overlap_time`] reporting the wall seconds in which two
//! or more phases were simultaneously active (always 0 under
//! `Pipeline::Off`).
//!
//! Orthogonal to all of the above is the **memory layout** of per-query
//! state, selected by the [`Layout`] knob. [`Layout::Hashed`] keeps the
//! original `FxHashMap` vertex-state/inbox/staging stores;
//! [`Layout::Flat`] (the default) replaces them with slab arenas and
//! columnar buffers — a dense `VertexId → u32` handle table over
//! contiguous `Vec` slots for vertex state and message slots, and
//! first-touch-ordered flat vectors for the per-destination staging
//! columns — so the innermost loops walk contiguous memory instead of
//! probing hash tables. Every order the determinism contract pins
//! (first-touch staging insertion, source-order delivery, worker-order
//! folds, reporting-round iteration) is recorded explicitly in the flat
//! structures, so `QueryResult::out` is bit-identical across
//! `Layout::{Hashed, Flat}` — the layout axis joins threads × workers ×
//! capacity × scheduler × split × edge-split × pipeline in the
//! determinism suite and the fuzzer.
//!
//! Finally, the graph itself may move underneath the serving front end:
//! [`Engine::try_mutate`] queues [`MutationBatch`]es on the simulated
//! clock next to `try_submit`, and every queued batch is applied at the
//! NEXT super-round boundary — on the coordinator, before admission,
//! never mid-superstep — bumping the engine's **epoch** by one per batch.
//! Each admitted query pins the epoch current at its admission round
//! (stamped into the query content by [`QueryApp::pin_epoch`] and into
//! `QueryStats::epoch`) and reads that one consistent version for its
//! whole lifetime through the app's `VersionedGraph` delta overlays;
//! after each round the engine recomputes the oldest still-pinned epoch
//! and lets the app retire (compact) everything older. This extends the
//! bit-identical contract with a **mutation axis**: `QueryResult::out`
//! is a pure function of (graph version pinned at admission, query) —
//! for any interleaving of `try_submit`/`try_mutate` calls, the
//! concurrent versioned run matches a serial engine replayed on the
//! materialized snapshot of the pinned epoch, regardless of threads ×
//! workers × scheduler × split × edge-split × pipeline × layout × admit
//! (pinned by the snapshot-replay oracle in `tests/determinism.rs` and
//! the mutation-schedule fuzz leg in `tests/fuzz_determinism.rs`).

use std::collections::VecDeque;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::Mutex;
use std::time::Instant;

use super::arena::{deliver_into_sink, ExchangeSink, Layout, StagedBuf, VStore};
use super::pool::{Job, RunStats, WorkerPool};
use super::query::{
    FanTask, OrderedStaging, Phase, QueryResult, QueryRt, StageStream, StageUnit, StagingCol,
    SubBuf, VState, WorkItem, WorkerShard,
};
use crate::graph::{Epoch, MutationBatch, VertexId};
use crate::metrics::EngineMetrics;
use crate::network::Cluster;
use crate::util::FxHashMap;
use crate::vertex::{Ctx, MasterAction, QueryApp, QueryId};

/// Safety cap: a query that exceeds this many supersteps is cut off and
/// flagged `truncated` in its stats (guards against non-converging UDFs).
const DEFAULT_MAX_SUPERSTEPS: u64 = 100_000;

/// Default capacity `C` (max in-flight queries): the paper's throughput
/// saturation point. Shared by `Engine::new` and the static-admission
/// test-matrix default so the two can never drift apart.
const DEFAULT_CAPACITY: usize = 8;

/// [`Split::Adaptive`]: sub-split only fires after a round whose compute
/// lane-imbalance ratio exceeded this (a balanced partition never pays the
/// split bookkeeping).
const SPLIT_IMBALANCE_TRIGGER: f64 = 1.5;

/// [`Split::Adaptive`]: tasks with fewer work items than this are never
/// worth cutting (sub-job dispatch would cost more than it parallelizes).
const SPLIT_MIN_ITEMS: usize = 256;

/// [`Split::Adaptive`]: floor on the sub-range size, so a pathological
/// task is never diced into per-vertex confetti.
const SPLIT_MIN_SUB: usize = 64;

/// [`EdgeSplit::Adaptive`]: a single `compute()` call must stage at least
/// this many messages before its outbox is parked for edge-range splitting
/// (below that, the park/dispatch/fold bookkeeping costs more than the
/// staging it parallelizes).
const EDGE_SPLIT_MIN_FAN: usize = 256;

/// [`EdgeSplit::Adaptive`]: floor on the edge-range size, so a mega-fanout
/// is never diced into per-edge confetti.
const EDGE_SPLIT_MIN_RANGE: usize = 64;

/// Retention cap on a lane's recycled ordered-staging pool, per
/// destination worker: enough to reseed every stream segment and a
/// generously-sized fan's range buffers next round, while bounding what a
/// long split-heavy session can accumulate (excess buffers are dropped).
const ORD_POOL_CAP_PER_WORKER: usize = 64;

/// Retention cap (entries) on a shard's flat staging columns between
/// super-rounds: the PR 5 recycling rule extended to [`Layout::Flat`]. A
/// round that staged a mega-fanout would otherwise leave every column
/// holding hub-sized capacity forever; after the exchange hands the
/// drained columns back, anything above this many slots is released.
/// The high-water mark before trimming is exported as
/// [`EngineMetrics::staging_bytes_peak`].
const FLAT_STAGED_RETAIN: usize = 1024;

/// Edge-level splitting policy: what to do when ONE vertex's `compute()`
/// stages a mega-fanout.
///
/// Sub-lane splitting ([`Split`]) cuts a heavy receiver batch into vertex
/// ranges, but a single hub vertex staging its entire fanout is still one
/// indivisible work item — the last compute-phase serialization point.
/// Under this knob, a compute call whose `ctx.send` count crosses the
/// threshold has its outbox *parked* instead of drained: the engine cuts
/// it into contiguous **edge ranges**, stages each range as its own pool
/// job into a private insertion-ordered buffer, and folds the ranges back
/// in fixed range order through the same `merge_msg` combiner replay the
/// sub-staging merge and the exchange use — concurrently across
/// destination workers, since distinct destinations own distinct staging
/// maps. The concatenated ranges are the exact `ctx.send` order, so the
/// staging map's insertion history — and with it exchange drain order and
/// `QueryResult::out` — is bit-identical to an inline drain for every
/// total or absent combiner. The decision reads only the outbox length
/// (deterministic app output), never thread scheduling.
///
/// Edge splitting engages only under [`Sched::Stealing`] with a pool
/// (`threads > 1`); the static baseline and serial engines never park.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EdgeSplit {
    /// Never park a fanout: every outbox drains inline (the PR 4
    /// behavior, kept as the benchmark baseline).
    Off,
    /// Park any compute call staging more than this many messages and cut
    /// it into ranges of at most this size.
    MaxFanout(usize),
    /// The default: park fanouts of at least [`EDGE_SPLIT_MIN_FAN`]
    /// messages and cut them into roughly `2 × threads` ranges (never
    /// smaller than [`EDGE_SPLIT_MIN_RANGE`]).
    Adaptive,
}

/// Per-round edge-split decision, derived from (`Sched`, `EdgeSplit`,
/// thread budget) once and copied into every lane.
#[derive(Debug, Clone, Copy)]
pub(crate) enum EdgePolicy {
    Never,
    /// Park outboxes longer than `.0`, cut at ranges of `.0`.
    Fixed(usize),
    /// Aim for `2 × threads` ranges per parked fan.
    Adaptive { threads: usize },
}

impl EdgePolicy {
    /// Edge-range size for a compute call that staged `fan` messages, or
    /// `None` to drain the outbox inline. Depends only on deterministic
    /// inputs (the app's send count, the engine configuration), never on
    /// thread scheduling — and either answer yields identical output.
    fn fan_range(self, fan: usize) -> Option<usize> {
        match self {
            EdgePolicy::Never => None,
            EdgePolicy::Fixed(n) => (fan > n).then_some(n.max(1)),
            EdgePolicy::Adaptive { threads } => (fan >= EDGE_SPLIT_MIN_FAN)
                .then(|| fan.div_ceil(2 * threads.max(1)).max(EDGE_SPLIT_MIN_RANGE)),
        }
    }
}

/// Intra-lane sub-job splitting policy for the compute phase.
///
/// Work stealing (PR 3) balances whole worker lanes, but one pathological
/// lane is still a single job — a hub-concentrated partition pins the
/// phase's wall time on whichever thread executes that lane. Splitting
/// cuts a heavy (query, worker) compute task's work-item list (message
/// receivers in delivery order, then still-active vertices) into
/// contiguous sub-ranges, runs each as its own pool job with private
/// staging buffers, and folds the results back **in fixed sub-range
/// order**, so the per-destination message sequences — and therefore the
/// exchange phase's source-order delivery and `QueryResult::out` — are
/// bit-identical to an unsplit run for every total or absent combiner
/// (the same contract the `workers` partitioning already imposes).
/// Splitting engages only under [`Sched::Stealing`]; the static baseline
/// stays split-free by definition.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Split {
    /// Never split: one compute job per worker lane (the PR 3 behavior,
    /// kept as the benchmark baseline).
    Off,
    /// Cut any task with more than this many work items into contiguous
    /// sub-ranges of at most this size.
    MaxTaskVertices(usize),
    /// The default: when skew is evident — after any round whose
    /// lane-imbalance ratio exceeded [`SPLIT_IMBALANCE_TRIGGER`], or
    /// whenever there are fewer worker lanes than threads (with a single
    /// lane the ratio is identically 1.0, yet splitting is the only way
    /// to use the other threads at all) — cut tasks of at least
    /// [`SPLIT_MIN_ITEMS`] items into roughly `2 × threads` sub-ranges
    /// (never smaller than [`SPLIT_MIN_SUB`]). All inputs (item counts,
    /// worker/thread counts and the cost-model imbalance) are
    /// deterministic, so the decision — and a fortiori the output —
    /// never depends on thread scheduling.
    Adaptive,
}

/// Per-round split decision, derived from (`Sched`, `Split`, last round's
/// imbalance) once and copied into every lane.
#[derive(Debug, Clone, Copy)]
enum SplitPolicy {
    Never,
    /// Cut tasks with more than `.0` items into ranges of `.0`.
    Fixed(usize),
    /// Imbalance-triggered: aim for `2 × threads` ranges per heavy task.
    Adaptive { threads: usize },
}

impl SplitPolicy {
    /// Sub-range size for a task with `items` work items, or `None` to run
    /// it serially inside the prep job. Depends only on deterministic
    /// inputs, never on thread scheduling.
    fn sub_size(self, items: usize) -> Option<usize> {
        match self {
            SplitPolicy::Never => None,
            SplitPolicy::Fixed(n) => (items > n).then_some(n.max(1)),
            SplitPolicy::Adaptive { threads } => (items >= SPLIT_MIN_ITEMS)
                .then(|| items.div_ceil(2 * threads.max(1)).max(SPLIT_MIN_SUB)),
        }
    }
}

/// The Quegel engine: owns the app (V-data lives inside it), the simulated
/// cluster, the query queue, all in-flight query state, and the persistent
/// worker pool that executes the parallel phases.
pub struct Engine<A: QueryApp> {
    app: A,
    cluster: Cluster,
    capacity: usize,
    /// OS threads for the parallel phases (1 = serial; capped at `workers`).
    threads: usize,
    /// Phase-job granularity: stealing (default) or the static baseline.
    sched: Sched,
    /// Intra-lane sub-job splitting policy (compute phase).
    split: Split,
    /// Edge-level splitting policy for mega-fanout compute calls.
    edge_split: EdgeSplit,
    /// Super-round execution mode: strict barriers or ready-driven
    /// pipelining (see [`Pipeline`]).
    pipeline: Pipeline,
    /// Per-query state layout: flat arenas/columns or the hashed baseline
    /// (see [`Layout`]). Fixed per engine; every shard and staging buffer
    /// of every query is built for this layout.
    layout: Layout,
    /// Admission policy: fixed FIFO budget or the per-round planner with
    /// a reserved heavy slice (see [`Admit`]).
    admit: Admit,
    /// Submission-queue bound for the serving front end: `try_submit`
    /// back-pressures once this many requests wait. `None` (default) =
    /// unbounded, the historical batch behavior.
    queue_bound: Option<usize>,
    /// Post-combiner messages routed in the most recent super-round: the
    /// deterministic saturation signal the adaptive admission planner
    /// squeezes its heavy slice on.
    last_round_messages: u64,
    /// Compute lane-imbalance ratio of the most recent super-round, the
    /// deterministic signal [`Split::Adaptive`] triggers on.
    last_compute_imbalance: f64,
    /// Largest single-compute-call fanout seen so far: deterministic
    /// evidence that edge splitting can engage, used (like the imbalance
    /// ratio) to decide when threads beyond the worker count are worth
    /// waking. Monotone — the pool only ever grows.
    seen_max_fan: u64,
    /// Long-lived pool, created lazily at the first super-round that needs
    /// it and joined when the engine drops (even mid-queue).
    pool: Option<WorkerPool>,
    n_vertices: usize,
    /// Current graph epoch: bumped once per applied [`MutationBatch`].
    /// Stays 0 forever for immutable-graph apps. Queries pin the value
    /// current at their admission round.
    epoch: Epoch,
    /// Mutation batches queued by [`Engine::try_mutate`], waiting for the
    /// next super-round boundary (FIFO; the `f64` is the simulated
    /// arrival stamp, mirroring `try_submit`).
    muts: Vec<(MutationBatch, f64)>,
    queue: VecDeque<Queued<A::Query>>,
    inflight: Vec<QueryRt<A>>,
    /// Queries whose reporting superstep a pipelined round deferred: their
    /// `finish` runs as jobs of the NEXT pipelined batch (overlapped with
    /// its compute) or serially in [`Engine::flush_pending_reports`].
    /// Always empty between rounds under `Pipeline::Off`.
    pending_reports: Vec<PendingReport<A>>,
    results: Vec<QueryResult<A::Out>>,
    next_qid: QueryId,
    clock: f64,
    max_supersteps: u64,
    metrics: EngineMetrics,
    // Per-worker scratch reused across super-rounds (perf: no allocation
    // in the hot loop; one per lane so threads never share): the outbox
    // plus the recycled sub-job buffers and work-item vectors of the
    // sub-lane split.
    lane_scratch: Vec<LaneScratch<A>>,
    // Exchange lanes reused across super-rounds: task structs and their
    // `inbound` vectors keep their capacity, so the steady-state exchange
    // allocates nothing (the maps themselves are loaned from the shards).
    exchange_scratch: Vec<ExchangeLane<A>>,
}

/// Recycled per-worker compute scratch: the serial outbox plus the
/// sub-lane split's reusable buffers, so steady-state splitting allocates
/// (almost) nothing.
struct LaneScratch<A: QueryApp> {
    /// Outbox for tasks run serially inside the prep job.
    outbox: Vec<(VertexId, A::Msg)>,
    /// Sub-job buffers, grown on demand and drained in place by the merge.
    subs: Vec<SubBuf<A>>,
    /// Recycled work-item vectors for split tasks.
    items_pool: Vec<Vec<WorkItem<A>>>,
    /// Recycled scratch for `split_items`' pointer-collection pass.
    ptr_index: FxHashMap<VertexId, usize>,
    /// Recycled insertion-ordered staging buffers: the staging-column
    /// replay drains buffers into here; fan-range allocation pops them
    /// back out, and each sub-buffer's stream re-seeds its private
    /// segment pool from here between rounds. Capped per round
    /// ([`ORD_POOL_CAP_PER_WORKER`]) so a long split-heavy session can't
    /// accumulate buffers without bound.
    ord_pool: Vec<OrderedStaging<A>>,
}

impl<A: QueryApp> LaneScratch<A> {
    fn new() -> Self {
        Self {
            outbox: Vec::new(),
            subs: Vec::new(),
            items_pool: Vec::new(),
            ptr_index: FxHashMap::default(),
            ord_pool: Vec::new(),
        }
    }
}

/// One worker's share of the compute phase: shard `w` of every running
/// query, plus this worker's scratch and counters. A lane is the unit of
/// the **prep** dispatch: tasks below the split threshold run to
/// completion right there (the PR 3 path); heavier tasks are transposed
/// into work-item lists and handed out as sub-jobs. Counters are integers
/// so lane totals are exactly associative — identical for every split
/// setting — and converted to simulated seconds once per round.
struct Lane<'a, A: QueryApp> {
    tasks: Vec<Task<'a, A>>,
    scratch: &'a mut LaneScratch<A>,
    /// This round's split decision (copied from the engine).
    policy: SplitPolicy,
    /// This round's edge-split decision (copied from the engine).
    edge: EdgePolicy,
    /// Tasks the prep pass decided to split, in task order.
    splits: Vec<SplitPrep<'a, A>>,
    /// Serial-path tasks that parked at least one mega-fanout, in task
    /// order: their post-first-fan staging lives in the attached stream.
    fans: Vec<FanPrep<A>>,
    /// Lane totals (serial tasks + merged sub-jobs).
    compute_calls: u64,
    msg_handled: u64,
    /// `ctx.send` calls (pre-combiner), for engine-wide traffic counters.
    sent: u64,
    /// Counters of the tasks run inline by the prep job only — the prep
    /// job's own load, one unit of the post-split imbalance metric.
    serial_calls: u64,
    serial_handled: u64,
    serial_sent: u64,
    /// Messages the serial path parked into fans (⊆ `serial_sent`); the
    /// post-split imbalance metric subtracts them, since edge-range jobs
    /// carry that staging.
    fanned: u64,
    /// Largest single `compute()` fanout (ctx.send count) this round,
    /// across the serial path and (after the merge fold) every sub-job.
    max_fan: u64,
    /// Per-sub-job loads in simulated seconds, filled by the merge (the
    /// other units of the post-split imbalance metric).
    sub_loads: Vec<f64>,
}

/// A serial-path task that parked at least one mega-fanout: once the
/// first fan parks, everything the task stages afterwards is captured in
/// `stream` (fans as their own units, ordinary messages in segments) so
/// the staging-column merge can replay it AFTER the fan — preserving the
/// shard staging map's serial insertion history, whose prefix the task
/// already wrote directly before the fan appeared.
struct FanPrep<A: QueryApp> {
    /// Index into `Lane::tasks` (for the merge to find the shard).
    task_idx: usize,
    stream: StageStream<A>,
}

/// One (query, worker) compute unit inside a lane. `pub(crate)` because
/// the multi-process worker loop ([`super::remote`]) drives the exact
/// same task body for the shards it hosts.
pub(crate) struct Task<'a, A: QueryApp> {
    pub(crate) qid: QueryId,
    /// Superstep this compute phase executes (1-based).
    pub(crate) step: u64,
    pub(crate) query: &'a A::Query,
    pub(crate) agg_prev: &'a A::Agg,
    pub(crate) shard: &'a mut WorkerShard<A>,
}

/// A task the prep pass transposed for splitting: its serial-order work
/// items plus everything a sub-job needs besides the shard itself.
struct SplitPrep<'a, A: QueryApp> {
    /// Index into `Lane::tasks` (for the merge to find the shard).
    task_idx: usize,
    qid: QueryId,
    step: u64,
    query: &'a A::Query,
    agg_prev: &'a A::Agg,
    items: Vec<WorkItem<A>>,
    /// Sub-range size this task is cut at.
    sub_size: usize,
}

/// One sub-range of one split task: the unit of the sub-job dispatch.
/// Owns a disjoint slice of the task's work items plus a private
/// [`SubBuf`]; nothing here is visible to any sibling sub-job.
struct SubJob<'a, A: QueryApp> {
    qid: QueryId,
    step: u64,
    query: &'a A::Query,
    agg_prev: &'a A::Agg,
    items: &'a mut [WorkItem<A>],
    buf: &'a mut SubBuf<A>,
}

/// One destination worker's share of the exchange phase: for every running
/// query, the staging buffers addressed to this worker plus the query's
/// destination-shard inbox. Tasks hold the maps *by value* (taken from the
/// shards for the duration of the phase and handed back afterwards), so a
/// lane is owned data — pool jobs need no shard borrows and every
/// destination drains concurrently with every other.
struct ExchangeLane<A: QueryApp> {
    /// One task per running query, in `inflight` order.
    tasks: Vec<ExchangeTask<A>>,
}

/// The exchange unit for one (destination worker, query) pair.
struct ExchangeTask<A: QueryApp> {
    /// `shards[src].staged[dw]` for each source worker, in worker order —
    /// the order the serial barrier replayed, so delivery is bit-identical.
    inbound: Vec<StagedBuf<A>>,
    /// The destination shard's delivery sink for the next superstep: the
    /// inbox map under [`Layout::Hashed`], the whole arena (delivery
    /// assigns handles) under [`Layout::Flat`].
    inbox: ExchangeSink<A>,
    /// Messages delivered (post-combiner); folded into stats afterwards.
    delivered: u64,
}

/// Per-(query, worker) context of one compute dispatch, shared by the
/// serial task loop and the split sub-jobs so the compute contract — Ctx
/// construction, halt/terminate handling, activation, outbox routing,
/// mega-fanout parking — lives in exactly one place and the paths can
/// never diverge.
struct ComputeCall<'a, A: QueryApp> {
    qid: QueryId,
    step: u64,
    query: &'a A::Query,
    agg_prev: &'a A::Agg,
    cluster: &'a Cluster,
    /// This round's edge-split decision (reads only the outbox length).
    edge: EdgePolicy,
}

/// Where a compute call's drained outbox lands. The serial paths stage
/// straight into the shard's staging maps until the first fan parks, then
/// switch to an overflow [`StageStream`] so everything after the fan can
/// be replayed after it; sub-jobs always stage into their private stream.
enum Router<'b, A: QueryApp> {
    Shard {
        staged: &'b mut Vec<StagedBuf<A>>,
        overflow: &'b mut Option<StageStream<A>>,
        fanned: &'b mut u64,
    },
    Stream {
        stream: &'b mut StageStream<A>,
        fanned: &'b mut u64,
    },
}

impl<A: QueryApp> Router<'_, A> {
    /// Stage one message at the current position of the serial staging
    /// order (direct buffer, overflow stream, or sub-stream).
    fn stage(&mut self, app: &A, cluster: &Cluster, dst: VertexId, msg: A::Msg) {
        let dw = cluster.worker_of(dst);
        match self {
            Router::Shard { staged, overflow, .. } => match overflow {
                Some(stream) => stream.stage(app, dw, dst, msg),
                None => staged[dw].stage(app, dst, msg),
            },
            Router::Stream { stream, .. } => stream.stage(app, dw, dst, msg),
        }
    }

    /// Park one mega-fanout at the current position (opening the overflow
    /// stream on the serial paths' first fan).
    fn park(&mut self, workers: usize, msgs: Vec<(VertexId, A::Msg)>, range: usize) {
        let (stream, fanned) = match self {
            Router::Shard { overflow, fanned, .. } => (
                overflow.get_or_insert_with(|| StageStream::new(workers)),
                fanned,
            ),
            Router::Stream { stream, fanned } => (&mut **stream, fanned),
        };
        **fanned += msgs.len() as u64;
        stream.park_fan(msgs, range);
    }
}

/// Everything one compute call may write: the aggregator partial, the
/// outbox scratch, the activation list and the terminate flag of the
/// executing unit — the shard itself for serial tasks, the private
/// [`SubBuf`] for sub-jobs.
struct ComputeSink<'a, A: QueryApp> {
    agg: &'a mut A::Agg,
    outbox: &'a mut Vec<(VertexId, A::Msg)>,
    next_active: &'a mut Vec<VertexId>,
    terminated: &'a mut bool,
}

impl<'a, A: QueryApp> ComputeCall<'a, A> {
    /// Run `compute()` for one vertex over in-place state, then route the
    /// staged outbox through the router — inline when the fanout is
    /// ordinary, parked as an edge-splittable [`super::query::FanTask`]
    /// when it crosses the edge-split threshold (the range-sliced send
    /// path; either way the eventual staging sequence is the `ctx.send`
    /// order). Returns `ctx.sent`.
    fn run(
        &self,
        app: &A,
        v: VertexId,
        st: &mut VState<A::VQ>,
        msgs: &[A::Msg],
        sink: &mut ComputeSink<'_, A>,
        router: &mut Router<'_, A>,
    ) -> u64 {
        let mut ctx = Ctx {
            app,
            qid: self.qid,
            query: self.query,
            step: self.step,
            msgs,
            prev_agg: self.agg_prev,
            agg_partial: &mut *sink.agg,
            outbox: &mut *sink.outbox,
            halt: false,
            terminate: false,
            sent: 0,
        };
        app.compute(&mut ctx, v, &mut st.vq);
        let (halt, terminate, sent) = (ctx.halt, ctx.terminate, ctx.sent);
        st.halted = halt;
        if !halt {
            sink.next_active.push(v);
        }
        if terminate {
            *sink.terminated = true;
        }
        if let Some(range) = self.edge.fan_range(sink.outbox.len()) {
            // Park the whole outbox (the scratch vec regrows; fans are by
            // definition rare and huge, so the trade is a few reallocs
            // against parallelizing the entire staging pass).
            let msgs = std::mem::take(sink.outbox);
            router.park(self.cluster.workers, msgs, range);
        } else {
            for (dst, msg) in sink.outbox.drain(..) {
                router.stage(app, self.cluster, dst, msg);
            }
        }
        sent
    }
}

/// Result of one serially executed (query, worker) compute task.
pub(crate) struct TaskRun<A: QueryApp> {
    pub(crate) calls: u64,
    pub(crate) handled: u64,
    pub(crate) sent: u64,
    /// Largest single compute-call fanout of this task.
    pub(crate) max_fan: u64,
    /// Messages parked into fans (⊆ `sent`).
    pub(crate) fanned: u64,
    /// Post-first-fan staging capture, when a mega-fanout parked.
    pub(crate) overflow: Option<StageStream<A>>,
}

/// Execute one (query, worker) compute task serially: the PR 3 per-task
/// body, now also the below-threshold path of the prep dispatch. Stages
/// straight into the shard's staging maps until (if ever) a mega-fanout
/// parks; from then on staging is captured in the returned overflow
/// stream for the staging-column merge to replay in place.
pub(crate) fn run_task<A: QueryApp>(
    app: &A,
    cluster: &Cluster,
    edge: EdgePolicy,
    task: &mut Task<'_, A>,
    outbox_scratch: &mut Vec<(VertexId, A::Msg)>,
) -> TaskRun<A> {
    let step = task.step;
    let call = ComputeCall {
        qid: task.qid,
        step,
        query: task.query,
        agg_prev: task.agg_prev,
        cluster,
        edge,
    };
    // Disjoint borrows of the shard's fields so the hot loop can mutate
    // vertex state IN PLACE while staging messages and aggregating.
    let WorkerShard {
        store,
        active,
        staged,
        agg_round,
        terminated,
    } = &mut *task.shard;

    let mut out = TaskRun {
        calls: 0,
        handled: 0,
        sent: 0,
        max_fan: 0,
        fanned: 0,
        overflow: None,
    };
    let mut next_active: Vec<VertexId> = Vec::new();
    let mut fanned = 0u64;
    let mut overflow: Option<StageStream<A>> = None;
    {
        let mut router = Router::Shard {
            staged,
            overflow: &mut overflow,
            fanned: &mut fanned,
        };
        match store {
            VStore::Hashed { vstate, inbox } => {
                let inbox_now = std::mem::take(inbox);
                // Process message receivers first, then still-active
                // vertices that got no messages.
                for (&v, msgs) in inbox_now.iter() {
                    let st = vstate.entry(v).or_insert_with(|| VState {
                        vq: app.init_value(call.query, v),
                        halted: false,
                        computed_step: 0,
                    });
                    st.halted = false;
                    st.computed_step = step;
                    out.handled += msgs.len() as u64;
                    out.calls += 1;
                    let mut sink = ComputeSink {
                        agg: &mut *agg_round,
                        outbox: &mut *outbox_scratch,
                        next_active: &mut next_active,
                        terminated: &mut *terminated,
                    };
                    let s = call.run(app, v, st, msgs.as_slice(), &mut sink, &mut router);
                    out.max_fan = out.max_fan.max(s);
                    out.sent += s;
                }
                // Active vertices without messages.
                let prev_active = std::mem::take(active);
                for v in prev_active {
                    let st = vstate.get_mut(&v).expect("active implies state");
                    if st.halted || st.computed_step == step {
                        continue;
                    }
                    st.computed_step = step;
                    out.calls += 1;
                    let mut sink = ComputeSink {
                        agg: &mut *agg_round,
                        outbox: &mut *outbox_scratch,
                        next_active: &mut next_active,
                        terminated: &mut *terminated,
                    };
                    let s = call.run(app, v, st, &[], &mut sink, &mut router);
                    out.max_fan = out.max_fan.max(s);
                    out.sent += s;
                }
                // Recycle the inbox map's capacity for the next round
                // (the exchange phase refills it).
                let mut inbox_now = inbox_now;
                inbox_now.clear();
                *inbox = inbox_now;
            }
            VStore::Flat(fs) => {
                // Receivers in delivery order: the recv list is the
                // source-order arrival sequence the exchange recorded, so
                // the flat path visits receivers in exactly the order the
                // hashed inbox would replay. Slots are moved out of the
                // arena (leaving `None`), mirroring the taken inbox map.
                let recv = std::mem::take(&mut fs.recv);
                for &h in recv.iter() {
                    let h = h as usize;
                    let v = fs.verts[h];
                    let slot = fs.msg[h].take().expect("recv implies pending slot");
                    if fs.state[h].is_none() {
                        fs.state[h] = Some(VState {
                            vq: app.init_value(call.query, v),
                            halted: false,
                            computed_step: 0,
                        });
                        fs.n_state += 1;
                    }
                    let st = fs.state[h].as_mut().expect("state ensured above");
                    st.halted = false;
                    st.computed_step = step;
                    out.handled += slot.len() as u64;
                    out.calls += 1;
                    let mut sink = ComputeSink {
                        agg: &mut *agg_round,
                        outbox: &mut *outbox_scratch,
                        next_active: &mut next_active,
                        terminated: &mut *terminated,
                    };
                    let s = call.run(app, v, st, slot.as_slice(), &mut sink, &mut router);
                    out.max_fan = out.max_fan.max(s);
                    out.sent += s;
                }
                // Recycle the recv list's capacity for the next round.
                let mut recv = recv;
                recv.clear();
                fs.recv = recv;
                // Active vertices without messages.
                let prev_active = std::mem::take(active);
                for v in prev_active {
                    let h = fs.handle_of(v).expect("active implies handle") as usize;
                    let st = fs.state[h].as_mut().expect("active implies state");
                    if st.halted || st.computed_step == step {
                        continue;
                    }
                    st.computed_step = step;
                    out.calls += 1;
                    let mut sink = ComputeSink {
                        agg: &mut *agg_round,
                        outbox: &mut *outbox_scratch,
                        next_active: &mut next_active,
                        terminated: &mut *terminated,
                    };
                    let s = call.run(app, v, st, &[], &mut sink, &mut router);
                    out.max_fan = out.max_fan.max(s);
                    out.sent += s;
                }
            }
        }
    }
    *active = next_active;
    out.fanned = fanned;
    out.overflow = overflow;
    out
}

/// Execute an already-transposed work-item list serially against the
/// shard itself: the single-sub-range fallback of the prep dispatch. The
/// split decision is made on a cheap pre-dedup estimate, so a task can
/// turn out to fit in one sub-range after transposition — dispatching it
/// as a sub-job would parallelize nothing and pay the merge replay for
/// free. Items are in serial order and stage straight into the shard's
/// own buffers (mega-fanouts may still park, exactly like `run_task`).
fn run_items_inline<A: QueryApp>(
    app: &A,
    cluster: &Cluster,
    edge: EdgePolicy,
    task: &mut Task<'_, A>,
    items: &mut [WorkItem<A>],
    outbox_scratch: &mut Vec<(VertexId, A::Msg)>,
) -> TaskRun<A> {
    let call = ComputeCall {
        qid: task.qid,
        step: task.step,
        query: task.query,
        agg_prev: task.agg_prev,
        cluster,
        edge,
    };
    // `vstate` stays untouched (items hold pointers into it); every other
    // shard field is the direct sink, exactly like the serial loop.
    let WorkerShard {
        active,
        staged,
        agg_round,
        terminated,
        ..
    } = &mut *task.shard;
    let mut out = TaskRun {
        calls: 0,
        handled: 0,
        sent: 0,
        max_fan: 0,
        fanned: 0,
        overflow: None,
    };
    let mut fanned = 0u64;
    let mut overflow: Option<StageStream<A>> = None;
    {
        let mut router = Router::Shard {
            staged,
            overflow: &mut overflow,
            fanned: &mut fanned,
        };
        for item in items.iter_mut() {
            // SAFETY: same argument as `run_sub` — the pointer was
            // collected after the last vstate insertion, the map's
            // structure is frozen, and this inline loop is the only live
            // access to the slot.
            let st: &mut VState<A::VQ> = unsafe { &mut *item.st.0 };
            let msgs: &[A::Msg] = item.msgs.as_ref().map_or(&[], |s| s.as_slice());
            let mut sink = ComputeSink {
                agg: &mut *agg_round,
                outbox: &mut *outbox_scratch,
                next_active: &mut *active,
                terminated: &mut *terminated,
            };
            let s = call.run(app, item.v, st, msgs, &mut sink, &mut router);
            out.max_fan = out.max_fan.max(s);
            out.sent += s;
            out.calls += 1;
            out.handled += msgs.len() as u64;
        }
    }
    out.fanned = fanned;
    out.overflow = overflow;
    out
}

/// The prep dispatch's per-lane job: run every below-threshold task to
/// completion (the serial path above), and transpose every task the split
/// policy selects into a work-item list plus enough recycled sub-buffers
/// for its sub-ranges. Tasks whose post-dedup item count fits in a single
/// sub-range fall back to the inline path — a split that produces one
/// sub-job parallelizes nothing. Touches only the lane's own
/// shards/scratch plus the read-shared app and cluster.
fn prep_lane<A: QueryApp>(app: &A, cluster: &Cluster, lane: &mut Lane<'_, A>) {
    let mut bufs_needed = 0usize;
    let workers = cluster.workers;
    for idx in 0..lane.tasks.len() {
        let task = &mut lane.tasks[idx];
        // Upper-bound estimate of the work items (actives may dedup
        // against receivers); deterministic, so the decision is too.
        let est = task.shard.store.pending() + task.shard.active.len();
        match lane.policy.sub_size(est) {
            None => {
                let run = run_task(app, cluster, lane.edge, task, &mut lane.scratch.outbox);
                lane.serial_calls += run.calls;
                lane.serial_handled += run.handled;
                lane.serial_sent += run.sent;
                lane.fanned += run.fanned;
                lane.max_fan = lane.max_fan.max(run.max_fan);
                if let Some(stream) = run.overflow {
                    lane.fans.push(FanPrep {
                        task_idx: idx,
                        stream,
                    });
                }
            }
            Some(sub_size) => {
                let mut items = lane.scratch.items_pool.pop().unwrap_or_default();
                task.shard.split_items(
                    app,
                    task.query,
                    task.step,
                    &mut items,
                    &mut lane.scratch.ptr_index,
                );
                if items.len() <= sub_size {
                    let run = run_items_inline(
                        app,
                        cluster,
                        lane.edge,
                        task,
                        &mut items,
                        &mut lane.scratch.outbox,
                    );
                    lane.serial_calls += run.calls;
                    lane.serial_handled += run.handled;
                    lane.serial_sent += run.sent;
                    lane.fanned += run.fanned;
                    lane.max_fan = lane.max_fan.max(run.max_fan);
                    if let Some(stream) = run.overflow {
                        lane.fans.push(FanPrep {
                            task_idx: idx,
                            stream,
                        });
                    }
                    items.clear();
                    lane.scratch.items_pool.push(items);
                } else {
                    bufs_needed += items.len().div_ceil(sub_size);
                    lane.splits.push(SplitPrep {
                        task_idx: idx,
                        qid: task.qid,
                        step: task.step,
                        query: task.query,
                        agg_prev: task.agg_prev,
                        items,
                        sub_size,
                    });
                }
            }
        }
    }
    if lane.scratch.subs.len() < bufs_needed {
        lane.scratch
            .subs
            .resize_with(bufs_needed, || SubBuf::new(workers));
    }
    lane.compute_calls += lane.serial_calls;
    lane.msg_handled += lane.serial_handled;
    lane.sent += lane.serial_sent;
}

/// The sub-job dispatch's unit: one contiguous sub-range of one split
/// task, computed against private staging. Identical semantics to the
/// serial loop except that staging, aggregation, actives and counters go
/// to the sub-job's own [`SubBuf`]; the merge replays them in sub-range
/// order afterwards.
fn run_sub<A: QueryApp>(app: &A, cluster: &Cluster, edge: EdgePolicy, sub: &mut SubJob<'_, A>) {
    let call = ComputeCall {
        qid: sub.qid,
        step: sub.step,
        query: sub.query,
        agg_prev: sub.agg_prev,
        cluster,
        edge,
    };
    let SubBuf {
        stream,
        next_active,
        outbox,
        agg,
        terminated,
        compute_calls,
        msg_handled,
        sent,
        fanned,
        max_fan,
    } = &mut *sub.buf;
    let mut router = Router::Stream { stream, fanned };
    for item in sub.items.iter_mut() {
        // SAFETY: the pointer was collected by `split_items` after the last
        // vstate insertion of this round; the map's structure is untouched
        // until the merge, items hold distinct vertices, and sub-jobs own
        // disjoint item ranges — so this is the only live access to the
        // slot, and the pool's run() barrier sequences it before any
        // coordinator use.
        let st: &mut VState<A::VQ> = unsafe { &mut *item.st.0 };
        let msgs: &[A::Msg] = item.msgs.as_ref().map_or(&[], |s| s.as_slice());
        let mut sink = ComputeSink {
            agg: &mut *agg,
            outbox: &mut *outbox,
            next_active: &mut *next_active,
            terminated: &mut *terminated,
        };
        let s = call.run(app, item.v, st, msgs, &mut sink, &mut router);
        *max_fan = (*max_fan).max(s);
        *sent += s;
        *compute_calls += 1;
        *msg_handled += msgs.len() as u64;
    }
}

/// The merge dispatch's per-lane control job: fold every split task's
/// sub-buffer *non-staging* state back into its shard **in sub-range
/// order** (the serial work order) — actives, aggregator partials,
/// terminate flags, counters, per-sub loads for the post-split imbalance
/// metric, and work-item recycling. Staged messages travel separately,
/// through the per-(task, destination worker) [`StagingCol`] replay jobs
/// of the same dispatch: the two touch disjoint state, and the columns
/// replay the identical serial insertion history concurrently instead of
/// re-serializing it behind one lane job.
fn control_merge<A: QueryApp>(app: &A, cluster: &Cluster, lane: &mut Lane<'_, A>) {
    let Lane {
        tasks,
        scratch,
        splits,
        compute_calls,
        msg_handled,
        sent,
        max_fan,
        sub_loads,
        ..
    } = lane;
    let c1 = cluster.cost.per_vertex_compute_s;
    let c2 = cluster.cost.per_msg_overhead_s;
    let mut buf_idx = 0usize;
    for sp in splits.drain(..) {
        let shard = &mut *tasks[sp.task_idx].shard;
        let n_subs = sp.items.len().div_ceil(sp.sub_size);
        for _ in 0..n_subs {
            let buf = &mut scratch.subs[buf_idx];
            buf_idx += 1;
            *compute_calls += buf.compute_calls;
            *msg_handled += buf.msg_handled;
            *sent += buf.sent;
            *max_fan = (*max_fan).max(buf.max_fan);
            // Same load basis as the lane-imbalance metric: receive-side
            // cost plus send-side staging overhead, minus the messages
            // parked into fans (edge-range jobs carry that staging).
            // Computed from exact integer counters, so it is identical
            // for every schedule.
            sub_loads.push(
                buf.compute_calls as f64 * c1
                    + (buf.msg_handled + buf.sent - buf.fanned) as f64 * c2,
            );
            shard.absorb_control(app, buf);
            buf.reset_counters();
        }
        let mut items = sp.items;
        items.clear();
        scratch.items_pool.push(items);
    }
}

/// One contiguous edge range of one parked mega-fanout: the unit of the
/// edge-range dispatch. Stages its slice of the fan's messages — in slice
/// order, combined sender-side within this range only — into the range's
/// private per-destination-worker buffers; nothing here is visible to any
/// sibling range.
struct EdgeJob<'e, A: QueryApp> {
    /// This range's slice of the fan's outbox, cut into an owned vector
    /// at collection time so the job MOVES messages into staging (no
    /// per-message clone on the very path this split parallelizes).
    msgs: Vec<(VertexId, A::Msg)>,
    /// `bufs[dw]`: this range's insertion-ordered staging per destination.
    bufs: &'e mut Vec<OrderedStaging<A>>,
}

fn run_edge<A: QueryApp>(app: &A, cluster: &Cluster, job: &mut EdgeJob<'_, A>) {
    let EdgeJob { msgs, bufs } = job;
    for (dst, msg) in msgs.drain(..) {
        bufs[cluster.worker_of(dst)].stage(app, dst, msg);
    }
}

/// One staging-replay merge job plus the provenance to hand its map back:
/// the column of one (split or fanned) task for one destination worker.
struct StagingMerge<A: QueryApp> {
    lane: usize,
    task: usize,
    dw: usize,
    col: StagingCol<A>,
}

/// The merge dispatch's heterogeneous unit: per-lane control folds and
/// per-(task, destination worker) staging replays touch disjoint state,
/// so one dispatch runs them all concurrently.
enum MergeJob<'l, 'a, A: QueryApp> {
    Control(&'l mut Lane<'a, A>),
    Staging(StagingMerge<A>),
}

/// Recycle a drained stage stream: leftover fan range buffers go back to
/// the ordered-staging pool, fan message vectors and segment husks are
/// dropped, and the unit list is cleared for the next round.
fn recycle_stream<A: QueryApp>(
    stream: &mut StageStream<A>,
    ord_pool: &mut Vec<OrderedStaging<A>>,
) {
    for unit in stream.units.drain(..) {
        if let StageUnit::Fan(ft) = unit {
            for rb in ft.bufs {
                ord_pool.extend(rb);
            }
        }
    }
}

/// Execute every task of one exchange lane: drain each source shard's
/// staging buffer addressed to this destination into the destination inbox,
/// in source-worker order, replaying the sender-side combiner per message.
/// Runs on a pool worker; touches only owned task data plus the read-shared
/// app.
fn run_exchange<A: QueryApp>(app: &A, lane: &mut ExchangeLane<A>) {
    for task in lane.tasks.iter_mut() {
        let ExchangeTask {
            inbound,
            inbox,
            delivered,
        } = task;
        for srcbuf in inbound.iter_mut() {
            *delivered += deliver_into_sink(app, inbox, srcbuf);
        }
    }
}

/// Phase-job granularity handed to the worker pool.
///
/// Both schedulers run on the same stealing deques; they differ only in
/// how a phase's items are cut into jobs, which is exactly what decides
/// whether skew can be absorbed. Outputs are bit-identical either way —
/// the scheduler picks executors, never merge or delivery orders.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Sched {
    /// One contiguous `div_ceil(items, threads)` mega-chunk per pool
    /// thread (the pre-stealing scheduler, kept as the benchmark
    /// baseline): a skewed item serializes its whole chunk behind it.
    Static,
    /// One job per item — per worker lane (compute), per destination
    /// worker (exchange), per query (fold). Idle pool threads steal queued
    /// jobs from the back of busy threads' deques, so a heavy lane never
    /// pins the phase on one thread. The default.
    Stealing,
}

/// Super-round execution mode: strict barriers or ready-driven pipelining.
///
/// Under [`Pipeline::On`] a super-round is ONE pool batch of per-(query,
/// worker) step jobs plus the previous round's deferred reporting jobs.
/// The last lane of a query to finish its compute ships the query's
/// staged columns and runs its fold immediately (see the module docs), so
/// fast queries flow through exchange and fold while a skewed query's
/// heavy lane is still computing, and reporting supersteps overlap the
/// next round's compute. Rounds where sub-lane splitting or edge-range
/// splitting would engage fall back to the barrier path (splitting is the
/// better answer to ONE pathologically heavy task; pipelining is the
/// answer to heavy tasks *next to* light ones), as do serial engines —
/// [`EngineMetrics::pipelined_rounds`] counts the rounds that actually
/// ran ready-driven. Results are bit-identical for either setting, for
/// every threads × workers × capacity × [`Sched`] × [`Split`] ×
/// [`EdgeSplit`] combination.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Pipeline {
    /// Strict compute → exchange → fold barriers (the PR 5 baseline).
    Off,
    /// Ready-driven super-rounds: eager per-query column handoff and
    /// fold, with reporting overlapped onto the next round's compute.
    On,
}

/// Admission-control policy: which queued queries a super-round admits
/// into the in-flight set (the serving layer's planner knob).
///
/// [`Admit::Static`] is the historical behavior — a fixed per-round
/// budget drained FIFO. [`Admit::Adaptive`] (the default) plans per
/// super-round: light queries still flow FIFO up to the capacity ceiling,
/// but queries the app flagged as whales at submission
/// ([`crate::vertex::QueryApp::is_heavy`] — e.g. hub2 PPSP pairs whose
/// index upper bound `d_ub` crosses a depth threshold) are confined to a
/// reserved capacity slice, squeezed further while the previous round was
/// message-saturated and lights are waiting — so one whale can't starve
/// thousands of point lookups by inflating every shared super-round.
///
/// The planner reads **deterministic inputs only** — queue contents and
/// prior-round integer counters, never wall-clock — so the admission
/// schedule is reproducible, and since admission timing never changes
/// what a query computes, `QueryResult::out` stays bit-identical per
/// query across the whole `Admit` axis (pinned by the determinism suite
/// and the fuzzer's forcing leg). Result *order* is deterministic within
/// an `Admit` setting but may legitimately differ between settings: that
/// is the planner doing its job.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Admit {
    /// Fixed FIFO admission budget of `c` queries per round (clamped to
    /// the engine capacity): exactly the pre-serving-layer behavior.
    Static(usize),
    /// Per-round planning with a reserved heavy slice (capacity/4, or
    /// capacity/8 under message pressure with lights waiting). With no
    /// heavy-flagged queries this is identical to `Static(capacity)`.
    /// The default.
    Adaptive,
}

/// The complete, plain-data configuration of an [`Engine`]: every knob
/// the builder methods set, in one serializable struct.
///
/// Two jobs:
///
/// 1. **One front door for defaults.** [`EngineConfig::from_env`] is the
///    single place the `QUEGEL_TEST_*` CI-matrix env hooks are read
///    (scheduler / pipeline / admission / layout) — it replaces the three
///    per-knob `default_from_env()` impls that used to be scattered across
///    `Sched`, `Pipeline` and `Admit`. `Engine::new` is now a thin
///    delegate to [`Engine::with_config`]`(…, EngineConfig::from_env())`,
///    and the existing builder methods keep working as per-field setters
///    on top of whatever config the engine started from.
///
/// 2. **The handshake object of the multi-process mode.** The coordinator
///    ships exactly this struct — via [`EngineConfig::to_bytes`] /
///    [`EngineConfig::from_bytes`], a zero-dependency byte codec — to
///    every worker process at connection setup, so remote shards run
///    under bit-identical knobs without re-reading any environment.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct EngineConfig {
    /// Capacity `C`: max in-flight queries per super-round.
    pub capacity: usize,
    /// OS threads for the parallel phases (1 = serial loop).
    pub threads: usize,
    /// Phase-job granularity (see [`Sched`]).
    pub sched: Sched,
    /// Intra-lane sub-job splitting policy (see [`Split`]).
    pub split: Split,
    /// Edge-level splitting policy for mega-fanouts (see [`EdgeSplit`]).
    pub edge_split: EdgeSplit,
    /// Super-round execution mode (see [`Pipeline`]).
    pub pipeline: Pipeline,
    /// Per-query state layout (see [`Layout`]).
    pub layout: Layout,
    /// Admission policy (see [`Admit`]). Taken verbatim by
    /// [`Engine::with_config`] — unlike the [`Engine::capacity`] builder,
    /// no `Admit::Static` re-sync happens, so set the payload you mean.
    pub admit: Admit,
    /// Submission-queue bound (`None` = unbounded batch behavior).
    pub queue_bound: Option<usize>,
    /// Superstep safety cap per query.
    pub max_supersteps: u64,
}

impl Default for EngineConfig {
    /// The hard-coded engine defaults, ignoring the environment.
    fn default() -> Self {
        Self {
            capacity: DEFAULT_CAPACITY, // paper: throughput saturates around C = 8
            threads: std::thread::available_parallelism()
                .map(|n| n.get())
                .unwrap_or(1),
            sched: Sched::Stealing,
            split: Split::Adaptive,
            edge_split: EdgeSplit::Adaptive,
            pipeline: Pipeline::Off,
            layout: Layout::Flat,
            admit: Admit::Adaptive,
            queue_bound: None,
            max_supersteps: DEFAULT_MAX_SUPERSTEPS,
        }
    }
}

/// Current version byte of the [`EngineConfig`] wire encoding; bumped on
/// any layout change so a stale worker binary fails the handshake loudly
/// instead of silently misreading knobs.
const ENGINE_CONFIG_WIRE_VERSION: u8 = 1;

impl EngineConfig {
    /// The defaults for new engines, honoring every `QUEGEL_TEST_*`
    /// test-matrix env hook in one place:
    ///
    /// - `QUEGEL_TEST_SCHED=static` → [`Sched::Static`] (else `Stealing`)
    /// - `QUEGEL_TEST_PIPELINE=on|1` → [`Pipeline::On`] (else `Off`)
    /// - `QUEGEL_TEST_ADMIT=static` → [`Admit::Static`] at the default
    ///   capacity (else `Adaptive`); the [`Engine::capacity`] builder
    ///   re-syncs the static payload, so the baseline leg reproduces the
    ///   historical fixed-capacity admission at every call site
    /// - `QUEGEL_TEST_LAYOUT=hashed` → [`Layout::Hashed`] (else `Flat`,
    ///   via [`Layout::default_from_env`], which stays in `arena.rs` next
    ///   to the layout itself)
    ///
    /// Each override announces itself on stderr once per process (an
    /// ambient env var silently changing engine behavior is surprising
    /// outside CI). Explicit builder calls and explicit field writes on
    /// the returned config still win.
    pub fn from_env() -> Self {
        let sched = match std::env::var("QUEGEL_TEST_SCHED") {
            Ok(v) if v.eq_ignore_ascii_case("static") => {
                static NOTE: std::sync::Once = std::sync::Once::new();
                NOTE.call_once(|| {
                    eprintln!(
                        "quegel: QUEGEL_TEST_SCHED=static overrides the default \
                         scheduler (test-matrix hook); unset it for production use"
                    );
                });
                Sched::Static
            }
            _ => Sched::Stealing,
        };
        let pipeline = match std::env::var("QUEGEL_TEST_PIPELINE") {
            Ok(v) if v.eq_ignore_ascii_case("on") || v == "1" => {
                static NOTE: std::sync::Once = std::sync::Once::new();
                NOTE.call_once(|| {
                    eprintln!(
                        "quegel: QUEGEL_TEST_PIPELINE=on overrides the default \
                         super-round mode (test-matrix hook); unset it for the \
                         barrier baseline"
                    );
                });
                Pipeline::On
            }
            _ => Pipeline::Off,
        };
        let admit = match std::env::var("QUEGEL_TEST_ADMIT") {
            Ok(v) if v.eq_ignore_ascii_case("static") => {
                static NOTE: std::sync::Once = std::sync::Once::new();
                NOTE.call_once(|| {
                    eprintln!(
                        "quegel: QUEGEL_TEST_ADMIT=static overrides the default \
                         admission planner (test-matrix hook); unset it for \
                         adaptive admission"
                    );
                });
                Admit::Static(DEFAULT_CAPACITY)
            }
            _ => Admit::Adaptive,
        };
        Self {
            sched,
            pipeline,
            admit,
            layout: Layout::default_from_env(),
            ..Self::default()
        }
    }

    /// The invariants the builder methods assert, in one place. Called by
    /// [`Engine::with_config`] and after [`EngineConfig::from_bytes`].
    fn validate(&self) -> Result<(), &'static str> {
        if self.capacity == 0 {
            return Err("capacity must be > 0");
        }
        if self.threads == 0 {
            return Err("threads must be > 0");
        }
        if matches!(self.admit, Admit::Static(0)) {
            return Err("static admission budget must be > 0");
        }
        if matches!(self.split, Split::MaxTaskVertices(0)) {
            return Err("split threshold must be > 0");
        }
        if matches!(self.edge_split, EdgeSplit::MaxFanout(0)) {
            return Err("edge-split threshold must be > 0");
        }
        if self.queue_bound == Some(0) {
            return Err("queue bound must be > 0");
        }
        Ok(())
    }

    /// Serialize for the worker handshake: a version byte, then every
    /// knob as fixed-width little-endian fields (enum tags as `u8`,
    /// counts as `u64`, `Option` as a presence flag). Zero dependencies.
    pub fn to_bytes(&self) -> Vec<u8> {
        use crate::network::wire::{put_u8, put_u64};
        let mut out = Vec::with_capacity(64);
        put_u8(&mut out, ENGINE_CONFIG_WIRE_VERSION);
        put_u64(&mut out, self.capacity as u64);
        put_u64(&mut out, self.threads as u64);
        put_u8(&mut out, matches!(self.sched, Sched::Stealing) as u8);
        match self.split {
            Split::Off => put_u8(&mut out, 0),
            Split::MaxTaskVertices(n) => {
                put_u8(&mut out, 1);
                put_u64(&mut out, n as u64);
            }
            Split::Adaptive => put_u8(&mut out, 2),
        }
        match self.edge_split {
            EdgeSplit::Off => put_u8(&mut out, 0),
            EdgeSplit::MaxFanout(n) => {
                put_u8(&mut out, 1);
                put_u64(&mut out, n as u64);
            }
            EdgeSplit::Adaptive => put_u8(&mut out, 2),
        }
        put_u8(&mut out, matches!(self.pipeline, Pipeline::On) as u8);
        put_u8(&mut out, matches!(self.layout, Layout::Flat) as u8);
        match self.admit {
            Admit::Static(c) => {
                put_u8(&mut out, 0);
                put_u64(&mut out, c as u64);
            }
            Admit::Adaptive => put_u8(&mut out, 1),
        }
        match self.queue_bound {
            Some(n) => {
                put_u8(&mut out, 1);
                put_u64(&mut out, n as u64);
            }
            None => put_u8(&mut out, 0),
        }
        put_u64(&mut out, self.max_supersteps);
        out
    }

    /// Inverse of [`EngineConfig::to_bytes`]. Errors (never panics) on a
    /// version mismatch, an unknown enum tag, truncation, trailing bytes,
    /// or a config that fails the builder invariants.
    pub fn from_bytes(bytes: &[u8]) -> Result<Self, crate::network::wire::WireError> {
        use crate::network::wire::{WireError, WireReader};
        let mut r = WireReader::new(bytes);
        if r.u8()? != ENGINE_CONFIG_WIRE_VERSION {
            return Err(WireError::Corrupt("engine-config version"));
        }
        let capacity = r.u64()? as usize;
        let threads = r.u64()? as usize;
        let sched = match r.u8()? {
            0 => Sched::Static,
            1 => Sched::Stealing,
            _ => return Err(WireError::Corrupt("sched tag")),
        };
        let split = match r.u8()? {
            0 => Split::Off,
            1 => Split::MaxTaskVertices(r.u64()? as usize),
            2 => Split::Adaptive,
            _ => return Err(WireError::Corrupt("split tag")),
        };
        let edge_split = match r.u8()? {
            0 => EdgeSplit::Off,
            1 => EdgeSplit::MaxFanout(r.u64()? as usize),
            2 => EdgeSplit::Adaptive,
            _ => return Err(WireError::Corrupt("edge-split tag")),
        };
        let pipeline = match r.u8()? {
            0 => Pipeline::Off,
            1 => Pipeline::On,
            _ => return Err(WireError::Corrupt("pipeline tag")),
        };
        let layout = match r.u8()? {
            0 => Layout::Hashed,
            1 => Layout::Flat,
            _ => return Err(WireError::Corrupt("layout tag")),
        };
        let admit = match r.u8()? {
            0 => Admit::Static(r.u64()? as usize),
            1 => Admit::Adaptive,
            _ => return Err(WireError::Corrupt("admit tag")),
        };
        let queue_bound = match r.u8()? {
            0 => None,
            1 => Some(r.u64()? as usize),
            _ => return Err(WireError::Corrupt("queue-bound flag")),
        };
        let max_supersteps = r.u64()?;
        r.expect_end()?;
        let cfg = Self {
            capacity,
            threads,
            sched,
            split,
            edge_split,
            pipeline,
            layout,
            admit,
            queue_bound,
            max_supersteps,
        };
        cfg.validate().map_err(WireError::Corrupt)?;
        Ok(cfg)
    }
}

/// Message volume per capacity slot above which the adaptive planner
/// treats the previous super-round as saturated and squeezes the heavy
/// admission slice from capacity/4 to capacity/8. An integer count from
/// the deterministic message accounting — never wall time — so the
/// squeeze decision replays identically on any machine.
pub(crate) const ADMIT_BUSY_MSGS_PER_SLOT: u64 = 256;

/// One entry of the submission queue: a request waiting for admission.
struct Queued<Q> {
    id: QueryId,
    query: Q,
    /// Simulated time the request arrived at the serving front end. May
    /// predate `enqueued_at` when a bounded queue back-pressured it
    /// (`Engine::try_submit`).
    arrived_at: f64,
    /// Simulated time the request entered this queue.
    enqueued_at: f64,
    /// Whale flag from `QueryApp::is_heavy`, frozen at submission.
    heavy: bool,
}

/// Phase tags for the busy/overlap interval log of a pipelined round.
const PHASE_COMPUTE: u8 = 0;
const PHASE_EXCHANGE: u8 = 1;
const PHASE_FOLD: u8 = 2;

/// Raw pointer handed to pipelined step jobs. `Send`/`Sync` because the
/// access discipline is enforced by the readiness protocol at the use
/// sites: shard `w` is touched only by the one (query, worker) job that
/// owns it, and the whole `QueryRt` only by the query's last-finishing
/// job (sequenced by the `remaining` AcqRel countdown) — with
/// `WorkerPool::run`'s barrier ordering everything before the
/// coordinator looks again.
struct PipePtr<T>(*mut T);

impl<T> Clone for PipePtr<T> {
    fn clone(&self) -> Self {
        *self
    }
}
impl<T> Copy for PipePtr<T> {}
// SAFETY: see the type docs — disjoint ownership per job plus the
// countdown/barrier happens-before edges.
unsafe impl<T: Send> Send for PipePtr<T> {}
unsafe impl<T: Send> Sync for PipePtr<T> {}

/// Shared handle to one running query inside a pipelined batch: raw
/// routes to its state plus the readiness countdown that elects the lane
/// which ships the query's exchange and fold.
struct PipeQuery<A: QueryApp> {
    rt: PipePtr<QueryRt<A>>,
    /// `rt.shards.as_mut_ptr()`, captured while the coordinator still had
    /// exclusive access so jobs never materialize a `&mut Vec` (two jobs
    /// doing that concurrently would alias).
    shards: PipePtr<WorkerShard<A>>,
    query: PipePtr<A::Query>,
    agg_prev: PipePtr<A::Agg>,
    qid: QueryId,
    /// Superstep this round executes for the query (1-based).
    step: u64,
    /// Lanes still computing; the job that decrements this to zero owns
    /// the whole query and runs its exchange + fold.
    remaining: AtomicUsize,
}

/// Read-shared context of one pipelined batch: app/cluster handles, the
/// per-worker compute counters (the same integer totals the barrier path
/// accumulates per lane, so the derived cost model is identical), and the
/// busy/overlap instrumentation.
struct PipeShared<'a, A: QueryApp> {
    app: &'a A,
    cluster: &'a Cluster,
    workers: usize,
    msg_size: usize,
    max_supersteps: u64,
    /// Per-worker-lane counters, `fetch_add`ed by step jobs: integer sums
    /// are associative, so the totals match the barrier path's lane
    /// counters exactly.
    calls: Vec<AtomicU64>,
    handled: Vec<AtomicU64>,
    sent: Vec<AtomicU64>,
    max_fan: AtomicU64,
    /// Post-combiner wire bytes delivered this round.
    round_bytes: AtomicU64,
    compute_busy: &'a AtomicU64,
    exchange_busy: &'a AtomicU64,
    fold_busy: &'a AtomicU64,
    /// Origin of the interval log's time axis.
    base: Instant,
    /// (phase, start_ns, end_ns) spans for the overlap sweep.
    intervals: Mutex<Vec<(u8, u64, u64)>>,
}

impl<A: QueryApp> PipeShared<'_, A> {
    /// Account one span of phase work: busy nanos plus an interval for
    /// the overlap sweep.
    fn record(&self, phase: u8, start: Instant, end: Instant) {
        let ns = end.duration_since(start).as_nanos() as u64;
        let busy = match phase {
            PHASE_COMPUTE => self.compute_busy,
            PHASE_EXCHANGE => self.exchange_busy,
            _ => self.fold_busy,
        };
        busy.fetch_add(ns, Ordering::Relaxed);
        let s = start.saturating_duration_since(self.base).as_nanos() as u64;
        self.intervals.lock().unwrap().push((phase, s, s + ns));
    }
}

/// A query whose reporting superstep was deferred by a pipelined round:
/// its stats are already final (completion was accounted the round it
/// converged); only `QueryApp::finish` is still owed, and it runs either
/// as a job overlapped with the next pipelined round's compute or
/// serially in [`Engine::flush_pending_reports`].
struct PendingReport<A: QueryApp> {
    rt: QueryRt<A>,
    out: Option<A::Out>,
}

/// One pipelined (query, worker) step job: run the task's compute, and —
/// when this is the query's last lane to finish — immediately drain the
/// query's staged columns into the destination inboxes and run its fold,
/// without waiting for any other query's lanes.
fn pipe_task<A: QueryApp>(sh: &PipeShared<'_, A>, pq: &PipeQuery<A>, w: usize) {
    let t0 = Instant::now();
    let run = {
        // SAFETY: exactly one job per (query, worker) exists, so shard `w`
        // is this job's exclusive property until the countdown below;
        // `query`/`agg_prev` are only read while step jobs run. The pool
        // barrier sequences all of it before the coordinator continues.
        let shard: &mut WorkerShard<A> = unsafe { &mut *pq.shards.0.add(w) };
        let query: &A::Query = unsafe { &*pq.query.0 };
        let agg_prev: &A::Agg = unsafe { &*pq.agg_prev.0 };
        let mut task = Task {
            qid: pq.qid,
            step: pq.step,
            query,
            agg_prev,
            shard,
        };
        // Private outbox scratch: unlike barrier lanes, tasks of distinct
        // queries on the same worker run concurrently here, so they
        // cannot share the lane scratch. Edge parking is disabled
        // (ranges would re-serialize behind this job anyway); parking is
        // output-neutral, so this changes no result.
        let mut outbox: Vec<(VertexId, A::Msg)> = Vec::new();
        run_task(sh.app, sh.cluster, EdgePolicy::Never, &mut task, &mut outbox)
    };
    debug_assert!(run.overflow.is_none() && run.fanned == 0);
    sh.calls[w].fetch_add(run.calls, Ordering::Relaxed);
    sh.handled[w].fetch_add(run.handled, Ordering::Relaxed);
    sh.sent[w].fetch_add(run.sent, Ordering::Relaxed);
    sh.max_fan.fetch_max(run.max_fan, Ordering::Relaxed);
    let t1 = Instant::now();
    sh.record(PHASE_COMPUTE, t0, t1);
    // Readiness handoff: the RMW chain on `remaining` (AcqRel) orders
    // this job after every sibling lane's writes; whoever reads 1 here is
    // the query's last lane and owns the whole query from now on.
    if pq.remaining.fetch_sub(1, Ordering::AcqRel) != 1 {
        return;
    }
    // SAFETY: all `workers` step jobs of this query have completed (the
    // countdown above), their borrows are dead, and the coordinator is
    // still blocked in `WorkerPool::run` — exclusive access.
    let rt: &mut QueryRt<A> = unsafe { &mut *pq.rt.0 };
    let mut delivered = 0u64;
    for dw in 0..sh.workers {
        // Take the delivery sink so the src == dw iteration needs no
        // split borrow; same store object the barrier exchange would have
        // taken (under Layout::Flat this moves the whole arena out and
        // back, a pointer-sized swap).
        let mut sink = rt.shards[dw].store.take_exchange_sink();
        for src in 0..sh.workers {
            delivered += deliver_into_sink(sh.app, &mut sink, &mut rt.shards[src].staged[dw]);
        }
        rt.shards[dw].store.restore_exchange_sink(sink);
    }
    rt.step += 1;
    rt.stats.messages += delivered;
    let q_bytes = delivered * sh.msg_size as u64;
    rt.stats.bytes += q_bytes;
    sh.round_bytes.fetch_add(q_bytes, Ordering::Relaxed);
    let t2 = Instant::now();
    sh.record(PHASE_EXCHANGE, t1, t2);
    fold_query(sh.app, rt, sh.max_supersteps);
    sh.record(PHASE_FOLD, t2, Instant::now());
}

/// Wall seconds during which two or more *distinct phases* were
/// simultaneously active, from a (phase, start_ns, end_ns) interval log.
/// Multiple concurrent jobs of the SAME phase do not count as overlap —
/// each phase's intervals are merged into a union first, then a sweep
/// accumulates the time with ≥ 2 phases live.
fn overlap_seconds(intervals: &[(u8, u64, u64)]) -> f64 {
    let mut events: Vec<(u64, i32)> = Vec::new();
    for phase in [PHASE_COMPUTE, PHASE_EXCHANGE, PHASE_FOLD] {
        let mut ivs: Vec<(u64, u64)> = intervals
            .iter()
            .filter(|iv| iv.0 == phase && iv.2 > iv.1)
            .map(|iv| (iv.1, iv.2))
            .collect();
        ivs.sort_unstable();
        let mut merged: Vec<(u64, u64)> = Vec::new();
        for (s, e) in ivs {
            match merged.last_mut() {
                Some(last) if s <= last.1 => last.1 = last.1.max(e),
                _ => merged.push((s, e)),
            }
        }
        for (s, e) in merged {
            events.push((s, 1));
            events.push((e, -1));
        }
    }
    // Sorting (t, delta) puts ends (-1) before starts (+1) at equal t, so
    // touching-but-disjoint phases never register phantom overlap.
    events.sort_unstable();
    let mut active = 0i32;
    let mut last_t = 0u64;
    let mut overlap_ns = 0u64;
    for (t, d) in events {
        if active >= 2 {
            overlap_ns += t - last_t;
        }
        active += d;
        last_t = t;
    }
    overlap_ns as f64 * 1e-9
}

/// Serial-segment stopwatch for the barrier path: accumulates the wall
/// time of coordinator-side phase work into that phase's busy counter,
/// *pausing* around pool dispatches (whose jobs time themselves inside
/// [`run_phase`]) so nothing is counted twice. Under `Pipeline::Off`
/// phases never overlap, so busy-summing the serial segments and the job
/// bodies reconstructs ≈ the phase's wall span — which is how the
/// three-phases-sum-to-wall invariant survives the move to busy time.
struct SerialTimer<'a> {
    busy: &'a AtomicU64,
    mark: Option<Instant>,
}

impl<'a> SerialTimer<'a> {
    fn start(busy: &'a AtomicU64) -> Self {
        Self {
            busy,
            mark: Some(Instant::now()),
        }
    }

    fn pause(&mut self) {
        if let Some(m) = self.mark.take() {
            self.busy
                .fetch_add(m.elapsed().as_nanos() as u64, Ordering::Relaxed);
        }
    }

    fn resume(&mut self) {
        self.mark = Some(Instant::now());
    }

    fn stop(mut self) {
        self.pause();
    }
}

/// Dispatch one parallel phase over the pool at the `sched` granularity,
/// or inline when no pool exists (`threads = 1`). All three phases
/// (compute / exchange / fold) route through here, so job-granularity
/// policy lives in exactly one place. Returns the pool's scheduling
/// counters for the engine's per-phase metrics.
///
/// Each job body times itself into `busy` (nanoseconds of actual phase
/// work, summed across threads) — the per-phase *busy* accounting that
/// replaced the coordinator's wall-segment stopwatches, which double-count
/// once phases overlap under [`Pipeline::On`].
fn run_phase<T: Send>(
    pool: Option<&WorkerPool>,
    nthreads: usize,
    sched: Sched,
    items: &mut [T],
    busy: &AtomicU64,
    f: impl Fn(&mut T) + Sync,
) -> RunStats {
    if items.is_empty() {
        return RunStats::default();
    }
    let Some(pool) = pool else {
        let t0 = Instant::now();
        for item in items.iter_mut() {
            f(item);
        }
        busy.fetch_add(t0.elapsed().as_nanos() as u64, Ordering::Relaxed);
        return RunStats {
            jobs: items.len() as u64,
            steals: 0,
        };
    };
    let f = &f;
    let jobs: Vec<Job<'_>> = match sched {
        Sched::Static => {
            let chunk = items.len().div_ceil(nthreads);
            items
                .chunks_mut(chunk)
                .map(|chunk_items| {
                    Box::new(move || {
                        let t0 = Instant::now();
                        for item in chunk_items.iter_mut() {
                            f(item);
                        }
                        busy.fetch_add(t0.elapsed().as_nanos() as u64, Ordering::Relaxed);
                    }) as Job<'_>
                })
                .collect()
        }
        Sched::Stealing => items
            .iter_mut()
            .map(|item| {
                Box::new(move || {
                    let t0 = Instant::now();
                    f(item);
                    busy.fetch_add(t0.elapsed().as_nanos() as u64, Ordering::Relaxed);
                }) as Job<'_>
            })
            .collect(),
    };
    pool.run(jobs)
}

/// The fold-phase unit for one query: merge per-worker aggregator partials
/// in worker order, OR the per-shard terminate flags, run the master hook,
/// and drive the lifecycle transition. Pure per-query state, so queries
/// fold concurrently on the pool without changing any result.
fn fold_query<A: QueryApp>(app: &A, rt: &mut QueryRt<A>, max_supersteps: u64) {
    if rt.phase != Phase::Running {
        return;
    }
    let mut merged = A::Agg::default();
    for shard in rt.shards.iter_mut() {
        let part = std::mem::take(&mut shard.agg_round);
        app.agg_merge(&mut merged, &part);
        if shard.terminated {
            rt.terminated = true;
            shard.terminated = false;
        }
    }
    let action = app.master_step(&rt.query, rt.step, &rt.agg_prev, &mut merged);
    rt.agg_prev = merged;
    if action == MasterAction::Terminate {
        rt.terminated = true;
    }
    if rt.step >= max_supersteps {
        rt.terminated = true;
        rt.stats.truncated = true;
    }
    if rt.terminated || rt.quiescent() {
        rt.phase = Phase::Reporting;
    }
    rt.stats.supersteps = rt.step;
}

impl<A: QueryApp> Engine<A> {
    /// Engine over `app` (which owns the graph / V-data) on `cluster`.
    /// `n_vertices` is |V|, used for access-rate accounting. Equivalent to
    /// [`Engine::with_config`] with [`EngineConfig::from_env`] — the env
    /// test-matrix hooks apply, and the builder methods below adjust
    /// individual knobs from there.
    pub fn new(app: A, cluster: Cluster, n_vertices: usize) -> Self {
        Self::with_config(app, cluster, n_vertices, EngineConfig::from_env())
    }

    /// Engine with an explicit, complete configuration — the constructor
    /// the multi-process mode uses on both sides of the handshake (the
    /// coordinator ships `cfg` in bytes; the worker rebuilds the identical
    /// engine knobs from them). No environment is consulted and no knob is
    /// adjusted: `cfg` is applied verbatim (in particular, an
    /// [`Admit::Static`] payload is NOT re-synced to `cfg.capacity` the
    /// way the [`Engine::capacity`] builder does). Panics if `cfg` fails
    /// the builder invariants (zero capacity/threads/bounds).
    pub fn with_config(app: A, cluster: Cluster, n_vertices: usize, cfg: EngineConfig) -> Self {
        if let Err(what) = cfg.validate() {
            panic!("invalid EngineConfig: {what}");
        }
        Self {
            app,
            cluster,
            capacity: cfg.capacity,
            threads: cfg.threads,
            sched: cfg.sched,
            split: cfg.split,
            edge_split: cfg.edge_split,
            pipeline: cfg.pipeline,
            layout: cfg.layout,
            admit: cfg.admit,
            queue_bound: cfg.queue_bound,
            last_round_messages: 0,
            last_compute_imbalance: 0.0,
            seen_max_fan: 0,
            pool: None,
            n_vertices,
            epoch: 0,
            muts: Vec::new(),
            queue: VecDeque::new(),
            inflight: Vec::new(),
            pending_reports: Vec::new(),
            results: Vec::new(),
            next_qid: 0,
            clock: 0.0,
            max_supersteps: cfg.max_supersteps,
            metrics: EngineMetrics::default(),
            lane_scratch: Vec::new(),
            exchange_scratch: Vec::new(),
        }
    }

    /// Set the capacity parameter `C` (max queries per super-round). A
    /// default-from-env [`Admit::Static`] payload is re-synced to `c`, so
    /// the `QUEGEL_TEST_ADMIT=static` baseline leg reproduces the
    /// historical fixed-capacity admission at every call site; set an
    /// explicit [`Engine::admit`] AFTER this to pin a smaller budget.
    pub fn capacity(mut self, c: usize) -> Self {
        assert!(c > 0);
        self.capacity = c;
        if let Admit::Static(_) = self.admit {
            self.admit = Admit::Static(c);
        }
        self
    }

    /// Select the admission policy (see [`Admit`]). [`Admit::Adaptive`]
    /// is the default; `QueryResult::out` is bit-identical per query for
    /// every setting (the planner only shapes *when* queries run).
    /// An [`Admit::Static`] budget is clamped to the engine capacity at
    /// planning time; call this after [`Engine::capacity`] so a later
    /// capacity re-sync doesn't overwrite an explicit static budget.
    pub fn admit(mut self, a: Admit) -> Self {
        if let Admit::Static(c) = a {
            assert!(c > 0);
        }
        self.admit = a;
        self
    }

    /// Bound the submission queue to `n` waiting requests: once full,
    /// [`Engine::try_submit`] back-pressures (returns the query to the
    /// caller) instead of growing the queue without limit — the serving
    /// front end's overload valve. Unbounded by default ([`Engine::submit`]
    /// keeps the historical batch semantics and panics on a full bound,
    /// since silently dropping a batch query would corrupt results).
    pub fn queue_bound(mut self, n: usize) -> Self {
        assert!(n > 0);
        self.queue_bound = Some(n);
        self
    }

    /// Set the number of OS threads for the parallel phases (compute,
    /// exchange, fold). Defaults to `std::thread::available_parallelism()`;
    /// `1` forces the fully serial loop. Values above the worker count
    /// engage whenever sub-lane splitting can use them (they parallelize
    /// inside a single shard); rounds where splitting cannot engage keep
    /// the worker-count clamp so idle threads are never spawned or woken.
    /// Results are bit-identical for every setting.
    pub fn threads(mut self, t: usize) -> Self {
        assert!(t > 0);
        self.threads = t;
        // Re-created at the right size by the next super-round that needs
        // it; dropping here joins any previously spawned workers.
        self.pool = None;
        self
    }

    /// Select the phase-job scheduler. [`Sched::Stealing`] (the default)
    /// splits every phase into per-item jobs balanced by work stealing;
    /// [`Sched::Static`] keeps the contiguous one-chunk-per-thread split.
    /// Results are bit-identical for either setting.
    pub fn scheduler(mut self, s: Sched) -> Self {
        self.sched = s;
        self
    }

    /// Select the intra-lane sub-job splitting policy for the compute
    /// phase (see [`Split`]). [`Split::Adaptive`] is the default; results
    /// are bit-identical for every setting.
    pub fn split(mut self, s: Split) -> Self {
        self.split = s;
        self
    }

    /// Convenience for [`Split::MaxTaskVertices`]: cut any (query, worker)
    /// compute task with more than `n` active/receiving vertices into
    /// sub-ranges of at most `n`.
    pub fn max_lane_vertices(self, n: usize) -> Self {
        assert!(n > 0);
        self.split(Split::MaxTaskVertices(n))
    }

    /// Select the edge-level splitting policy for mega-fanout compute
    /// calls (see [`EdgeSplit`]). [`EdgeSplit::Adaptive`] is the default;
    /// results are bit-identical for every setting.
    pub fn edge_split(mut self, e: EdgeSplit) -> Self {
        self.edge_split = e;
        self
    }

    /// Convenience for [`EdgeSplit::MaxFanout`]: park any compute call
    /// staging more than `n` messages and cut it into edge ranges of at
    /// most `n`.
    pub fn max_task_edges(self, n: usize) -> Self {
        assert!(n > 0);
        self.edge_split(EdgeSplit::MaxFanout(n))
    }

    /// Select the super-round execution mode (see [`Pipeline`]).
    /// [`Pipeline::Off`] — the strict barrier loop — is the default;
    /// results are bit-identical for either setting.
    pub fn pipeline(mut self, p: Pipeline) -> Self {
        self.pipeline = p;
        self
    }

    /// Select the per-query state layout (see [`Layout`]).
    /// [`Layout::Flat`] — slab arenas and columnar staging — is the
    /// default; [`Layout::Hashed`] keeps the original hash-map stores as
    /// the benchmark baseline. Must be set before any query is submitted
    /// (every shard is built for the engine's layout); results are
    /// bit-identical for either setting.
    pub fn layout(mut self, l: Layout) -> Self {
        assert!(
            self.inflight.is_empty() && self.queue.is_empty(),
            "layout must be chosen before queries are submitted"
        );
        self.layout = l;
        self
    }

    /// Override the superstep safety cap.
    pub fn max_supersteps(mut self, n: u64) -> Self {
        self.max_supersteps = n;
        self
    }

    /// Borrow the app (e.g. to read indexes it built).
    pub fn app(&self) -> &A {
        &self.app
    }

    /// Mutably borrow the app (e.g. to install index data between jobs).
    pub fn app_mut(&mut self) -> &mut A {
        &mut self.app
    }

    /// Current simulated cluster time (seconds).
    pub fn sim_time(&self) -> f64 {
        self.clock
    }

    /// Advance the simulated clock (e.g. to account for graph loading).
    pub fn advance_clock(&mut self, dt: f64) {
        self.clock += dt;
    }

    /// Engine-wide counters.
    pub fn metrics(&self) -> &EngineMetrics {
        &self.metrics
    }

    /// Mutably borrow the engine-wide counters (e.g. to call
    /// [`EngineMetrics::reset`] directly when re-syncing `sim_time` via
    /// [`Engine::reset_metrics`] is not wanted).
    pub fn metrics_mut(&mut self) -> &mut EngineMetrics {
        &mut self.metrics
    }

    /// Zero the engine-wide counters, so a caller can account a session
    /// (e.g. one `run_one`) in isolation: scheduler counters like
    /// `steals`/`jobs_executed` are per-`WorkerPool::run` batch and only
    /// ever accumulate, so without a reset a second session always reads
    /// the first one's totals too. The simulated clock is NOT reset (it is
    /// engine state, not a counter); `sim_time` re-syncs to it at the next
    /// super-round.
    pub fn reset_metrics(&mut self) {
        self.metrics.reset();
        self.metrics.sim_time = self.clock;
    }

    /// Completed queries so far (submission order not guaranteed; sort by
    /// qid if needed).
    pub fn results(&self) -> &[QueryResult<A::Out>] {
        &self.results
    }

    /// Drain completed query results. Reports deferred by a pipelined
    /// round are flushed first, so everything completed so far is visible.
    pub fn take_results(&mut self) -> Vec<QueryResult<A::Out>> {
        self.flush_pending_reports();
        std::mem::take(&mut self.results)
    }

    /// Submit a query; returns its id. Processing starts at the next
    /// super-round with free capacity. Arrival and queue entry coincide
    /// (the historical batch semantics); panics if a configured
    /// [`Engine::queue_bound`] is full — bounded serving front ends use
    /// [`Engine::try_submit`] and handle the back-pressure.
    pub fn submit(&mut self, q: A::Query) -> QueryId {
        match self.try_submit(q, self.clock) {
            Ok(id) => id,
            Err(_) => panic!(
                "submission queue full (bound {:?}): use try_submit for back-pressure",
                self.queue_bound
            ),
        }
    }

    /// Serving front-end submission with back-pressure: enqueue the
    /// request, or hand it back (`Err`) if a configured
    /// [`Engine::queue_bound`] is full so the arrival source can retry
    /// after the next super-round. `arrived_at` is the simulated time the
    /// request reached the front end — for a retried request that is
    /// *earlier* than the eventual queue entry, and
    /// [`crate::metrics::QueryStats::latency`] measures from it, so the
    /// wait spent back-pressured stays visible in the tail percentiles.
    /// The app's [`crate::vertex::QueryApp::is_heavy`] hook is evaluated
    /// here, once, and the flag frozen for the query's lifetime.
    pub fn try_submit(&mut self, q: A::Query, arrived_at: f64) -> Result<QueryId, A::Query> {
        if let Some(bound) = self.queue_bound {
            if self.queue.len() >= bound {
                return Err(q);
            }
        }
        let id = self.next_qid;
        self.next_qid += 1;
        let heavy = self.app.is_heavy(&q);
        self.queue.push_back(Queued {
            id,
            query: q,
            arrived_at,
            enqueued_at: self.clock,
            heavy,
        });
        Ok(id)
    }

    /// Requests waiting in the submission queue (excludes in-flight
    /// queries) — the depth signal arrival sources pace themselves on.
    pub fn queue_depth(&self) -> usize {
        self.queue.len()
    }

    /// Queue a graph mutation batch on the simulated clock, next to
    /// [`Engine::try_submit`]: the batch is applied at the NEXT
    /// super-round boundary (all queued batches, FIFO, each bumping the
    /// epoch by one), never mid-round — an in-flight query keeps reading
    /// the epoch it pinned at admission for its whole lifetime.
    /// `arrived_at` is a stamp only (like a submission's arrival time);
    /// it does not reorder batches. Hands the batch back (`Err`) when the
    /// app's graph is immutable
    /// ([`crate::vertex::QueryApp::supports_mutations`] is false).
    pub fn try_mutate(
        &mut self,
        batch: MutationBatch,
        arrived_at: f64,
    ) -> Result<(), MutationBatch> {
        if !self.app.supports_mutations() {
            return Err(batch);
        }
        self.muts.push((batch, arrived_at));
        Ok(())
    }

    /// Current graph epoch (what the next admitted query would pin).
    pub fn epoch(&self) -> Epoch {
        self.epoch
    }

    /// Apply every queued mutation batch, FIFO, each bumping the epoch by
    /// one. Runs on the coordinator at the very top of `super_round` —
    /// strictly between supersteps, before admission — so a batch is
    /// visible to exactly the queries admitted at its epoch or later.
    fn apply_pending_mutations(&mut self) {
        if self.muts.is_empty() {
            return;
        }
        for (batch, _arrived_at) in std::mem::take(&mut self.muts) {
            let applied = self.app.apply_mutations(&batch);
            self.epoch = applied.epoch;
            self.n_vertices = applied.n_vertices;
            self.metrics.epochs_applied += 1;
            // Peak is sampled per apply, BEFORE any compaction: a batch
            // that is applied and immediately retired still registers.
            self.metrics.delta_bytes_peak = self
                .metrics
                .delta_bytes_peak
                .max(applied.delta_bytes as u64);
        }
    }

    /// Recompute the oldest epoch still pinned by an in-flight (or
    /// pending-report) query and let the app retire everything older —
    /// when the oldest pin catches up with the current epoch the app's
    /// overlay compacts. No-op for immutable-graph apps.
    fn refresh_epoch_pin(&mut self) {
        if !self.app.supports_mutations() {
            return;
        }
        let oldest = self
            .inflight
            .iter()
            .map(|rt| rt.epoch)
            .chain(self.pending_reports.iter().map(|p| p.rt.epoch))
            .min()
            .unwrap_or(self.epoch);
        self.metrics.oldest_pinned_epoch = oldest;
        self.app.retire_epochs(oldest);
    }

    /// Run super-rounds until the queue and all in-flight queries drain.
    pub fn run_until_idle(&mut self) {
        while self.super_round() {}
    }

    /// Convenience: submit one query and run it to completion, returning
    /// its result (interactive-mode helper). The result is removed from the
    /// completed-result buffer, so sessions that only ever call `run_one`
    /// never accumulate results; completion is still accounted in
    /// [`EngineMetrics::queries_completed`] whether or not `take_results`
    /// is ever called, so engine-level stats stay consistent either way.
    pub fn run_one(&mut self, q: A::Query) -> QueryResult<A::Out> {
        let id = self.submit(q);
        self.run_until_idle();
        let idx = self
            .results
            .iter()
            .position(|r| r.qid == id)
            .expect("query must have completed");
        self.results.swap_remove(idx)
    }

    /// Execute one super-round. Returns false if there was nothing to do.
    pub fn super_round(&mut self) -> bool {
        // Queued mutation batches land here and only here — at the
        // super-round boundary, BEFORE admission and BEFORE the idle
        // check (a mutation-only round still advances the epoch) — so a
        // version change falls strictly between supersteps: in-flight
        // queries keep their pinned epoch, queries admitted below pin
        // the fresh one.
        self.apply_pending_mutations();
        if self.inflight.is_empty() && self.queue.is_empty() {
            // The last pipelined round may have deferred reporting work
            // with no next round to overlap it onto — run it now, so
            // `run_until_idle` never strands a result.
            self.flush_pending_reports();
            // Nothing in flight pins anything: let the overlay compact.
            self.refresh_epoch_pin();
            return false;
        }
        let wall_start = Instant::now();
        let workers = self.cluster.workers;

        // --- Admission: fetch queries according to the round's admission
        // plan (paper §3.1, extended by the [`Admit`] planner). The
        // admitted batch is collected first and offered to the app's
        // [`QueryApp::admit_batch`] hook in admission order — the
        // batched-kernel entry point (e.g. hub2 fills lazy distance upper
        // bounds for the whole batch in one min-plus sweep) — before any
        // per-query runtime state is built.
        let mut admitted: Vec<Queued<A::Query>> = Vec::new();
        match self.admit {
            // Fixed FIFO budget (clamped to capacity): the historical
            // admission loop, bit for bit.
            Admit::Static(c) => {
                let budget = c.min(self.capacity);
                while self.inflight.len() + admitted.len() < budget {
                    let Some(e) = self.queue.pop_front() else {
                        break;
                    };
                    admitted.push(e);
                }
            }
            // Per-round plan: lights flow FIFO up to the capacity
            // ceiling; heavies are confined to a reserved slice so a
            // queue full of whales can't occupy every slot a point
            // lookup needs. All inputs are deterministic — queue
            // contents, the in-flight heavy count and the previous
            // round's message counter — so the schedule replays
            // identically on any machine or thread count.
            Admit::Adaptive => {
                // Reserved whale slice: a quarter of capacity, squeezed
                // to an eighth while the previous round was
                // message-saturated AND a light query is actually
                // waiting (with only whales queued there is nobody to
                // protect, so no reason to idle slots). At least one
                // slot, and heavies already in flight count against it,
                // so whales trickle through instead of starving.
                let saturated = self.last_round_messages
                    > ADMIT_BUSY_MSGS_PER_SLOT * self.capacity as u64;
                let light_waiting = self.queue.iter().any(|e| !e.heavy);
                let div = if saturated && light_waiting { 8 } else { 4 };
                let slice = (self.capacity / div).max(1);
                let heavy_inflight = self.inflight.iter().filter(|rt| rt.heavy).count();
                let mut heavy_budget = slice.saturating_sub(heavy_inflight);
                let mut kept: VecDeque<Queued<A::Query>> =
                    VecDeque::with_capacity(self.queue.len());
                while let Some(e) = self.queue.pop_front() {
                    if self.inflight.len() + admitted.len() >= self.capacity {
                        // Out of slots: everything else keeps waiting in
                        // order (not a planner deferral — a full engine
                        // defers under Static too).
                        kept.push_back(e);
                        continue;
                    }
                    if e.heavy && heavy_budget == 0 {
                        // Slots are free but the whale slice is spent:
                        // hold the whale, let lights behind it pass.
                        // This is the planner engaging.
                        self.metrics.admit_deferrals += 1;
                        kept.push_back(e);
                        continue;
                    }
                    if e.heavy {
                        heavy_budget -= 1;
                    }
                    admitted.push(e);
                }
                self.queue = kept;
            }
        }
        let mut metas: Vec<(QueryId, f64, f64, bool)> = Vec::with_capacity(admitted.len());
        let mut qs: Vec<A::Query> = Vec::with_capacity(admitted.len());
        for e in admitted {
            metas.push((e.id, e.arrived_at, e.enqueued_at, e.heavy));
            qs.push(e.query);
        }
        if !qs.is_empty() {
            // Epoch pinning precedes the batched-kernel hook: whatever
            // admit_batch computes (e.g. hub2's lazy d_ub fill) is
            // computed against the pinned version's index state, and the
            // epoch is frozen query content from here on.
            self.app.pin_epoch(&mut qs, self.epoch);
            self.app.admit_batch(&mut qs);
        }
        for ((id, arrived_at, submitted_at, heavy), q) in metas.into_iter().zip(qs) {
            let mut rt = QueryRt::<A>::new(
                id,
                q,
                workers,
                self.layout,
                arrived_at,
                submitted_at,
                heavy,
                self.epoch,
                self.n_vertices,
            );
            rt.stats.started_at = self.clock;
            // init_activate: seed the initial activation set V_q^I.
            let init = self.app.init_activate(&rt.query);
            for v in init {
                let w = self.cluster.worker_of(v);
                let shard = &mut rt.shards[w];
                let app = &self.app;
                let query = &rt.query;
                shard.store.seed_with(v, || VState {
                    vq: app.init_value(query, v),
                    halted: false,
                    computed_step: 0,
                });
                shard.active.push(v);
            }
            self.inflight.push(rt);
        }
        self.metrics.peak_inflight = self.metrics.peak_inflight.max(self.inflight.len());
        if self.inflight.is_empty() {
            self.flush_pending_reports();
            self.refresh_epoch_pin();
            return false;
        }

        // Per-phase *busy* accumulators (nanoseconds of actual phase work,
        // summed across threads). Every phase body — pool job or
        // coordinator serial segment — times itself into one of these;
        // the totals land in the `EngineMetrics` phase fields at the end
        // of the round. Wall-segment stopwatches can't survive
        // pipelining: once phases overlap, their segments double-count.
        let compute_busy = AtomicU64::new(0);
        let exchange_busy = AtomicU64::new(0);
        let fold_busy = AtomicU64::new(0);

        // --- Thread budget & pool. Since the sub-lane split, threads
        // beyond `workers` are exactly what parallelizes INSIDE one
        // pathological shard (workers = 1, threads = 8 used to force a
        // fully serial engine). The old worker-count clamp still applies
        // whenever splitting cannot engage THIS round — static scheduler,
        // Split::Off, or an unarmed Split::Adaptive — so balanced
        // default-configured engines never spawn (and wake, three times
        // per round) pool threads that cannot have work.
        //
        // Adaptive arms on skew evidence, all of it deterministic: a
        // prior lane-imbalance round OR fewer lanes than threads (a
        // single lane's imbalance ratio is identically 1.0, yet splitting
        // is the only way to use the other threads at all) — AND at least
        // one task this round big enough to actually split.
        let mut max_task_est = 0usize;
        for rt in self.inflight.iter() {
            if rt.phase != Phase::Running {
                continue;
            }
            for shard in rt.shards.iter() {
                max_task_est = max_task_est.max(shard.store.pending() + shard.active.len());
            }
        }
        let adaptive_armed = (self.last_compute_imbalance > SPLIT_IMBALANCE_TRIGGER
            || workers < self.threads)
            && max_task_est >= SPLIT_MIN_ITEMS;
        // Edge-split arming for the thread budget only: the park decision
        // itself is made per compute call on the outbox length, but a
        // mega-fanout seen in ANY earlier round (deterministic evidence,
        // like the imbalance ratio) is what justifies waking threads
        // beyond the worker count for rounds that may park again.
        let edge_armed = match (self.sched, self.edge_split) {
            (Sched::Stealing, EdgeSplit::MaxFanout(n)) => self.seen_max_fan as usize > n,
            (Sched::Stealing, EdgeSplit::Adaptive) => {
                self.seen_max_fan as usize >= EDGE_SPLIT_MIN_FAN
            }
            _ => false,
        };
        let splittable = edge_armed
            || match (self.sched, self.split) {
                (Sched::Stealing, Split::MaxTaskVertices(_)) => true,
                (Sched::Stealing, Split::Adaptive) => adaptive_armed,
                _ => false,
            };
        // Pipelined rounds also use threads beyond the worker count: a
        // batch holds (queries × workers) step jobs plus deferred report
        // jobs, so there is work for them even with a single worker lane
        // per query.
        let nthreads = if splittable || self.pipeline == Pipeline::On {
            self.threads.max(1)
        } else {
            self.threads.min(workers).max(1)
        };
        // The pool only ever GROWS to the demanded size; a bigger-than-
        // needed pool from an earlier skewed round is kept, not thrashed.
        let need_pool = nthreads > 1
            && match &self.pool {
                Some(pool) => pool.threads() < nthreads,
                None => true,
            };
        if need_pool {
            self.pool = None; // join any smaller pool's workers first
            self.pool = Some(WorkerPool::new(nthreads));
        }

        let policy = if nthreads == 1 {
            // Serial engine: sub-jobs would run one after another on the
            // same thread, so transposition + merge replay would be pure
            // overhead. Outputs are split-invariant by construction
            // (pinned by the fuzzer), so skipping is unobservable.
            SplitPolicy::Never
        } else {
            match (self.sched, self.split) {
                // The static baseline and explicit Off never split.
                (Sched::Static, _) | (_, Split::Off) => SplitPolicy::Never,
                (_, Split::MaxTaskVertices(n)) => SplitPolicy::Fixed(n.max(1)),
                (_, Split::Adaptive) => {
                    if adaptive_armed {
                        SplitPolicy::Adaptive { threads: nthreads }
                    } else {
                        SplitPolicy::Never
                    }
                }
            }
        };
        // Edge-split decision for this round. Unlike the vertex policy it
        // needs no arming: the park test reads the outbox length at
        // compute time, which is exactly the deterministic signal — a
        // round with no mega-fanout pays nothing.
        let edge_policy = if nthreads == 1 {
            EdgePolicy::Never
        } else {
            match (self.sched, self.edge_split) {
                (Sched::Static, _) | (_, EdgeSplit::Off) => EdgePolicy::Never,
                (_, EdgeSplit::MaxFanout(n)) => EdgePolicy::Fixed(n.max(1)),
                (_, EdgeSplit::Adaptive) => EdgePolicy::Adaptive { threads: nthreads },
            }
        };

        // --- Pipelined-round gate. A round runs ready-driven only when no
        // splitting machinery wants it: sub-lane and edge-range splitting
        // answer ONE pathologically heavy task (they need barriers to
        // merge), pipelining answers heavy tasks NEXT TO light ones. Every
        // input here is deterministic (engine knobs plus skew evidence
        // from prior rounds' integer counters), so the same round of the
        // same run pipelines — or not — on every machine alike.
        let pipelined = self.pipeline == Pipeline::On
            && nthreads > 1
            && self.pool.is_some()
            && matches!(policy, SplitPolicy::Never)
            && !edge_armed;
        if pipelined {
            return self.pipelined_round(wall_start, workers);
        }
        // Reporting work a pipelined round deferred can only overlap a
        // pipelined batch; run it serially before this barrier round.
        self.flush_pending_reports();

        let msg_size = self.app.msg_bytes() + self.cluster.cost.msg_header_bytes;
        let app = &self.app;
        let cluster = &self.cluster;
        let pool = self.pool.as_ref();
        let sched = self.sched;

        // --- Compute phase: transpose the running queries into worker
        // lanes (shard w of every query + worker w's scratch) and run them
        // through up to four pool dispatches: **prep** (below-threshold
        // tasks run to completion, heavy tasks transpose into work items,
        // mega-fanouts park), **sub-jobs** (one per contiguous vertex
        // sub-range, private staging), **edge ranges** (one per contiguous
        // range of a parked fanout, private staging), and **merge** (fold
        // everything back in fixed serial-stream order — staging columns
        // concurrent per destination worker, control folds per lane). When
        // nothing splits — the common balanced case — the prep dispatch IS
        // the whole phase and the others are skipped.
        if self.lane_scratch.len() < workers {
            self.lane_scratch.resize_with(workers, LaneScratch::new);
        }
        let mut lanes: Vec<Lane<'_, A>> = self
            .lane_scratch
            .iter_mut()
            .take(workers)
            .map(|scratch| Lane {
                tasks: Vec::new(),
                scratch,
                policy,
                edge: edge_policy,
                splits: Vec::new(),
                fans: Vec::new(),
                compute_calls: 0,
                msg_handled: 0,
                sent: 0,
                serial_calls: 0,
                serial_handled: 0,
                serial_sent: 0,
                fanned: 0,
                max_fan: 0,
                sub_loads: Vec::new(),
            })
            .collect();
        for rt in self.inflight.iter_mut() {
            if rt.phase != Phase::Running {
                continue;
            }
            let qid = rt.id;
            let step = rt.step + 1;
            let QueryRt { query, agg_prev, shards, .. } = rt;
            // Shared refs (Copy) so every lane's task can carry them.
            let query: &A::Query = query;
            let agg_prev: &A::Agg = agg_prev;
            for (lane, shard) in lanes.iter_mut().zip(shards.iter_mut()) {
                lane.tasks.push(Task { qid, step, query, agg_prev, shard });
            }
        }

        // Coordinator-side serial segments of the phase (dispatch prep,
        // buffer plumbing) count as phase busy time too; the timer pauses
        // around pool dispatches, whose job bodies time themselves.
        let mut ct = SerialTimer::start(&compute_busy);
        ct.pause();
        let prep_stats = run_phase(pool, nthreads, sched, &mut lanes, &compute_busy, |lane| {
            prep_lane(app, cluster, lane)
        });
        ct.resume();
        self.metrics.compute_sched.add(prep_stats.jobs, prep_stats.steals);

        // Sub-job dispatch: pair each split task's item sub-ranges with the
        // lane's recycled sub-buffers, in a fixed order the merge replays.
        let mut tasks_split = 0u64;
        let mut subjobs: Vec<SubJob<'_, A>> = Vec::new();
        for lane in lanes.iter_mut() {
            tasks_split += lane.splits.len() as u64;
            let Lane { splits, scratch, .. } = lane;
            let mut bufs = scratch.subs.iter_mut();
            for sp in splits.iter_mut() {
                for items in sp.items.chunks_mut(sp.sub_size) {
                    let buf = bufs.next().expect("prep sized the buffer pool");
                    subjobs.push(SubJob {
                        qid: sp.qid,
                        step: sp.step,
                        query: sp.query,
                        agg_prev: sp.agg_prev,
                        items,
                        buf,
                    });
                }
            }
        }
        let did_subjobs = !subjobs.is_empty();
        if did_subjobs {
            ct.pause();
            let sub_stats = run_phase(pool, nthreads, sched, &mut subjobs, &compute_busy, |sub| {
                run_sub(app, cluster, edge_policy, sub)
            });
            ct.resume();
            self.metrics.compute_sched.add(sub_stats.jobs, sub_stats.steals);
            self.metrics.subjobs_executed += sub_stats.jobs;
            self.metrics.tasks_split += tasks_split;
        }
        drop(subjobs);

        // --- Edge-range dispatch: cut every parked mega-fanout (from the
        // serial paths' overflow streams and the sub-jobs' streams) into
        // contiguous edge ranges, each staged by its own pool job into a
        // private insertion-ordered buffer. Range buffers recycle through
        // the lane's ordered-staging pool.
        let mut edge_loads: Vec<f64> = Vec::new();
        let c2_edge = cluster.cost.per_msg_overhead_s;
        let mut edge_jobs: Vec<EdgeJob<'_, A>> = Vec::new();
        for lane in lanes.iter_mut() {
            let Lane { scratch, fans, .. } = lane;
            let LaneScratch { subs, ord_pool, .. } = &mut **scratch;
            for stream in fans
                .iter_mut()
                .map(|fp| &mut fp.stream)
                .chain(subs.iter_mut().map(|b| &mut b.stream))
            {
                for unit in stream.units.iter_mut() {
                    let StageUnit::Fan(ft) = unit else { continue };
                    let n = ft.n_ranges();
                    ft.bufs.clear();
                    for _ in 0..n {
                        let mut rb = Vec::with_capacity(workers);
                        for _ in 0..workers {
                            rb.push(ord_pool.pop().unwrap_or_else(OrderedStaging::empty));
                        }
                        ft.bufs.push(rb);
                    }
                    let FanTask { msgs, range, bufs } = ft;
                    let range = (*range).max(1);
                    // Move the fan's messages into owned per-range chunks
                    // (one pass, one Vec per range) so the jobs stage by
                    // move, not clone.
                    let mut drain = std::mem::take(msgs).into_iter();
                    for rb in bufs.iter_mut() {
                        let chunk: Vec<(VertexId, A::Msg)> =
                            drain.by_ref().take(range).collect();
                        // An edge range's load is pure staging overhead
                        // (the compute call itself stays with its task).
                        edge_loads.push(chunk.len() as f64 * c2_edge);
                        edge_jobs.push(EdgeJob { msgs: chunk, bufs: rb });
                    }
                    debug_assert!(drain.next().is_none(), "bufs cover every range");
                }
            }
        }
        let n_edge_jobs = edge_jobs.len() as u64;
        if !edge_jobs.is_empty() {
            ct.pause();
            let edge_stats = run_phase(pool, nthreads, sched, &mut edge_jobs, &compute_busy, |job| {
                run_edge(app, cluster, job)
            });
            ct.resume();
            self.metrics.compute_sched.add(edge_stats.jobs, edge_stats.steals);
            self.metrics.edge_ranges_split += n_edge_jobs;
        }
        drop(edge_jobs);

        // --- Merge dispatch: per-(task, destination worker) staging
        // columns replay the serial insertion history concurrently
        // (distinct destinations own distinct maps), while per-lane
        // control jobs fold the non-staging sub-buffer state — disjoint
        // work, one dispatch.
        if did_subjobs || n_edge_jobs > 0 {
            let mut merge_jobs: Vec<MergeJob<'_, '_, A>> = Vec::new();
            for (li, lane) in lanes.iter_mut().enumerate() {
                let Lane {
                    tasks,
                    scratch,
                    splits,
                    fans,
                    ..
                } = lane;
                let subs = &mut scratch.subs;
                let mut buf_idx = 0usize;
                for sp in splits.iter() {
                    let n_subs = sp.items.len().div_ceil(sp.sub_size);
                    let bufs = &mut subs[buf_idx..buf_idx + n_subs];
                    buf_idx += n_subs;
                    let staged = &mut tasks[sp.task_idx].shard.staged;
                    for (dw, target) in staged.iter_mut().enumerate() {
                        let mut sources = Vec::new();
                        for buf in bufs.iter_mut() {
                            buf.stream.collect_column(dw, &mut sources);
                        }
                        if sources.is_empty() {
                            continue;
                        }
                        merge_jobs.push(MergeJob::Staging(StagingMerge {
                            lane: li,
                            task: sp.task_idx,
                            dw,
                            col: StagingCol {
                                target: std::mem::take(target),
                                sources,
                            },
                        }));
                    }
                }
                for fp in fans.iter_mut() {
                    let staged = &mut tasks[fp.task_idx].shard.staged;
                    for (dw, target) in staged.iter_mut().enumerate() {
                        let mut sources = Vec::new();
                        fp.stream.collect_column(dw, &mut sources);
                        if sources.is_empty() {
                            continue;
                        }
                        merge_jobs.push(MergeJob::Staging(StagingMerge {
                            lane: li,
                            task: fp.task_idx,
                            dw,
                            col: StagingCol {
                                target: std::mem::take(target),
                                sources,
                            },
                        }));
                    }
                }
            }
            for lane in lanes.iter_mut() {
                if !lane.splits.is_empty() {
                    merge_jobs.push(MergeJob::Control(lane));
                }
            }
            ct.pause();
            let merge_stats =
                run_phase(pool, nthreads, sched, &mut merge_jobs, &compute_busy, |job| match job {
                    MergeJob::Control(lane) => control_merge(app, cluster, lane),
                    MergeJob::Staging(s) => s.col.replay(app),
                });
            ct.resume();
            self.metrics.compute_sched.add(merge_stats.jobs, merge_stats.steals);
            // Hand the replayed staging maps back to their shards, then
            // recycle the drained buffers and stream husks. Two passes:
            // the first consumes the job list (releasing the control
            // jobs' lane borrows), the second may index lanes freely.
            let mut replayed: Vec<StagingMerge<A>> = Vec::new();
            for job in merge_jobs {
                if let MergeJob::Staging(s) = job {
                    replayed.push(s);
                }
            }
            for s in replayed {
                let lane = &mut lanes[s.lane];
                lane.tasks[s.task].shard.staged[s.dw] = s.col.target;
                lane.scratch.ord_pool.extend(s.col.sources);
            }
            for lane in lanes.iter_mut() {
                let Lane { scratch, fans, .. } = lane;
                let LaneScratch { subs, ord_pool, .. } = &mut **scratch;
                for fp in fans.drain(..) {
                    let mut stream = fp.stream;
                    recycle_stream(&mut stream, ord_pool);
                }
                for buf in subs.iter_mut() {
                    recycle_stream(&mut buf.stream, ord_pool);
                    // Reseed the stream's private segment pool (one
                    // segment's worth) so next round's sub-jobs reuse
                    // capacity instead of allocating fresh buffers.
                    buf.stream.seed(ord_pool, workers);
                }
                // Bound the pool: without a cap, every split round pushes
                // drained buffers that only fan rounds ever pop back out.
                ord_pool.truncate(ORD_POOL_CAP_PER_WORKER * workers);
            }
        }
        ct.stop();

        let c1 = cluster.cost.per_vertex_compute_s;
        let c2 = cluster.cost.per_msg_overhead_s;
        let mut worker_cost = Vec::with_capacity(workers);
        let mut lane_load = Vec::with_capacity(workers);
        let mut round_msgs: u64 = 0;
        let mut total_compute_calls: u64 = 0;
        // Post-split work units: the prep job's serial share per lane plus
        // every sub-job — what the scheduler can actually move between
        // threads after splitting.
        let mut max_unit_load = 0.0_f64;
        let mut round_max_fan = 0u64;
        for lane in &lanes {
            // Lane totals come from exact integer counters, so the derived
            // simulated cost is identical for every split setting.
            let cost = lane.compute_calls as f64 * c1 + lane.msg_handled as f64 * c2;
            worker_cost.push(cost);
            // Imbalance basis: receive-side cost PLUS send-side staging
            // overhead, which for combiner apps is exactly the skew that
            // hurts wall time — a hub lane's big out-fanout is staging
            // work on the sender.
            lane_load.push(cost + lane.sent as f64 * c2);
            round_msgs += lane.sent;
            total_compute_calls += lane.compute_calls;
            round_max_fan = round_max_fan.max(lane.max_fan);
            // The prep job's own share: messages it parked into fans are
            // subtracted — their staging ran as edge-range jobs.
            let serial_load = lane.serial_calls as f64 * c1
                + (lane.serial_handled + lane.serial_sent - lane.fanned) as f64 * c2;
            max_unit_load = max_unit_load.max(serial_load);
            for &l in &lane.sub_loads {
                max_unit_load = max_unit_load.max(l);
            }
        }
        for &l in &edge_loads {
            max_unit_load = max_unit_load.max(l);
        }
        drop(lanes);
        self.metrics.max_edge_task = self.metrics.max_edge_task.max(round_max_fan);
        self.seen_max_fan = self.seen_max_fan.max(round_max_fan);
        self.metrics.total_compute_calls += total_compute_calls;
        // Lane-imbalance ratio of this round's compute phase (max lane
        // load over mean lane load, from the deterministic cost model):
        // the skew the stealing scheduler exists to absorb. ~1.0 means a
        // balanced partition; W means one lane carried everything. The
        // per-round value also drives next round's Split::Adaptive
        // decision; the post-split ratio uses the same normalization but
        // measures the largest *schedulable unit* left after splitting —
        // read the two together to see how much of a pathological lane the
        // sub-jobs actually broke up.
        let max_load = lane_load.iter().copied().fold(0.0_f64, f64::max);
        let total_load: f64 = lane_load.iter().sum();
        if total_load > 0.0 {
            let ratio = max_load * lane_load.len() as f64 / total_load;
            self.last_compute_imbalance = ratio;
            if ratio > self.metrics.max_lane_imbalance {
                self.metrics.max_lane_imbalance = ratio;
            }
            let post_ratio = max_unit_load * lane_load.len() as f64 / total_load;
            if post_ratio > self.metrics.max_post_split_imbalance {
                self.metrics.max_post_split_imbalance = post_ratio;
            }
        }

        // --- Exchange phase: destination-sharded message routing. The
        // staging buffers are keyed by destination worker already, so each
        // destination drains its column of the W×W staging matrix
        // independently. The maps are *taken* from the shards (cheap
        // pointer-sized moves) so exchange lanes own their data outright,
        // and are handed back below to recycle their capacity.
        let mut et = SerialTimer::start(&exchange_busy);
        if self.exchange_scratch.len() < workers {
            self.exchange_scratch
                .resize_with(workers, || ExchangeLane { tasks: Vec::new() });
        }
        let ex_lanes = &mut self.exchange_scratch[..workers];
        let mut qi = 0usize;
        for rt in self.inflight.iter_mut() {
            if rt.phase != Phase::Running {
                continue;
            }
            for (dw, lane) in ex_lanes.iter_mut().enumerate() {
                // Reuse last round's task slot where possible: its inbound
                // vector was drained (capacity kept) and its inbox is an
                // unallocated leftover default.
                if lane.tasks.len() == qi {
                    lane.tasks.push(ExchangeTask {
                        inbound: Vec::with_capacity(workers),
                        inbox: ExchangeSink::default(),
                        delivered: 0,
                    });
                }
                let task = &mut lane.tasks[qi];
                task.inbox = rt.shards[dw].store.take_exchange_sink();
                task.delivered = 0;
            }
            // Column extraction in source-worker order, so each destination
            // replays arrivals exactly as the serial barrier did.
            for shard in rt.shards.iter_mut() {
                for (stg, lane) in shard.staged.iter_mut().zip(ex_lanes.iter_mut()) {
                    lane.tasks[qi].inbound.push(std::mem::take(stg));
                }
            }
            qi += 1;
        }
        let nq = qi;
        for lane in ex_lanes.iter_mut() {
            // Drop stale slots from rounds that ran more queries.
            lane.tasks.truncate(nq);
        }
        et.pause();
        let exchange_stats =
            run_phase(pool, nthreads, sched, &mut *ex_lanes, &exchange_busy, |lane| {
                run_exchange(app, lane)
            });
        et.resume();
        self.metrics.exchange_sched.add(exchange_stats.jobs, exchange_stats.steals);
        // Post-pass: hand filled inboxes and drained staging maps back to
        // their shards (recycling capacity) and fold delivered counts into
        // per-query stats.
        let mut round_bytes: u64 = 0;
        let mut qi = 0usize;
        for rt in self.inflight.iter_mut() {
            if rt.phase != Phase::Running {
                continue;
            }
            rt.step += 1;
            let mut q_msgs: u64 = 0;
            for (dw, lane) in ex_lanes.iter_mut().enumerate() {
                let task = &mut lane.tasks[qi];
                q_msgs += task.delivered;
                rt.shards[dw]
                    .store
                    .restore_exchange_sink(std::mem::take(&mut task.inbox));
                for (src, buf) in task.inbound.drain(..).enumerate() {
                    rt.shards[src].staged[dw] = buf;
                }
            }
            qi += 1;
            rt.stats.messages += q_msgs;
            let q_bytes = q_msgs * msg_size as u64;
            rt.stats.bytes += q_bytes;
            round_bytes += q_bytes;
        }
        et.stop();
        self.sweep_flat_staging();

        // --- Fold phase: per-query aggregator fold, master hook and
        // lifecycle, parallel across queries (the fold inside each query
        // stays in worker order, so results are unchanged).
        let mut ft = SerialTimer::start(&fold_busy);
        let max_supersteps = self.max_supersteps;
        ft.pause();
        let fold_stats = run_phase(pool, nthreads, sched, &mut self.inflight, &fold_busy, |rt| {
            fold_query(app, rt, max_supersteps)
        });
        ft.resume();
        self.metrics.fold_sched.add(fold_stats.jobs, fold_stats.steals);

        // Aggregator sync bytes: one Agg per worker per running query.
        round_bytes +=
            (self.inflight.len() * workers * std::mem::size_of::<A::Agg>()) as u64;

        // --- Advance the simulated clock.
        let dt = self.cluster.super_round_time(&worker_cost, round_bytes as usize);
        self.clock += dt;
        self.metrics.super_rounds += 1;
        self.metrics.total_messages += round_msgs;
        self.metrics.total_bytes += round_bytes;
        self.metrics.sim_time = self.clock;
        // Deterministic saturation signal for the next round's admission
        // plan (the adaptive heavy-slice squeeze).
        self.last_round_messages = round_msgs;

        // --- Reporting super-round (n_q + 1): assemble results and free
        // all VQ-data / Q-data of finished queries. Completion is counted
        // in the engine metrics here, so per-query accounting never depends
        // on the caller draining `take_results`.
        let clock = self.clock;
        let results = &mut self.results;
        let metrics = &mut self.metrics;
        self.inflight.retain_mut(|rt| {
            if rt.phase != Phase::Reporting {
                return true;
            }
            let touched = rt.touched();
            rt.stats.touched = touched;
            // Normalized against the |V| of the version this query
            // pinned at admission, not the engine's current count —
            // mutations applied mid-flight must not skew the rate.
            rt.stats.access_rate = touched as f64 / rt.n_vertices.max(1) as f64;
            rt.stats.finished_at = clock;
            metrics.queries_completed += 1;
            metrics.latency.record(rt.stats.latency());
            metrics.queueing.record(rt.stats.queueing());
            let mut iter = rt
                .shards
                .iter()
                .flat_map(|s| s.store.touched_iter());
            let out = app.finish(&rt.query, &mut iter, &rt.agg_prev);
            results.push(QueryResult {
                qid: rt.id,
                out,
                stats: rt.stats.clone(),
            });
            false // drop: frees HT_Q entry + all LUT_v entries of q
        });
        ft.stop();

        // Queries that just reported released their epoch pins: retire
        // everything below the new oldest pin (compacts the overlay once
        // every pre-mutation query drains).
        self.refresh_epoch_pin();
        self.fold_busy_into_metrics(&compute_busy, &exchange_busy, &fold_busy);
        self.metrics.wall_time += wall_start.elapsed().as_secs_f64();
        true
    }

    /// The PR 5 recycling cap extended to the flat layout (see
    /// [`FLAT_STAGED_RETAIN`]): after the exchange hands the drained flat
    /// staging columns back, record their retained footprint in the
    /// [`EngineMetrics::staging_bytes_peak`] high-water gauge, then trim
    /// every column above the retention cap — so one mega-fanout round
    /// cannot pin hub-sized scratch in every column forever. No-op (and a
    /// zero gauge) under [`Layout::Hashed`], which makes the gauge double
    /// as the flat-engagement signal the fuzzer's forcing leg asserts on.
    fn sweep_flat_staging(&mut self) {
        if self.layout == Layout::Hashed {
            return;
        }
        let mut retained: u64 = 0;
        for rt in self.inflight.iter_mut() {
            for shard in rt.shards.iter_mut() {
                for buf in shard.staged.iter_mut() {
                    if let StagedBuf::Flat(ord) = buf {
                        retained += ord.retained_bytes() as u64;
                        ord.shrink_to(FLAT_STAGED_RETAIN);
                    }
                }
            }
        }
        if retained > self.metrics.staging_bytes_peak {
            self.metrics.staging_bytes_peak = retained;
        }
    }

    /// Land a round's per-phase busy accumulators in the metrics fields.
    fn fold_busy_into_metrics(
        &mut self,
        compute_busy: &AtomicU64,
        exchange_busy: &AtomicU64,
        fold_busy: &AtomicU64,
    ) {
        self.metrics.compute_time += compute_busy.load(Ordering::Relaxed) as f64 * 1e-9;
        self.metrics.exchange_time += exchange_busy.load(Ordering::Relaxed) as f64 * 1e-9;
        self.metrics.barrier_time += fold_busy.load(Ordering::Relaxed) as f64 * 1e-9;
    }

    /// Run the reporting supersteps a pipelined round deferred, serially
    /// on the coordinator: the fallback for rounds that cannot pipeline,
    /// for the engine draining idle, and for [`Engine::take_results`].
    /// Results are pushed in pending (completion) order, so the result
    /// sequence is exactly what the barrier path would have produced.
    fn flush_pending_reports(&mut self) {
        if self.pending_reports.is_empty() {
            return;
        }
        let t0 = Instant::now();
        let app = &self.app;
        let results = &mut self.results;
        for rep in std::mem::take(&mut self.pending_reports) {
            let PendingReport { rt, out } = rep;
            let out = out.unwrap_or_else(|| {
                let mut iter = rt
                    .shards
                    .iter()
                    .flat_map(|s| s.store.touched_iter());
                app.finish(&rt.query, &mut iter, &rt.agg_prev)
            });
            results.push(QueryResult {
                qid: rt.id,
                out,
                stats: rt.stats,
            });
        }
        // Reporting is fold-phase work; it runs outside any round's wall
        // span here, so it extends wall time by the same amount.
        let dt = t0.elapsed().as_secs_f64();
        self.metrics.barrier_time += dt;
        self.metrics.wall_time += dt;
    }

    /// One ready-driven super-round (see the module docs and [`Pipeline`]):
    /// a single pool batch holding one step job per (running query, worker)
    /// plus the previous pipelined round's deferred reporting jobs. Fast
    /// queries drain through exchange and fold inside the batch — the last
    /// lane of each query to finish ships its staged columns and folds it —
    /// while slow lanes are still computing; nothing waits for the slowest
    /// query except its own lifecycle.
    ///
    /// Everything observable (outputs, per-query stats, the simulated
    /// clock, the cost-model metrics) is bit-identical to the barrier
    /// path: step jobs run the same `run_task`, delivery replays the same
    /// source-order [`deliver_into_sink`] sequence, folds stay per-query in
    /// worker order, and counters are integers folded in fixed order.
    fn pipelined_round(&mut self, wall_start: Instant, workers: usize) -> bool {
        let compute_busy = AtomicU64::new(0);
        let exchange_busy = AtomicU64::new(0);
        let fold_busy = AtomicU64::new(0);
        let msg_size = self.app.msg_bytes() + self.cluster.cost.msg_header_bytes;
        let mut reports = std::mem::take(&mut self.pending_reports);
        let shared = PipeShared {
            app: &self.app,
            cluster: &self.cluster,
            workers,
            msg_size,
            max_supersteps: self.max_supersteps,
            calls: (0..workers).map(|_| AtomicU64::new(0)).collect(),
            handled: (0..workers).map(|_| AtomicU64::new(0)).collect(),
            sent: (0..workers).map(|_| AtomicU64::new(0)).collect(),
            max_fan: AtomicU64::new(0),
            round_bytes: AtomicU64::new(0),
            compute_busy: &compute_busy,
            exchange_busy: &exchange_busy,
            fold_busy: &fold_busy,
            base: wall_start,
            intervals: Mutex::new(Vec::new()),
        };
        // One raw route per running query, collected in a single
        // `iter_mut` pass (re-indexing `inflight` between queries would
        // invalidate earlier pointers); every field pointer derives from
        // the query's own `rt_ptr` so jobs touch nothing else.
        let mut pipe_queries: Vec<PipeQuery<A>> = Vec::new();
        for rt in self.inflight.iter_mut() {
            if rt.phase != Phase::Running {
                continue;
            }
            let rt_ptr: *mut QueryRt<A> = rt;
            // SAFETY: `rt_ptr` is valid for the whole batch (the coordinator
            // blocks in `WorkerPool::run` and touches `inflight` only after
            // it returns); derived pointers are read per the discipline on
            // [`PipePtr`].
            unsafe {
                pipe_queries.push(PipeQuery {
                    rt: PipePtr(rt_ptr),
                    shards: PipePtr((*rt_ptr).shards.as_mut_ptr()),
                    query: PipePtr(std::ptr::addr_of_mut!((*rt_ptr).query)),
                    agg_prev: PipePtr(std::ptr::addr_of_mut!((*rt_ptr).agg_prev)),
                    qid: (*rt_ptr).id,
                    step: (*rt_ptr).step + 1,
                    remaining: AtomicUsize::new(workers),
                });
            }
        }
        let sh = &shared;
        let mut jobs: Vec<Job<'_>> =
            Vec::with_capacity(pipe_queries.len() * workers + reports.len());
        for pq in pipe_queries.iter() {
            for w in 0..workers {
                jobs.push(Box::new(move || pipe_task(sh, pq, w)));
            }
        }
        // Deferred reporting supersteps from the LAST pipelined round run
        // at the tail of this batch, overlapped with this round's compute.
        // Their stats were finalized the round they converged, so timing
        // is untouched; only `finish` still has to run.
        for rep in reports.iter_mut() {
            jobs.push(Box::new(move || {
                let t0 = Instant::now();
                let mut iter = rep
                    .rt
                    .shards
                    .iter()
                    .flat_map(|s| s.store.touched_iter());
                rep.out = Some(sh.app.finish(&rep.rt.query, &mut iter, &rep.rt.agg_prev));
                sh.record(PHASE_FOLD, t0, Instant::now());
            }));
        }
        let stats = self
            .pool
            .as_ref()
            .expect("pipelined gate requires a pool")
            .run(jobs);
        // The batch is heterogeneous (steps + reports); its scheduling
        // counters land on the compute ledger, which dominates it.
        self.metrics.compute_sched.add(stats.jobs, stats.steals);
        self.metrics.pipelined_rounds += 1;
        for rep in reports {
            let out = rep.out.expect("report job ran in this batch");
            self.results.push(QueryResult {
                qid: rep.rt.id,
                out,
                stats: rep.rt.stats,
            });
        }

        // --- Cost-model accounting, from the same integer counters the
        // barrier path sums per lane (fetch_add totals are associative, so
        // the floats derived here are bit-identical).
        let c1 = self.cluster.cost.per_vertex_compute_s;
        let c2 = self.cluster.cost.per_msg_overhead_s;
        let mut worker_cost = Vec::with_capacity(workers);
        let mut lane_load = Vec::with_capacity(workers);
        let mut round_msgs = 0u64;
        let mut total_compute_calls = 0u64;
        let mut max_unit_load = 0.0_f64;
        for w in 0..workers {
            let calls = shared.calls[w].load(Ordering::Relaxed);
            let handled = shared.handled[w].load(Ordering::Relaxed);
            let sent = shared.sent[w].load(Ordering::Relaxed);
            let cost = calls as f64 * c1 + handled as f64 * c2;
            worker_cost.push(cost);
            // Same imbalance basis as the barrier path; with no splitting
            // the schedulable unit IS the lane.
            let load = cost + sent as f64 * c2;
            max_unit_load = max_unit_load.max(load);
            lane_load.push(load);
            round_msgs += sent;
            total_compute_calls += calls;
        }
        let round_max_fan = shared.max_fan.load(Ordering::Relaxed);
        self.metrics.max_edge_task = self.metrics.max_edge_task.max(round_max_fan);
        self.seen_max_fan = self.seen_max_fan.max(round_max_fan);
        self.metrics.total_compute_calls += total_compute_calls;
        let max_load = lane_load.iter().copied().fold(0.0_f64, f64::max);
        let total_load: f64 = lane_load.iter().sum();
        if total_load > 0.0 {
            let ratio = max_load * lane_load.len() as f64 / total_load;
            self.last_compute_imbalance = ratio;
            if ratio > self.metrics.max_lane_imbalance {
                self.metrics.max_lane_imbalance = ratio;
            }
            let post_ratio = max_unit_load * lane_load.len() as f64 / total_load;
            if post_ratio > self.metrics.max_post_split_imbalance {
                self.metrics.max_post_split_imbalance = post_ratio;
            }
        }
        let round_bytes = shared.round_bytes.load(Ordering::Relaxed)
            + (self.inflight.len() * workers * std::mem::size_of::<A::Agg>()) as u64;

        // --- Advance the simulated clock (identical inputs → identical
        // `dt` → identical per-query `finished_at` stamps).
        let dt = self.cluster.super_round_time(&worker_cost, round_bytes as usize);
        self.clock += dt;
        self.metrics.super_rounds += 1;
        self.metrics.total_messages += round_msgs;
        self.metrics.total_bytes += round_bytes;
        self.metrics.sim_time = self.clock;
        // Deterministic saturation signal for the next round's admission
        // plan (the adaptive heavy-slice squeeze).
        self.last_round_messages = round_msgs;

        // --- Extract queries that converged this round, in `inflight`
        // order (the order the barrier path reports them). Their stats are
        // finalized NOW — completion timing is identical to the barrier
        // path, and capacity frees this round either way — but `finish`
        // is deferred into the next pipelined batch.
        let mut i = 0;
        while i < self.inflight.len() {
            if self.inflight[i].phase != Phase::Reporting {
                i += 1;
                continue;
            }
            let mut rt = self.inflight.remove(i);
            let touched = rt.touched();
            rt.stats.touched = touched;
            // Pinned-version |V|, as on the barrier path.
            rt.stats.access_rate = touched as f64 / rt.n_vertices.max(1) as f64;
            rt.stats.finished_at = self.clock;
            self.metrics.queries_completed += 1;
            self.metrics.latency.record(rt.stats.latency());
            self.metrics.queueing.record(rt.stats.queueing());
            self.pending_reports.push(PendingReport { rt, out: None });
        }

        drop(pipe_queries);
        self.sweep_flat_staging();
        // Extracted queries moved their pins into `pending_reports`
        // (counted by refresh), so retirement here is exactly as
        // conservative as the barrier path's.
        self.refresh_epoch_pin();
        self.metrics.overlap_time +=
            overlap_seconds(&shared.intervals.into_inner().expect("no poisoned batch"));
        self.fold_busy_into_metrics(&compute_busy, &exchange_busy, &fold_busy);
        self.metrics.wall_time += wall_start.elapsed().as_secs_f64();
        true
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const S: u64 = 1_000_000_000; // 1 second in the log's nanosecond axis

    #[test]
    fn overlap_requires_two_distinct_phases() {
        // Phases strictly one after another: no overlap.
        let log = [
            (PHASE_COMPUTE, 0, 2 * S),
            (PHASE_EXCHANGE, 2 * S, 3 * S),
            (PHASE_FOLD, 3 * S, 4 * S),
        ];
        assert_eq!(overlap_seconds(&log), 0.0);
        // Touching boundaries are not overlap (ends sort before starts).
        let log = [(PHASE_COMPUTE, 0, S), (PHASE_FOLD, S, 2 * S)];
        assert_eq!(overlap_seconds(&log), 0.0);
    }

    #[test]
    fn same_phase_concurrency_is_not_overlap() {
        // Four compute jobs running at once is parallelism, not phase
        // overlap — the union of one phase's intervals counts once.
        let log = [
            (PHASE_COMPUTE, 0, 2 * S),
            (PHASE_COMPUTE, 0, 2 * S),
            (PHASE_COMPUTE, S, 3 * S),
            (PHASE_COMPUTE, 0, 3 * S),
        ];
        assert_eq!(overlap_seconds(&log), 0.0);
    }

    #[test]
    fn overlap_measures_wall_with_two_phases_live() {
        // Compute [0, 10s), exchange [5s, 15s): 5 seconds of overlap.
        let log = [(PHASE_COMPUTE, 0, 10 * S), (PHASE_EXCHANGE, 5 * S, 15 * S)];
        let got = overlap_seconds(&log);
        assert!((got - 5.0).abs() < 1e-9, "got {got}");
        // A third phase inside the same window adds no extra overlap
        // (the sweep counts wall time with >= 2 live, not pair counts).
        let log = [
            (PHASE_COMPUTE, 0, 10 * S),
            (PHASE_EXCHANGE, 5 * S, 15 * S),
            (PHASE_FOLD, 6 * S, 9 * S),
        ];
        let got = overlap_seconds(&log);
        assert!((got - 5.0).abs() < 1e-9, "got {got}");
        // Fragmented same-phase intervals merge before the sweep.
        let log = [
            (PHASE_COMPUTE, 0, 4 * S),
            (PHASE_COMPUTE, 4 * S, 10 * S),
            (PHASE_FOLD, 8 * S, 12 * S),
        ];
        let got = overlap_seconds(&log);
        assert!((got - 2.0).abs() < 1e-9, "got {got}");
    }

    #[test]
    fn empty_and_zero_width_intervals_are_ignored() {
        assert_eq!(overlap_seconds(&[]), 0.0);
        let log = [(PHASE_COMPUTE, 0, 10 * S), (PHASE_EXCHANGE, 5 * S, 5 * S)];
        assert_eq!(overlap_seconds(&log), 0.0);
    }

    #[test]
    fn engine_config_round_trips_every_variant() {
        let cfgs = [
            EngineConfig::default(),
            EngineConfig {
                capacity: 17,
                threads: 3,
                sched: Sched::Static,
                split: Split::MaxTaskVertices(128),
                edge_split: EdgeSplit::MaxFanout(512),
                pipeline: Pipeline::On,
                layout: Layout::Hashed,
                admit: Admit::Static(5),
                queue_bound: Some(64),
                max_supersteps: 42,
            },
            EngineConfig {
                split: Split::Off,
                edge_split: EdgeSplit::Off,
                queue_bound: Some(1),
                ..EngineConfig::default()
            },
        ];
        for cfg in cfgs {
            let bytes = cfg.to_bytes();
            let got = EngineConfig::from_bytes(&bytes).expect("round trip");
            assert_eq!(got, cfg);
        }
    }

    #[test]
    fn engine_config_decode_rejects_garbage_without_panicking() {
        use crate::network::wire::WireError;
        let wire = EngineConfig {
            split: Split::MaxTaskVertices(128),
            admit: Admit::Static(4),
            queue_bound: Some(8),
            ..EngineConfig::default()
        }
        .to_bytes();
        // Every strict prefix fails cleanly.
        for cut in 0..wire.len() {
            assert!(
                EngineConfig::from_bytes(&wire[..cut]).is_err(),
                "prefix of {cut} bytes must fail"
            );
        }
        // Trailing bytes are rejected (the handshake frame is exactly one
        // config).
        let mut long = wire.clone();
        long.push(0);
        assert_eq!(
            EngineConfig::from_bytes(&long),
            Err(WireError::Corrupt("trailing bytes"))
        );
        // A wrong version byte is rejected before anything else is read.
        let mut vers = wire.clone();
        vers[0] ^= 0xFF;
        assert_eq!(
            EngineConfig::from_bytes(&vers),
            Err(WireError::Corrupt("engine-config version"))
        );
        // Single-byte corruption sweep: any verdict, never a panic.
        for i in 0..wire.len() {
            for flip in [0x01u8, 0x80, 0xFF] {
                let mut bad = wire.clone();
                bad[i] ^= flip;
                let _ = EngineConfig::from_bytes(&bad);
            }
        }
        // A structurally valid encoding of an invalid config (capacity 0)
        // is caught by the builder invariants at decode time.
        let mut zero_cap = EngineConfig::default();
        zero_cap.capacity = 1;
        let mut bytes = zero_cap.to_bytes();
        // capacity is the u64 right after the version byte
        bytes[1..9].copy_from_slice(&0u64.to_le_bytes());
        assert_eq!(
            EngineConfig::from_bytes(&bytes),
            Err(WireError::Corrupt("capacity must be > 0"))
        );
    }

    #[test]
    fn with_config_applies_knobs_verbatim() {
        use crate::apps::ppsp::VersionedBfs;
        use crate::graph::gen;
        let cfg = EngineConfig {
            capacity: 3,
            threads: 1,
            sched: Sched::Static,
            split: Split::Off,
            edge_split: EdgeSplit::Off,
            pipeline: Pipeline::Off,
            layout: Layout::Hashed,
            admit: Admit::Static(2),
            queue_bound: Some(4),
            max_supersteps: 7,
        };
        let g = gen::twitter_like(50, 3, 7101);
        let eng = Engine::with_config(VersionedBfs::new(g), Cluster::new(2), 50, cfg);
        assert_eq!(eng.capacity, 3);
        assert_eq!(eng.threads, 1);
        assert_eq!(eng.sched, Sched::Static);
        assert_eq!(eng.split, Split::Off);
        assert_eq!(eng.edge_split, EdgeSplit::Off);
        assert_eq!(eng.pipeline, Pipeline::Off);
        assert_eq!(eng.layout, Layout::Hashed);
        // No capacity re-sync: the static budget stays what cfg said.
        assert_eq!(eng.admit, Admit::Static(2));
        assert_eq!(eng.queue_bound, Some(4));
        assert_eq!(eng.max_supersteps, 7);
    }
}
