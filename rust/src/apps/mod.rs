//! The paper's five applications (§5), each written against the
//! query-centric [`crate::vertex::QueryApp`] interface:
//!
//! * [`ppsp`]    — point-to-point shortest paths: BFS, BiBFS, Hub² (§5.1)
//! * [`xml`]     — XML keyword search: SLCA / ELCA / MaxMatch (§5.2)
//! * [`terrain`] — terrain shortest-path queries (§5.3)
//! * [`reach`]   — P2P reachability with level/yes/no labels (§5.4)
//! * [`gkws`]    — graph (RDF) keyword search (§5.5)

pub mod gkws;
pub mod ppsp;
pub mod reach;
pub mod terrain;
pub mod xml;
