//! A small SAX-style XML parser producing an [`XmlTree`] (the paper parses
//! documents with a SAX parser at load time). Supports elements, self-
//! closing tags, text content, comments and XML declarations; attributes
//! are folded into the element's word set. Not a validating parser —
//! enough for corpora of the DBLP/XMark shape.

use super::data::{XmlTree, NO_PARENT};
use crate::bail;
use crate::util::error::Result;

/// Parse an XML document string into a tree.
pub fn parse(doc: &str) -> Result<XmlTree> {
    let mut t = XmlTree::default();
    let mut stack: Vec<u32> = Vec::new();
    let bytes = doc.as_bytes();
    let mut i = 0usize;

    let flush_text = |t: &mut XmlTree, stack: &[u32], text: &str| {
        let trimmed = text.trim();
        if trimmed.is_empty() {
            return;
        }
        let words: Vec<u32> = trimmed
            .split_whitespace()
            .map(|w| t.intern(&w.to_lowercase()))
            .collect();
        let parent = stack.last().copied().unwrap_or(NO_PARENT);
        t.add_vertex(parent, words);
    };

    while i < bytes.len() {
        if bytes[i] == b'<' {
            let close = doc[i..]
                .find('>')
                .map(|p| i + p)
                .ok_or_else(|| crate::err!("unterminated tag at byte {i}"))?;
            let tag = &doc[i + 1..close];
            if tag.starts_with("?") || tag.starts_with("!") {
                // declaration / comment / doctype: skip
            } else if let Some(name) = tag.strip_prefix('/') {
                let name = name.trim();
                let Some(top) = stack.pop() else {
                    bail!("unmatched closing tag </{name}>");
                };
                let _ = top;
            } else {
                let self_closing = tag.ends_with('/');
                let tag = tag.trim_end_matches('/').trim();
                let mut parts = tag.split_whitespace();
                let name = parts.next().unwrap_or_default().to_lowercase();
                if name.is_empty() {
                    bail!("empty tag name at byte {i}");
                }
                let mut words = vec![t.intern(&name)];
                // Attribute values contribute words too.
                for attr in parts {
                    if let Some((_, v)) = attr.split_once('=') {
                        let v = v.trim_matches(|c| c == '"' || c == '\'');
                        if !v.is_empty() {
                            words.push(t.intern(&v.to_lowercase()));
                        }
                    }
                }
                let parent = stack.last().copied().unwrap_or(NO_PARENT);
                let v = t.add_vertex(parent, words);
                if !self_closing {
                    stack.push(v);
                }
            }
            i = close + 1;
        } else {
            let next_tag = doc[i..].find('<').map(|p| i + p).unwrap_or(bytes.len());
            flush_text(&mut t, &stack, &doc[i..next_tag]);
            i = next_tag;
        }
    }
    if !stack.is_empty() {
        bail!("{} unclosed element(s)", stack.len());
    }
    t.assign_spans();
    t.build_inverted_index();
    Ok(t)
}

#[cfg(test)]
mod tests {
    use super::*;

    const DOC: &str = r#"<?xml version="1.0"?>
<lab>
  <member>
    <name>Tom</name>
    <interest>Graph Database</interest>
  </member>
  <member>
    <name>Peter</name>
  </member>
  <seminar topic="graph"/>
</lab>"#;

    #[test]
    fn parses_structure() {
        let t = parse(DOC).unwrap();
        // lab + 2 member + name + text + interest + text + name + text + seminar
        assert_eq!(t.parent[0], super::super::data::NO_PARENT);
        assert!(t.len() >= 9);
        assert_eq!(t.level[0], 0);
        // "tom" must be indexed
        let tom = t.vocab["tom"];
        assert_eq!(t.inverted[&tom].len(), 1);
    }

    #[test]
    fn attributes_indexed() {
        let t = parse(DOC).unwrap();
        let g = t.vocab["graph"];
        assert!(!t.inverted[&g].is_empty());
    }

    #[test]
    fn rejects_unbalanced() {
        assert!(parse("<a><b></a>").is_err() || parse("<a><b>").is_err());
    }

    #[test]
    fn self_closing_has_no_children() {
        let t = parse("<r><x/><y/></r>").unwrap();
        assert_eq!(t.children[0].len(), 2);
        assert!(t.children[1].is_empty());
    }

    #[test]
    fn roundtrip_with_generator_style_queries() {
        let t = parse(DOC).unwrap();
        let q = t.query_ids(&["tom", "graph"]).unwrap();
        let m = t.matching_vertices(&q);
        assert!(m.len() >= 2);
    }
}
