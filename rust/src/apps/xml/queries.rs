//! Distributed XML keyword query algorithms (paper §5.2.2): SLCA (naive and
//! level-aligned), ELCA, and MaxMatch, as `QueryApp`s over [`XmlTree`].
//!
//! Queries are sets of ≤ 32 keyword ids; per-vertex state carries the
//! subtree keyword bitmap `bm(v)`. Messages combine at the sender into a
//! single triple (OR of bitmaps, OR of non-all-one bitmaps, "some child was
//! all-one"), which is exactly the information SLCA/ELCA labeling needs.

use super::data::{XmlTree, NO_PARENT};
use crate::graph::VertexId;
use crate::vertex::{Ctx, MasterAction, QueryApp};

/// Query content: interned keyword ids (m ≤ 32).
pub type XmlQuery = Vec<u32>;

/// Labeled result vertex: (vertex, start, end) document span.
pub type SpanOut = Vec<(VertexId, u64, u64)>;

fn own_bits(t: &XmlTree, v: VertexId, q: &[u32]) -> u32 {
    let mut b = 0u32;
    for (i, k) in q.iter().enumerate() {
        if t.text[v as usize].contains(k) {
            b |= 1 << i;
        }
    }
    b
}

fn all_one(q: &[u32]) -> u32 {
    (1u32 << q.len()) - 1
}

// ---------------------------------------------------------------------------
// Naive SLCA: upward bitmap propagation, possibly multiple sends per vertex.
// ---------------------------------------------------------------------------

/// Vertex labels used by the SLCA algorithms.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SlcaLabel {
    Unlabeled,
    Slca,
    NonSlca,
}

/// VQ-data of the SLCA apps.
#[derive(Debug, Clone)]
pub struct SlcaState {
    pub bm: u32,
    pub label: SlcaLabel,
}

/// Combined upward message: (OR of bms, OR of non-all-one bms, any all-one).
#[derive(Debug, Clone, Copy, Default)]
pub struct UpMsg {
    pub or_all: u32,
    pub or_non_allone: u32,
    pub any_allone: bool,
}

impl UpMsg {
    fn new(bm: u32, allone_mask: u32) -> Self {
        Self {
            or_all: bm,
            or_non_allone: if bm == allone_mask { 0 } else { bm },
            any_allone: bm == allone_mask,
        }
    }
}

/// Naive SLCA (paper §5.2.2 "Computing SLCA in Quegel", first variant).
pub struct SlcaNaive<'t> {
    pub t: &'t XmlTree,
    /// Sender-side combining. Disabling it reproduces a combiner-less
    /// Pregel runtime, where the naive algorithm's repeated upward sends
    /// hit the network in full (the regime where the paper's level-aligned
    /// variant wins on DBLP).
    pub combiner: bool,
}

impl<'t> SlcaNaive<'t> {
    pub fn new(t: &'t XmlTree) -> Self {
        Self { t, combiner: true }
    }

    pub fn without_combiner(t: &'t XmlTree) -> Self {
        Self { t, combiner: false }
    }
}

impl<'t> QueryApp for SlcaNaive<'t> {
    type Query = XmlQuery;
    type VQ = SlcaState;
    type Msg = UpMsg;
    type Agg = ();
    type Out = SpanOut;

    fn init_activate(&self, q: &XmlQuery) -> Vec<VertexId> {
        self.t.matching_vertices(q)
    }

    fn init_value(&self, q: &XmlQuery, v: VertexId) -> SlcaState {
        SlcaState {
            bm: own_bits(self.t, v, q),
            label: SlcaLabel::Unlabeled,
        }
    }

    fn compute(&self, ctx: &mut Ctx<'_, Self>, v: VertexId, st: &mut SlcaState) {
        let q = ctx.query().clone();
        let ao = all_one(&q);
        let pa = self.t.parent[v as usize];
        if ctx.superstep() == 1 {
            // Matching vertices push their own bits upward.
            if st.bm == ao {
                // A single vertex covering every keyword is itself an SLCA
                // candidate (children may relabel it later).
                st.label = SlcaLabel::Slca;
            }
            if pa != NO_PARENT && st.bm != 0 {
                ctx.send(pa, UpMsg::new(st.bm, ao));
            }
            ctx.vote_halt();
            return;
        }
        let mut or_all = 0u32;
        let mut any_allone = false;
        for m in ctx.msgs() {
            or_all |= m.or_all;
            any_allone |= m.any_allone;
        }
        if st.bm != ao {
            // Case (a): bitmap still incomplete.
            let bm_or = st.bm | or_all;
            if bm_or != st.bm {
                st.bm = bm_or;
                if pa != NO_PARENT {
                    ctx.send(pa, UpMsg::new(st.bm, ao));
                }
            }
            if bm_or == ao {
                st.label = if any_allone {
                    SlcaLabel::NonSlca
                } else {
                    SlcaLabel::Slca
                };
            }
        } else {
            // Case (b): already all-one (labeled in an earlier superstep).
            if st.label == SlcaLabel::Slca && any_allone {
                st.label = SlcaLabel::NonSlca;
            }
        }
        ctx.vote_halt();
    }

    fn combine(&self, into: &mut UpMsg, from: &UpMsg) -> bool {
        if !self.combiner {
            return false;
        }
        into.or_all |= from.or_all;
        into.or_non_allone |= from.or_non_allone;
        into.any_allone |= from.any_allone;
        true
    }

    fn finish(
        &self,
        _q: &XmlQuery,
        touched: &mut dyn Iterator<Item = (VertexId, &SlcaState)>,
        _agg: &(),
    ) -> SpanOut {
        let mut out: SpanOut = Vec::new();
        for (v, st) in touched {
            if st.label == SlcaLabel::Slca {
                let (s, e) = self.t.span[v as usize];
                out.push((v, s, e));
            }
        }
        out.sort_unstable();
        out
    }

    fn msg_bytes(&self) -> usize {
        9
    }
}

// ---------------------------------------------------------------------------
// Level-aligned machinery shared by SLCA-LA, ELCA and MaxMatch.
// ---------------------------------------------------------------------------

/// Aggregator for level-aligned algorithms: the current ℓ_max countdown
/// plus the MaxMatch phase number.
#[derive(Debug, Clone, Copy)]
pub struct LevelAgg {
    pub lmax: i64,
    pub phase: u8,
}

impl Default for LevelAgg {
    fn default() -> Self {
        Self { lmax: -1, phase: 1 }
    }
}

/// Worker partials only ever raise `lmax` (step-1 level collection); fold
/// by max. `phase` is master-owned and identical across partials.
fn level_agg_merge(into: &mut LevelAgg, from: &LevelAgg) {
    into.lmax = into.lmax.max(from.lmax);
    into.phase = into.phase.max(from.phase);
}

fn level_master(step: u64, prev: &LevelAgg, cur: &mut LevelAgg) -> MasterAction {
    if step == 1 {
        // cur.lmax holds the max matching-vertex level collected this step.
        if cur.lmax < 0 {
            return MasterAction::Terminate; // no matches at all
        }
        cur.phase = 1;
        return MasterAction::Continue;
    }
    cur.lmax = prev.lmax - 1;
    cur.phase = prev.phase;
    if cur.lmax < 0 {
        return MasterAction::Terminate;
    }
    MasterAction::Continue
}

/// Level-aligned SLCA (paper's second variant: each vertex sends at most
/// one message, driven by the ℓ_max countdown aggregator).
pub struct SlcaLevelAligned<'t> {
    pub t: &'t XmlTree,
}

impl<'t> SlcaLevelAligned<'t> {
    pub fn new(t: &'t XmlTree) -> Self {
        Self { t }
    }
}

impl<'t> QueryApp for SlcaLevelAligned<'t> {
    type Query = XmlQuery;
    type VQ = SlcaState;
    type Msg = UpMsg;
    type Agg = LevelAgg;
    type Out = SpanOut;

    fn init_activate(&self, q: &XmlQuery) -> Vec<VertexId> {
        self.t.matching_vertices(q)
    }

    fn init_value(&self, q: &XmlQuery, v: VertexId) -> SlcaState {
        SlcaState {
            bm: own_bits(self.t, v, q),
            label: SlcaLabel::Unlabeled,
        }
    }

    fn compute(&self, ctx: &mut Ctx<'_, Self>, v: VertexId, st: &mut SlcaState) {
        let q = ctx.query().clone();
        let ao = all_one(&q);
        if ctx.superstep() == 1 {
            // Collection superstep: contribute ℓ(v), stay active.
            let lvl = self.t.level[v as usize] as i64;
            ctx.aggregate(|_, a| a.lmax = a.lmax.max(lvl));
            return; // no vote_halt: remain active until processed
        }
        let lmax = ctx.agg_prev().lmax;
        if self.t.level[v as usize] as i64 != lmax {
            return; // not our turn yet; stay active
        }
        let mut or_all = 0u32;
        let mut any_allone = false;
        for m in ctx.msgs() {
            or_all |= m.or_all;
            any_allone |= m.any_allone;
        }
        st.bm |= or_all;
        if any_allone {
            st.label = SlcaLabel::NonSlca;
        } else if st.bm == ao {
            st.label = SlcaLabel::Slca;
        }
        let pa = self.t.parent[v as usize];
        if pa != NO_PARENT && st.bm != 0 {
            ctx.send(pa, UpMsg::new(st.bm, ao));
        }
        ctx.vote_halt();
    }

    fn combine(&self, into: &mut UpMsg, from: &UpMsg) -> bool {
        into.or_all |= from.or_all;
        into.or_non_allone |= from.or_non_allone;
        into.any_allone |= from.any_allone;
        true
    }

    fn agg_merge(&self, into: &mut LevelAgg, from: &LevelAgg) {
        level_agg_merge(into, from);
    }

    fn master_step(
        &self,
        _q: &XmlQuery,
        step: u64,
        prev: &LevelAgg,
        cur: &mut LevelAgg,
    ) -> MasterAction {
        level_master(step, prev, cur)
    }

    fn finish(
        &self,
        _q: &XmlQuery,
        touched: &mut dyn Iterator<Item = (VertexId, &SlcaState)>,
        _agg: &LevelAgg,
    ) -> SpanOut {
        let mut out: SpanOut = Vec::new();
        for (v, st) in touched {
            if st.label == SlcaLabel::Slca {
                let (s, e) = self.t.span[v as usize];
                out.push((v, s, e));
            }
        }
        out.sort_unstable();
        out
    }

    fn msg_bytes(&self) -> usize {
        9
    }
}

// ---------------------------------------------------------------------------
// ELCA (level-aligned).
// ---------------------------------------------------------------------------

/// VQ-data for ELCA.
#[derive(Debug, Clone)]
pub struct ElcaState {
    pub bm: u32,
    pub elca: bool,
}

/// Level-aligned ELCA (paper §5.2.2 "Computing ELCA in Quegel").
pub struct Elca<'t> {
    pub t: &'t XmlTree,
}

impl<'t> Elca<'t> {
    pub fn new(t: &'t XmlTree) -> Self {
        Self { t }
    }
}

impl<'t> QueryApp for Elca<'t> {
    type Query = XmlQuery;
    type VQ = ElcaState;
    type Msg = UpMsg;
    type Agg = LevelAgg;
    type Out = SpanOut;

    fn init_activate(&self, q: &XmlQuery) -> Vec<VertexId> {
        self.t.matching_vertices(q)
    }

    fn init_value(&self, q: &XmlQuery, v: VertexId) -> ElcaState {
        ElcaState {
            bm: own_bits(self.t, v, q),
            elca: false,
        }
    }

    fn compute(&self, ctx: &mut Ctx<'_, Self>, v: VertexId, st: &mut ElcaState) {
        let q = ctx.query().clone();
        let ao = all_one(&q);
        if ctx.superstep() == 1 {
            let lvl = self.t.level[v as usize] as i64;
            ctx.aggregate(|_, a| a.lmax = a.lmax.max(lvl));
            return;
        }
        if self.t.level[v as usize] as i64 != ctx.agg_prev().lmax {
            return;
        }
        let mut or_all = 0u32;
        let mut or_non = 0u32;
        for m in ctx.msgs() {
            or_all |= m.or_all;
            or_non |= m.or_non_allone;
        }
        // bm*_OR: own bits (bm before update) + non-all-one child bitmaps.
        let star = st.bm | or_non;
        if star == ao {
            st.elca = true;
        }
        st.bm |= or_all;
        let pa = self.t.parent[v as usize];
        if pa != NO_PARENT && st.bm != 0 {
            ctx.send(pa, UpMsg::new(st.bm, ao));
        }
        ctx.vote_halt();
    }

    fn combine(&self, into: &mut UpMsg, from: &UpMsg) -> bool {
        into.or_all |= from.or_all;
        into.or_non_allone |= from.or_non_allone;
        into.any_allone |= from.any_allone;
        true
    }

    fn agg_merge(&self, into: &mut LevelAgg, from: &LevelAgg) {
        level_agg_merge(into, from);
    }

    fn master_step(
        &self,
        _q: &XmlQuery,
        step: u64,
        prev: &LevelAgg,
        cur: &mut LevelAgg,
    ) -> MasterAction {
        level_master(step, prev, cur)
    }

    fn finish(
        &self,
        _q: &XmlQuery,
        touched: &mut dyn Iterator<Item = (VertexId, &ElcaState)>,
        _agg: &LevelAgg,
    ) -> SpanOut {
        let mut out: SpanOut = Vec::new();
        for (v, st) in touched {
            if st.elca {
                let (s, e) = self.t.span[v as usize];
                out.push((v, s, e));
            }
        }
        out.sort_unstable();
        out
    }

    fn msg_bytes(&self) -> usize {
        9
    }
}

// ---------------------------------------------------------------------------
// MaxMatch (two-phase level-aligned).
// ---------------------------------------------------------------------------

/// MaxMatch message: upward (child id, bm) in phase 1 — NOT combined, the
/// parent needs per-child bitmaps — or a downward inclusion mark in phase 2.
#[derive(Debug, Clone, Copy)]
pub enum MmMsg {
    Up { child: VertexId, bm: u32 },
    Down,
}

/// VQ-data for MaxMatch.
#[derive(Debug, Clone, Default)]
pub struct MmState {
    pub bm: u32,
    /// Child bitmaps recorded when this vertex was processed in phase 1.
    pub child_bms: Vec<(VertexId, u32)>,
    pub slca: bool,
    pub in_tree: bool,
}

/// Two-phase MaxMatch (paper §5.2.2 "Computing MaxMatch in Quegel").
pub struct MaxMatch<'t> {
    pub t: &'t XmlTree,
}

impl<'t> MaxMatch<'t> {
    pub fn new(t: &'t XmlTree) -> Self {
        Self { t }
    }

    /// Children (of the recorded candidates) not strictly dominated by a
    /// sibling: K(u1) ⊂ K(u2) ⇔ bm1 != bm2 && (bm1 | bm2) == bm2.
    fn undominated(cands: &[(VertexId, u32)]) -> Vec<VertexId> {
        cands
            .iter()
            .filter(|&&(_, bm1)| {
                bm1 != 0
                    && !cands
                        .iter()
                        .any(|&(_, bm2)| bm1 != bm2 && (bm1 | bm2) == bm2)
            })
            .map(|&(c, _)| c)
            .collect()
    }
}

impl<'t> QueryApp for MaxMatch<'t> {
    type Query = XmlQuery;
    type VQ = MmState;
    type Msg = MmMsg;
    type Agg = LevelAgg;
    /// All vertices of the pruned matching trees.
    type Out = Vec<VertexId>;

    fn init_activate(&self, q: &XmlQuery) -> Vec<VertexId> {
        self.t.matching_vertices(q)
    }

    fn init_value(&self, q: &XmlQuery, v: VertexId) -> MmState {
        MmState {
            bm: own_bits(self.t, v, q),
            ..Default::default()
        }
    }

    fn compute(&self, ctx: &mut Ctx<'_, Self>, v: VertexId, st: &mut MmState) {
        let q = ctx.query().clone();
        let ao = all_one(&q);
        if ctx.superstep() == 1 {
            let lvl = self.t.level[v as usize] as i64;
            ctx.aggregate(|_, a| a.lmax = a.lmax.max(lvl));
            return;
        }
        let agg = *ctx.agg_prev();
        if agg.phase == 1 {
            // ---- Phase 1: level-aligned SLCA with per-child bitmaps.
            if self.t.level[v as usize] as i64 != agg.lmax {
                return; // stay active until our level
            }
            let mut any_allone = false;
            for m in ctx.msgs() {
                if let MmMsg::Up { child, bm } = *m {
                    st.child_bms.push((child, bm));
                    st.bm |= bm;
                    any_allone |= bm == ao;
                }
            }
            if !any_allone && st.bm == ao {
                st.slca = true;
            }
            // Always report upward (ancestors must see all-one children to
            // rule themselves out as SLCAs).
            let pa = self.t.parent[v as usize];
            if pa != NO_PARENT && st.bm != 0 {
                ctx.send(pa, MmMsg::Up { child: v, bm: st.bm });
            }
            if !st.slca {
                // SLCAs stay active so they can kick off phase 2.
                ctx.vote_halt();
            }
        } else {
            // ---- Phase 2: downward propagation from the SLCAs.
            let start = st.slca && !st.in_tree;
            let told = ctx.msgs().iter().any(|m| matches!(m, MmMsg::Down));
            if start || told {
                st.in_tree = true;
                for c in Self::undominated(&st.child_bms) {
                    ctx.send(c, MmMsg::Down);
                }
            }
            ctx.vote_halt();
        }
    }

    fn agg_merge(&self, into: &mut LevelAgg, from: &LevelAgg) {
        level_agg_merge(into, from);
    }

    fn master_step(
        &self,
        _q: &XmlQuery,
        step: u64,
        prev: &LevelAgg,
        cur: &mut LevelAgg,
    ) -> MasterAction {
        if step == 1 {
            if cur.lmax < 0 {
                return MasterAction::Terminate;
            }
            cur.phase = 1;
            return MasterAction::Continue;
        }
        cur.phase = prev.phase;
        if prev.phase == 1 {
            cur.lmax = prev.lmax - 1;
            if cur.lmax < 0 {
                // Root level processed: switch to downward phase.
                cur.phase = 2;
            }
            MasterAction::Continue
        } else {
            // Phase 2 runs until message flow dries up (engine quiescence).
            cur.lmax = prev.lmax;
            MasterAction::Continue
        }
    }

    fn finish(
        &self,
        _q: &XmlQuery,
        touched: &mut dyn Iterator<Item = (VertexId, &MmState)>,
        _agg: &LevelAgg,
    ) -> Vec<VertexId> {
        let mut out: Vec<VertexId> = Vec::new();
        for (v, st) in touched {
            if st.in_tree {
                out.push(v);
            }
        }
        out.sort_unstable();
        out
    }

    fn msg_bytes(&self) -> usize {
        8
    }
}

#[cfg(test)]
mod tests {
    use super::super::data::{generate, query_pool, XmlGenConfig};
    use super::super::oracle;
    use super::*;
    use crate::coordinator::Engine;
    use crate::network::Cluster;

    fn corpus(dblp: bool, seed: u64) -> XmlTree {
        generate(&XmlGenConfig {
            dblp_like: dblp,
            records: 120,
            vocab: 150,
            seed,
        })
    }

    fn run_spans<A: QueryApp<Query = XmlQuery, Out = SpanOut>>(
        app: A,
        n: usize,
        q: &XmlQuery,
    ) -> Vec<VertexId> {
        let mut eng = Engine::new(app, Cluster::new(4), n);
        eng.run_one(q.clone()).out.into_iter().map(|(v, _, _)| v).collect()
    }

    #[test]
    fn slca_naive_matches_oracle() {
        for (dblp, seed) in [(true, 5), (false, 6)] {
            let t = corpus(dblp, seed);
            for q in query_pool(&t, 15, 2, seed + 10) {
                let want = oracle::slca(&t, &q);
                let got = run_spans(SlcaNaive::new(&t), t.len(), &q);
                assert_eq!(got, want, "dblp={dblp} q={q:?}");
            }
        }
    }

    #[test]
    fn slca_level_aligned_matches_oracle() {
        for (dblp, seed) in [(true, 7), (false, 8)] {
            let t = corpus(dblp, seed);
            for q in query_pool(&t, 15, 3, seed + 10) {
                let want = oracle::slca(&t, &q);
                let got = run_spans(SlcaLevelAligned::new(&t), t.len(), &q);
                assert_eq!(got, want, "dblp={dblp} q={q:?}");
            }
        }
    }

    #[test]
    fn elca_matches_oracle() {
        for (dblp, seed) in [(true, 9), (false, 10)] {
            let t = corpus(dblp, seed);
            for q in query_pool(&t, 15, 2, seed + 10) {
                let want = oracle::elca(&t, &q);
                let got = run_spans(Elca::new(&t), t.len(), &q);
                assert_eq!(got, want, "dblp={dblp} q={q:?}");
            }
        }
    }

    #[test]
    fn maxmatch_matches_oracle() {
        for (dblp, seed) in [(true, 11), (false, 12)] {
            let t = corpus(dblp, seed);
            for q in query_pool(&t, 10, 2, seed + 10) {
                let want = oracle::maxmatch(&t, &q);
                let mut eng = Engine::new(MaxMatch::new(&t), Cluster::new(4), t.len());
                let got = eng.run_one(q.clone()).out;
                assert_eq!(got, want, "dblp={dblp} q={q:?}");
            }
        }
    }

    #[test]
    fn empty_query_result_when_keyword_missing() {
        let t = corpus(true, 13);
        // An id beyond the vocabulary matches nothing.
        let q = vec![u32::MAX - 1];
        let got = run_spans(SlcaNaive::new(&t), t.len(), &q);
        assert!(got.is_empty());
        let got = run_spans(SlcaLevelAligned::new(&t), t.len(), &q);
        assert!(got.is_empty());
    }

    #[test]
    fn access_rate_is_fractional() {
        // The paper's Table 8 shows sub-1% access on DBLP: queries must not
        // touch the whole tree.
        let t = corpus(true, 14);
        let q = &query_pool(&t, 1, 2, 15)[0];
        let mut eng = Engine::new(SlcaLevelAligned::new(&t), Cluster::new(4), t.len());
        let r = eng.run_one(q.clone());
        assert!(
            r.stats.access_rate < 0.5,
            "access rate {} too high",
            r.stats.access_rate
        );
    }
}
