//! XML tree substrate: the in-memory document model, the per-worker
//! inverted keyword index (paper §4 `load2Idx`), and deterministic
//! generators for DBLP-like and XMark-like corpora (DESIGN.md §5).

use crate::graph::VertexId;
use crate::util::{FxHashMap, Rng};

/// Sentinel parent id for the root.
pub const NO_PARENT: VertexId = VertexId::MAX;

/// An XML document as a rooted tree (paper Fig. 3): internal vertices are
/// tags, leaves are text; `ψ(v)` is the set of interned word ids of v's tag
/// or text.
#[derive(Debug, Default)]
pub struct XmlTree {
    /// pa(v); NO_PARENT for the root.
    pub parent: Vec<VertexId>,
    /// Γ_c(v).
    pub children: Vec<Vec<VertexId>>,
    /// ψ(v): interned word ids.
    pub text: Vec<Vec<u32>>,
    /// ℓ(v): depth from the root (root = 0). The paper computes this with
    /// a separate Pregel BFS job; the builder records it at construction
    /// and `recompute_levels` re-derives it for loaded documents.
    pub level: Vec<u32>,
    /// [start(v), end(v)] positions in the serialized document.
    pub span: Vec<(u64, u64)>,
    /// word string -> word id.
    pub vocab: FxHashMap<String, u32>,
    /// word id -> word string.
    pub words: Vec<String>,
    /// Inverted index: word id -> matching vertices (built by `load2Idx`).
    pub inverted: FxHashMap<u32, Vec<VertexId>>,
}

impl XmlTree {
    /// Number of vertices.
    pub fn len(&self) -> usize {
        self.parent.len()
    }

    /// True if the tree is empty.
    pub fn is_empty(&self) -> bool {
        self.parent.is_empty()
    }

    /// Intern a word.
    pub fn intern(&mut self, w: &str) -> u32 {
        if let Some(&id) = self.vocab.get(w) {
            return id;
        }
        let id = self.words.len() as u32;
        self.vocab.insert(w.to_string(), id);
        self.words.push(w.to_string());
        id
    }

    /// Add a vertex with the given parent (NO_PARENT for root) and text.
    pub fn add_vertex(&mut self, parent: VertexId, words: Vec<u32>) -> VertexId {
        let v = self.parent.len() as VertexId;
        self.parent.push(parent);
        self.children.push(Vec::new());
        self.text.push(words);
        let lvl = if parent == NO_PARENT {
            0
        } else {
            self.level[parent as usize] + 1
        };
        self.level.push(lvl);
        self.span.push((0, 0));
        if parent != NO_PARENT {
            self.children[parent as usize].push(v);
        }
        v
    }

    /// Recompute ℓ(v) by BFS from the root (for documents loaded from
    /// external sources where construction order is unknown).
    pub fn recompute_levels(&mut self) {
        let n = self.len();
        self.level = vec![0; n];
        let roots: Vec<VertexId> = (0..n as VertexId)
            .filter(|&v| self.parent[v as usize] == NO_PARENT)
            .collect();
        let mut frontier = roots;
        let mut lvl = 0;
        while !frontier.is_empty() {
            let mut next = Vec::new();
            for &v in &frontier {
                self.level[v as usize] = lvl;
                next.extend_from_slice(&self.children[v as usize]);
            }
            frontier = next;
            lvl += 1;
        }
    }

    /// Assign [start, end] spans by DFS (document order).
    pub fn assign_spans(&mut self) {
        let n = self.len();
        self.span = vec![(0, 0); n];
        let mut counter: u64 = 0;
        // Iterative DFS over all roots.
        let roots: Vec<VertexId> = (0..n as VertexId)
            .filter(|&v| self.parent[v as usize] == NO_PARENT)
            .collect();
        for root in roots {
            // (vertex, child_index)
            let mut stack: Vec<(VertexId, usize)> = vec![(root, 0)];
            self.span[root as usize].0 = counter;
            counter += 1;
            while let Some(&mut (v, ref mut ci)) = stack.last_mut() {
                if *ci < self.children[v as usize].len() {
                    let c = self.children[v as usize][*ci];
                    *ci += 1;
                    self.span[c as usize].0 = counter;
                    counter += 1;
                    stack.push((c, 0));
                } else {
                    self.span[v as usize].1 = counter;
                    counter += 1;
                    stack.pop();
                }
            }
        }
    }

    /// Build the inverted keyword index (the `load2Idx` UDF of paper §4:
    /// called once per vertex right after loading).
    pub fn build_inverted_index(&mut self) {
        self.inverted.clear();
        for v in 0..self.len() as VertexId {
            for &w in &self.text[v as usize] {
                self.inverted.entry(w).or_default().push(v);
            }
        }
    }

    /// Vertices matching any of the query word ids (the init_activate set).
    pub fn matching_vertices(&self, words: &[u32]) -> Vec<VertexId> {
        let mut out = Vec::new();
        for &w in words {
            if let Some(vs) = self.inverted.get(&w) {
                out.extend_from_slice(vs);
            }
        }
        out.sort_unstable();
        out.dedup();
        out
    }

    /// Look up word ids for string keywords (None if any is unknown).
    pub fn query_ids(&self, keywords: &[&str]) -> Option<Vec<u32>> {
        keywords
            .iter()
            .map(|k| self.vocab.get(*k).copied())
            .collect()
    }

    /// Maximum fan-out (used by tests to characterize DBLP vs XMark shape).
    pub fn max_fanout(&self) -> usize {
        self.children.iter().map(Vec::len).max().unwrap_or(0)
    }

    /// Approximate serialized size in bytes (for load-cost modeling).
    pub fn footprint_bytes(&self) -> usize {
        self.len() * 24 + self.text.iter().map(|t| t.len() * 4).sum::<usize>()
    }
}

/// Generator configuration for synthetic corpora.
#[derive(Debug, Clone)]
pub struct XmlGenConfig {
    /// Corpus shape: true = DBLP-like (shallow, huge fan-out at level 1),
    /// false = XMark-like (deeper nesting, small fan-outs).
    pub dblp_like: bool,
    /// Number of top-level records (articles / auction items).
    pub records: usize,
    /// Vocabulary size.
    pub vocab: usize,
    pub seed: u64,
}

/// Generate a synthetic corpus per the config.
pub fn generate(cfg: &XmlGenConfig) -> XmlTree {
    let mut rng = Rng::new(cfg.seed);
    let mut t = XmlTree::default();
    // Pre-intern the vocabulary: w0..wN, Zipf-sampled in text.
    let word_ids: Vec<u32> = (0..cfg.vocab)
        .map(|i| t.intern(&format!("w{i}")))
        .collect();
    let sample_words = |rng: &mut Rng, t: &mut XmlTree, count: usize| -> Vec<u32> {
        let _ = t;
        (0..count)
            .map(|_| word_ids[rng.zipf(word_ids.len(), 1.1)])
            .collect()
    };

    if cfg.dblp_like {
        // dblp root with `records` article children: high level-1 fan-out.
        let root_w = t.intern("dblp");
        let root = t.add_vertex(NO_PARENT, vec![root_w]);
        let article_w = t.intern("article");
        let title_w = t.intern("title");
        let author_w = t.intern("author");
        let year_w = t.intern("year");
        let crossref_w = t.intern("crossref");
        let booktitle_w = t.intern("booktitle");
        for _ in 0..cfg.records {
            let art = t.add_vertex(root, vec![article_w]);
            let title = t.add_vertex(art, vec![title_w]);
            let c = 3 + rng.below_usize(5);
            let tw = sample_words(&mut rng, &mut t, c);
            t.add_vertex(title, tw);
            for _ in 0..1 + rng.below_usize(3) {
                let au = t.add_vertex(art, vec![author_w]);
                let aw = sample_words(&mut rng, &mut t, 2);
                t.add_vertex(au, aw);
            }
            let yr = t.add_vertex(art, vec![year_w]);
            let yw = sample_words(&mut rng, &mut t, 1);
            t.add_vertex(yr, yw);
            // Some records nest deeper (proceedings crossrefs): matching
            // leaves then sit at mixed depths, which is what makes the
            // naive SLCA algorithm re-send bitmaps upward (paper §5.2.2).
            if rng.chance(0.3) {
                let cr = t.add_vertex(art, vec![crossref_w]);
                let bt = t.add_vertex(cr, vec![booktitle_w]);
                let c = 2 + rng.below_usize(3);
                let bw = sample_words(&mut rng, &mut t, c);
                t.add_vertex(bt, bw);
            }
        }
    } else {
        // XMark-like: site -> 6 sections -> items -> nested descriptions.
        let site_w = t.intern("site");
        let root = t.add_vertex(NO_PARENT, vec![site_w]);
        let sections = [
            "regions",
            "categories",
            "catgraph",
            "people",
            "open_auctions",
            "closed_auctions",
        ];
        let per_section = cfg.records / sections.len();
        for sec in sections {
            let sw = t.intern(sec);
            let s = t.add_vertex(root, vec![sw]);
            let item_w = t.intern("item");
            for _ in 0..per_section {
                let item = t.add_vertex(s, vec![item_w]);
                // Nested chain: description -> parlist -> listitem -> text,
                // depth 3..6, fan-out 1..3.
                let mut cur = item;
                let depth = 3 + rng.below_usize(4);
                for d in 0..depth {
                    let tag =
                        t.intern(["description", "parlist", "listitem", "text", "bold"][d % 5]);
                    let nxt = t.add_vertex(cur, vec![tag]);
                    // Occasionally a sibling text leaf.
                    if rng.chance(0.5) {
                        let c = 2 + rng.below_usize(4);
                        let ws = sample_words(&mut rng, &mut t, c);
                        t.add_vertex(cur, ws);
                    }
                    cur = nxt;
                }
                let c = 3 + rng.below_usize(6);
                let ws = sample_words(&mut rng, &mut t, c);
                t.add_vertex(cur, ws);
            }
        }
    }
    t.assign_spans();
    t.build_inverted_index();
    t
}

/// Build a deterministic query pool of `count` queries with `m` keywords
/// each, drawn from the moderately-frequent band of the vocabulary so that
/// queries are selective but non-empty (paper: pools from prior work).
pub fn query_pool(t: &XmlTree, count: usize, m: usize, seed: u64) -> Vec<Vec<u32>> {
    let mut rng = Rng::new(seed);
    // Rank words by document frequency.
    let mut freq: Vec<(u32, usize)> = t
        .inverted
        .iter()
        .map(|(&w, vs)| (w, vs.len()))
        .collect();
    freq.sort_by_key(|&(w, c)| (std::cmp::Reverse(c), w));
    // Moderately frequent band: skip the few stop-word-ish top tags, keep
    // the next slice.
    let lo = freq.len().min(5);
    let hi = freq.len().min(lo + 200.max(freq.len() / 4));
    let band: Vec<u32> = freq[lo..hi].iter().map(|&(w, _)| w).collect();
    assert!(band.len() >= m, "vocabulary too small for query pool");
    (0..count)
        .map(|_| {
            let mut q = Vec::with_capacity(m);
            while q.len() < m {
                let w = band[rng.below_usize(band.len())];
                if !q.contains(&w) {
                    q.push(w);
                }
            }
            q
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn dblp_small() -> XmlTree {
        generate(&XmlGenConfig {
            dblp_like: true,
            records: 200,
            vocab: 300,
            seed: 1,
        })
    }

    #[test]
    fn dblp_shape() {
        let t = dblp_small();
        assert!(t.len() > 1000);
        // High fan-out at the root (level 1 articles).
        assert!(t.children[0].len() == 200);
        assert_eq!(t.level[0], 0);
    }

    #[test]
    fn xmark_shape_is_deeper() {
        let x = generate(&XmlGenConfig {
            dblp_like: false,
            records: 120,
            vocab: 300,
            seed: 2,
        });
        let d = dblp_small();
        let max_lvl_x = *x.level.iter().max().unwrap();
        let max_lvl_d = *d.level.iter().max().unwrap();
        assert!(
            max_lvl_x > max_lvl_d,
            "xmark depth {max_lvl_x} !> dblp depth {max_lvl_d}"
        );
        assert!(x.max_fanout() < d.max_fanout());
    }

    #[test]
    fn spans_nest_properly() {
        let t = dblp_small();
        for v in 0..t.len() as VertexId {
            let (s, e) = t.span[v as usize];
            assert!(s < e);
            let p = t.parent[v as usize];
            if p != NO_PARENT {
                let (ps, pe) = t.span[p as usize];
                assert!(ps < s && e < pe, "child span must nest inside parent");
            }
        }
    }

    #[test]
    fn inverted_index_finds_matches() {
        let t = dblp_small();
        for (&w, vs) in t.inverted.iter().take(20) {
            for &v in vs {
                assert!(t.text[v as usize].contains(&w));
            }
        }
        let m = t.matching_vertices(&[t.vocab["article"]]);
        assert_eq!(m.len(), 200);
    }

    #[test]
    fn query_pool_nonempty_matches() {
        let t = dblp_small();
        for q in query_pool(&t, 50, 2, 3) {
            assert_eq!(q.len(), 2);
            for &w in &q {
                assert!(!t.inverted[&w].is_empty());
            }
        }
    }

    #[test]
    fn recompute_levels_matches_builder() {
        let mut t = dblp_small();
        let want = t.level.clone();
        t.recompute_levels();
        assert_eq!(t.level, want);
    }
}
