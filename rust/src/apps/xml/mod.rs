//! XML keyword search (paper §5.2): SLCA, ELCA and MaxMatch semantics over
//! XML trees, with a per-worker inverted index built at load time.

pub mod data;
pub mod parser;
pub mod queries;

pub use data::{XmlGenConfig, XmlTree};
pub use queries::{Elca, MaxMatch, SlcaLevelAligned, SlcaNaive, XmlQuery};
pub mod oracle;
