//! Serial oracles for SLCA / ELCA / MaxMatch (used by tests and benches to
//! validate the distributed algorithms).

use super::data::{XmlTree, NO_PARENT};
use crate::graph::VertexId;

/// Subtree keyword bitmaps: bm[v] has bit i set iff keyword i occurs in T_v.
pub fn subtree_bitmaps(t: &XmlTree, q: &[u32]) -> Vec<u32> {
    let n = t.len();
    let mut bm = vec![0u32; n];
    for (v, slot) in bm.iter_mut().enumerate() {
        for (i, &k) in q.iter().enumerate() {
            if t.text[v].contains(&k) {
                *slot |= 1 << i;
            }
        }
    }
    // Children have larger ids than parents in generated trees, but loaded
    // documents may not be ordered: process by decreasing level.
    let mut order: Vec<VertexId> = (0..n as VertexId).collect();
    order.sort_by_key(|&v| std::cmp::Reverse(t.level[v as usize]));
    for &v in &order {
        let p = t.parent[v as usize];
        if p != NO_PARENT {
            let b = bm[v as usize];
            bm[p as usize] |= b;
        }
    }
    bm
}

/// All SLCAs of `q`: vertices whose subtree covers all keywords and no
/// child subtree does.
pub fn slca(t: &XmlTree, q: &[u32]) -> Vec<VertexId> {
    let all = (1u32 << q.len()) - 1;
    let bm = subtree_bitmaps(t, q);
    let mut out: Vec<VertexId> = (0..t.len() as VertexId)
        .filter(|&v| {
            bm[v as usize] == all
                && !t.children[v as usize]
                    .iter()
                    .any(|&c| bm[c as usize] == all)
        })
        .collect();
    out.sort_unstable();
    out
}

/// All ELCAs of `q`: vertices v whose own text plus non-all-one child
/// subtrees still cover all keywords.
pub fn elca(t: &XmlTree, q: &[u32]) -> Vec<VertexId> {
    let all = (1u32 << q.len()) - 1;
    let bm = subtree_bitmaps(t, q);
    let mut out = Vec::new();
    for v in 0..t.len() as VertexId {
        let mut star = 0u32;
        for (i, &k) in q.iter().enumerate() {
            if t.text[v as usize].contains(&k) {
                star |= 1 << i;
            }
        }
        for &c in &t.children[v as usize] {
            if bm[c as usize] != all {
                star |= bm[c as usize];
            }
        }
        if star == all {
            out.push(v);
        }
    }
    out.sort_unstable();
    out
}

/// MaxMatch result: the union of pruned matching trees rooted at each SLCA.
pub fn maxmatch(t: &XmlTree, q: &[u32]) -> Vec<VertexId> {
    let bm = subtree_bitmaps(t, q);
    let mut included = Vec::new();
    for r in slca(t, q) {
        let mut stack = vec![r];
        while let Some(v) = stack.pop() {
            included.push(v);
            // Candidate children: those whose subtree matches something.
            let cands: Vec<VertexId> = t.children[v as usize]
                .iter()
                .copied()
                .filter(|&c| bm[c as usize] != 0)
                .collect();
            for &c in &cands {
                let dominated = cands.iter().any(|&o| {
                    bm[c as usize] != bm[o as usize]
                        && (bm[c as usize] | bm[o as usize]) == bm[o as usize]
                });
                if !dominated {
                    stack.push(c);
                }
            }
        }
    }
    included.sort_unstable();
    included.dedup();
    included
}

#[cfg(test)]
mod tests {
    use super::super::parser::parse;
    use super::*;

    /// The paper's Figure 3 example document.
    const LAB: &str = r#"<lab>
      <name>Infolab</name>
      <members>
        <member>
          <name>Tom</name>
          <interest>Graph Database</interest>
        </member>
        <member>
          <name>Jack</name>
        </member>
      </members>
      <projects>Web Data</projects>
    </lab>"#;

    #[test]
    fn figure3_semantics() {
        let t = parse(LAB).unwrap();
        let q = t.query_ids(&["tom", "graph"]).unwrap();
        let s = slca(&t, &q);
        // The member element containing both Tom and Graph.
        assert_eq!(s.len(), 1);
        // ELCA includes the same member; the root is NOT an ELCA (its only
        // coverage comes through the all-one member subtree).
        let e = elca(&t, &q);
        assert!(e.contains(&s[0]));
        assert!(!e.contains(&0));
    }

    #[test]
    fn elca_includes_root_with_split_coverage() {
        // Root sees "tom" from one child and "graph" from another child
        // whose subtree is not all-one, plus a member covering both.
        let doc = r#"<lab><a>Tom</a><b>Graph</b><m><x>Tom</x><y>Graph</y></m></lab>"#;
        let t = parse(doc).unwrap();
        let q = t.query_ids(&["tom", "graph"]).unwrap();
        let e = elca(&t, &q);
        assert!(e.contains(&0), "root is an ELCA via a+b coverage");
        let s = slca(&t, &q);
        assert!(!s.contains(&0), "root is not an SLCA (m is lower)");
    }

    #[test]
    fn maxmatch_prunes_dominated_siblings() {
        // SLCA is the root r (no child subtree covers all three keywords);
        // sibling c3 = {tom} is strictly dominated by c1 = {tom, graph}.
        let doc = r#"<r><c1>Tom Graph</c1><c2>Db</c2><c3>Tom</c3></r>"#;
        let t = parse(doc).unwrap();
        let q = t.query_ids(&["tom", "graph", "db"]).unwrap();
        assert_eq!(slca(&t, &q), vec![0], "root must be the only SLCA");
        let mm = maxmatch(&t, &q);
        let c3 = t.inverted[&t.vocab["c3"]][0];
        assert!(!mm.contains(&c3), "dominated sibling c3 must be pruned");
        assert!(mm.contains(&0), "SLCA root included");
        let c1 = t.inverted[&t.vocab["c1"]][0];
        let c2 = t.inverted[&t.vocab["c2"]][0];
        assert!(mm.contains(&c1) && mm.contains(&c2));
    }
}
