//! The ε-shortcut network transform (paper §5.3, Figure 4b).
//!
//! Each DEM cell edge is split so that consecutive vertices are ≤ ε apart,
//! and within each cell a straight (3D) shortcut edge is added between
//! every pair of boundary vertices that do not lie on the same horizontal
//! or vertical edge line. Shortcuts point in many directions, so the
//! network shortest path tracks the true terrain shortest path much better
//! than the TIN's axis/diagonal edges (the paper's Manhattan-lower-bound
//! argument).

use super::dem::Dem;
use crate::graph::{Graph, GraphBuilder, VertexId};

/// The transformed terrain network: weighted graph + 3D vertex coordinates.
pub struct TerrainNet {
    pub graph: Graph,
    /// (x, y, z) meters per vertex.
    pub coords: Vec<(f64, f64, f64)>,
    /// Grid corner (x, y) -> vertex id (for picking query endpoints).
    width: usize,
    height: usize,
}

impl TerrainNet {
    /// Vertex id of grid corner (x, y).
    pub fn corner(&self, x: usize, y: usize) -> VertexId {
        debug_assert!(x < self.width && y < self.height);
        (y * self.width + x) as VertexId
    }

    /// Euclidean (3D straight-line) distance between two vertices.
    pub fn euclid(&self, a: VertexId, b: VertexId) -> f64 {
        let (ax, ay, az) = self.coords[a as usize];
        let (bx, by, bz) = self.coords[b as usize];
        ((ax - bx).powi(2) + (ay - by).powi(2) + (az - bz).powi(2)).sqrt()
    }

    /// Build the ε-network from a DEM.
    pub fn build(dem: &Dem, eps: f64) -> Self {
        let (w, h, s) = (dem.width, dem.height, dem.spacing);
        // Interior split points per cell edge.
        let m = ((s / eps).ceil() as usize).saturating_sub(1);
        let corner_count = w * h;
        let hedge_count = (w - 1) * h; // horizontal edges
        let vedge_count = w * (h - 1); // vertical edges
        let n = corner_count + (hedge_count + vedge_count) * m;
        let mut coords = Vec::with_capacity(n);

        // Corners.
        for y in 0..h {
            for x in 0..w {
                coords.push((x as f64 * s, y as f64 * s, dem.at(x, y)));
            }
        }
        // Horizontal edge interiors: edge e = (x,y)->(x+1,y), points k=1..m.
        let hbase = corner_count;
        for y in 0..h {
            for x in 0..w - 1 {
                for k in 1..=m {
                    let fx = x as f64 + k as f64 / (m + 1) as f64;
                    coords.push((fx * s, y as f64 * s, dem.sample(fx, y as f64)));
                }
            }
        }
        // Vertical edge interiors.
        let vbase = hbase + hedge_count * m;
        for y in 0..h - 1 {
            for x in 0..w {
                for k in 1..=m {
                    let fy = y as f64 + k as f64 / (m + 1) as f64;
                    coords.push((x as f64 * s, fy * s, dem.sample(x as f64, fy)));
                }
            }
        }
        assert_eq!(coords.len(), n);

        let hpt = |x: usize, y: usize, k: usize| -> usize {
            debug_assert!(k >= 1 && k <= m);
            hbase + (y * (w - 1) + x) * m + (k - 1)
        };
        let vpt = |x: usize, y: usize, k: usize| -> usize {
            debug_assert!(k >= 1 && k <= m);
            vbase + (y * w + x) * m + (k - 1)
        };

        let dist = |a: usize, b: usize| -> f32 {
            let (ax, ay, az) = coords[a];
            let (bx, by, bz) = coords[b];
            (((ax - bx).powi(2) + (ay - by).powi(2) + (az - bz).powi(2)).sqrt()) as f32
        };

        let mut b = GraphBuilder::new(n).undirected();

        // Split-edge segments along every grid edge.
        for y in 0..h {
            for x in 0..w - 1 {
                let mut prev = y * w + x;
                for k in 1..=m {
                    let p = hpt(x, y, k);
                    b.wedge(prev as VertexId, p as VertexId, dist(prev, p));
                    prev = p;
                }
                let end = y * w + x + 1;
                b.wedge(prev as VertexId, end as VertexId, dist(prev, end));
            }
        }
        for y in 0..h - 1 {
            for x in 0..w {
                let mut prev = y * w + x;
                for k in 1..=m {
                    let p = vpt(x, y, k);
                    b.wedge(prev as VertexId, p as VertexId, dist(prev, p));
                    prev = p;
                }
                let end = (y + 1) * w + x;
                b.wedge(prev as VertexId, end as VertexId, dist(prev, end));
            }
        }

        // Cell shortcuts: boundary vertices grouped by which edge *line*
        // they lie on; pairs on different lines get a straight-line edge.
        // Group ids: 0 = bottom h-line, 1 = top h-line, 2 = left v-line,
        // 3 = right v-line. Corners belong to one h-line and one v-line.
        for y in 0..h - 1 {
            for x in 0..w - 1 {
                // (vertex, h-group or -1, v-group or -1)
                let mut boundary: Vec<(usize, i8, i8)> = Vec::with_capacity(4 * (m + 1));
                boundary.push((y * w + x, 0, 2)); // bottom-left
                boundary.push((y * w + x + 1, 0, 3)); // bottom-right
                boundary.push(((y + 1) * w + x, 1, 2)); // top-left
                boundary.push(((y + 1) * w + x + 1, 1, 3)); // top-right
                for k in 1..=m {
                    boundary.push((hpt(x, y, k), 0, -1));
                    boundary.push((hpt(x, y + 1, k), 1, -1));
                    boundary.push((vpt(x, y, k), -1, 2));
                    boundary.push((vpt(x + 1, y, k), -1, 3));
                }
                for i in 0..boundary.len() {
                    for j in i + 1..boundary.len() {
                        let (a, ha, va) = boundary[i];
                        let (c, hb, vb) = boundary[j];
                        let same_h = ha >= 0 && ha == hb;
                        let same_v = va >= 0 && va == vb;
                        if same_h || same_v {
                            continue; // same edge line: already linked
                        }
                        b.wedge(a as VertexId, c as VertexId, dist(a, c));
                    }
                }
            }
        }

        Self {
            graph: b.build(),
            coords,
            width: w,
            height: h,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn flat_net(w: usize, h: usize, eps: f64) -> TerrainNet {
        let dem = Dem {
            width: w,
            height: h,
            spacing: 10.0,
            elev: vec![0.0; w * h],
        };
        TerrainNet::build(&dem, eps)
    }

    #[test]
    fn vertex_count_matches_formula() {
        let net = flat_net(4, 3, 2.0); // m = 4
        let m = 4;
        let expected = 4 * 3 + (3 * 3 + 4 * 2) * m;
        assert_eq!(net.coords.len(), expected);
        assert_eq!(net.graph.num_vertices(), expected);
    }

    #[test]
    fn shortcuts_beat_manhattan_on_flat_terrain() {
        // Paper's motivating bound: axis-only grids cannot go below the
        // Manhattan distance, shortcuts can. On flat ground the network
        // distance for a diagonal must be well under Manhattan.
        let net = flat_net(6, 6, 2.0);
        let s = net.corner(0, 0);
        let t = net.corner(5, 5);
        let d = super::super::baseline::dijkstra(&net.graph, s, Some(t)).0[t as usize];
        let manhattan = 2.0 * 5.0 * 10.0;
        let euclid = net.euclid(s, t);
        assert!(d < manhattan * 0.8, "network d {d} not beating Manhattan");
        assert!(d >= euclid - 1e-6, "network d {d} below Euclid {euclid}");
        // With ε = 2m shortcuts the detour factor should be small.
        assert!(d < euclid * 1.10, "detour {} too large", d / euclid);
    }

    #[test]
    fn weights_are_positive_3d_lengths() {
        let dem = Dem::fractal(5, 5, 10.0, 80.0, 11);
        let net = TerrainNet::build(&dem, 5.0);
        for v in 0..net.graph.num_vertices() as VertexId {
            for (&u, &w) in net.graph.out(v).iter().zip(net.graph.out_w(v)) {
                assert!(w > 0.0);
                let e = net.euclid(v, u) as f32;
                assert!((w - e).abs() < 1e-3, "weight {w} vs euclid {e}");
            }
        }
    }

    #[test]
    fn elevation_lengthens_paths() {
        let flat = flat_net(6, 6, 5.0);
        let dem = Dem::fractal(6, 6, 10.0, 120.0, 13);
        let rough = TerrainNet::build(&dem, 5.0);
        let (s, t) = (flat.corner(0, 0), flat.corner(5, 5));
        let df = super::super::baseline::dijkstra(&flat.graph, s, Some(t)).0[t as usize];
        let dr = super::super::baseline::dijkstra(&rough.graph, s, Some(t)).0[t as usize];
        assert!(dr > df, "rough terrain {dr} must be longer than flat {df}");
    }
}
