//! Baselines and geometry utilities for the terrain experiments.
//!
//! * [`dijkstra`] — serial exact shortest path on any weighted graph (the
//!   oracle for the distributed SSSP, and the engine under the CH stand-in).
//! * [`ChenHanStandIn`] — the paper benchmarks Chen & Han's polyhedron
//!   shortest-path algorithm [16, 20], which is quadratic in the number of
//!   TIN faces and runs out of memory beyond ~1km paths (Table 10a). We
//!   cannot run the authors' implementation offline; the stand-in computes
//!   the same *answer* on a densely steinerized TIN and *models* CH's cost:
//!   time ∝ (faces touched)², memory = unfolding table of the same order,
//!   returning OOM above a budget — reproducing who-wins and where CH dies
//!   (DESIGN.md §5).
//! * [`hausdorff`] — polyline Hausdorff distance (Table 10b "HDist").

use super::dem::Dem;
use super::network::TerrainNet;
use crate::graph::{Graph, VertexId};
use std::collections::BinaryHeap;

/// Serial Dijkstra over the weighted graph; returns (dist, pred). Stops
/// early when `target`'s distance is final (if provided).
pub fn dijkstra(g: &Graph, s: VertexId, target: Option<VertexId>) -> (Vec<f64>, Vec<VertexId>) {
    let n = g.num_vertices();
    let mut dist = vec![f64::INFINITY; n];
    let mut pred = vec![VertexId::MAX; n];
    let mut heap: BinaryHeap<(std::cmp::Reverse<u64>, VertexId)> = BinaryHeap::new();
    dist[s as usize] = 0.0;
    heap.push((std::cmp::Reverse(0), s));
    while let Some((std::cmp::Reverse(du), u)) = heap.pop() {
        let du = f64::from_bits(du);
        if du > dist[u as usize] {
            continue;
        }
        if Some(u) == target {
            break;
        }
        for (&v, &w) in g.out(u).iter().zip(g.out_w(u)) {
            let cand = du + w as f64;
            if cand < dist[v as usize] {
                dist[v as usize] = cand;
                pred[v as usize] = u;
                heap.push((std::cmp::Reverse(cand.to_bits()), v));
            }
        }
    }
    (dist, pred)
}

/// Extract the s→t polyline from a predecessor array.
pub fn extract_path(
    pred: &[VertexId],
    coords: &[(f64, f64, f64)],
    s: VertexId,
    t: VertexId,
) -> Option<Vec<(f64, f64, f64)>> {
    let mut path = vec![coords[t as usize]];
    let mut cur = t;
    while cur != s {
        let p = pred[cur as usize];
        if p == VertexId::MAX {
            return None;
        }
        path.push(coords[p as usize]);
        cur = p;
    }
    path.reverse();
    Some(path)
}

/// Result of a CH stand-in run.
#[derive(Debug, Clone)]
pub enum ChResult {
    /// (path length meters, modeled seconds, polyline)
    Ok {
        len: f64,
        modeled_secs: f64,
        path: Vec<(f64, f64, f64)>,
    },
    /// The modeled unfolding table exceeded the memory budget.
    Oom,
}

/// Chen–Han stand-in (see module docs).
pub struct ChenHanStandIn {
    /// Fine steinerized network over the same DEM (δ << ε).
    net: TerrainNet,
    /// Per-face-pair unfolding cost in seconds (calibrated so that a
    /// ~1e5-face workload lands in the paper's hundreds-of-seconds range).
    pub secs_per_unfold: f64,
    /// Unfolding-table memory budget in bytes.
    pub mem_budget: usize,
    faces: usize,
    spacing: f64,
}

impl ChenHanStandIn {
    pub fn new(dem: &Dem) -> Self {
        // δ = spacing/8: a dense approximation whose answers track the
        // exact surface path closely.
        let net = TerrainNet::build(dem, dem.spacing / 8.0);
        Self {
            net,
            secs_per_unfold: 2e-7,
            mem_budget: 12 << 30, // paper cluster node: 48 GB / degree of sharing
            faces: dem.tin_faces(),
            spacing: dem.spacing,
        }
    }

    /// Run one (s, t) query given grid-corner coordinates.
    pub fn query(&self, sx: usize, sy: usize, tx: usize, ty: usize) -> ChResult {
        let s = self.net.corner(sx, sy);
        let t = self.net.corner(tx, ty);
        // CH explores an ellipse of faces around the s-t segment; model the
        // touched-face count by the bounding box inflated by 50%.
        let dx = sx.abs_diff(tx).max(1) as f64;
        let dy = sy.abs_diff(ty).max(1) as f64;
        let touched_faces = (2.0 * dx * dy * 2.25).min(self.faces as f64);
        // Quadratic sequence-tree growth: unfoldings ≈ faces².
        let unfoldings = touched_faces * touched_faces;
        let mem = unfoldings * 48.0; // bytes per unfolding record
        if mem > self.mem_budget as f64 {
            return ChResult::Oom;
        }
        let (dist, pred) = dijkstra(&self.net.graph, s, Some(t));
        let len = dist[t as usize];
        let path = extract_path(&pred, &self.net.coords, s, t).unwrap_or_default();
        let _ = self.spacing;
        ChResult::Ok {
            len,
            modeled_secs: unfoldings * self.secs_per_unfold,
            path,
        }
    }
}

/// Distance from a point to a 3D segment.
fn point_seg(p: (f64, f64, f64), a: (f64, f64, f64), b: (f64, f64, f64)) -> f64 {
    let ab = (b.0 - a.0, b.1 - a.1, b.2 - a.2);
    let ap = (p.0 - a.0, p.1 - a.1, p.2 - a.2);
    let ab2 = ab.0 * ab.0 + ab.1 * ab.1 + ab.2 * ab.2;
    let t = if ab2 <= 1e-18 {
        0.0
    } else {
        ((ap.0 * ab.0 + ap.1 * ab.1 + ap.2 * ab.2) / ab2).clamp(0.0, 1.0)
    };
    let q = (a.0 + ab.0 * t, a.1 + ab.1 * t, a.2 + ab.2 * t);
    ((p.0 - q.0).powi(2) + (p.1 - q.1).powi(2) + (p.2 - q.2).powi(2)).sqrt()
}

/// One-sided Hausdorff: max over sampled points of P of distance to Q.
fn one_sided(p: &[(f64, f64, f64)], q: &[(f64, f64, f64)]) -> f64 {
    let mut worst: f64 = 0.0;
    for &pt in p {
        let mut best = f64::INFINITY;
        for w in q.windows(2) {
            best = best.min(point_seg(pt, w[0], w[1]));
            if best == 0.0 {
                break;
            }
        }
        if q.len() == 1 {
            best = point_seg(pt, q[0], q[0]);
        }
        worst = worst.max(best);
    }
    worst
}

/// Symmetric polyline Hausdorff distance (paper's HDist, [12]).
pub fn hausdorff(p: &[(f64, f64, f64)], q: &[(f64, f64, f64)]) -> f64 {
    if p.is_empty() || q.is_empty() {
        return f64::INFINITY;
    }
    one_sided(p, q).max(one_sided(q, p))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::GraphBuilder;

    #[test]
    fn dijkstra_small_weighted() {
        let mut b = GraphBuilder::new(4).undirected();
        b.wedge(0, 1, 1.0);
        b.wedge(1, 2, 1.0);
        b.wedge(0, 2, 5.0);
        b.wedge(2, 3, 1.0);
        let g = b.build();
        let (d, pred) = dijkstra(&g, 0, None);
        assert_eq!(d[2], 2.0);
        assert_eq!(d[3], 3.0);
        assert_eq!(pred[2], 1);
    }

    #[test]
    fn hausdorff_identical_is_zero() {
        let p = vec![(0.0, 0.0, 0.0), (1.0, 0.0, 0.0), (2.0, 0.0, 0.0)];
        assert!(hausdorff(&p, &p) < 1e-12);
    }

    #[test]
    fn hausdorff_parallel_lines() {
        let p = vec![(0.0, 0.0, 0.0), (10.0, 0.0, 0.0)];
        let q = vec![(0.0, 3.0, 0.0), (10.0, 3.0, 0.0)];
        let h = hausdorff(&p, &q);
        assert!((h - 3.0).abs() < 1e-9, "got {h}");
    }

    #[test]
    fn ch_standin_close_queries_ok_far_queries_oom() {
        let dem = Dem::fractal(40, 40, 10.0, 100.0, 17);
        let mut ch = ChenHanStandIn::new(&dem);
        ch.mem_budget = 64 << 20; // small budget to trigger OOM in-test
        match ch.query(0, 0, 3, 3) {
            ChResult::Ok { len, .. } => assert!(len >= 30.0),
            ChResult::Oom => panic!("short query must fit"),
        }
        match ch.query(0, 0, 39, 39) {
            ChResult::Oom => {}
            ChResult::Ok { .. } => panic!("long query must exceed the budget"),
        }
    }

    #[test]
    fn ch_time_grows_superlinearly() {
        let dem = Dem::fractal(60, 60, 10.0, 100.0, 19);
        let ch = ChenHanStandIn::new(&dem);
        let t = |d: usize| match ch.query(0, 0, d, d) {
            ChResult::Ok { modeled_secs, .. } => modeled_secs,
            ChResult::Oom => f64::INFINITY,
        };
        let (t4, t16) = (t(4), t(16));
        assert!(
            t16 > 16.0 * t4,
            "quadratic blow-up expected: {t4} -> {t16}"
        );
    }
}
