//! Digital Elevation Model substrate (paper §5.3).
//!
//! The paper uses USGS DEMs (Eagle Peak 1012×1400, Bearhead 970×1404 at 10m
//! spacing). Offline, we generate fractal terrains by multi-octave value
//! noise — smooth, deterministic, and with the elevation continuity the
//! shortest-path experiments exercise (DESIGN.md §5).

use crate::util::Rng;

/// A regular elevation grid: `width × height` samples at `spacing` meters.
#[derive(Debug, Clone)]
pub struct Dem {
    pub width: usize,
    pub height: usize,
    /// Sampling interval in meters (paper: 10m).
    pub spacing: f64,
    /// Row-major elevations in meters.
    pub elev: Vec<f64>,
}

impl Dem {
    /// Elevation at grid coordinates.
    #[inline]
    pub fn at(&self, x: usize, y: usize) -> f64 {
        self.elev[y * self.width + x]
    }

    /// Bilinear elevation at fractional grid coordinates.
    pub fn sample(&self, fx: f64, fy: f64) -> f64 {
        let x0 = (fx.floor() as usize).min(self.width - 2);
        let y0 = (fy.floor() as usize).min(self.height - 2);
        let tx = (fx - x0 as f64).clamp(0.0, 1.0);
        let ty = (fy - y0 as f64).clamp(0.0, 1.0);
        let a = self.at(x0, y0);
        let b = self.at(x0 + 1, y0);
        let c = self.at(x0, y0 + 1);
        let d = self.at(x0 + 1, y0 + 1);
        a * (1.0 - tx) * (1.0 - ty) + b * tx * (1.0 - ty) + c * (1.0 - tx) * ty + d * tx * ty
    }

    /// Number of triangular faces of the derived TIN (2 per cell, the
    /// paper's |F| column).
    pub fn tin_faces(&self) -> usize {
        2 * (self.width - 1) * (self.height - 1)
    }

    /// Generate a fractal terrain: `octaves` layers of bilinear value
    /// noise with persistence 0.5, scaled to `relief` meters of total
    /// variation.
    pub fn fractal(width: usize, height: usize, spacing: f64, relief: f64, seed: u64) -> Self {
        assert!(width >= 2 && height >= 2);
        let mut elev = vec![0.0f64; width * height];
        let octaves = 5;
        let mut amp = 1.0;
        let mut cell = (width.max(height) / 4).max(2);
        let mut total_amp = 0.0;
        for oct in 0..octaves {
            // Coarse random grid for this octave.
            let gw = width.div_ceil(cell) + 2;
            let gh = height.div_ceil(cell) + 2;
            let mut rng = Rng::new(seed ^ (0x5eed + oct as u64 * 7919));
            let grid: Vec<f64> = (0..gw * gh).map(|_| rng.f64() * 2.0 - 1.0).collect();
            for y in 0..height {
                for x in 0..width {
                    let fx = x as f64 / cell as f64;
                    let fy = y as f64 / cell as f64;
                    let x0 = fx.floor() as usize;
                    let y0 = fy.floor() as usize;
                    let tx = smooth(fx - x0 as f64);
                    let ty = smooth(fy - y0 as f64);
                    let g = |xx: usize, yy: usize| grid[yy * gw + xx];
                    let v = g(x0, y0) * (1.0 - tx) * (1.0 - ty)
                        + g(x0 + 1, y0) * tx * (1.0 - ty)
                        + g(x0, y0 + 1) * (1.0 - tx) * ty
                        + g(x0 + 1, y0 + 1) * tx * ty;
                    elev[y * width + x] += amp * v;
                }
            }
            total_amp += amp;
            amp *= 0.5;
            cell = (cell / 2).max(2);
        }
        // Normalize to [0, relief].
        let (mut lo, mut hi) = (f64::INFINITY, f64::NEG_INFINITY);
        for &e in &elev {
            lo = lo.min(e);
            hi = hi.max(e);
        }
        let span = (hi - lo).max(1e-9);
        for e in &mut elev {
            *e = (*e - lo) / span * relief;
        }
        let _ = total_amp;
        Self {
            width,
            height,
            spacing,
            elev,
        }
    }
}

#[inline]
fn smooth(t: f64) -> f64 {
    t * t * (3.0 - 2.0 * t)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fractal_is_deterministic_and_bounded() {
        let a = Dem::fractal(50, 40, 10.0, 200.0, 7);
        let b = Dem::fractal(50, 40, 10.0, 200.0, 7);
        assert_eq!(a.elev, b.elev);
        for &e in &a.elev {
            assert!((0.0..=200.0).contains(&e));
        }
    }

    #[test]
    fn fractal_is_smooth() {
        // Adjacent samples must not jump by more than a fraction of relief.
        let d = Dem::fractal(80, 80, 10.0, 100.0, 9);
        for y in 0..80 {
            for x in 0..79 {
                let delta = (d.at(x + 1, y) - d.at(x, y)).abs();
                assert!(delta < 30.0, "jump {delta} at ({x},{y})");
            }
        }
    }

    #[test]
    fn bilinear_sample_matches_corners() {
        let d = Dem::fractal(10, 10, 10.0, 50.0, 3);
        assert!((d.sample(3.0, 4.0) - d.at(3, 4)).abs() < 1e-9);
        let mid = d.sample(3.5, 4.0);
        let lo = d.at(3, 4).min(d.at(4, 4));
        let hi = d.at(3, 4).max(d.at(4, 4));
        assert!(mid >= lo - 1e-9 && mid <= hi + 1e-9);
    }

    #[test]
    fn tin_faces_count() {
        let d = Dem::fractal(11, 21, 10.0, 10.0, 1);
        assert_eq!(d.tin_faces(), 2 * 10 * 20);
    }
}
