//! Distributed terrain SSSP with Euclidean-lower-bound early termination
//! (paper §5.3).
//!
//! Standard Pregel SSSP over the ε-network, plus the paper's wavefront
//! aggregator: every vertex updated in a superstep contributes its
//! straight-line distance d_E(s, v); since d_E(s, v) ≤ d_N(s, v) and all
//! future relaxations descend from the current wavefront, the query can
//! stop as soon as the best known d_N(s, t) is below the wavefront's
//! minimum d_E — without flooding the rest of the terrain.

use super::network::TerrainNet;
use crate::graph::VertexId;
use crate::vertex::{Ctx, MasterAction, QueryApp};

/// Aggregator: best distance at t so far + wavefront Euclidean minimum.
#[derive(Debug, Clone)]
pub struct SsspAgg {
    pub best_t: f64,
    pub min_euclid: f64,
    /// Messages sent this superstep (0 ⇒ converged).
    pub sent: u64,
}

impl Default for SsspAgg {
    fn default() -> Self {
        Self {
            best_t: f64::INFINITY,
            min_euclid: f64::INFINITY,
            sent: 0,
        }
    }
}

/// Per-vertex state: tentative distance + predecessor (for path dumps).
#[derive(Debug, Clone)]
pub struct SsspState {
    pub d: f64,
    pub pred: VertexId,
}

/// Query result: distance and the s→t polyline. `PartialEq` compares the
/// floats exactly — determinism tests assert bit-identical results across
/// engine thread counts.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct SsspOut {
    pub dist: f64,
    pub path: Vec<(f64, f64, f64)>,
    pub reached: bool,
}

/// Terrain SSSP query app; query = (s, t).
pub struct TerrainSssp<'n> {
    net: &'n TerrainNet,
}

impl<'n> TerrainSssp<'n> {
    pub fn new(net: &'n TerrainNet) -> Self {
        Self { net }
    }
}

impl<'n> QueryApp for TerrainSssp<'n> {
    type Query = (VertexId, VertexId);
    type VQ = SsspState;
    /// (tentative distance, sender).
    type Msg = (f64, VertexId);
    type Agg = SsspAgg;
    type Out = SsspOut;

    fn init_activate(&self, q: &(VertexId, VertexId)) -> Vec<VertexId> {
        vec![q.0]
    }

    fn init_value(&self, q: &(VertexId, VertexId), v: VertexId) -> SsspState {
        SsspState {
            d: if v == q.0 { 0.0 } else { f64::INFINITY },
            pred: VertexId::MAX,
        }
    }

    fn compute(&self, ctx: &mut Ctx<'_, Self>, v: VertexId, st: &mut SsspState) {
        let (s, t) = *ctx.query();
        let g = &self.net.graph;
        let mut improved = ctx.superstep() == 1 && v == s;
        for &(d, from) in ctx.msgs() {
            if d < st.d {
                st.d = d;
                st.pred = from;
                improved = true;
            }
        }
        if improved {
            // Wavefront bookkeeping for the early-termination rule.
            let de = self.net.euclid(s, v);
            let dv = st.d;
            ctx.aggregate(|_, a| a.min_euclid = a.min_euclid.min(de));
            if v == t {
                ctx.aggregate(|_, a| a.best_t = a.best_t.min(dv));
            }
            let mut sent = 0u64;
            for (&u, &w) in g.out(v).iter().zip(g.out_w(v)) {
                let cand = st.d + w as f64;
                ctx.send(u, (cand, v));
                sent += 1;
            }
            ctx.aggregate(|_, a| a.sent += sent);
        }
        ctx.vote_halt();
    }

    /// Min-combiner on tentative distances.
    fn combine(&self, into: &mut (f64, VertexId), from: &(f64, VertexId)) -> bool {
        if from.0 < into.0 {
            *into = *from;
        }
        true
    }

    fn agg_merge(&self, into: &mut SsspAgg, from: &SsspAgg) {
        into.best_t = into.best_t.min(from.best_t);
        into.min_euclid = into.min_euclid.min(from.min_euclid);
        into.sent += from.sent;
    }

    fn master_step(
        &self,
        _q: &(VertexId, VertexId),
        _step: u64,
        prev: &SsspAgg,
        agg: &mut SsspAgg,
    ) -> MasterAction {
        agg.best_t = agg.best_t.min(prev.best_t);
        // Early termination: the best path to t cannot be improved by any
        // vertex whose straight-line distance from s already exceeds it.
        if agg.best_t < agg.min_euclid {
            return MasterAction::Terminate;
        }
        if agg.sent == 0 {
            return MasterAction::Terminate;
        }
        agg.min_euclid = f64::INFINITY;
        agg.sent = 0;
        MasterAction::Continue
    }

    fn finish(
        &self,
        q: &(VertexId, VertexId),
        touched: &mut dyn Iterator<Item = (VertexId, &SsspState)>,
        agg: &SsspAgg,
    ) -> SsspOut {
        let (s, t) = *q;
        // Rebuild the polyline by walking predecessors over touched state.
        let mut dmap = crate::util::FxHashMap::default();
        for (v, st) in touched {
            dmap.insert(v, (st.d, st.pred));
        }
        let Some(&(dist, _)) = dmap.get(&t) else {
            return SsspOut::default();
        };
        if dist.is_infinite() {
            return SsspOut::default();
        }
        let mut path = vec![self.net.coords[t as usize]];
        let mut cur = t;
        while cur != s {
            let Some(&(_, p)) = dmap.get(&cur) else {
                break;
            };
            if p == VertexId::MAX {
                break;
            }
            path.push(self.net.coords[p as usize]);
            cur = p;
        }
        path.reverse();
        let _ = agg;
        SsspOut {
            dist,
            path,
            reached: true,
        }
    }

    fn msg_bytes(&self) -> usize {
        12
    }
}

#[cfg(test)]
mod tests {
    use super::super::baseline::dijkstra;
    use super::super::dem::Dem;
    use super::*;
    use crate::coordinator::Engine;
    use crate::network::Cluster;

    fn small_net(seed: u64) -> TerrainNet {
        let dem = Dem::fractal(12, 10, 10.0, 80.0, seed);
        TerrainNet::build(&dem, 5.0)
    }

    #[test]
    fn sssp_matches_dijkstra() {
        let net = small_net(21);
        let n = net.graph.num_vertices();
        let app = TerrainSssp::new(&net);
        let mut eng = Engine::new(app, Cluster::new(4), n);
        for (sx, sy, tx, ty) in [(0, 0, 11, 9), (3, 2, 8, 9), (0, 9, 11, 0)] {
            let s = net.corner(sx, sy);
            let t = net.corner(tx, ty);
            let want = dijkstra(&net.graph, s, Some(t)).0[t as usize];
            let got = eng.run_one((s, t)).out;
            assert!(got.reached);
            assert!(
                (got.dist - want).abs() < 1e-6,
                "({sx},{sy})->({tx},{ty}): {} vs {want}",
                got.dist
            );
        }
    }

    #[test]
    fn early_termination_limits_access_for_close_pairs() {
        let net = small_net(23);
        let n = net.graph.num_vertices();
        let s = net.corner(0, 0);
        let close = net.corner(1, 1);
        let far = net.corner(11, 9);
        let mut eng = Engine::new(TerrainSssp::new(&net), Cluster::new(4), n);
        let r_close = eng.run_one((s, close));
        let mut eng2 = Engine::new(TerrainSssp::new(&net), Cluster::new(4), n);
        let r_far = eng2.run_one((s, far));
        assert!(r_close.out.reached && r_far.out.reached);
        assert!(
            r_close.stats.touched * 2 < r_far.stats.touched,
            "close query touched {} vs far {}",
            r_close.stats.touched,
            r_far.stats.touched
        );
    }

    #[test]
    fn path_endpoints_are_correct() {
        let net = small_net(25);
        let s = net.corner(2, 2);
        let t = net.corner(9, 7);
        let mut eng =
            Engine::new(TerrainSssp::new(&net), Cluster::new(2), net.graph.num_vertices());
        let out = eng.run_one((s, t)).out;
        assert!(out.reached);
        let first = out.path.first().unwrap();
        let last = out.path.last().unwrap();
        assert_eq!(*first, net.coords[s as usize]);
        assert_eq!(*last, net.coords[t as usize]);
        // Polyline length must equal the reported distance.
        let len: f64 = out
            .path
            .windows(2)
            .map(|w| {
                ((w[0].0 - w[1].0).powi(2) + (w[0].1 - w[1].1).powi(2) + (w[0].2 - w[1].2).powi(2))
                    .sqrt()
            })
            .sum();
        assert!((len - out.dist).abs() < 1e-6);
    }
}
