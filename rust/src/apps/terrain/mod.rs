//! Terrain shortest-path queries (paper §5.3): DEM grids, the ε-shortcut
//! network transform, distributed SSSP with Euclidean-lower-bound early
//! termination, and the Chen–Han-style exact baseline.

pub mod baseline;
pub mod dem;
pub mod network;
pub mod sssp;

pub use dem::Dem;
pub use network::TerrainNet;
pub use sssp::TerrainSssp;
