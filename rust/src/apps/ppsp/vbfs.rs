//! Plain BFS over a [`VersionedGraph`] — the index-free mutation-capable
//! PPSP app.
//!
//! [`VersionedBfs`] is [`super::Bfs`] with the adjacency reads routed
//! through the epoch overlay: each query carries the epoch pinned at its
//! admission ([`crate::vertex::QueryApp::pin_epoch`]) and traverses
//! exactly that version for its whole lifetime. No index means no
//! maintenance on mutation — [`VersionedGraph::apply`] is the entire
//! apply hook — which makes this the reference app for the serial
//! snapshot-replay oracle and the mutation-schedule fuzzer: its output on
//! a mutating engine must match plain [`super::Bfs`] on the
//! [`crate::graph::Graph::apply`]-folded snapshot of the pinned epoch.

use super::UNREACHED;
use crate::graph::{Epoch, Graph, MutationApplied, MutationBatch, VersionedGraph, VertexId};
use crate::vertex::{Ctx, QueryApp};

/// A versioned PPSP query: `(s, t, epoch)`. The epoch slot is stamped by
/// the engine at admission; submit via [`vbfs_query`].
pub type VBfsQuery = (VertexId, VertexId, Epoch);

/// Build a query for submission (the epoch is filled at admission).
#[inline]
pub fn vbfs_query(s: VertexId, t: VertexId) -> VBfsQuery {
    (s, t, 0)
}

/// BFS PPSP over a versioned graph. V-data = the overlay adjacency.
pub struct VersionedBfs {
    vg: VersionedGraph,
    /// Whale classification knob for admission-planner tests: a query is
    /// heavy iff `heavy_every != 0 && (s + t) % heavy_every == 0`. Purely
    /// content-derived, so it never perturbs the determinism contract.
    pub heavy_every: u32,
}

impl VersionedBfs {
    /// Wrap `g` as epoch 0.
    pub fn new(g: Graph) -> Self {
        Self {
            vg: VersionedGraph::new(g),
            heavy_every: 0,
        }
    }

    /// The versioned graph being served.
    pub fn graph(&self) -> &VersionedGraph {
        &self.vg
    }
}

impl QueryApp for VersionedBfs {
    type Query = VBfsQuery;
    /// d(s, v) estimate at the pinned epoch.
    type VQ = u32;
    type Msg = ();
    type Agg = ();
    type Out = Option<u32>;

    fn supports_mutations(&self) -> bool {
        true
    }

    fn apply_mutations(&mut self, batch: &MutationBatch) -> MutationApplied {
        self.vg.apply(batch)
    }

    fn pin_epoch(&self, batch: &mut [VBfsQuery], epoch: Epoch) {
        for q in batch {
            q.2 = epoch;
        }
    }

    fn retire_epochs(&mut self, oldest: Epoch) {
        self.vg.retire(oldest);
    }

    fn is_heavy(&self, q: &VBfsQuery) -> bool {
        self.heavy_every != 0 && (q.0.wrapping_add(q.1)) % self.heavy_every == 0
    }

    fn init_activate(&self, q: &VBfsQuery) -> Vec<VertexId> {
        vec![q.0]
    }

    fn init_value(&self, q: &VBfsQuery, v: VertexId) -> u32 {
        if v == q.0 {
            0
        } else {
            UNREACHED
        }
    }

    fn compute(&self, ctx: &mut Ctx<'_, Self>, v: VertexId, d: &mut u32) {
        let step = ctx.superstep();
        let (_, t, e) = *ctx.query();
        if step == 1 {
            if v == t {
                ctx.force_terminate(); // s == t: d = 0 already recorded
            }
            for &u in self.vg.out_at(v, e).iter() {
                ctx.send(u, ());
            }
            ctx.vote_halt();
            return;
        }
        if *d == UNREACHED {
            *d = (step - 1) as u32;
            if v == t {
                ctx.force_terminate();
            } else {
                for &u in self.vg.out_at(v, e).iter() {
                    ctx.send(u, ());
                }
            }
        }
        ctx.vote_halt();
    }

    fn combine(&self, _into: &mut (), _from: &()) -> bool {
        true
    }

    fn finish(
        &self,
        q: &VBfsQuery,
        touched: &mut dyn Iterator<Item = (VertexId, &u32)>,
        _agg: &(),
    ) -> Option<u32> {
        let t = q.1;
        for (v, &d) in touched {
            if v == t && d != UNREACHED {
                return Some(d);
            }
        }
        None
    }

    fn msg_bytes(&self) -> usize {
        1
    }
}

#[cfg(test)]
mod tests {
    use super::super::oracle;
    use super::*;
    use crate::coordinator::Engine;
    use crate::graph::gen;
    use crate::network::Cluster;

    #[test]
    fn matches_plain_bfs_at_epoch_zero() {
        let g = gen::twitter_like(300, 4, 61);
        let mut eng = Engine::new(VersionedBfs::new(g.clone()), Cluster::new(4), 300);
        for (s, t) in gen::random_pairs(300, 10, 62) {
            let want = oracle::bfs_dist(&g, s, t);
            let got = eng.run_one(vbfs_query(s, t)).out;
            assert_eq!(got, (want != UNREACHED).then_some(want), "({s},{t})");
        }
    }

    #[test]
    fn matches_oracle_on_the_folded_snapshot_after_mutations() {
        let g = gen::twitter_like(300, 4, 63);
        let mut eng = Engine::new(VersionedBfs::new(g.clone()), Cluster::new(4), 300);
        let mut batch = MutationBatch::new();
        for v in 0..5u32 {
            if let Some(&u) = g.out(v).first() {
                batch.delete_edge(v, u);
            }
        }
        batch.add_edge(7, 251).add_vertex().add_edge(300, 3);
        let folded = g.apply(&batch);
        eng.try_mutate(batch, 0.0).unwrap();
        for (s, t) in gen::random_pairs(300, 10, 64) {
            let r = eng.run_one(vbfs_query(s, t));
            let want = oracle::bfs_dist(&folded, s, t);
            assert_eq!(r.out, (want != UNREACHED).then_some(want), "({s},{t})");
            assert_eq!(r.stats.epoch, 1, "queries after the batch pin epoch 1");
        }
        // The new vertex is reachable through its wired arcs.
        let want = oracle::bfs_dist(&folded, 7, 300);
        assert_eq!(eng.run_one(vbfs_query(7, 300)).out, Some(want));
    }
}
