//! Plain BFS over a [`VersionedGraph`] — the index-free mutation-capable
//! PPSP app.
//!
//! [`VersionedBfs`] is [`super::Bfs`] with the adjacency reads routed
//! through the epoch overlay: each query carries the epoch pinned at its
//! admission ([`crate::vertex::QueryApp::pin_epoch`]) and traverses
//! exactly that version for its whole lifetime. No index means no
//! maintenance on mutation — [`VersionedGraph::apply`] is the entire
//! apply hook — which makes this the reference app for the serial
//! snapshot-replay oracle and the mutation-schedule fuzzer: its output on
//! a mutating engine must match plain [`super::Bfs`] on the
//! [`crate::graph::Graph::apply`]-folded snapshot of the pinned epoch.

use super::UNREACHED;
use crate::coordinator::remote::WireApp;
use crate::graph::{Epoch, Graph, MutationApplied, MutationBatch, VersionedGraph, VertexId};
use crate::network::wire::{self, put_u32, put_u64, put_u8, WireError, WireReader, WireResult};
use crate::vertex::{Ctx, QueryApp};

/// A versioned PPSP query: `(s, t, epoch)`. The epoch slot is stamped by
/// the engine at admission; submit via [`vbfs_query`].
pub type VBfsQuery = (VertexId, VertexId, Epoch);

/// Build a query for submission (the epoch is filled at admission).
#[inline]
pub fn vbfs_query(s: VertexId, t: VertexId) -> VBfsQuery {
    (s, t, 0)
}

/// BFS PPSP over a versioned graph. V-data = the overlay adjacency.
pub struct VersionedBfs {
    vg: VersionedGraph,
    /// Whale classification knob for admission-planner tests: a query is
    /// heavy iff `heavy_every != 0 && (s + t) % heavy_every == 0`. Purely
    /// content-derived, so it never perturbs the determinism contract.
    pub heavy_every: u32,
}

impl VersionedBfs {
    /// Wrap `g` as epoch 0.
    pub fn new(g: Graph) -> Self {
        Self {
            vg: VersionedGraph::new(g),
            heavy_every: 0,
        }
    }

    /// The versioned graph being served.
    pub fn graph(&self) -> &VersionedGraph {
        &self.vg
    }
}

impl QueryApp for VersionedBfs {
    type Query = VBfsQuery;
    /// d(s, v) estimate at the pinned epoch.
    type VQ = u32;
    type Msg = ();
    type Agg = ();
    type Out = Option<u32>;

    fn supports_mutations(&self) -> bool {
        true
    }

    fn apply_mutations(&mut self, batch: &MutationBatch) -> MutationApplied {
        self.vg.apply(batch)
    }

    fn pin_epoch(&self, batch: &mut [VBfsQuery], epoch: Epoch) {
        for q in batch {
            q.2 = epoch;
        }
    }

    fn retire_epochs(&mut self, oldest: Epoch) {
        self.vg.retire(oldest);
    }

    fn is_heavy(&self, q: &VBfsQuery) -> bool {
        self.heavy_every != 0 && (q.0.wrapping_add(q.1)) % self.heavy_every == 0
    }

    fn init_activate(&self, q: &VBfsQuery) -> Vec<VertexId> {
        vec![q.0]
    }

    fn init_value(&self, q: &VBfsQuery, v: VertexId) -> u32 {
        if v == q.0 {
            0
        } else {
            UNREACHED
        }
    }

    fn compute(&self, ctx: &mut Ctx<'_, Self>, v: VertexId, d: &mut u32) {
        let step = ctx.superstep();
        let (_, t, e) = *ctx.query();
        if step == 1 {
            if v == t {
                ctx.force_terminate(); // s == t: d = 0 already recorded
            }
            for &u in self.vg.out_at(v, e).iter() {
                ctx.send(u, ());
            }
            ctx.vote_halt();
            return;
        }
        if *d == UNREACHED {
            *d = (step - 1) as u32;
            if v == t {
                ctx.force_terminate();
            } else {
                for &u in self.vg.out_at(v, e).iter() {
                    ctx.send(u, ());
                }
            }
        }
        ctx.vote_halt();
    }

    fn combine(&self, _into: &mut (), _from: &()) -> bool {
        true
    }

    fn finish(
        &self,
        q: &VBfsQuery,
        touched: &mut dyn Iterator<Item = (VertexId, &u32)>,
        _agg: &(),
    ) -> Option<u32> {
        let t = q.1;
        for (v, &d) in touched {
            if v == t && d != UNREACHED {
                return Some(d);
            }
        }
        None
    }

    fn msg_bytes(&self) -> usize {
        1
    }
}

impl WireApp for VersionedBfs {
    /// Base graph + the heavy-classification knob. Shipped at worker
    /// spawn, which happens before any mutation batch can have been
    /// applied — asserted here rather than shipping the overlay chain.
    fn spec_bytes(&self) -> Vec<u8> {
        assert_eq!(
            self.vg.epoch(),
            0,
            "spawn worker processes before applying mutations"
        );
        let mut out = Vec::new();
        wire::encode_graph(self.vg.base(), &mut out);
        put_u32(&mut out, self.heavy_every);
        out
    }

    fn from_spec(r: &mut WireReader<'_>) -> WireResult<Self> {
        let g = wire::decode_graph(r)?;
        let mut app = VersionedBfs::new(g);
        app.heavy_every = r.u32()?;
        Ok(app)
    }

    fn enc_query(q: &VBfsQuery, out: &mut Vec<u8>) {
        put_u32(out, q.0);
        put_u32(out, q.1);
        put_u64(out, q.2);
    }

    fn dec_query(r: &mut WireReader<'_>) -> WireResult<VBfsQuery> {
        Ok((r.u32()?, r.u32()?, r.u64()?))
    }

    fn enc_msg(_m: &(), _out: &mut Vec<u8>) {}

    fn dec_msg(_r: &mut WireReader<'_>) -> WireResult<()> {
        Ok(())
    }

    fn enc_vq(vq: &u32, out: &mut Vec<u8>) {
        put_u32(out, *vq);
    }

    fn dec_vq(r: &mut WireReader<'_>) -> WireResult<u32> {
        r.u32()
    }

    fn enc_agg(_a: &(), _out: &mut Vec<u8>) {}

    fn dec_agg(_r: &mut WireReader<'_>) -> WireResult<()> {
        Ok(())
    }

    fn enc_out(o: &Option<u32>, out: &mut Vec<u8>) {
        match o {
            Some(d) => {
                put_u8(out, 1);
                put_u32(out, *d);
            }
            None => put_u8(out, 0),
        }
    }

    fn dec_out(r: &mut WireReader<'_>) -> WireResult<Option<u32>> {
        match r.u8()? {
            0 => Ok(None),
            1 => Ok(Some(r.u32()?)),
            _ => Err(WireError::Corrupt("option flag")),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::super::oracle;
    use super::*;
    use crate::coordinator::Engine;
    use crate::graph::gen;
    use crate::network::Cluster;

    #[test]
    fn wire_codecs_round_trip_and_reject_corrupt_bytes() {
        use crate::network::wire::WireReader;

        // Query codec.
        let q = (7u32, 911u32, 3u64);
        let mut buf = Vec::new();
        VersionedBfs::enc_query(&q, &mut buf);
        let mut r = WireReader::new(&buf);
        assert_eq!(VersionedBfs::dec_query(&mut r).unwrap(), q);
        r.expect_end().unwrap();

        // Out codec: both variants, bad flag is an error, never a panic.
        for o in [None, Some(42u32)] {
            let mut buf = Vec::new();
            VersionedBfs::enc_out(&o, &mut buf);
            let mut r = WireReader::new(&buf);
            assert_eq!(VersionedBfs::dec_out(&mut r).unwrap(), o);
            r.expect_end().unwrap();
        }
        let mut r = WireReader::new(&[9u8]);
        assert!(VersionedBfs::dec_out(&mut r).is_err());

        // Spec round trip rebuilds an identical replica: same adjacency,
        // same heavy knob.
        let g = gen::twitter_like(80, 3, 41);
        let mut app = VersionedBfs::new(g.clone());
        app.heavy_every = 5;
        let spec = app.spec_bytes();
        let mut r = WireReader::new(&spec);
        let back = VersionedBfs::from_spec(&mut r).unwrap();
        r.expect_end().unwrap();
        assert_eq!(back.heavy_every, 5);
        assert_eq!(back.vg.base().num_vertices(), g.num_vertices());
        for v in 0..g.num_vertices() as u32 {
            assert_eq!(back.vg.base().out(v), g.out(v));
        }
        // Every truncation of the spec errors.
        for cut in [0, 1, spec.len() / 2, spec.len() - 1] {
            let mut r = WireReader::new(&spec[..cut]);
            assert!(VersionedBfs::from_spec(&mut r).is_err());
        }
    }

    #[test]
    fn matches_plain_bfs_at_epoch_zero() {
        let g = gen::twitter_like(300, 4, 61);
        let mut eng = Engine::new(VersionedBfs::new(g.clone()), Cluster::new(4), 300);
        for (s, t) in gen::random_pairs(300, 10, 62) {
            let want = oracle::bfs_dist(&g, s, t);
            let got = eng.run_one(vbfs_query(s, t)).out;
            assert_eq!(got, (want != UNREACHED).then_some(want), "({s},{t})");
        }
    }

    #[test]
    fn matches_oracle_on_the_folded_snapshot_after_mutations() {
        let g = gen::twitter_like(300, 4, 63);
        let mut eng = Engine::new(VersionedBfs::new(g.clone()), Cluster::new(4), 300);
        let mut batch = MutationBatch::new();
        for v in 0..5u32 {
            if let Some(&u) = g.out(v).first() {
                batch.delete_edge(v, u);
            }
        }
        batch.add_edge(7, 251).add_vertex().add_edge(300, 3);
        let folded = g.apply(&batch);
        eng.try_mutate(batch, 0.0).unwrap();
        for (s, t) in gen::random_pairs(300, 10, 64) {
            let r = eng.run_one(vbfs_query(s, t));
            let want = oracle::bfs_dist(&folded, s, t);
            assert_eq!(r.out, (want != UNREACHED).then_some(want), "({s},{t})");
            assert_eq!(r.stats.epoch, 1, "queries after the batch pin epoch 1");
        }
        // The new vertex is reachable through its wired arcs.
        let want = oracle::bfs_dist(&folded, 7, 300);
        assert_eq!(eng.run_one(vbfs_query(7, 300)).out, Some(want));
    }
}
